//! Cluster management (paper §4.4): membership, heartbeats, failure
//! detection, and the post-failure cleanup contract.
//!
//! The CM is a centralized service (the paper's "cluster management
//! module"): instances register, send periodic heartbeats, and receive
//! epoch-stamped membership broadcasts. When an instance misses
//! `max_misses` heartbeat intervals it is declared dead; the CM bumps the
//! epoch and the broadcast tells every survivor to (a) release memory
//! blocks owned by the dead instance (addresses encode the owner) and
//! (b) drop it from global prompt trees. Pure logic here — the transport
//! wiring lives in [`crate::server`] and the failover example.

use std::collections::BTreeMap;

use crate::mempool::InstanceId;
use crate::scheduler::prompt_tree::InstanceKind;

#[derive(Clone, Copy, Debug, PartialEq)]
pub struct MemberInfo {
    pub kind: InstanceKind,
    pub last_heartbeat: f64,
    pub alive: bool,
}

/// Epoch-stamped membership snapshot (what gets broadcast).
#[derive(Clone, Debug, PartialEq)]
pub struct Membership {
    pub epoch: u64,
    pub alive: Vec<(InstanceId, InstanceKind)>,
}

pub struct ClusterManager {
    members: BTreeMap<InstanceId, MemberInfo>,
    epoch: u64,
    heartbeat_interval_s: f64,
    max_misses: u32,
}

impl ClusterManager {
    pub fn new(heartbeat_interval_s: f64, max_misses: u32) -> Self {
        assert!(heartbeat_interval_s > 0.0 && max_misses > 0);
        ClusterManager {
            members: BTreeMap::new(),
            epoch: 0,
            heartbeat_interval_s,
            max_misses,
        }
    }

    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// Register (or re-register) an instance; bumps the epoch.
    pub fn register(&mut self, id: InstanceId, kind: InstanceKind, now: f64)
                    -> Membership {
        self.members.insert(
            id,
            MemberInfo {
                kind,
                last_heartbeat: now,
                alive: true,
            },
        );
        self.epoch += 1;
        self.membership()
    }

    /// Graceful removal (scale-down) — also epoch-bumping.
    pub fn deregister(&mut self, id: InstanceId) -> Membership {
        if self.members.remove(&id).is_some() {
            self.epoch += 1;
        }
        self.membership()
    }

    /// Record a heartbeat.
    pub fn heartbeat(&mut self, id: InstanceId, now: f64) {
        if let Some(m) = self.members.get_mut(&id) {
            m.last_heartbeat = now;
            if !m.alive {
                // An instance returning from the dead re-registers with a
                // new epoch (its state is gone; peers released its blocks).
                m.alive = true;
                self.epoch += 1;
            }
        }
    }

    /// Failure sweep: returns instances *newly* declared dead at `now`
    /// (the caller broadcasts the new membership when non-empty).
    pub fn sweep(&mut self, now: f64) -> Vec<InstanceId> {
        let deadline = self.heartbeat_interval_s * self.max_misses as f64;
        let mut newly_dead = vec![];
        for (id, m) in self.members.iter_mut() {
            if m.alive && now - m.last_heartbeat > deadline {
                m.alive = false;
                newly_dead.push(*id);
            }
        }
        if !newly_dead.is_empty() {
            self.epoch += 1;
        }
        newly_dead
    }

    pub fn membership(&self) -> Membership {
        Membership {
            epoch: self.epoch,
            alive: self
                .members
                .iter()
                .filter(|(_, m)| m.alive)
                .map(|(id, m)| (*id, m.kind))
                .collect(),
        }
    }

    pub fn is_alive(&self, id: InstanceId) -> bool {
        self.members.get(&id).map(|m| m.alive).unwrap_or(false)
    }

    /// Heartbeat miss streaks at `now`, in heartbeat intervals, for
    /// every *live* member (dead ones already tripped the sweep) —
    /// the ISSUE 9 watchdog's `hb.miss_streak` feed. A healthy member
    /// sits below 1.0; the sweep kills at `max_misses`.
    pub fn miss_streaks(&self, now: f64) -> Vec<(u32, f64)> {
        self.members
            .iter()
            .filter(|(_, m)| m.alive)
            .map(|(id, m)| {
                let streak =
                    (now - m.last_heartbeat) / self.heartbeat_interval_s;
                (id.0, streak.max(0.0))
            })
            .collect()
    }
}

/// Survivor-side cleanup after a membership change: what every instance
/// must do with a dead peer (paper §4.4). Returns a human-readable action
/// log (the server applies the actions; tests assert on them).
pub fn survivor_actions(dead: &[InstanceId]) -> Vec<String> {
    let mut out = vec![];
    for d in dead {
        out.push(format!("release blocks allocated by {d}"));
        out.push(format!("abort in-flight transfers to/from {d}"));
        out.push(format!("drop {d} from global prompt trees"));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cm() -> ClusterManager {
        ClusterManager::new(0.1, 3)
    }

    #[test]
    fn register_and_membership() {
        let mut c = cm();
        c.register(InstanceId(0), InstanceKind::PrefillOnly, 0.0);
        let m = c.register(InstanceId(1), InstanceKind::DecodeOnly, 0.0);
        assert_eq!(m.epoch, 2);
        assert_eq!(m.alive.len(), 2);
    }

    #[test]
    fn missed_heartbeats_kill() {
        let mut c = cm();
        c.register(InstanceId(0), InstanceKind::Colocated, 0.0);
        c.register(InstanceId(1), InstanceKind::Colocated, 0.0);
        // 1 keeps beating; 0 goes silent.
        for i in 1..=5 {
            c.heartbeat(InstanceId(1), i as f64 * 0.1);
        }
        assert!(c.sweep(0.25).is_empty(), "too early to kill");
        let dead = c.sweep(0.5);
        assert_eq!(dead, vec![InstanceId(0)]);
        assert!(!c.is_alive(InstanceId(0)));
        assert!(c.is_alive(InstanceId(1)));
        // Idempotent: already-dead not re-reported.
        c.heartbeat(InstanceId(1), 0.9);
        assert!(c.sweep(1.0).is_empty());
    }

    #[test]
    fn epoch_bumps_on_every_change() {
        let mut c = cm();
        let e0 = c.register(InstanceId(0), InstanceKind::Colocated, 0.0).epoch;
        c.heartbeat(InstanceId(0), 0.05);
        assert_eq!(c.epoch(), e0, "heartbeat must not bump epoch");
        c.sweep(10.0);
        assert_eq!(c.epoch(), e0 + 1);
        c.heartbeat(InstanceId(0), 10.1); // resurrection
        assert_eq!(c.epoch(), e0 + 2);
        assert!(c.is_alive(InstanceId(0)));
    }

    #[test]
    fn miss_streaks_report_live_members_in_intervals() {
        let mut c = ClusterManager::new(0.1, 3);
        c.register(InstanceId(0), InstanceKind::PrefillOnly, 0.0);
        c.register(InstanceId(1), InstanceKind::DecodeOnly, 0.0);
        c.heartbeat(InstanceId(0), 0.4);
        let s = c.miss_streaks(0.5);
        assert_eq!(s.len(), 2);
        assert!((s[0].1 - 1.0).abs() < 1e-9, "one interval behind");
        assert!((s[1].1 - 5.0).abs() < 1e-9, "five intervals behind");
        c.sweep(0.5); // kills instance 1 (deadline 0.3)
        let s = c.miss_streaks(0.5);
        assert_eq!(s.len(), 1, "dead members leave the streak report");
        assert_eq!(s[0].0, 0);
    }

    #[test]
    fn deregister_is_graceful() {
        let mut c = cm();
        c.register(InstanceId(0), InstanceKind::Colocated, 0.0);
        c.register(InstanceId(1), InstanceKind::Colocated, 0.0);
        let m = c.deregister(InstanceId(0));
        assert_eq!(m.alive.len(), 1);
        assert!(c.deregister(InstanceId(9)).epoch == m.epoch, "no-op");
    }

    #[test]
    fn survivor_action_contract() {
        let a = survivor_actions(&[InstanceId(3)]);
        assert_eq!(a.len(), 3);
        assert!(a[0].contains("release blocks"));
        assert!(a[2].contains("prompt trees"));
    }
}
