//! Serving metrics: per-request lifecycle records and aggregated digests.
//!
//! The paper reports TTFT (time-to-first-token), JCT (job completion
//! time), and TPOT (time-per-output-token); Fig 8/15 report mean and P99.
//! All times are f64 seconds on whatever clock the caller uses (real or
//! virtual), so the same code serves both the live server and the
//! discrete-event simulator.

use std::collections::BTreeMap;

use crate::util::stats::Samples;

/// One request's lifecycle timestamps (seconds, caller's clock).
#[derive(Clone, Debug, Default)]
pub struct RequestRecord {
    pub request_id: u64,
    pub session_id: u64,
    pub arrival: f64,
    pub scheduled: f64,
    pub first_token: f64,
    pub completion: f64,
    pub prompt_tokens: usize,
    pub cached_tokens: usize,
    pub output_tokens: usize,
    /// Which instance ran prefill / decode (same for colocated).
    pub prefill_instance: u32,
    pub decode_instance: u32,
}

impl RequestRecord {
    pub fn ttft(&self) -> f64 {
        self.first_token - self.arrival
    }

    pub fn jct(&self) -> f64 {
        self.completion - self.arrival
    }

    /// Time per output token over the decode stretch. The first token is
    /// produced by prefill, so TPOT divides by (output_tokens - 1).
    pub fn tpot(&self) -> f64 {
        if self.output_tokens <= 1 {
            return 0.0;
        }
        (self.completion - self.first_token) / (self.output_tokens - 1) as f64
    }

    pub fn queueing(&self) -> f64 {
        self.scheduled - self.arrival
    }

    pub fn cached_ratio(&self) -> f64 {
        if self.prompt_tokens == 0 {
            return 0.0;
        }
        self.cached_tokens as f64 / self.prompt_tokens as f64
    }
}

/// Aggregate over completed requests + system counters.
#[derive(Clone, Debug, Default)]
pub struct Metrics {
    pub records: Vec<RequestRecord>,
    pub counters: BTreeMap<String, u64>,
}

/// The digest the benches print: (mean, p50, p99, max) per metric.
#[derive(Clone, Copy, Debug, Default)]
pub struct Digest {
    pub mean: f64,
    pub p50: f64,
    pub p99: f64,
    pub max: f64,
    pub n: usize,
}

impl Metrics {
    pub fn push(&mut self, r: RequestRecord) {
        self.records.push(r);
    }

    pub fn bump(&mut self, counter: &str, by: u64) {
        *self.counters.entry(counter.to_string()).or_insert(0) += by;
    }

    pub fn counter(&self, name: &str) -> u64 {
        self.counters.get(name).copied().unwrap_or(0)
    }

    pub fn merge(&mut self, other: &Metrics) {
        self.records.extend(other.records.iter().cloned());
        for (k, v) in &other.counters {
            *self.counters.entry(k.clone()).or_insert(0) += v;
        }
    }

    fn digest_of<F: Fn(&RequestRecord) -> f64>(&self, f: F) -> Digest {
        let mut s = Samples::new();
        for r in &self.records {
            s.push(f(r));
        }
        if s.is_empty() {
            return Digest::default();
        }
        let (mean, p50, p99, max) = s.digest();
        Digest {
            mean,
            p50,
            p99,
            max,
            n: s.len(),
        }
    }

    pub fn ttft(&self) -> Digest {
        self.digest_of(|r| r.ttft())
    }

    pub fn jct(&self) -> Digest {
        self.digest_of(|r| r.jct())
    }

    pub fn tpot(&self) -> Digest {
        self.digest_of(|r| r.tpot())
    }

    pub fn queueing(&self) -> Digest {
        self.digest_of(|r| r.queueing())
    }

    pub fn mean_cached_ratio(&self) -> f64 {
        if self.records.is_empty() {
            return 0.0;
        }
        self.records.iter().map(|r| r.cached_ratio()).sum::<f64>()
            / self.records.len() as f64
    }

    /// Completed requests per second over the observed span.
    pub fn throughput(&self) -> f64 {
        if self.records.len() < 2 {
            return 0.0;
        }
        let t0 = self
            .records
            .iter()
            .map(|r| r.arrival)
            .fold(f64::INFINITY, f64::min);
        let t1 = self
            .records
            .iter()
            .map(|r| r.completion)
            .fold(f64::NEG_INFINITY, f64::max);
        if t1 <= t0 {
            return 0.0;
        }
        self.records.len() as f64 / (t1 - t0)
    }

    pub fn summary_line(&self) -> String {
        let jct = self.jct();
        let ttft = self.ttft();
        let tpot = self.tpot();
        format!(
            "n={} jct(mean={:.3}s p99={:.3}s) ttft(mean={:.3}s p99={:.3}s) \
             tpot(mean={:.4}s) cached_ratio={:.2}",
            self.records.len(),
            jct.mean,
            jct.p99,
            ttft.mean,
            ttft.p99,
            tpot.mean,
            self.mean_cached_ratio()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(arrival: f64, first: f64, done: f64, out: usize) -> RequestRecord {
        RequestRecord {
            arrival,
            scheduled: arrival,
            first_token: first,
            completion: done,
            prompt_tokens: 100,
            cached_tokens: 50,
            output_tokens: out,
            ..Default::default()
        }
    }

    #[test]
    fn per_request_metrics() {
        let r = rec(1.0, 1.5, 3.5, 21);
        assert!((r.ttft() - 0.5).abs() < 1e-12);
        assert!((r.jct() - 2.5).abs() < 1e-12);
        assert!((r.tpot() - 0.1).abs() < 1e-12);
        assert!((r.cached_ratio() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn tpot_single_token_is_zero() {
        assert_eq!(rec(0.0, 1.0, 1.0, 1).tpot(), 0.0);
    }

    #[test]
    fn digests() {
        let mut m = Metrics::default();
        for i in 0..100 {
            m.push(rec(0.0, 1.0 + i as f64 * 0.01, 2.0, 2));
        }
        let d = m.ttft();
        assert_eq!(d.n, 100);
        assert!((d.mean - 1.495).abs() < 1e-9, "{}", d.mean);
        assert!(d.p99 >= 1.97);
    }

    #[test]
    fn counters_and_merge() {
        let mut a = Metrics::default();
        a.bump("cache_hit_tokens", 5);
        let mut b = Metrics::default();
        b.bump("cache_hit_tokens", 7);
        b.push(rec(0.0, 1.0, 2.0, 3));
        a.merge(&b);
        assert_eq!(a.counter("cache_hit_tokens"), 12);
        assert_eq!(a.records.len(), 1);
        assert_eq!(a.counter("missing"), 0);
    }

    #[test]
    fn throughput() {
        let mut m = Metrics::default();
        m.push(rec(0.0, 0.5, 1.0, 2));
        m.push(rec(1.0, 1.5, 2.0, 2));
        assert!((m.throughput() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn empty_digests_are_zero() {
        let m = Metrics::default();
        assert_eq!(m.ttft().n, 0);
        assert_eq!(m.throughput(), 0.0);
    }
}
