//! Control-plane flight recorder (ISSUE 8 tentpole, part 3).
//!
//! A bounded ring buffer of recent control-plane events — deltas
//! applied, heartbeats, suspicion, promotion, fence epochs, member
//! deregistration — kept per node (live leader, or the sim as a
//! whole). When the failure detector fires, the leader dumps the ring
//! to the bench-JSON sink, turning fig18-style blackout debugging from
//! stderr-log archaeology into a replayable artifact: the dump shows
//! exactly which heartbeats were missed, which deltas had landed, and
//! what the promotion handshake did, in caller-clock order.
//!
//! Recording is a mutex push + ring rotation — control-plane events
//! are tens-per-second, not per-request, so no atomics heroics are
//! needed here (the per-request paths go through `obs::trace` and
//! `obs::registry` instead).

use std::collections::VecDeque;
use std::sync::{Arc, Mutex};

use crate::util::json::Json;

/// Event kinds recorded into the ring. Kept as constants (not an
/// enum) so call sites read like log lines and new kinds don't need a
/// cross-file type change.
pub mod kind {
    pub const HEARTBEAT: &str = "heartbeat";
    pub const DELTA: &str = "delta";
    pub const SUSPICION: &str = "suspicion";
    pub const PROMOTION: &str = "promotion";
    pub const FENCE: &str = "fence";
    pub const MEMBERSHIP: &str = "membership";
    pub const DEREGISTER: &str = "deregister";
    pub const FAILOVER: &str = "failover";
    /// Watchdog invariant violation (ISSUE 9) — detail carries the
    /// rule name and subject metric.
    pub const ALERT: &str = "alert";
}

/// One recorded control-plane event.
#[derive(Clone, Debug, PartialEq)]
pub struct FlightEvent {
    /// Caller-clock seconds.
    pub t: f64,
    /// Node that observed the event (`u32::MAX` = leader).
    pub node: u32,
    pub kind: &'static str,
    pub detail: String,
}

struct State {
    ring: VecDeque<FlightEvent>,
    cap: usize,
    /// Total recorded, including rotated-out events.
    total: u64,
    /// Dumps taken (suspicion firings that produced an artifact).
    dumps: u64,
}

/// Shared bounded recorder; clones share the ring.
#[derive(Clone)]
pub struct FlightRecorder(Arc<Mutex<State>>);

pub const DEFAULT_FLIGHT_CAP: usize = 512;

impl Default for FlightRecorder {
    fn default() -> Self {
        FlightRecorder::new(DEFAULT_FLIGHT_CAP)
    }
}

impl FlightRecorder {
    pub fn new(cap: usize) -> Self {
        FlightRecorder(Arc::new(Mutex::new(State {
            ring: VecDeque::with_capacity(cap.min(4096)),
            cap: cap.max(1),
            total: 0,
            dumps: 0,
        })))
    }

    pub fn record(
        &self,
        t: f64,
        node: u32,
        kind: &'static str,
        detail: impl Into<String>,
    ) {
        let mut st = self.0.lock().unwrap();
        if st.ring.len() >= st.cap {
            st.ring.pop_front();
        }
        st.ring.push_back(FlightEvent {
            t,
            node,
            kind,
            detail: detail.into(),
        });
        st.total += 1;
    }

    pub fn events(&self) -> Vec<FlightEvent> {
        self.0.lock().unwrap().ring.iter().cloned().collect()
    }

    pub fn len(&self) -> usize {
        self.0.lock().unwrap().ring.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Total events ever recorded (survives ring rotation).
    pub fn total(&self) -> u64 {
        self.0.lock().unwrap().total
    }

    /// Events rotated out of the ring (`total - len`) — the overflow
    /// signal the cluster view scrapes.
    pub fn dropped(&self) -> u64 {
        let st = self.0.lock().unwrap();
        st.total - st.ring.len() as u64
    }

    pub fn dumps(&self) -> u64 {
        self.0.lock().unwrap().dumps
    }

    /// Events of one kind, oldest first.
    pub fn of_kind(&self, kind: &str) -> Vec<FlightEvent> {
        self.0
            .lock()
            .unwrap()
            .ring
            .iter()
            .filter(|e| e.kind == kind)
            .cloned()
            .collect()
    }

    pub fn to_json(&self) -> Json {
        let st = self.0.lock().unwrap();
        let evs: Vec<Json> = st
            .ring
            .iter()
            .map(|e| {
                Json::obj(vec![
                    ("t", Json::num(e.t)),
                    ("node", Json::num(e.node as f64)),
                    ("kind", Json::str(e.kind)),
                    ("detail", Json::str(&e.detail)),
                ])
            })
            .collect();
        Json::obj(vec![
            ("total", Json::num(st.total as f64)),
            ("dumps", Json::num(st.dumps as f64)),
            ("events", Json::arr(evs)),
        ])
    }

    /// Dump the ring to `<dir>/<name>.json`. Returns the path written,
    /// or `None` (recording the attempt either way) if the write
    /// failed — observability must never take the control plane down.
    pub fn dump_to(&self, dir: &str, name: &str) -> Option<String> {
        let text = self.to_json().to_string();
        self.0.lock().unwrap().dumps += 1;
        if std::fs::create_dir_all(dir).is_err() {
            return None;
        }
        let path = format!("{dir}/{name}.json");
        match std::fs::write(&path, text) {
            Ok(()) => Some(path),
            Err(_) => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_rotates_at_cap() {
        let fr = FlightRecorder::new(3);
        for i in 0..5 {
            fr.record(i as f64, 0, kind::HEARTBEAT, format!("beat {i}"));
        }
        let evs = fr.events();
        assert_eq!(evs.len(), 3);
        assert_eq!(evs[0].detail, "beat 2"); // oldest two rotated out
        assert_eq!(fr.total(), 5);
    }

    #[test]
    fn of_kind_filters() {
        let fr = FlightRecorder::default();
        fr.record(1.0, 0, kind::HEARTBEAT, "beat");
        fr.record(2.0, 7, kind::SUSPICION, "instance 7 missed 3 beats");
        fr.record(3.0, 0, kind::PROMOTION, "shard 0 -> instance 2");
        assert_eq!(fr.of_kind(kind::SUSPICION).len(), 1);
        assert_eq!(fr.of_kind(kind::SUSPICION)[0].node, 7);
    }

    /// ISSUE 9 satellite: the default 512-cap ring under sustained
    /// overflow — oldest-first eviction order, exact dropped
    /// accounting, and a stable survivor window.
    #[test]
    fn default_cap_wraparound_ordering_and_dropped() {
        let fr = FlightRecorder::default();
        let n = DEFAULT_FLIGHT_CAP + 88;
        for i in 0..n {
            fr.record(i as f64, 0, kind::DELTA, format!("seq {i}"));
        }
        assert_eq!(fr.len(), DEFAULT_FLIGHT_CAP);
        assert_eq!(fr.total(), n as u64);
        assert_eq!(fr.dropped(), 88);
        let evs = fr.events();
        // Survivors are exactly the newest `cap`, still oldest-first.
        assert_eq!(evs[0].detail, "seq 88");
        assert_eq!(evs.last().unwrap().detail, format!("seq {}", n - 1));
        for w in evs.windows(2) {
            assert!(w[1].t > w[0].t, "ring order broke under rotation");
        }
    }

    /// ISSUE 9 satellite: `dump_to` accounting on both outcomes — a
    /// successful dump writes the artifact, a failed one (unwritable
    /// dir) returns `None`, and *both* count as dump attempts.
    #[test]
    fn dump_to_counts_attempts_and_survives_write_failure() {
        let fr = FlightRecorder::default();
        fr.record(1.0, 0, kind::ALERT, "repl_lag_growing: shard 0");
        let dir = std::env::temp_dir().join("memserve_flight_dump_test");
        let dir = dir.to_str().unwrap().to_string();
        let p = fr.dump_to(&dir, "wrap").expect("dump writes");
        assert_eq!(fr.dumps(), 1);
        let j = Json::parse(&std::fs::read_to_string(&p).unwrap()).unwrap();
        assert_eq!(
            j.at(&["events"]).unwrap().as_arr().unwrap()[0]
                .at(&["kind"])
                .unwrap()
                .as_str(),
            Some("alert")
        );
        // A file where the directory should be: create_dir_all fails,
        // dump returns None, attempt still counted.
        let blocked = std::env::temp_dir().join("memserve_flight_blocked");
        std::fs::write(&blocked, b"not a dir").unwrap();
        let bad = blocked.join("sub");
        assert!(fr.dump_to(bad.to_str().unwrap(), "x").is_none());
        assert_eq!(fr.dumps(), 2, "failed dump still counts the attempt");
    }

    #[test]
    fn json_dump_roundtrips() {
        let fr = FlightRecorder::default();
        fr.record(0.5, 3, kind::DELTA, "applied seq 12..15");
        let j = Json::parse(&fr.to_json().to_string()).unwrap();
        assert_eq!(j.at(&["total"]).unwrap().as_f64(), Some(1.0));
        let evs = j.at(&["events"]).unwrap().as_arr().unwrap();
        assert_eq!(evs[0].at(&["kind"]).unwrap().as_str(), Some("delta"));
        assert_eq!(
            evs[0].at(&["detail"]).unwrap().as_str(),
            Some("applied seq 12..15")
        );
    }
}
