//! Per-request latency attribution (ISSUE 9 tentpole, part 2).
//!
//! Two consumers of the same signal, at two granularities:
//!
//! * [`breakdown`] — a pure function over the span events
//!   [`super::trace::TraceSink`] already closes, decomposing each
//!   request's wall time into route / queue-wait / prefill compute /
//!   KV-transfer / decode. Because the leader and the sim close the
//!   phases edge-to-edge (QUEUE ends where PREFILL begins, and so on),
//!   the phase sum reconstructs the span's wall time — fig20 asserts
//!   the two agree within 1% on both the live and virtual clocks.
//! * [`AttribBook`] — windowed per-instance digests: each phase
//!   duration, TTFT, TBT, and the observed-vs-Eq.1-predicted prefill
//!   cost error, observed into registry histograms so the
//!   [`super::timeline::Timeline`] carries per-window percentiles.
//!   The cost-error histogram (`attrib.cost_err_pm`) is the
//!   calibration signal the ROADMAP's SLO-admission item consumes:
//!   admission can only trust Eq. 1's TTFT prediction as far as this
//!   distribution is tight.
//!
//! Everything here is record-only: pure reads of trace events plus
//! relaxed-atomic histogram bumps. No routing or clock input depends
//! on it.

use std::collections::{BTreeMap, HashMap};
use std::sync::Mutex;

use crate::obs::registry::{Histo, Labels, Registry};
use crate::obs::trace::{phase, TraceEvent};
use crate::util::json::Json;

/// Spans with either high bit set are migration/promotion spans, not
/// requests (see `trace::migration_span` / `trace::promotion_span`).
const NON_REQUEST_BITS: u64 = (1 << 62) | (1 << 63);

/// One request's reconstructed latency decomposition, in seconds.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Breakdown {
    pub route_s: f64,
    pub queue_s: f64,
    pub prefill_s: f64,
    pub kv_transfer_s: f64,
    pub decode_s: f64,
    /// Earliest phase start seen for the span.
    pub t_start: f64,
    /// Latest phase end seen for the span.
    pub t_end: f64,
    /// Phase events folded in.
    pub phases: usize,
}

impl Breakdown {
    /// Sum of the attributed phase durations.
    pub fn total(&self) -> f64 {
        self.route_s
            + self.queue_s
            + self.prefill_s
            + self.kv_transfer_s
            + self.decode_s
    }

    /// End-to-end wall time of the span chain. When phases tile (each
    /// begins where the last ended), `total() == wall()`.
    pub fn wall(&self) -> f64 {
        (self.t_end - self.t_start).max(0.0)
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("route_s", Json::num(self.route_s)),
            ("queue_s", Json::num(self.queue_s)),
            ("prefill_s", Json::num(self.prefill_s)),
            ("kv_transfer_s", Json::num(self.kv_transfer_s)),
            ("decode_s", Json::num(self.decode_s)),
            ("wall_s", Json::num(self.wall())),
        ])
    }
}

/// Decompose every *request* span (migration/promotion spans are
/// skipped) in `events` into a [`Breakdown`], keyed by span id. Pure:
/// call it on `sink.events()` at any point, any clock.
pub fn breakdown(events: &[TraceEvent]) -> BTreeMap<u64, Breakdown> {
    let mut out: BTreeMap<u64, Breakdown> = BTreeMap::new();
    for ev in events {
        if ev.span & NON_REQUEST_BITS != 0 {
            continue;
        }
        let d = (ev.t1 - ev.t0).max(0.0);
        let b = out.entry(ev.span).or_insert_with(|| Breakdown {
            t_start: ev.t0,
            t_end: ev.t1,
            ..Default::default()
        });
        match ev.phase {
            phase::ROUTE => b.route_s += d,
            phase::QUEUE => b.queue_s += d,
            phase::PREFILL => b.prefill_s += d,
            phase::KV_TRANSFER => b.kv_transfer_s += d,
            phase::DECODE => b.decode_s += d,
            // RETIRE is a zero-width marker; MIGRATE/PROMOTE never
            // appear on request spans.
            _ => {}
        }
        b.t_start = b.t_start.min(ev.t0);
        b.t_end = b.t_end.max(ev.t1);
        b.phases += 1;
    }
    out
}

/// What the leader (or sim) knows about a request when it retires —
/// the inputs for the retire-side digests.
#[derive(Clone, Copy, Debug)]
pub struct RetireSample {
    pub arrival: f64,
    pub scheduled: f64,
    pub first_token: f64,
    pub completion: f64,
    pub output_tokens: usize,
    /// Eq. 1's prefill-time prediction captured at route
    /// (`RouteOutcome::expected_prefill_s`); `<= 0` means the route
    /// path recorded no prediction and the cost-error digest is
    /// skipped.
    pub predicted_prefill_s: f64,
}

struct Handles {
    queue_us: Histo,
    prefill_us: Histo,
    kv_transfer_us: Histo,
    decode_us: Histo,
    ttft_us: Histo,
    tbt_us: Histo,
    cost_err_pm: Histo,
}

/// Per-instance attribution digests over a shared [`Registry`].
/// Handles are registered once per instance and cached; registration
/// is idempotent, so the leader, every instance thread, and the sim
/// can each hold their own book over the same registry.
pub struct AttribBook {
    reg: Registry,
    handles: Mutex<HashMap<u32, Handles>>,
}

impl AttribBook {
    pub fn new(reg: &Registry) -> Self {
        AttribBook {
            reg: reg.clone(),
            handles: Mutex::new(HashMap::new()),
        }
    }

    fn with_handles<R>(
        &self,
        instance: u32,
        f: impl FnOnce(&Handles) -> R,
    ) -> R {
        let mut map = self.handles.lock().unwrap();
        let h = map.entry(instance).or_insert_with(|| {
            let l = Labels::instance(instance);
            Handles {
                queue_us: self.reg.histogram("attrib.queue_us", l),
                prefill_us: self.reg.histogram("attrib.prefill_us", l),
                kv_transfer_us: self
                    .reg
                    .histogram("attrib.kv_transfer_us", l),
                decode_us: self.reg.histogram("attrib.decode_us", l),
                ttft_us: self.reg.histogram("lat.ttft_us", l),
                tbt_us: self.reg.histogram("lat.tbt_us", l),
                cost_err_pm: self.reg.histogram("attrib.cost_err_pm", l),
            }
        });
        f(h)
    }

    /// Observe one phase duration for `instance` — the instance-side
    /// feed at the exact points the trace phases close. Non-attributed
    /// phases (ROUTE/RETIRE/MIGRATE/PROMOTE) are ignored.
    pub fn observe_phase_secs(
        &self,
        instance: u32,
        ph: &'static str,
        secs: f64,
    ) {
        if !self.reg.enabled() {
            return;
        }
        self.with_handles(instance, |h| match ph {
            phase::QUEUE => h.queue_us.observe_secs(secs),
            phase::PREFILL => h.prefill_us.observe_secs(secs),
            phase::KV_TRANSFER => h.kv_transfer_us.observe_secs(secs),
            phase::DECODE => h.decode_us.observe_secs(secs),
            _ => {}
        });
    }

    /// Retire-side digests: queue wait, TTFT, TBT, and the
    /// observed-vs-predicted prefill cost error (per mille, absolute).
    /// `instance` is the prefill instance the request ran on.
    pub fn observe_retire(&self, instance: u32, s: &RetireSample) {
        if !self.reg.enabled() {
            return;
        }
        self.with_handles(instance, |h| {
            h.queue_us.observe_secs(s.scheduled - s.arrival);
            h.ttft_us.observe_secs(s.first_token - s.arrival);
            if s.output_tokens > 1 {
                h.tbt_us.observe_secs(
                    (s.completion - s.first_token)
                        / (s.output_tokens - 1) as f64,
                );
            }
            if s.predicted_prefill_s > 0.0 {
                let observed = (s.first_token - s.scheduled).max(0.0);
                let err = (observed - s.predicted_prefill_s).abs()
                    / s.predicted_prefill_s;
                h.cost_err_pm.observe((err * 1000.0).min(1e9) as u64);
            }
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::trace::{self, TraceSink};

    /// A disaggregated request whose phases tile edge-to-edge
    /// reconstructs its wall time exactly.
    #[test]
    fn breakdown_sums_to_wall_when_phases_tile() {
        let sink = TraceSink::new(true);
        let span = trace::request_span(7);
        sink.complete(span, phase::ROUTE, u32::MAX, 1.0, 1.0);
        sink.complete(span, phase::QUEUE, 0, 1.0, 1.5);
        sink.complete(span, phase::PREFILL, 0, 1.5, 2.25);
        sink.complete(span, phase::KV_TRANSFER, 1, 2.25, 2.5);
        sink.complete(span, phase::DECODE, 1, 2.5, 4.0);
        sink.complete(span, phase::RETIRE, 1, 4.0, 4.0);
        let map = breakdown(&sink.events());
        let b = map.get(&span).expect("request span decomposed");
        assert_eq!(b.queue_s, 0.5);
        assert_eq!(b.prefill_s, 0.75);
        assert_eq!(b.kv_transfer_s, 0.25);
        assert_eq!(b.decode_s, 1.5);
        assert!((b.total() - b.wall()).abs() < 1e-12);
        assert_eq!(b.wall(), 3.0);
    }

    #[test]
    fn breakdown_skips_non_request_spans() {
        let sink = TraceSink::new(true);
        sink.complete(
            trace::migration_span(3),
            phase::MIGRATE,
            0,
            0.0,
            1.0,
        );
        sink.complete(
            trace::promotion_span(1),
            phase::PROMOTE,
            0,
            0.0,
            1.0,
        );
        sink.complete(trace::request_span(9), phase::QUEUE, 0, 0.0, 0.5);
        let map = breakdown(&sink.events());
        assert_eq!(map.len(), 1);
        assert!(map.contains_key(&trace::request_span(9)));
    }

    #[test]
    fn retire_digests_feed_per_instance_histograms() {
        let reg = Registry::new(true);
        let book = AttribBook::new(&reg);
        book.observe_retire(
            2,
            &RetireSample {
                arrival: 0.0,
                scheduled: 0.5,
                first_token: 1.5,
                completion: 3.5,
                output_tokens: 5,
                predicted_prefill_s: 0.8,
            },
        );
        let snap = reg.snapshot(4.0);
        let q = snap.histo("attrib.queue_us{instance=2}").unwrap();
        assert_eq!(q.count, 1);
        assert_eq!(q.sum, 500_000, "0.5s queue wait in µs");
        let ttft = snap.histo("lat.ttft_us{instance=2}").unwrap();
        assert_eq!(ttft.sum, 1_500_000);
        let tbt = snap.histo("lat.tbt_us{instance=2}").unwrap();
        assert_eq!(tbt.sum, 500_000, "2s decode over 4 gaps");
        let err = snap.histo("attrib.cost_err_pm{instance=2}").unwrap();
        // observed 1.0s vs predicted 0.8s → 25% → 250‰.
        assert_eq!(err.sum, 250);
    }

    #[test]
    fn phase_feed_routes_to_the_right_histogram() {
        let reg = Registry::new(true);
        let book = AttribBook::new(&reg);
        book.observe_phase_secs(0, phase::PREFILL, 0.25);
        book.observe_phase_secs(0, phase::KV_TRANSFER, 0.125);
        book.observe_phase_secs(0, phase::ROUTE, 9.0); // ignored
        let snap = reg.snapshot(1.0);
        assert_eq!(
            snap.histo("attrib.prefill_us{instance=0}").unwrap().sum,
            250_000
        );
        assert_eq!(
            snap.histo("attrib.kv_transfer_us{instance=0}")
                .unwrap()
                .sum,
            125_000
        );
        assert_eq!(snap.counter_sum("attrib.route"), 0);
    }

    #[test]
    fn disabled_registry_records_nothing() {
        let reg = Registry::new(false);
        let book = AttribBook::new(&reg);
        book.observe_phase_secs(0, phase::DECODE, 1.0);
        book.observe_retire(
            0,
            &RetireSample {
                arrival: 0.0,
                scheduled: 0.0,
                first_token: 1.0,
                completion: 2.0,
                output_tokens: 2,
                predicted_prefill_s: 1.0,
            },
        );
        assert!(reg.snapshot(0.0).entries.is_empty());
    }
}
