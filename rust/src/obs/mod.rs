//! Cluster-wide observability (ISSUE 8 recording layer + ISSUE 9
//! analysis layer).
//!
//! Recording (ISSUE 8), threaded through every layer of the stack:
//!
//! * [`registry`] — atomic counters/gauges and log2-bucket histograms
//!   with mergeable snapshots, labeled instance/shard/tier. `&self`
//!   everywhere, one relaxed load when disabled. Snapshots export as
//!   JSON or Prometheus text exposition.
//! * [`trace`] — request-scoped spans (route → queue → prefill →
//!   kv_transfer → decode → retire, plus migration/promotion),
//!   idempotent under PR 6 message replay, exported as Chrome
//!   trace-event JSON. Knob: `MEMSERVE_TRACE`.
//! * [`flight`] — bounded ring of control-plane events, dumped to the
//!   bench-JSON sink when the failure detector (or the watchdog)
//!   fires.
//! * [`view`] — periodic leader scrape folding per-instance stats
//!   (`PoolStats`, `NetStats`, replication lag, trace/flight health)
//!   into one cluster view.
//!
//! Analysis (ISSUE 9), fed by the same scrape cadence:
//!
//! * [`timeline`] — a bounded ring of windowed frames over registry
//!   snapshots: per-window counter deltas, end-of-window gauges, and
//!   per-window histogram digests (TTFT/TBT/route-µs percentiles per
//!   second, not since boot).
//! * [`attrib`] — per-request latency decomposition from the closed
//!   span chains (pure), plus per-instance phase/TTFT/TBT digests and
//!   the observed-vs-Eq.1-predicted prefill cost error recorded at
//!   retire.
//! * [`watchdog`] — rule-based online invariant checks over timeline
//!   frames (growing replication lag, GS belief divergence, touch
//!   backlog, span-chain incompleteness, heartbeat-miss streaks),
//!   firing structured alerts into the flight recorder. Record-only:
//!   no decision consumes an alert.
//!
//! Knobs: `MEMSERVE_METRICS=0|off` disables the registry;
//! `MEMSERVE_TRACE=1` (or any non-`0`/`off` value) enables tracing.
//! Both live and sim clocks work unchanged: every timestamp is
//! caller-clock f64 seconds.

pub mod attrib;
pub mod flight;
pub mod registry;
pub mod timeline;
pub mod trace;
pub mod view;
pub mod watchdog;

pub use attrib::{breakdown, AttribBook, Breakdown, RetireSample};
pub use flight::{FlightEvent, FlightRecorder};
pub use registry::{
    Counter, Gauge, Histo, HistoSnapshot, Labels, MetricValue, ObsSnapshot,
    Registry,
};
pub use timeline::{Frame, Timeline, TimelineConfig};
pub use trace::{TraceEvent, TraceSink};
pub use view::ClusterView;
pub use watchdog::{Alert, Watchdog, WatchdogConfig};
