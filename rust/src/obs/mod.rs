//! Cluster-wide observability (ISSUE 8).
//!
//! Four pieces, threaded through every layer of the serving stack:
//!
//! * [`registry`] — atomic counters/gauges and log2-bucket histograms
//!   with mergeable snapshots, labeled instance/shard/tier. `&self`
//!   everywhere, one relaxed load when disabled.
//! * [`trace`] — request-scoped spans (route → queue → prefill →
//!   kv_transfer → decode → retire, plus migration/promotion),
//!   idempotent under PR 6 message replay, exported as Chrome
//!   trace-event JSON. Knob: `MEMSERVE_TRACE`.
//! * [`flight`] — bounded ring of control-plane events, dumped to the
//!   bench-JSON sink when the failure detector fires.
//! * [`view`] — periodic leader scrape folding per-instance stats
//!   (`PoolStats`, `NetStats`, replication lag) into one cluster view.
//!
//! Knobs: `MEMSERVE_METRICS=0|off` disables the registry;
//! `MEMSERVE_TRACE=1` (or any non-`0`/`off` value) enables tracing.
//! Both live and sim clocks work unchanged: every timestamp is
//! caller-clock f64 seconds.

pub mod flight;
pub mod registry;
pub mod trace;
pub mod view;

pub use flight::{FlightEvent, FlightRecorder};
pub use registry::{
    Counter, Gauge, Histo, HistoSnapshot, Labels, MetricValue, ObsSnapshot,
    Registry,
};
pub use trace::{TraceEvent, TraceSink};
pub use view::ClusterView;
