//! Low-overhead metric registry (ISSUE 8 tentpole, part 1).
//!
//! Three metric kinds behind cheap clonable handles:
//!
//! * [`Counter`] — monotonic `u64` (relaxed `fetch_add`);
//! * [`Gauge`] — last-write-wins `f64` (stored as bits);
//! * [`Histo`] — fixed log2-bucket histogram over non-negative `u64`
//!   values (64 buckets: bucket *b* spans `[2^b, 2^(b+1))`, bucket 0
//!   also holds 0), with count and sum so snapshots carry the mean.
//!
//! Every write path is `&self` over relaxed atomics, so the PR 7
//! lock-free read paths (MemPool `match_prefix`, fabric `send`) can
//! carry handles without reintroducing locks. The registry's disabled
//! mode short-circuits each write after **one** relaxed load — the
//! fig19 overhead gate holds the instrumented route path within 5% of
//! the uninstrumented baseline either way.
//!
//! Registration (`counter`/`gauge`/`histogram`) takes a short `RwLock`
//! write; callers register once and keep the handle. Metrics are keyed
//! by a static name plus [`Labels`] (instance/shard/tier — the three
//! dimensions the MemServe fleet actually has). [`Registry::snapshot`]
//! produces a mergeable [`ObsSnapshot`]; merging sums counters and
//! histogram buckets and last-write-wins gauges, so per-instance or
//! per-run snapshots fold into one cluster view.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, RwLock};

use crate::util::json::Json;

/// Histogram bucket count: bucket `b` spans `[2^b, 2^(b+1))` for
/// `b ≥ 1`; bucket 0 holds `{0, 1}`. 64 buckets cover the full u64
/// range, so microsecond-scaled observations never clamp.
pub const HISTO_BUCKETS: usize = 64;

/// Metric labels — the fleet's three dimensions. `None` means the
/// metric is cluster-global on that axis.
#[derive(
    Clone, Copy, Debug, Default, PartialEq, Eq, Hash, PartialOrd, Ord,
)]
pub struct Labels {
    pub instance: Option<u32>,
    pub shard: Option<u32>,
    pub tier: Option<&'static str>,
}

impl Labels {
    pub fn none() -> Self {
        Labels::default()
    }

    pub fn instance(id: u32) -> Self {
        Labels {
            instance: Some(id),
            ..Default::default()
        }
    }

    pub fn shard(s: u32) -> Self {
        Labels {
            shard: Some(s),
            ..Default::default()
        }
    }

    pub fn with_tier(mut self, tier: &'static str) -> Self {
        self.tier = Some(tier);
        self
    }

    /// `{instance=3,shard=1,tier=hbm}`, or `""` when unlabeled — the
    /// suffix of the snapshot key.
    pub fn render(&self) -> String {
        let mut parts: Vec<String> = vec![];
        if let Some(i) = self.instance {
            parts.push(format!("instance={i}"));
        }
        if let Some(s) = self.shard {
            parts.push(format!("shard={s}"));
        }
        if let Some(t) = self.tier {
            parts.push(format!("tier={t}"));
        }
        if parts.is_empty() {
            String::new()
        } else {
            format!("{{{}}}", parts.join(","))
        }
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
struct MetricKey {
    name: &'static str,
    labels: Labels,
}

struct HistoCore {
    buckets: [AtomicU64; HISTO_BUCKETS],
    count: AtomicU64,
    sum: AtomicU64,
}

impl HistoCore {
    fn new() -> Self {
        HistoCore {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
        }
    }
}

/// `floor(log2(max(v, 1)))` — the log2 bucket index.
#[inline]
fn bucket_of(v: u64) -> usize {
    (63 - (v | 1).leading_zeros()) as usize
}

enum Slot {
    Counter(Arc<AtomicU64>),
    Gauge(Arc<AtomicU64>),
    Histo(Arc<HistoCore>),
}

struct Shared {
    enabled: Arc<AtomicBool>,
    slots: RwLock<BTreeMap<MetricKey, Slot>>,
}

/// The process-wide (or sim-wide) metric registry. Clones share state.
#[derive(Clone)]
pub struct Registry(Arc<Shared>);

impl Registry {
    pub fn new(enabled: bool) -> Self {
        Registry(Arc::new(Shared {
            enabled: Arc::new(AtomicBool::new(enabled)),
            slots: RwLock::new(BTreeMap::new()),
        }))
    }

    /// Enabled unless `MEMSERVE_METRICS` is `0`/`off`.
    pub fn from_env() -> Self {
        let off = matches!(
            std::env::var("MEMSERVE_METRICS").as_deref(),
            Ok("0") | Ok("off")
        );
        Registry::new(!off)
    }

    pub fn disabled() -> Self {
        Registry::new(false)
    }

    pub fn enabled(&self) -> bool {
        // ordering: Relaxed — on/off flag; handles re-check it per
        // call, and no other memory is published through it.
        self.0.enabled.load(Ordering::Relaxed)
    }

    pub fn set_enabled(&self, on: bool) {
        // ordering: Relaxed — see `enabled`.
        self.0.enabled.store(on, Ordering::Relaxed);
    }

    /// Register (or look up) a counter. Idempotent by (name, labels);
    /// a kind mismatch on an existing key panics — that is a naming
    /// bug, not a runtime condition.
    pub fn counter(&self, name: &'static str, labels: Labels) -> Counter {
        let key = MetricKey { name, labels };
        let v = {
            let mut slots = self.0.slots.write().unwrap();
            match slots
                .entry(key)
                .or_insert_with(|| Slot::Counter(Arc::new(AtomicU64::new(0))))
            {
                Slot::Counter(v) => Arc::clone(v),
                _ => panic!("metric {name} registered with another kind"),
            }
        };
        Counter {
            on: Arc::clone(&self.0.enabled),
            v,
        }
    }

    pub fn gauge(&self, name: &'static str, labels: Labels) -> Gauge {
        let key = MetricKey { name, labels };
        let v = {
            let mut slots = self.0.slots.write().unwrap();
            match slots
                .entry(key)
                .or_insert_with(|| Slot::Gauge(Arc::new(AtomicU64::new(0))))
            {
                Slot::Gauge(v) => Arc::clone(v),
                _ => panic!("metric {name} registered with another kind"),
            }
        };
        Gauge {
            on: Arc::clone(&self.0.enabled),
            v,
        }
    }

    pub fn histogram(&self, name: &'static str, labels: Labels) -> Histo {
        let key = MetricKey { name, labels };
        let core = {
            let mut slots = self.0.slots.write().unwrap();
            match slots
                .entry(key)
                .or_insert_with(|| Slot::Histo(Arc::new(HistoCore::new())))
            {
                Slot::Histo(c) => Arc::clone(c),
                _ => panic!("metric {name} registered with another kind"),
            }
        };
        Histo {
            on: Arc::clone(&self.0.enabled),
            core,
        }
    }

    /// Absolute fold-in of an externally-accumulated total (the scrape
    /// path: `NetStats`, `PoolStats`, replication lag). Idempotent —
    /// repeated scrapes overwrite rather than double-count.
    pub fn set_counter(&self, name: &'static str, labels: Labels, v: u64) {
        self.counter(name, labels).set(v);
    }

    pub fn set_gauge(&self, name: &'static str, labels: Labels, x: f64) {
        self.gauge(name, labels).set(x);
    }

    /// A point-in-time mergeable snapshot of every registered metric.
    pub fn snapshot(&self, at: f64) -> ObsSnapshot {
        let slots = self.0.slots.read().unwrap();
        let mut entries = BTreeMap::new();
        for (key, slot) in slots.iter() {
            let rendered = format!("{}{}", key.name, key.labels.render());
            let value = match slot {
                Slot::Counter(v) => {
                    // ordering: Relaxed — snapshot reads are
                    // point-in-time; no cross-metric consistency.
                    MetricValue::Counter(v.load(Ordering::Relaxed))
                }
                Slot::Gauge(v) => MetricValue::Gauge(f64::from_bits(
                    // ordering: Relaxed — as above.
                    v.load(Ordering::Relaxed),
                )),
                Slot::Histo(c) => MetricValue::Histo(HistoSnapshot {
                    // ordering: Relaxed — as above; a histogram may
                    // tear between cells, tolerated by the merge.
                    count: c.count.load(Ordering::Relaxed),
                    sum: c.sum.load(Ordering::Relaxed),
                    buckets: c
                        .buckets
                        .iter()
                        // ordering: Relaxed — as above.
                        .map(|b| b.load(Ordering::Relaxed))
                        .collect(),
                }),
            };
            entries.insert(rendered, value);
        }
        ObsSnapshot { at, entries }
    }
}

/// Monotonic counter handle (see module docs for the fast path).
#[derive(Clone)]
pub struct Counter {
    on: Arc<AtomicBool>,
    v: Arc<AtomicU64>,
}

impl Counter {
    #[inline]
    pub fn inc(&self, n: u64) {
        // ordering: Relaxed — monotonic standalone counter; nothing
        // is published through it.
        if self.on.load(Ordering::Relaxed) {
            self.v.fetch_add(n, Ordering::Relaxed);
        }
    }

    /// Absolute store — the scrape fold path (not gated on `enabled`,
    /// so a final snapshot can be folded even after metrics are
    /// switched off mid-drain).
    pub fn set(&self, n: u64) {
        // ordering: Relaxed — absolute fold-path store; see `inc`.
        self.v.store(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        // ordering: Relaxed — point-in-time read; see `inc`.
        self.v.load(Ordering::Relaxed)
    }
}

/// Last-write-wins f64 gauge handle.
#[derive(Clone)]
pub struct Gauge {
    on: Arc<AtomicBool>,
    v: Arc<AtomicU64>,
}

impl Gauge {
    #[inline]
    pub fn set(&self, x: f64) {
        // ordering: Relaxed — last-write-wins gauge bits.
        if self.on.load(Ordering::Relaxed) {
            self.v.store(x.to_bits(), Ordering::Relaxed);
        }
    }

    pub fn get(&self) -> f64 {
        // ordering: Relaxed — point-in-time gauge read.
        f64::from_bits(self.v.load(Ordering::Relaxed))
    }
}

/// Log2-bucket histogram handle.
#[derive(Clone)]
pub struct Histo {
    on: Arc<AtomicBool>,
    core: Arc<HistoCore>,
}

impl Histo {
    #[inline]
    pub fn observe(&self, v: u64) {
        // ordering: Relaxed — on/off flag; see `Registry::enabled`.
        if !self.on.load(Ordering::Relaxed) {
            return;
        }
        // ordering: Relaxed — independent cells; a concurrent scrape
        // may tear between them, which the merge tolerates.
        self.core.buckets[bucket_of(v)].fetch_add(1, Ordering::Relaxed);
        self.core.count.fetch_add(1, Ordering::Relaxed);
        self.core.sum.fetch_add(v, Ordering::Relaxed);
    }

    /// Observe a duration in seconds, bucketed at microsecond scale.
    #[inline]
    pub fn observe_secs(&self, s: f64) {
        // ordering: Relaxed — on/off flag; see `Registry::enabled`.
        if !self.on.load(Ordering::Relaxed) {
            return;
        }
        self.observe((s.max(0.0) * 1e6) as u64);
    }
}

/// One histogram's frozen buckets — mergeable, with approximate
/// percentiles (linear interpolation inside the matched log2 bucket,
/// so worst-case relative error is the bucket width: a factor of 2).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct HistoSnapshot {
    pub buckets: Vec<u64>,
    pub count: u64,
    pub sum: u64,
}

impl HistoSnapshot {
    pub fn merge(&mut self, other: &HistoSnapshot) {
        if self.buckets.len() < other.buckets.len() {
            self.buckets.resize(other.buckets.len(), 0);
        }
        for (a, b) in self.buckets.iter_mut().zip(&other.buckets) {
            *a += b;
        }
        self.count += other.count;
        self.sum += other.sum;
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            f64::NAN
        } else {
            self.sum as f64 / self.count as f64
        }
    }

    /// Approximate percentile, `p` in `[0, 100]`.
    pub fn percentile(&self, p: f64) -> f64 {
        if self.count == 0 {
            return f64::NAN;
        }
        let rank = (p / 100.0).clamp(0.0, 1.0) * (self.count - 1) as f64;
        let mut below = 0u64;
        for (b, &n) in self.buckets.iter().enumerate() {
            if n == 0 {
                continue;
            }
            if rank < (below + n) as f64 {
                let lo = if b == 0 { 0.0 } else { (1u64 << b) as f64 };
                let hi = (1u128 << (b + 1)) as f64;
                let frac =
                    ((rank - below as f64) / n as f64).clamp(0.0, 1.0);
                return lo + frac * (hi - lo);
            }
            below += n;
        }
        // rank == count - 1 landed past the loop due to fp rounding:
        // the top of the highest occupied bucket.
        let top = self.buckets.iter().rposition(|&n| n > 0).unwrap_or(0);
        (1u128 << (top + 1)) as f64
    }

    pub fn p50(&self) -> f64 {
        self.percentile(50.0)
    }

    pub fn p99(&self) -> f64 {
        self.percentile(99.0)
    }
}

/// One snapshot entry's value.
#[derive(Clone, Debug, PartialEq)]
pub enum MetricValue {
    Counter(u64),
    Gauge(f64),
    Histo(HistoSnapshot),
}

/// A frozen, mergeable view of a registry (or of a whole cluster, once
/// per-instance snapshots are folded together).
#[derive(Clone, Debug, Default)]
pub struct ObsSnapshot {
    /// Caller-clock seconds the snapshot was taken at.
    pub at: f64,
    /// `name{labels}` → value, sorted by key.
    pub entries: BTreeMap<String, MetricValue>,
}

impl ObsSnapshot {
    /// Fold `other` in: counters and histograms sum; gauges (and the
    /// timestamp) are last-write-wins.
    pub fn merge(&mut self, other: &ObsSnapshot) {
        self.at = self.at.max(other.at);
        for (k, v) in &other.entries {
            match (self.entries.get_mut(k), v) {
                (
                    Some(MetricValue::Counter(a)),
                    MetricValue::Counter(b),
                ) => *a += b,
                (Some(MetricValue::Histo(a)), MetricValue::Histo(b)) => {
                    a.merge(b)
                }
                (Some(slot), v) => *slot = v.clone(),
                (None, v) => {
                    self.entries.insert(k.clone(), v.clone());
                }
            }
        }
    }

    pub fn counter(&self, key: &str) -> u64 {
        match self.entries.get(key) {
            Some(MetricValue::Counter(v)) => *v,
            _ => 0,
        }
    }

    pub fn gauge(&self, key: &str) -> f64 {
        match self.entries.get(key) {
            Some(MetricValue::Gauge(v)) => *v,
            _ => f64::NAN,
        }
    }

    pub fn histo(&self, key: &str) -> Option<&HistoSnapshot> {
        match self.entries.get(key) {
            Some(MetricValue::Histo(h)) => Some(h),
            _ => None,
        }
    }

    /// Sum every counter whose key starts with `prefix` — the
    /// cluster-view roll-up over per-instance labels.
    pub fn counter_sum(&self, prefix: &str) -> u64 {
        self.entries
            .iter()
            .filter(|(k, _)| k.starts_with(prefix))
            .filter_map(|(_, v)| match v {
                MetricValue::Counter(n) => Some(*n),
                _ => None,
            })
            .sum()
    }

    /// Prometheus text exposition (ISSUE 9 satellite): every entry as
    /// `memserve_<name with dots as underscores>{label="v",…}`, with
    /// one `# TYPE` line per family. Histograms export cumulative
    /// `_bucket` series with `le` at each occupied log2 bucket's upper
    /// bound (`2^(b+1)`), then `+Inf`, `_count`, and `_sum` — the
    /// shape `histogram_quantile()` expects.
    pub fn to_prometheus(&self) -> String {
        let mut out = String::new();
        let mut typed: std::collections::BTreeSet<String> =
            std::collections::BTreeSet::new();
        for (key, v) in &self.entries {
            let (name, raw_labels) = match key.split_once('{') {
                Some((n, rest)) => {
                    (n, rest.trim_end_matches('}').to_string())
                }
                None => (key.as_str(), String::new()),
            };
            let fam = format!("memserve_{}", name.replace('.', "_"));
            let pairs: Vec<String> = raw_labels
                .split(',')
                .filter(|p| !p.is_empty())
                .filter_map(|p| {
                    p.split_once('=')
                        .map(|(k, val)| format!("{k}=\"{val}\""))
                })
                .collect();
            let label_set = |extra: Option<String>| -> String {
                let mut all = pairs.clone();
                if let Some(e) = extra {
                    all.push(e);
                }
                if all.is_empty() {
                    String::new()
                } else {
                    format!("{{{}}}", all.join(","))
                }
            };
            let kind = match v {
                MetricValue::Counter(_) => "counter",
                MetricValue::Gauge(_) => "gauge",
                MetricValue::Histo(_) => "histogram",
            };
            if typed.insert(fam.clone()) {
                out.push_str(&format!("# TYPE {fam} {kind}\n"));
            }
            match v {
                MetricValue::Counter(n) => {
                    out.push_str(&format!("{fam}{} {n}\n", label_set(None)));
                }
                MetricValue::Gauge(x) => {
                    let x = if x.is_finite() { *x } else { 0.0 };
                    out.push_str(&format!("{fam}{} {x}\n", label_set(None)));
                }
                MetricValue::Histo(h) => {
                    let mut cum = 0u64;
                    for (b, &n) in h.buckets.iter().enumerate() {
                        if n == 0 {
                            continue;
                        }
                        cum += n;
                        let le = (1u128 << (b + 1)).to_string();
                        out.push_str(&format!(
                            "{fam}_bucket{} {cum}\n",
                            label_set(Some(format!("le=\"{le}\"")))
                        ));
                    }
                    out.push_str(&format!(
                        "{fam}_bucket{} {}\n",
                        label_set(Some("le=\"+Inf\"".to_string())),
                        h.count
                    ));
                    out.push_str(&format!(
                        "{fam}_count{} {}\n",
                        label_set(None),
                        h.count
                    ));
                    out.push_str(&format!(
                        "{fam}_sum{} {}\n",
                        label_set(None),
                        h.sum
                    ));
                }
            }
        }
        out
    }

    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        for (k, v) in &self.entries {
            let j = match v {
                MetricValue::Counter(n) => Json::num(*n as f64),
                MetricValue::Gauge(x) => {
                    Json::num(if x.is_finite() { *x } else { 0.0 })
                }
                MetricValue::Histo(h) => Json::obj(vec![
                    ("count", Json::num(h.count as f64)),
                    ("sum", Json::num(h.sum as f64)),
                    ("mean", Json::num(if h.count > 0 {
                        h.mean()
                    } else {
                        0.0
                    })),
                    ("p50", Json::num(if h.count > 0 { h.p50() } else { 0.0 })),
                    ("p99", Json::num(if h.count > 0 { h.p99() } else { 0.0 })),
                    (
                        "buckets",
                        Json::arr(
                            h.buckets
                                .iter()
                                .map(|&b| Json::num(b as f64))
                                .collect(),
                        ),
                    ),
                ]),
            };
            m.insert(k.clone(), j);
        }
        Json::obj(vec![
            ("at", Json::num(self.at)),
            ("metrics", Json::Obj(m)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::Samples;

    #[test]
    fn counters_and_gauges_roundtrip() {
        let r = Registry::new(true);
        let c = r.counter("test.count", Labels::instance(3));
        c.inc(2);
        c.inc(5);
        let g = r.gauge("test.gauge", Labels::shard(1).with_tier("hbm"));
        g.set(0.25);
        let snap = r.snapshot(1.0);
        assert_eq!(snap.counter("test.count{instance=3}"), 7);
        assert_eq!(snap.gauge("test.gauge{shard=1,tier=hbm}"), 0.25);
        // Handles are shared: a second registration sees the total.
        assert_eq!(r.counter("test.count", Labels::instance(3)).get(), 7);
    }

    #[test]
    fn disabled_mode_is_inert() {
        let r = Registry::new(false);
        let c = r.counter("x", Labels::none());
        let h = r.histogram("h", Labels::none());
        c.inc(10);
        h.observe(100);
        assert_eq!(r.snapshot(0.0).counter("x"), 0);
        assert_eq!(r.snapshot(0.0).histo("h").unwrap().count, 0);
        // set() bypasses the gate (scrape fold contract).
        c.set(4);
        assert_eq!(r.snapshot(0.0).counter("x"), 4);
        r.set_enabled(true);
        c.inc(1);
        assert_eq!(r.snapshot(0.0).counter("x"), 5);
    }

    #[test]
    fn log2_bucket_indexing() {
        assert_eq!(bucket_of(0), 0);
        assert_eq!(bucket_of(1), 0);
        assert_eq!(bucket_of(2), 1);
        assert_eq!(bucket_of(3), 1);
        assert_eq!(bucket_of(4), 2);
        assert_eq!(bucket_of(1023), 9);
        assert_eq!(bucket_of(1024), 10);
        assert_eq!(bucket_of(u64::MAX), 63);
    }

    /// ISSUE 8 satellite: histogram percentiles track exact `Samples`
    /// percentiles within the log2-bucket error bound (a factor of 2,
    /// much tighter in practice with in-bucket interpolation) on known
    /// distributions.
    #[test]
    fn histo_percentiles_track_samples() {
        let mut state = 0xD15EA5Eu64;
        // Uniform over [0, 64k) and a heavy-tailed power-ish mix.
        let uniform: Vec<u64> = (0..20_000)
            .map(|_| crate::util::rng::splitmix64(&mut state) % 65_536)
            .collect();
        let tailed: Vec<u64> = (0..20_000)
            .map(|_| {
                let r = crate::util::rng::splitmix64(&mut state);
                1 + (r % 100) * (r % 1000) * (1 + r % 7)
            })
            .collect();
        for xs in [&uniform, &tailed] {
            let r = Registry::new(true);
            let h = r.histogram("lat", Labels::none());
            let mut s = Samples::unbounded();
            for &x in xs.iter() {
                h.observe(x);
                s.push(x as f64);
            }
            let snap = r.snapshot(0.0);
            let hs = snap.histo("lat").unwrap();
            assert_eq!(hs.count, xs.len() as u64);
            for p in [10.0, 50.0, 90.0, 99.0] {
                let exact = s.percentile(p).max(1.0);
                let approx = hs.percentile(p).max(1.0);
                let ratio = approx / exact;
                assert!(
                    (0.5..=2.0).contains(&ratio),
                    "p{p}: approx {approx} vs exact {exact}"
                );
            }
            assert!(
                (hs.mean() - s.mean()).abs() / s.mean() < 1e-9,
                "sum/count mean is exact"
            );
        }
    }

    /// Merging two half-snapshots equals observing the whole stream
    /// into one histogram — the cluster-fold property.
    #[test]
    fn snapshot_merge_equals_single_stream() {
        let mut state = 7u64;
        let xs: Vec<u64> = (0..5000)
            .map(|_| crate::util::rng::splitmix64(&mut state) % 1_000_000)
            .collect();
        let whole = Registry::new(true);
        let hw = whole.histogram("lat", Labels::none());
        let cw = whole.counter("n", Labels::none());
        let (a, b) = (Registry::new(true), Registry::new(true));
        let (ha, hb) = (
            a.histogram("lat", Labels::none()),
            b.histogram("lat", Labels::none()),
        );
        let (ca, cb) =
            (a.counter("n", Labels::none()), b.counter("n", Labels::none()));
        for (i, &x) in xs.iter().enumerate() {
            hw.observe(x);
            cw.inc(1);
            if i % 2 == 0 {
                ha.observe(x);
                ca.inc(1);
            } else {
                hb.observe(x);
                cb.inc(1);
            }
        }
        let mut merged = a.snapshot(1.0);
        merged.merge(&b.snapshot(2.0));
        let want = whole.snapshot(2.0);
        assert_eq!(merged.counter("n"), want.counter("n"));
        assert_eq!(merged.histo("lat"), want.histo("lat"));
        assert_eq!(merged.at, 2.0);
    }

    #[test]
    fn counter_sum_rolls_up_labels() {
        let r = Registry::new(true);
        r.counter("pool.matches", Labels::instance(0)).inc(3);
        r.counter("pool.matches", Labels::instance(1)).inc(4);
        r.counter("other", Labels::none()).inc(9);
        let snap = r.snapshot(0.0);
        assert_eq!(snap.counter_sum("pool.matches"), 7);
    }

    /// ISSUE 9 satellite: Prometheus exposition of the README
    /// metric-naming table — counters/gauges/histograms with
    /// instance/shard/tier labels.
    #[test]
    fn prometheus_exposition_matches_naming_table() {
        let r = Registry::new(true);
        r.counter("sched.routes", Labels::shard(1)).inc(12);
        r.counter("sched.routes", Labels::shard(0)).inc(3);
        r.counter("pool.swapped_out", Labels::instance(2).with_tier("dram"))
            .inc(4);
        r.gauge(
            "repl.ack_lag",
            Labels {
                instance: Some(3),
                shard: Some(1),
                tier: None,
            },
        )
        .set(2.5);
        let h = r.histogram("sched.matched_tokens", Labels::shard(0));
        h.observe(3); // bucket 1 → le=4
        h.observe(100); // bucket 6 → le=128
        r.counter("net.messages", Labels::none()).inc(9);
        let text = r.snapshot(0.0).to_prometheus();

        for line in [
            "# TYPE memserve_sched_routes counter",
            "memserve_sched_routes{shard=\"0\"} 3",
            "memserve_sched_routes{shard=\"1\"} 12",
            "memserve_pool_swapped_out{instance=\"2\",tier=\"dram\"} 4",
            "# TYPE memserve_repl_ack_lag gauge",
            "memserve_repl_ack_lag{instance=\"3\",shard=\"1\"} 2.5",
            "# TYPE memserve_sched_matched_tokens histogram",
            "memserve_sched_matched_tokens_bucket{shard=\"0\",le=\"4\"} 1",
            "memserve_sched_matched_tokens_bucket{shard=\"0\",le=\"128\"} 2",
            "memserve_sched_matched_tokens_bucket{shard=\"0\",le=\"+Inf\"} 2",
            "memserve_sched_matched_tokens_count{shard=\"0\"} 2",
            "memserve_sched_matched_tokens_sum{shard=\"0\"} 103",
            "# TYPE memserve_net_messages counter",
            "memserve_net_messages 9",
        ] {
            assert!(
                text.lines().any(|l| l == line),
                "missing exposition line {line:?} in:\n{text}"
            );
        }
        // One TYPE line per family, even with several label sets.
        assert_eq!(
            text.matches("# TYPE memserve_sched_routes counter").count(),
            1
        );
    }

    #[test]
    fn snapshot_json_is_parseable() {
        let r = Registry::new(true);
        r.counter("a", Labels::none()).inc(1);
        r.histogram("h", Labels::instance(2)).observe(5);
        let text = r.snapshot(3.5).to_json().to_string();
        let j = crate::util::json::Json::parse(&text).unwrap();
        assert_eq!(j.at(&["metrics", "a"]).unwrap().as_f64(), Some(1.0));
        assert_eq!(
            j.at(&["metrics", "h{instance=2}", "count"])
                .unwrap()
                .as_f64(),
            Some(1.0)
        );
    }
}
