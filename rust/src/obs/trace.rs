//! Request-scoped tracing (ISSUE 8 tentpole, part 2).
//!
//! A span id is minted when a request enters the system (the live
//! leader's submit path, or the sim's `on_send`) and rides along in
//! `Msg` envelopes and sim events. Each lifecycle phase —
//! route → queue → prefill → kv_transfer → decode → retire, plus
//! migration and promotion handshakes — closes one interval on that
//! span. Timestamps are caller-clock f64 seconds, so the same sink
//! serves the live server (shared-epoch `Instant` elapsed) and the
//! sim (virtual `EventQueue` clock) without translation.
//!
//! **Replay safety** (PR 6 interop): the fault fabric duplicates and
//! reorders messages, and receivers dedupe with `SeenMids` /
//! landed-window checks — but trace calls can still fire twice for
//! the same (span, phase). The sink is idempotent: a `begin` on an
//! already-closed phase is ignored, a duplicate `begin` keeps the
//! first open timestamp, and an `end`/`complete` after close counts
//! into `dup_closes` instead of emitting a second event. Orphan
//! `end`s (no matching begin — e.g. the begin's message was dropped
//! before a resend) count into `orphan_ends`.
//!
//! Export is Chrome trace-event JSON (`chrome://tracing` / Perfetto):
//! one complete `"X"` event per closed phase, `ts`/`dur` in
//! microseconds, `tid` = span id so each request gets its own row.

use std::collections::{HashMap, HashSet};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};

use crate::util::json::Json;

/// Lifecycle phase names, used both as trace-event names and as the
/// keys of the span-chain completeness check.
pub mod phase {
    pub const ROUTE: &str = "route";
    pub const QUEUE: &str = "queue";
    pub const PREFILL: &str = "prefill";
    pub const KV_TRANSFER: &str = "kv_transfer";
    pub const DECODE: &str = "decode";
    pub const RETIRE: &str = "retire";
    pub const MIGRATE: &str = "migrate";
    pub const PROMOTE: &str = "promote";
}

/// Span-id namespaces. Request spans use the request id directly;
/// migrations and promotions are folded into disjoint high ranges so
/// one sink holds all three without collisions.
pub fn request_span(rid: u64) -> u64 {
    rid
}

pub fn migration_span(mid: u64) -> u64 {
    mid | (1 << 62)
}

pub fn promotion_span(shard: u64) -> u64 {
    shard | (1 << 63)
}

/// One closed interval on a span.
#[derive(Clone, Debug, PartialEq)]
pub struct TraceEvent {
    pub span: u64,
    pub phase: &'static str,
    /// Process/instance the phase ran on (`u32::MAX` = leader).
    pub pid: u32,
    pub t0: f64,
    pub t1: f64,
}

#[derive(Default)]
struct State {
    /// (span, phase) → (begin time, pid).
    open: HashMap<(u64, &'static str), (f64, u32)>,
    /// Phases already closed — the idempotence guard.
    closed: HashSet<(u64, &'static str)>,
    events: Vec<TraceEvent>,
    dropped: u64,
    dup_closes: u64,
    orphan_ends: u64,
}

struct TraceShared {
    enabled: AtomicBool,
    cap: usize,
    st: Mutex<State>,
}

/// Shared tracing sink. Disabled mode is a single relaxed load per
/// call; clones share state.
#[derive(Clone)]
pub struct TraceSink(Arc<TraceShared>);

/// Default event cap: bounded memory on long runs; overflow counts
/// into `dropped` and is reported in the export.
pub const DEFAULT_TRACE_CAP: usize = 262_144;

impl TraceSink {
    pub fn new(enabled: bool) -> Self {
        Self::with_cap(enabled, DEFAULT_TRACE_CAP)
    }

    pub fn with_cap(enabled: bool, cap: usize) -> Self {
        TraceSink(Arc::new(TraceShared {
            enabled: AtomicBool::new(enabled),
            cap,
            st: Mutex::new(State::default()),
        }))
    }

    /// Enabled iff `MEMSERVE_TRACE` is set to something other than
    /// `""`/`0`/`off`.
    pub fn from_env() -> Self {
        let on = match std::env::var("MEMSERVE_TRACE").as_deref() {
            Ok("") | Ok("0") | Ok("off") | Err(_) => false,
            Ok(_) => true,
        };
        TraceSink::new(on)
    }

    pub fn disabled() -> Self {
        TraceSink::new(false)
    }

    pub fn enabled(&self) -> bool {
        // ordering: Relaxed — on/off flag read on the hot path; no
        // other memory is published through it (events go under the
        // mutex below).
        self.0.enabled.load(Ordering::Relaxed)
    }

    /// Open a phase interval. Idempotent: ignored when the phase is
    /// already open (first begin wins) or already closed (replay).
    pub fn begin(&self, span: u64, ph: &'static str, pid: u32, now: f64) {
        if !self.enabled() {
            return;
        }
        let mut st = self.0.st.lock().unwrap();
        if st.closed.contains(&(span, ph)) {
            return;
        }
        st.open.entry((span, ph)).or_insert((now, pid));
    }

    /// Close a phase interval opened by `begin`. A close without a
    /// matching open is counted (`dup_closes` if the phase already
    /// closed, `orphan_ends` otherwise) and otherwise ignored.
    pub fn end(&self, span: u64, ph: &'static str, now: f64) {
        if !self.enabled() {
            return;
        }
        let mut st = self.0.st.lock().unwrap();
        match st.open.remove(&(span, ph)) {
            Some((t0, pid)) => {
                st.closed.insert((span, ph));
                push_event(
                    &mut st,
                    self.0.cap,
                    TraceEvent { span, phase: ph, pid, t0, t1: now },
                );
            }
            None => {
                if st.closed.contains(&(span, ph)) {
                    st.dup_closes += 1;
                } else {
                    st.orphan_ends += 1;
                }
            }
        }
    }

    /// Record a phase whose begin and end are known at one call site.
    /// Same idempotence contract as `begin`+`end`.
    pub fn complete(
        &self,
        span: u64,
        ph: &'static str,
        pid: u32,
        t0: f64,
        t1: f64,
    ) {
        if !self.enabled() {
            return;
        }
        let mut st = self.0.st.lock().unwrap();
        if !st.closed.insert((span, ph)) {
            st.dup_closes += 1;
            return;
        }
        st.open.remove(&(span, ph));
        push_event(
            &mut st,
            self.0.cap,
            TraceEvent { span, phase: ph, pid, t0, t1 },
        );
    }

    pub fn events(&self) -> Vec<TraceEvent> {
        self.0.st.lock().unwrap().events.clone()
    }

    /// (recorded, dropped, dup_closes, orphan_ends).
    pub fn stats(&self) -> (u64, u64, u64, u64) {
        let st = self.0.st.lock().unwrap();
        (st.events.len() as u64, st.dropped, st.dup_closes, st.orphan_ends)
    }

    /// Closed-phase sets per span — the span-chain view.
    pub fn chains(&self) -> HashMap<u64, HashSet<&'static str>> {
        let st = self.0.st.lock().unwrap();
        let mut out: HashMap<u64, HashSet<&'static str>> = HashMap::new();
        for ev in &st.events {
            out.entry(ev.span).or_default().insert(ev.phase);
        }
        out
    }

    /// True iff `span` closed every required request-lifecycle phase:
    /// route, queue, prefill, decode, retire — plus kv_transfer when
    /// `disaggregated` (colocated requests never ship KV over the
    /// wire, so the phase legitimately never opens).
    pub fn chain_complete(&self, span: u64, disaggregated: bool) -> bool {
        let st = self.0.st.lock().unwrap();
        let mut need = vec![
            phase::ROUTE,
            phase::QUEUE,
            phase::PREFILL,
            phase::DECODE,
            phase::RETIRE,
        ];
        if disaggregated {
            need.push(phase::KV_TRANSFER);
        }
        need.iter().all(|ph| st.closed.contains(&(span, ph)))
    }

    /// Chrome trace-event JSON (load in `chrome://tracing` or
    /// ui.perfetto.dev). Seconds → microseconds; `tid` = span id so
    /// each request renders as its own track.
    pub fn to_chrome_json(&self) -> Json {
        let st = self.0.st.lock().unwrap();
        let evs: Vec<Json> = st
            .events
            .iter()
            .map(|ev| {
                Json::obj(vec![
                    ("name", Json::str(ev.phase)),
                    ("cat", Json::str("memserve")),
                    ("ph", Json::str("X")),
                    ("ts", Json::num(ev.t0 * 1e6)),
                    ("dur", Json::num((ev.t1 - ev.t0).max(0.0) * 1e6)),
                    ("pid", Json::num(ev.pid as f64)),
                    ("tid", Json::num(ev.span as f64)),
                ])
            })
            .collect();
        Json::obj(vec![
            ("traceEvents", Json::arr(evs)),
            ("displayTimeUnit", Json::str("ms")),
            ("dropped", Json::num(st.dropped as f64)),
            ("dupCloses", Json::num(st.dup_closes as f64)),
            ("orphanEnds", Json::num(st.orphan_ends as f64)),
        ])
    }
}

fn push_event(st: &mut State, cap: usize, ev: TraceEvent) {
    if st.events.len() >= cap {
        st.dropped += 1;
    } else {
        st.events.push(ev);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn begin_end_records_one_event() {
        let t = TraceSink::new(true);
        t.begin(7, phase::PREFILL, 2, 1.0);
        t.end(7, phase::PREFILL, 1.5);
        let evs = t.events();
        assert_eq!(evs.len(), 1);
        assert_eq!(evs[0].phase, "prefill");
        assert_eq!(evs[0].pid, 2);
        assert!((evs[0].t1 - evs[0].t0 - 0.5).abs() < 1e-12);
    }

    /// ISSUE 8 satellite: a duplicated message (PR 6 fault fabric)
    /// replaying begin/end must not double-close or orphan the span.
    #[test]
    fn replayed_phases_are_idempotent() {
        let t = TraceSink::new(true);
        t.begin(1, phase::DECODE, 0, 1.0);
        t.begin(1, phase::DECODE, 0, 2.0); // dup begin: first wins
        t.end(1, phase::DECODE, 3.0);
        t.end(1, phase::DECODE, 4.0); // dup end: counted, not emitted
        t.begin(1, phase::DECODE, 0, 5.0); // begin after close: ignored
        let evs = t.events();
        assert_eq!(evs.len(), 1);
        assert_eq!(evs[0].t0, 1.0);
        assert_eq!(evs[0].t1, 3.0);
        let (recorded, dropped, dups, orphans) = t.stats();
        assert_eq!((recorded, dropped, dups, orphans), (1, 0, 1, 0));
        // complete() replay is likewise inert.
        t.complete(2, phase::ROUTE, 9, 0.0, 0.1);
        t.complete(2, phase::ROUTE, 9, 0.0, 0.2);
        assert_eq!(t.events().len(), 2);
        assert_eq!(t.stats().2, 2);
    }

    #[test]
    fn orphan_end_is_counted_not_emitted() {
        let t = TraceSink::new(true);
        t.end(42, phase::KV_TRANSFER, 1.0);
        assert!(t.events().is_empty());
        assert_eq!(t.stats().3, 1);
    }

    #[test]
    fn chain_completeness() {
        let t = TraceSink::new(true);
        for ph in [
            phase::ROUTE,
            phase::QUEUE,
            phase::PREFILL,
            phase::DECODE,
            phase::RETIRE,
        ] {
            t.complete(5, ph, 0, 0.0, 1.0);
        }
        assert!(t.chain_complete(5, false));
        assert!(!t.chain_complete(5, true)); // no kv_transfer yet
        t.complete(5, phase::KV_TRANSFER, 0, 0.2, 0.4);
        assert!(t.chain_complete(5, true));
        assert!(!t.chain_complete(6, false));
    }

    #[test]
    fn disabled_sink_is_inert() {
        let t = TraceSink::disabled();
        t.begin(1, phase::ROUTE, 0, 0.0);
        t.end(1, phase::ROUTE, 1.0);
        t.complete(1, phase::QUEUE, 0, 0.0, 1.0);
        assert!(t.events().is_empty());
        assert_eq!(t.stats(), (0, 0, 0, 0));
    }

    #[test]
    fn cap_bounds_memory() {
        let t = TraceSink::with_cap(true, 2);
        for span in 0..5 {
            t.complete(span, phase::ROUTE, 0, 0.0, 1.0);
        }
        assert_eq!(t.events().len(), 2);
        assert_eq!(t.stats().1, 3);
    }

    #[test]
    fn span_namespaces_are_disjoint() {
        let r = request_span(123);
        let m = migration_span(123);
        let p = promotion_span(123);
        assert_ne!(r, m);
        assert_ne!(r, p);
        assert_ne!(m, p);
    }

    #[test]
    fn chrome_export_parses_and_scales() {
        let t = TraceSink::new(true);
        t.complete(9, phase::PREFILL, 3, 1.0, 1.25);
        let text = t.to_chrome_json().to_string();
        let j = Json::parse(&text).unwrap();
        let evs = match j.at(&["traceEvents"]).unwrap() {
            Json::Arr(a) => a,
            other => panic!("expected array, got {other:?}"),
        };
        assert_eq!(evs.len(), 1);
        assert_eq!(evs[0].at(&["ts"]).unwrap().as_f64(), Some(1e6));
        assert_eq!(evs[0].at(&["dur"]).unwrap().as_f64(), Some(0.25e6));
        assert_eq!(evs[0].at(&["tid"]).unwrap().as_f64(), Some(9.0));
    }
}
