//! Online invariant watchdog (ISSUE 9 tentpole, part 3).
//!
//! PR 4–8 assert the cluster's invariants in tests; nothing checks
//! them *while serving*. [`Watchdog`] evaluates rule-based checks over
//! the [`super::timeline::Timeline`]'s closed frames on every scrape
//! and fires a structured [`Alert`] per violated invariant:
//!
//! * [`rule::REPL_LAG_GROWING`] — a follower's replication ack lag
//!   (`repl.ack_lag{instance,shard}` gauge) grew strictly across K
//!   consecutive windows and is still positive: the delta stream is
//!   stalled, not just bursty.
//! * [`rule::GS_DIVERGENCE`] — the global scheduler believes an
//!   instance caches materially more token-blocks
//!   (`gs.believed_token_blocks`) than the instance actually indexes
//!   (`pool.indexed_token_blocks`): the honest-eviction contract
//!   (belief never exceeds reality) is broken.
//! * [`rule::TOUCH_BACKLOG`] — the deferred-touch queue is saturated
//!   (pending at cap) or dropped refreshes this window: LRU recency is
//!   under-credited.
//! * [`rule::CHAIN_INCOMPLETE`] — the trace sink's orphaned ends plus
//!   ring drops exceed a rate bound of recorded events: span chains
//!   can no longer be trusted for attribution.
//! * [`rule::HEARTBEAT_MISSES`] — an instance's miss streak
//!   (`hb.miss_streak` gauge, in heartbeat intervals) reached the
//!   configured streak before the failure detector acted.
//!
//! The watchdog is strictly record-only: alerts go to the flight
//! recorder (and its gated dump); no decision consumes them. Each
//! ongoing condition fires **once** — the rule re-arms when the
//! condition clears, so a stalled shard produces one alert, not one
//! per scrape.

use std::collections::BTreeSet;

use crate::obs::timeline::Frame;
use crate::util::json::Json;

/// Alert rule names — also the `detail` prefix in flight-recorder
/// events.
pub mod rule {
    pub const REPL_LAG_GROWING: &str = "repl_lag_growing";
    pub const GS_DIVERGENCE: &str = "gs_divergence";
    pub const TOUCH_BACKLOG: &str = "touch_backlog";
    pub const CHAIN_INCOMPLETE: &str = "chain_incomplete";
    pub const HEARTBEAT_MISSES: &str = "heartbeat_misses";
}

#[derive(Clone, Debug)]
pub struct WatchdogConfig {
    /// Consecutive windows of strict lag growth before
    /// [`rule::REPL_LAG_GROWING`] fires.
    pub k_windows: usize,
    /// Relative over-belief bound for [`rule::GS_DIVERGENCE`]:
    /// believed must exceed `indexed * (1 + ratio)`.
    pub divergence_ratio: f64,
    /// Absolute slack (token-blocks) under which divergence never
    /// fires — TTL expiry on the two sides is not clock-synchronized.
    pub divergence_slack_blocks: u64,
    /// Pending-touch count at which [`rule::TOUCH_BACKLOG`] fires
    /// (the queue's capacity means "saturated").
    pub backlog_cap: u64,
    /// `(orphan_ends + dropped) / recorded` bound for
    /// [`rule::CHAIN_INCOMPLETE`].
    pub incomplete_rate_bound: f64,
    /// Miss streak (in heartbeat intervals) for
    /// [`rule::HEARTBEAT_MISSES`].
    pub heartbeat_miss_streak: f64,
}

impl Default for WatchdogConfig {
    fn default() -> Self {
        WatchdogConfig {
            k_windows: 3,
            divergence_ratio: 0.5,
            divergence_slack_blocks: 128,
            backlog_cap: crate::mempool::DEFERRED_TOUCH_CAP as u64,
            incomplete_rate_bound: 0.01,
            heartbeat_miss_streak: 3.0,
        }
    }
}

/// One fired invariant violation.
#[derive(Clone, Debug)]
pub struct Alert {
    pub rule: &'static str,
    /// Frame-end timestamp the violation was detected at.
    pub at: f64,
    /// The metric key (or family) that violated — unique per ongoing
    /// condition.
    pub subject: String,
    pub detail: String,
}

impl Alert {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("rule", Json::str(self.rule)),
            ("at", Json::num(self.at)),
            ("subject", Json::str(self.subject.clone())),
            ("detail", Json::str(self.detail.clone())),
        ])
    }
}

/// Stateful checker: owns the fired-condition set for re-arm
/// semantics. One per cluster/sim, driven from the scrape path.
pub struct Watchdog {
    cfg: WatchdogConfig,
    /// `(rule, subject)` pairs currently in violation — fired once,
    /// re-armed on clear.
    active: BTreeSet<(&'static str, String)>,
    fired_total: u64,
}

impl Watchdog {
    pub fn new(cfg: WatchdogConfig) -> Self {
        Watchdog {
            cfg,
            active: BTreeSet::new(),
            fired_total: 0,
        }
    }

    pub fn config(&self) -> &WatchdogConfig {
        &self.cfg
    }

    /// Alerts fired over this watchdog's lifetime.
    pub fn fired_total(&self) -> u64 {
        self.fired_total
    }

    /// Evaluate every rule over the timeline's closed frames (oldest
    /// first) and return newly-fired alerts. Idempotent per ongoing
    /// condition.
    pub fn check(&mut self, frames: &[Frame]) -> Vec<Alert> {
        let Some(last) = frames.last() else {
            return vec![];
        };
        let mut conditions: Vec<Alert> = vec![];
        self.repl_lag(frames, &mut conditions);
        self.divergence(last, &mut conditions);
        self.backlog(last, &mut conditions);
        self.chains(last, &mut conditions);
        self.heartbeats(last, &mut conditions);

        // Re-arm: conditions absent this round leave the active set.
        let now_active: BTreeSet<(&'static str, String)> = conditions
            .iter()
            .map(|a| (a.rule, a.subject.clone()))
            .collect();
        self.active.retain(|k| now_active.contains(k));

        let mut fired = vec![];
        for a in conditions {
            if self.active.insert((a.rule, a.subject.clone())) {
                self.fired_total += 1;
                fired.push(a);
            }
        }
        fired
    }

    /// Strictly growing `repl.ack_lag` gauge across the last K+1
    /// frames (K growth steps), still positive.
    fn repl_lag(&self, frames: &[Frame], out: &mut Vec<Alert>) {
        let last = frames.last().unwrap();
        for (key, lag) in last.gauges_under("repl.ack_lag{") {
            if lag <= 0.0 {
                continue;
            }
            let need = self.cfg.k_windows + 1;
            if frames.len() < need {
                continue;
            }
            let tail = &frames[frames.len() - need..];
            let grew = tail.windows(2).all(|w| {
                match (w[0].gauge(key), w[1].gauge(key)) {
                    (Some(a), Some(b)) => b > a,
                    _ => false,
                }
            });
            if grew {
                out.push(Alert {
                    rule: rule::REPL_LAG_GROWING,
                    at: last.t1,
                    subject: key.to_string(),
                    detail: format!(
                        "{key} grew for {} consecutive windows to {lag}",
                        self.cfg.k_windows
                    ),
                });
            }
        }
    }

    /// GS believes more cached token-blocks than the pool indexes.
    fn divergence(&self, last: &Frame, out: &mut Vec<Alert>) {
        for (key, believed) in
            last.counters_under("gs.believed_token_blocks{")
        {
            let Some(label) = key.strip_prefix("gs.believed_token_blocks")
            else {
                continue;
            };
            let indexed =
                last.counter(&format!("pool.indexed_token_blocks{label}"));
            let over = believed.saturating_sub(indexed);
            if over > self.cfg.divergence_slack_blocks
                && believed as f64
                    > indexed as f64 * (1.0 + self.cfg.divergence_ratio)
            {
                out.push(Alert {
                    rule: rule::GS_DIVERGENCE,
                    at: last.t1,
                    subject: key.to_string(),
                    detail: format!(
                        "gs believes {believed} token-blocks but \
                         {indexed} are indexed{label}"
                    ),
                });
            }
        }
    }

    /// Deferred-touch queue saturated or dropping this window.
    fn backlog(&self, last: &Frame, out: &mut Vec<Alert>) {
        for (key, deferred) in
            last.counters_under("pool.touches_deferred{")
        {
            let Some(label) = key.strip_prefix("pool.touches_deferred")
            else {
                continue;
            };
            let drained =
                last.counter(&format!("pool.touches_drained{label}"));
            let dropped_key = format!("pool.touches_dropped{label}");
            let dropped = last.counter(&dropped_key);
            let pending = deferred.saturating_sub(drained + dropped);
            let dropped_now = last.delta(&dropped_key);
            if pending >= self.cfg.backlog_cap || dropped_now > 0 {
                out.push(Alert {
                    rule: rule::TOUCH_BACKLOG,
                    at: last.t1,
                    subject: key.to_string(),
                    detail: format!(
                        "touch queue{label}: {pending} pending \
                         (cap {}), {dropped_now} dropped this window",
                        self.cfg.backlog_cap
                    ),
                });
            }
        }
    }

    /// Span-chain incompleteness rate over the whole trace.
    fn chains(&self, last: &Frame, out: &mut Vec<Alert>) {
        let recorded = last.counter("trace.recorded");
        if recorded == 0 {
            return;
        }
        let bad = last.counter("trace.orphan_ends")
            + last.counter("trace.dropped");
        let rate = bad as f64 / recorded as f64;
        if rate > self.cfg.incomplete_rate_bound {
            out.push(Alert {
                rule: rule::CHAIN_INCOMPLETE,
                at: last.t1,
                subject: "trace".to_string(),
                detail: format!(
                    "{bad}/{recorded} trace events orphaned or dropped \
                     ({:.2}% > {:.2}% bound)",
                    rate * 100.0,
                    self.cfg.incomplete_rate_bound * 100.0
                ),
            });
        }
    }

    /// Heartbeat miss streaks at or past the configured bound.
    fn heartbeats(&self, last: &Frame, out: &mut Vec<Alert>) {
        for (key, streak) in last.gauges_under("hb.miss_streak{") {
            if streak >= self.cfg.heartbeat_miss_streak {
                out.push(Alert {
                    rule: rule::HEARTBEAT_MISSES,
                    at: last.t1,
                    subject: key.to_string(),
                    detail: format!(
                        "{key}: {streak:.1} intervals without a \
                         heartbeat (bound {:.1})",
                        self.cfg.heartbeat_miss_streak
                    ),
                });
            }
        }
    }
}

impl Default for Watchdog {
    fn default() -> Self {
        Watchdog::new(WatchdogConfig::default())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::BTreeMap;

    fn frame(t1: f64) -> Frame {
        Frame {
            t0: t1 - 1.0,
            t1,
            counters: BTreeMap::new(),
            deltas: BTreeMap::new(),
            gauges: BTreeMap::new(),
            histos: BTreeMap::new(),
        }
    }

    fn lag_frame(t1: f64, lag: f64) -> Frame {
        let mut f = frame(t1);
        f.gauges
            .insert("repl.ack_lag{instance=1,shard=0}".into(), lag);
        f
    }

    #[test]
    fn growing_lag_fires_once_and_rearms() {
        let mut wd = Watchdog::default(); // k_windows = 3
        let mut frames =
            vec![lag_frame(1.0, 1.0), lag_frame(2.0, 2.0)];
        assert!(wd.check(&frames).is_empty(), "not enough windows");
        frames.push(lag_frame(3.0, 3.0));
        frames.push(lag_frame(4.0, 4.0));
        let fired = wd.check(&frames);
        assert_eq!(fired.len(), 1);
        assert_eq!(fired[0].rule, rule::REPL_LAG_GROWING);
        assert_eq!(fired[0].subject, "repl.ack_lag{instance=1,shard=0}");
        // Still growing: same ongoing condition, no re-fire.
        frames.push(lag_frame(5.0, 5.0));
        assert!(wd.check(&frames).is_empty());
        // Lag drains: condition clears and re-arms...
        frames.push(lag_frame(6.0, 0.0));
        assert!(wd.check(&frames).is_empty());
        // ...so a second stall fires again.
        for (i, lag) in [1.0, 2.0, 3.0, 4.0].iter().enumerate() {
            frames.push(lag_frame(7.0 + i as f64, *lag));
        }
        assert_eq!(wd.check(&frames).len(), 1);
        assert_eq!(wd.fired_total(), 2);
    }

    #[test]
    fn flat_or_shrinking_lag_is_quiet() {
        let mut wd = Watchdog::default();
        let frames: Vec<Frame> = (0..6)
            .map(|i| lag_frame(i as f64 + 1.0, 5.0))
            .collect();
        assert!(wd.check(&frames).is_empty(), "flat lag is backlog, not stall");
        let frames: Vec<Frame> = (0..6)
            .map(|i| lag_frame(i as f64 + 1.0, 10.0 - i as f64))
            .collect();
        assert!(wd.check(&frames).is_empty(), "draining lag is healthy");
    }

    #[test]
    fn divergence_needs_both_ratio_and_slack() {
        let mut wd = Watchdog::default();
        let mut f = frame(1.0);
        f.counters.insert(
            "gs.believed_token_blocks{instance=0}".into(),
            1000,
        );
        f.counters
            .insert("pool.indexed_token_blocks{instance=0}".into(), 900);
        // 100 over, but under both the ratio and the slack: quiet.
        assert!(wd.check(std::slice::from_ref(&f)).is_empty());
        f.counters.insert(
            "gs.believed_token_blocks{instance=0}".into(),
            2000,
        );
        let fired = wd.check(std::slice::from_ref(&f));
        assert_eq!(fired.len(), 1);
        assert_eq!(fired[0].rule, rule::GS_DIVERGENCE);
    }

    #[test]
    fn backlog_fires_on_saturation_or_window_drops() {
        let mut wd = Watchdog::new(WatchdogConfig {
            backlog_cap: 100,
            ..Default::default()
        });
        let mut f = frame(1.0);
        f.counters
            .insert("pool.touches_deferred{instance=2}".into(), 150);
        f.counters
            .insert("pool.touches_drained{instance=2}".into(), 60);
        // pending = 90 < 100, no drops: quiet.
        assert!(wd.check(std::slice::from_ref(&f)).is_empty());
        f.deltas
            .insert("pool.touches_dropped{instance=2}".into(), 5);
        f.counters
            .insert("pool.touches_dropped{instance=2}".into(), 5);
        let fired = wd.check(std::slice::from_ref(&f));
        assert_eq!(fired.len(), 1);
        assert_eq!(fired[0].rule, rule::TOUCH_BACKLOG);
    }

    #[test]
    fn chain_incompleteness_rate() {
        let mut wd = Watchdog::default(); // 1% bound
        let mut f = frame(1.0);
        f.counters.insert("trace.recorded".into(), 1000);
        f.counters.insert("trace.orphan_ends".into(), 5);
        assert!(wd.check(std::slice::from_ref(&f)).is_empty());
        f.counters.insert("trace.dropped".into(), 20);
        let fired = wd.check(std::slice::from_ref(&f));
        assert_eq!(fired.len(), 1);
        assert_eq!(fired[0].rule, rule::CHAIN_INCOMPLETE);
    }

    #[test]
    fn heartbeat_streak() {
        let mut wd = Watchdog::default(); // streak bound 3.0
        let mut f = frame(1.0);
        f.gauges.insert("hb.miss_streak{instance=4}".into(), 2.0);
        assert!(wd.check(std::slice::from_ref(&f)).is_empty());
        f.gauges.insert("hb.miss_streak{instance=4}".into(), 3.5);
        let fired = wd.check(std::slice::from_ref(&f));
        assert_eq!(fired.len(), 1);
        assert_eq!(fired[0].rule, rule::HEARTBEAT_MISSES);
        assert_eq!(fired[0].subject, "hb.miss_streak{instance=4}");
    }

    #[test]
    fn healthy_frames_are_silent() {
        let mut wd = Watchdog::default();
        let mut f = frame(1.0);
        f.counters.insert("trace.recorded".into(), 500);
        f.counters
            .insert("gs.believed_token_blocks{instance=0}".into(), 300);
        f.counters
            .insert("pool.indexed_token_blocks{instance=0}".into(), 300);
        f.gauges
            .insert("repl.ack_lag{instance=1,shard=0}".into(), 0.0);
        f.gauges.insert("hb.miss_streak{instance=0}".into(), 0.4);
        assert!(wd.check(std::slice::from_ref(&f)).is_empty());
        assert_eq!(wd.fired_total(), 0);
    }
}
