//! Cluster-view fold (ISSUE 8 tentpole, part 4).
//!
//! The leader (live `ServeCluster` collector) or the sim periodically
//! *scrapes* each instance's ad-hoc counters — `PoolStats` from the
//! MemPool, `NetStats` from the fabric, replication ack-lag from the
//! delta transport — into the shared [`Registry`] under
//! instance/shard labels. Scrapes use the absolute `set_counter` /
//! `set_gauge` fold, so re-scraping is idempotent and the *last*
//! scrape of a crashed instance survives it (the counter-loss fix:
//! the fold also runs on deregistration, so a force-decommissioned
//! instance's stats stay in the final cluster view instead of dying
//! with its thread).

use crate::mempool::api::PoolStats;
use crate::net::fabric::NetStats;
use crate::util::json::Json;

use super::flight::FlightRecorder;
use super::registry::{Labels, ObsSnapshot, Registry};
use super::trace::TraceSink;

/// Fold one instance's `PoolStats` into the registry (absolute
/// stores — idempotent across repeated scrapes).
pub fn fold_pool(reg: &Registry, instance: u32, s: &PoolStats) {
    let l = Labels::instance(instance);
    reg.set_counter("pool.inserts", l, s.inserts);
    reg.set_counter("pool.insert_dup_blocks", l, s.insert_dup_blocks);
    reg.set_counter("pool.matches", l, s.matches);
    reg.set_counter("pool.match_hit_token_blocks", l, s.match_hit_token_blocks);
    reg.set_counter("pool.evicted_blocks", l, s.evicted_blocks);
    reg.set_counter("pool.expired_blocks", l, s.expired_blocks);
    reg.set_counter("pool.swapped_out", l.with_tier("dram"), s.swapped_out);
    reg.set_counter("pool.swapped_in", l.with_tier("hbm"), s.swapped_in);
    reg.set_counter("pool.alloc_failures", l, s.alloc_failures);
    reg.set_counter("pool.touches_deferred", l, s.touches_deferred);
    reg.set_counter("pool.touches_drained", l, s.touches_drained);
    reg.set_counter("pool.touches_dropped", l, s.touches_dropped);
}

/// Fold the pool index's *current* footprint (token-blocks indexed
/// right now, not a monotone event count). The ISSUE 9 watchdog's
/// divergence rule compares this against the GS-side
/// `gs.believed_token_blocks` for the same instance.
pub fn fold_pool_index(reg: &Registry, instance: u32, indexed: usize) {
    reg.set_counter(
        "pool.indexed_token_blocks",
        Labels::instance(instance),
        indexed as u64,
    );
}

/// Fold fabric-wide `NetStats` into the registry.
pub fn fold_net(reg: &Registry, s: &NetStats) {
    let l = Labels::none();
    reg.set_counter("net.messages", l, s.messages);
    reg.set_counter("net.payload_bytes", l, s.payload_bytes);
    reg.set_counter("net.api_calls", l, s.api_calls);
    reg.set_gauge("net.busy_seconds", l, s.busy_seconds);
    reg.set_counter("net.dropped", l, s.dropped);
    reg.set_counter("net.duplicated", l, s.duplicated);
    reg.set_counter("net.reordered", l, s.reordered);
}

/// Fold one shard's replication state: the transport's next sequence
/// and each follower's ack lag (`next_seq - acked`).
pub fn fold_replication(
    reg: &Registry,
    shard: u32,
    next_seq: u64,
    lags: &[(u32, u64)],
) {
    reg.set_counter("repl.next_seq", Labels::shard(shard), next_seq);
    for &(peer, lag) in lags {
        let l = Labels { instance: Some(peer), shard: Some(shard), tier: None };
        reg.set_gauge("repl.ack_lag", l, lag as f64);
    }
}

/// Fold the trace sink's health counters (ISSUE 9 satellite): replay
/// anomalies (`dup_closes` are expected under PR 6 message replay;
/// `orphan_ends` never are) and ring overflow become scrape-visible
/// instead of test-only.
pub fn fold_trace(reg: &Registry, sink: &TraceSink) {
    let (recorded, dropped, dup_closes, orphan_ends) = sink.stats();
    let l = Labels::none();
    reg.set_counter("trace.recorded", l, recorded);
    reg.set_counter("trace.dropped", l, dropped);
    reg.set_counter("trace.dup_closes", l, dup_closes);
    reg.set_counter("trace.orphan_ends", l, orphan_ends);
}

/// Fold the flight recorder's ring accounting: total ever recorded and
/// how many rotated out (the ring-overflow signal).
pub fn fold_flight(reg: &Registry, fr: &FlightRecorder) {
    let l = Labels::none();
    reg.set_counter("flight.total", l, fr.total());
    reg.set_counter("flight.dropped", l, fr.dropped());
}

/// One folded cluster view: a timestamped snapshot of every metric the
/// leader has scraped plus everything instrumented code recorded live.
#[derive(Clone, Debug, Default)]
pub struct ClusterView {
    pub at: f64,
    pub snapshot: ObsSnapshot,
}

impl ClusterView {
    pub fn capture(reg: &Registry, at: f64) -> Self {
        ClusterView { at, snapshot: reg.snapshot(at) }
    }

    pub fn to_json(&self) -> Json {
        self.snapshot.to_json()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pool_fold_is_idempotent_and_labeled() {
        let reg = Registry::new(true);
        let s = PoolStats { matches: 10, evicted_blocks: 3, ..Default::default() };
        fold_pool(&reg, 2, &s);
        fold_pool(&reg, 2, &s); // re-scrape must not double-count
        let snap = reg.snapshot(1.0);
        assert_eq!(snap.counter("pool.matches{instance=2}"), 10);
        assert_eq!(snap.counter("pool.evicted_blocks{instance=2}"), 3);
    }

    /// The counter-loss fix in miniature: a "crashed" instance's last
    /// scrape persists in the view after its source struct is gone.
    #[test]
    fn last_scrape_survives_instance_death() {
        let reg = Registry::new(true);
        {
            let s = PoolStats { matches: 42, ..Default::default() };
            fold_pool(&reg, 7, &s);
        } // instance dies; PoolStats dropped
        fold_pool(&reg, 1, &PoolStats { matches: 5, ..Default::default() });
        let view = ClusterView::capture(&reg, 9.0);
        assert_eq!(view.snapshot.counter("pool.matches{instance=7}"), 42);
        assert_eq!(view.snapshot.counter_sum("pool.matches"), 47);
    }

    #[test]
    fn replication_fold_exposes_lag() {
        let reg = Registry::new(true);
        fold_replication(&reg, 0, 15, &[(1, 0), (2, 4)]);
        let snap = reg.snapshot(0.0);
        assert_eq!(snap.counter("repl.next_seq{shard=0}"), 15);
        assert_eq!(snap.gauge("repl.ack_lag{instance=2,shard=0}"), 4.0);
    }

    /// ISSUE 9 satellite: trace replay anomalies and flight-ring
    /// overflow are scrape-visible in the folded cluster view.
    #[test]
    fn trace_and_flight_health_fold_into_view() {
        use crate::obs::trace::phase;
        let reg = Registry::new(true);
        let sink = TraceSink::new(true);
        let span = crate::obs::trace::request_span(1);
        sink.complete(span, phase::ROUTE, 0, 0.0, 0.0);
        sink.complete(span, phase::ROUTE, 0, 0.0, 0.0); // replay: dup close
        sink.end(span, phase::DECODE, 1.0); // never begun: orphan
        let fr = FlightRecorder::new(2);
        for i in 0..5 {
            fr.record(i as f64, 0, crate::obs::flight::kind::DELTA, "d");
        }
        fold_trace(&reg, &sink);
        fold_flight(&reg, &fr);
        let view = ClusterView::capture(&reg, 1.0);
        assert_eq!(view.snapshot.counter("trace.recorded"), 1);
        assert_eq!(view.snapshot.counter("trace.dup_closes"), 1);
        assert_eq!(view.snapshot.counter("trace.orphan_ends"), 1);
        assert_eq!(view.snapshot.counter("flight.total"), 5);
        assert_eq!(view.snapshot.counter("flight.dropped"), 3);
    }

    #[test]
    fn net_fold_roundtrips() {
        let reg = Registry::new(true);
        let s = NetStats {
            messages: 100,
            dropped: 7,
            busy_seconds: 1.5,
            ..Default::default()
        };
        fold_net(&reg, &s);
        let snap = reg.snapshot(0.0);
        assert_eq!(snap.counter("net.messages"), 100);
        assert_eq!(snap.counter("net.dropped"), 7);
        assert_eq!(snap.gauge("net.busy_seconds"), 1.5);
    }
}
