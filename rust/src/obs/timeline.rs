//! Windowed time-series frames over registry snapshots (ISSUE 9
//! tentpole, part 1).
//!
//! PR 8's [`ObsSnapshot`] is a point-in-time total; nothing in the
//! system can see a replication lag *growing* or a hit rate
//! *collapsing*. [`Timeline`] turns the existing scrape cadence (the
//! leader's ~500ms collector sweep; the sim's virtual-clock folds)
//! into a bounded ring of [`Frame`]s, each covering one wall (or
//! virtual) window and carrying:
//!
//! * end-of-window **absolute** counters and gauges (what the watchdog's
//!   divergence/backlog/lag rules read);
//! * per-window counter **deltas** (rates: routes/s, evictions/s);
//! * per-window **histogram digests** — the difference of two
//!   cumulative [`HistoSnapshot`]s, well-defined because buckets,
//!   count, and sum are all monotone — so TTFT/TBT/route-µs
//!   percentiles are per-window, not since-boot.
//!
//! Feeding is pull-based and clock-agnostic: the owner calls
//! [`Timeline::observe`] with a fresh snapshot whenever it scrapes; a
//! frame closes only once the snapshot's timestamp has advanced a full
//! window past the open frame's start. On a virtual clock the sim
//! drives this between popped events (never *as* events — pushing
//! observation events would shift the queue's push-order tie-break and
//! change routing, breaking the PR 6/7 determinism guarantees).

use std::collections::{BTreeMap, VecDeque};
use std::sync::{Arc, Mutex};

use crate::obs::registry::{HistoSnapshot, MetricValue, ObsSnapshot};
use crate::util::json::Json;

/// Default frame width: ~1s live (every other ~500ms collector scrape
/// closes a frame); the sim overrides via `SimConfig::obs_window_s`.
pub const DEFAULT_TIMELINE_WINDOW_S: f64 = 1.0;

/// Default ring capacity — at the 1s default window, ~4 minutes of
/// history, bounded the same way the flight recorder is.
pub const DEFAULT_TIMELINE_CAP: usize = 256;

#[derive(Clone, Debug)]
pub struct TimelineConfig {
    /// Minimum seconds a frame spans before a scrape closes it.
    pub window_s: f64,
    /// Ring capacity; the oldest frame is evicted (and counted in
    /// [`Timeline::dropped`]) past this.
    pub cap: usize,
}

impl Default for TimelineConfig {
    fn default() -> Self {
        TimelineConfig {
            window_s: DEFAULT_TIMELINE_WINDOW_S,
            cap: DEFAULT_TIMELINE_CAP,
        }
    }
}

/// One closed window `[t0, t1]` of the series.
#[derive(Clone, Debug, Default)]
pub struct Frame {
    pub t0: f64,
    pub t1: f64,
    /// End-of-window absolute counter values (every registered key).
    pub counters: BTreeMap<String, u64>,
    /// Counter increments within the window — only keys that moved.
    pub deltas: BTreeMap<String, u64>,
    /// End-of-window gauge values.
    pub gauges: BTreeMap<String, f64>,
    /// Per-window histogram digests — only keys with observations
    /// inside the window.
    pub histos: BTreeMap<String, HistoSnapshot>,
}

impl Frame {
    pub fn counter(&self, key: &str) -> u64 {
        self.counters.get(key).copied().unwrap_or(0)
    }

    pub fn delta(&self, key: &str) -> u64 {
        self.deltas.get(key).copied().unwrap_or(0)
    }

    pub fn gauge(&self, key: &str) -> Option<f64> {
        self.gauges.get(key).copied()
    }

    pub fn histo(&self, key: &str) -> Option<&HistoSnapshot> {
        self.histos.get(key)
    }

    /// Gauges whose key starts with `prefix` — the watchdog walks
    /// per-instance/per-shard label families this way.
    pub fn gauges_under(&self, prefix: &str) -> Vec<(&str, f64)> {
        self.gauges
            .iter()
            .filter(|(k, _)| k.starts_with(prefix))
            .map(|(k, &v)| (k.as_str(), v))
            .collect()
    }

    /// Counters whose key starts with `prefix` (absolutes).
    pub fn counters_under(&self, prefix: &str) -> Vec<(&str, u64)> {
        self.counters
            .iter()
            .filter(|(k, _)| k.starts_with(prefix))
            .map(|(k, &v)| (k.as_str(), v))
            .collect()
    }

    pub fn to_json(&self) -> Json {
        let counters = Json::Obj(
            self.counters
                .iter()
                .map(|(k, &v)| (k.clone(), Json::num(v as f64)))
                .collect(),
        );
        let deltas = Json::Obj(
            self.deltas
                .iter()
                .map(|(k, &v)| (k.clone(), Json::num(v as f64)))
                .collect(),
        );
        let gauges = Json::Obj(
            self.gauges
                .iter()
                .map(|(k, &v)| {
                    (k.clone(), Json::num(if v.is_finite() { v } else { 0.0 }))
                })
                .collect(),
        );
        let histos = Json::Obj(
            self.histos
                .iter()
                .map(|(k, h)| {
                    (
                        k.clone(),
                        Json::obj(vec![
                            ("count", Json::num(h.count as f64)),
                            ("sum", Json::num(h.sum as f64)),
                            ("mean", Json::num(h.mean())),
                            ("p50", Json::num(h.p50())),
                            ("p99", Json::num(h.p99())),
                        ]),
                    )
                })
                .collect(),
        );
        Json::obj(vec![
            ("t0", Json::num(self.t0)),
            ("t1", Json::num(self.t1)),
            ("counters", counters),
            ("deltas", deltas),
            ("gauges", gauges),
            ("histos", histos),
        ])
    }
}

/// Cumulative-histogram subtraction: valid because buckets/count/sum
/// only grow. `saturating_sub` tolerates an absolute `set_counter`
/// fold racing a scrape (never goes negative, worst case under-counts
/// one window and credits the next).
fn histo_sub(cur: &HistoSnapshot, prev: &HistoSnapshot) -> HistoSnapshot {
    let mut out = cur.clone();
    for (i, b) in out.buckets.iter_mut().enumerate() {
        *b = b.saturating_sub(prev.buckets.get(i).copied().unwrap_or(0));
    }
    out.count = cur.count.saturating_sub(prev.count);
    out.sum = cur.sum.saturating_sub(prev.sum);
    out
}

struct Inner {
    /// Snapshot that opened the current window (`None` until the first
    /// observe establishes a baseline).
    baseline: Option<ObsSnapshot>,
    frames: VecDeque<Frame>,
    dropped: u64,
}

/// Clonable shared handle to the frame ring. One per cluster (leader)
/// or per simulation.
#[derive(Clone)]
pub struct Timeline {
    window_s: f64,
    cap: usize,
    inner: Arc<Mutex<Inner>>,
}

impl Default for Timeline {
    fn default() -> Self {
        Timeline::new(TimelineConfig::default())
    }
}

impl Timeline {
    pub fn new(cfg: TimelineConfig) -> Self {
        Timeline {
            window_s: cfg.window_s.max(1e-9),
            cap: cfg.cap.max(1),
            inner: Arc::new(Mutex::new(Inner {
                baseline: None,
                frames: VecDeque::new(),
                dropped: 0,
            })),
        }
    }

    pub fn with_window(window_s: f64) -> Self {
        Timeline::new(TimelineConfig {
            window_s,
            ..Default::default()
        })
    }

    pub fn window_s(&self) -> f64 {
        self.window_s
    }

    /// Feed a fresh snapshot. The first call establishes the baseline;
    /// later calls close a frame (returning `true`) once the snapshot
    /// timestamp is a full window past the open frame's start. Calls
    /// inside the window are discarded — scraping faster than the
    /// window is allowed and cheap.
    pub fn observe(&self, snap: ObsSnapshot) -> bool {
        self.feed(snap, false)
    }

    /// Close the open window regardless of fill — the end-of-run
    /// flush, so a final partial frame is never lost.
    pub fn flush(&self, snap: ObsSnapshot) -> bool {
        self.feed(snap, true)
    }

    fn feed(&self, snap: ObsSnapshot, force: bool) -> bool {
        let mut inner = self.inner.lock().unwrap();
        let Some(base) = inner.baseline.as_ref() else {
            inner.baseline = Some(snap);
            return false;
        };
        let span = snap.at - base.at;
        if !force && span < self.window_s {
            return false;
        }
        if force && span <= 0.0 {
            return false;
        }
        let frame = diff_frame(base, &snap);
        inner.baseline = Some(snap);
        inner.frames.push_back(frame);
        while inner.frames.len() > self.cap {
            inner.frames.pop_front();
            inner.dropped += 1;
        }
        true
    }

    /// All retained frames, oldest first.
    pub fn frames(&self) -> Vec<Frame> {
        self.inner.lock().unwrap().frames.iter().cloned().collect()
    }

    pub fn latest(&self) -> Option<Frame> {
        self.inner.lock().unwrap().frames.back().cloned()
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().frames.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Frames evicted off the ring's front so far.
    pub fn dropped(&self) -> u64 {
        self.inner.lock().unwrap().dropped
    }

    /// The whole series as one JSON document — the artifact fig20
    /// drops next to its bench tables.
    pub fn to_json(&self) -> Json {
        let inner = self.inner.lock().unwrap();
        Json::obj(vec![
            ("window_s", Json::num(self.window_s)),
            ("dropped", Json::num(inner.dropped as f64)),
            (
                "frames",
                Json::Arr(inner.frames.iter().map(|f| f.to_json()).collect()),
            ),
        ])
    }
}

fn diff_frame(base: &ObsSnapshot, cur: &ObsSnapshot) -> Frame {
    let mut f = Frame {
        t0: base.at,
        t1: cur.at,
        ..Default::default()
    };
    for (k, v) in &cur.entries {
        match v {
            MetricValue::Counter(n) => {
                f.counters.insert(k.clone(), *n);
                let prev = base.counter(k);
                if *n > prev {
                    f.deltas.insert(k.clone(), n - prev);
                }
            }
            MetricValue::Gauge(x) => {
                f.gauges.insert(k.clone(), *x);
            }
            MetricValue::Histo(h) => {
                let d = match base.histo(k) {
                    Some(prev) => histo_sub(h, prev),
                    None => h.clone(),
                };
                if d.count > 0 {
                    f.histos.insert(k.clone(), d);
                }
            }
        }
    }
    f
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::registry::{Labels, Registry};

    #[test]
    fn frames_carry_per_window_deltas() {
        let r = Registry::new(true);
        let c = r.counter("routes", Labels::none());
        let h = r.histogram("lat", Labels::none());
        let g = r.gauge("lag", Labels::shard(0));
        let tl = Timeline::with_window(1.0);

        c.inc(5);
        h.observe(100);
        g.set(2.0);
        assert!(!tl.observe(r.snapshot(0.0)), "first call is the baseline");

        c.inc(3);
        h.observe(200);
        h.observe(400);
        g.set(7.0);
        assert!(!tl.observe(r.snapshot(0.4)), "inside the window");
        assert!(tl.observe(r.snapshot(1.0)), "window filled");

        let f = tl.latest().unwrap();
        assert_eq!(f.t0, 0.0);
        assert_eq!(f.t1, 1.0);
        assert_eq!(f.counter("routes"), 8, "absolute at window end");
        assert_eq!(f.delta("routes"), 3, "increment within the window");
        assert_eq!(f.gauge("lag{shard=0}"), Some(7.0));
        let d = f.histo("lat").unwrap();
        assert_eq!(d.count, 2, "only in-window observations");
        assert_eq!(d.sum, 600);
    }

    #[test]
    fn unchanged_counters_produce_no_delta_entries() {
        let r = Registry::new(true);
        r.counter("a", Labels::none()).inc(2);
        r.counter("b", Labels::none()).inc(1);
        let tl = Timeline::with_window(1.0);
        tl.observe(r.snapshot(0.0));
        r.counter("a", Labels::none()).inc(1);
        assert!(tl.observe(r.snapshot(1.5)));
        let f = tl.latest().unwrap();
        assert_eq!(f.delta("a"), 1);
        assert!(!f.deltas.contains_key("b"), "quiet counter omitted");
        assert_eq!(f.counter("b"), 1, "but its absolute is retained");
    }

    #[test]
    fn ring_caps_and_counts_evictions() {
        let r = Registry::new(true);
        let c = r.counter("x", Labels::none());
        let tl = Timeline::new(TimelineConfig {
            window_s: 1.0,
            cap: 3,
        });
        tl.observe(r.snapshot(0.0));
        for i in 1..=6u32 {
            c.inc(1);
            assert!(tl.observe(r.snapshot(i as f64)));
        }
        assert_eq!(tl.len(), 3);
        assert_eq!(tl.dropped(), 3);
        let frames = tl.frames();
        assert_eq!(frames[0].t0, 3.0, "oldest surviving frame");
        assert_eq!(frames[2].t1, 6.0);
    }

    #[test]
    fn flush_closes_a_partial_window() {
        let r = Registry::new(true);
        let tl = Timeline::with_window(10.0);
        tl.observe(r.snapshot(0.0));
        r.counter("x", Labels::none()).inc(4);
        assert!(!tl.observe(r.snapshot(2.0)), "window not filled");
        assert!(tl.flush(r.snapshot(2.0)), "flush closes it anyway");
        let f = tl.latest().unwrap();
        assert_eq!((f.t0, f.t1), (0.0, 2.0));
        assert_eq!(f.delta("x"), 4);
        assert!(!tl.flush(r.snapshot(2.0)), "zero-span flush is a no-op");
    }

    #[test]
    fn json_roundtrip() {
        let r = Registry::new(true);
        let tl = Timeline::with_window(1.0);
        tl.observe(r.snapshot(0.0));
        r.counter("n", Labels::none()).inc(2);
        r.histogram("lat", Labels::none()).observe(64);
        tl.observe(r.snapshot(1.0));
        let j = crate::util::json::Json::parse(&tl.to_json().to_string())
            .unwrap();
        assert_eq!(
            j.at(&["frames"]).unwrap().as_arr().unwrap().len(),
            1
        );
        assert_eq!(
            j.as_obj()
                .unwrap()
                .get("frames")
                .unwrap()
                .as_arr()
                .unwrap()[0]
                .at(&["deltas", "n"])
                .unwrap()
                .as_f64(),
            Some(2.0)
        );
    }
}
