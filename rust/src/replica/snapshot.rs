//! Compact fused-tree snapshots — replica bootstrap and log truncation
//! (ISSUE 4 tentpole, part 2).
//!
//! A replica that joins late (or falls behind a truncated log) cannot
//! replay from sequence 0; it bootstraps from a [`TreeSnapshot`]
//! captured at a known log position and then catches up on the delta
//! suffix. The snapshot is *semantic*, not structural: it records every
//! `(instance, token-path, last-insert stamp)` ownership pair
//! ([`crate::scheduler::fused_tree::FusedPromptTree::ownership_entries`])
//! plus the instance registry — never node indices, never addresses —
//! and restores by replaying the entries as ordinary `Record` deltas in
//! ascending-stamp order through the same `apply_delta`-family
//! machinery the log uses. Restored state is therefore equivalent by
//! construction: matches, per-instance counters, *and* TTL expiry
//! behave bit-identically to a replica that applied the full log
//! (interior stamps are preserved — the differential tests in
//! [`crate::replica::group`] pin this, collision masks included).
//!
//! Snapshots also gate log truncation: once every replica's ack has
//! passed a snapshot's sequence, [`crate::replica::log::DeltaTransport::
//! truncate_below`] may drop the prefix — the snapshot is the recovery
//! path for anything older.

use crate::mempool::InstanceId;
use crate::scheduler::prompt_tree::{GlobalPromptTrees, InstanceKind};

/// One ownership fact: `instance` cached `tokens` as of `stamp`.
#[derive(Clone, Debug, PartialEq)]
pub struct SnapshotEntry {
    pub instance: InstanceId,
    pub tokens: Vec<u32>,
    pub stamp: f64,
}

/// A fused-tree snapshot at log position `seq` (the first delta NOT
/// reflected in it — catch-up replays from `seq`).
#[derive(Clone, Debug)]
pub struct TreeSnapshot {
    pub seq: u64,
    pub block_tokens: usize,
    /// Instance registry: id, kind, draining flag.
    pub instances: Vec<(InstanceId, InstanceKind, bool)>,
    /// Ownership pairs, ascending `(stamp, instance, tokens)` — the
    /// restore replay order.
    pub entries: Vec<SnapshotEntry>,
}

impl TreeSnapshot {
    /// Capture `tree`'s full ownership state as of log position `seq`.
    pub fn capture(tree: &GlobalPromptTrees, seq: u64) -> TreeSnapshot {
        let instances = tree
            .instances()
            .map(|(id, kind)| (id, kind, tree.is_draining(id)))
            .collect();
        let mut entries: Vec<SnapshotEntry> = tree
            .ownership_entries()
            .into_iter()
            .map(|(instance, tokens, stamp)| SnapshotEntry {
                instance,
                tokens,
                stamp,
            })
            .collect();
        entries.sort_by(|a, b| {
            a.stamp
                .total_cmp(&b.stamp)
                .then(a.instance.cmp(&b.instance))
                .then(a.tokens.cmp(&b.tokens))
        });
        TreeSnapshot {
            seq,
            block_tokens: tree.block_tokens(),
            instances,
            entries,
        }
    }

    /// Token-block total across entries (wire-size estimate).
    pub fn token_blocks(&self) -> usize {
        self.entries
            .iter()
            .map(|e| e.tokens.len() / self.block_tokens.max(1))
            .sum()
    }

    /// Load this snapshot into an **empty** tree (the caller constructs
    /// it with its own TTL — and, in tests, fingerprint mask — so the
    /// replica's config, not the snapshot, governs those).
    pub fn restore_into(&self, tree: &mut GlobalPromptTrees) {
        assert_eq!(
            tree.block_tokens(),
            self.block_tokens,
            "snapshot/replica block_tokens mismatch"
        );
        assert_eq!(
            tree.node_count(),
            0,
            "snapshot restore requires an empty tree"
        );
        for &(id, kind, _) in &self.instances {
            tree.add_instance(id, kind);
        }
        // Ascending-stamp replay: each node's own entry carries the
        // maximum stamp on its path and lands last, so interior stamps
        // come out exact (see `ownership_entries`).
        for e in &self.entries {
            tree.record(e.instance, &e.tokens, e.stamp);
        }
        for &(id, _, draining) in &self.instances {
            if draining {
                tree.set_draining(id, true);
            }
        }
    }

    /// Convenience: restore into a fresh tree with TTL `ttl`.
    pub fn restore(&self, ttl: f64) -> GlobalPromptTrees {
        let mut tree = GlobalPromptTrees::new(self.block_tokens, ttl);
        self.restore_into(&mut tree);
        tree
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::elastic::delta::DeltaEvent;
    use crate::scheduler::prompt_tree::match_all_vec;

    const BT: usize = 4;

    fn toks(n: usize, seed: u32) -> Vec<u32> {
        (0..n as u32).map(|i| i * 5 + seed).collect()
    }

    fn busy_tree() -> GlobalPromptTrees {
        let mut g = GlobalPromptTrees::new(BT, 20.0);
        for i in 0..5 {
            let kind = if i == 4 {
                InstanceKind::DecodeOnly
            } else {
                InstanceKind::PrefillOnly
            };
            g.add_instance(InstanceId(i), kind);
        }
        g.record(InstanceId(0), &toks(12, 0), 1.0);
        g.record(InstanceId(1), &toks(12, 0), 2.0);
        g.record(InstanceId(1), &toks(8, 0), 6.0); // fresher interior
        g.record(InstanceId(2), &toks(16, 100), 3.0);
        g.record(InstanceId(4), &toks(8, 0), 4.0); // decode-only view
        g.apply_delta(&DeltaEvent::Handoff {
            from: InstanceId(2),
            to: InstanceId(3),
            tokens: toks(16, 100),
            now: 5.0,
        });
        g.set_draining(InstanceId(0), true);
        g
    }

    #[test]
    fn capture_restore_preserves_matches_and_counters() {
        let mut g = busy_tree();
        let snap = TreeSnapshot::capture(&g, 42);
        assert_eq!(snap.seq, 42);
        assert!(snap.token_blocks() > 0);
        let mut r = snap.restore(20.0);
        for i in 0..5 {
            let id = InstanceId(i);
            assert_eq!(g.cached_blocks(id), r.cached_blocks(id), "{id}");
            assert_eq!(g.is_draining(id), r.is_draining(id));
            for probe in [toks(12, 0), toks(16, 100), toks(8, 7)] {
                assert_eq!(
                    g.match_one(id, &probe),
                    r.match_one(id, &probe),
                    "{id} probe"
                );
            }
        }
        assert_eq!(
            match_all_vec(&mut g, &toks(12, 0)),
            match_all_vec(&mut r, &toks(12, 0))
        );
        r.debug_check_counters();
    }

    #[test]
    fn restored_ttl_expiry_is_bit_identical() {
        let mut g = busy_tree();
        let snap = TreeSnapshot::capture(&g, 0);
        let mut r = snap.restore(20.0);
        // Sweep a range of clocks across every stamp boundary: the
        // restored tree must expire in lockstep (interior stamps exact).
        for now in [21.5, 22.5, 23.5, 25.5, 26.5, 40.0] {
            g.expire(now);
            r.expire(now);
            for i in 0..5 {
                let id = InstanceId(i);
                for probe in [toks(12, 0), toks(16, 100)] {
                    assert_eq!(
                        g.match_one(id, &probe),
                        r.match_one(id, &probe),
                        "{id} at now={now}"
                    );
                }
                assert_eq!(
                    g.cached_blocks(id),
                    r.cached_blocks(id),
                    "{id} at now={now}"
                );
            }
        }
        r.debug_check_counters();
    }

    #[test]
    fn empty_tree_snapshots_cleanly() {
        let g = GlobalPromptTrees::new(BT, 0.0);
        let snap = TreeSnapshot::capture(&g, 7);
        assert!(snap.entries.is_empty());
        let r = snap.restore(0.0);
        assert_eq!(r.instance_count(), 0);
        assert_eq!(r.node_count(), 0);
    }

    #[test]
    #[should_panic(expected = "block_tokens mismatch")]
    fn restore_rejects_geometry_mismatch() {
        let g = GlobalPromptTrees::new(BT, 0.0);
        let snap = TreeSnapshot::capture(&g, 0);
        let mut other = GlobalPromptTrees::new(BT * 2, 0.0);
        snap.restore_into(&mut other);
    }
}
