//! `ReplicaGroup` — one primary plus N follower replicas of the global
//! prompt tree behind the sequenced delta log (ISSUE 4 tentpole,
//! part 3).
//!
//! The deterministic in-process replication engine: the discrete-event
//! simulator mirrors every ownership delta through it so a scripted GS
//! crash can promote a follower mid-trace, `benches/fig17_replica.rs`
//! measures route throughput and failover blackout on it, and the
//! differential tests in this module pin the whole protocol stack
//! (transport windowing, loss + re-request, snapshot bootstrap,
//! promotion catch-up) against a log-order reference tree. The live
//! server runs the same [`DeltaTransport`]/[`DeltaCursor`]/
//! [`TreeSnapshot`] pieces over real fabric messages instead
//! (`server/replica.rs`).
//!
//! Semantics:
//!
//! * **Writes** go to the primary: [`ReplicaGroup::apply`] applies the
//!   delta to the primary's tree and appends it to the transport;
//!   [`ReplicaGroup::pump`] ships sendable windows to followers, drains
//!   their acks, and truncates the log behind the slowest replica.
//! * **Reads** (route matching) are serveable from *any* live replica —
//!   [`ReplicaGroup::route_match`] — because replicas of the same
//!   prefix of the log agree exactly (a follower can at worst lag,
//!   never diverge).
//! * **Failover**: [`ReplicaGroup::fail_primary`] kills the primary and
//!   promotes the most-caught-up follower; before it serves, promotion
//!   *catches up* from the surviving replicas' retained log suffixes
//!   (any entry some survivor applied is recoverable — entries only the
//!   dead primary held are gone, which the bounded ack window keeps
//!   small). The promoted replica's retained suffix seeds the new
//!   transport so laggard followers resync from it.
//! * **Late join**: [`ReplicaGroup::join_replica`] bootstraps a fresh
//!   replica from a primary snapshot at the current log head, then
//!   catches up on the delta suffix like any follower.

use crate::elastic::delta::DeltaEvent;
use crate::mempool::InstanceId;
use crate::replica::log::{DeltaCursor, DeltaTransport, Ingest, SeqBuffer};
use crate::replica::snapshot::TreeSnapshot;
use crate::scheduler::prompt_tree::GlobalPromptTrees;

struct Replica {
    tree: GlobalPromptTrees,
    cursor: DeltaCursor,
    /// Applied suffix retained for peer catch-up after a primary
    /// failure — the shared [`SeqBuffer`] core (one implementation for
    /// this and the transport's retained log). Trimmed in lockstep with
    /// the transport's truncation.
    retained: SeqBuffer,
}

/// See module docs.
pub struct ReplicaGroup {
    replicas: Vec<Option<Replica>>,
    primary: usize,
    transport: DeltaTransport,
    block_tokens: usize,
    ttl: f64,
    window: usize,
    /// Deltas delivered to followers (diagnostics/benches).
    delivered: u64,
    /// Coalesced acks processed (≤ one per follower per pump; the ack-
    /// storm regression guard — pre-batching this equaled `delivered`).
    acks_sent: u64,
}

impl ReplicaGroup {
    /// A group of `n` replicas (primary = index 0, `n - 1` followers).
    pub fn new(n: usize, block_tokens: usize, ttl: f64, window: usize)
               -> Self {
        assert!(n >= 1);
        let mut transport = DeltaTransport::new(window);
        let mut replicas = vec![];
        for i in 0..n {
            if i != 0 {
                transport.register(i as u64, 0);
            }
            replicas.push(Some(Replica {
                tree: GlobalPromptTrees::new(block_tokens, ttl),
                cursor: DeltaCursor::new(),
                retained: SeqBuffer::new(),
            }));
        }
        ReplicaGroup {
            replicas,
            primary: 0,
            transport,
            block_tokens,
            ttl,
            window,
            delivered: 0,
            acks_sent: 0,
        }
    }

    /// Test hook: force fingerprint collisions on every replica tree.
    /// Must run before any delta.
    #[doc(hidden)]
    pub fn set_fingerprint_mask(&mut self, mask: u64) {
        for r in self.replicas.iter_mut().flatten() {
            r.tree.set_fingerprint_mask(mask);
        }
    }

    pub fn primary_index(&self) -> usize {
        self.primary
    }

    pub fn len(&self) -> usize {
        self.replicas.len()
    }

    pub fn is_empty(&self) -> bool {
        self.replicas.is_empty()
    }

    pub fn is_live(&self, i: usize) -> bool {
        self.replicas.get(i).is_some_and(|r| r.is_some())
    }

    pub fn live_indices(&self) -> Vec<usize> {
        (0..self.replicas.len())
            .filter(|&i| self.is_live(i))
            .collect()
    }

    pub fn delivered(&self) -> u64 {
        self.delivered
    }

    /// Coalesced acks processed so far (≤ followers × pumps).
    pub fn acks_sent(&self) -> u64 {
        self.acks_sent
    }

    pub fn resends(&self) -> u64 {
        self.transport.resends()
    }

    pub fn log_head(&self) -> u64 {
        self.transport.next_seq()
    }

    pub fn retained_log_len(&self) -> usize {
        self.transport.retained_len()
    }

    /// Sequences replica `i` has contiguously applied.
    pub fn applied_seq(&self, i: usize) -> u64 {
        if i == self.primary {
            self.transport.next_seq()
        } else {
            self.replicas[i]
                .as_ref()
                .map(|r| r.cursor.expected())
                .unwrap_or(0)
        }
    }

    pub fn all_caught_up(&self) -> bool {
        self.transport.all_caught_up()
    }

    /// Read access to replica `i`'s tree (`None` when dead).
    pub fn tree(&self, i: usize) -> Option<&GlobalPromptTrees> {
        self.replicas.get(i)?.as_ref().map(|r| &r.tree)
    }

    /// Route-read from replica `i`: the one-walk fleet match (needs
    /// `&mut` only for the tree's reusable scratch buffers).
    pub fn route_match(
        &mut self,
        i: usize,
        tokens: &[u32],
        out: &mut Vec<(InstanceId, usize)>,
    ) {
        let Some(r) = self.replicas.get_mut(i).and_then(Option::as_mut)
        else {
            debug_assert!(false, "route_match on dead replica {i}");
            out.clear();
            return;
        };
        r.tree.match_into(tokens, out);
    }

    /// Apply one delta at the primary and append it to the log; ship it
    /// with [`Self::pump`]. Returns the assigned sequence.
    pub fn apply(&mut self, ev: DeltaEvent) -> u64 {
        let Some(r) = self.replicas[self.primary].as_mut() else {
            // A write against a dead primary is a caller bug; dropping
            // it (rather than appending a delta no tree applied) keeps
            // log and tree in agreement.
            debug_assert!(false, "apply with dead primary — promote first");
            log::error!("dropping delta applied to dead primary");
            return self.transport.next_seq();
        };
        r.tree.apply_delta(&ev);
        self.transport.append(ev)
    }

    /// [`Self::apply`] + pump until every live follower confirms —
    /// synchronous replication for deterministic callers (the sim).
    pub fn apply_sync(&mut self, ev: DeltaEvent) -> u64 {
        let seq = self.apply(ev);
        let mut guard = 0;
        while !self.transport.all_caught_up() {
            self.pump();
            guard += 1;
            assert!(guard < 1_000_000, "replication failed to converge");
        }
        seq
    }

    /// Deliver every sendable window, reliably and in order.
    pub fn pump(&mut self) {
        self.pump_lossy(&mut |_, _| false);
    }

    /// Deliver sendable windows with fault injection: `drop(replica,
    /// seq)` true drops that delivery on the floor (the entry is marked
    /// sent, so only the receiver's gap re-request — an ack regression —
    /// recovers it, exactly like a lost fabric message).
    pub fn pump_lossy(&mut self, drop: &mut dyn FnMut(usize, u64) -> bool) {
        let peers: Vec<u64> = self.transport.peers().collect();
        for peer in peers {
            let i = peer as usize;
            if !self.is_live(i) {
                continue;
            }
            let mut range = self.transport.sendable(peer);
            if range.is_empty() && self.transport.lag(peer) > 0 {
                // Nothing new to send but the peer is behind: the log
                // tail was lost in flight (marked sent, never acked, no
                // later entry to trigger a gap re-request). Pump doubles
                // as the retransmit timer: rewind and re-offer.
                self.transport.retransmit_unacked(peer);
                range = self.transport.sendable(peer);
            }
            if range.is_empty() {
                continue;
            }
            // Batched acks (ISSUE 5 satellite): the receiver no longer
            // acks every delta — it coalesces the whole delivered batch
            // into ONE cumulative ack per pump. The cursor's `expected`
            // value is simultaneously the cumulative ack and (when it
            // trails what was just sent) the gap re-request, so loss
            // recovery latency is unchanged: the very next ack after a
            // gap rewinds the send cursor.
            let mut delivered_any = false;
            for seq in range.clone() {
                let Some(ev) = self.transport.get(seq).cloned() else {
                    debug_assert!(false, "sendable {seq} not retained");
                    continue;
                };
                if drop(i, seq) {
                    continue;
                }
                // Liveness was checked at loop entry and nothing in
                // between kills replicas; skip the peer if it raced.
                let Some(r) = self.replicas[i].as_mut() else { break };
                self.delivered += 1;
                delivered_any = true;
                match r.cursor.offer(seq, ev) {
                    Ingest::Ready(evs) => {
                        let first = r.cursor.expected() - evs.len() as u64;
                        for (k, e) in evs.into_iter().enumerate() {
                            r.tree.apply_delta(&e);
                            r.retained.push_at(first + k as u64, e);
                        }
                    }
                    Ingest::Buffered { .. } | Ingest::Duplicate => {}
                }
            }
            self.transport.mark_sent(peer, range.end);
            if delivered_any {
                // A receiver that got NOTHING sends nothing (a real NIC
                // has no stimulus); the sender-side retransmit timer
                // above recovers a fully-lost tail.
                let Some(r) = self.replicas[i].as_ref() else {
                    continue;
                };
                let next = r.cursor.expected();
                self.acks_sent += 1;
                self.transport.on_ack(peer, next);
            }
        }
        // Truncate behind the slowest live replica; followers trim
        // their retained suffixes in lockstep.
        self.transport.truncate_below(self.transport.min_acked());
        let floor = self.transport.first_retained();
        for r in self.replicas.iter_mut().flatten() {
            r.retained.trim_below(floor);
        }
    }

    /// Kill replica `i` (crash injection). Killing the primary leaves
    /// the group write-dead until [`Self::fail_primary`] promotes.
    pub fn kill(&mut self, i: usize) {
        self.replicas[i] = None;
        self.transport.deregister(i as u64);
    }

    /// Crash the primary and promote the most-caught-up live follower
    /// (ties break toward the lowest index). Before serving, the
    /// promotee catches up from every survivor's retained suffix — any
    /// delta that reached *some* follower survives the crash. Its own
    /// retained suffix then seeds the new transport so laggards resync.
    /// Returns the promoted index, or `None` when no follower survives.
    pub fn fail_primary(&mut self) -> Option<usize> {
        self.kill(self.primary);
        let promoted = self
            .live_indices()
            .into_iter()
            .max_by_key(|&i| {
                (
                    self.replicas[i]
                        .as_ref()
                        .map(|r| r.cursor.expected())
                        .unwrap_or(0),
                    usize::MAX - i,
                )
            })?;
        // Catch-up: pull contiguous entries beyond the promotee's
        // cursor out of any survivor's retained log.
        loop {
            let Some(pr) = self.replicas[promoted].as_ref() else {
                break;
            };
            let need = pr.cursor.expected();
            let mut found = None;
            for i in self.live_indices() {
                if let Some(ev) = self.replicas[i]
                    .as_ref()
                    .and_then(|r| r.retained.get(need))
                {
                    found = Some(ev.clone());
                    break;
                }
            }
            let Some(ev) = found else { break };
            let Some(r) = self.replicas[promoted].as_mut() else { break };
            match r.cursor.offer(need, ev) {
                Ingest::Ready(evs) => {
                    let first = r.cursor.expected() - evs.len() as u64;
                    for (k, e) in evs.into_iter().enumerate() {
                        r.tree.apply_delta(&e);
                        r.retained.push_at(first + k as u64, e);
                    }
                }
                Ingest::Buffered { .. } | Ingest::Duplicate => {
                    // Offering exactly at the cursor always returns
                    // Ready; bail out of catch-up rather than loop.
                    debug_assert!(false, "offer at cursor not ready");
                    break;
                }
            }
        }
        // Rebuild the transport around the promotee's retained suffix.
        let Some(p) = self.replicas[promoted].as_mut() else {
            debug_assert!(false, "promoted replica vanished mid-failover");
            return None;
        };
        // Anything still buffered out-of-order at the promotee is an
        // old-primary event beyond the surviving history — dead.
        let head = p.cursor.expected();
        p.cursor.purge_from(head);
        let base = p.retained.base();
        let mut transport = DeltaTransport::new(self.window);
        transport.advance_base(base);
        for ev in p.retained.iter() {
            transport.append(ev.clone());
        }
        let head = transport.next_seq();
        for i in 0..self.replicas.len() {
            if i != promoted {
                let Some(r) = self.replicas[i].as_mut() else {
                    continue;
                };
                // Sequences >= the new head will be reassigned to
                // DIFFERENT events by the new primary; anything a
                // laggard buffered from the dead primary there is stale
                // and would silently diverge the replica when its
                // contiguous run reaches it. Purge before re-serving.
                r.cursor.purge_from(head);
                let from = r.cursor.expected().max(base);
                transport.register(i as u64, from);
            }
        }
        self.transport = transport;
        self.primary = promoted;
        self.pump();
        Some(promoted)
    }

    /// Extract the promoted (or any live) replica's tree, marking the
    /// replica dead — the in-process convenience the simulator uses to
    /// hand the promoted state to its serving scheduler.
    pub fn extract_tree(&mut self, i: usize) -> Option<GlobalPromptTrees> {
        self.transport.deregister(i as u64);
        self.replicas.get_mut(i)?.take().map(|r| r.tree)
    }

    /// Bootstrap a new follower from a primary snapshot at the log head
    /// (snapshot + catch-up, the late-joiner path). Returns its index.
    /// Returns `None` when the primary is dead (nothing to snapshot).
    pub fn join_replica(&mut self) -> Option<usize> {
        let seq = self.transport.next_seq();
        let primary = self.replicas[self.primary].as_ref()?;
        let snap = TreeSnapshot::capture(&primary.tree, seq);
        let mut tree = GlobalPromptTrees::new(self.block_tokens, self.ttl);
        snap.restore_into(&mut tree);
        let mut cursor = DeltaCursor::new();
        let ready = cursor.advance_to(seq);
        debug_assert!(ready.is_empty());
        let idx = self.replicas.len();
        self.transport.register(idx as u64, seq);
        self.replicas.push(Some(Replica {
            tree,
            cursor,
            retained: SeqBuffer::with_base(seq),
        }));
        Some(idx)
    }

    /// Snapshot the primary at the current log head (`None` when the
    /// primary is dead).
    pub fn snapshot(&self) -> Option<TreeSnapshot> {
        let primary = self.replicas[self.primary].as_ref()?;
        Some(TreeSnapshot::capture(&primary.tree, self.transport.next_seq()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scheduler::policy::{decide, Candidate, PolicyKind};
    use crate::scheduler::prompt_tree::InstanceKind;
    use crate::util::proptest::proptest;

    const BT: usize = 4;

    fn toks(n: usize, seed: u32) -> Vec<u32> {
        (0..n as u32).map(|i| i * 3 + seed).collect()
    }

    fn seed_instances(g: &mut ReplicaGroup, n: u32) {
        for i in 0..n {
            g.apply_sync(DeltaEvent::Join {
                instance: InstanceId(i),
                kind: InstanceKind::PrefillOnly,
            });
        }
    }

    fn matches_of(
        g: &mut ReplicaGroup,
        i: usize,
        t: &[u32],
    ) -> Vec<(InstanceId, usize)> {
        let mut out = vec![];
        g.route_match(i, t, &mut out);
        out
    }

    #[test]
    fn followers_converge_and_serve_reads() {
        let mut g = ReplicaGroup::new(3, BT, 0.0, 8);
        seed_instances(&mut g, 4);
        let t = toks(12, 0);
        g.apply_sync(DeltaEvent::Record {
            instance: InstanceId(2),
            tokens: t.clone(),
            now: 1.0,
        });
        let want = matches_of(&mut g, 0, &t);
        assert_eq!(want[2], (InstanceId(2), 12));
        for i in 1..3 {
            assert_eq!(matches_of(&mut g, i, &t), want, "replica {i}");
        }
        assert!(g.all_caught_up());
        // Log truncates behind the acked fleet.
        assert_eq!(g.retained_log_len(), 0);
    }

    #[test]
    fn lost_deliveries_recover_via_gap_rerequest() {
        let mut g = ReplicaGroup::new(2, BT, 0.0, 4);
        seed_instances(&mut g, 2);
        for k in 0..10u32 {
            g.apply(DeltaEvent::Record {
                instance: InstanceId(k % 2),
                tokens: toks(8, k),
                now: k as f64,
            });
        }
        // Drop every third delivery on the first pass.
        let mut n = 0;
        g.pump_lossy(&mut |_, _| {
            n += 1;
            n % 3 == 0
        });
        assert!(!g.all_caught_up(), "drops must leave a gap");
        let mut guard = 0;
        while !g.all_caught_up() {
            g.pump();
            guard += 1;
            assert!(guard < 100);
        }
        assert!(g.resends() > 0, "recovery must have rewound the cursor");
        let t = toks(8, 9);
        assert_eq!(matches_of(&mut g, 1, &t), matches_of(&mut g, 0, &t));
    }

    #[test]
    fn acks_are_batched_per_pump_and_lossy_streams_still_converge() {
        // ISSUE 5 satellite: one coalesced ack per follower per pump —
        // not one per delta (the ack storm) — while lossy delivery
        // still converges through the same gap re-request discipline.
        let mut g = ReplicaGroup::new(3, BT, 0.0, 64);
        seed_instances(&mut g, 2); // apply_sync: some pumps already ran
        let base_acks = g.acks_sent();
        for k in 0..40u32 {
            g.apply(DeltaEvent::Record {
                instance: InstanceId(k % 2),
                tokens: toks(8, k),
                now: k as f64,
            });
        }
        // One pump ships all 40 deltas to both followers: exactly one
        // ack each.
        g.pump();
        assert!(g.all_caught_up());
        assert_eq!(g.acks_sent() - base_acks, 2, "acks not batched");
        // Lossy: drop a third of deliveries; convergence must survive
        // batching, with ≤ one ack per follower per pump.
        let mut n = 0;
        let before = g.acks_sent();
        for k in 0..20u32 {
            g.apply(DeltaEvent::Record {
                instance: InstanceId(k % 2),
                tokens: toks(8, 100 + k),
                now: k as f64,
            });
        }
        let mut pumps = 0u64;
        g.pump_lossy(&mut |_, _| {
            n += 1;
            n % 3 == 0
        });
        pumps += 1;
        while !g.all_caught_up() {
            g.pump();
            pumps += 1;
            assert!(pumps < 100, "lossy pump failed to converge");
        }
        assert!(g.acks_sent() - before <= 2 * pumps);
        let t = toks(8, 119);
        assert_eq!(matches_of(&mut g, 1, &t), matches_of(&mut g, 0, &t));
        assert_eq!(matches_of(&mut g, 2, &t), matches_of(&mut g, 0, &t));
    }

    #[test]
    fn total_loss_at_log_tail_recovers_on_next_pump() {
        // Lose EVERY delivery of the log tail: no later entry exists to
        // trigger the receiver's gap re-request, so the sender's pump
        // must retransmit unacked in-flight entries on its own.
        let mut g = ReplicaGroup::new(2, BT, 0.0, 8);
        seed_instances(&mut g, 2);
        let t = toks(12, 5);
        g.apply(DeltaEvent::Record {
            instance: InstanceId(0),
            tokens: t.clone(),
            now: 1.0,
        });
        g.pump_lossy(&mut |_, _| true);
        assert!(!g.all_caught_up(), "everything was dropped");
        let mut n = 0;
        while !g.all_caught_up() {
            g.pump();
            n += 1;
            assert!(n < 10, "pump must retransmit the lost tail");
        }
        assert!(g.resends() > 0);
        assert_eq!(matches_of(&mut g, 1, &t), matches_of(&mut g, 0, &t));
    }

    #[test]
    fn failover_promotes_most_caught_up_with_catch_up() {
        let mut g = ReplicaGroup::new(3, BT, 0.0, 64);
        seed_instances(&mut g, 3);
        let hot = toks(16, 1);
        g.apply_sync(DeltaEvent::Record {
            instance: InstanceId(1),
            tokens: hot.clone(),
            now: 1.0,
        });
        // Two more records: replica 2 sees both, replica 1 sees neither
        // (lossy delivery to 1 only).
        for k in 0..2u32 {
            g.apply(DeltaEvent::Record {
                instance: InstanceId(0),
                tokens: toks(8, 50 + k),
                now: 2.0,
            });
        }
        g.pump_lossy(&mut |replica, _| replica == 1);
        assert_eq!(g.applied_seq(2), g.log_head());
        assert!(g.applied_seq(1) < g.log_head());
        let reference = matches_of(&mut g, 0, &hot);
        // Crash the primary: replica 2 must be promoted (most caught
        // up), and after promotion its reads equal the old primary's.
        let p = g.fail_primary().unwrap();
        assert_eq!(p, 2);
        assert_eq!(g.primary_index(), 2);
        assert_eq!(matches_of(&mut g, 2, &hot), reference);
        // The laggard follower resyncs from the promoted primary's
        // retained suffix (catch-up served the gap, not the dead node).
        let mut guard = 0;
        while !g.all_caught_up() {
            g.pump();
            guard += 1;
            assert!(guard < 100);
        }
        assert_eq!(matches_of(&mut g, 1, &hot), reference);
        for k in 0..2u32 {
            let t = toks(8, 50 + k);
            assert_eq!(matches_of(&mut g, 1, &t), matches_of(&mut g, 2, &t));
        }
        // Writes continue through the new primary.
        g.apply_sync(DeltaEvent::Record {
            instance: InstanceId(2),
            tokens: toks(12, 99),
            now: 3.0,
        });
        assert_eq!(
            matches_of(&mut g, 1, &toks(12, 99)),
            matches_of(&mut g, 2, &toks(12, 99))
        );
    }

    #[test]
    fn failover_purges_stale_buffered_entries_on_rebase() {
        // A promotion rebases the log: sequences past the promoted
        // replica's head are REUSED for different events. A laggard
        // that buffered the dead primary's entries at those sequences
        // must not apply them when its contiguous run arrives there.
        let mut g = ReplicaGroup::new(3, BT, 0.0, 8);
        seed_instances(&mut g, 2);
        let first = g.apply(DeltaEvent::Record {
            instance: InstanceId(0),
            tokens: toks(8, 100),
            now: 1.0,
        });
        g.apply(DeltaEvent::Record {
            instance: InstanceId(0),
            tokens: toks(8, 200), // the entry that dies with the primary
            now: 1.0,
        });
        // Deliver out of order: both followers miss `first`, buffer the
        // second — then the primary crashes before any resend.
        g.pump_lossy(&mut |_, seq| seq == first);
        let p = g.fail_primary().unwrap();
        // The new primary writes different events at the reused seqs.
        g.apply_sync(DeltaEvent::Record {
            instance: InstanceId(1),
            tokens: toks(8, 300),
            now: 2.0,
        });
        g.apply_sync(DeltaEvent::Record {
            instance: InstanceId(1),
            tokens: toks(8, 400),
            now: 2.0,
        });
        // The dead primary's seq-`first+1` record (seed 200) must exist
        // NOWHERE; the survivor must match the new primary exactly.
        for i in g.live_indices() {
            assert_eq!(
                g.tree(i).unwrap().match_one(InstanceId(0), &toks(8, 200)),
                0,
                "replica {i} applied a stale pre-crash entry"
            );
            for seed in [300, 400] {
                let t = toks(8, seed);
                assert_eq!(
                    g.tree(i).unwrap().match_one(InstanceId(1), &t),
                    g.tree(p).unwrap().match_one(InstanceId(1), &t),
                    "replica {i} diverged at seed {seed}"
                );
            }
        }
    }

    #[test]
    fn late_joiner_bootstraps_from_snapshot_then_log() {
        let mut g = ReplicaGroup::new(2, BT, 30.0, 16);
        seed_instances(&mut g, 3);
        g.apply_sync(DeltaEvent::Record {
            instance: InstanceId(0),
            tokens: toks(12, 0),
            now: 1.0,
        });
        let j = g.join_replica().expect("primary live");
        assert_eq!(g.applied_seq(j), g.log_head(), "snapshot covers log");
        // Deltas after the snapshot flow to the joiner like any
        // follower.
        g.apply_sync(DeltaEvent::Record {
            instance: InstanceId(1),
            tokens: toks(12, 7),
            now: 2.0,
        });
        for t in [toks(12, 0), toks(12, 7)] {
            assert_eq!(matches_of(&mut g, j, &t), matches_of(&mut g, 0, &t));
        }
    }

    /// ISSUE 4 satellite: the same delta stream through (a) the primary,
    /// (b) a follower behind the lossy windowed transport, and (c) a
    /// snapshot + catch-up late joiner yields identical route decisions
    /// — matched vectors, policy decisions, and per-instance counters —
    /// under the normal fingerprint and a collision-forcing 4-bit mask.
    /// A mid-stream primary crash must preserve the property on the
    /// promoted replica.
    #[test]
    fn prop_replicas_agree_with_primary_everywhere() {
        for mask in [u64::MAX, 0xF] {
            proptest(12, move |g| {
                let ttl = 10.0;
                let mut grp = ReplicaGroup::new(3, BT, ttl, 8);
                grp.set_fingerprint_mask(mask);
                let n_inst = 8 + g.usize(0, 8) as u32;
                for i in 0..n_inst {
                    let kind = match i % 4 {
                        0 => InstanceKind::DecodeOnly,
                        _ => InstanceKind::PrefillOnly,
                    };
                    grp.apply_sync(DeltaEvent::Join {
                        instance: InstanceId(i),
                        kind,
                    });
                }
                let mut joiner: Option<usize> = None;
                let mut now = 0.0;
                let n_ops = g.usize(15, 40);
                let crash_at = g.usize(5, n_ops);
                for op in 0..n_ops {
                    now += g.f64(0.1, 3.0);
                    let len = g.usize(0, 5) * BT + g.usize(0, BT - 1);
                    let t = g.vec_u32(len, 0, 3);
                    let inst = InstanceId(g.u64(0, (n_inst - 1) as u64) as u32);
                    let ev = match g.usize(0, 5) {
                        0 | 1 => DeltaEvent::Record {
                            instance: inst,
                            tokens: t.clone(),
                            now,
                        },
                        2 => DeltaEvent::Expire {
                            instance: inst,
                            prefix: t.clone(),
                        },
                        3 => DeltaEvent::Handoff {
                            from: inst,
                            to: InstanceId((inst.0 + 1) % n_inst),
                            tokens: t.clone(),
                            now,
                        },
                        4 => DeltaEvent::SetDraining {
                            instance: inst,
                            draining: g.bool(),
                        },
                        _ => DeltaEvent::Record {
                            instance: inst,
                            tokens: t.clone(),
                            now,
                        },
                    };
                    grp.apply(ev);
                    // Lossy, windowed delivery with occasional drops;
                    // convergence is forced only at comparison points.
                    let p_drop = g.f64(0.0, 0.3);
                    grp.pump_lossy(&mut |_, _| g.rng().chance(p_drop));
                    if op == 5 && joiner.is_none() {
                        // Force sync so the snapshot covers the stream,
                        // then bootstrap the late joiner.
                        while !grp.all_caught_up() {
                            grp.pump();
                        }
                        joiner = grp.join_replica();
                        assert!(joiner.is_some());
                    }
                    if op == crash_at {
                        while !grp.all_caught_up() {
                            grp.pump();
                        }
                        grp.fail_primary().expect("followers survive");
                    }
                }
                // Comparison point: fully synced, every live replica
                // must agree on every route decision.
                while !grp.all_caught_up() {
                    grp.pump();
                }
                let p = grp.primary_index();
                let probes: Vec<Vec<u32>> =
                    (0..6).map(|_| g.vec_u32(4 * BT, 0, 3)).collect();
                for t in &probes {
                    let want = matches_of(&mut grp, p, t);
                    let cands: Vec<Candidate> = want
                        .iter()
                        .map(|&(id, matched)| Candidate {
                            instance: id,
                            queued_tokens: (id.0 as usize * 37) % 256,
                            queued_cached_ratio: 0.0,
                            matched_tokens: matched,
                            pressure: 0.0,
                        })
                        .collect();
                    for i in grp.live_indices() {
                        let got = matches_of(&mut grp, i, t);
                        assert_eq!(got, want, "replica {i} diverged");
                        if !got.is_empty() {
                            let c2: Vec<Candidate> = got
                                .iter()
                                .map(|&(id, matched)| Candidate {
                                    instance: id,
                                    queued_tokens: (id.0 as usize * 37)
                                        % 256,
                                    queued_cached_ratio: 0.0,
                                    matched_tokens: matched,
                                    pressure: 0.0,
                                })
                                .collect();
                            for policy in [
                                PolicyKind::LeastLoad,
                                PolicyKind::PromptTree,
                            ] {
                                assert_eq!(
                                    decide(policy, &cands, t.len(), 3, |x,
                                     y| {
                                        x as f64 * (1.0 - y) + 1.0
                                    }),
                                    decide(policy, &c2, t.len(), 3, |x, y| {
                                        x as f64 * (1.0 - y) + 1.0
                                    }),
                                    "decision diverged on replica {i}"
                                );
                            }
                        }
                    }
                    for i in grp.live_indices() {
                        for inst in 0..n_inst {
                            let id = InstanceId(inst);
                            assert_eq!(
                                grp.tree(i).unwrap().cached_blocks(id),
                                grp.tree(p).unwrap().cached_blocks(id),
                                "cached_blocks({id}) on replica {i}"
                            );
                        }
                    }
                }
            });
        }
    }
}

