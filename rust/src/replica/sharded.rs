//! Per-shard replica groups (ISSUE 5 tentpole, replication side).
//!
//! One [`ReplicaGroup`] per prefix-range shard: a delta routed by
//! [`ShardMap`] lands in exactly one shard's sequenced log (membership
//! and whole-view expiries fan out), so delta application and log
//! append parallelize S-ways — N replicas per shard keep the PR 4
//! durability story while writes now scale with the shard count
//! instead of being serialized through one log.
//!
//! This is the deterministic in-process engine behind
//! `SimConfig.gs_shards` (scripted per-shard failover: one shard's
//! primary crashes and promotes while the other shards keep serving
//! untouched) and `benches/fig17_replica.rs`'s write-scaling sweep.
//! The live server runs the same split over fabric messages — one
//! `DeltaTransport` per shard inside `server/replica.rs::
//! GsReplication`, shard-tagged `Msg::Delta`/`Msg::DeltaAck`.

use crate::elastic::delta::DeltaEvent;
use crate::mempool::InstanceId;
use crate::replica::group::ReplicaGroup;
use crate::scheduler::prompt_tree::GlobalPromptTrees;
use crate::scheduler::shard::{ShardMap, ShardRoute};

/// S independent replica groups behind one delta surface (module docs).
pub struct ShardedReplicaGroup {
    /// `None` marks a shard whose promoted tree was extracted (the
    /// serving scheduler owns it now — the sim's failover landing);
    /// subsequent deltas for that shard are no longer mirrored.
    groups: Vec<Option<ReplicaGroup>>,
    map: ShardMap,
}

impl ShardedReplicaGroup {
    /// `shards` groups of `replicas` replicas each (primary +
    /// followers, exactly [`ReplicaGroup::new`] per shard).
    pub fn new(
        shards: usize,
        replicas: usize,
        block_tokens: usize,
        ttl: f64,
        window: usize,
    ) -> Self {
        assert!(shards >= 1);
        ShardedReplicaGroup {
            groups: (0..shards)
                .map(|_| {
                    Some(ReplicaGroup::new(replicas, block_tokens, ttl,
                                           window))
                })
                .collect(),
            map: ShardMap::new(shards, block_tokens),
        }
    }

    pub fn shards(&self) -> usize {
        self.groups.len()
    }

    pub fn map(&self) -> &ShardMap {
        &self.map
    }

    /// Test hook: force fingerprint collisions in the map and every
    /// shard's replica trees. Must run before any delta.
    #[doc(hidden)]
    pub fn set_fingerprint_mask(&mut self, mask: u64) {
        self.map.set_fingerprint_mask(mask);
        for g in self.groups.iter_mut().flatten() {
            g.set_fingerprint_mask(mask);
        }
    }

    pub fn is_consumed(&self, shard: usize) -> bool {
        self.groups[shard].is_none()
    }

    /// One shard's group (`None` when that shard was consumed).
    pub fn group(&self, shard: usize) -> Option<&ReplicaGroup> {
        self.groups.get(shard)?.as_ref()
    }

    pub fn group_mut(&mut self, shard: usize) -> Option<&mut ReplicaGroup> {
        self.groups.get_mut(shard)?.as_mut()
    }

    /// This shard's log head (deltas sequenced through it; 0 once the
    /// shard was consumed).
    pub fn log_head(&self, shard: usize) -> u64 {
        self.group(shard).map(|g| g.log_head()).unwrap_or(0)
    }

    /// Apply one delta at its shard's primary (fanning membership to
    /// every live shard) without pumping; see [`ReplicaGroup::apply`].
    /// Consumed shards are skipped — their state lives in the serving
    /// scheduler now.
    pub fn apply(&mut self, ev: DeltaEvent) {
        match self.map.route(&ev) {
            ShardRoute::One(s) => {
                if let Some(g) = self.groups[s].as_mut() {
                    g.apply(ev);
                }
            }
            ShardRoute::All => {
                for g in self.groups.iter_mut().flatten() {
                    g.apply(ev.clone());
                }
            }
        }
    }

    /// [`Self::apply`] + pump the touched shard(s) until every live
    /// follower confirms — synchronous replication for the sim.
    pub fn apply_sync(&mut self, ev: DeltaEvent) {
        match self.map.route(&ev) {
            ShardRoute::One(s) => {
                if let Some(g) = self.groups[s].as_mut() {
                    g.apply_sync(ev);
                }
            }
            ShardRoute::All => {
                for g in self.groups.iter_mut().flatten() {
                    g.apply_sync(ev.clone());
                }
            }
        }
    }

    /// Pump every live shard's transport once.
    pub fn pump(&mut self) {
        for g in self.groups.iter_mut().flatten() {
            g.pump();
        }
    }

    /// [`Self::pump`] with fault injection (ISSUE 6): `drop(shard,
    /// replica, seq)` true drops that delivery on the floor — recovered
    /// only by the receiver's gap re-request or the pump's retransmit
    /// path, exactly like a lost fabric message.
    pub fn pump_lossy(
        &mut self,
        drop: &mut dyn FnMut(usize, usize, u64) -> bool,
    ) {
        for (s, g) in self.groups.iter_mut().enumerate() {
            if let Some(g) = g {
                g.pump_lossy(&mut |r, seq| drop(s, r, seq));
            }
        }
    }

    pub fn all_caught_up(&self) -> bool {
        self.groups
            .iter()
            .flatten()
            .all(|g| g.all_caught_up())
    }

    /// Route-read from replica index `i` of the prompt's shard (short
    /// prompts read shard 0 — they match nothing anywhere, and every
    /// shard carries the full registry).
    pub fn route_match(
        &mut self,
        i: usize,
        tokens: &[u32],
        out: &mut Vec<(InstanceId, usize)>,
    ) {
        let s = self.map.shard_of_tokens(tokens).unwrap_or(0);
        match self.group_mut(s) {
            Some(g) => g.route_match(i, tokens, out),
            None => out.clear(),
        }
    }

    /// Route-read from the prompt's shard's current primary — the read
    /// path that stays valid across per-shard failovers (each shard's
    /// primary index moves independently).
    pub fn route_match_primary(
        &mut self,
        tokens: &[u32],
        out: &mut Vec<(InstanceId, usize)>,
    ) {
        let s = self.map.shard_of_tokens(tokens).unwrap_or(0);
        let Some(g) = self.group_mut(s) else {
            out.clear();
            return;
        };
        let p = g.primary_index();
        g.route_match(p, tokens, out);
    }

    /// Crash ONE shard's primary and promote its most-caught-up
    /// follower (catch-up included); every other shard is untouched.
    /// Returns the promoted replica index within that shard's group.
    pub fn fail_primary(&mut self, shard: usize) -> Option<usize> {
        self.group_mut(shard)?.fail_primary()
    }

    /// Extract replica `i`'s tree from `shard` and consume the shard's
    /// group — the sim's failover landing: the promoted slice becomes
    /// the serving scheduler's shard tree, and mirroring for that shard
    /// stops (a second failover of the same shard needs fresh
    /// replicas).
    /// `None` when the shard was already consumed or replica `i` is
    /// dead (the shard's group is still consumed in that case).
    pub fn extract_tree(&mut self, shard: usize, i: usize)
                        -> Option<GlobalPromptTrees> {
        let mut g = self.groups.get_mut(shard)?.take()?;
        g.extract_tree(i)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::replica::TreeSnapshot;
    use crate::scheduler::prompt_tree::InstanceKind;
    use crate::util::proptest::proptest;
    use crate::util::rng::Rng;

    const BT: usize = 4;

    fn toks(n: usize, seed: u32) -> Vec<u32> {
        (0..n as u32).map(|i| i * 3 + seed * 1009).collect()
    }

    fn seed(g: &mut ShardedReplicaGroup, n: u32) {
        for i in 0..n {
            g.apply_sync(DeltaEvent::Join {
                instance: InstanceId(i),
                kind: InstanceKind::PrefillOnly,
            });
        }
    }

    fn matches_primary(
        g: &mut ShardedReplicaGroup,
        t: &[u32],
    ) -> Vec<(InstanceId, usize)> {
        let mut out = vec![];
        g.route_match_primary(t, &mut out);
        out
    }

    #[test]
    fn membership_fans_records_split_by_shard() {
        let mut g = ShardedReplicaGroup::new(4, 2, BT, 0.0, 64);
        seed(&mut g, 3);
        let membership = g.log_head(0);
        for s in 1..4 {
            assert_eq!(g.log_head(s), membership, "membership must fan");
        }
        // 32 distinct records split across shards; each lands in
        // exactly one log.
        let mut per_shard = vec![0u64; 4];
        for k in 0..32u32 {
            let t = toks(2 * BT, k);
            let s = g.map().shard_of_tokens(&t).unwrap();
            per_shard[s] += 1;
            g.apply_sync(DeltaEvent::Record {
                instance: InstanceId(k % 3),
                tokens: t,
                now: 1.0,
            });
        }
        let mut total = 0;
        for s in 0..4 {
            let records = g.log_head(s) - membership;
            assert_eq!(records, per_shard[s], "shard {s} log drifted");
            total += records;
        }
        assert_eq!(total, 32, "every record sequenced exactly once");
        assert!(
            per_shard.iter().filter(|&&c| c > 0).count() > 1,
            "records failed to spread across shards"
        );
    }

    #[test]
    fn sharded_reads_agree_with_unsharded() {
        let mut shd = ShardedReplicaGroup::new(3, 2, BT, 0.0, 64);
        let mut flat = ShardedReplicaGroup::new(1, 2, BT, 0.0, 64);
        seed(&mut shd, 4);
        seed(&mut flat, 4);
        for k in 0..24u32 {
            let ev = DeltaEvent::Record {
                instance: InstanceId(k % 4),
                tokens: toks((1 + k as usize % 3) * BT, k % 8),
                now: k as f64,
            };
            shd.apply_sync(ev.clone());
            flat.apply_sync(ev);
        }
        shd.apply_sync(DeltaEvent::Expire {
            instance: InstanceId(1),
            prefix: vec![],
        });
        flat.apply_sync(DeltaEvent::Expire {
            instance: InstanceId(1),
            prefix: vec![],
        });
        for k in 0..8u32 {
            let t = toks(3 * BT, k);
            assert_eq!(
                matches_primary(&mut shd, &t),
                matches_primary(&mut flat, &t),
                "seed {k}"
            );
        }
    }

    #[test]
    fn per_shard_failover_leaves_other_shards_untouched() {
        let mut g = ShardedReplicaGroup::new(2, 3, BT, 0.0, 64);
        seed(&mut g, 2);
        // Find prompts for each shard.
        let mut by_shard: Vec<Option<Vec<u32>>> = vec![None, None];
        for k in 0..64u32 {
            let t = toks(2 * BT, k);
            let s = g.map().shard_of_tokens(&t).unwrap();
            if by_shard[s].is_none() {
                by_shard[s] = Some(t);
            }
        }
        let (t0, t1) = (
            by_shard[0].clone().expect("shard 0 prompt"),
            by_shard[1].clone().expect("shard 1 prompt"),
        );
        g.apply_sync(DeltaEvent::Record {
            instance: InstanceId(0),
            tokens: t0.clone(),
            now: 1.0,
        });
        g.apply_sync(DeltaEvent::Record {
            instance: InstanceId(1),
            tokens: t1.clone(),
            now: 1.0,
        });
        let want0 = matches_primary(&mut g, &t0);
        let want1 = matches_primary(&mut g, &t1);
        // Crash shard 1's primary only.
        let p = g.fail_primary(1).expect("followers survive");
        assert_eq!(g.group(1).unwrap().primary_index(), p);
        assert_eq!(
            g.group(0).unwrap().primary_index(),
            0,
            "shard 0 untouched"
        );
        assert_eq!(matches_primary(&mut g, &t0), want0);
        assert_eq!(matches_primary(&mut g, &t1), want1);
        // Writes keep flowing to both shards.
        g.apply_sync(DeltaEvent::Record {
            instance: InstanceId(0),
            tokens: t1.clone(),
            now: 2.0,
        });
        assert_eq!(
            matches_primary(&mut g, &t1)
                .iter()
                .find(|(id, _)| *id == InstanceId(0))
                .unwrap()
                .1,
            t1.len()
        );
        // Extraction consumes the shard; the other shard keeps
        // mirroring.
        let tree = g
            .extract_tree(1, g.group(1).unwrap().primary_index())
            .expect("shard 1 live");
        assert_eq!(tree.match_one(InstanceId(1), &t1), t1.len());
        assert!(g.is_consumed(1));
        g.apply_sync(DeltaEvent::Record {
            instance: InstanceId(1),
            tokens: t0.clone(),
            now: 3.0,
        });
        assert!(g.all_caught_up());
    }

    #[test]
    fn lossy_schedules_converge_to_fault_free_state() {
        // Differential property (ISSUE 6): a seeded drop schedule —
        // which induces retransmits, hence duplicate and reordered
        // ingests at the cursors — must converge every replica of
        // every shard to EXACTLY the fault-free twin's tree state once
        // the transports quiesce. Run at the natural fingerprint and a
        // 4-bit mask (forced shard/fingerprint collisions).
        proptest(16, |g| {
            for &mask in &[u64::MAX, 0xF] {
                let shards = *g.pick(&[1usize, 2, 4]);
                // Small window: retained-log pressure + SnapshotReq-less
                // gap repair both get exercised.
                let mut lossy =
                    ShardedReplicaGroup::new(shards, 3, BT, 0.0, 8);
                let mut clean =
                    ShardedReplicaGroup::new(shards, 3, BT, 0.0, 8);
                lossy.set_fingerprint_mask(mask);
                clean.set_fingerprint_mask(mask);
                let p_drop = g.f64(0.05, 0.4);
                let mut drop_rng = Rng::new(g.rng().next_u64());
                for i in 0..3u32 {
                    let ev = DeltaEvent::Join {
                        instance: InstanceId(i),
                        kind: InstanceKind::PrefillOnly,
                    };
                    clean.apply_sync(ev.clone());
                    lossy.apply(ev);
                }
                let n_evs = g.usize(8, 48);
                for k in 0..n_evs {
                    let ev = if k > 0 && g.rng().chance(0.1) {
                        DeltaEvent::Expire {
                            instance: InstanceId(g.u64(0, 2) as u32),
                            prefix: vec![],
                        }
                    } else {
                        DeltaEvent::Record {
                            instance: InstanceId(g.u64(0, 2) as u32),
                            tokens: toks(
                                (1 + g.usize(0, 2)) * BT,
                                g.u64(0, 9) as u32,
                            ),
                            now: 1.0 + k as f64,
                        }
                    };
                    clean.apply_sync(ev.clone());
                    lossy.apply(ev);
                    lossy.pump_lossy(&mut |_, _, _| {
                        drop_rng.chance(p_drop)
                    });
                }
                // Quiesce: keep pumping (still lossy) until every
                // replica confirms — the gap-repair/retransmit path
                // must win against the drop schedule.
                let mut guard = 0u32;
                while !lossy.all_caught_up() {
                    lossy.pump_lossy(&mut |_, _, _| {
                        drop_rng.chance(p_drop)
                    });
                    guard += 1;
                    assert!(guard < 100_000, "transport never converged");
                }
                for s in 0..shards {
                    for i in 0..lossy.group(s).unwrap().len() {
                        let a = TreeSnapshot::capture(
                            lossy.group(s).unwrap().tree(i).unwrap(), 0,
                        );
                        let b = TreeSnapshot::capture(
                            clean.group(s).unwrap().tree(i).unwrap(), 0,
                        );
                        assert_eq!(
                            a.entries, b.entries,
                            "shard {s} replica {i} diverged \
                             (mask {mask:#x})"
                        );
                    }
                }
            }
        });
    }
}
