//! Sequenced delta-log transport — the replication protocol's wire
//! discipline (ISSUE 4 tentpole, part 1).
//!
//! `elastic/delta.rs` established *what* replicates: self-contained
//! [`DeltaEvent`]s over token sequences, applied through
//! `FusedPromptTree::apply_delta`, converging every consumer of the same
//! stream to the same ownership state. This module adds *how*: the
//! sequencing layer that makes "the same stream" a guarantee rather than
//! an assumption.
//!
//! Two halves, transport-agnostic (the live server ships entries as
//! `Msg::Delta` over the fabric; `ReplicaGroup` and the sim drive them
//! in-process):
//!
//! * [`DeltaTransport`] — the authority side. Assigns monotonic sequence
//!   numbers on append, retains a suffix of the log (a windowed
//!   [`crate::elastic::delta::DeltaLog`]), tracks one `(acked, sent)`
//!   cursor pair per peer, bounds the in-flight window per peer, rewinds
//!   the send cursor when an ack regresses (the receiver's gap
//!   re-request), and truncates the retained suffix once **every** peer
//!   has acked past a sequence — the log never outgrows the slowest
//!   live replica.
//! * [`DeltaCursor`] — the receiver side. Applies entries strictly
//!   in-order: duplicates (seq below the cursor) are dropped, gaps (seq
//!   above it) are buffered out-of-order and answered with a re-request
//!   for the missing range, and the contiguous run starting at the
//!   cursor is released for application in one batch.
//!
//! Acks double as negative acks: a peer always reports the next
//! sequence it *needs* ([`DeltaCursor::expected`]); an ack that is lower
//! than what the authority already sent is precisely a gap report, and
//! [`DeltaTransport::on_ack`] rewinds the send cursor so the missing
//! range goes out again. One message type covers both directions of the
//! protocol.

use std::collections::{BTreeMap, VecDeque};

use crate::elastic::delta::DeltaEvent;

/// One sequence-stamped log entry.
#[derive(Clone, Debug, PartialEq)]
pub struct SeqDelta {
    pub seq: u64,
    pub ev: DeltaEvent,
}

/// A contiguous sequence-indexed window of delta events: append at the
/// head, random-access by sequence, trim from the tail. This is the ONE
/// implementation of the retained-suffix bookkeeping — shared by
/// [`DeltaTransport`] (the authority's log) and
/// [`crate::replica::group`]'s per-replica retained suffixes, which
/// previously hand-rolled the same `VecDeque + base` arithmetic twice
/// (a divergence hazard: the transport clamps its trim behind the
/// slowest peer, the replica trims raw — the *clamp* belongs to the
/// transport, the *buffer* is identical).
#[derive(Clone, Debug, Default)]
pub struct SeqBuffer {
    entries: VecDeque<DeltaEvent>,
    base: u64,
}

impl SeqBuffer {
    pub fn new() -> Self {
        SeqBuffer::default()
    }

    /// An empty buffer whose first append will carry `base` — a replica
    /// bootstrapped from a snapshot at that sequence.
    pub fn with_base(base: u64) -> Self {
        SeqBuffer {
            entries: VecDeque::new(),
            base,
        }
    }

    /// Oldest retained sequence (entries below were trimmed).
    pub fn base(&self) -> u64 {
        self.base
    }

    /// Sequence the next append will receive.
    pub fn next_seq(&self) -> u64 {
        self.base + self.entries.len() as u64
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Append at the head; returns the assigned sequence.
    pub fn push(&mut self, ev: DeltaEvent) -> u64 {
        let seq = self.next_seq();
        self.entries.push_back(ev);
        seq
    }

    /// Append an entry the caller already sequenced; must be exactly
    /// the head (retained suffixes are gap-free by construction).
    pub fn push_at(&mut self, seq: u64, ev: DeltaEvent) {
        debug_assert_eq!(seq, self.next_seq(), "retained suffix gapped");
        self.entries.push_back(ev);
    }

    /// Retained entry at `seq`, if not yet trimmed (or ahead).
    pub fn get(&self, seq: u64) -> Option<&DeltaEvent> {
        seq.checked_sub(self.base)
            .and_then(|i| self.entries.get(i as usize))
    }

    /// Drop entries below `floor` (clamped at the head); returns how
    /// many were dropped.
    pub fn trim_below(&mut self, floor: u64) -> usize {
        let mut dropped = 0;
        while self.base < floor && !self.entries.is_empty() {
            self.entries.pop_front();
            self.base += 1;
            dropped += 1;
        }
        dropped
    }

    /// Rebase an empty buffer (construction-time operation — a promoted
    /// replica rebuilding a transport around its retained suffix).
    pub fn rebase(&mut self, base: u64) {
        assert!(
            self.entries.is_empty() && self.base == 0,
            "rebase is a construction-time operation"
        );
        self.base = base;
    }

    /// Entries in sequence order starting at [`Self::base`].
    pub fn iter(&self) -> impl Iterator<Item = &DeltaEvent> + '_ {
        self.entries.iter()
    }
}

/// Per-peer replication cursors: `acked` — the peer has contiguously
/// applied every seq below it; `sent` — entries below it have been
/// handed to the wire (`sent >= acked`; `sent - acked` is in flight).
#[derive(Clone, Copy, Debug, Default)]
struct Peer {
    acked: u64,
    sent: u64,
}

/// Authority side of the delta log (see module docs).
#[derive(Debug)]
pub struct DeltaTransport {
    /// Retained suffix (the shared [`SeqBuffer`] core).
    log: SeqBuffer,
    window: usize,
    peers: BTreeMap<u64, Peer>,
    /// Cumulative resends triggered by ack regressions (diagnostics).
    resends: u64,
}

impl DeltaTransport {
    pub fn new(window: usize) -> Self {
        assert!(window > 0, "in-flight window must be positive");
        DeltaTransport {
            log: SeqBuffer::new(),
            window,
            peers: BTreeMap::new(),
            resends: 0,
        }
    }

    /// Sequence the next append will receive.
    pub fn next_seq(&self) -> u64 {
        self.log.next_seq()
    }

    /// Oldest retained sequence (entries below it were truncated and
    /// can only be recovered via a snapshot).
    pub fn first_retained(&self) -> u64 {
        self.log.base()
    }

    pub fn retained_len(&self) -> usize {
        self.log.len()
    }

    pub fn resends(&self) -> u64 {
        self.resends
    }

    /// Register a peer whose cursor starts at `from` (0 for a replica
    /// that will replay the whole log; a snapshot's seq for a late
    /// joiner bootstrapped past the prefix).
    pub fn register(&mut self, peer: u64, from: u64) {
        self.peers.insert(peer, Peer {
            acked: from,
            sent: from,
        });
    }

    /// Drop a peer (failed replica): its cursor no longer holds
    /// truncation back.
    pub fn deregister(&mut self, peer: u64) {
        self.peers.remove(&peer);
    }

    pub fn peers(&self) -> impl Iterator<Item = u64> + '_ {
        self.peers.keys().copied()
    }

    pub fn has_peers(&self) -> bool {
        !self.peers.is_empty()
    }

    /// Start an empty log at `base` instead of 0 — a promoted replica
    /// rebuilding the transport around its retained suffix, whose first
    /// entry carries that sequence.
    pub fn advance_base(&mut self, base: u64) {
        self.log.rebase(base);
    }

    /// Append one event; returns its assigned sequence.
    pub fn append(&mut self, ev: DeltaEvent) -> u64 {
        self.log.push(ev)
    }

    /// Retained entry at `seq`, if not yet truncated.
    pub fn get(&self, seq: u64) -> Option<&DeltaEvent> {
        self.log.get(seq)
    }

    /// The half-open seq range this peer should be sent now: from its
    /// send cursor up to the log head, capped by the in-flight window.
    /// Empty when the peer is unknown.
    pub fn sendable(&self, peer: u64) -> std::ops::Range<u64> {
        let Some(p) = self.peers.get(&peer) else {
            return 0..0;
        };
        let hi = self.next_seq().min(p.acked + self.window as u64);
        p.sent.max(self.log.base())..hi.max(p.sent)
    }

    /// Record that entries below `upto` were handed to the wire.
    pub fn mark_sent(&mut self, peer: u64, upto: u64) {
        if let Some(p) = self.peers.get_mut(&peer) {
            p.sent = p.sent.max(upto);
        }
    }

    /// Process an ack: the peer needs `next` as its next entry. Forward
    /// acks open window; an ack *below* the send cursor is a gap
    /// re-request — the send cursor rewinds so the range goes out again.
    /// Returns true when a rewind (resend) was triggered.
    pub fn on_ack(&mut self, peer: u64, next: u64) -> bool {
        let Some(p) = self.peers.get_mut(&peer) else {
            return false;
        };
        p.acked = p.acked.max(next);
        if next < p.sent {
            // The receiver is missing [next, sent): rewind and resend.
            p.sent = next.max(p.acked);
            self.resends += 1;
            true
        } else {
            p.sent = p.sent.max(next);
            false
        }
    }

    /// Timeout-style retransmit: rewind the peer's send cursor to its
    /// ack floor so unacked in-flight entries go out again. The
    /// recovery path when the *last* entries of the log were lost — no
    /// later entry will ever arrive to trigger the receiver's gap
    /// re-request, so the sender must re-offer on its own schedule.
    /// Returns true when there was anything to rewind.
    pub fn retransmit_unacked(&mut self, peer: u64) -> bool {
        let Some(p) = self.peers.get_mut(&peer) else {
            return false;
        };
        if p.sent > p.acked {
            p.sent = p.acked;
            self.resends += 1;
            true
        } else {
            false
        }
    }

    /// Force a peer's cursors to at least `seq` — used after shipping it
    /// a snapshot captured at `seq` (the log prefix is superseded).
    pub fn skip_to(&mut self, peer: u64, seq: u64) {
        if let Some(p) = self.peers.get_mut(&peer) {
            p.acked = p.acked.max(seq);
            p.sent = p.sent.max(seq);
        }
    }

    pub fn acked(&self, peer: u64) -> Option<u64> {
        self.peers.get(&peer).map(|p| p.acked)
    }

    /// Entries the peer has not yet confirmed (∞-safe lag in events).
    pub fn lag(&self, peer: u64) -> u64 {
        self.peers
            .get(&peer)
            .map(|p| self.next_seq() - p.acked)
            .unwrap_or(0)
    }

    pub fn all_caught_up(&self) -> bool {
        let head = self.next_seq();
        self.peers.values().all(|p| p.acked >= head)
    }

    /// Lowest ack across peers (the truncation floor); the log head when
    /// no peers are registered.
    pub fn min_acked(&self) -> u64 {
        self.peers
            .values()
            .map(|p| p.acked)
            .min()
            .unwrap_or_else(|| self.next_seq())
    }

    /// Drop retained entries below `floor`, clamped so no peer loses an
    /// entry it still needs (truncation never outruns `min_acked`).
    /// Returns the number of entries dropped.
    pub fn truncate_below(&mut self, floor: u64) -> usize {
        let to = floor.min(self.min_acked());
        self.log.trim_below(to)
    }
}

/// What [`DeltaCursor::offer`] decided about one incoming entry.
#[derive(Debug, PartialEq)]
pub enum Ingest {
    /// In-order: apply these events now (the offered one plus any
    /// buffered entries it unblocked, in sequence order).
    Ready(Vec<DeltaEvent>),
    /// Out of order: buffered; re-request the log from `resend_from`.
    Buffered { resend_from: u64 },
    /// Already applied (seq below the cursor): drop.
    Duplicate,
}

/// Receiver side: strict in-order application with an out-of-order
/// buffer and gap re-requests (see module docs).
#[derive(Debug, Default)]
pub struct DeltaCursor {
    expected: u64,
    pending: BTreeMap<u64, DeltaEvent>,
}

impl DeltaCursor {
    pub fn new() -> Self {
        DeltaCursor::default()
    }

    /// Next sequence this replica needs — the ack value.
    pub fn expected(&self) -> u64 {
        self.expected
    }

    /// Out-of-order entries currently buffered (diagnostics).
    pub fn buffered(&self) -> usize {
        self.pending.len()
    }

    /// Offer one sequenced entry; see [`Ingest`].
    pub fn offer(&mut self, seq: u64, ev: DeltaEvent) -> Ingest {
        if seq < self.expected {
            return Ingest::Duplicate;
        }
        if seq > self.expected {
            self.pending.insert(seq, ev);
            return Ingest::Buffered {
                resend_from: self.expected,
            };
        }
        let mut ready = vec![ev];
        self.expected += 1;
        while let Some(next) = self.pending.remove(&self.expected) {
            ready.push(next);
            self.expected += 1;
        }
        Ingest::Ready(ready)
    }

    /// Jump the cursor to `seq` (a snapshot restored state through it);
    /// buffered entries below `seq` are superseded and dropped, and any
    /// contiguous run starting at `seq` is released for application.
    pub fn advance_to(&mut self, seq: u64) -> Vec<DeltaEvent> {
        self.expected = self.expected.max(seq);
        self.pending.retain(|&s, _| s >= seq);
        let mut ready = vec![];
        while let Some(next) = self.pending.remove(&self.expected) {
            ready.push(next);
            self.expected += 1;
        }
        ready
    }

    /// Drop buffered entries at sequences `>= seq`. Required when the
    /// authority rebases the log (a promotion reuses the sequences past
    /// the promoted replica's head for *different* events): anything a
    /// laggard buffered from the dead authority at those sequences is
    /// stale and must never be applied.
    pub fn purge_from(&mut self, seq: u64) {
        self.pending.retain(|&s, _| s < seq);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mempool::InstanceId;

    fn ev(tag: u32) -> DeltaEvent {
        DeltaEvent::Expire {
            instance: InstanceId(tag),
            prefix: vec![tag],
        }
    }

    #[test]
    fn seq_buffer_window_arithmetic() {
        let mut b = SeqBuffer::new();
        assert_eq!(b.next_seq(), 0);
        for i in 0..5 {
            assert_eq!(b.push(ev(i)), i as u64);
        }
        assert_eq!(b.get(3), Some(&ev(3)));
        assert_eq!(b.get(5), None);
        assert_eq!(b.trim_below(2), 2);
        assert_eq!(b.base(), 2);
        assert_eq!(b.get(1), None);
        assert_eq!(b.get(2), Some(&ev(2)));
        // Trim past the head clamps.
        assert_eq!(b.trim_below(99), 3);
        assert_eq!(b.next_seq(), 5);
        assert!(b.is_empty());
        // with_base / push_at (the replica retain path).
        let mut r = SeqBuffer::with_base(10);
        r.push_at(10, ev(0));
        r.push_at(11, ev(1));
        assert_eq!(r.get(10), Some(&ev(0)));
        assert_eq!(r.iter().count(), 2);
        assert_eq!(r.next_seq(), 12);
    }

    #[test]
    fn sequences_are_monotonic_and_windowed() {
        let mut t = DeltaTransport::new(4);
        t.register(7, 0);
        for i in 0..10 {
            assert_eq!(t.append(ev(i)), i as u64);
        }
        // Window caps the first batch at 4 in-flight.
        assert_eq!(t.sendable(7), 0..4);
        t.mark_sent(7, 4);
        assert_eq!(t.sendable(7), 4..4, "window full until acks");
        assert!(!t.on_ack(7, 2));
        assert_eq!(t.sendable(7), 4..6, "partial ack opens window");
        t.mark_sent(7, 6);
        t.on_ack(7, 6);
        assert_eq!(t.sendable(7), 6..10);
        assert_eq!(t.lag(7), 4);
    }

    #[test]
    fn ack_regression_rewinds_for_resend() {
        let mut t = DeltaTransport::new(8);
        t.register(1, 0);
        for i in 0..6 {
            t.append(ev(i));
        }
        t.mark_sent(1, 6);
        // Receiver reports it is still missing seq 2: resend from there.
        assert!(t.on_ack(1, 2));
        assert_eq!(t.resends(), 1);
        assert_eq!(t.sendable(1), 2..6);
        // The rewound cursor never regresses below the ack floor.
        t.mark_sent(1, 6);
        assert!(!t.on_ack(1, 6));
    }

    #[test]
    fn truncation_waits_for_all_peers() {
        let mut t = DeltaTransport::new(16);
        t.register(1, 0);
        t.register(2, 0);
        for i in 0..8 {
            t.append(ev(i));
        }
        t.mark_sent(1, 8);
        t.mark_sent(2, 8);
        t.on_ack(1, 8);
        t.on_ack(2, 3);
        assert_eq!(t.min_acked(), 3);
        assert_eq!(t.truncate_below(8), 3, "clamped to the slowest peer");
        assert_eq!(t.first_retained(), 3);
        assert!(t.get(2).is_none());
        assert_eq!(t.get(3), Some(&ev(3)));
        // The slow peer leaves: its cursor no longer pins the log.
        t.deregister(2);
        assert_eq!(t.truncate_below(u64::MAX), 5);
        assert_eq!(t.retained_len(), 0);
        // No peers at all: min_acked is the head, appends still work.
        t.deregister(1);
        assert_eq!(t.min_acked(), t.next_seq());
    }

    #[test]
    fn cursor_orders_buffers_and_dedups() {
        let mut c = DeltaCursor::new();
        assert_eq!(c.offer(0, ev(0)), Ingest::Ready(vec![ev(0)]));
        // Gap: 2 arrives before 1.
        assert_eq!(c.offer(2, ev(2)), Ingest::Buffered { resend_from: 1 });
        assert_eq!(c.buffered(), 1);
        // The missing entry releases the buffered run in order.
        assert_eq!(c.offer(1, ev(1)), Ingest::Ready(vec![ev(1), ev(2)]));
        assert_eq!(c.expected(), 3);
        assert_eq!(c.offer(1, ev(1)), Ingest::Duplicate);
    }

    #[test]
    fn replayed_deltas_never_mutate_cursor_state() {
        // ISSUE 6 pin: a fault-injecting fabric can replay any Delta
        // any number of times (duplication, retransmits). Every replay
        // below the cursor must be classified Duplicate and leave the
        // cursor's state — expected sequence AND the out-of-order
        // buffer — bit-identical, so the replica applies each event
        // exactly once no matter the delivery schedule.
        let mut c = DeltaCursor::new();
        for i in 0..4 {
            assert!(matches!(c.offer(i, ev(i as u32)), Ingest::Ready(_)));
        }
        // Open a gap so the pending buffer is non-empty too.
        assert!(matches!(c.offer(6, ev(6)), Ingest::Buffered { .. }));
        let (exp, buf) = (c.expected(), c.buffered());
        // Replay storm: every already-applied seq, several times over.
        for _round in 0..3 {
            for i in 0..4 {
                assert_eq!(c.offer(i, ev(i as u32)), Ingest::Duplicate);
                assert_eq!(c.expected(), exp);
                assert_eq!(c.buffered(), buf);
            }
        }
        // The gap still heals normally afterwards.
        assert_eq!(c.offer(4, ev(4)), Ingest::Ready(vec![ev(4)]));
        assert_eq!(c.offer(5, ev(5)), Ingest::Ready(vec![ev(5), ev(6)]));
        assert_eq!(c.expected(), 7);
        // And a replay of the healed run is still inert.
        assert_eq!(c.offer(6, ev(6)), Ingest::Duplicate);
        assert_eq!(c.expected(), 7);
        assert_eq!(c.buffered(), 0);
    }

    #[test]
    fn cursor_snapshot_jump_drops_superseded() {
        let mut c = DeltaCursor::new();
        assert!(matches!(c.offer(5, ev(5)), Ingest::Buffered { .. }));
        assert!(matches!(c.offer(9, ev(9)), Ingest::Buffered { .. }));
        // Snapshot at 6: entry 5 is superseded, 9 stays buffered.
        assert_eq!(c.advance_to(6), vec![]);
        assert_eq!(c.expected(), 6);
        assert_eq!(c.buffered(), 1);
        // 6..=8 arrive; 9 rides the contiguous run out.
        assert!(matches!(c.offer(6, ev(6)), Ingest::Ready(_)));
        assert!(matches!(c.offer(7, ev(7)), Ingest::Ready(_)));
        assert_eq!(c.offer(8, ev(8)), Ingest::Ready(vec![ev(8), ev(9)]));
        assert_eq!(c.expected(), 10);
    }

    #[test]
    fn snapshot_skip_moves_both_cursors() {
        let mut t = DeltaTransport::new(4);
        t.register(1, 0);
        for i in 0..20 {
            t.append(ev(i));
        }
        t.skip_to(1, 12);
        assert_eq!(t.acked(1), Some(12));
        assert_eq!(t.sendable(1), 12..16);
    }
}
