//! Replicated global scheduler (ISSUE 4): the transport, snapshot, and
//! group machinery that turns the single leader-local fused prompt tree
//! into a replicated group — so the GS survives a crash with its
//! locality state intact and routing reads can fan out across replicas.
//!
//! PR 3 built the replication *content*: every ownership mutation of
//! the fused tree is a self-contained [`crate::elastic::delta::
//! DeltaEvent`] over token sequences, and replicas applying the same
//! event stream converge to the same state. This subsystem supplies
//! what ROADMAP recorded as missing — "the transport (sequencing,
//! snapshots, catch-up for joining replicas)":
//!
//! * [`log`] — monotonic sequencing over the delta log: per-replica ack
//!   cursors, a bounded in-flight window, gap detection with
//!   re-request, and truncation behind the slowest replica.
//! * [`snapshot`] — compact semantic snapshots of the fused tree
//!   (token-path + per-instance ownership + stamps), restored by
//!   ascending-stamp `Record` replay; the bootstrap for late joiners
//!   and the recovery floor under log truncation.
//! * [`group`] — [`group::ReplicaGroup`]: one primary plus N followers;
//!   writes sequence through the log, reads serve from any replica, and
//!   primary failure promotes the most-caught-up follower after
//!   catching it up from the survivors' retained log suffixes.
//!
//! The live server runs the same protocol over fabric messages
//! (`Msg::{Delta, DeltaAck, SnapshotReq, Snapshot, Promote}` —
//! `server/replica.rs`); the simulator and `benches/fig17_replica.rs`
//! drive `ReplicaGroup` directly.

pub mod group;
pub mod log;
pub mod sharded;
pub mod snapshot;

pub use group::ReplicaGroup;
pub use log::{DeltaCursor, DeltaTransport, Ingest, SeqBuffer, SeqDelta};
pub use sharded::ShardedReplicaGroup;
pub use snapshot::{SnapshotEntry, TreeSnapshot};
