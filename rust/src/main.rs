//! MemServe launcher.
//!
//! Subcommands:
//!   serve           — start a live cluster and run a workload against it
//!   bench-sim       — discrete-event rate sweep (fast, cost-model-timed)
//!   workload-stats  — print Fig-7-style workload statistics
//!   calibrate       — fit the operator-level cost model from real PJRT
//!                     measurements; writes artifacts/cost_model.json
//!   dump-config     — print the effective configuration
//!
//! Common flags: --config <file.toml>, --set k=v (repeatable), --help.

use std::sync::Arc;
use std::time::Duration;

use memserve::config::Config;
use memserve::engine::{DisaggMilestone, SamplingParams};
use memserve::mempool::BlockGeometry;
use memserve::runtime::ModelRuntime;
use memserve::scheduler::cost_model::{model_to_json, OperatorCostModel};
use memserve::server::{ServeCluster, ServeOptions};
use memserve::sim::{SimConfig, Simulation};
use memserve::util::args::Parser;
use memserve::workload::{ArrivalPlan, WorkloadKind, WorkloadSpec, WorkloadStats};

fn main() {
    memserve::util::logging::init();
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let parser = Parser::new(
        "memserve",
        "MemServe: context caching for disaggregated LLM serving",
    )
    .opt("config", "", "TOML config file (configs/*.toml)")
    .opt("milestone", "pd_caching_3", "disaggregation milestone")
    .opt("requests", "32", "requests to run (serve mode)")
    .opt("rate", "2.0", "request rate per second (bench-sim)")
    .flag("real-sleep", "model wire time with real sleeps");

    let args = match parser.parse(&argv) {
        Ok(a) => a,
        Err(msg) => {
            eprintln!("{msg}");
            std::process::exit(2);
        }
    };
    let cmd = args.positional().first().cloned().unwrap_or_default();

    let mut cfg = match args.get("config") {
        Some(path) if !path.is_empty() => match Config::from_file(path) {
            Ok(c) => c,
            Err(e) => {
                eprintln!("config error: {e}");
                std::process::exit(2);
            }
        },
        _ => Config::default(),
    };
    if let Err(e) = cfg.apply_sets(args.sets()) {
        eprintln!("config error: {e}");
        std::process::exit(2);
    }

    let result = match cmd.as_str() {
        "serve" => cmd_serve(&cfg, &args),
        "bench-sim" => cmd_bench_sim(&cfg, &args),
        "workload-stats" => cmd_workload_stats(&cfg),
        "calibrate" => cmd_calibrate(&cfg),
        "dump-config" => {
            for (k, v) in cfg.dump() {
                println!("{k} = {v}");
            }
            Ok(())
        }
        other => {
            eprintln!("unknown command '{other}'");
            eprintln!("{}", parser.help_text());
            eprintln!(
                "commands: serve bench-sim workload-stats calibrate dump-config"
            );
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn cmd_serve(cfg: &Config, args: &memserve::util::args::Args)
             -> anyhow::Result<()> {
    let milestone = DisaggMilestone::parse(args.get_or("milestone", ""))
        .unwrap_or(DisaggMilestone::PdCaching3);
    let n_requests: usize = args.get_usize("requests").unwrap_or(32);
    println!("loading runtime from {} ...", cfg.artifacts_dir);
    let runtime = Arc::new(ModelRuntime::load(&cfg.artifacts_dir)?);
    let vocab = runtime.meta.vocab as u32;
    let max_seq = runtime.meta.max_seq;
    let cluster = ServeCluster::start(
        ServeOptions {
            config: cfg.clone(),
            milestone,
            real_sleep: args.flag("real-sleep"),
        },
        runtime,
    )?;
    let kind = WorkloadKind::parse(&cfg.workload.kind)
        .unwrap_or(WorkloadKind::ShareGpt);
    let spec = WorkloadSpec::generate(
        kind,
        cfg.workload.sessions,
        cfg.workload.seed,
        vocab,
        max_seq,
    );
    println!(
        "serving {} requests from {} sessions ({})",
        n_requests,
        spec.sessions.len(),
        kind.name()
    );
    let mut sent = 0usize;
    'outer: for sess in &spec.sessions {
        let mut ctx = sess.shared_prefix.clone();
        for turn in &sess.turns {
            if sent >= n_requests {
                break 'outer;
            }
            let mut prompt = ctx.clone();
            prompt.extend_from_slice(&turn.user_tokens);
            if prompt.len() + turn.target_gen + 1 >= max_seq {
                break;
            }
            let rid = cluster.submit(prompt.clone(), sess.id, SamplingParams {
                max_new_tokens: turn.target_gen,
                eos_token: u32::MAX,
                ..Default::default()
            })?;
            let (generated, rec) =
                cluster.collect(rid, Duration::from_secs(120))?;
            sent += 1;
            println!(
                "  rid={rid} prompt={} cached={} gen={} ttft={:.3}s jct={:.3}s",
                rec.prompt_tokens,
                rec.cached_tokens,
                generated.len(),
                rec.ttft(),
                rec.jct()
            );
            ctx = prompt;
            ctx.extend(generated);
        }
    }
    let m = cluster.metrics();
    println!("== summary ==\n{}", m.summary_line());
    cluster.shutdown();
    Ok(())
}

fn cmd_bench_sim(cfg: &Config, args: &memserve::util::args::Args)
                 -> anyhow::Result<()> {
    let rate: f64 = args.get_f64("rate").unwrap_or(2.0);
    let kind = WorkloadKind::parse(&cfg.workload.kind)
        .unwrap_or(WorkloadKind::ShareGpt);
    let spec = WorkloadSpec::generate(
        kind,
        cfg.workload.sessions,
        cfg.workload.seed,
        2048,
        4096,
    );
    let plan = ArrivalPlan::poisson(&spec, rate, cfg.workload.seed);
    let sim_cfg = SimConfig {
        prefill_instances: cfg.cluster.prefill_instances,
        decode_instances: cfg.cluster.decode_instances,
        colocated_instances: cfg.cluster.colocated_instances,
        caching: cfg.mempool.context_caching,
        policy: cfg.scheduler.policy,
        transfer_mode: cfg.engine.transfer_mode,
        ..Default::default()
    };
    let rep = Simulation::new(sim_cfg, spec, &plan).run();
    println!("{}", rep.metrics.summary_line());
    println!(
        "wire: {:.1} MB in {} calls ({:.3}s busy); evicted {} blocks; \
         sim span {:.1}s",
        rep.wire_bytes as f64 / 1e6,
        rep.wire_calls,
        rep.wire_seconds,
        rep.evicted_blocks,
        rep.sim_seconds
    );
    Ok(())
}

fn cmd_workload_stats(cfg: &Config) -> anyhow::Result<()> {
    for kind in WorkloadKind::all() {
        let spec = WorkloadSpec::generate(
            kind,
            cfg.workload.sessions.max(100),
            cfg.workload.seed,
            2048,
            4096,
        );
        let mut st = WorkloadStats::compute(&spec);
        println!("{:>9}: {}", kind.name(), st.summary());
    }
    Ok(())
}

/// Fit the operator-level cost model against the real PJRT runtime
/// (paper §5.3.2: profile operators, fit the forms).
fn cmd_calibrate(cfg: &Config) -> anyhow::Result<()> {
    let runtime = ModelRuntime::load(&cfg.artifacts_dir)?;
    let meta = runtime.meta.clone();
    let geom = BlockGeometry {
        block_tokens: cfg.mempool.block_tokens,
        layers: meta.layers,
        n_heads: meta.n_heads,
        head_dim: meta.head_dim,
        aggregated: true,
    };
    let mut model = OperatorCostModel::default_tiny();
    let toks = |n: usize| -> Vec<u32> {
        (0..n as u32)
            .map(|i| (i * 31 + 7) % meta.vocab as u32)
            .collect()
    };
    // --- Prefill samples over (x, y): every bucket at y=0, plus cached
    // points for the cached_per_token residual fit. ---
    let mut bucket_list: Vec<usize> =
        meta.prefill_buckets.iter().map(|&(n, _)| n).collect();
    bucket_list.sort_unstable();
    bucket_list.dedup();
    let mut grid: Vec<(usize, usize)> =
        bucket_list.iter().map(|&b| (b, 0usize)).collect();
    grid.extend([(128usize, 64usize), (256, 128), (320, 192)]);
    let mut samples: Vec<(usize, f64, f64)> = vec![];
    for &(x, cached_req) in &grid {
        {
            let cached = cached_req / geom.block_tokens * geom.block_tokens;
            let prompt = toks(x);
            let cache_buf = if cached > 0 {
                let out = runtime.prefill(&prompt[..cached], None, 0)?;
                let cap = meta
                    .pick_prefill_bucket(x - cached, cached)
                    .map(|(_, c)| c)
                    .unwrap_or(256);
                let s = meta.n_heads * meta.head_dim;
                let mut buf = vec![0f32; meta.layers * 2 * cap * s];
                for l in 0..meta.layers {
                    for h in 0..2 {
                        for t in 0..cached {
                            let src = ((l * 2 + h) * out.bucket_n + t) * s;
                            let dst = ((l * 2 + h) * cap + t) * s;
                            buf[dst..dst + s]
                                .copy_from_slice(&out.new_kv[src..src + s]);
                        }
                    }
                }
                Some(buf)
            } else {
                None
            };
            // Warmups + median of 7 (CPU wallclock is noisy).
            for _ in 0..2 {
                let _ = runtime.prefill(&prompt[cached..],
                                        cache_buf.as_deref(), cached)?;
            }
            let mut times = vec![];
            for _ in 0..7 {
                let t0 = std::time::Instant::now();
                let _ = runtime.prefill(&prompt[cached..],
                                        cache_buf.as_deref(), cached)?;
                times.push(t0.elapsed().as_secs_f64());
            }
            times.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let med = times[times.len() / 2];
            let y = cached as f64 / x as f64;
            println!("  prefill x={x} y={y:.2}: {med:.4}s");
            samples.push((x, y, med));
        }
    }
    // Per-bucket compute table from the y=0 samples (one measured cost
    // per compiled shape — the paper's operator profiling, made exact).
    model.buckets = bucket_list.clone();
    model.bucket_costs = bucket_list
        .iter()
        .map(|&b| {
            samples
                .iter()
                .find(|&&(x, y, _)| x == b && y == 0.0)
                .map(|&(_, _, t)| t)
                .unwrap_or(0.0)
        })
        .collect();
    model.gemm_per_token = model.bucket_costs.last().copied()
        .unwrap_or(1e-4)
        / *bucket_list.last().unwrap_or(&256) as f64;
    model.constant = 0.0;
    // Cached-token read/staging cost from the y>0 residuals.
    let bucket_cost_of = |new: usize| -> f64 {
        let idx = bucket_list
            .iter()
            .position(|&b| b >= new)
            .unwrap_or(bucket_list.len() - 1);
        model.bucket_costs[idx]
    };
    let mut num = 0.0f64;
    let mut den = 0.0f64;
    for &(x, y, t) in &samples {
        if y <= 0.0 {
            continue;
        }
        let cached_tokens = x as f64 * y;
        let base = bucket_cost_of(
            (x as f64 * (1.0 - y)).ceil() as usize,
        );
        num += (t - base) * cached_tokens;
        den += cached_tokens * cached_tokens;
    }
    model.cached_per_token = (num / den.max(1.0)).max(0.0);
    model.attn_a = -1e-12; // attention x² terms are negligible at 512 ctx
    model.attn_b = 2e-12;
    model.attn_c = 0.0;
    model.attn_d = 0.0;
    model.wave_tokens = 16;
    model.tp = 1;
    // --- Decode samples over ctx. ---
    let mut dec = vec![];
    for &ctx in &[64usize, 256] {
        let prompt = toks(ctx / 2);
        let out = runtime.prefill(&prompt, None, 0)?;
        let s = meta.n_heads * meta.head_dim;
        let mut kv = vec![0f32; meta.layers * 2 * ctx * s];
        for l in 0..meta.layers {
            for h in 0..2 {
                for t in 0..prompt.len() {
                    let src = ((l * 2 + h) * out.bucket_n + t) * s;
                    let dst = ((l * 2 + h) * ctx + t) * s;
                    kv[dst..dst + s]
                        .copy_from_slice(&out.new_kv[src..src + s]);
                }
            }
        }
        let mut sess = runtime.decode_start(&kv, ctx, prompt.len())?;
        for i in 0..4 {
            let _ = runtime.decode_step(&mut sess, i as u32)?;
        }
        let t0 = std::time::Instant::now();
        let steps = 16;
        for i in 0..steps {
            let _ = runtime.decode_step(&mut sess, (i % 100) as u32)?;
        }
        let per = t0.elapsed().as_secs_f64() / steps as f64;
        println!("  decode ctx={ctx}: {per:.4}s/step");
        dec.push((ctx as f64, per));
    }
    let slope_d = (dec[1].1 - dec[0].1) / (dec[1].0 - dec[0].0);
    model.decode_per_ctx_token = slope_d.max(0.0);
    model.decode_base = (dec[0].1 - slope_d * dec[0].0).max(1e-6);

    let out_path = format!("{}/cost_model.json", cfg.artifacts_dir);
    std::fs::write(&out_path, model_to_json(&model).to_string())?;
    println!("wrote {out_path}: {model:?}");
    let mut max_rel = 0.0f64;
    for &(x, y, t) in &samples {
        let pred = model.exec(x, y);
        max_rel = max_rel.max((pred - t).abs() / t);
    }
    println!("prefill fit max rel err: {:.1}%", max_rel * 100.0);
    Ok(())
}
