//! Wire messages between the leader, instance threads, and GS replica
//! threads.

use crate::elastic::delta::DeltaEvent;
use crate::engine::Request;
use crate::mempool::InstanceId;
use crate::net::WireCost;
use crate::replica::snapshot::TreeSnapshot;

/// One cluster message. Bulk KV messages report their wire cost (bytes +
/// per-block network calls) so the fabric models NCCL behaviour; control
/// messages pay only the control latency.
///
/// `Clone` is required by the fault-injecting fabric (duplicated
/// deliveries clone the message).
#[derive(Clone)]
pub enum Msg {
    /// Leader → prefill-capable instance: run this request. For
    /// disaggregated requests `decode_to` names the decode instance.
    /// `span` is the trace-span id minted when the request was routed
    /// (ISSUE 8); it rides every hop of the request's lifecycle so
    /// instances close phases on the same span the leader opened.
    Dispatch {
        req: Request,
        decode_to: Option<InstanceId>,
        span: u64,
    },
    /// Prefill → decode instance: `transfer_with_insert` of the prompt KV
    /// (one-shot, receiver allocates on demand). `calls` is the modeled
    /// number of network API calls (layout- and mode-dependent).
    KvHandoff {
        req: Request,
        payload: Vec<f32>,
        n_blocks: usize,
        prompt_len: usize,
        cached_tokens: usize,
        scheduled: f64,
        first_token_time: f64,
        logits: Vec<f32>,
        calls: usize,
        /// Receiver should insert into its index (milestone >= 2).
        insert: bool,
        /// Trace-span id propagated from the dispatch (ISSUE 8).
        span: u64,
    },
    /// Decode → prefill instance: `transfer_with_insert` of the decode
    /// suffix KV (milestone 3). `seq` = prompt + consumed generated
    /// tokens; payload covers blocks `[suffix_start_block..)`.
    KvBackflow {
        seq: Vec<u32>,
        payload: Vec<f32>,
        n_blocks: usize,
        suffix_start_block: usize,
        calls: usize,
    },
    /// Instance → leader: one generated token (streaming path).
    Token {
        rid: u64,
        token: u32,
        done: bool,
    },
    /// Instance → leader: request finished (metrics payload).
    Finished {
        rid: u64,
        instance: InstanceId,
        prompt_tokens: usize,
        cached_tokens: usize,
        output_tokens: usize,
        scheduled: f64,
        first_token_time: f64,
        completion_time: f64,
        /// Full consumed sequence (for global-tree update).
        cached_seq: Vec<u32>,
    },
    /// Instance → leader: liveness.
    Heartbeat { from: InstanceId },
    /// Instance → leader: response-path cache report (paper Fig 6
    /// right) for tokens cached *outside* a decode retirement — prefill
    /// retire after a disaggregated handoff, backflow suffix insert.
    /// Without it the GS would only ever learn what decode instances
    /// cache (via `Finished`), leaving prefill candidates invisible to
    /// the prompt-tree policy and the migration planner.
    Cached {
        instance: InstanceId,
        seq: Vec<u32>,
    },
    /// Leader → draining donor: ship the cached prefix `tokens` to `to`
    /// (one migration-plan task; the donor pins, exports, and sends a
    /// [`Msg::KvMigrate`]). `mid` is the leader-assigned migration id
    /// that rides the whole 3-step handshake — retries reuse it, every
    /// receiver dedupes on it.
    MigrateOut {
        mid: u64,
        to: InstanceId,
        tokens: Vec<u32>,
    },
    /// Donor → receiver: migrated prefix KV (`transfer_with_insert`
    /// over the fabric; receiver allocates on demand, inserts, and acks
    /// the leader with [`Msg::MigrateLanded`]). A duplicate `mid` must
    /// not re-land: the receiver re-acks from its dedupe window instead.
    KvMigrate {
        mid: u64,
        from: InstanceId,
        tokens: Vec<u32>,
        payload: Vec<f32>,
        n_blocks: usize,
        calls: usize,
    },
    /// Receiver → leader: the prefix landed and is indexed — apply the
    /// ownership handoff. (Also sent by the donor itself with empty
    /// `tokens` when it had nothing to ship, so drain progress never
    /// stalls.) The leader dedupes on `mid`, so replayed acks are safe.
    MigrateLanded {
        mid: u64,
        from: InstanceId,
        to: InstanceId,
        tokens: Vec<u32>,
    },
    /// Leader → decode instance: membership changed — send milestone-3
    /// decode-KV backflow to this prefill instance from now on (`None`
    /// disables backflow when no prefill peer remains).
    Rewire {
        backflow_to: Option<InstanceId>,
    },
    /// Leader → instance: all migration tasks have been queued; answer
    /// with [`Msg::DrainDone`] once they are processed (FIFO order makes
    /// this a barrier).
    Drain,
    /// Draining instance → leader: migration tasks processed.
    DrainDone { from: InstanceId },
    /// Leader → instances: membership change (epoch-stamped).
    Membership {
        epoch: u64,
        dead: Vec<InstanceId>,
    },
    /// Instance → leader: the pool's LRU evicted these token prefixes
    /// (each the `DeltaEvent::Expire` shape — the prefix and every
    /// extension are gone). The honest-eviction signal that replaces
    /// global-tree TTL guessing (§6 Discussion).
    Evicted {
        instance: InstanceId,
        prefixes: Vec<Vec<u32>>,
    },
    /// Leader (GS primary) → GS follower: one sequenced ownership delta
    /// of the replicated global prompt tree. `shard` names the prefix-
    /// range shard whose log assigned `seq` — each shard is its own
    /// sequence space and replica state.
    Delta {
        shard: usize,
        seq: u64,
        ev: DeltaEvent,
    },
    /// GS follower → leader: `next` is the next sequence this replica
    /// needs **on `shard`'s stream** — a cumulative ack, and (when it
    /// is lower than what the leader already sent) a gap re-request
    /// that rewinds that shard's send cursor. Followers coalesce: at
    /// most one ack per shard per ingest pump (or per GS_WINDOW/4
    /// applied deltas), not one per delta.
    DeltaAck {
        from: InstanceId,
        shard: usize,
        next: u64,
    },
    /// GS follower → leader: this replica's `shard` fell behind the
    /// retained log (or is joining late) — bootstrap it with a
    /// [`Msg::Snapshot`].
    SnapshotReq { from: InstanceId, shard: usize },
    /// Fused-tree snapshot of one shard at a log position: leader →
    /// follower for bootstrap/catch-up, or follower → leader as the
    /// [`Msg::Promote`] reply carrying the promoted replica's state.
    Snapshot { shard: usize, snap: TreeSnapshot },
    /// Leader → the most-caught-up GS follower of `shard` after a
    /// primary crash: that shard slice is promoted — reply to
    /// `reply_to` with its tree state (as a [`Msg::Snapshot`] at your
    /// applied sequence). Shards fail over independently.
    Promote {
        shard: usize,
        reply_to: InstanceId,
    },
    /// Leader → instance: drain and exit.
    Shutdown,
}

impl WireCost for Msg {
    fn wire_cost(&self) -> Option<(usize, usize, bool, bool)> {
        match self {
            Msg::KvHandoff { payload, calls, .. }
            | Msg::KvBackflow { payload, calls, .. }
            | Msg::KvMigrate { payload, calls, .. } => {
                Some((payload.len() * 4, (*calls).max(1), false, false))
            }
            // Control-plane traffic models as zero wire cost;
            // enumerated (no `_`) so a new payload-bearing variant
            // cannot silently ship for free.
            Msg::Dispatch { .. }
            | Msg::Token { .. }
            | Msg::Finished { .. }
            | Msg::Heartbeat { .. }
            | Msg::Cached { .. }
            | Msg::MigrateOut { .. }
            | Msg::MigrateLanded { .. }
            | Msg::Rewire { .. }
            | Msg::Drain
            | Msg::DrainDone { .. }
            | Msg::Membership { .. }
            | Msg::Evicted { .. }
            | Msg::Delta { .. }
            | Msg::DeltaAck { .. }
            | Msg::SnapshotReq { .. }
            | Msg::Snapshot { .. }
            | Msg::Promote { .. }
            | Msg::Shutdown => None,
        }
    }
}

impl std::fmt::Debug for Msg {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Msg::Dispatch { req, decode_to, span } => f
                .debug_struct("Dispatch")
                .field("rid", &req.id)
                .field("decode_to", decode_to)
                .field("span", span)
                .finish(),
            Msg::KvHandoff { req, n_blocks, .. } => f
                .debug_struct("KvHandoff")
                .field("rid", &req.id)
                .field("n_blocks", n_blocks)
                .finish(),
            Msg::KvBackflow { n_blocks, .. } => f
                .debug_struct("KvBackflow")
                .field("n_blocks", n_blocks)
                .finish(),
            Msg::Token { rid, token, done } => f
                .debug_struct("Token")
                .field("rid", rid)
                .field("token", token)
                .field("done", done)
                .finish(),
            Msg::Finished { rid, .. } => {
                f.debug_struct("Finished").field("rid", rid).finish()
            }
            Msg::Heartbeat { from } => {
                f.debug_struct("Heartbeat").field("from", from).finish()
            }
            Msg::Membership { epoch, dead } => f
                .debug_struct("Membership")
                .field("epoch", epoch)
                .field("dead", dead)
                .finish(),
            Msg::Cached { instance, seq } => f
                .debug_struct("Cached")
                .field("instance", instance)
                .field("seq", &seq.len())
                .finish(),
            Msg::MigrateOut { mid, to, tokens } => f
                .debug_struct("MigrateOut")
                .field("mid", mid)
                .field("to", to)
                .field("tokens", &tokens.len())
                .finish(),
            Msg::KvMigrate {
                mid, from, n_blocks, ..
            } => f
                .debug_struct("KvMigrate")
                .field("mid", mid)
                .field("from", from)
                .field("n_blocks", n_blocks)
                .finish(),
            Msg::MigrateLanded { mid, from, to, tokens } => f
                .debug_struct("MigrateLanded")
                .field("mid", mid)
                .field("from", from)
                .field("to", to)
                .field("tokens", &tokens.len())
                .finish(),
            Msg::Rewire { backflow_to } => f
                .debug_struct("Rewire")
                .field("backflow_to", backflow_to)
                .finish(),
            Msg::Drain => write!(f, "Drain"),
            Msg::DrainDone { from } => {
                f.debug_struct("DrainDone").field("from", from).finish()
            }
            Msg::Evicted { instance, prefixes } => f
                .debug_struct("Evicted")
                .field("instance", instance)
                .field("prefixes", &prefixes.len())
                .finish(),
            Msg::Delta { shard, seq, ev } => f
                .debug_struct("Delta")
                .field("shard", shard)
                .field("seq", seq)
                .field("ev", ev)
                .finish(),
            Msg::DeltaAck { from, shard, next } => f
                .debug_struct("DeltaAck")
                .field("from", from)
                .field("shard", shard)
                .field("next", next)
                .finish(),
            Msg::SnapshotReq { from, shard } => f
                .debug_struct("SnapshotReq")
                .field("from", from)
                .field("shard", shard)
                .finish(),
            Msg::Snapshot { shard, snap } => f
                .debug_struct("Snapshot")
                .field("shard", shard)
                .field("seq", &snap.seq)
                .field("entries", &snap.entries.len())
                .finish(),
            Msg::Promote { shard, reply_to } => f
                .debug_struct("Promote")
                .field("shard", shard)
                .field("reply_to", reply_to)
                .finish(),
            Msg::Shutdown => write!(f, "Shutdown"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::SamplingParams;

    #[test]
    fn wire_cost_only_for_bulk() {
        let hb = Msg::Heartbeat {
            from: InstanceId(0),
        };
        assert!(hb.wire_cost().is_none());
        let kv = Msg::KvBackflow {
            seq: vec![],
            payload: vec![0.0; 1000],
            n_blocks: 2,
            suffix_start_block: 0,
            calls: 2,
        };
        assert_eq!(kv.wire_cost(), Some((4000, 2, false, false)));
        let mig = Msg::KvMigrate {
            mid: 0,
            from: InstanceId(1),
            tokens: vec![],
            payload: vec![0.0; 500],
            n_blocks: 1,
            calls: 4,
        };
        assert_eq!(mig.wire_cost(), Some((2000, 4, false, false)));
        assert!(Msg::Drain.wire_cost().is_none());
        assert!(Msg::MigrateOut {
            mid: 0,
            to: InstanceId(0),
            tokens: vec![1]
        }
        .wire_cost()
        .is_none());
        let d = Msg::Dispatch {
            req: Request {
                id: 1,
                session: 0,
                prompt: vec![1],
                sampling: SamplingParams::default(),
                arrival: 0.0,
            },
            decode_to: None,
            span: 1,
        };
        assert!(d.wire_cost().is_none());
    }
}
