//! Wire messages between the leader and instance threads.

use crate::engine::Request;
use crate::mempool::InstanceId;
use crate::net::WireCost;

/// One cluster message. Bulk KV messages report their wire cost (bytes +
/// per-block network calls) so the fabric models NCCL behaviour; control
/// messages pay only the control latency.
pub enum Msg {
    /// Leader → prefill-capable instance: run this request. For
    /// disaggregated requests `decode_to` names the decode instance.
    Dispatch {
        req: Request,
        decode_to: Option<InstanceId>,
    },
    /// Prefill → decode instance: `transfer_with_insert` of the prompt KV
    /// (one-shot, receiver allocates on demand). `calls` is the modeled
    /// number of network API calls (layout- and mode-dependent).
    KvHandoff {
        req: Request,
        payload: Vec<f32>,
        n_blocks: usize,
        prompt_len: usize,
        cached_tokens: usize,
        scheduled: f64,
        first_token_time: f64,
        logits: Vec<f32>,
        calls: usize,
        /// Receiver should insert into its index (milestone >= 2).
        insert: bool,
    },
    /// Decode → prefill instance: `transfer_with_insert` of the decode
    /// suffix KV (milestone 3). `seq` = prompt + consumed generated
    /// tokens; payload covers blocks `[suffix_start_block..)`.
    KvBackflow {
        seq: Vec<u32>,
        payload: Vec<f32>,
        n_blocks: usize,
        suffix_start_block: usize,
        calls: usize,
    },
    /// Instance → leader: one generated token (streaming path).
    Token {
        rid: u64,
        token: u32,
        done: bool,
    },
    /// Instance → leader: request finished (metrics payload).
    Finished {
        rid: u64,
        instance: InstanceId,
        prompt_tokens: usize,
        cached_tokens: usize,
        output_tokens: usize,
        scheduled: f64,
        first_token_time: f64,
        completion_time: f64,
        /// Full consumed sequence (for global-tree update).
        cached_seq: Vec<u32>,
    },
    /// Instance → leader: liveness.
    Heartbeat { from: InstanceId },
    /// Leader → instances: membership change (epoch-stamped).
    Membership {
        epoch: u64,
        dead: Vec<InstanceId>,
    },
    /// Leader → instance: drain and exit.
    Shutdown,
}

impl WireCost for Msg {
    fn wire_cost(&self) -> Option<(usize, usize, bool, bool)> {
        match self {
            Msg::KvHandoff { payload, calls, .. }
            | Msg::KvBackflow { payload, calls, .. } => {
                Some((payload.len() * 4, (*calls).max(1), false, false))
            }
            _ => None,
        }
    }
}

impl std::fmt::Debug for Msg {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Msg::Dispatch { req, decode_to } => f
                .debug_struct("Dispatch")
                .field("rid", &req.id)
                .field("decode_to", decode_to)
                .finish(),
            Msg::KvHandoff { req, n_blocks, .. } => f
                .debug_struct("KvHandoff")
                .field("rid", &req.id)
                .field("n_blocks", n_blocks)
                .finish(),
            Msg::KvBackflow { n_blocks, .. } => f
                .debug_struct("KvBackflow")
                .field("n_blocks", n_blocks)
                .finish(),
            Msg::Token { rid, token, done } => f
                .debug_struct("Token")
                .field("rid", rid)
                .field("token", token)
                .field("done", done)
                .finish(),
            Msg::Finished { rid, .. } => {
                f.debug_struct("Finished").field("rid", rid).finish()
            }
            Msg::Heartbeat { from } => {
                f.debug_struct("Heartbeat").field("from", from).finish()
            }
            Msg::Membership { epoch, dead } => f
                .debug_struct("Membership")
                .field("epoch", epoch)
                .field("dead", dead)
                .finish(),
            Msg::Shutdown => write!(f, "Shutdown"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::SamplingParams;

    #[test]
    fn wire_cost_only_for_bulk() {
        let hb = Msg::Heartbeat {
            from: InstanceId(0),
        };
        assert!(hb.wire_cost().is_none());
        let kv = Msg::KvBackflow {
            seq: vec![],
            payload: vec![0.0; 1000],
            n_blocks: 2,
            suffix_start_block: 0,
            calls: 2,
        };
        assert_eq!(kv.wire_cost(), Some((4000, 2, false, false)));
        let d = Msg::Dispatch {
            req: Request {
                id: 1,
                session: 0,
                prompt: vec![1],
                sampling: SamplingParams::default(),
                arrival: 0.0,
            },
            decode_to: None,
        };
        assert!(d.wire_cost().is_none());
    }
}
