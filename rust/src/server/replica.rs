//! GS replication over the fabric: follower threads and the leader's
//! replication bookkeeping (ISSUE 4 tentpole, resharded by ISSUE 5).
//!
//! With `scheduler.gs_replicas = N` and `scheduler.gs_shards = S`, the
//! leader keeps one [`DeltaTransport`] **per prefix-range shard** and
//! spawns `N` follower threads, each owning a replica of *every* shard
//! (per-shard tree + cursor — the shard subsets a thread owns; the
//! per-shard streams stay independent so a real deployment can split
//! them across processes). Every ownership mutation the leader applies
//! (`ServeCluster::gs_apply`) is appended to its shard's log —
//! membership deltas fan to all shards — and shipped as a shard-tagged
//! `Msg::Delta`; followers apply each shard's stream in strict
//! sequence order through a [`DeltaCursor`].
//!
//! **Batched acks** (ISSUE 5 satellite): a follower no longer acks
//! every delta — an ack storm on a real NIC. It coalesces into at most
//! one `Msg::DeltaAck` per shard per ingest pump (the endpoint's
//! message burst) and forces a flush every `GS_WINDOW / 4` applied
//! deltas so the leader's window never starves. Gap re-requests are
//! still immediate: an out-of-order delta nacks `resend_from` on the
//! spot, so loss-recovery latency is unchanged.
//!
//! A follower shard that falls behind the truncated log asks for
//! `Msg::SnapshotReq` → `Msg::Snapshot` bootstrap. On a primary-GS
//! crash (`ServeCluster::fail_gs_primary`), the leader promotes, for
//! EACH shard, the most-caught-up follower with `Msg::Promote`; the
//! follower answers with a snapshot of that shard's replica, and the
//! leader restores it — then replays any retained log suffix past the
//! snapshot — so routing resumes with the full locality state a real
//! crash would otherwise have lost.

use std::time::{Duration, Instant};

use crate::mempool::InstanceId;
use crate::net::fabric::NetError;
use crate::net::{Endpoint, Fabric};
use crate::replica::log::{DeltaCursor, DeltaTransport, Ingest};
use crate::replica::snapshot::TreeSnapshot;
use crate::scheduler::prompt_tree::GlobalPromptTrees;
use crate::scheduler::shard::{ShardMap, ShardRoute};
use crate::server::message::Msg;

/// Follower ids live at the top of the id space, just below the leader
/// (`u32::MAX`), far above any instance id.
pub const GS_FOLLOWER_BASE: u32 = u32::MAX - 1;

/// Fabric id of GS follower `k` (counting down from the leader).
pub fn follower_id(k: usize) -> InstanceId {
    InstanceId(GS_FOLLOWER_BASE - k as u32)
}

/// In-flight delta window per follower per shard before acks must
/// catch up.
pub const GS_WINDOW: usize = 1024;

/// Applied deltas a follower may accumulate before it must flush its
/// coalesced ack (keeps the leader's send window from stalling even in
/// an endless burst).
pub const GS_ACK_EVERY: usize = GS_WINDOW / 4;

/// Leader-side replication state (guarded by one mutex in the leader;
/// lock order: `gs` before this). One transport per prefix-range
/// shard; every follower is a peer of every shard.
pub struct GsReplication {
    pub shards: Vec<DeltaTransport>,
    pub followers: Vec<InstanceId>,
    pub map: ShardMap,
}

impl GsReplication {
    pub fn new(
        followers: Vec<InstanceId>,
        shards: usize,
        block_tokens: usize,
    ) -> Self {
        let shards = (0..shards.max(1))
            .map(|_| {
                let mut t = DeltaTransport::new(GS_WINDOW);
                for f in &followers {
                    t.register(f.0 as u64, 0);
                }
                t
            })
            .collect::<Vec<_>>();
        let map = ShardMap::new(shards.len(), block_tokens);
        GsReplication {
            shards,
            followers,
            map,
        }
    }

    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// Append one delta to its shard's log (membership and whole-view
    /// expiries fan to every shard — each shard's replica needs the
    /// full registry).
    pub fn append(&mut self, ev: crate::elastic::delta::DeltaEvent) {
        match self.map.route(&ev) {
            ShardRoute::One(s) => {
                self.shards[s].append(ev);
            }
            ShardRoute::All => {
                for t in &mut self.shards {
                    t.append(ev.clone());
                }
            }
        }
    }

    /// Ship every shard's sendable windows; a follower whose endpoint
    /// is gone is dropped from every shard's peer set so it cannot
    /// stall log truncation.
    pub fn flush(&mut self, fabric: &Fabric<Msg>, leader: InstanceId) {
        let mut dead = vec![];
        for (shard, t) in self.shards.iter_mut().enumerate() {
            for &f in &self.followers {
                if dead.contains(&f) {
                    continue;
                }
                let peer = f.0 as u64;
                let range = t.sendable(peer);
                if range.is_empty() {
                    continue;
                }
                for seq in range.clone() {
                    // Sendable seqs are always retained; skip (the
                    // follower's cumulative ack re-requests any gap)
                    // rather than tearing the replica thread down.
                    let Some(ev) = t.get(seq).cloned() else {
                        debug_assert!(false, "sendable {seq} missing");
                        continue;
                    };
                    if fabric
                        .send(leader, f, Msg::Delta { shard, seq, ev })
                        .is_err()
                    {
                        dead.push(f);
                        break;
                    }
                }
                t.mark_sent(peer, range.end);
            }
        }
        for f in dead {
            log::warn!("GS follower {f} unreachable; dropping replica");
            for t in &mut self.shards {
                t.deregister(f.0 as u64);
            }
            self.followers.retain(|x| *x != f);
        }
        for t in &mut self.shards {
            t.truncate_below(t.min_acked());
        }
    }

    /// Is `f` currently a registered replication peer?
    pub fn is_registered(&self, f: InstanceId) -> bool {
        self.followers.contains(&f)
    }

    /// (Re-)register a follower on every shard from sequence 0 — the
    /// rejoin-as-follower path (ISSUE 6): a follower that was dropped
    /// (partition, missed heartbeats) and resumes beating is wired back
    /// in; its first deltas arrive wildly out of order, the cursor
    /// buffers past the window, and the normal `SnapshotReq` bootstrap
    /// catches it up.
    pub fn register_follower(&mut self, f: InstanceId) {
        if self.is_registered(f) {
            return;
        }
        for t in &mut self.shards {
            // Restart from the retained floor: everything earlier is
            // truncated, and the snapshot path covers the gap.
            let from = t.first_retained();
            t.register(f.0 as u64, from);
        }
        self.followers.push(f);
    }

    /// Drop a follower from every shard's peer set (heartbeat-miss
    /// suspicion) so it cannot stall log truncation while dark.
    pub fn deregister_follower(&mut self, f: InstanceId) {
        for t in &mut self.shards {
            t.deregister(f.0 as u64);
        }
        self.followers.retain(|x| *x != f);
    }

    /// The follower holding `shard`'s longest applied prefix (that
    /// shard's promotion target); `None` when no follower is
    /// registered. Different shards may promote different followers.
    pub fn most_caught_up(&self, shard: usize) -> Option<InstanceId> {
        let t = &self.shards[shard];
        self.followers
            .iter()
            .copied()
            .max_by_key(|f| {
                (t.acked(f.0 as u64).unwrap_or(0), u32::MAX - f.0)
            })
    }
}

/// What [`FollowerShard::on_delta`] wants sent back to the leader.
#[derive(Debug, PartialEq)]
pub enum FollowerReply {
    /// Nothing yet — the coalesced ack stays pending until the pump
    /// flush or the `GS_ACK_EVERY` threshold.
    None,
    /// Send `DeltaAck { next }` now (threshold reached, or a gap
    /// re-request that must not wait).
    Ack(u64),
    /// This shard fell irrecoverably behind: ask for a snapshot.
    SnapshotReq,
}

/// One shard's replica state inside a follower thread: the tree, the
/// strict-order cursor, and the coalesced-ack bookkeeping. Extracted
/// from the thread loop so the batching discipline is unit-testable.
pub struct FollowerShard {
    pub tree: GlobalPromptTrees,
    cursor: DeltaCursor,
    /// Deltas applied since the last ack left.
    applied_since_ack: usize,
    /// An ack is owed (applies or duplicates landed since the last
    /// flush).
    dirty: bool,
}

impl FollowerShard {
    pub fn new(block_tokens: usize, ttl: f64) -> Self {
        FollowerShard {
            tree: GlobalPromptTrees::new(block_tokens, ttl),
            cursor: DeltaCursor::new(),
            applied_since_ack: 0,
            dirty: false,
        }
    }

    /// Next sequence this shard replica needs (its ack value).
    pub fn expected(&self) -> u64 {
        self.cursor.expected()
    }

    /// Ingest one shard-stream delta; see [`FollowerReply`].
    pub fn on_delta(
        &mut self,
        seq: u64,
        ev: crate::elastic::delta::DeltaEvent,
    ) -> FollowerReply {
        match self.cursor.offer(seq, ev) {
            Ingest::Ready(evs) => {
                self.applied_since_ack += evs.len();
                for e in &evs {
                    self.tree.apply_delta(e);
                }
                if self.applied_since_ack >= GS_ACK_EVERY {
                    FollowerReply::Ack(self.take_ack())
                } else {
                    self.dirty = true;
                    FollowerReply::None
                }
            }
            Ingest::Buffered { resend_from } => {
                // The window bounds legitimate out-of-order buffering at
                // GS_WINDOW - 1 entries; a buffer past half the window
                // means the gap keeps not arriving (resend loss) — stop
                // nacking and ask for a snapshot bootstrap instead.
                if self.cursor.buffered() > GS_WINDOW / 2 {
                    FollowerReply::SnapshotReq
                } else {
                    // Gap re-requests are IMMEDIATE — batching must not
                    // add loss-recovery latency. The nack value doubles
                    // as the cumulative ack, so pending state flushes
                    // with it.
                    self.dirty = false;
                    self.applied_since_ack = 0;
                    FollowerReply::Ack(resend_from)
                }
            }
            // A duplicate means the leader resent something we already
            // acked (or our ack was lost): owe it a refreshed ack at
            // the next flush so its send cursor converges.
            Ingest::Duplicate => {
                self.dirty = true;
                FollowerReply::None
            }
        }
    }

    /// Bootstrap / catch-up from a shard snapshot; returns the ack to
    /// send (snapshot acks are immediate — the leader's `skip_to`
    /// cursor is waiting on it). A snapshot OLDER than the applied
    /// cursor is ignored: restoring it would roll the tree back while
    /// the deltas in between — already applied and acked — would never
    /// be resent.
    pub fn on_snapshot(
        &mut self,
        snap: &TreeSnapshot,
        block_tokens: usize,
        ttl: f64,
    ) -> u64 {
        if snap.seq >= self.cursor.expected() {
            let mut fresh = GlobalPromptTrees::new(block_tokens, ttl);
            snap.restore_into(&mut fresh);
            self.tree = fresh;
            for e in self.cursor.advance_to(snap.seq) {
                self.tree.apply_delta(&e);
            }
        }
        self.take_ack()
    }

    /// Drain the pending coalesced ack, if one is owed — the per-pump
    /// flush (and the tick path when the stream goes idle).
    pub fn flush_ack(&mut self) -> Option<u64> {
        if self.dirty {
            Some(self.take_ack())
        } else {
            None
        }
    }

    fn take_ack(&mut self) -> u64 {
        self.dirty = false;
        self.applied_since_ack = 0;
        self.cursor.expected()
    }
}

/// One GS follower thread: a full replica of every shard's prompt
/// tree slice, fed by the per-shard sequenced delta streams. Runs
/// until `Shutdown`. Acks are coalesced per shard per ingest pump
/// (see module docs). The follower heartbeats the leader every
/// `heartbeat_every` so the leader's failure detector tracks it; a
/// follower the leader dropped keeps beating, which is exactly the
/// rejoin signal (`GsReplication::register_follower`).
#[allow(clippy::too_many_arguments)]
pub fn run_gs_follower(
    id: InstanceId,
    leader: InstanceId,
    block_tokens: usize,
    ttl: f64,
    shards: usize,
    heartbeat_every: Duration,
    epoch: Instant,
    fabric: Fabric<Msg>,
    endpoint: Endpoint<Msg>,
) {
    let mut states: Vec<FollowerShard> = (0..shards.max(1))
        .map(|_| FollowerShard::new(block_tokens, ttl))
        .collect();
    let send_ack = |fabric: &Fabric<Msg>, shard: usize, next: u64| {
        let _ = fabric.send(id, leader, Msg::DeltaAck {
            from: id,
            shard,
            next,
        });
    };
    // First beat goes out immediately so the detector sees us at birth.
    let mut last_beat = Instant::now()
        .checked_sub(heartbeat_every)
        .unwrap_or_else(Instant::now);
    loop {
        if last_beat.elapsed() >= heartbeat_every {
            let _ = fabric.send(id, leader, Msg::Heartbeat { from: id });
            last_beat = Instant::now();
        }
        // Pump: block for the first message, then drain the burst
        // without blocking, then flush ONE coalesced ack per dirty
        // shard. A 50 ms timeout doubles as the idle ack tick.
        let mut next_msg = match endpoint
            .recv_timeout(Duration::from_millis(50).min(heartbeat_every / 2))
        {
            Ok((_, m)) => Some(m),
            Err(NetError::Timeout) => None,
            // Our inbox sender is gone: the leader detached this
            // follower (crash injection / shutdown teardown). Exit now
            // — a timeout-conflating loop would spin here forever
            // (ISSUE 6 satellite).
            Err(_) => return,
        };
        while let Some(msg) = next_msg.take() {
            match msg {
                Msg::Shutdown => return,
                Msg::Delta { shard, seq, ev } => {
                    let Some(st) = states.get_mut(shard) else {
                        log::debug!("delta for unknown shard {shard}");
                        next_msg = endpoint.try_recv().map(|(_, m)| m);
                        continue;
                    };
                    match st.on_delta(seq, ev) {
                        FollowerReply::Ack(next) => {
                            send_ack(&fabric, shard, next)
                        }
                        FollowerReply::SnapshotReq => {
                            let _ = fabric.send(id, leader,
                                                Msg::SnapshotReq {
                                                    from: id,
                                                    shard,
                                                });
                        }
                        FollowerReply::None => {}
                    }
                }
                Msg::Snapshot { shard, snap } => {
                    let Some(st) = states.get_mut(shard) else {
                        log::debug!("snapshot for unknown shard {shard}");
                        next_msg = endpoint.try_recv().map(|(_, m)| m);
                        continue;
                    };
                    let next = st.on_snapshot(&snap, block_tokens, ttl);
                    send_ack(&fabric, shard, next);
                }
                Msg::Promote { shard, reply_to } => {
                    // Failover: hand the caller this shard's replica at
                    // its applied sequence. The thread keeps
                    // replicating — the restored primary resumes
                    // streaming to it.
                    let Some(st) = states.get(shard) else {
                        log::debug!("promote for unknown shard {shard}");
                        next_msg = endpoint.try_recv().map(|(_, m)| m);
                        continue;
                    };
                    let snap = TreeSnapshot::capture(
                        &st.tree,
                        st.expected(),
                    );
                    let _ = fabric.send(id, reply_to, Msg::Snapshot {
                        shard,
                        snap,
                    });
                }
                // Leader/instance traffic; enumerated (no `_`) so a
                // new Msg variant forces a routing decision here.
                Msg::Dispatch { .. }
                | Msg::KvHandoff { .. }
                | Msg::KvBackflow { .. }
                | Msg::Token { .. }
                | Msg::Finished { .. }
                | Msg::Heartbeat { .. }
                | Msg::Cached { .. }
                | Msg::MigrateOut { .. }
                | Msg::KvMigrate { .. }
                | Msg::MigrateLanded { .. }
                | Msg::Rewire { .. }
                | Msg::Drain
                | Msg::DrainDone { .. }
                | Msg::Membership { .. }
                | Msg::Evicted { .. }
                | Msg::DeltaAck { .. }
                | Msg::SnapshotReq { .. } => {
                    log::debug!("GS follower {id} ignoring peer msg");
                }
            }
            next_msg = endpoint.try_recv().map(|(_, m)| m);
        }
        for (shard, st) in states.iter_mut().enumerate() {
            if let Some(next) = st.flush_ack() {
                send_ack(&fabric, shard, next);
            }
            // Local TTL housekeeping: expiry is a pure function of
            // stamps, so replicas expire independently yet equivalently
            // — a replica never needs an expiry delta.
            st.tree.expire(epoch.elapsed().as_secs_f64());
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::elastic::delta::DeltaEvent;
    use crate::scheduler::prompt_tree::InstanceKind;

    const BT: usize = 4;

    fn rec(k: u32) -> DeltaEvent {
        DeltaEvent::Record {
            instance: InstanceId(0),
            tokens: (0..2 * BT as u32).map(|i| i * 3 + k * 997).collect(),
            now: k as f64,
        }
    }

    #[test]
    fn follower_acks_batch_until_threshold_or_flush() {
        let mut f = FollowerShard::new(BT, 0.0);
        let join = DeltaEvent::Join {
            instance: InstanceId(0),
            kind: InstanceKind::PrefillOnly,
        };
        assert_eq!(f.on_delta(0, join), FollowerReply::None);
        // In-order deltas below the threshold: no acks on the wire…
        let mut acks = 0usize;
        let n = GS_ACK_EVERY as u64 * 2 + 5;
        for seq in 1..=n {
            match f.on_delta(seq, rec(seq as u32)) {
                FollowerReply::Ack(next) => {
                    acks += 1;
                    assert_eq!(next, seq + 1, "cumulative ack");
                }
                FollowerReply::None => {}
                r => panic!("unexpected {r:?}"),
            }
        }
        // …exactly one forced ack per GS_ACK_EVERY applied deltas.
        assert_eq!(acks, (n as usize + 1) / GS_ACK_EVERY);
        // The pump flush drains the remainder in ONE ack.
        assert_eq!(f.flush_ack(), Some(n + 1));
        assert_eq!(f.flush_ack(), None, "nothing owed after the flush");
    }

    #[test]
    fn gap_rerequest_is_immediate_despite_batching() {
        let mut f = FollowerShard::new(BT, 0.0);
        assert_eq!(
            f.on_delta(0, DeltaEvent::Join {
                instance: InstanceId(0),
                kind: InstanceKind::PrefillOnly,
            }),
            FollowerReply::None
        );
        // seq 2 arrives before 1: the nack must go out NOW, carrying
        // the cumulative ack value (gap re-request latency bounded).
        assert_eq!(f.on_delta(2, rec(2)), FollowerReply::Ack(1));
        assert_eq!(f.flush_ack(), None, "nack flushed the pending state");
        // The resent gap releases the buffered run; the ack for it
        // coalesces into the next flush.
        assert_eq!(f.on_delta(1, rec(1)), FollowerReply::None);
        assert_eq!(f.flush_ack(), Some(3));
    }

    #[test]
    fn lossy_stream_converges_through_batched_acks() {
        // Leader-side transport + batched follower, with every third
        // delivery dropped: the coalesced acks must still drive the
        // send cursor to convergence (the satellite's regression bar).
        let mut t = DeltaTransport::new(GS_WINDOW);
        t.register(1, 0);
        let mut f = FollowerShard::new(BT, 0.0);
        t.append(DeltaEvent::Join {
            instance: InstanceId(0),
            kind: InstanceKind::PrefillOnly,
        });
        for k in 1..40u32 {
            t.append(rec(k));
        }
        let mut n = 0u64;
        let mut pumps = 0;
        loop {
            pumps += 1;
            assert!(pumps < 100, "lossy stream failed to converge");
            let mut range = t.sendable(1);
            if range.is_empty() && t.lag(1) > 0 {
                t.retransmit_unacked(1);
                range = t.sendable(1);
            }
            if range.is_empty() {
                break;
            }
            for seq in range.clone() {
                let ev = t.get(seq).unwrap().clone();
                n += 1;
                if n % 3 == 0 {
                    continue; // dropped on the wire
                }
                match f.on_delta(seq, ev) {
                    FollowerReply::Ack(next) => {
                        t.on_ack(1, next);
                    }
                    FollowerReply::None => {}
                    FollowerReply::SnapshotReq => {
                        panic!("window cannot overflow here")
                    }
                }
            }
            t.mark_sent(1, range.end);
            if let Some(next) = f.flush_ack() {
                t.on_ack(1, next);
            }
            if t.lag(1) == 0 {
                break;
            }
        }
        assert_eq!(f.expected(), 40, "follower missed deltas");
        assert!(t.resends() > 0, "loss must have triggered re-requests");
        assert_eq!(f.tree.cached_blocks(InstanceId(0)), 39 * 2);
    }

    #[test]
    fn deregister_then_rejoin_reregisters_at_retained_floor() {
        let mut rep = GsReplication::new(vec![follower_id(0)], 2, BT);
        for k in 1..10u32 {
            rep.append(rec(k));
        }
        let f = follower_id(0);
        rep.deregister_follower(f);
        assert!(!rep.is_registered(f));
        assert_eq!(rep.most_caught_up(0), None);
        // While the follower is dark the log can truncate freely.
        for t in &mut rep.shards {
            t.truncate_below(t.min_acked());
        }
        rep.register_follower(f);
        assert!(rep.is_registered(f));
        assert_eq!(rep.most_caught_up(0), Some(f));
        // Idempotent re-register keeps a single entry.
        rep.register_follower(f);
        assert_eq!(rep.followers.len(), 1);
        // The rejoin cursor starts at the retained floor, never below.
        for t in &rep.shards {
            assert!(t.acked(f.0 as u64).unwrap_or(0) >= t.first_retained());
        }
    }

    #[test]
    fn stale_snapshot_ignored_fresh_one_restores() {
        let mut f = FollowerShard::new(BT, 20.0);
        f.on_delta(0, DeltaEvent::Join {
            instance: InstanceId(0),
            kind: InstanceKind::PrefillOnly,
        });
        for seq in 1..=4 {
            f.on_delta(seq, rec(seq as u32));
        }
        assert_eq!(f.expected(), 5);
        // Stale snapshot (older than applied): ignored, ack refreshed.
        let empty = TreeSnapshot::capture(&GlobalPromptTrees::new(BT, 0.0),
                                          2);
        assert_eq!(f.on_snapshot(&empty, BT, 20.0), 5);
        assert!(f.tree.cached_blocks(InstanceId(0)) > 0, "rolled back");
        // Fresh snapshot: restores and jumps the cursor.
        let mut ahead = GlobalPromptTrees::new(BT, 20.0);
        ahead.add_instance(InstanceId(1), InstanceKind::PrefillOnly);
        ahead.record(InstanceId(1), &[1, 2, 3, 4], 1.0);
        let snap = TreeSnapshot::capture(&ahead, 9);
        assert_eq!(f.on_snapshot(&snap, BT, 20.0), 9);
        assert_eq!(f.tree.cached_blocks(InstanceId(1)), 1);
        assert_eq!(f.tree.cached_blocks(InstanceId(0)), 0);
    }
}
