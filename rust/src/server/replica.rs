//! GS replication over the fabric: follower threads and the leader's
//! replication bookkeeping (ISSUE 4 tentpole, server wiring).
//!
//! With `scheduler.gs_replicas = N`, `ServeCluster::start` spawns `N`
//! follower threads, each owning its own fused prompt tree. Every
//! ownership mutation the leader applies (`ServeCluster::gs_apply`)
//! is appended to a [`DeltaTransport`] and shipped as `Msg::Delta`;
//! followers apply in strict sequence order through a [`DeltaCursor`],
//! acking with `Msg::DeltaAck` (which doubles as the gap re-request —
//! an ack below the send cursor rewinds it). A follower that falls
//! behind the truncated log asks for `Msg::SnapshotReq` → `Msg::
//! Snapshot` bootstrap. On a primary-GS crash
//! (`ServeCluster::fail_gs_primary`), the leader promotes the
//! most-caught-up follower with `Msg::Promote`; the follower answers
//! with a snapshot of its replica at its applied sequence, and the
//! leader restores it — then replays any retained log suffix past the
//! snapshot — so routing resumes with the full locality state a real
//! crash would otherwise have lost.

use std::time::{Duration, Instant};

use crate::mempool::InstanceId;
use crate::net::{Endpoint, Fabric};
use crate::replica::log::{DeltaCursor, DeltaTransport, Ingest};
use crate::replica::snapshot::TreeSnapshot;
use crate::scheduler::prompt_tree::GlobalPromptTrees;
use crate::server::message::Msg;

/// Follower ids live at the top of the id space, just below the leader
/// (`u32::MAX`), far above any instance id.
pub const GS_FOLLOWER_BASE: u32 = u32::MAX - 1;

/// Fabric id of GS follower `k` (counting down from the leader).
pub fn follower_id(k: usize) -> InstanceId {
    InstanceId(GS_FOLLOWER_BASE - k as u32)
}

/// In-flight delta window per follower before acks must catch up.
pub const GS_WINDOW: usize = 1024;

/// Leader-side replication state (guarded by one mutex in the leader;
/// lock order: `gs` before this).
pub struct GsReplication {
    pub transport: DeltaTransport,
    pub followers: Vec<InstanceId>,
}

impl GsReplication {
    pub fn new(followers: Vec<InstanceId>) -> Self {
        let mut transport = DeltaTransport::new(GS_WINDOW);
        for f in &followers {
            transport.register(f.0 as u64, 0);
        }
        GsReplication {
            transport,
            followers,
        }
    }

    /// Ship every sendable window; a follower whose endpoint is gone is
    /// dropped from the peer set so it cannot stall log truncation.
    pub fn flush(&mut self, fabric: &Fabric<Msg>, leader: InstanceId) {
        let mut dead = vec![];
        for &f in &self.followers {
            let peer = f.0 as u64;
            let range = self.transport.sendable(peer);
            if range.is_empty() {
                continue;
            }
            for seq in range.clone() {
                let ev = self
                    .transport
                    .get(seq)
                    .expect("sendable entry retained")
                    .clone();
                if fabric.send(leader, f, Msg::Delta { seq, ev }).is_err() {
                    dead.push(f);
                    break;
                }
            }
            self.transport.mark_sent(peer, range.end);
        }
        for f in dead {
            log::warn!("GS follower {f} unreachable; dropping replica");
            self.transport.deregister(f.0 as u64);
            self.followers.retain(|x| *x != f);
        }
        self.transport
            .truncate_below(self.transport.min_acked());
    }

    /// The follower holding the longest applied prefix (promotion
    /// target); `None` when no follower is registered.
    pub fn most_caught_up(&self) -> Option<InstanceId> {
        self.followers
            .iter()
            .copied()
            .max_by_key(|f| {
                (
                    self.transport.acked(f.0 as u64).unwrap_or(0),
                    u32::MAX - f.0,
                )
            })
    }
}

/// One GS follower thread: a full replica of the global prompt tree,
/// fed by the sequenced delta stream. Runs until `Shutdown`.
pub fn run_gs_follower(
    id: InstanceId,
    leader: InstanceId,
    block_tokens: usize,
    ttl: f64,
    epoch: Instant,
    fabric: Fabric<Msg>,
    endpoint: Endpoint<Msg>,
) {
    let mut tree = GlobalPromptTrees::new(block_tokens, ttl);
    let mut cursor = DeltaCursor::new();
    let ack = |fabric: &Fabric<Msg>, next: u64| {
        let _ = fabric.send(id, leader, Msg::DeltaAck { from: id, next });
    };
    loop {
        match endpoint.recv_timeout(Duration::from_millis(50)) {
            Ok((_, Msg::Shutdown)) => return,
            Ok((_, Msg::Delta { seq, ev })) => {
                match cursor.offer(seq, ev) {
                    Ingest::Ready(evs) => {
                        for e in &evs {
                            tree.apply_delta(e);
                        }
                        ack(&fabric, cursor.expected());
                    }
                    Ingest::Buffered { resend_from } => {
                        // The window bounds legitimate out-of-order
                        // buffering at GS_WINDOW - 1 entries; a buffer
                        // past half the window means the gap keeps not
                        // arriving (resend loss) — stop nacking and ask
                        // for a snapshot bootstrap instead.
                        if cursor.buffered() > GS_WINDOW / 2 {
                            let _ = fabric.send(id, leader, Msg::SnapshotReq {
                                from: id,
                            });
                        } else {
                            // Gap: the ack value IS the re-request.
                            ack(&fabric, resend_from);
                        }
                    }
                    Ingest::Duplicate => ack(&fabric, cursor.expected()),
                }
            }
            Ok((_, Msg::Snapshot { snap })) => {
                // Bootstrap / catch-up past a truncated log prefix. A
                // snapshot OLDER than our applied cursor must be
                // ignored: restoring it would roll the tree back to
                // snap.seq while the cursor stays at expected(), and
                // the deltas in between — already applied and acked —
                // would never be resent (e.g. a SnapshotReq raced gap
                // resends that then filled the hole).
                if snap.seq < cursor.expected() {
                    ack(&fabric, cursor.expected());
                } else {
                    let mut fresh =
                        GlobalPromptTrees::new(block_tokens, ttl);
                    snap.restore_into(&mut fresh);
                    tree = fresh;
                    for e in cursor.advance_to(snap.seq) {
                        tree.apply_delta(&e);
                    }
                    ack(&fabric, cursor.expected());
                }
            }
            Ok((_, Msg::Promote { reply_to })) => {
                // Failover: hand the caller this replica's state at its
                // applied sequence. The thread keeps replicating — the
                // restored primary resumes streaming to it.
                let snap = TreeSnapshot::capture(&tree, cursor.expected());
                let _ = fabric.send(id, reply_to, Msg::Snapshot { snap });
            }
            Ok((_, other)) => {
                log::debug!("GS follower {id} ignoring {other:?}");
            }
            Err(_) => {}
        }
        // Local TTL housekeeping: expiry is a pure function of stamps,
        // so replicas expire independently yet equivalently — a replica
        // never needs an expiry delta.
        tree.expire(epoch.elapsed().as_secs_f64());
    }
}
