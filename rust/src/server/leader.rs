//! The leader: global scheduler + cluster manager + client API.
//!
//! `ServeCluster::start` spawns the instance threads and a collector
//! thread; `ClientHandle` is the public API — submit prompts (text or
//! tokens) and collect streamed responses with full request metrics.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::cluster::ClusterManager;
use crate::config::Config;
use crate::engine::{DisaggMilestone, Request, SamplingParams};
use crate::mempool::{BlockGeometry, InstanceId};
use crate::metrics::{Metrics, RequestRecord};
use crate::net::{Fabric, LinkModel};
use crate::runtime::ModelRuntime;
use crate::scheduler::cost_model::OperatorCostModel;
use crate::scheduler::prompt_tree::InstanceKind;
use crate::scheduler::router::{GlobalScheduler, InstanceLoad};
use crate::server::instance::{run_instance, InstanceConfig};
use crate::server::message::Msg;
use crate::tokenizer::Tokenizer;

const LEADER: InstanceId = InstanceId(u32::MAX);

#[derive(Clone, Debug)]
pub struct ServeOptions {
    pub config: Config,
    pub milestone: DisaggMilestone,
    /// Model the wire by actually sleeping for the link time (true for
    /// perf-realistic examples; false for fast tests).
    pub real_sleep: bool,
}

impl Default for ServeOptions {
    fn default() -> Self {
        ServeOptions {
            config: Config::default(),
            milestone: DisaggMilestone::PdCaching3,
            real_sleep: false,
        }
    }
}

#[derive(Default)]
struct Pending {
    tokens: Vec<u32>,
    record: Option<RequestRecord>,
    done: bool,
    /// Prompt retained for re-dispatch on instance failure.
    prompt: Vec<u32>,
    session: u64,
    sampling: SamplingParams,
    dispatched_to: InstanceId,
}

struct Shared {
    pending: Mutex<HashMap<u64, Pending>>,
    cv: Condvar,
}

pub struct ServeCluster {
    fabric: Fabric<Msg>,
    gs: Mutex<GlobalScheduler>,
    cm: Mutex<ClusterManager>,
    shared: Arc<Shared>,
    instances: Vec<(InstanceId, InstanceKind)>,
    handles: Mutex<Vec<std::thread::JoinHandle<()>>>,
    next_rid: AtomicU64,
    started: Instant,
    tokenizer: Tokenizer,
    opts: ServeOptions,
    metrics: Mutex<Metrics>,
    /// Decode pairing for disaggregated dispatch (round-robin).
    decode_rr: AtomicU64,
}

/// Client-facing handle (cheap to clone via Arc).
pub type ClientHandle = Arc<ServeCluster>;

impl ServeCluster {
    /// Spawn the whole cluster. `runtime` is shared by all instances
    /// (the PJRT CPU client is thread-safe; each instance still owns its
    /// MemPool and decode sessions).
    pub fn start(opts: ServeOptions, runtime: Arc<ModelRuntime>)
                 -> Result<ClientHandle> {
        let cfgc = &opts.config;
        let link = LinkModel::from_config(&cfgc.fabric);
        let fabric: Fabric<Msg> = Fabric::new(link, opts.real_sleep);
        let geom = BlockGeometry {
            block_tokens: cfgc.mempool.block_tokens,
            layers: runtime.meta.layers,
            n_heads: runtime.meta.n_heads,
            head_dim: runtime.meta.head_dim,
            aggregated: cfgc.mempool.aggregated_layout,
        };
        let mut cost = OperatorCostModel::default_tiny();
        // Calibration from artifacts/cost_model.json when present.
        if let Ok(text) =
            std::fs::read_to_string(format!("{}/cost_model.json",
                                            cfgc.artifacts_dir))
        {
            if let Ok(j) = crate::util::json::Json::parse(&text) {
                cost = crate::scheduler::cost_model::model_from_json(&j)
                    .unwrap_or(cost);
            }
        }
        let mut gs = GlobalScheduler::new(
            cfgc.scheduler.policy,
            cost,
            geom.block_tokens,
            cfgc.scheduler.tree_ttl_s,
        );
        gs.bytes_per_token = geom.floats_per_token() * 4;
        gs.bandwidth_bytes_per_s = cfgc.fabric.bandwidth_gbps * 1e9;
        gs.per_call_s = cfgc.fabric.call_overhead_us * 1e-6;
        gs.transfer_decision_enabled = cfgc.scheduler.transfer_decision;

        let mut cm = ClusterManager::new(
            cfgc.cluster.heartbeat_ms / 1e3,
            cfgc.cluster.heartbeat_misses,
        );

        let mut specs = vec![];
        let mut id = 0u32;
        for _ in 0..cfgc.cluster.prefill_instances {
            specs.push((InstanceId(id), InstanceKind::PrefillOnly));
            id += 1;
        }
        for _ in 0..cfgc.cluster.decode_instances {
            specs.push((InstanceId(id), InstanceKind::DecodeOnly));
            id += 1;
        }
        for _ in 0..cfgc.cluster.colocated_instances {
            specs.push((InstanceId(id), InstanceKind::Colocated));
            id += 1;
        }
        for &(iid, kind) in &specs {
            gs.add_instance(iid, kind);
            cm.register(iid, kind, 0.0);
        }

        let epoch = Instant::now();
        let leader_ep = fabric.attach(LEADER);
        let shared = Arc::new(Shared {
            pending: Mutex::new(HashMap::new()),
            cv: Condvar::new(),
        });

        let prefills: Vec<InstanceId> = specs
            .iter()
            .filter(|(_, k)| *k == InstanceKind::PrefillOnly)
            .map(|(i, _)| *i)
            .collect();
        let mut handles = vec![];
        for (idx, &(iid, kind)) in specs.iter().enumerate() {
            let backflow_to = if kind == InstanceKind::DecodeOnly
                && !prefills.is_empty()
            {
                Some(prefills[idx % prefills.len()])
            } else {
                None
            };
            let icfg = InstanceConfig {
                id: iid,
                kind,
                leader: LEADER,
                context_caching: cfgc.mempool.context_caching,
                milestone: opts.milestone,
                transfer_mode: cfgc.engine.transfer_mode,
                max_batch: cfgc.engine.max_batch,
                heartbeat_every: Duration::from_secs_f64(
                    cfgc.cluster.heartbeat_ms / 1e3,
                ),
                geom,
                hbm_blocks: cfgc.mempool.hbm_blocks,
                dram_blocks: cfgc.mempool.dram_blocks,
                index_ttl_s: cfgc.mempool.index_ttl_s,
                backflow_to,
                epoch,
            };
            let rt = runtime.clone();
            let fab = fabric.clone();
            let ep = fabric.attach(iid);
            handles.push(std::thread::spawn(move || {
                run_instance(icfg, rt, fab, ep);
            }));
        }

        let cluster = Arc::new(ServeCluster {
            fabric,
            gs: Mutex::new(gs),
            cm: Mutex::new(cm),
            shared,
            instances: specs,
            handles: Mutex::new(handles),
            next_rid: AtomicU64::new(1),
            started: epoch,
            tokenizer: Tokenizer::new(runtime.meta.vocab as u32),
            opts,
            metrics: Mutex::new(Metrics::default()),
            decode_rr: AtomicU64::new(0),
        });

        // Collector thread: drains the leader endpoint.
        let c2 = cluster.clone();
        let h = std::thread::spawn(move || c2.collector(leader_ep));
        cluster.handles.lock().unwrap().push(h);
        Ok(cluster)
    }

    fn now(&self) -> f64 {
        self.started.elapsed().as_secs_f64()
    }

    fn collector(&self, ep: crate::net::Endpoint<Msg>) {
        let mut last_sweep = Instant::now();
        loop {
            // Periodic failure sweep (time-gated, runs regardless of
            // message traffic).
            if last_sweep.elapsed() > Duration::from_millis(20) {
                last_sweep = Instant::now();
                let now = self.now();
                let dead = self.cm.lock().unwrap().sweep(now);
                if !dead.is_empty() {
                    self.on_failure(&dead);
                }
                // Global-tree TTL housekeeping: heap-driven, so this is
                // an O(1) peek when nothing is stale (routing also
                // expires opportunistically; this covers idle periods).
                self.gs.lock().unwrap().expire(now);
            }
            let Ok((_, msg)) = ep.recv_timeout(Duration::from_millis(20))
            else {
                if self.shutting_down() {
                    return;
                }
                continue;
            };
            match msg {
                Msg::Token { rid, token, done } => {
                    let mut p = self.shared.pending.lock().unwrap();
                    if let Some(entry) = p.get_mut(&rid) {
                        entry.tokens.push(token);
                        if done && entry.record.is_none() {
                            // Finished may still follow with metrics.
                        }
                    }
                }
                Msg::Finished {
                    rid,
                    instance,
                    prompt_tokens,
                    cached_tokens,
                    output_tokens,
                    scheduled,
                    first_token_time,
                    completion_time,
                    cached_seq,
                } => {
                    // Response path: update global prompt trees (Fig 6).
                    if !cached_seq.is_empty() {
                        self.gs.lock().unwrap().record_cached(
                            instance,
                            &cached_seq,
                            self.now(),
                        );
                    }
                    let mut p = self.shared.pending.lock().unwrap();
                    if let Some(entry) = p.get_mut(&rid) {
                        let rec = RequestRecord {
                            request_id: rid,
                            session_id: entry.session,
                            arrival: entry
                                .record
                                .as_ref()
                                .map(|r| r.arrival)
                                .unwrap_or(scheduled),
                            scheduled,
                            first_token: first_token_time,
                            completion: completion_time,
                            prompt_tokens,
                            cached_tokens,
                            output_tokens,
                            prefill_instance: entry.dispatched_to.0,
                            decode_instance: instance.0,
                        };
                        self.metrics.lock().unwrap().push(rec.clone());
                        entry.record = Some(rec);
                        entry.done = true;
                        self.shared.cv.notify_all();
                    }
                }
                Msg::Heartbeat { from } => {
                    self.cm.lock().unwrap().heartbeat(from, self.now());
                }
                Msg::Shutdown => return,
                other => log::debug!("leader ignoring {other:?}"),
            }
        }
    }

    fn shutting_down(&self) -> bool {
        false // replaced by Shutdown message on drop path
    }

    fn on_failure(&self, dead: &[InstanceId]) {
        log::warn!("instances failed: {dead:?}");
        {
            let mut gs = self.gs.lock().unwrap();
            for d in dead {
                gs.trees.remove_instance(*d);
            }
        }
        let epoch = self.cm.lock().unwrap().epoch();
        for &(iid, _) in &self.instances {
            if !dead.contains(&iid) {
                let _ = self.fabric.send(LEADER, iid, Msg::Membership {
                    epoch,
                    dead: dead.to_vec(),
                });
            }
        }
        // Re-dispatch in-flight requests that were on dead instances.
        let retry: Vec<(u64, Vec<u32>, u64, SamplingParams)> = {
            let p = self.shared.pending.lock().unwrap();
            p.iter()
                .filter(|(_, e)| {
                    !e.done && dead.contains(&e.dispatched_to)
                })
                .map(|(rid, e)| {
                    (*rid, e.prompt.clone(), e.session, e.sampling)
                })
                .collect()
        };
        for (rid, prompt, session, sampling) in retry {
            log::info!("re-dispatching rid={rid} after failure");
            {
                let mut p = self.shared.pending.lock().unwrap();
                if let Some(e) = p.get_mut(&rid) {
                    e.tokens.clear();
                }
            }
            let _ = self.dispatch(rid, prompt, session, sampling);
        }
    }

    /// Is this instance currently believed alive?
    pub fn is_alive(&self, id: InstanceId) -> bool {
        self.cm.lock().unwrap().is_alive(id)
    }

    /// Kill an instance (failure injection for tests/examples): detaches
    /// it from the fabric so its heartbeats stop and sends to it fail.
    pub fn kill(&self, id: InstanceId) {
        log::warn!("killing {id} (failure injection)");
        self.fabric.send(LEADER, id, Msg::Shutdown).ok();
        self.fabric.detach(id);
    }

    /// Submit raw text (tokenized by the GS — paper Fig 6 step 1).
    pub fn submit_text(&self, text: &str, session: u64,
                       sampling: SamplingParams) -> Result<u64> {
        let tokens = self.tokenizer.encode_prompt(text);
        self.submit(tokens, session, sampling)
    }

    /// Submit a tokenized prompt; returns the request id.
    pub fn submit(&self, prompt: Vec<u32>, session: u64,
                  sampling: SamplingParams) -> Result<u64> {
        let rid = self.next_rid.fetch_add(1, Ordering::SeqCst);
        {
            let mut p = self.shared.pending.lock().unwrap();
            let mut rec = RequestRecord::default();
            rec.arrival = self.now();
            p.insert(rid, Pending {
                tokens: vec![],
                record: Some(rec),
                done: false,
                prompt: prompt.clone(),
                session,
                sampling,
                dispatched_to: InstanceId(0),
            });
        }
        self.dispatch(rid, prompt, session, sampling)?;
        Ok(rid)
    }

    fn dispatch(&self, rid: u64, prompt: Vec<u32>, session: u64,
                sampling: SamplingParams) -> Result<()> {
        let now = self.now();
        let alive: Vec<InstanceId> = self
            .instances
            .iter()
            .filter(|(i, _)| self.cm.lock().unwrap().is_alive(*i))
            .map(|(i, _)| *i)
            .collect();
        let outcome = {
            let mut gs = self.gs.lock().unwrap();
            // Loads: approximate by in-flight request counts per instance.
            let pend = self.shared.pending.lock().unwrap();
            let mut queued: HashMap<InstanceId, usize> = HashMap::new();
            for e in pend.values() {
                if !e.done {
                    *queued.entry(e.dispatched_to).or_insert(0) +=
                        e.prompt.len();
                }
            }
            gs.route(&prompt, session, &|id| InstanceLoad {
                queued_tokens: queued.get(&id).copied().unwrap_or(0),
                queued_cached_ratio: 0.0,
                running: 0,
            }, now)?
        };
        let target = outcome.decision.instance;
        anyhow::ensure!(
            alive.contains(&target),
            "routed to dead instance {target}"
        );
        // Decode pairing for prefill-only targets: round-robin over
        // alive decode-only instances.
        let decode_to = if self
            .instances
            .iter()
            .any(|(i, k)| *i == target && *k == InstanceKind::PrefillOnly)
        {
            let decs: Vec<InstanceId> = self
                .instances
                .iter()
                .filter(|(i, k)| {
                    *k == InstanceKind::DecodeOnly && alive.contains(i)
                })
                .map(|(i, _)| *i)
                .collect();
            anyhow::ensure!(!decs.is_empty(), "no decode instances alive");
            let i = self.decode_rr.fetch_add(1, Ordering::Relaxed) as usize;
            Some(decs[i % decs.len()])
        } else {
            None
        };
        {
            let mut p = self.shared.pending.lock().unwrap();
            if let Some(e) = p.get_mut(&rid) {
                e.dispatched_to = target;
            }
        }
        let req = Request {
            id: rid,
            session,
            prompt,
            sampling,
            arrival: now,
        };
        self.fabric
            .send(LEADER, target, Msg::Dispatch { req, decode_to })
            .map_err(|e| anyhow::anyhow!("dispatch: {e}"))?;
        Ok(())
    }

    /// Block until `rid` finishes; returns (generated tokens, record).
    pub fn collect(&self, rid: u64, timeout: Duration)
                   -> Result<(Vec<u32>, RequestRecord)> {
        let deadline = Instant::now() + timeout;
        let mut p = self.shared.pending.lock().unwrap();
        loop {
            if let Some(e) = p.get(&rid) {
                if e.done {
                    let e = p.remove(&rid).unwrap();
                    return Ok((e.tokens, e.record.context("no record")?));
                }
            } else {
                anyhow::bail!("unknown rid {rid}");
            }
            let left = deadline.saturating_duration_since(Instant::now());
            anyhow::ensure!(!left.is_zero(), "collect timeout for {rid}");
            let (guard, _) = self
                .shared
                .cv
                .wait_timeout(p, left.min(Duration::from_millis(100)))
                .unwrap();
            p = guard;
        }
    }

    /// Aggregated metrics over completed requests.
    pub fn metrics(&self) -> Metrics {
        self.metrics.lock().unwrap().clone()
    }

    pub fn net_stats(&self) -> crate::net::NetStats {
        self.fabric.stats()
    }

    pub fn instances(&self) -> &[(InstanceId, InstanceKind)] {
        &self.instances
    }

    /// Graceful shutdown: stop instances and the collector.
    pub fn shutdown(&self) {
        for &(iid, _) in &self.instances {
            let _ = self.fabric.send(LEADER, iid, Msg::Shutdown);
        }
        let _ = self.fabric.send(LEADER, LEADER, Msg::Shutdown);
        let handles = std::mem::take(&mut *self.handles.lock().unwrap());
        for h in handles {
            let _ = h.join();
        }
    }
}
