//! The leader: global scheduler + cluster manager + client API.
//!
//! `ServeCluster::start` spawns the instance threads and a collector
//! thread; `ClientHandle` is the public API — submit prompts (text or
//! tokens) and collect streamed responses with full request metrics.

use std::collections::{HashMap, HashSet};
use std::sync::atomic::{AtomicU32, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, PoisonError, RwLock};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::cluster::ClusterManager;
use crate::config::Config;
use crate::elastic::delta::DeltaEvent;
use crate::elastic::lifecycle::Lifecycle;
use crate::elastic::planner::{PlannerConfig, Recipient};
use crate::engine::{DisaggMilestone, Request, SamplingParams};
use crate::mempool::{BlockGeometry, InstanceId};
use crate::metrics::{Metrics, RequestRecord};
use crate::net::fabric::NetError;
use crate::net::{Fabric, LinkModel};
use crate::obs::flight::kind as fkind;
use crate::obs::trace::phase;
use crate::obs::{
    trace, view, Alert, AttribBook, ClusterView, FlightRecorder, Labels,
    Registry, RetireSample, Timeline, TraceSink, Watchdog,
};
use crate::runtime::ModelRuntime;
use crate::util::rng::{DetMap, DetSet};
use crate::util::sync::{LockExt, RwLockExt};
use crate::scheduler::cost_model::OperatorCostModel;
use crate::scheduler::prompt_tree::{GlobalPromptTrees, InstanceKind};
use crate::scheduler::router::{GlobalScheduler, InstanceLoad};
use crate::server::data_plane::{GsDataPlane, PromotionRestore};
use crate::server::instance::{run_instance, InstanceConfig};
use crate::server::message::Msg;
use crate::server::replica::{follower_id, run_gs_follower};
use crate::tokenizer::Tokenizer;

const LEADER: InstanceId = InstanceId(u32::MAX);

/// First retry delay for an unacked migration task (seconds); doubles
/// per attempt up to [`MIGRATE_RETRY_CAP`].
const MIGRATE_RETRY_BASE: f64 = 0.1;
const MIGRATE_RETRY_CAP: f64 = 1.0;

/// First re-send delay for an unanswered `Msg::Promote` (seconds);
/// doubles per attempt up to [`PROMOTE_RETRY_CAP`].
const PROMOTE_RETRY_BASE: f64 = 0.05;
const PROMOTE_RETRY_CAP: f64 = 0.5;

/// Capped exponential backoff: `base * 2^attempt`, clamped to `cap`.
fn backoff(base: f64, cap: f64, attempt: u32) -> f64 {
    (base * 2f64.powi(attempt.min(16) as i32)).min(cap)
}

/// Bounded seen-set for migration ids: replayed [`Msg::MigrateLanded`]
/// acks (fabric duplication, donor retries) must not re-apply their
/// ownership handoff.
#[derive(Default)]
struct SeenMids {
    set: HashSet<u64>,
    order: std::collections::VecDeque<u64>,
}

impl SeenMids {
    const CAP: usize = 1024;

    /// True the first time `mid` is offered.
    fn insert(&mut self, mid: u64) -> bool {
        if !self.set.insert(mid) {
            return false;
        }
        self.order.push_back(mid);
        if self.order.len() > Self::CAP {
            if let Some(old) = self.order.pop_front() {
                self.set.remove(&old);
            }
        }
        true
    }
}

/// One outstanding migration task of an in-flight drain, keyed by mid.
#[derive(Debug)]
struct MigrateTask {
    to: InstanceId,
    tokens: Vec<u32>,
    attempt: u32,
    /// Leader-clock time after which the task is re-sent.
    next_retry: f64,
}

/// Per-shard GS primary health (ISSUE 6 failure detector). The shard
/// primaries live in the leader process, so their liveness signal is a
/// self-beat the sweep refreshes — crash injection suppresses it and
/// detection genuinely flows through the heartbeat miss window, exactly
/// as it would for an out-of-process primary.
struct ShardHealth {
    last_beat: f64,
    /// Crash injected: beats stop until the promoted snapshot lands.
    crashed: bool,
    /// Promotion in flight: (target, attempt, next re-send time).
    promotion: Option<(InstanceId, u32, f64)>,
}

/// Leader-side failure-detector state: shard self-beats plus the GS
/// follower heartbeat ledger. `all_followers` is the configured roster
/// (fixed at start) — a follower the replication layer dropped stays
/// listed here so its next heartbeat can rejoin it.
struct GsHealth {
    all_followers: Vec<InstanceId>,
    follower_beats: HashMap<InstanceId, f64>,
    shards: Vec<ShardHealth>,
}

#[derive(Clone, Debug)]
pub struct ServeOptions {
    pub config: Config,
    pub milestone: DisaggMilestone,
    /// Model the wire by actually sleeping for the link time (true for
    /// perf-realistic examples; false for fast tests).
    pub real_sleep: bool,
}

impl Default for ServeOptions {
    fn default() -> Self {
        ServeOptions {
            config: Config::default(),
            milestone: DisaggMilestone::PdCaching3,
            real_sleep: false,
        }
    }
}

#[derive(Default)]
struct Pending {
    tokens: Vec<u32>,
    record: Option<RequestRecord>,
    done: bool,
    /// Prompt retained for re-dispatch on instance failure.
    prompt: Vec<u32>,
    session: u64,
    sampling: SamplingParams,
    dispatched_to: InstanceId,
    /// Decode pairing (disaggregated dispatch) — a drain of the decode
    /// instance must wait for this request too.
    decode_on: Option<InstanceId>,
    /// Eq. 1 prefill-cost prediction captured at route time, compared
    /// against the observed prefill at retire (ISSUE 9 attribution).
    predicted_prefill_s: f64,
}

struct Shared {
    pending: Mutex<DetMap<u64, Pending>>,
    cv: Condvar,
}

/// Progress of one in-flight drain (keyed by the draining instance).
#[derive(Debug, Default)]
struct DrainProgress {
    /// Migration tasks the leader queued.
    expected: usize,
    /// `MigrateLanded` acks received (success or failure).
    landed: usize,
    /// Acks that actually carried a prefix (landed + indexed).
    landed_prefixes: usize,
    /// Token-blocks those successful acks covered.
    landed_blocks: usize,
    /// `DrainDone` barrier received.
    done: bool,
    /// Outstanding tasks by mid — the drain driver's retry queue; an
    /// acked mid is removed, an unacked one is re-sent with capped
    /// exponential backoff.
    outstanding: DetMap<u64, MigrateTask>,
}

/// What a completed [`ServeCluster::drain`] moved. Migrated figures
/// count prefixes that actually *landed* (acked by the receiver), not
/// what the planner scheduled — a failed task shows up as the
/// planned-vs-migrated gap.
#[derive(Clone, Copy, Debug, Default)]
pub struct DrainReport {
    /// Hot prefixes that landed on a receiver and were indexed.
    pub migrated_prefixes: usize,
    /// Token-blocks those prefixes covered.
    pub migrated_blocks: usize,
    /// Migration tasks the planner scheduled.
    pub planned_prefixes: usize,
    /// Cold/shallow token-blocks dropped with the instance.
    pub dropped_blocks: usize,
    /// Token-blocks already replicated on an Active peer.
    pub replicated_blocks: usize,
}

pub struct ServeCluster {
    fabric: Fabric<Msg>,
    /// The sharded GS data plane (ISSUE 7): per-shard units each
    /// holding that shard's tree + replication log behind their own
    /// lock, so routes and prefix-keyed deltas for different shards
    /// never contend. Cross-shard ops are epoch-fenced broadcasts.
    plane: GsDataPlane,
    cm: Mutex<ClusterManager>,
    shared: Arc<Shared>,
    /// Live roster (grows on `join`, shrinks on `drain`).
    instances: RwLock<Vec<(InstanceId, InstanceKind)>>,
    lifecycle: Mutex<Lifecycle>,
    /// In-flight drains (instance → progress).
    drains: Mutex<DetMap<InstanceId, DrainProgress>>,
    /// Signaled (paired with `drains`) on any drain progress — a
    /// migration ack, the drain barrier, or an in-flight request
    /// finishing — so [`Self::drain`] waits event-driven instead of
    /// polling.
    drain_cv: Condvar,
    /// Heartbeat failure detector (ISSUE 6). Lock order: never held
    /// across a plane-lock acquisition.
    gs_health: Mutex<GsHealth>,
    /// Migration-id dedupe window (replayed MigrateLanded acks).
    landed_mids: Mutex<SeenMids>,
    /// Next migration id for the 3-step handshake.
    next_mid: AtomicU64,
    /// Promotion handshake for [`Self::fail_gs_primary`]: shards whose
    /// promoted snapshot has not landed yet.
    promote_pending: Mutex<DetSet<usize>>,
    promote_cv: Condvar,
    handles: Mutex<Vec<std::thread::JoinHandle<()>>>,
    next_rid: AtomicU64,
    /// Next instance id for scale-up joins.
    next_iid: AtomicU32,
    started: Instant,
    tokenizer: Tokenizer,
    opts: ServeOptions,
    metrics: Mutex<Metrics>,
    runtime: Arc<ModelRuntime>,
    geom: BlockGeometry,
    /// Decode pairing for disaggregated dispatch (round-robin).
    decode_rr: AtomicU64,
    /// Cluster-wide metric registry (ISSUE 8): shared with every
    /// instance thread and each shard's router; the collector scrapes
    /// leader-side stats (fabric, replication lag) into it
    /// periodically so [`Self::cluster_view`] is one merged snapshot.
    obs: Registry,
    /// Request-scoped trace sink: the leader mints a span per routed
    /// request (`trace::request_span(rid)`); instances close their
    /// phases on the same span carried by the dispatch.
    trace: TraceSink,
    /// Bounded control-plane flight recorder (heartbeats, deltas,
    /// suspicion, promotions, fence epochs) — dumped to the bench-JSON
    /// sink when the failure detector fires.
    flight: FlightRecorder,
    /// Windowed time-series over registry snapshots (ISSUE 9): the
    /// collector's ~500ms scrape feeds it; frames close on 1s windows.
    timeline: Timeline,
    /// Online invariant checker over closed timeline frames. Only the
    /// collector thread drives it; the mutex keeps `&self` plumbing.
    watchdog: Mutex<Watchdog>,
    /// Retire-side latency digests (queue/TTFT/TBT per instance) and
    /// the Eq. 1 predicted-vs-observed prefill cost error.
    attrib: AttribBook,
}

/// Client-facing handle (cheap to clone via Arc).
pub type ClientHandle = Arc<ServeCluster>;

impl ServeCluster {
    /// Spawn the whole cluster. `runtime` is shared by all instances
    /// (the PJRT CPU client is thread-safe; each instance still owns its
    /// MemPool and decode sessions).
    pub fn start(opts: ServeOptions, runtime: Arc<ModelRuntime>)
                 -> Result<ClientHandle> {
        let cfgc = &opts.config;
        let link = LinkModel::from_config(&cfgc.fabric);
        let fabric: Fabric<Msg> = Fabric::new(link, opts.real_sleep);
        let geom = BlockGeometry {
            block_tokens: cfgc.mempool.block_tokens,
            layers: runtime.meta.layers,
            n_heads: runtime.meta.n_heads,
            head_dim: runtime.meta.head_dim,
            aggregated: cfgc.mempool.aggregated_layout,
        };
        let mut cost = OperatorCostModel::default_tiny();
        // Calibration from artifacts/cost_model.json when present.
        if let Ok(text) =
            std::fs::read_to_string(format!("{}/cost_model.json",
                                            cfgc.artifacts_dir))
        {
            if let Ok(j) = crate::util::json::Json::parse(&text) {
                cost = crate::scheduler::cost_model::model_from_json(&j)
                    .unwrap_or(cost);
            }
        }
        // One 1-shard scheduler per data-plane unit, all with the same
        // knobs; each unit's tree carries its prefix-range slice plus
        // the full registry (broadcast membership).
        let gs_shards = cfgc.scheduler.gs_shards.max(1);
        let make_gs = |cost: OperatorCostModel| {
            let mut gs = GlobalScheduler::new(
                cfgc.scheduler.policy,
                cost,
                geom.block_tokens,
                cfgc.scheduler.tree_ttl_s,
            );
            gs.bytes_per_token = geom.floats_per_token() * 4;
            gs.bandwidth_bytes_per_s = cfgc.fabric.bandwidth_gbps * 1e9;
            gs.per_call_s = cfgc.fabric.call_overhead_us * 1e-6;
            gs.transfer_decision_enabled = cfgc.scheduler.transfer_decision;
            gs
        };
        let mut unit_schedulers: Vec<GlobalScheduler> =
            (0..gs_shards).map(|_| make_gs(cost.clone())).collect();

        // Observability plumbing (ISSUE 8): one registry + trace sink
        // shared by the leader, every instance thread, and each
        // shard's router. Both are env-gated (`MEMSERVE_METRICS`,
        // `MEMSERVE_TRACE`), so the disabled path costs a few relaxed
        // loads on the hot route.
        let obs = Registry::from_env();
        let trace_sink = TraceSink::from_env();
        let flight = FlightRecorder::default();
        // Analysis layer (ISSUE 9) on top of the recording layer: all
        // three are no-ops while the registry is disabled.
        let timeline = Timeline::default();
        let watchdog = Mutex::new(Watchdog::default());
        let attrib = AttribBook::new(&obs);
        for (k, gs) in unit_schedulers.iter_mut().enumerate() {
            gs.attach_obs(&obs, Some(k as u32));
            // Live server: the route_us digest reads the shared
            // monotonic clock. Injected by name so the scheduler core
            // stays wall-clock-free (archlint R1).
            gs.set_route_timer(crate::util::clock::monotonic_secs);
        }

        let mut cm = ClusterManager::new(
            cfgc.cluster.heartbeat_ms / 1e3,
            cfgc.cluster.heartbeat_misses,
        );

        let mut specs = vec![];
        let mut id = 0u32;
        for _ in 0..cfgc.cluster.prefill_instances {
            specs.push((InstanceId(id), InstanceKind::PrefillOnly));
            id += 1;
        }
        for _ in 0..cfgc.cluster.decode_instances {
            specs.push((InstanceId(id), InstanceKind::DecodeOnly));
            id += 1;
        }
        for _ in 0..cfgc.cluster.colocated_instances {
            specs.push((InstanceId(id), InstanceKind::Colocated));
            id += 1;
        }
        let mut lifecycle = Lifecycle::new();
        for &(iid, kind) in &specs {
            for gs in &mut unit_schedulers {
                gs.add_instance(iid, kind);
            }
            cm.register(iid, kind, 0.0);
            if let Err(e) = lifecycle.join(iid, kind) {
                debug_assert!(false, "seed join rejected: {e}");
                log::error!("seed join for {iid} rejected: {e}");
            }
        }

        let epoch = Instant::now();
        let leader_ep = fabric.attach(LEADER);
        let shared = Arc::new(Shared {
            pending: Mutex::new(DetMap::default()),
            cv: Condvar::new(),
        });

        let prefills: Vec<InstanceId> = specs
            .iter()
            .filter(|(_, k)| *k == InstanceKind::PrefillOnly)
            .map(|(i, _)| *i)
            .collect();
        let mut handles = vec![];
        for (idx, &(iid, kind)) in specs.iter().enumerate() {
            let backflow_to = if kind == InstanceKind::DecodeOnly
                && !prefills.is_empty()
            {
                Some(prefills[idx % prefills.len()])
            } else {
                None
            };
            let icfg = InstanceConfig {
                id: iid,
                kind,
                leader: LEADER,
                context_caching: cfgc.mempool.context_caching,
                milestone: opts.milestone,
                transfer_mode: cfgc.engine.transfer_mode,
                max_batch: cfgc.engine.max_batch,
                heartbeat_every: Duration::from_secs_f64(
                    cfgc.cluster.heartbeat_ms / 1e3,
                ),
                geom,
                hbm_blocks: cfgc.mempool.hbm_blocks,
                dram_blocks: cfgc.mempool.dram_blocks,
                index_ttl_s: cfgc.mempool.index_ttl_s,
                backflow_to,
                epoch,
                obs: obs.clone(),
                trace: trace_sink.clone(),
            };
            let rt = runtime.clone();
            let fab = fabric.clone();
            let ep = fabric.attach(iid);
            handles.push(std::thread::spawn(move || {
                run_instance(icfg, rt, fab, ep);
            }));
        }

        // GS replication: spawn follower replica threads — each owning
        // a replica of every prefix-range shard — and seed every
        // shard's delta log with the roster's Join events so replicas
        // converge from sequence 0.
        let followers: Vec<InstanceId> = (0..cfgc.scheduler.gs_replicas)
            .map(follower_id)
            .collect();
        let plane = GsDataPlane::new(
            geom.block_tokens,
            cfgc.scheduler.tree_ttl_s,
            unit_schedulers,
            followers.clone(),
        );
        if !followers.is_empty() {
            for &(iid, kind) in &specs {
                plane.seed_log_all(DeltaEvent::Join {
                    instance: iid,
                    kind,
                });
            }
            for &fid in &followers {
                let fab = fabric.clone();
                let ep = fabric.attach(fid);
                let bt = geom.block_tokens;
                let ttl = cfgc.scheduler.tree_ttl_s;
                let beat = Duration::from_secs_f64(
                    cfgc.cluster.heartbeat_ms / 1e3,
                );
                handles.push(std::thread::spawn(move || {
                    run_gs_follower(fid, LEADER, bt, ttl, gs_shards, beat,
                                    epoch, fab, ep);
                }));
            }
        }

        // Threads are up: the whole seed roster goes Active.
        for &(iid, _) in &specs {
            if let Err(e) = lifecycle.activate(iid) {
                debug_assert!(false, "seed activate rejected: {e}");
                log::error!("seed activate for {iid} rejected: {e}");
            }
        }
        let gs_health = GsHealth {
            all_followers: followers.clone(),
            follower_beats: followers.iter().map(|f| (*f, 0.0)).collect(),
            shards: (0..gs_shards.max(1))
                .map(|_| ShardHealth {
                    last_beat: 0.0,
                    crashed: false,
                    promotion: None,
                })
                .collect(),
        };
        let cluster = Arc::new(ServeCluster {
            fabric,
            plane,
            cm: Mutex::new(cm),
            shared,
            next_iid: AtomicU32::new(id),
            instances: RwLock::new(specs),
            lifecycle: Mutex::new(lifecycle),
            drains: Mutex::new(DetMap::default()),
            drain_cv: Condvar::new(),
            gs_health: Mutex::new(gs_health),
            landed_mids: Mutex::new(SeenMids::default()),
            next_mid: AtomicU64::new(1),
            promote_pending: Mutex::new(DetSet::default()),
            promote_cv: Condvar::new(),
            handles: Mutex::new(handles),
            next_rid: AtomicU64::new(1),
            started: epoch,
            tokenizer: Tokenizer::new(runtime.meta.vocab as u32),
            opts,
            metrics: Mutex::new(Metrics::default()),
            runtime,
            geom,
            decode_rr: AtomicU64::new(0),
            obs,
            trace: trace_sink,
            flight,
            timeline,
            watchdog,
            attrib,
        });

        // Ship the seed-roster backlog to the GS followers.
        cluster.plane.flush_all(&cluster.fabric, LEADER);
        // Collector thread: drains the leader endpoint.
        let c2 = cluster.clone();
        let h = std::thread::spawn(move || c2.collector(leader_ep));
        cluster.handles.plock().push(h);
        Ok(cluster)
    }

    fn now(&self) -> f64 {
        self.started.elapsed().as_secs_f64()
    }

    /// The single write path of the (replicated) global prompt tree:
    /// apply the delta to the primary, append it to the sequenced log,
    /// and ship sendable windows to every GS follower. Every ownership
    /// mutation — response-path records, honest evictions, handoffs,
    /// drain toggles, membership — funnels through here, which is what
    /// makes a follower's replica a faithful promotion target.
    fn gs_apply(&self, ev: DeltaEvent) {
        self.gs_apply_batch(std::iter::once(ev));
    }

    /// Batch form, delegated to the sharded data plane: each delta's
    /// tree-apply and log-append happen under ONE hold of its shard's
    /// unit lock (apply order and log order must never invert across
    /// threads — concurrent appliers would otherwise replicate a
    /// different history than the primary executed), shard-keyed
    /// batches touch only their units so S shards absorb ~1/S of the
    /// write stream each without contending, and a batch carrying a
    /// membership/whole-view event takes the epoch-fenced broadcast
    /// path (all units, ascending) so every shard sees it at the same
    /// cut. The fabric flush happens with no unit lock held — flush
    /// order is irrelevant (per-peer, per-shard cursors send by
    /// sequence), so routing never waits on the wire.
    fn gs_apply_batch(&self, evs: impl IntoIterator<Item = DeltaEvent>) {
        // Count applied deltas for the flight recorder without
        // buffering the batch (the plane consumes the iterator).
        let n = std::cell::Cell::new(0u64);
        self.plane.apply_batch(
            evs.into_iter().inspect(|_| n.set(n.get() + 1)),
            &self.fabric,
            LEADER,
        );
        if n.get() > 0 {
            self.flight.record(
                self.now(),
                u32::MAX,
                fkind::DELTA,
                format!("applied={}", n.get()),
            );
        }
    }

    /// Fold leader-side stats — fabric counters and per-shard
    /// replication lag — into the shared registry (absolute stores, so
    /// re-scraping is idempotent). Instance pool stats arrive on their
    /// own heartbeats; this covers everything only the leader sees.
    fn scrape(&self) {
        view::fold_net(&self.obs, &self.fabric.stats());
        for s in 0..self.plane.shard_count() {
            let (head, acks) = self.plane.shard_status(s);
            let lags: Vec<(u32, u64)> = acks
                .iter()
                .map(|&(i, acked)| (i.0, head.saturating_sub(acked)))
                .collect();
            view::fold_replication(&self.obs, s as u32, head, &lags);
        }
        view::fold_trace(&self.obs, &self.trace);
        view::fold_flight(&self.obs, &self.flight);
        // Watchdog feeds (ISSUE 9): heartbeat-miss streaks per live
        // member, and the GS's believed cached-block count per instance
        // (the pool-side `pool.indexed_token_blocks` counterpart rides
        // instance heartbeats; divergence between the two is rule 2).
        let now = self.now();
        let streaks = self.cm.plock().miss_streaks(now);
        for (id, streak) in streaks {
            self.obs
                .set_gauge("hb.miss_streak", Labels::instance(id), streak);
        }
        let roster: Vec<InstanceId> = self
            .instances
            .pread()
            .iter()
            .map(|&(i, _)| i)
            .collect();
        let believed = self.plane.cached_blocks_for(&roster);
        for id in roster {
            self.obs.set_counter(
                "gs.believed_token_blocks",
                Labels::instance(id.0),
                believed.get(&id).copied().unwrap_or(0) as u64,
            );
        }
    }

    /// Watchdog alerts land in the flight recorder (structured, kind
    /// `alert`), and — like the failure detector's dumps — the ring is
    /// persisted only when `MEMSERVE_BENCH_JSON` was explicitly set, so
    /// unit tests never grow a `bench_results/` side effect.
    fn record_alerts(&self, alerts: &[Alert]) {
        for a in alerts {
            log::warn!("watchdog: {} [{}] {}", a.rule, a.subject, a.detail);
            self.flight.record(
                a.at,
                u32::MAX,
                fkind::ALERT,
                format!("{} [{}] {}", a.rule, a.subject, a.detail),
            );
        }
        if !alerts.is_empty() {
            if let Some(dir) = crate::util::bench::explicit_json_dir() {
                if let Some(p) = self.flight.dump_to(&dir, "flight_watchdog")
                {
                    log::warn!("watchdog: flight ring dumped to {p}");
                }
            }
        }
    }

    fn collector(&self, ep: crate::net::Endpoint<Msg>) {
        let mut last_sweep = Instant::now();
        let mut sweeps: u64 = 0;
        loop {
            // Periodic failure sweep (time-gated, runs regardless of
            // message traffic).
            if last_sweep.elapsed() > Duration::from_millis(20) {
                last_sweep = Instant::now();
                let now = self.now();
                sweeps += 1;
                // Cluster scrape every ~25 sweeps (~500ms): fold the
                // leader-side stats into the registry so the merged
                // cluster view stays current without a caller in the
                // loop. Skipped entirely when metrics are off.
                if self.obs.enabled() && sweeps % 25 == 0 {
                    self.scrape();
                    // Timeline + watchdog (ISSUE 9): every scrape feeds
                    // the windowed series; each *closed* frame gets one
                    // invariant pass, and fired alerts go to the flight
                    // recorder. Record-only: nothing here feeds back
                    // into routing.
                    if self.timeline.observe(self.obs.snapshot(now)) {
                        let alerts = self
                            .watchdog
                            .plock()
                            .check(&self.timeline.frames());
                        self.record_alerts(&alerts);
                    }
                }
                let dead = self.cm.plock().sweep(now);
                if !dead.is_empty() {
                    self.on_failure(&dead);
                }
                // GS heartbeat failure detector: shard suspicion →
                // promotion (with retry/backoff), follower liveness.
                self.gs_failure_sweep(now);
                // Global-tree TTL housekeeping: heap-driven, so this is
                // an O(1) peek when nothing is stale (routing also
                // expires opportunistically; this covers idle periods).
                // Shard-local, so each unit expires under its own lock.
                self.plane.expire(now);
            }
            let msg = match ep.recv_timeout(Duration::from_millis(20)) {
                Ok((_, m)) => m,
                Err(NetError::Timeout) => {
                    if self.shutting_down() {
                        return;
                    }
                    continue;
                }
                // The leader's own inbox sender is gone: hard teardown.
                // Distinguishing this from Timeout matters (ISSUE 6
                // satellite) — conflating them would spin this loop at
                // full speed forever.
                Err(_) => return,
            };
            match msg {
                Msg::Token { rid, token, done } => {
                    let mut p = self.shared.pending.plock();
                    if let Some(entry) = p.get_mut(&rid) {
                        entry.tokens.push(token);
                        if done && entry.record.is_none() {
                            // Finished may still follow with metrics.
                        }
                    }
                }
                Msg::Finished {
                    rid,
                    instance,
                    prompt_tokens,
                    cached_tokens,
                    output_tokens,
                    scheduled,
                    first_token_time,
                    completion_time,
                    cached_seq,
                } => {
                    // Retire closes the request's span chain (ISSUE 8)
                    // on the same span the dispatch minted; replayed
                    // Finished messages are dedup'd by the sink.
                    self.trace.complete(
                        trace::request_span(rid),
                        phase::RETIRE,
                        instance.0,
                        completion_time,
                        self.now(),
                    );
                    // Response path: update global prompt trees (Fig 6),
                    // replicated as a Record delta.
                    if !cached_seq.is_empty() {
                        self.gs_apply(DeltaEvent::Record {
                            instance,
                            tokens: cached_seq,
                            now: self.now(),
                        });
                    }
                    {
                        let mut p = self.shared.pending.plock();
                        if let Some(entry) = p.get_mut(&rid) {
                            let rec = RequestRecord {
                                request_id: rid,
                                session_id: entry.session,
                                arrival: entry
                                    .record
                                    .as_ref()
                                    .map(|r| r.arrival)
                                    .unwrap_or(scheduled),
                                scheduled,
                                first_token: first_token_time,
                                completion: completion_time,
                                prompt_tokens,
                                cached_tokens,
                                output_tokens,
                                prefill_instance: entry.dispatched_to.0,
                                decode_instance: instance.0,
                            };
                            self.metrics.plock().push(rec.clone());
                            // Retire-side latency digests (ISSUE 9):
                            // queue wait, TTFT, TBT, and the Eq. 1
                            // predicted-vs-observed prefill error, per
                            // prefill instance. Cheap atomics; gated
                            // inside on `obs.enabled()`.
                            self.attrib.observe_retire(
                                entry.dispatched_to.0,
                                &RetireSample {
                                    arrival: rec.arrival,
                                    scheduled,
                                    first_token: first_token_time,
                                    completion: completion_time,
                                    output_tokens,
                                    predicted_prefill_s: entry
                                        .predicted_prefill_s,
                                },
                            );
                            entry.record = Some(rec);
                            entry.done = true;
                            self.shared.cv.notify_all();
                        }
                    }
                    // Wake any drain waiting out in-flight requests.
                    // Lock order: `pending` is released before `drains`
                    // is taken (the drain waiter holds `drains`, then
                    // briefly `pending`).
                    let _g = self.drains.plock();
                    self.drain_cv.notify_all();
                }
                Msg::Heartbeat { from } => {
                    let now = self.now();
                    self.flight
                        .record(now, from.0, fkind::HEARTBEAT, "");
                    let is_follower = {
                        let mut health = self.gs_health.plock();
                        if health.all_followers.contains(&from) {
                            health.follower_beats.insert(from, now);
                            true
                        } else {
                            false
                        }
                    };
                    if is_follower {
                        // Rejoin-as-follower (ISSUE 6): a beat from a
                        // follower the replication layer dropped wires
                        // it back in; the SnapshotReq bootstrap path
                        // catches its stale cursor up.
                        if !self.plane.is_registered(from) {
                            log::info!("GS follower {from} rejoined");
                            self.plane.register_follower(from);
                            self.plane.flush_all(&self.fabric, LEADER);
                        }
                    } else {
                        self.cm.plock().heartbeat(from, now);
                    }
                }
                Msg::Cached { instance, seq } => {
                    // Response path for prefill-side caching (retire
                    // after handoff, backflow suffix) — keeps prefill
                    // candidates visible to the prompt-tree policy and
                    // gives the migration planner a real inventory.
                    if !seq.is_empty() {
                        self.gs_apply(DeltaEvent::Record {
                            instance,
                            tokens: seq,
                            now: self.now(),
                        });
                    }
                }
                Msg::Evicted { instance, prefixes } => {
                    // Honest local-eviction report: the instance's LRU
                    // dropped these prefixes — retire them from the
                    // global view instead of waiting out the TTL. One
                    // lock acquisition + one follower flush per batch.
                    self.gs_apply_batch(prefixes.into_iter().map(
                        |prefix| DeltaEvent::Expire { instance, prefix },
                    ));
                }
                Msg::MigrateLanded { mid, from, to, tokens } => {
                    // Idempotent under replay (ISSUE 6): a duplicated or
                    // retried ack re-arrives with the same mid — the
                    // first one wins, later copies are dropped whole so
                    // the Handoff delta is never double-applied and the
                    // drain ledger never over-counts.
                    if !self.landed_mids.plock().insert(mid) {
                        log::debug!("dropping replayed MigrateLanded \
                                     mid={mid}");
                        continue;
                    }
                    // Ownership re-points atomically: the receiver gains
                    // the prefix and the donor's claim retires in one
                    // delta — routing never sees it as lost. Empty
                    // tokens (failed/no-op task) only advance progress.
                    let now = self.now();
                    self.trace
                        .end(trace::migration_span(mid), phase::MIGRATE, now);
                    let blocks = tokens.len() / self.geom.block_tokens;
                    self.gs_apply(DeltaEvent::Handoff {
                        from,
                        to,
                        tokens,
                        now,
                    });
                    let mut d = self.drains.plock();
                    if let Some(p) = d.get_mut(&from) {
                        p.outstanding.remove(&mid);
                        p.landed += 1;
                        if blocks > 0 {
                            p.landed_prefixes += 1;
                            p.landed_blocks += blocks;
                        }
                    }
                    self.drain_cv.notify_all();
                }
                Msg::DrainDone { from } => {
                    let mut d = self.drains.plock();
                    if let Some(p) = d.get_mut(&from) {
                        p.done = true;
                    }
                    self.drain_cv.notify_all();
                }
                Msg::DeltaAck { from, shard, next } => {
                    // Coalesced cumulative ack / gap re-request from a
                    // GS follower on one shard's stream: advance (or
                    // rewind) that shard's cursor, ship whatever became
                    // sendable, truncate behind the slowest replica.
                    // Touches that shard's unit only.
                    self.plane
                        .on_ack(shard, from, next, &self.fabric, LEADER);
                }
                Msg::SnapshotReq { from, shard } => {
                    // A follower shard fell behind the retained log (or
                    // joined late): bootstrap it at that shard's
                    // current head. Tree and log are read under one
                    // unit hold so no delta lands in between.
                    let Some(snap) = self.plane.snapshot_for(shard, from)
                    else {
                        continue;
                    };
                    let _ = self
                        .fabric
                        .send(LEADER, from, Msg::Snapshot { shard, snap });
                }
                Msg::Snapshot { shard, snap } => {
                    // Promotion reply: the promoted follower's replica
                    // of ONE shard at its applied sequence. Restore it,
                    // then replay that shard's retained log suffix past
                    // the snapshot — the transport keeps every unacked
                    // entry, so the restored shard carries the FULL
                    // pre-crash ownership state plus everything routed
                    // during the blackout.
                    //
                    // Dedupe (ISSUE 6): Promote re-sends mean a shard
                    // can answer more than once, and fabric duplication
                    // can replay the same reply. Only a shard still
                    // awaiting promotion restores — the second copy is a
                    // no-op.
                    if !self
                        .promote_pending
                        .plock()
                        .contains(&shard)
                    {
                        log::debug!("dropping duplicate promotion \
                                     snapshot for shard {shard}");
                        continue;
                    }
                    // Staleness guard: a late reply from an earlier
                    // (timed-out) promotion round can arrive after
                    // followers acked past its seq and truncation
                    // dropped the prefix. Restoring it would replay
                    // `snap.seq..head` with a silent hole — roll the
                    // shard back and permanently lose the truncated
                    // deltas. The plane restores (and re-warms routing
                    // for the shard's prefix range) only a fresh
                    // snapshot, under one hold of that shard's unit.
                    match self.plane.restore_promoted(shard, &snap) {
                        PromotionRestore::Restored => {}
                        PromotionRestore::Stale => {
                            log::warn!(
                                "ignoring stale promotion snapshot for \
                                 shard {shard} (seq {})",
                                snap.seq,
                            );
                            continue;
                        }
                        PromotionRestore::OutOfRange => continue,
                    }
                    {
                        let mut health = self.gs_health.plock();
                        if let Some(sh) = health.shards.get_mut(shard) {
                            sh.crashed = false;
                            sh.promotion = None;
                            sh.last_beat = self.now();
                        }
                    }
                    let pnow = self.now();
                    self.flight.record(
                        pnow,
                        shard as u32,
                        fkind::PROMOTION,
                        format!("snapshot restored at seq {}", snap.seq),
                    );
                    self.trace.end(
                        trace::promotion_span(shard as u64),
                        phase::PROMOTE,
                        pnow,
                    );
                    let mut pending =
                        self.promote_pending.plock();
                    pending.remove(&shard);
                    self.promote_cv.notify_all();
                }
                Msg::Shutdown => return,
                // Instance/replica-bound traffic that never addresses
                // the leader inbox; enumerated (no `_`) so adding a
                // Msg variant forces a routing decision here.
                Msg::Dispatch { .. }
                | Msg::KvHandoff { .. }
                | Msg::KvBackflow { .. }
                | Msg::MigrateOut { .. }
                | Msg::KvMigrate { .. }
                | Msg::Rewire { .. }
                | Msg::Drain
                | Msg::Membership { .. }
                | Msg::Delta { .. }
                | Msg::Promote { .. } => {
                    log::debug!("leader ignoring instance-bound msg");
                }
            }
        }
    }

    fn shutting_down(&self) -> bool {
        false // replaced by Shutdown message on drop path
    }

    fn on_failure(&self, dead: &[InstanceId]) {
        log::warn!("instances failed: {dead:?}");
        {
            let now = self.now();
            for d in dead {
                self.flight
                    .record(now, d.0, fkind::MEMBERSHIP, "declared dead");
            }
        }
        {
            let mut lc = self.lifecycle.plock();
            for d in dead {
                lc.force_decommission(*d);
            }
        }
        for d in dead {
            // Membership leaves via the replicated delta log (§4.4).
            self.gs_apply(DeltaEvent::Leave { instance: *d });
        }
        let epoch = self.cm.plock().epoch();
        self.flight.record(
            self.now(),
            LEADER.0,
            fkind::FENCE,
            format!("membership epoch {epoch}"),
        );
        let roster = self.instances.pread().clone();
        for &(iid, _) in &roster {
            if !dead.contains(&iid) {
                let _ = self.fabric.send(LEADER, iid, Msg::Membership {
                    epoch,
                    dead: dead.to_vec(),
                });
            }
        }
        // Re-dispatch in-flight requests that were on dead instances —
        // prefill side or decode pairing.
        let retry: Vec<(u64, Vec<u32>, u64, SamplingParams)> = {
            let p = self.shared.pending.plock();
            p.iter()
                .filter(|(_, e)| {
                    !e.done
                        && (dead.contains(&e.dispatched_to)
                            || e.decode_on
                                .is_some_and(|d| dead.contains(&d)))
                })
                .map(|(rid, e)| {
                    (*rid, e.prompt.clone(), e.session, e.sampling)
                })
                .collect()
        };
        // Surviving decode instances must stop backflowing to the dead.
        self.rewire_backflow();
        for (rid, prompt, session, sampling) in retry {
            log::info!("re-dispatching rid={rid} after failure");
            {
                let mut p = self.shared.pending.plock();
                if let Some(e) = p.get_mut(&rid) {
                    e.tokens.clear();
                }
            }
            let _ = self.dispatch(rid, prompt, session, sampling);
        }
    }

    /// Is this instance currently believed alive?
    pub fn is_alive(&self, id: InstanceId) -> bool {
        self.cm.plock().is_alive(id)
    }

    /// Kill an instance (failure injection for tests/examples): detaches
    /// it from the fabric so its heartbeats stop and sends to it fail.
    pub fn kill(&self, id: InstanceId) {
        log::warn!("killing {id} (failure injection)");
        self.fabric.send(LEADER, id, Msg::Shutdown).ok();
        self.fabric.detach(id);
    }

    /// Submit raw text (tokenized by the GS — paper Fig 6 step 1).
    pub fn submit_text(&self, text: &str, session: u64,
                       sampling: SamplingParams) -> Result<u64> {
        let tokens = self.tokenizer.encode_prompt(text);
        self.submit(tokens, session, sampling)
    }

    /// Submit a tokenized prompt; returns the request id.
    pub fn submit(&self, prompt: Vec<u32>, session: u64,
                  sampling: SamplingParams) -> Result<u64> {
        // ordering: SeqCst — rid allocation is off the hot path and
        // rids must be unique across every submitting thread.
        let rid = self.next_rid.fetch_add(1, Ordering::SeqCst);
        {
            let mut p = self.shared.pending.plock();
            let mut rec = RequestRecord::default();
            rec.arrival = self.now();
            p.insert(rid, Pending {
                tokens: vec![],
                record: Some(rec),
                done: false,
                prompt: prompt.clone(),
                session,
                sampling,
                dispatched_to: InstanceId(0),
                decode_on: None,
            });
        }
        // The queue phase spans accept → dispatch send; the route
        // phase (inside it) is completed by `dispatch` itself.
        self.trace.begin(
            trace::request_span(rid),
            phase::QUEUE,
            LEADER.0,
            self.now(),
        );
        self.dispatch(rid, prompt, session, sampling)?;
        Ok(rid)
    }

    fn dispatch(&self, rid: u64, prompt: Vec<u32>, session: u64,
                sampling: SamplingParams) -> Result<()> {
        let now = self.now();
        let roster = self.instances.pread().clone();
        let alive: Vec<InstanceId> = {
            let cm = self.cm.plock();
            roster
                .iter()
                .filter(|(i, _)| cm.is_alive(*i))
                .map(|(i, _)| *i)
                .collect()
        };
        // Loads: in-flight prompt tokens per instance, plus the
        // capacity-pressure estimate from the global tree's cached-
        // block counters (Eq. 1 discounts churning cache holders).
        // Pushed into the routed unit's load book — an unchanged load
        // is an O(1) no-op there, and the capped cold sample reads
        // the book's policy ordering instead of ranking the fleet.
        let queued: DetMap<InstanceId, usize> = {
            let pend = self.shared.pending.plock();
            let mut q: DetMap<InstanceId, usize> = DetMap::default();
            for e in pend.values() {
                if !e.done {
                    *q.entry(e.dispatched_to).or_insert(0) +=
                        e.prompt.len();
                }
            }
            q
        };
        let ids: Vec<InstanceId> = roster.iter().map(|(i, _)| *i).collect();
        // Cached blocks are summed across shards in one plane pass (S
        // short lock holds), before the routed unit's lock is taken.
        let cached = self.plane.cached_blocks_for(&ids);
        let loads: Vec<(InstanceId, InstanceLoad)> = roster
            .iter()
            .map(|&(iid, _)| {
                (iid, InstanceLoad {
                    queued_tokens: queued.get(&iid).copied().unwrap_or(0),
                    queued_cached_ratio: 0.0,
                    running: 0,
                    capacity_pressure: self.pressure_from(
                        cached.get(&iid).copied().unwrap_or(0),
                    ),
                })
            })
            .collect();
        let outcome =
            self.plane.route_request(&prompt, session, now, &loads)?;
        let span = trace::request_span(rid);
        self.trace
            .complete(span, phase::ROUTE, LEADER.0, now, self.now());
        let target = outcome.decision.instance;
        anyhow::ensure!(
            alive.contains(&target),
            "routed to dead instance {target}"
        );
        debug_assert!(
            !self.plane.is_draining(target),
            "routed to draining instance {target}"
        );
        // Decode pairing for prefill-only targets: round-robin over
        // alive, routable (non-draining) decode-only instances.
        let decode_to = if roster
            .iter()
            .any(|(i, k)| *i == target && *k == InstanceKind::PrefillOnly)
        {
            let lc = self.lifecycle.plock();
            let decs: Vec<InstanceId> = roster
                .iter()
                .filter(|(i, k)| {
                    *k == InstanceKind::DecodeOnly
                        && alive.contains(i)
                        && lc.is_routable(*i)
                })
                .map(|(i, _)| *i)
                .collect();
            anyhow::ensure!(!decs.is_empty(), "no decode instances alive");
            // ordering: Relaxed — round-robin cursor; any
            // interleaving is a valid RR order.
            let i = self.decode_rr.fetch_add(1, Ordering::Relaxed) as usize;
            Some(decs[i % decs.len()])
        } else {
            None
        };
        {
            let mut p = self.shared.pending.plock();
            if let Some(e) = p.get_mut(&rid) {
                e.dispatched_to = target;
                e.decode_on = decode_to;
                e.predicted_prefill_s = outcome.expected_prefill_s;
            }
        }
        let req = Request {
            id: rid,
            session,
            prompt,
            sampling,
            arrival: now,
        };
        self.trace.end(span, phase::QUEUE, self.now());
        self.fabric
            .send(LEADER, target, Msg::Dispatch { req, decode_to, span })
            .map_err(|e| anyhow::anyhow!("dispatch: {e}"))?;
        Ok(())
    }

    /// Block until `rid` finishes; returns (generated tokens, record).
    pub fn collect(&self, rid: u64, timeout: Duration)
                   -> Result<(Vec<u32>, RequestRecord)> {
        let deadline = Instant::now() + timeout;
        let mut p = self.shared.pending.plock();
        loop {
            if let Some(e) = p.get(&rid) {
                if e.done {
                    let Some(e) = p.remove(&rid) else {
                        anyhow::bail!("rid {rid} vanished mid-collect");
                    };
                    return Ok((e.tokens, e.record.context("no record")?));
                }
            } else {
                anyhow::bail!("unknown rid {rid}");
            }
            let left = deadline.saturating_duration_since(Instant::now());
            anyhow::ensure!(!left.is_zero(), "collect timeout for {rid}");
            let (guard, _) = self
                .shared
                .cv
                .wait_timeout(p, left.min(Duration::from_millis(100)))
                .unwrap_or_else(PoisonError::into_inner);
            p = guard;
        }
    }

    /// Aggregated metrics over completed requests.
    pub fn metrics(&self) -> Metrics {
        self.metrics.plock().clone()
    }

    pub fn net_stats(&self) -> crate::net::NetStats {
        self.fabric.stats()
    }

    /// The cluster's shared metric registry (enabled unless
    /// `MEMSERVE_METRICS=0`/`off`).
    pub fn obs(&self) -> &Registry {
        &self.obs
    }

    /// The request-scoped trace sink (enabled via `MEMSERVE_TRACE`).
    pub fn trace(&self) -> &TraceSink {
        &self.trace
    }

    /// The control-plane flight recorder (always on; bounded ring).
    pub fn flight(&self) -> &FlightRecorder {
        &self.flight
    }

    /// The windowed time-series (ISSUE 9): frames close on the
    /// collector's ~500ms scrape cadence with 1s windows. Empty while
    /// metrics are disabled. `timeline().to_json()` exports the ring.
    pub fn timeline(&self) -> &Timeline {
        &self.timeline
    }

    /// One merged cluster-wide observability snapshot. Leader-side
    /// stats (fabric, replication lag) are folded in first, so the
    /// view is current as of this call; instance pool stats ride
    /// heartbeats (plus a final fold on instance exit), so they are at
    /// most one heartbeat stale.
    pub fn cluster_view(&self) -> ClusterView {
        self.scrape();
        ClusterView::capture(&self.obs, self.now())
    }

    /// Current roster snapshot (grows on [`Self::join`], shrinks on
    /// [`Self::drain`]).
    pub fn instances(&self) -> Vec<(InstanceId, InstanceKind)> {
        self.instances.pread().clone()
    }

    /// Lifecycle state of an instance (None for unknown ids).
    pub fn lifecycle_state(
        &self,
        id: InstanceId,
    ) -> Option<crate::elastic::InstanceState> {
        self.lifecycle.plock().state(id)
    }

    /// GS replication status, aggregated over shards: (sum of shard log
    /// heads, per-follower summed acked sequences). Per-shard detail:
    /// [`Self::gs_shard_status`].
    pub fn gs_replication_status(&self) -> (u64, Vec<(InstanceId, u64)>) {
        self.plane.replication_status()
    }

    /// One shard's replication status: (log head, per-follower acked).
    pub fn gs_shard_status(&self, shard: usize)
                           -> (u64, Vec<(InstanceId, u64)>) {
        self.plane.shard_status(shard)
    }

    /// Crash the GS primary and fail over to follower replicas
    /// (failure injection; requires `scheduler.gs_replicas > 0`). The
    /// primary's in-memory tree — every shard of it — is discarded:
    /// exactly what a real leader-GS crash loses. Each shard is rebuilt
    /// from cluster membership so routing continues *immediately* (cold
    /// matches, zero request loss) while, PER SHARD, the most-caught-up
    /// follower of that shard's stream is promoted: it replies with a
    /// snapshot of its shard replica, which the leader restores and
    /// tops up from that shard's retained log suffix. Because each
    /// transport retains every entry some replica has not acked, the
    /// restored shards carry the complete pre-crash ownership state —
    /// locality survives the crash (§5's standing assumption, still
    /// enforced under sharding). Shards may promote different
    /// followers. Blocks until every promotion lands or `timeout`;
    /// returns the per-shard promotion targets.
    pub fn fail_gs_primary(&self, timeout: Duration)
                           -> Result<Vec<(usize, InstanceId)>> {
        self.fail_gs_shards(None, timeout)
    }

    /// Shard-addressed failover: crash and re-promote only `shard`
    /// (the other shards keep serving their slices untouched).
    pub fn fail_gs_shard(&self, shard: usize, timeout: Duration)
                         -> Result<Vec<(usize, InstanceId)>> {
        self.fail_gs_shards(Some(shard), timeout)
    }

    fn fail_gs_shards(
        &self,
        only: Option<usize>,
        timeout: Duration,
    ) -> Result<Vec<(usize, InstanceId)>> {
        let targets: Vec<(usize, InstanceId)> = {
            let shards: Vec<usize> = match only {
                Some(s) => {
                    anyhow::ensure!(
                        s < self.plane.shard_count(),
                        "shard {s} out of range (gs_shards = {})",
                        self.plane.shard_count()
                    );
                    vec![s]
                }
                None => (0..self.plane.shard_count()).collect(),
            };
            shards
                .into_iter()
                .map(|s| self.plane.most_caught_up(s).map(|t| (s, t)))
                .collect::<Option<Vec<_>>>()
                .context(
                    "no GS replicas configured (scheduler.gs_replicas)",
                )?
        };
        *self.promote_pending.plock() =
            targets.iter().map(|&(s, _)| s).collect();
        // The crash: ownership state dies with the primary. Membership
        // (and drain visibility) is re-derived from the lifecycle — the
        // GS never owned that. The `instances` roster alone is NOT
        // enough: failed instances are force-decommissioned but stay
        // listed (only drains prune the list), and re-adding one here
        // would resurrect a dead instance as routable for the blackout.
        // Snapshot roster + states first (no nested lock orders), then
        // swap the crashed shards' trees.
        let roster = self.instances.pread().clone();
        let members: Vec<(InstanceId, InstanceKind, bool)> = {
            use crate::elastic::InstanceState;
            let lc = self.lifecycle.plock();
            roster
                .iter()
                .filter_map(|&(iid, kind)| match lc.state(iid) {
                    Some(InstanceState::Active)
                    | Some(InstanceState::Joining) => {
                        Some((iid, kind, false))
                    }
                    Some(InstanceState::Draining) => Some((iid, kind, true)),
                    _ => None, // Decommissioned / unknown: stay gone
                })
                .collect()
        };
        for &(shard, _) in &targets {
            let mut fresh = GlobalPromptTrees::new(
                self.geom.block_tokens,
                self.opts.config.scheduler.tree_ttl_s,
            );
            for &(iid, kind, draining) in &members {
                fresh.add_instance(iid, kind);
                if draining {
                    fresh.set_draining(iid, true);
                }
            }
            self.plane.set_shard_tree(shard, fresh);
        }
        for &(shard, target) in &targets {
            log::warn!(
                "GS shard {shard} crashed (injected); promoting {target}"
            );
            let pnow = self.now();
            self.flight.record(
                pnow,
                shard as u32,
                fkind::FAILOVER,
                format!("promoting {target}"),
            );
            self.trace.begin(
                trace::promotion_span(shard as u64),
                phase::PROMOTE,
                LEADER.0,
                pnow,
            );
            self.fabric
                .send(LEADER, target, Msg::Promote {
                    shard,
                    reply_to: LEADER,
                })
                .map_err(|e| {
                    anyhow::anyhow!("promote {target} (shard {shard}): {e}")
                })?;
        }
        // Waiting with per-shard Promote re-send (ISSUE 6): the request
        // or its Snapshot reply can be dropped by a lossy fabric, so
        // the wait slices and re-sends unanswered promotions with
        // capped exponential backoff. Re-picking most_caught_up each
        // round also heals the case where the original target died.
        let mut retry: HashMap<usize, (u32, f64)> = targets
            .iter()
            .map(|&(s, _)| {
                (s, (0, self.now() + backoff(
                    PROMOTE_RETRY_BASE, PROMOTE_RETRY_CAP, 0,
                )))
            })
            .collect();
        let deadline = Instant::now() + timeout;
        let mut pending = self.promote_pending.plock();
        while !pending.is_empty() {
            let left = deadline.saturating_duration_since(Instant::now());
            anyhow::ensure!(!left.is_zero(), "GS promotion timed out");
            let now = self.now();
            for &shard in pending.iter() {
                let Some((attempt, next_retry)) = retry.get_mut(&shard)
                else {
                    continue;
                };
                if now < *next_retry {
                    continue;
                }
                let target = self.plane.most_caught_up(shard);
                if let Some(t) = target {
                    log::debug!(
                        "re-sending Promote for shard {shard} to {t} \
                         (attempt {})",
                        *attempt + 1
                    );
                    let _ = self.fabric.send(LEADER, t, Msg::Promote {
                        shard,
                        reply_to: LEADER,
                    });
                }
                *attempt += 1;
                *next_retry = now + backoff(
                    PROMOTE_RETRY_BASE, PROMOTE_RETRY_CAP, *attempt,
                );
            }
            let (guard, _) = self
                .promote_cv
                .wait_timeout(pending, left.min(Duration::from_millis(50)))
                .unwrap_or_else(PoisonError::into_inner);
            pending = guard;
        }
        Ok(targets)
    }

    /// Inject a GS shard-primary crash WITHOUT the synchronous failover
    /// of [`Self::fail_gs_shard`] — recovery flows entirely through the
    /// heartbeat failure detector: the shard's liveness beats stop, the
    /// sweep suspects it after `heartbeat_misses` missed windows, marks
    /// its prefix range degraded (router falls back to load-only
    /// placement, keeps serving), and drives the promotion handshake
    /// with re-send backoff until a follower's snapshot lands and the
    /// shard re-warms. The shard's tree is immediately reduced to bare
    /// membership — exactly what the crash loses.
    pub fn inject_gs_shard_crash(&self, shard: usize) -> Result<()> {
        anyhow::ensure!(
            shard < self.plane.shard_count(),
            "shard {shard} out of range (gs_shards = {})",
            self.plane.shard_count()
        );
        anyhow::ensure!(
            !self.plane.followers().is_empty(),
            "no GS replicas configured (scheduler.gs_replicas)"
        );
        let roster = self.instances.pread().clone();
        let members: Vec<(InstanceId, InstanceKind, bool)> = {
            use crate::elastic::InstanceState;
            let lc = self.lifecycle.plock();
            roster
                .iter()
                .filter_map(|&(iid, kind)| match lc.state(iid) {
                    Some(InstanceState::Active)
                    | Some(InstanceState::Joining) => {
                        Some((iid, kind, false))
                    }
                    Some(InstanceState::Draining) => Some((iid, kind, true)),
                    _ => None,
                })
                .collect()
        };
        {
            let mut fresh = GlobalPromptTrees::new(
                self.geom.block_tokens,
                self.opts.config.scheduler.tree_ttl_s,
            );
            for &(iid, kind, draining) in &members {
                fresh.add_instance(iid, kind);
                if draining {
                    fresh.set_draining(iid, true);
                }
            }
            self.plane.set_shard_tree(shard, fresh);
        }
        let mut health = self.gs_health.plock();
        let sh = &mut health.shards[shard];
        sh.crashed = true;
        sh.promotion = None;
        self.flight.record(
            self.now(),
            shard as u32,
            fkind::FAILOVER,
            "injected crash; awaiting heartbeat detection",
        );
        log::warn!(
            "GS shard {shard} crashed (injected); awaiting heartbeat \
             detection"
        );
        Ok(())
    }

    /// Is this shard's prefix range currently degraded (serving via
    /// load-only fallback while its promotion completes)?
    pub fn gs_shard_degraded(&self, shard: usize) -> bool {
        self.plane.is_shard_degraded(shard)
    }

    /// The configured GS follower roster (for fault-plan targeting).
    pub fn gs_follower_ids(&self) -> Vec<InstanceId> {
        self.gs_health.plock().all_followers.clone()
    }

    /// Install a fault plan on the cluster fabric (fault injection for
    /// tests/benches). Replaces any existing plan.
    pub fn install_fault_plan(&self, plan: crate::net::FaultPlan) {
        self.fabric.set_fault_plan(plan);
    }

    /// Remove the fabric fault plan, flushing any held-back messages.
    pub fn clear_fault_plan(&self) {
        self.fabric.clear_fault_plan();
    }

    /// Flush reorder-holdback buffers (quiesce helper for benches).
    pub fn release_held(&self) {
        self.fabric.release_held();
    }

    /// Mutate the installed fault plan in place (partitions:
    /// `isolate`/`heal`). No-op when no plan is installed.
    pub fn with_faults<R>(
        &self,
        f: impl FnOnce(&mut crate::net::FaultPlan) -> R,
    ) -> Option<R> {
        self.fabric.with_faults(f)
    }

    /// The heartbeat failure detector (collector sweep, ~20ms cadence).
    ///
    /// Followers: one whose beats stopped for a full miss window is
    /// deregistered from replication (its retained-log pressure must
    /// not wedge truncation forever); its next beat rejoins it via the
    /// Heartbeat arm.
    ///
    /// Shard primaries: they live in-process, so liveness is a
    /// self-beat this sweep refreshes — unless a crash was injected,
    /// in which case beats stop and detection takes the same
    /// `heartbeat_misses x heartbeat_ms` window a remote primary
    /// would. On suspicion the shard's prefix range is marked degraded
    /// (router serves via load-only fallback) and the promotion
    /// handshake starts, re-sending with capped backoff until the
    /// Snapshot arm lands the promoted replica and clears the state.
    fn gs_failure_sweep(&self, now: f64) {
        let cfgc = &self.opts.config.cluster;
        let window =
            (cfgc.heartbeat_ms / 1e3) * cfgc.heartbeat_misses as f64;
        // Phase 1: follower liveness. Health lock is dropped before the
        // replication lock is taken (lock order: never nested).
        let lapsed: Vec<InstanceId> = {
            let health = self.gs_health.plock();
            health
                .all_followers
                .iter()
                .filter(|f| {
                    let last = health
                        .follower_beats
                        .get(f)
                        .copied()
                        .unwrap_or(0.0);
                    last > 0.0 && now - last > window
                })
                .copied()
                .collect()
        };
        if !lapsed.is_empty() {
            for f in lapsed {
                if self.plane.is_registered(f) {
                    log::warn!(
                        "GS follower {f} missed {} heartbeats; \
                         deregistering",
                        cfgc.heartbeat_misses
                    );
                    self.flight.record(
                        now,
                        f.0,
                        fkind::DEREGISTER,
                        "missed heartbeats",
                    );
                    self.plane.deregister_follower(f);
                }
            }
        }
        // Phase 2: shard-primary suspicion + promotion driving.
        let mut actions: Vec<(usize, u32, bool)> = vec![];
        {
            let mut health = self.gs_health.plock();
            for (s, sh) in health.shards.iter_mut().enumerate() {
                if !sh.crashed {
                    sh.last_beat = now; // in-process self-beat
                    continue;
                }
                match sh.promotion {
                    None => {
                        if now - sh.last_beat > window {
                            actions.push((s, 0, true));
                        }
                    }
                    Some((_, attempt, next_retry)) => {
                        if now >= next_retry {
                            actions.push((s, attempt, false));
                        }
                    }
                }
            }
        }
        for (shard, attempt, first) in actions {
            if first {
                log::warn!(
                    "GS shard {shard} suspected (no beat for \
                     {window:.3}s); degrading its prefix range and \
                     promoting a follower"
                );
                let now = self.now();
                self.flight.record(
                    now,
                    shard as u32,
                    fkind::SUSPICION,
                    format!("no beat for {window:.3}s"),
                );
                self.trace.begin(
                    trace::promotion_span(shard as u64),
                    phase::PROMOTE,
                    LEADER.0,
                    now,
                );
                // The failure detector fired: dump the flight ring to
                // the bench-JSON sink (only when the sink is
                // explicitly configured — tests that trip the
                // detector must not litter the workspace).
                if let Some(dir) = crate::util::bench::explicit_json_dir() {
                    if let Some(p) = self
                        .flight
                        .dump_to(&dir, &format!("flight_shard{shard}"))
                    {
                        log::info!("flight recorder dumped to {p}");
                    }
                }
                self.plane.set_shard_degraded(shard, true);
                self.promote_pending.plock().insert(shard);
            }
            let target = self.plane.most_caught_up(shard);
            if let Some(t) = target {
                let _ = self.fabric.send(LEADER, t, Msg::Promote {
                    shard,
                    reply_to: LEADER,
                });
                let mut health = self.gs_health.plock();
                if let Some(sh) = health.shards.get_mut(shard) {
                    if sh.crashed {
                        sh.promotion = Some((t, attempt + 1, now
                            + backoff(PROMOTE_RETRY_BASE,
                                      PROMOTE_RETRY_CAP, attempt)));
                    }
                }
            } else {
                // No promotable replica yet (all deregistered?) —
                // back off and retry; degraded routing keeps serving.
                let mut health = self.gs_health.plock();
                if let Some(sh) = health.shards.get_mut(shard) {
                    if sh.crashed {
                        sh.promotion =
                            Some((InstanceId(u32::MAX), attempt + 1,
                                  now + backoff(PROMOTE_RETRY_BASE,
                                                PROMOTE_RETRY_CAP,
                                                attempt)));
                    }
                }
            }
        }
    }

    /// Recompute the decode→prefill backflow pairing (round-robin over
    /// routable prefill-only instances) and push it to every routable
    /// decode-only instance. Called after any membership change (drain,
    /// join, failure) so milestone-3 backflow never keeps targeting a
    /// gone instance — and a freshly joined prefill instance starts
    /// receiving its share.
    fn rewire_backflow(&self) {
        let roster = self.instances.pread().clone();
        let (prefills, decodes): (Vec<InstanceId>, Vec<InstanceId>) = {
            let lc = self.lifecycle.plock();
            (
                roster
                    .iter()
                    .filter(|(i, k)| {
                        *k == InstanceKind::PrefillOnly && lc.is_routable(*i)
                    })
                    .map(|(i, _)| *i)
                    .collect(),
                roster
                    .iter()
                    .filter(|(i, k)| {
                        *k == InstanceKind::DecodeOnly && lc.is_routable(*i)
                    })
                    .map(|(i, _)| *i)
                    .collect(),
            )
        };
        for (idx, d) in decodes.iter().enumerate() {
            let target = if prefills.is_empty() {
                None
            } else {
                Some(prefills[idx % prefills.len()])
            };
            let _ = self.fabric.send(LEADER, *d, Msg::Rewire {
                backflow_to: target,
            });
        }
    }

    /// Capacity-pressure estimate from the GS's view: token-blocks the
    /// global tree believes the instance caches (summed across plane
    /// units by the caller), as a fraction of its configured HBM
    /// capacity. An *estimate* — the GS never sees local evictions —
    /// but the same best-effort bound the TTL already leans on (§6
    /// Discussion).
    fn pressure_from(&self, cached_token_blocks: usize) -> f64 {
        let per = self.geom.blocks_per_token_block().max(1);
        let cap = self.opts.config.mempool.hbm_blocks.max(1);
        ((cached_token_blocks * per) as f64 / cap as f64).min(1.0)
    }

    /// Scale up: spawn a fresh instance of `kind` and make it routable.
    /// Lifecycle: `Joining → Active`; the fused tree starts it with an
    /// empty view, so the prompt-tree policy warms it organically (or
    /// migration rebalances onto it).
    pub fn join(&self, kind: InstanceKind) -> Result<InstanceId> {
        // ordering: SeqCst — instance ids must be globally unique;
        // allocation is rare (scale-up only).
        let id = InstanceId(self.next_iid.fetch_add(1, Ordering::SeqCst));
        self.lifecycle
            .plock()
            .join(id, kind)
            .map_err(|e| anyhow::anyhow!("join {id}: {e}"))?;
        let cfgc = &self.opts.config;
        let icfg = InstanceConfig {
            id,
            kind,
            leader: LEADER,
            context_caching: cfgc.mempool.context_caching,
            milestone: self.opts.milestone,
            transfer_mode: cfgc.engine.transfer_mode,
            max_batch: cfgc.engine.max_batch,
            heartbeat_every: Duration::from_secs_f64(
                cfgc.cluster.heartbeat_ms / 1e3,
            ),
            geom: self.geom,
            hbm_blocks: cfgc.mempool.hbm_blocks,
            dram_blocks: cfgc.mempool.dram_blocks,
            index_ttl_s: cfgc.mempool.index_ttl_s,
            // Assigned by the rewire broadcast below, which sees the
            // whole (post-join) fleet through the lifecycle filter.
            backflow_to: None,
            epoch: self.started,
            obs: self.obs.clone(),
            trace: self.trace.clone(),
        };
        let rt = self.runtime.clone();
        let fab = self.fabric.clone();
        let ep = self.fabric.attach(id);
        let h = std::thread::spawn(move || run_instance(icfg, rt, fab, ep));
        self.handles.plock().push(h);
        // Visibility order matters against concurrent dispatches, which
        // snapshot the roster *before* routing: roster + membership
        // first, the scheduler's routing set last — so by the time the
        // tree can choose this instance, every dispatch snapshot
        // already considers it alive.
        self.instances.pwrite().push((id, kind));
        self.cm.plock().register(id, kind, self.now());
        self.lifecycle
            .plock()
            .activate(id)
            .map_err(|e| anyhow::anyhow!("activate {id}: {e}"))?;
        self.gs_apply(DeltaEvent::Join { instance: id, kind });
        self.rewire_backflow();
        log::info!("instance {id} joined as {kind:?}");
        Ok(id)
    }

    /// Scale down gracefully: `Active → Draining → Decommissioned` with
    /// live KV migration. The instance leaves the routing set
    /// immediately; the migration planner ships its hot, deep cached
    /// prefixes to Active peers over the fabric (3-step transfer with
    /// pin-during-transfer); ownership re-points via handoff deltas as
    /// each prefix lands; in-flight requests finish normally; only then
    /// is the instance shut down and removed. Blocks until done or
    /// `timeout`.
    pub fn drain(&self, id: InstanceId, timeout: Duration)
                 -> Result<DrainReport> {
        let kind = self
            .instances
            .pread()
            .iter()
            .find(|(i, _)| *i == id)
            .map(|(_, k)| *k)
            .context("unknown instance")?;
        // Refuse before any state changes: draining the last routable
        // prefill-capable instance would leave nothing to serve (or
        // receive the migration), and draining the last decode peer
        // would strand every prefill-only instance's dispatch.
        if kind.runs_prefill() {
            let lc = self.lifecycle.plock();
            anyhow::ensure!(
                lc.active_where(|k| k.runs_prefill())
                    .iter()
                    .any(|r| *r != id),
                "cannot drain {id}: no Active prefill-capable peer"
            );
        } else {
            let needs_decode = self
                .instances
                .pread()
                .iter()
                .any(|(_, k)| *k == InstanceKind::PrefillOnly);
            if needs_decode {
                let lc = self.lifecycle.plock();
                anyhow::ensure!(
                    lc.active_where(|k| k == InstanceKind::DecodeOnly)
                        .iter()
                        .any(|r| *r != id),
                    "cannot drain {id}: prefill-only instances need a \
                     decode peer"
                );
            }
        }
        self.lifecycle
            .plock()
            .begin_drain(id)
            .map_err(|e| anyhow::anyhow!("drain {id}: {e}"))?;
        let now = self.now();
        // Stop routing to it (replicated — a promoted GS must know the
        // drain state too) and plan while its view is intact.
        self.gs_apply(DeltaEvent::SetDraining {
            instance: id,
            draining: true,
        });
        let plan = {
            let receiver_ids: Vec<InstanceId> = {
                let lc = self.lifecycle.plock();
                lc.active_where(|k| k.runs_prefill())
                    .into_iter()
                    .filter(|r| *r != id)
                    .collect()
            };
            let cached = self.plane.cached_blocks_for(&receiver_ids);
            let recipients: Vec<Recipient> = receiver_ids
                .into_iter()
                .map(|rid| Recipient {
                    id: rid,
                    pressure: self.pressure_from(
                        cached.get(&rid).copied().unwrap_or(0),
                    ),
                })
                .collect();
            self.plane.plan_drain(
                id,
                now,
                &recipients,
                &PlannerConfig::default(),
            )
        };
        let expected = plan.tasks.len();
        // Each task gets a migration id that rides the whole 3-step
        // handshake; the outstanding map is the retry queue — an unacked
        // mid is re-sent (same mid, so receivers dedupe) with capped
        // exponential backoff while the wait loop below runs.
        let mut outstanding = DetMap::default();
        let mut sends = vec![];
        for task in &plan.tasks {
            // ordering: SeqCst — migration ids ride a cross-instance
            // dedupe handshake; uniqueness over speed.
            let mid = self.next_mid.fetch_add(1, Ordering::SeqCst);
            outstanding.insert(mid, MigrateTask {
                to: task.to,
                tokens: task.tokens.clone(),
                attempt: 0,
                next_retry: now
                    + backoff(MIGRATE_RETRY_BASE, MIGRATE_RETRY_CAP, 0),
            });
            sends.push((mid, task.to, task.tokens.clone()));
        }
        self.drains.plock().insert(id, DrainProgress {
            expected,
            outstanding,
            ..Default::default()
        });
        for (mid, to, tokens) in sends {
            self.trace.begin(
                trace::migration_span(mid),
                phase::MIGRATE,
                id.0,
                self.now(),
            );
            self.fabric
                .send(LEADER, id, Msg::MigrateOut { mid, to, tokens })
                .map_err(|e| anyhow::anyhow!("migrate-out: {e}"))?;
        }
        self.fabric
            .send(LEADER, id, Msg::Drain)
            .map_err(|e| anyhow::anyhow!("drain barrier: {e}"))?;
        // Wait: every migration landed, the barrier acked, and no
        // in-flight request still prefilling OR decoding here (zero
        // request loss). Event-driven: the collector signals `drain_cv`
        // on every migration ack, the drain barrier, and every request
        // completion — no polling tick. The condvar pairs with the
        // `drains` mutex; `pending` is only ever taken briefly *inside*
        // that critical section (the collector releases `pending`
        // before touching `drains`, so the order is acyclic and a
        // completion signaled between our check and the wait cannot be
        // lost — the notifier blocks on `drains` until we wait).
        let deadline = Instant::now() + timeout;
        let (landed_prefixes, landed_blocks) = {
            let mut d = self.drains.plock();
            loop {
                let migrated = {
                    let p = d.get(&id).context("drain state lost")?;
                    p.done && p.landed >= p.expected
                };
                let idle = {
                    let pend = self.shared.pending.plock();
                    !pend.values().any(|e| {
                        !e.done
                            && (e.dispatched_to == id
                                || e.decode_on == Some(id))
                    })
                };
                if migrated && idle {
                    let p = d.get(&id).context("drain state lost")?;
                    break (p.landed_prefixes, p.landed_blocks);
                }
                let left = deadline.saturating_duration_since(Instant::now());
                if left.is_zero() {
                    // Abort, don't wedge: restore the instance to
                    // Active. Handoffs already applied stay applied —
                    // the receivers really hold those prefixes; the
                    // donor resumes serving with whatever it still
                    // caches.
                    d.remove(&id);
                    drop(d);
                    self.gs_apply(DeltaEvent::SetDraining {
                        instance: id,
                        draining: false,
                    });
                    let _ = self.lifecycle.plock().abort_drain(id);
                    anyhow::bail!(
                        "drain timeout for {id}: drain aborted, instance \
                         restored to Active"
                    );
                }
                // Self-healing (ISSUE 6): re-send unacked migration
                // tasks past their backoff deadline. A lossy fabric can
                // drop any leg of the handshake; re-sending the same
                // mid is safe end to end (donor re-exports, receiver
                // re-acks from its dedupe window, leader drops the
                // replayed ack above).
                let rnow = self.now();
                if let Some(p) = d.get_mut(&id) {
                    for (mid, task) in p.outstanding.iter_mut() {
                        if rnow < task.next_retry {
                            continue;
                        }
                        log::debug!(
                            "re-sending MigrateOut mid={mid} \
                             (attempt {})",
                            task.attempt + 1
                        );
                        let _ = self.fabric.send(LEADER, id,
                            Msg::MigrateOut {
                                mid: *mid,
                                to: task.to,
                                tokens: task.tokens.clone(),
                            });
                        task.attempt += 1;
                        task.next_retry = rnow + backoff(
                            MIGRATE_RETRY_BASE,
                            MIGRATE_RETRY_CAP,
                            task.attempt,
                        );
                    }
                }
                let (guard, _) = self
                    .drain_cv
                    .wait_timeout(d, left.min(Duration::from_millis(50)))
                    .unwrap_or_else(PoisonError::into_inner);
                d = guard;
            }
        };
        // Decommission: stop the thread, clear membership + ownership.
        // The instance folds its final pool-stat snapshot into the
        // shared registry on its Shutdown path, so its counters
        // survive into the cluster view (ISSUE 8 satellite).
        let _ = self.fabric.send(LEADER, id, Msg::Shutdown);
        self.fabric.detach(id);
        self.flight
            .record(self.now(), id.0, fkind::DEREGISTER, "decommissioned");
        self.cm.plock().deregister(id);
        self.gs_apply(DeltaEvent::Leave { instance: id });
        self.lifecycle
            .plock()
            .decommission(id)
            .map_err(|e| anyhow::anyhow!("decommission {id}: {e}"))?;
        self.instances.pwrite().retain(|(i, _)| *i != id);
        self.drains.plock().remove(&id);
        // Decode instances whose backflow pointed at the drained
        // instance get a surviving target (or None).
        self.rewire_backflow();
        log::info!(
            "instance {id} decommissioned: {landed_prefixes}/{expected} \
             prefixes migrated ({landed_blocks} blocks), {} blocks dropped",
            plan.dropped_blocks
        );
        Ok(DrainReport {
            migrated_prefixes: landed_prefixes,
            migrated_blocks: landed_blocks,
            planned_prefixes: expected,
            dropped_blocks: plan.dropped_blocks,
            replicated_blocks: plan.replicated_blocks,
        })
    }

    /// Graceful shutdown: stop instances, GS followers, the collector.
    pub fn shutdown(&self) {
        let roster = self.instances.pread().clone();
        for &(iid, _) in &roster {
            let _ = self.fabric.send(LEADER, iid, Msg::Shutdown);
        }
        let followers = self.plane.followers();
        for fid in followers {
            let _ = self.fabric.send(LEADER, fid, Msg::Shutdown);
        }
        let _ = self.fabric.send(LEADER, LEADER, Msg::Shutdown);
        let handles = std::mem::take(&mut *self.handles.plock());
        for h in handles {
            let _ = h.join();
        }
    }
}
