//! The live serving assembly: leader + instance threads over the fabric,
//! with real PJRT compute on every request path (Python never runs here).
//!
//! Topology mirrors Figure 1: a leader thread hosts the global scheduler
//! (tokenize → global-tree match → policy → dispatch) and the cluster
//! manager (heartbeats, failure sweeps); each inference instance is a
//! thread owning an [`crate::engine::Engine`] (MemPool + shared
//! `ModelRuntime`). Disaggregated KV movement uses the one-shot
//! `transfer_with_insert` form (receiver-side on-demand allocation —
//! Table 1 `flags`); the pre-negotiated-address handshake of Fig 2 is
//! exercised by the transfer-mode benches and the simulator.

pub mod data_plane;
pub mod instance;
pub mod leader;
pub mod message;
pub mod replica;

pub use leader::{ClientHandle, DrainReport, ServeCluster, ServeOptions};
pub use message::Msg;
