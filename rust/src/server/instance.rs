//! Instance thread loops: colocated / prefill-only / decode-only roles
//! composed from [`crate::engine::Engine`] primitives.

use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::engine::kv as kvops;
use crate::engine::{
    ActiveDecodeSet, DisaggMilestone, Engine, EngineOptions,
};
use crate::engine::core::ActiveDecode;
use crate::mempool::{BlockGeometry, InstanceId, MemPool, TransferMode};
use crate::net::fabric::NetError;
use crate::net::{Endpoint, Fabric};
use crate::obs::{trace::phase, view, AttribBook, Registry, TraceSink};
use crate::runtime::ModelRuntime;
use crate::scheduler::prompt_tree::InstanceKind;
use crate::server::message::Msg;

pub struct InstanceConfig {
    pub id: InstanceId,
    pub kind: InstanceKind,
    pub leader: InstanceId,
    pub context_caching: bool,
    pub milestone: DisaggMilestone,
    pub transfer_mode: TransferMode,
    pub max_batch: usize,
    pub heartbeat_every: Duration,
    pub geom: BlockGeometry,
    pub hbm_blocks: usize,
    pub dram_blocks: usize,
    pub index_ttl_s: f64,
    /// Where this decode instance returns decode KV (milestone 3);
    /// by leader convention, its paired prefill instance.
    pub backflow_to: Option<InstanceId>,
    /// Cluster-wide clock epoch (shared with the leader so timestamps
    /// are comparable across threads).
    pub epoch: Instant,
    /// Shared metric registry (ISSUE 8): the instance folds its pool
    /// stats in on every heartbeat and on exit, so the leader's
    /// cluster view keeps the last snapshot even if this thread dies.
    pub obs: Registry,
    /// Shared trace sink; instance-side phases (prefill, kv_transfer
    /// landing, decode) close on the span carried by the dispatch.
    pub trace: TraceSink,
}

/// Run one instance until `Shutdown`. Designed to be spawned on its own
/// thread; owns its Engine (pool + shared runtime).
pub fn run_instance(
    cfg: InstanceConfig,
    runtime: Arc<ModelRuntime>,
    fabric: Fabric<Msg>,
    endpoint: Endpoint<Msg>,
) {
    let pool = MemPool::new(
        cfg.id,
        cfg.geom,
        cfg.hbm_blocks,
        cfg.dram_blocks,
        cfg.index_ttl_s,
        true,
    );
    let caching = cfg.context_caching
        && match cfg.kind {
            InstanceKind::Colocated => true,
            InstanceKind::PrefillOnly => cfg.milestone.prefill_caches(),
            InstanceKind::DecodeOnly => cfg.milestone.decode_caches(),
        };
    let mut engine = Engine::new(
        runtime,
        pool,
        EngineOptions {
            context_caching: caching,
            max_batch: cfg.max_batch,
        },
    );
    let epoch = cfg.epoch;
    let now = move || epoch.elapsed().as_secs_f64();
    // Phase-duration digests (ISSUE 9): prefill/kv_transfer/decode
    // seconds observed at each phase close, labeled by this instance.
    // Cheap atomics on a shared registry; no-ops when metrics are off.
    let attrib = AttribBook::new(&cfg.obs);
    let mut active = ActiveDecodeSet::default();
    let mut last_beat = Instant::now();
    let mut rr = 0usize; // round-robin cursor over active decodes
    // Landed-migration dedupe window (ISSUE 6): mid -> acked tokens.
    // A duplicated/retried KvMigrate re-acks instead of re-landing.
    let mut landed: std::collections::VecDeque<(u64, Vec<u32>)> =
        std::collections::VecDeque::new();
    const LANDED_WINDOW: usize = 64;
    // Decode→prefill backflow target; the leader re-points it on
    // membership changes (drain/join/failure) via Msg::Rewire.
    let mut backflow_to = cfg.backflow_to;

    loop {
        // Heartbeat (plus the heartbeat-cadence metric scrape: pool
        // stats fold into the shared registry under this instance's
        // label — absolute stores, so re-folding is idempotent).
        if last_beat.elapsed() >= cfg.heartbeat_every {
            let _ = fabric.send(cfg.id, cfg.leader, Msg::Heartbeat {
                from: cfg.id,
            });
            view::fold_pool(&cfg.obs, cfg.id.0, &engine.pool.stats());
            view::fold_pool_index(
                &cfg.obs, cfg.id.0, engine.pool.indexed_token_blocks(),
            );
            last_beat = Instant::now();
        }
        // Drain the inbox (non-blocking while there is decode work).
        let msg = if active.is_empty() {
            match endpoint.recv_timeout(cfg.heartbeat_every / 2) {
                Ok((_, m)) => Some(m),
                Err(NetError::Timeout) => None,
                // Our own inbox sender is gone: the leader detached us
                // (decommission/kill). Exit now instead of spinning on
                // a dead channel until shutdown (ISSUE 6 satellite —
                // Disconnected is not a timeout). Fold a final stats
                // snapshot first so a killed instance's counters reach
                // the cluster view (ISSUE 8 counter-loss fix).
                Err(_) => {
                    view::fold_pool(&cfg.obs, cfg.id.0, &engine.pool.stats());
                    view::fold_pool_index(
                        &cfg.obs, cfg.id.0, engine.pool.indexed_token_blocks(),
                    );
                    return;
                }
            }
        } else {
            endpoint.try_recv().map(|(_, m)| m)
        };
        match msg {
            Some(Msg::Shutdown) => {
                view::fold_pool(&cfg.obs, cfg.id.0, &engine.pool.stats());
                view::fold_pool_index(
                    &cfg.obs, cfg.id.0, engine.pool.indexed_token_blocks(),
                );
                return;
            }
            Some(Msg::Dispatch { req, decode_to, span }) => {
                handle_dispatch(
                    &cfg, &attrib, &mut engine, &fabric, &mut active, req,
                    decode_to, span, now(),
                );
            }
            Some(Msg::KvHandoff {
                req,
                payload,
                n_blocks,
                prompt_len,
                cached_tokens,
                scheduled,
                first_token_time,
                logits,
                insert,
                span,
                ..
            }) => {
                handle_handoff(
                    &cfg, &attrib, &mut engine, &fabric, &mut active, req,
                    payload, n_blocks, prompt_len, cached_tokens, scheduled,
                    first_token_time, logits, insert, span, now(),
                );
            }
            Some(Msg::KvBackflow {
                seq,
                payload,
                n_blocks,
                suffix_start_block,
                ..
            }) => {
                // transfer_with_insert receive path (step 5 landing).
                let t = now();
                if let Ok(groups) = import_groups(
                    &mut engine, &payload, n_blocks, t,
                ) {
                    if matches!(
                        engine.insert_suffix(
                            &seq, groups, suffix_start_block, t,
                        ),
                        Ok(true)
                    ) {
                        let _ = fabric.send(cfg.id, cfg.leader, Msg::Cached {
                            instance: cfg.id,
                            seq,
                        });
                    }
                }
            }
            Some(Msg::MigrateOut { mid, to, tokens }) => {
                handle_migrate_out(
                    &cfg, &mut engine, &fabric, mid, to, &tokens, now(),
                );
            }
            Some(Msg::KvMigrate {
                mid,
                from,
                tokens,
                payload,
                n_blocks,
                ..
            }) => {
                // Receiver half of the migration transfer
                // (`elastic::executor::land_prefix`: on-demand alloc,
                // land, transfer_with_insert), then ack the leader so it
                // applies the ownership handoff. On failure the ack
                // carries no tokens so the drain driver is not left
                // waiting. Duplicates (fabric replay or donor retry
                // after a lost ack) re-ack from the dedupe window
                // without touching the pool.
                let t = now();
                let ack_tokens = if let Some((_, acked)) =
                    landed.iter().find(|(m, _)| *m == mid)
                {
                    acked.clone()
                } else {
                    let already = crate::elastic::executor::holds_prefix(
                        &mut engine.pool,
                        &tokens,
                        t,
                    );
                    let result = if already {
                        Ok(())
                    } else {
                        crate::elastic::executor::land_prefix(
                            &mut engine.pool,
                            &tokens,
                            &payload,
                            n_blocks,
                            t,
                        )
                    };
                    let acked = match result {
                        Ok(()) => tokens,
                        Err(e) => {
                            log::error!("migrate land: {e:#}");
                            vec![]
                        }
                    };
                    if landed.len() >= LANDED_WINDOW {
                        landed.pop_front();
                    }
                    landed.push_back((mid, acked.clone()));
                    acked
                };
                let _ = fabric.send(cfg.id, cfg.leader, Msg::MigrateLanded {
                    mid,
                    from,
                    to: cfg.id,
                    tokens: ack_tokens,
                });
            }
            Some(Msg::Rewire { backflow_to: b }) => {
                backflow_to = b;
            }
            Some(Msg::Drain) => {
                // Fabric channels are FIFO per sender: every MigrateOut
                // the leader queued before this marker has been handled
                // above, so this ack is the migration barrier.
                let _ = fabric.send(cfg.id, cfg.leader, Msg::DrainDone {
                    from: cfg.id,
                });
            }
            Some(Msg::Membership { dead, .. }) => {
                // §4.4: release anything owned by dead peers. Local pools
                // hold only local blocks, so this is bookkeeping today;
                // in-flight requests to dead peers fail at send and are
                // retried by the leader.
                for d in dead {
                    engine.pool.release_remote(d);
                }
            }
            // Leader- or replica-bound traffic; enumerated (no `_`)
            // so a new Msg variant forces a routing decision here.
            Some(
                Msg::Token { .. }
                | Msg::Finished { .. }
                | Msg::Heartbeat { .. }
                | Msg::Cached { .. }
                | Msg::MigrateLanded { .. }
                | Msg::DrainDone { .. }
                | Msg::Evicted { .. }
                | Msg::Delta { .. }
                | Msg::DeltaAck { .. }
                | Msg::SnapshotReq { .. }
                | Msg::Snapshot { .. }
                | Msg::Promote { .. },
            ) => {
                log::debug!("instance {} ignoring peer-bound msg", cfg.id);
            }
            None => {}
        }

        // Honest-eviction reporting: whatever the pool's LRU dropped
        // since the last loop turn goes to the leader as Expire-shaped
        // prefixes, so global-tree routing stops counting on KV this
        // instance no longer holds (replacing TTL guessing end to end).
        let evicted = engine.pool.take_evicted_prefixes();
        if !evicted.is_empty() {
            let _ = fabric.send(cfg.id, cfg.leader, Msg::Evicted {
                instance: cfg.id,
                prefixes: evicted,
            });
        }

        // One decode iteration (round-robin one request per loop so the
        // inbox stays responsive — iteration-level scheduling).
        if !active.is_empty() {
            rr %= active.len();
            let finished = {
                let a = &mut active.jobs[rr];
                match engine.step(a) {
                    Ok(outcome) => {
                        let done = matches!(
                            outcome,
                            crate::engine::StepOutcome::Finished(_)
                        );
                        if let Some(&tok) = a.generated.last() {
                            let _ = fabric.send(
                                cfg.id,
                                cfg.leader,
                                Msg::Token {
                                    rid: a.req.id,
                                    token: tok,
                                    done,
                                },
                            );
                        } else {
                            debug_assert!(false, "step made no token");
                        }
                        done
                    }
                    Err(e) => {
                        log::error!("decode step failed: {e:#}");
                        true
                    }
                }
            };
            if finished {
                let a = active.jobs.swap_remove(rr);
                finish_decode(
                    &cfg, &attrib, &mut engine, &fabric, a, backflow_to,
                    now(),
                );
            } else {
                rr += 1;
            }
        }
    }
}

fn import_groups(
    engine: &mut Engine,
    payload: &[f32],
    n_blocks: usize,
    now: f64,
) -> anyhow::Result<crate::mempool::GroupList> {
    let per = engine.pool.geometry().blocks_per_token_block();
    let addrs = engine.pool.import_blocks(
        payload,
        n_blocks,
        None,
        crate::mempool::Tier::Hbm,
        now,
    )?;
    let mut groups = crate::mempool::GroupList::default();
    for c in addrs.chunks(per) {
        groups.push_group(c);
    }
    Ok(groups)
}

/// Donor half of one migration task — [`crate::elastic::executor::
/// export_prefix`] (pin-during-transfer, DRAM swap-in, serialize) plus
/// the fabric ship. On any failure — including holding none of the
/// prefix — the leader is acked directly with an empty
/// [`Msg::MigrateLanded`] so drain progress never stalls.
fn handle_migrate_out(
    cfg: &InstanceConfig,
    engine: &mut Engine,
    fabric: &Fabric<Msg>,
    mid: u64,
    to: InstanceId,
    tokens: &[u32],
    t: f64,
) {
    let mut sent = false;
    match crate::elastic::executor::export_prefix(&mut engine.pool, tokens, t)
    {
        Ok(Some(e)) => {
            let calls = cfg
                .transfer_mode
                .network_calls(engine.pool.geometry(), e.tokens)
                .max(1);
            let msg = Msg::KvMigrate {
                mid,
                from: cfg.id,
                tokens: tokens[..e.tokens].to_vec(),
                payload: e.payload,
                n_blocks: e.n_blocks,
                calls,
            };
            match fabric.send(cfg.id, to, msg) {
                Ok(_) => sent = true,
                Err(e) => log::warn!("migrate to {to} failed: {e}"),
            }
        }
        Ok(None) => {}
        Err(e) => log::error!("migrate export: {e:#}"),
    }
    if !sent {
        let _ = fabric.send(cfg.id, cfg.leader, Msg::MigrateLanded {
            mid,
            from: cfg.id,
            to,
            tokens: vec![],
        });
    }
}

#[allow(clippy::too_many_arguments)]
fn handle_dispatch(
    cfg: &InstanceConfig,
    attrib: &AttribBook,
    engine: &mut Engine,
    fabric: &Fabric<Msg>,
    active: &mut ActiveDecodeSet,
    req: crate::engine::Request,
    decode_to: Option<InstanceId>,
    span: u64,
    t: f64,
) {
    let scheduled = t;
    cfg.trace.begin(span, phase::PREFILL, cfg.id.0, t);
    let pf = match engine.prefill(&req.prompt, t) {
        Ok(pf) => pf,
        Err(e) => {
            log::error!("prefill failed rid={}: {e:#}", req.id);
            let _ = fabric.send(cfg.id, cfg.leader, Msg::Token {
                rid: req.id,
                token: crate::tokenizer::EOS,
                done: true,
            });
            return;
        }
    };
    let prefill_end = cfg.epoch.elapsed().as_secs_f64();
    cfg.trace.end(span, phase::PREFILL, prefill_end);
    attrib.observe_phase_secs(cfg.id.0, phase::PREFILL, prefill_end - t);
    match decode_to {
        None => {
            // Colocated: first token + local decode.
            let rid = req.id;
            match engine.start_decode(req, pf) {
                Ok(a) => {
                    cfg.trace.begin(
                        span,
                        phase::DECODE,
                        cfg.id.0,
                        cfg.epoch.elapsed().as_secs_f64(),
                    );
                    let _ = fabric.send(cfg.id, cfg.leader, Msg::Token {
                        rid,
                        token: a.pending_token,
                        done: false,
                    });
                    let mut a = a;
                    a.scheduled = scheduled;
                    a.first_token_time =
                        t.max(scheduled); // prefill emitted now
                    active.jobs.push(a);
                }
                Err(e) => log::error!("start_decode rid={rid}: {e:#}"),
            }
        }
        Some(d) => {
            // Disaggregated: export the full prompt KV, hand off, retire
            // locally (milestone step 2 caches at P).
            let first_token_time = t;
            let mut groups = pf.prefix_groups.clone();
            groups.extend_list(&pf.new_groups);
            let flat = groups.flat();
            let payload = match engine.pool.export_blocks(flat) {
                Ok(p) => p,
                Err(e) => {
                    log::error!("export failed: {e:#}");
                    return;
                }
            };
            let calls = cfg
                .transfer_mode
                .network_calls(engine.pool.geometry(), pf.prompt_len);
            let msg = Msg::KvHandoff {
                payload,
                n_blocks: flat.len(),
                prompt_len: pf.prompt_len,
                cached_tokens: pf.cached_tokens,
                scheduled,
                first_token_time,
                logits: pf.logits.clone(),
                calls,
                insert: cfg.milestone.decode_caches(),
                req: req.clone(),
                span,
            };
            cfg.trace.begin(
                span,
                phase::KV_TRANSFER,
                cfg.id.0,
                cfg.epoch.elapsed().as_secs_f64(),
            );
            if let Err(e) = fabric.send(cfg.id, d, msg) {
                log::error!("handoff to {d} failed: {e}");
            }
            match engine.retire_prefill(&req.prompt, pf, t) {
                Ok(()) => {
                    // Response path (Fig 6): tell the GS this prefill
                    // instance now caches the prompt — the prompt-tree
                    // policy and drain-time migration both read this.
                    let _ = fabric.send(cfg.id, cfg.leader, Msg::Cached {
                        instance: cfg.id,
                        seq: req.prompt.clone(),
                    });
                }
                Err(e) => log::error!("retire_prefill: {e:#}"),
            }
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn handle_handoff(
    cfg: &InstanceConfig,
    attrib: &AttribBook,
    engine: &mut Engine,
    fabric: &Fabric<Msg>,
    active: &mut ActiveDecodeSet,
    req: crate::engine::Request,
    payload: Vec<f32>,
    n_blocks: usize,
    prompt_len: usize,
    cached_tokens: usize,
    scheduled: f64,
    first_token_time: f64,
    logits: Vec<f32>,
    _insert: bool,
    span: u64,
    t: f64,
) {
    let groups = match import_groups(engine, &payload, n_blocks, t) {
        Ok(g) => g,
        Err(e) => {
            log::error!("import failed rid={}: {e:#}", req.id);
            return;
        }
    };
    // The prompt KV has landed in this decode instance's pool: the
    // wire transfer the prefill side opened is over. (A duplicated
    // handoff replays this close; the sink is idempotent.)
    let kv_end = cfg.epoch.elapsed().as_secs_f64();
    cfg.trace.end(span, phase::KV_TRANSFER, kv_end);
    // Transfer time = first-token (prefill done, export shipped) →
    // landed here; observed on the *receiving* instance's label.
    attrib.observe_phase_secs(
        cfg.id.0,
        phase::KV_TRANSFER,
        (kv_end - first_token_time).max(0.0),
    );
    let rid = req.id;
    match engine.start_decode_from_blocks(req, groups, prompt_len, logits, 0)
    {
        Ok(mut a) => {
            a.cached_tokens = cached_tokens;
            a.scheduled = scheduled;
            a.first_token_time = first_token_time;
            cfg.trace.begin(
                span,
                phase::DECODE,
                cfg.id.0,
                cfg.epoch.elapsed().as_secs_f64(),
            );
            let _ = fabric.send(cfg.id, cfg.leader, Msg::Token {
                rid,
                token: a.pending_token,
                done: false,
            });
            active.jobs.push(a);
        }
        Err(e) => log::error!("start_decode_from_blocks rid={rid}: {e:#}"),
    }
}

fn finish_decode(
    cfg: &InstanceConfig,
    attrib: &AttribBook,
    engine: &mut Engine,
    fabric: &Fabric<Msg>,
    mut a: ActiveDecode,
    backflow_to: Option<InstanceId>,
    t: f64,
) {
    let rid = a.req.id;
    let prompt_tokens = a.req.prompt.len();
    let cached_tokens = a.cached_tokens;
    let output_tokens = a.generated.len();
    let scheduled = a.scheduled;
    let first_token_time = a.first_token_time;
    let prompt_len = a.prompt_len;
    let consumed = a.sess.pos;

    // Milestone 3: ship the decode-produced KV suffix back to a prefill
    // instance BEFORE retiring (retire consumes the session).
    let backflow = if cfg.kind == InstanceKind::DecodeOnly
        && cfg.milestone.decode_to_prefill()
    {
        let bt = engine.pool.geometry().block_tokens;
        let full_prompt_blocks = prompt_len / bt;
        let total_full_blocks = consumed / bt;
        if total_full_blocks > full_prompt_blocks {
            let from = full_prompt_blocks * bt;
            let to = total_full_blocks * bt;
            match engine.runtime.decode_kv(&mut a.sess) {
                Ok(kv_host) => {
                    let geom = *engine.pool.geometry();
                    let tail = kvops::slice_tokens(
                        &geom, &kv_host, a.sess.ctx, from, to,
                    );
                    let mut seq = a.req.prompt.clone();
                    seq.extend_from_slice(
                        &a.generated[..consumed - prompt_len],
                    );
                    Some((seq, tail, (to - from) / bt, full_prompt_blocks))
                }
                Err(e) => {
                    log::error!("decode_kv for backflow: {e:#}");
                    None
                }
            }
        } else {
            None
        }
    } else {
        None
    };

    let cached_seq = match engine.retire(a, t) {
        Ok(seq) => seq,
        Err(e) => {
            log::error!("retire rid={rid}: {e:#}");
            vec![]
        }
    };

    if let Some((seq, tail, n_token_blocks, suffix_start)) = backflow {
        // Re-pack the tail into block-layout payload (bucket = tail len).
        let n_tokens = n_token_blocks * engine.pool.geometry().block_tokens;
        let geom = *engine.pool.geometry();
        let per = geom.blocks_per_token_block();
        let payload = pack_payload(&geom, &tail, n_tokens);
        let calls = cfg
            .transfer_mode
            .network_calls(&geom, n_tokens)
            .max(1);
        let msg = Msg::KvBackflow {
            seq,
            payload,
            n_blocks: n_token_blocks * per,
            suffix_start_block: suffix_start,
            calls,
        };
        // Target: the leader-designated paired prefill instance
        // (rewired live on membership changes).
        if let Some(p) = backflow_to {
            if let Err(e) = fabric.send(cfg.id, p, msg) {
                log::warn!("backflow to {p} failed: {e}");
            }
        }
    }

    // Request spans are the request id by construction (the leader
    // mints them with `trace::request_span`), so the decode close does
    // not need the span threaded through `ActiveDecode`.
    cfg.trace.end(
        crate::obs::trace::request_span(rid),
        phase::DECODE,
        t,
    );
    attrib.observe_phase_secs(
        cfg.id.0,
        phase::DECODE,
        (t - first_token_time).max(0.0),
    );
    let _ = fabric.send(cfg.id, cfg.leader, Msg::Finished {
        rid,
        instance: cfg.id,
        prompt_tokens,
        cached_tokens,
        output_tokens,
        scheduled,
        first_token_time,
        completion_time: t,
        cached_seq,
    });
}

/// Pack a contiguous `[L,2,n,H,hd]` tail into the block-export layout
/// (the same layout `export_blocks` produces) without round-tripping
/// through the pool: scatter into a scratch pool then export would cost
/// an alloc; direct repack is equivalent.
fn pack_payload(geom: &BlockGeometry, tail: &[f32], n_tokens: usize)
                -> Vec<f32> {
    let s = geom.n_heads * geom.head_dim;
    let bt = geom.block_tokens;
    let n_blocks = n_tokens / bt;
    let fpb = geom.floats_per_block();
    let per = geom.blocks_per_token_block();
    let mut out = vec![0f32; n_blocks * per * fpb];
    for b in 0..n_blocks {
        for l in 0..geom.layers {
            for h in 0..2 {
                for t in 0..bt {
                    let tok = b * bt + t;
                    let src = ((l * 2 + h) * n_tokens + tok) * s;
                    let dst = if geom.aggregated {
                        b * fpb + ((l * 2 + h) * bt + t) * s
                    } else {
                        (b * per + l * 2 + h) * fpb + t * s
                    };
                    out[dst..dst + s].copy_from_slice(&tail[src..src + s]);
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pack_payload_matches_export_layout() {
        use crate::mempool::MemPool;
        let geom = BlockGeometry {
            block_tokens: 4,
            layers: 2,
            n_heads: 2,
            head_dim: 3,
            aggregated: true,
        };
        let mut rng = crate::util::rng::Rng::new(1);
        let n_tokens = 8;
        let s = geom.n_heads * geom.head_dim;
        let tail: Vec<f32> = (0..geom.layers * 2 * n_tokens * s)
            .map(|_| rng.f64() as f32)
            .collect();
        // Reference: scatter into a pool then export.
        let mut pool =
            MemPool::new(InstanceId(0), geom, 8, 0, 0.0, true);
        let groups = crate::engine::kv::scatter_new_kv(
            &mut pool, &tail, n_tokens, n_tokens, 0.0,
        )
        .unwrap();
        let flat: Vec<_> = groups.iter().flatten().copied().collect();
        let expect = pool.export_blocks(&flat).unwrap();
        let got = pack_payload(&geom, &tail, n_tokens);
        assert_eq!(got, expect);
    }
}
