//! The leader's sharded GS data plane (ISSUE 7 tentpole, server half).
//!
//! PR 4/5 sharded the *state* — one `FusedPromptTree` + one
//! `DeltaTransport` per prefix-range shard — but the leader still
//! serialized every route and every delta through one
//! `Mutex<GlobalScheduler>` plus one `Mutex<GsReplication>`. This
//! module pins each shard's tree AND its replication log together in
//! one [`GsUnit`] behind its own lock (the ISSUE's sharded-lock
//! fallback of the per-core worker design; the worker-thread variant
//! lives in [`crate::scheduler::data_plane`]):
//!
//! ```text
//!   dispatch / Record / Expire / Handoff / DeltaAck   (shard-keyed)
//!        │ ShardMap: first-block fingerprint → unit k
//!        ▼
//!   ┌──────────────┐ ┌──────────────┐     ┌──────────────┐
//!   │ Mutex<GsUnit>│ │ Mutex<GsUnit>│ ... │ Mutex<GsUnit>│
//!   │  gs (1-shard)│ │  gs (1-shard)│     │  gs (1-shard)│
//!   │  log (shard) │ │  log (shard) │     │  log (shard) │
//!   └──────────────┘ └──────────────┘     └──────────────┘
//!        ▲ Join/Leave/SetDraining/whole-view Expire: epoch-fenced
//!        │ broadcast — bump `all_epoch`, lock ALL units in ascending
//!        │ order, apply + append everywhere, release together.
//! ```
//!
//! Writes now scale by shards: a route or a prefix-keyed delta takes
//! exactly one unit lock, so S shards serve S disjoint prefix ranges
//! concurrently instead of convoying on the global mutex.
//!
//! **Invariants.**
//! * *Per-shard order.* A unit's tree-apply order and its log-append
//!   order are the same order — both happen under one hold of that
//!   unit's lock. Followers replay per-shard streams, so this is the
//!   only order replication correctness needs.
//! * *Epoch-fenced broadcasts.* Cross-shard events (membership, drain
//!   toggles, whole-view expiries) take every unit lock in ascending
//!   index order — the fence — so all shards observe the event at a
//!   single cut of their streams and two concurrent broadcasts cannot
//!   interleave differently on different shards (a Leave/SetDraining
//!   pair must agree everywhere). `all_epoch` numbers the fences.
//! * *Lock order.* `followers` roster before any unit; units strictly
//!   ascending; never acquire the roster while holding a unit. Fabric
//!   sends happen with NO plane lock held (a `real_sleep` fabric
//!   actually sleeps on the wire — routing must not wait on it).
//! * *Registry agreement.* Every unit's 1-shard scheduler carries the
//!   full instance registry (broadcasts fan to all units), so any unit
//!   can answer registry reads (`is_draining`) and a one-unit route
//!   still considers every routable instance — which is exactly why a
//!   unit's decisions are bit-identical to the monolithic scheduler's
//!   for prompts of its shard (pinned by tests below and by the
//!   fig15 `threads` mode).

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, MutexGuard};

use anyhow::Result;

use crate::elastic::delta::DeltaEvent;
use crate::elastic::planner::{
    plan_migration_from, MigrationPlan, PlannerConfig, Recipient,
};
use crate::mempool::InstanceId;
use crate::net::Fabric;
use crate::replica::log::DeltaTransport;
use crate::replica::snapshot::TreeSnapshot;
use crate::scheduler::prompt_tree::GlobalPromptTrees;
use crate::scheduler::router::{
    GlobalScheduler, InstanceLoad, RouteOutcome,
};
use crate::scheduler::shard::{ShardMap, ShardRoute};
use crate::server::message::Msg;
use crate::server::replica::GS_WINDOW;
use crate::util::rng::DetMap;
use crate::util::sync::LockExt;

/// One shard's slice of the data plane: its 1-shard scheduler (tree +
/// load book) and its sequenced replication log, locked together so
/// apply order and log order can never invert.
pub struct GsUnit {
    pub gs: GlobalScheduler,
    pub log: DeltaTransport,
}

/// What [`GsDataPlane::restore_promoted`] did with a promotion
/// snapshot.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PromotionRestore {
    /// Snapshot restored and topped up from the retained log suffix.
    Restored,
    /// Snapshot predates the retained log — replaying would leave a
    /// silent hole; dropped.
    Stale,
    /// Shard index out of range; dropped.
    OutOfRange,
}

pub struct GsDataPlane {
    units: Vec<Mutex<GsUnit>>,
    map: ShardMap,
    /// Replication roster, shared by every unit's log. Lock order:
    /// before any unit lock; snapshot-and-release on hot paths.
    followers: Mutex<Vec<InstanceId>>,
    /// Fence counter: bumped once per cross-shard broadcast.
    all_epoch: AtomicU64,
    ttl: f64,
}

impl GsDataPlane {
    /// Build the plane from per-shard 1-shard schedulers (the caller
    /// seeds each with identical config knobs and the full registry).
    pub fn new(
        block_tokens: usize,
        ttl: f64,
        schedulers: Vec<GlobalScheduler>,
        followers: Vec<InstanceId>,
    ) -> Self {
        let shards = schedulers.len().max(1);
        let units = schedulers
            .into_iter()
            .map(|gs| {
                let mut log = DeltaTransport::new(GS_WINDOW);
                for f in &followers {
                    log.register(f.0 as u64, 0);
                }
                Mutex::new(GsUnit { gs, log })
            })
            .collect();
        GsDataPlane {
            units,
            map: ShardMap::new(shards, block_tokens),
            followers: Mutex::new(followers),
            all_epoch: AtomicU64::new(0),
            ttl,
        }
    }

    pub fn shard_count(&self) -> usize {
        self.units.len()
    }

    pub fn map(&self) -> &ShardMap {
        &self.map
    }

    /// Completed cross-shard fences so far.
    pub fn broadcast_epoch(&self) -> u64 {
        // ordering: Relaxed — the counter is advisory (tests/metrics);
        // real fencing is the ascending lock_all hold, not this load.
        self.all_epoch.load(Ordering::Relaxed)
    }

    pub fn followers(&self) -> Vec<InstanceId> {
        self.followers.plock().clone()
    }

    pub fn is_registered(&self, f: InstanceId) -> bool {
        self.followers.plock().contains(&f)
    }

    fn unit(&self, s: usize) -> MutexGuard<'_, GsUnit> {
        self.units[s].plock()
    }

    /// All unit locks, ascending — the broadcast fence.
    fn lock_all(&self) -> Vec<MutexGuard<'_, GsUnit>> {
        self.units.iter().map(|u| u.plock()).collect()
    }

    /// Seed every unit's log with a pre-start backlog event (roster
    /// Joins) without touching the trees — the caller already built
    /// the registry into each scheduler.
    pub fn seed_log_all(&self, ev: DeltaEvent) {
        for s in 0..self.shard_count() {
            self.unit(s).log.append(ev.clone());
        }
    }

    /// Route one request on the shard owning its prefix chain: one
    /// unit lock, loads pushed, decision out. Other shards keep
    /// routing concurrently.
    pub fn route_request(
        &self,
        prompt: &[u32],
        session: u64,
        now: f64,
        loads: &[(InstanceId, InstanceLoad)],
    ) -> Result<RouteOutcome> {
        let s = self.map.shard_of_tokens(prompt).unwrap_or(0);
        let mut u = self.unit(s);
        for &(id, load) in loads {
            u.gs.set_load(id, load);
        }
        u.gs.route(prompt, session, now)
    }

    /// The single write path of the replicated global prompt tree:
    /// apply each delta to its shard's tree and append it to that
    /// shard's log under ONE hold of the unit lock, then ship sendable
    /// windows with no lock held. A batch containing any cross-shard
    /// event takes the epoch fence (all units, ascending) for the
    /// whole batch so every shard sees the same relative order.
    pub fn apply_batch(
        &self,
        evs: impl IntoIterator<Item = DeltaEvent>,
        fabric: &Fabric<Msg>,
        leader: InstanceId,
    ) {
        let evs: Vec<DeltaEvent> = evs.into_iter().collect();
        if evs.is_empty() {
            return;
        }
        let followers = self.followers();
        let replicate = !followers.is_empty();
        let any_all = evs
            .iter()
            .any(|ev| matches!(self.map.route(ev), ShardRoute::All));
        let mut touched: Vec<usize> = vec![];
        if any_all {
            // ordering: Relaxed — bumped while about to hold every
            // unit lock; lock_all is the fence, the counter just
            // numbers it for observers.
            self.all_epoch.fetch_add(1, Ordering::Relaxed);
            let mut guards = self.lock_all();
            for ev in &evs {
                match self.map.route(ev) {
                    ShardRoute::One(s) => {
                        guards[s].gs.trees.apply_delta(ev);
                        if replicate {
                            guards[s].log.append(ev.clone());
                        }
                    }
                    ShardRoute::All => {
                        for g in guards.iter_mut() {
                            g.gs.trees.apply_delta(ev);
                            if replicate {
                                g.log.append(ev.clone());
                            }
                        }
                    }
                }
            }
            drop(guards);
            touched.extend(0..self.units.len());
        } else {
            // Shard-keyed only: group by unit, preserving relative
            // order within each shard's slice of the batch.
            let mut per: DetMap<usize, Vec<&DeltaEvent>> = DetMap::default();
            for ev in &evs {
                if let ShardRoute::One(s) = self.map.route(ev) {
                    per.entry(s).or_default().push(ev);
                }
            }
            let mut shards: Vec<usize> = per.keys().copied().collect();
            shards.sort_unstable();
            for s in shards {
                let mut u = self.unit(s);
                for ev in &per[&s] {
                    u.gs.trees.apply_delta(ev);
                    if replicate {
                        u.log.append((*ev).clone());
                    }
                }
                touched.push(s);
            }
        }
        if replicate {
            self.flush_shards(&touched, &followers, fabric, leader);
        }
    }

    /// Ship the sendable windows of `shards` to every follower.
    /// Messages are collected under each unit's lock but sent with no
    /// lock held; a follower whose endpoint is gone is deregistered
    /// from every shard so it cannot stall log truncation.
    pub fn flush_shards(
        &self,
        shards: &[usize],
        followers: &[InstanceId],
        fabric: &Fabric<Msg>,
        leader: InstanceId,
    ) {
        let mut dead: Vec<InstanceId> = vec![];
        for &s in shards {
            let msgs: Vec<(InstanceId, u64, DeltaEvent)> = {
                let mut u = self.unit(s);
                let mut out = vec![];
                for &f in followers {
                    let peer = f.0 as u64;
                    let range = u.log.sendable(peer);
                    if range.is_empty() {
                        continue;
                    }
                    for seq in range.clone() {
                        // A sendable seq is always retained; if the
                        // log ever disagrees, skip rather than tear
                        // down the plane (the follower re-requests
                        // the gap via its cumulative ack).
                        let Some(ev) = u.log.get(seq) else {
                            debug_assert!(
                                false,
                                "sendable entry {seq} not retained"
                            );
                            continue;
                        };
                        out.push((f, seq, ev.clone()));
                    }
                    u.log.mark_sent(peer, range.end);
                }
                let floor = u.log.min_acked();
                u.log.truncate_below(floor);
                out
            };
            for (f, seq, ev) in msgs {
                if dead.contains(&f) {
                    continue;
                }
                if fabric
                    .send(leader, f, Msg::Delta { shard: s, seq, ev })
                    .is_err()
                {
                    dead.push(f);
                }
            }
        }
        for f in dead {
            log::warn!("GS follower {f} unreachable; dropping replica");
            self.deregister_follower(f);
        }
    }

    /// Flush every shard (the seed-backlog and rejoin paths).
    pub fn flush_all(&self, fabric: &Fabric<Msg>, leader: InstanceId) {
        let followers = self.followers();
        if followers.is_empty() {
            return;
        }
        let shards: Vec<usize> = (0..self.units.len()).collect();
        self.flush_shards(&shards, &followers, fabric, leader);
    }

    /// A follower's coalesced cumulative ack / gap re-request on one
    /// shard's stream: advance (or rewind) its cursor, then ship
    /// whatever became sendable.
    pub fn on_ack(
        &self,
        shard: usize,
        from: InstanceId,
        next: u64,
        fabric: &Fabric<Msg>,
        leader: InstanceId,
    ) {
        if shard >= self.units.len() {
            return;
        }
        self.unit(shard).log.on_ack(from.0 as u64, next);
        let followers = self.followers();
        if !followers.is_empty() {
            self.flush_shards(&[shard], &followers, fabric, leader);
        }
    }

    /// (Re-)register a follower on every shard at the retained floor —
    /// the rejoin-as-follower path; the snapshot bootstrap covers the
    /// truncated gap.
    pub fn register_follower(&self, f: InstanceId) {
        let mut roster = self.followers.plock();
        if roster.contains(&f) {
            return;
        }
        for s in 0..self.shard_count() {
            let mut u = self.unit(s);
            let from = u.log.first_retained();
            u.log.register(f.0 as u64, from);
        }
        roster.push(f);
    }

    /// Drop a follower from every shard's peer set (heartbeat-miss
    /// suspicion or send failure) so it cannot stall truncation.
    pub fn deregister_follower(&self, f: InstanceId) {
        let mut roster = self.followers.plock();
        for s in 0..self.shard_count() {
            self.unit(s).log.deregister(f.0 as u64);
        }
        roster.retain(|x| *x != f);
    }

    /// The follower holding `shard`'s longest applied prefix (that
    /// shard's promotion target).
    pub fn most_caught_up(&self, shard: usize) -> Option<InstanceId> {
        let roster = self.followers.plock().clone();
        let u = self.unit(shard);
        roster
            .iter()
            .copied()
            .max_by_key(|f| {
                (u.log.acked(f.0 as u64).unwrap_or(0), u32::MAX - f.0)
            })
    }

    /// Aggregated replication status: (sum of shard log heads,
    /// per-follower summed acked sequences).
    pub fn replication_status(&self) -> (u64, Vec<(InstanceId, u64)>) {
        let roster = self.followers();
        let mut head = 0u64;
        let mut acks: Vec<(InstanceId, u64)> =
            roster.iter().map(|f| (*f, 0)).collect();
        for s in 0..self.shard_count() {
            let u = self.unit(s);
            head += u.log.next_seq();
            for (f, a) in acks.iter_mut() {
                *a += u.log.acked(f.0 as u64).unwrap_or(0);
            }
        }
        (head, acks)
    }

    /// One shard's replication status: (log head, per-follower acked).
    pub fn shard_status(&self, shard: usize) -> (u64, Vec<(InstanceId, u64)>) {
        let roster = self.followers();
        let u = self.unit(shard);
        let head = u.log.next_seq();
        let acks = roster
            .iter()
            .map(|f| (*f, u.log.acked(f.0 as u64).unwrap_or(0)))
            .collect();
        (head, acks)
    }

    /// Capture `shard`'s tree at its log head for a follower bootstrap
    /// (`SnapshotReq`), skipping that follower's cursor to the head so
    /// streaming resumes past the snapshot. Both under one unit hold
    /// so no delta lands in between.
    pub fn snapshot_for(
        &self,
        shard: usize,
        from: InstanceId,
    ) -> Option<TreeSnapshot> {
        if shard >= self.units.len() {
            return None;
        }
        let mut u = self.unit(shard);
        let seq = u.log.next_seq();
        u.log.skip_to(from.0 as u64, seq);
        Some(TreeSnapshot::capture(u.gs.trees.shard(0), seq))
    }

    /// Restore a promoted follower's shard snapshot: replay the
    /// retained log suffix past it, install the tree, re-warm routing
    /// for the shard's prefix range.
    pub fn restore_promoted(
        &self,
        shard: usize,
        snap: &TreeSnapshot,
    ) -> PromotionRestore {
        if shard >= self.units.len() {
            return PromotionRestore::OutOfRange;
        }
        let mut u = self.unit(shard);
        if snap.seq < u.log.first_retained() {
            return PromotionRestore::Stale;
        }
        let mut fresh = snap.restore(self.ttl);
        for seq in snap.seq..u.log.next_seq() {
            if let Some(ev) = u.log.get(seq) {
                // Clone out of the log so the tree can apply while the
                // unit stays borrowed.
                let ev = ev.clone();
                fresh.apply_delta(&ev);
            }
        }
        u.gs.trees.set_shard_tree(0, fresh);
        u.gs.set_shard_degraded(0, false);
        PromotionRestore::Restored
    }

    /// Replace one shard's tree wholesale (crash injection: the
    /// primary's slice dies and is rebuilt from bare membership).
    pub fn set_shard_tree(&self, shard: usize, tree: GlobalPromptTrees) {
        self.unit(shard).gs.trees.set_shard_tree(0, tree);
    }

    pub fn set_shard_degraded(&self, shard: usize, degraded: bool) {
        self.unit(shard).gs.set_shard_degraded(0, degraded);
    }

    pub fn is_shard_degraded(&self, shard: usize) -> bool {
        self.unit(shard).gs.is_shard_degraded(0)
    }

    /// TTL housekeeping, shard by shard — expiry is shard-local, so no
    /// fence: each unit expires under its own lock.
    pub fn expire(&self, now: f64) {
        for s in 0..self.shard_count() {
            self.unit(s).gs.expire(now);
        }
    }

    /// Registry read: broadcasts keep every unit's registry identical,
    /// so unit 0 answers for the plane.
    pub fn is_draining(&self, id: InstanceId) -> bool {
        self.unit(0).gs.trees.is_draining(id)
    }

    /// Token-blocks the global view credits each of `ids` with, summed
    /// across shards — one pass, S short lock holds (not |ids| × S).
    pub fn cached_blocks_for(
        &self,
        ids: &[InstanceId],
    ) -> HashMap<InstanceId, usize> {
        let mut out: HashMap<InstanceId, usize> =
            ids.iter().map(|id| (*id, 0)).collect();
        for s in 0..self.shard_count() {
            let u = self.unit(s);
            for (id, n) in out.iter_mut() {
                *n += u.gs.trees.cached_blocks(*id);
            }
        }
        out
    }

    /// Plan a drain across the per-shard trees: inventory is the
    /// concatenation of per-unit `owned_paths`, replication probes
    /// route to the unit owning the prefix. All units are locked
    /// (ascending) for the plan so it sees one consistent cut.
    pub fn plan_drain(
        &self,
        donor: InstanceId,
        now: f64,
        recipients: &[Recipient],
        cfg: &PlannerConfig,
    ) -> MigrationPlan {
        let guards = self.lock_all();
        let inventory = guards
            .iter()
            .flat_map(|g| g.gs.trees.owned_paths(donor))
            .collect();
        plan_migration_from(
            inventory,
            |id, tokens| {
                let s = self.map.shard_of_tokens(tokens).unwrap_or(0);
                guards[s].gs.trees.match_one(id, tokens)
            },
            donor,
            now,
            recipients,
            cfg,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scheduler::cost_model::OperatorCostModel;
    use crate::scheduler::policy::PolicyKind;
    use crate::scheduler::prompt_tree::InstanceKind;
    use crate::scheduler::shard::ShardedPromptTrees;

    const BT: usize = 4;

    fn plane(shards: usize, n_inst: u32) -> GsDataPlane {
        let scheds = (0..shards)
            .map(|_| {
                let mut gs = GlobalScheduler::new(
                    PolicyKind::PromptTree,
                    OperatorCostModel::paper_13b(),
                    BT,
                    0.0,
                );
                for i in 0..n_inst {
                    gs.add_instance(
                        InstanceId(i),
                        InstanceKind::PrefillOnly,
                    );
                }
                gs
            })
            .collect();
        GsDataPlane::new(BT, 0.0, scheds, vec![])
    }

    fn toks(blocks: usize, seed: u32) -> Vec<u32> {
        (0..(blocks * BT) as u32)
            .map(|i| i.wrapping_mul(7).wrapping_add(seed * 131) % 9)
            .collect()
    }

    fn apply_local(p: &GsDataPlane, ev: &DeltaEvent) {
        // Test-only apply without a fabric: same routing as
        // apply_batch with no followers (nothing to flush).
        match p.map().route(ev) {
            ShardRoute::One(s) => {
                p.unit(s).gs.trees.apply_delta(ev);
            }
            ShardRoute::All => {
                p.all_epoch.fetch_add(1, Ordering::Relaxed);
                for g in p.lock_all().iter_mut() {
                    g.gs.trees.apply_delta(ev);
                }
            }
        }
    }

    /// Shard-keyed writes touch one unit; broadcasts bump the epoch
    /// fence and land on every unit.
    #[test]
    fn one_routed_writes_are_shard_local() {
        let p = plane(4, 2);
        let rec = DeltaEvent::Record {
            instance: InstanceId(0),
            tokens: toks(2, 3),
            now: 1.0,
        };
        let home = p.map().shard_of_tokens(&toks(2, 3)).unwrap();
        let before = p.broadcast_epoch();
        apply_local(&p, &rec);
        assert_eq!(p.broadcast_epoch(), before, "no fence for One(k)");
        for s in 0..4 {
            let u = p.unit(s);
            let blocks = u.gs.trees.cached_blocks(InstanceId(0));
            assert_eq!(blocks, if s == home { 2 } else { 0 });
        }
        apply_local(&p, &DeltaEvent::SetDraining {
            instance: InstanceId(1),
            draining: true,
        });
        assert_eq!(p.broadcast_epoch(), before + 1, "broadcast fenced");
        for s in 0..4 {
            assert!(p.unit(s).gs.trees.is_draining(InstanceId(1)));
        }
        assert!(p.is_draining(InstanceId(1)));
    }

    /// The plane's per-unit route equals the monolithic S-shard
    /// scheduler's decision for every prompt — the sharded-lock
    /// bit-identity claim.
    #[test]
    fn plane_routes_match_monolithic() {
        let n_inst = 6u32;
        let p = plane(4, n_inst);
        let mut mono = GlobalScheduler::with_shards(
            PolicyKind::PromptTree,
            OperatorCostModel::paper_13b(),
            BT,
            0.0,
            4,
        );
        for i in 0..n_inst {
            mono.add_instance(InstanceId(i), InstanceKind::PrefillOnly);
        }
        for r in 0..24u32 {
            let ev = DeltaEvent::Record {
                instance: InstanceId(r % n_inst),
                tokens: toks(1 + (r as usize % 3), r),
                now: 1.0,
            };
            apply_local(&p, &ev);
            mono.trees.apply_delta(&ev);
        }
        let loads: Vec<(InstanceId, InstanceLoad)> = (0..n_inst)
            .map(|i| {
                (
                    InstanceId(i),
                    InstanceLoad {
                        queued_tokens: (i as usize * 53) % 700,
                        ..Default::default()
                    },
                )
            })
            .collect();
        for q in 0..40u32 {
            let prompt = toks(2, q % 17);
            for &(id, l) in &loads {
                mono.set_load(id, l);
            }
            let want = mono.route(&prompt, q as u64, 2.0).unwrap();
            let got = p
                .route_request(&prompt, q as u64, 2.0, &loads)
                .unwrap();
            assert_eq!(got.decision, want.decision, "prompt {q}");
        }
    }

    /// `plan_drain` over per-shard trees equals `plan_migration` over
    /// the monolithic sharded view — same inventory, same probes, same
    /// deterministic order.
    #[test]
    fn plan_drain_matches_monolithic_planner() {
        let n_inst = 4u32;
        let p = plane(2, n_inst);
        let mut trees = ShardedPromptTrees::with_shards(BT, 0.0, 2);
        for i in 0..n_inst {
            trees.add_instance(InstanceId(i), InstanceKind::PrefillOnly);
        }
        for r in 0..20u32 {
            let ev = DeltaEvent::Record {
                instance: InstanceId(r % n_inst),
                tokens: toks(1 + (r as usize % 4), r * 3),
                now: r as f64,
            };
            apply_local(&p, &ev);
            trees.apply_delta(&ev);
        }
        let recipients: Vec<Recipient> = (1..n_inst)
            .map(|i| Recipient {
                id: InstanceId(i),
                pressure: i as f64 / 10.0,
            })
            .collect();
        let cfg = PlannerConfig::default();
        let want = crate::elastic::planner::plan_migration(
            &trees,
            InstanceId(0),
            30.0,
            &recipients,
            &cfg,
        );
        let got = p.plan_drain(InstanceId(0), 30.0, &recipients, &cfg);
        assert_eq!(got.tasks, want.tasks);
        assert_eq!(got.planned_blocks, want.planned_blocks);
        assert_eq!(got.dropped_blocks, want.dropped_blocks);
        assert_eq!(got.replicated_blocks, want.replicated_blocks);
    }

    /// Follower bookkeeping: register/deregister span every unit; the
    /// promotion target tracks per-shard acks.
    #[test]
    fn follower_roster_spans_every_unit() {
        let p = plane(2, 1);
        let f = crate::server::replica::follower_id(0);
        assert!(!p.is_registered(f));
        p.register_follower(f);
        assert!(p.is_registered(f));
        p.register_follower(f); // idempotent
        assert_eq!(p.followers().len(), 1);
        p.seed_log_all(DeltaEvent::Join {
            instance: InstanceId(0),
            kind: InstanceKind::PrefillOnly,
        });
        let (head, acks) = p.replication_status();
        assert_eq!(head, 2, "one seed entry per shard log");
        assert_eq!(acks, vec![(f, 0)]);
        p.unit(1).log.on_ack(f.0 as u64, 1);
        assert_eq!(p.most_caught_up(1), Some(f));
        let (h1, a1) = p.shard_status(1);
        assert_eq!((h1, a1), (1, vec![(f, 1)]));
        p.deregister_follower(f);
        assert!(!p.is_registered(f));
        assert_eq!(p.most_caught_up(0), None);
    }
}
