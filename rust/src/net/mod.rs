//! The cluster fabric: message transport between instance threads plus a
//! calibrated NCCL-like link-cost model.
//!
//! The paper implements `transfer` over NCCL send/recv (one call per
//! discrete block, single thread per communicator for ordering — §7) and
//! studies the resulting overheads (Fig 11/12). Real NCCL and H800 NVLink
//! are unavailable here, so [`LinkModel`] reproduces the *cost structure*
//! that drives those figures:
//!
//! ```text
//! time = ceil(n_calls / communicators) · call_overhead        (serial launches)
//!      + bytes / bandwidth                                    (wire time)
//!      + chunk penalty when a call's payload exceeds buffer_mb
//!      + dram_penalty per call when either endpoint is DRAM   (socket path)
//! ```
//!
//! Two delivery modes share this model: [`Fabric`] (real thread
//! channels; the sender blocks for the modeled time, like a synchronous
//! NCCL send) and the discrete-event simulator (which adds the modeled
//! time to its virtual clock).

pub mod fabric;
pub mod faults;
pub mod link;

pub use fabric::{Endpoint, Fabric, NetStats, WireCost};
pub use faults::{FaultPlan, LinkFaults};
pub use link::LinkModel;
