//! The link-cost model — the math behind Figures 11 and 12.

use crate::config::FabricConfig;

/// Cost model for one point-to-point transfer.
#[derive(Clone, Debug, PartialEq)]
pub struct LinkModel {
    /// Per network-API-call launch overhead (seconds). NCCL's measured
    /// send/recv launch cost is ~10–20 µs.
    pub call_overhead_s: f64,
    /// Wire bandwidth, bytes/second.
    pub bandwidth: f64,
    /// Parallel serialization domains (NCCL communicators). Calls are
    /// round-robined; launches within one communicator are serial (§7:
    /// one thread per communicator for ordering).
    pub communicators: usize,
    /// Per-communicator staging buffer (bytes). A call whose payload
    /// exceeds it pays extra launches for the extra chunks.
    pub buffer_bytes: usize,
    /// Extra per-call cost when either endpoint is DRAM (socket path).
    pub dram_penalty_s: f64,
}

impl LinkModel {
    pub fn from_config(cfg: &FabricConfig) -> Self {
        LinkModel {
            call_overhead_s: cfg.call_overhead_us * 1e-6,
            bandwidth: cfg.bandwidth_gbps * 1e9,
            communicators: cfg.communicators.max(1),
            buffer_bytes: (cfg.buffer_mb * 1e6) as usize,
            dram_penalty_s: cfg.dram_penalty_us * 1e-6,
        }
    }

    /// Modeled time to push `bytes` split across `n_calls` equal calls.
    pub fn transfer_seconds(
        &self,
        bytes: usize,
        n_calls: usize,
        src_dram: bool,
        dst_dram: bool,
    ) -> f64 {
        if bytes == 0 || n_calls == 0 {
            return 0.0;
        }
        let per_call = bytes.div_ceil(n_calls);
        // Chunking: each call needs ceil(payload / buffer) launches.
        let chunks_per_call = per_call.div_ceil(self.buffer_bytes.max(1));
        let launches = n_calls * chunks_per_call;
        let serial_launches = launches.div_ceil(self.communicators);
        let mut t = serial_launches as f64 * self.call_overhead_s
            + bytes as f64 / self.bandwidth;
        if src_dram || dst_dram {
            // Socket path: per-call penalty + halved effective bandwidth
            // (extra host copy on the slow side).
            t += n_calls as f64 * self.dram_penalty_s
                + bytes as f64 / self.bandwidth;
        }
        t
    }

    /// HBM consumed by communicator staging buffers (Fig 11 right: more
    /// communicators and bigger buffers cost device memory).
    pub fn hbm_buffer_bytes(&self) -> usize {
        // Send + receive rings per communicator.
        2 * self.communicators * self.buffer_bytes
    }

    /// Small constant latency for control-plane messages (allocation
    /// round-trip, acks, heartbeats).
    pub fn control_latency_s(&self) -> f64 {
        self.call_overhead_s
    }
}

impl Default for LinkModel {
    fn default() -> Self {
        LinkModel {
            call_overhead_s: 15e-6,
            bandwidth: 40e9,
            communicators: 1,
            buffer_bytes: 4_000_000,
            dram_penalty_s: 50e-6,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn link() -> LinkModel {
        LinkModel::default()
    }

    #[test]
    fn zero_bytes_is_free() {
        assert_eq!(link().transfer_seconds(0, 0, false, false), 0.0);
    }

    #[test]
    fn more_calls_cost_more_for_same_bytes() {
        let l = link();
        let bytes = 4 << 20;
        let t1 = l.transfer_seconds(bytes, 1, false, false);
        let t64 = l.transfer_seconds(bytes, 64, false, false);
        // 64 launches vs 2 (4 MiB > 4 MB buffer -> 2 chunks): ~8x.
        assert!(t64 > t1 * 5.0, "t1={t1} t64={t64}");
    }

    #[test]
    fn aggregation_story_fig11() {
        // 2048-token KV, tiny geometry: 128 discrete blocks vs 16 agg
        // blocks (2*L = 8 ratio at L=4). Aggregated must win by a margin.
        let l = link();
        let bytes = 2048 * 2048 * 4; // tokens * floats/token * 4
        let t_disc = l.transfer_seconds(bytes, 1024, false, false);
        let t_agg = l.transfer_seconds(bytes, 128, false, false);
        assert!(t_disc > 3.0 * t_agg, "disc={t_disc} agg={t_agg}");
    }

    #[test]
    fn communicators_help_small_blocks() {
        let mut l = link();
        let bytes = 4 << 20;
        let t_c1 = l.transfer_seconds(bytes, 512, false, false);
        l.communicators = 8;
        let t_c8 = l.transfer_seconds(bytes, 512, false, false);
        assert!(t_c8 < t_c1 / 4.0, "c1={t_c1} c8={t_c8}");
        // But they consume HBM (Fig 11 right).
        assert_eq!(l.hbm_buffer_bytes(), 8 * 2 * 4_000_000);
    }

    #[test]
    fn single_communicator_enough_for_large_blocks() {
        // With one buffer-sized call, extra communicators don't help.
        let mut l = link();
        let t_c1 = l.transfer_seconds(4_000_000, 1, false, false);
        l.communicators = 8;
        let t_c8 = l.transfer_seconds(4_000_000, 1, false, false);
        assert!((t_c1 - t_c8).abs() / t_c1 < 0.05);
    }

    #[test]
    fn small_buffer_forces_chunking() {
        let mut l = link();
        l.buffer_bytes = 64 << 10;
        let t_small_buf = l.transfer_seconds(4 << 20, 1, false, false);
        l.buffer_bytes = 8 << 20;
        let t_big_buf = l.transfer_seconds(4 << 20, 1, false, false);
        assert!(t_small_buf > t_big_buf);
    }

    #[test]
    fn dram_endpoint_slower() {
        let l = link();
        let hbm = l.transfer_seconds(1 << 20, 16, false, false);
        let dram = l.transfer_seconds(1 << 20, 16, true, false);
        assert!(dram > hbm);
    }

    #[test]
    fn bandwidth_term_dominates_eventually() {
        let l = link();
        // 1 GB in one call: wire ~26.8 ms dominates even the ~269 chunk
        // launches (~4 ms) the 4 MB buffer forces.
        let t = l.transfer_seconds(1 << 30, 1, false, false);
        let wire = (1u64 << 30) as f64 / 40e9;
        assert!(t >= wire, "t={t} wire={wire}");
        assert!(t < wire * 1.3, "launch overhead should be minor: {t}");
    }

    #[test]
    fn from_config_roundtrip() {
        let cfg = FabricConfig {
            call_overhead_us: 10.0,
            bandwidth_gbps: 100.0,
            communicators: 4,
            buffer_mb: 2.0,
            dram_penalty_us: 30.0,
        };
        let l = LinkModel::from_config(&cfg);
        assert_eq!(l.communicators, 4);
        assert!((l.bandwidth - 100e9).abs() < 1.0);
        assert_eq!(l.buffer_bytes, 2_000_000);
    }
}
