//! Real-time message fabric: std::mpsc channels between instance threads
//! with the [`LinkModel`] applied as sender-side blocking (synchronous
//! NCCL-send semantics, which is also what the paper implements — §7).

use std::collections::HashMap;
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::mempool::InstanceId;
use crate::net::faults::{FaultDecision, FaultPlan};
use crate::net::link::LinkModel;
use crate::util::sync::LockExt;

/// Messages that carry bulk payload report `(bytes, n_calls, src_dram,
/// dst_dram)`; control messages return `None` and pay only the control
/// latency.
pub trait WireCost {
    fn wire_cost(&self) -> Option<(usize, usize, bool, bool)>;
}

/// Aggregate transport statistics (drives Fig 11/12 reporting).
#[derive(Clone, Debug, Default)]
pub struct NetStats {
    pub messages: u64,
    pub payload_bytes: u64,
    pub api_calls: u64,
    pub busy_seconds: f64,
    /// Messages silently lost by the fault plan (drops + partitions).
    pub dropped: u64,
    /// Extra copies injected by the fault plan.
    pub duplicated: u64,
    /// Messages held back for out-of-order delivery.
    pub reordered: u64,
}

#[derive(Debug, thiserror::Error)]
pub enum NetError {
    #[error("unknown destination {0}")]
    Unknown(InstanceId),
    #[error("destination {0} disconnected")]
    Disconnected(InstanceId),
    #[error("receive timeout")]
    Timeout,
}

struct Shared<M> {
    senders: Mutex<HashMap<InstanceId, Sender<(InstanceId, M)>>>,
    link: LinkModel,
    stats: Mutex<NetStats>,
    /// When false (tests/CI), the sender does not actually sleep; the
    /// modeled time is still accounted in stats.
    real_sleep: bool,
    /// Installed fault schedule (None = perfect network, zero overhead
    /// beyond one uncontended lock probe per send).
    faults: Mutex<Option<FaultPlan>>,
    /// Messages held back for reordering, keyed by directed link;
    /// released behind the next delivered message on the same link.
    held: Mutex<HashMap<(InstanceId, InstanceId), Vec<M>>>,
}

/// Cloneable fabric handle.
pub struct Fabric<M> {
    shared: Arc<Shared<M>>,
}

impl<M> Clone for Fabric<M> {
    fn clone(&self) -> Self {
        Fabric {
            shared: self.shared.clone(),
        }
    }
}

/// One instance's attachment: its inbox + a fabric handle for sending.
pub struct Endpoint<M> {
    pub id: InstanceId,
    rx: Receiver<(InstanceId, M)>,
    fabric: Fabric<M>,
}

impl<M: WireCost + Clone + Send + 'static> Fabric<M> {
    pub fn new(link: LinkModel, real_sleep: bool) -> Self {
        Fabric {
            shared: Arc::new(Shared {
                senders: Mutex::new(HashMap::new()),
                link,
                stats: Mutex::new(NetStats::default()),
                real_sleep,
                faults: Mutex::new(None),
                held: Mutex::new(HashMap::new()),
            }),
        }
    }

    /// Install (or replace) the fault schedule. `None`-plan fabrics are
    /// behaviorally identical to builds without fault injection.
    pub fn set_fault_plan(&self, plan: FaultPlan) {
        *self.shared.faults.plock() = Some(plan);
    }

    /// Remove the fault schedule and deliver anything still held back.
    pub fn clear_fault_plan(&self) {
        *self.shared.faults.plock() = None;
        self.release_held();
    }

    /// Mutate the installed plan in place (partitions: `isolate`/`heal`).
    /// No-op when no plan is installed.
    pub fn with_faults<R>(
        &self,
        f: impl FnOnce(&mut FaultPlan) -> R,
    ) -> Option<R> {
        self.shared.faults.plock().as_mut().map(f)
    }

    /// Flush every holdback buffer — the quiesce helper: reordering
    /// must delay messages, never strand them once traffic stops.
    pub fn release_held(&self) {
        let held: Vec<((InstanceId, InstanceId), Vec<M>)> =
            self.shared.held.plock().drain().collect();
        let senders = self.shared.senders.plock();
        for ((from, to), msgs) in held {
            if let Some(tx) = senders.get(&to) {
                for m in msgs {
                    let _ = tx.send((from, m));
                }
            }
        }
    }

    /// Attach an instance; returns its endpoint (single consumer).
    pub fn attach(&self, id: InstanceId) -> Endpoint<M> {
        let (tx, rx) = channel();
        self.shared.senders.plock().insert(id, tx);
        Endpoint {
            id,
            rx,
            fabric: self.clone(),
        }
    }

    /// Remove an instance (simulating failure — its inbox closes and
    /// subsequent sends error out, which peers' timeouts surface).
    pub fn detach(&self, id: InstanceId) {
        self.shared.senders.plock().remove(&id);
    }

    pub fn link(&self) -> &LinkModel {
        &self.shared.link
    }

    pub fn stats(&self) -> NetStats {
        self.shared.stats.plock().clone()
    }

    /// Send with modeled wire time (blocking the caller, like a
    /// synchronous NCCL send). Returns the modeled seconds.
    ///
    /// When a [`FaultPlan`] is installed the message may be dropped,
    /// duplicated, jittered, or held back for reordering; the sender
    /// still pays wire time and sees `Ok` on a silent loss (datagram
    /// semantics — only end-to-end acks reveal the drop).
    pub fn send(&self, from: InstanceId, to: InstanceId, msg: M)
                -> Result<f64, NetError> {
        let mut t = match msg.wire_cost() {
            Some((bytes, calls, src_dram, dst_dram)) => {
                let t = self
                    .shared
                    .link
                    .transfer_seconds(bytes, calls, src_dram, dst_dram);
                let mut s = self.shared.stats.plock();
                s.payload_bytes += bytes as u64;
                s.api_calls += calls as u64;
                s.busy_seconds += t;
                s.messages += 1;
                t
            }
            None => {
                let t = self.shared.link.control_latency_s();
                let mut s = self.shared.stats.plock();
                s.messages += 1;
                s.busy_seconds += t;
                t
            }
        };
        // Fault injection: consult the plan (if any) before sleeping so
        // jitter rides the same modeled-time sleep as wire cost.
        let mut copies = 1u32;
        {
            let mut faults = self.shared.faults.plock();
            if let Some(plan) = faults.as_mut() {
                let link = (from, to);
                let depth = self
                    .shared
                    .held
                    .plock()
                    .get(&link)
                    .map_or(0, Vec::len);
                match plan.decide(from, to, depth) {
                    FaultDecision::Deliver { copies: c, extra_s } => {
                        copies = c;
                        t += extra_s;
                        if c > 1 {
                            self.shared.stats.plock().duplicated +=
                                (c - 1) as u64;
                        }
                    }
                    FaultDecision::Drop => {
                        self.shared.stats.plock().dropped += 1;
                        drop(faults);
                        if self.shared.real_sleep && t > 0.0 {
                            std::thread::sleep(Duration::from_secs_f64(t));
                        }
                        return Ok(t);
                    }
                    FaultDecision::HoldBack { extra_s } => {
                        t += extra_s;
                        self.shared.stats.plock().reordered += 1;
                        self.shared
                            .held
                            .plock()
                            .entry(link)
                            .or_default()
                            .push(msg);
                        drop(faults);
                        if self.shared.real_sleep && t > 0.0 {
                            std::thread::sleep(Duration::from_secs_f64(t));
                        }
                        return Ok(t);
                    }
                }
            }
        }
        if self.shared.real_sleep && t > 0.0 {
            std::thread::sleep(Duration::from_secs_f64(t));
        }
        // A delivered message releases anything held back on this link
        // *behind* it — that is the reordering.
        let released: Vec<M> = self
            .shared
            .held
            .plock()
            .get_mut(&(from, to))
            .map(std::mem::take)
            .unwrap_or_default();
        let senders = self.shared.senders.plock();
        let tx = senders.get(&to).ok_or(NetError::Unknown(to))?;
        for _ in 1..copies {
            let _ = tx.send((from, msg.clone()));
        }
        tx.send((from, msg))
            .map_err(|_| NetError::Disconnected(to))?;
        for m in released {
            let _ = tx.send((from, m));
        }
        Ok(t)
    }
}

impl<M> Endpoint<M> {
    /// Blocking receive.
    pub fn recv(&self) -> Option<(InstanceId, M)> {
        self.rx.recv().ok()
    }

    pub fn recv_timeout(&self, d: Duration)
                        -> Result<(InstanceId, M), NetError> {
        self.rx.recv_timeout(d).map_err(|e| match e {
            RecvTimeoutError::Timeout => NetError::Timeout,
            RecvTimeoutError::Disconnected => NetError::Disconnected(self.id),
        })
    }

    pub fn try_recv(&self) -> Option<(InstanceId, M)> {
        self.rx.try_recv().ok()
    }

    pub fn fabric(&self) -> &Fabric<M> {
        &self.fabric
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    use crate::net::faults::LinkFaults;

    #[derive(Clone, Debug, PartialEq)]
    enum TestMsg {
        Ctl(u32),
        Bulk(usize, usize), // bytes, calls
    }

    impl WireCost for TestMsg {
        fn wire_cost(&self) -> Option<(usize, usize, bool, bool)> {
            match self {
                TestMsg::Ctl(_) => None,
                TestMsg::Bulk(b, c) => Some((*b, *c, false, false)),
            }
        }
    }

    fn fabric() -> Fabric<TestMsg> {
        Fabric::new(LinkModel::default(), false)
    }

    #[test]
    fn point_to_point_delivery() {
        let f = fabric();
        let a = f.attach(InstanceId(0));
        let b = f.attach(InstanceId(1));
        f.send(InstanceId(0), InstanceId(1), TestMsg::Ctl(7)).unwrap();
        let (from, msg) = b.recv().unwrap();
        assert_eq!(from, InstanceId(0));
        assert_eq!(msg, TestMsg::Ctl(7));
        drop(a);
    }

    #[test]
    fn unknown_destination_errors() {
        let f = fabric();
        let _a = f.attach(InstanceId(0));
        assert!(matches!(
            f.send(InstanceId(0), InstanceId(9), TestMsg::Ctl(0)),
            Err(NetError::Unknown(_))
        ));
    }

    #[test]
    fn detach_simulates_failure() {
        let f = fabric();
        let _a = f.attach(InstanceId(0));
        let b = f.attach(InstanceId(1));
        f.detach(InstanceId(1));
        assert!(f
            .send(InstanceId(0), InstanceId(1), TestMsg::Ctl(1))
            .is_err());
        drop(b);
    }

    #[test]
    fn stats_account_bulk_and_control() {
        let f = fabric();
        let _a = f.attach(InstanceId(0));
        let _b = f.attach(InstanceId(1));
        f.send(InstanceId(0), InstanceId(1), TestMsg::Ctl(0)).unwrap();
        let t = f
            .send(InstanceId(0), InstanceId(1), TestMsg::Bulk(1 << 20, 16))
            .unwrap();
        assert!(t > 0.0);
        let s = f.stats();
        assert_eq!(s.messages, 2);
        assert_eq!(s.payload_bytes, 1 << 20);
        assert_eq!(s.api_calls, 16);
        assert!(s.busy_seconds > 0.0);
    }

    #[test]
    fn threaded_ping_pong() {
        let f = fabric();
        let a = f.attach(InstanceId(0));
        let b = f.attach(InstanceId(1));
        let fb = f.clone();
        let h = std::thread::spawn(move || {
            let (from, msg) = b.recv().unwrap();
            assert_eq!(msg, TestMsg::Ctl(1));
            fb.send(InstanceId(1), from, TestMsg::Ctl(2)).unwrap();
        });
        f.send(InstanceId(0), InstanceId(1), TestMsg::Ctl(1)).unwrap();
        let (_, reply) = a.recv().unwrap();
        assert_eq!(reply, TestMsg::Ctl(2));
        h.join().unwrap();
    }

    #[test]
    fn timeout_receive() {
        let f = fabric();
        let a = f.attach(InstanceId(0));
        assert!(matches!(
            a.recv_timeout(Duration::from_millis(10)),
            Err(NetError::Timeout)
        ));
    }

    /// Regression (ISSUE 6 satellite): a detached endpoint's receive
    /// must surface `Disconnected` immediately — callers that conflate
    /// it with `Timeout` wait out the full timer for a peer that is
    /// already gone.
    #[test]
    fn detached_endpoint_recv_is_disconnected_immediately() {
        let f = fabric();
        let a = f.attach(InstanceId(0));
        f.detach(InstanceId(0));
        let start = std::time::Instant::now();
        let got = a.recv_timeout(Duration::from_secs(5));
        assert!(matches!(got, Err(NetError::Disconnected(_))), "{got:?}");
        assert!(
            start.elapsed() < Duration::from_secs(1),
            "Disconnected must not wait out the timeout"
        );
    }

    #[test]
    fn fault_plan_drops_and_counts() {
        let f = fabric();
        let _a = f.attach(InstanceId(0));
        let b = f.attach(InstanceId(1));
        let mut plan = FaultPlan::new(1);
        plan.set_link(
            InstanceId(0),
            InstanceId(1),
            LinkFaults { drop: 1.0, ..Default::default() },
        );
        f.set_fault_plan(plan);
        // Silent loss: sender still sees Ok.
        f.send(InstanceId(0), InstanceId(1), TestMsg::Ctl(1)).unwrap();
        assert!(b.try_recv().is_none());
        assert_eq!(f.stats().dropped, 1);
    }

    #[test]
    fn fault_plan_duplicates_delivery() {
        let f = fabric();
        let _a = f.attach(InstanceId(0));
        let b = f.attach(InstanceId(1));
        let mut plan = FaultPlan::new(1);
        plan.set_link(
            InstanceId(0),
            InstanceId(1),
            LinkFaults { duplicate: 1.0, ..Default::default() },
        );
        f.set_fault_plan(plan);
        f.send(InstanceId(0), InstanceId(1), TestMsg::Ctl(9)).unwrap();
        assert_eq!(b.try_recv().unwrap().1, TestMsg::Ctl(9));
        assert_eq!(b.try_recv().unwrap().1, TestMsg::Ctl(9));
        assert!(b.try_recv().is_none());
        assert_eq!(f.stats().duplicated, 1);
    }

    #[test]
    fn fault_plan_reorders_behind_later_traffic() {
        let f = fabric();
        let _a = f.attach(InstanceId(0));
        let b = f.attach(InstanceId(1));
        let mut plan = FaultPlan::new(1);
        // First send held back; the plan is then swapped for a clean
        // one so the second send releases the first behind it.
        plan.set_link(
            InstanceId(0),
            InstanceId(1),
            LinkFaults { reorder: 1.0, ..Default::default() },
        );
        f.set_fault_plan(plan);
        f.send(InstanceId(0), InstanceId(1), TestMsg::Ctl(1)).unwrap();
        assert!(b.try_recv().is_none(), "first message must be held");
        f.with_faults(|p| {
            p.set_link(InstanceId(0), InstanceId(1), LinkFaults::default());
        });
        f.send(InstanceId(0), InstanceId(1), TestMsg::Ctl(2)).unwrap();
        assert_eq!(b.try_recv().unwrap().1, TestMsg::Ctl(2));
        assert_eq!(b.try_recv().unwrap().1, TestMsg::Ctl(1));
        assert_eq!(f.stats().reordered, 1);
    }

    #[test]
    fn release_held_flushes_stranded_messages() {
        let f = fabric();
        let _a = f.attach(InstanceId(0));
        let b = f.attach(InstanceId(1));
        let mut plan = FaultPlan::new(1);
        plan.set_link(
            InstanceId(0),
            InstanceId(1),
            LinkFaults { reorder: 1.0, ..Default::default() },
        );
        f.set_fault_plan(plan);
        f.send(InstanceId(0), InstanceId(1), TestMsg::Ctl(5)).unwrap();
        assert!(b.try_recv().is_none());
        f.release_held();
        assert_eq!(b.try_recv().unwrap().1, TestMsg::Ctl(5));
    }

    #[test]
    fn isolate_partitions_one_direction_until_heal() {
        let f = fabric();
        let a = f.attach(InstanceId(0));
        let b = f.attach(InstanceId(1));
        f.set_fault_plan(FaultPlan::new(1));
        f.with_faults(|p| p.isolate(InstanceId(0), InstanceId(1)));
        f.send(InstanceId(0), InstanceId(1), TestMsg::Ctl(1)).unwrap();
        assert!(b.try_recv().is_none());
        // Reverse direction still flows.
        f.send(InstanceId(1), InstanceId(0), TestMsg::Ctl(2)).unwrap();
        assert_eq!(a.try_recv().unwrap().1, TestMsg::Ctl(2));
        f.with_faults(|p| p.heal(InstanceId(0), InstanceId(1)));
        f.send(InstanceId(0), InstanceId(1), TestMsg::Ctl(3)).unwrap();
        assert_eq!(b.try_recv().unwrap().1, TestMsg::Ctl(3));
        assert_eq!(f.stats().dropped, 1);
    }

    #[test]
    fn jitter_inflates_modeled_time_only() {
        let f = fabric();
        let _a = f.attach(InstanceId(0));
        let b = f.attach(InstanceId(1));
        let base = f
            .send(InstanceId(0), InstanceId(1), TestMsg::Ctl(0))
            .unwrap();
        let mut plan = FaultPlan::new(99);
        plan.set_default(LinkFaults { jitter_s: 1.0, ..Default::default() });
        f.set_fault_plan(plan);
        let mut saw_jitter = false;
        for i in 0..16 {
            let t = f
                .send(InstanceId(0), InstanceId(1), TestMsg::Ctl(i))
                .unwrap();
            saw_jitter |= t > base + 1e-6;
        }
        assert!(saw_jitter, "jitter never surfaced in modeled time");
        while b.try_recv().is_some() {}
    }
}
