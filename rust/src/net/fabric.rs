//! Real-time message fabric: std::mpsc channels between instance threads
//! with the [`LinkModel`] applied as sender-side blocking (synchronous
//! NCCL-send semantics, which is also what the paper implements — §7).

use std::collections::HashMap;
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::mempool::InstanceId;
use crate::net::link::LinkModel;

/// Messages that carry bulk payload report `(bytes, n_calls, src_dram,
/// dst_dram)`; control messages return `None` and pay only the control
/// latency.
pub trait WireCost {
    fn wire_cost(&self) -> Option<(usize, usize, bool, bool)>;
}

/// Aggregate transport statistics (drives Fig 11/12 reporting).
#[derive(Clone, Debug, Default)]
pub struct NetStats {
    pub messages: u64,
    pub payload_bytes: u64,
    pub api_calls: u64,
    pub busy_seconds: f64,
}

#[derive(Debug, thiserror::Error)]
pub enum NetError {
    #[error("unknown destination {0}")]
    Unknown(InstanceId),
    #[error("destination {0} disconnected")]
    Disconnected(InstanceId),
    #[error("receive timeout")]
    Timeout,
}

struct Shared<M> {
    senders: Mutex<HashMap<InstanceId, Sender<(InstanceId, M)>>>,
    link: LinkModel,
    stats: Mutex<NetStats>,
    /// When false (tests/CI), the sender does not actually sleep; the
    /// modeled time is still accounted in stats.
    real_sleep: bool,
}

/// Cloneable fabric handle.
pub struct Fabric<M> {
    shared: Arc<Shared<M>>,
}

impl<M> Clone for Fabric<M> {
    fn clone(&self) -> Self {
        Fabric {
            shared: self.shared.clone(),
        }
    }
}

/// One instance's attachment: its inbox + a fabric handle for sending.
pub struct Endpoint<M> {
    pub id: InstanceId,
    rx: Receiver<(InstanceId, M)>,
    fabric: Fabric<M>,
}

impl<M: WireCost + Send + 'static> Fabric<M> {
    pub fn new(link: LinkModel, real_sleep: bool) -> Self {
        Fabric {
            shared: Arc::new(Shared {
                senders: Mutex::new(HashMap::new()),
                link,
                stats: Mutex::new(NetStats::default()),
                real_sleep,
            }),
        }
    }

    /// Attach an instance; returns its endpoint (single consumer).
    pub fn attach(&self, id: InstanceId) -> Endpoint<M> {
        let (tx, rx) = channel();
        self.shared.senders.lock().unwrap().insert(id, tx);
        Endpoint {
            id,
            rx,
            fabric: self.clone(),
        }
    }

    /// Remove an instance (simulating failure — its inbox closes and
    /// subsequent sends error out, which peers' timeouts surface).
    pub fn detach(&self, id: InstanceId) {
        self.shared.senders.lock().unwrap().remove(&id);
    }

    pub fn link(&self) -> &LinkModel {
        &self.shared.link
    }

    pub fn stats(&self) -> NetStats {
        self.shared.stats.lock().unwrap().clone()
    }

    /// Send with modeled wire time (blocking the caller, like a
    /// synchronous NCCL send). Returns the modeled seconds.
    pub fn send(&self, from: InstanceId, to: InstanceId, msg: M)
                -> Result<f64, NetError> {
        let t = match msg.wire_cost() {
            Some((bytes, calls, src_dram, dst_dram)) => {
                let t = self
                    .shared
                    .link
                    .transfer_seconds(bytes, calls, src_dram, dst_dram);
                let mut s = self.shared.stats.lock().unwrap();
                s.payload_bytes += bytes as u64;
                s.api_calls += calls as u64;
                s.busy_seconds += t;
                s.messages += 1;
                t
            }
            None => {
                let t = self.shared.link.control_latency_s();
                let mut s = self.shared.stats.lock().unwrap();
                s.messages += 1;
                s.busy_seconds += t;
                t
            }
        };
        if self.shared.real_sleep && t > 0.0 {
            std::thread::sleep(Duration::from_secs_f64(t));
        }
        let senders = self.shared.senders.lock().unwrap();
        let tx = senders.get(&to).ok_or(NetError::Unknown(to))?;
        tx.send((from, msg))
            .map_err(|_| NetError::Disconnected(to))?;
        Ok(t)
    }
}

impl<M> Endpoint<M> {
    /// Blocking receive.
    pub fn recv(&self) -> Option<(InstanceId, M)> {
        self.rx.recv().ok()
    }

    pub fn recv_timeout(&self, d: Duration)
                        -> Result<(InstanceId, M), NetError> {
        self.rx.recv_timeout(d).map_err(|e| match e {
            RecvTimeoutError::Timeout => NetError::Timeout,
            RecvTimeoutError::Disconnected => NetError::Disconnected(self.id),
        })
    }

    pub fn try_recv(&self) -> Option<(InstanceId, M)> {
        self.rx.try_recv().ok()
    }

    pub fn fabric(&self) -> &Fabric<M> {
        &self.fabric
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[derive(Debug, PartialEq)]
    enum TestMsg {
        Ctl(u32),
        Bulk(usize, usize), // bytes, calls
    }

    impl WireCost for TestMsg {
        fn wire_cost(&self) -> Option<(usize, usize, bool, bool)> {
            match self {
                TestMsg::Ctl(_) => None,
                TestMsg::Bulk(b, c) => Some((*b, *c, false, false)),
            }
        }
    }

    fn fabric() -> Fabric<TestMsg> {
        Fabric::new(LinkModel::default(), false)
    }

    #[test]
    fn point_to_point_delivery() {
        let f = fabric();
        let a = f.attach(InstanceId(0));
        let b = f.attach(InstanceId(1));
        f.send(InstanceId(0), InstanceId(1), TestMsg::Ctl(7)).unwrap();
        let (from, msg) = b.recv().unwrap();
        assert_eq!(from, InstanceId(0));
        assert_eq!(msg, TestMsg::Ctl(7));
        drop(a);
    }

    #[test]
    fn unknown_destination_errors() {
        let f = fabric();
        let _a = f.attach(InstanceId(0));
        assert!(matches!(
            f.send(InstanceId(0), InstanceId(9), TestMsg::Ctl(0)),
            Err(NetError::Unknown(_))
        ));
    }

    #[test]
    fn detach_simulates_failure() {
        let f = fabric();
        let _a = f.attach(InstanceId(0));
        let b = f.attach(InstanceId(1));
        f.detach(InstanceId(1));
        assert!(f
            .send(InstanceId(0), InstanceId(1), TestMsg::Ctl(1))
            .is_err());
        drop(b);
    }

    #[test]
    fn stats_account_bulk_and_control() {
        let f = fabric();
        let _a = f.attach(InstanceId(0));
        let _b = f.attach(InstanceId(1));
        f.send(InstanceId(0), InstanceId(1), TestMsg::Ctl(0)).unwrap();
        let t = f
            .send(InstanceId(0), InstanceId(1), TestMsg::Bulk(1 << 20, 16))
            .unwrap();
        assert!(t > 0.0);
        let s = f.stats();
        assert_eq!(s.messages, 2);
        assert_eq!(s.payload_bytes, 1 << 20);
        assert_eq!(s.api_calls, 16);
        assert!(s.busy_seconds > 0.0);
    }

    #[test]
    fn threaded_ping_pong() {
        let f = fabric();
        let a = f.attach(InstanceId(0));
        let b = f.attach(InstanceId(1));
        let fb = f.clone();
        let h = std::thread::spawn(move || {
            let (from, msg) = b.recv().unwrap();
            assert_eq!(msg, TestMsg::Ctl(1));
            fb.send(InstanceId(1), from, TestMsg::Ctl(2)).unwrap();
        });
        f.send(InstanceId(0), InstanceId(1), TestMsg::Ctl(1)).unwrap();
        let (_, reply) = a.recv().unwrap();
        assert_eq!(reply, TestMsg::Ctl(2));
        h.join().unwrap();
    }

    #[test]
    fn timeout_receive() {
        let f = fabric();
        let a = f.attach(InstanceId(0));
        assert!(matches!(
            a.recv_timeout(Duration::from_millis(10)),
            Err(NetError::Timeout)
        ));
    }
}
