//! Deterministic fault injection for the message fabric (ISSUE 6
//! tentpole, part 1).
//!
//! A [`FaultPlan`] attached to [`crate::net::Fabric`] perturbs every
//! `send` on a per-directed-link basis: silent drops, duplicated
//! deliveries, bounded reordering (a message is held back and released
//! behind later traffic on the same link), added latency jitter, and
//! hard directed partitions ([`FaultPlan::isolate`] / [`FaultPlan::
//! heal`]). Every probabilistic choice draws from a per-link
//! [`crate::util::rng::Rng`] stream derived from the plan seed and the
//! `(from, to)` pair alone — concurrent senders on different links
//! cannot perturb each other's streams, so a seeded run is replayable
//! regardless of thread interleaving.
//!
//! The plan only *decides*; the fabric owns the mechanics (cloning for
//! duplication, the per-link holdback buffer for reordering, the
//! dropped/duplicated/reordered counters on `NetStats`). With no plan
//! installed the fabric's send path is behaviorally identical to the
//! fault-free build — no RNG draws, no extra state.

use std::collections::{HashMap, HashSet};

use crate::mempool::InstanceId;
use crate::util::rng::Rng;

/// Maximum messages held back per link for reordering; a full buffer
/// forces delivery so reordering depth stays bounded.
pub const REORDER_CAP: usize = 3;

/// Per-link fault probabilities. `Default` is the fault-free link.
#[derive(Clone, Debug, Default)]
pub struct LinkFaults {
    /// P(message silently lost) — the sender still pays wire time and
    /// sees `Ok`, exactly like a datagram dropped downstream.
    pub drop: f64,
    /// P(message delivered twice).
    pub duplicate: f64,
    /// P(message held back and delivered *after* later traffic on the
    /// same link) — bounded by [`REORDER_CAP`].
    pub reorder: f64,
    /// Added latency: uniform in `[0, jitter_s)` modeled seconds.
    pub jitter_s: f64,
}

impl LinkFaults {
    fn is_clean(&self) -> bool {
        self.drop <= 0.0
            && self.duplicate <= 0.0
            && self.reorder <= 0.0
            && self.jitter_s <= 0.0
    }
}

/// What the fabric should do with one send.
#[derive(Debug, PartialEq)]
pub enum FaultDecision {
    /// Deliver `copies` copies (1 = normal, 2 = duplicated) after
    /// `extra_s` additional modeled seconds of jitter.
    Deliver { copies: u32, extra_s: f64 },
    /// Silently lose the message (partition or random drop).
    Drop,
    /// Hold the message back; the fabric releases it behind the next
    /// delivered message on the same link.
    HoldBack { extra_s: f64 },
}

/// Seeded, replayable fault schedule over directed links.
#[derive(Debug)]
pub struct FaultPlan {
    seed: u64,
    default: LinkFaults,
    links: HashMap<(InstanceId, InstanceId), LinkFaults>,
    isolated: HashSet<(InstanceId, InstanceId)>,
    rngs: HashMap<(InstanceId, InstanceId), Rng>,
}

impl FaultPlan {
    pub fn new(seed: u64) -> Self {
        FaultPlan {
            seed,
            default: LinkFaults::default(),
            links: HashMap::new(),
            isolated: HashSet::new(),
            rngs: HashMap::new(),
        }
    }

    /// Fault profile applied to every link without an explicit override.
    pub fn set_default(&mut self, faults: LinkFaults) -> &mut Self {
        self.default = faults;
        self
    }

    /// Override the profile for one directed link `from -> to`.
    pub fn set_link(
        &mut self,
        from: InstanceId,
        to: InstanceId,
        faults: LinkFaults,
    ) -> &mut Self {
        self.links.insert((from, to), faults);
        self
    }

    /// Directed partition: every `from -> to` message is dropped until
    /// [`Self::heal`]. (Partition both directions with two calls.)
    pub fn isolate(&mut self, from: InstanceId, to: InstanceId) {
        self.isolated.insert((from, to));
    }

    /// Lift a directed partition installed by [`Self::isolate`].
    pub fn heal(&mut self, from: InstanceId, to: InstanceId) {
        self.isolated.remove(&(from, to));
    }

    pub fn is_isolated(&self, from: InstanceId, to: InstanceId) -> bool {
        self.isolated.contains(&(from, to))
    }

    fn faults_for(&self, link: (InstanceId, InstanceId)) -> &LinkFaults {
        self.links.get(&link).unwrap_or(&self.default)
    }

    /// Per-link RNG stream: seeded from the plan seed and the directed
    /// link id only, so creation order and cross-link interleaving
    /// never shift a link's schedule.
    fn rng_for(&mut self, link: (InstanceId, InstanceId)) -> &mut Rng {
        let seed = self.seed;
        self.rngs.entry(link).or_insert_with(|| {
            let tag = ((link.0 .0 as u64) << 32) | link.1 .0 as u64;
            Rng::new(seed ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15))
        })
    }

    /// Decide the fate of one message on `from -> to`. `held` is the
    /// link's current holdback depth (the plan refuses to exceed
    /// [`REORDER_CAP`]). Clean links make no RNG draws, keeping their
    /// streams untouched by unrelated traffic.
    pub fn decide(
        &mut self,
        from: InstanceId,
        to: InstanceId,
        held: usize,
    ) -> FaultDecision {
        let link = (from, to);
        if self.isolated.contains(&link) {
            return FaultDecision::Drop;
        }
        let f = self.faults_for(link).clone();
        if f.is_clean() {
            return FaultDecision::Deliver { copies: 1, extra_s: 0.0 };
        }
        let rng = self.rng_for(link);
        // Fixed draw order (drop, jitter, duplicate, reorder) so the
        // schedule is a pure function of (seed, link, send index).
        if f.drop > 0.0 && rng.chance(f.drop) {
            return FaultDecision::Drop;
        }
        let extra_s = if f.jitter_s > 0.0 {
            rng.range_f64(0.0, f.jitter_s)
        } else {
            0.0
        };
        if f.duplicate > 0.0 && rng.chance(f.duplicate) {
            return FaultDecision::Deliver { copies: 2, extra_s };
        }
        if f.reorder > 0.0 && held < REORDER_CAP && rng.chance(f.reorder) {
            return FaultDecision::HoldBack { extra_s };
        }
        FaultDecision::Deliver { copies: 1, extra_s }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const A: InstanceId = InstanceId(1);
    const B: InstanceId = InstanceId(2);
    const C: InstanceId = InstanceId(3);

    #[test]
    fn clean_plan_always_delivers_without_rng_draws() {
        let mut p = FaultPlan::new(7);
        for _ in 0..100 {
            assert_eq!(
                p.decide(A, B, 0),
                FaultDecision::Deliver { copies: 1, extra_s: 0.0 }
            );
        }
        // No RNG stream was ever materialized.
        assert!(p.rngs.is_empty());
    }

    #[test]
    fn certain_drop_and_certain_duplicate() {
        let mut p = FaultPlan::new(7);
        p.set_link(A, B, LinkFaults { drop: 1.0, ..Default::default() });
        p.set_link(A, C, LinkFaults { duplicate: 1.0, ..Default::default() });
        assert_eq!(p.decide(A, B, 0), FaultDecision::Drop);
        assert!(matches!(
            p.decide(A, C, 0),
            FaultDecision::Deliver { copies: 2, .. }
        ));
    }

    #[test]
    fn reorder_respects_holdback_cap() {
        let mut p = FaultPlan::new(7);
        p.set_default(LinkFaults { reorder: 1.0, ..Default::default() });
        assert!(matches!(p.decide(A, B, 0), FaultDecision::HoldBack { .. }));
        // At the cap the plan must force delivery.
        assert!(matches!(
            p.decide(A, B, REORDER_CAP),
            FaultDecision::Deliver { copies: 1, .. }
        ));
    }

    #[test]
    fn isolate_and_heal_are_directed() {
        let mut p = FaultPlan::new(7);
        p.isolate(A, B);
        assert_eq!(p.decide(A, B, 0), FaultDecision::Drop);
        // Reverse direction unaffected.
        assert!(matches!(
            p.decide(B, A, 0),
            FaultDecision::Deliver { copies: 1, .. }
        ));
        p.heal(A, B);
        assert!(matches!(
            p.decide(A, B, 0),
            FaultDecision::Deliver { copies: 1, .. }
        ));
    }

    #[test]
    fn jitter_adds_bounded_latency() {
        let mut p = FaultPlan::new(7);
        p.set_default(LinkFaults { jitter_s: 0.5, ..Default::default() });
        for _ in 0..100 {
            match p.decide(A, B, 0) {
                FaultDecision::Deliver { copies: 1, extra_s } => {
                    assert!((0.0..0.5).contains(&extra_s));
                }
                other => panic!("unexpected decision {other:?}"),
            }
        }
    }

    #[test]
    fn per_link_streams_are_replayable_regardless_of_interleaving() {
        let faults = LinkFaults { drop: 0.3, ..Default::default() };
        // Run 1: A->B decisions interleaved with heavy A->C traffic.
        let mut p1 = FaultPlan::new(42);
        p1.set_default(faults.clone());
        let mut ab1 = Vec::new();
        for i in 0..50 {
            for _ in 0..i % 5 {
                p1.decide(A, C, 0);
            }
            ab1.push(p1.decide(A, B, 0));
        }
        // Run 2: A->B alone. The schedule must match exactly.
        let mut p2 = FaultPlan::new(42);
        p2.set_default(faults);
        let ab2: Vec<_> = (0..50).map(|_| p2.decide(A, B, 0)).collect();
        assert_eq!(ab1, ab2);
    }

    #[test]
    fn drop_rate_tracks_probability() {
        let mut p = FaultPlan::new(1234);
        p.set_default(LinkFaults { drop: 0.2, ..Default::default() });
        let n = 10_000;
        let dropped = (0..n)
            .filter(|_| p.decide(A, B, 0) == FaultDecision::Drop)
            .count();
        let rate = dropped as f64 / n as f64;
        assert!((rate - 0.2).abs() < 0.02, "rate={rate}");
    }
}
