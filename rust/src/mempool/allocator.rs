//! Fixed-size block allocator: O(1) alloc/free over a free-list stack.
//!
//! The paper's MemPool manages all memory as fixed-size blocks (§4.1);
//! fixed-size means no fragmentation and no compaction, and a stack-based
//! free list keeps recently-freed (cache-warm) slots hot.

/// Allocator over `capacity` equally-sized slots.
#[derive(Clone, Debug)]
pub struct BlockAllocator {
    free: Vec<u32>,
    allocated: Vec<bool>,
    high_water: usize,
}

#[derive(Debug, thiserror::Error, PartialEq)]
pub enum AllocError {
    #[error("out of blocks: requested {requested}, free {free}")]
    OutOfBlocks { requested: usize, free: usize },
    #[error("double free of block {0}")]
    DoubleFree(u32),
    #[error("block index {0} out of range")]
    OutOfRange(u32),
}

impl BlockAllocator {
    pub fn new(capacity: usize) -> Self {
        BlockAllocator {
            // Reverse so allocation order starts at slot 0 (nice for tests
            // and for arena locality).
            free: (0..capacity as u32).rev().collect(),
            allocated: vec![false; capacity],
            high_water: 0,
        }
    }

    pub fn capacity(&self) -> usize {
        self.allocated.len()
    }

    pub fn free_count(&self) -> usize {
        self.free.len()
    }

    pub fn used(&self) -> usize {
        self.capacity() - self.free_count()
    }

    /// Peak simultaneous usage since creation.
    pub fn high_water(&self) -> usize {
        self.high_water
    }

    pub fn can_alloc(&self, n: usize) -> bool {
        self.free.len() >= n
    }

    /// Allocate `n` blocks; all-or-nothing.
    pub fn alloc(&mut self, n: usize) -> Result<Vec<u32>, AllocError> {
        if self.free.len() < n {
            return Err(AllocError::OutOfBlocks {
                requested: n,
                free: self.free.len(),
            });
        }
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            let idx = self.free.pop().unwrap();
            debug_assert!(!self.allocated[idx as usize]);
            self.allocated[idx as usize] = true;
            out.push(idx);
        }
        self.high_water = self.high_water.max(self.used());
        Ok(out)
    }

    /// Free blocks; duplicate or out-of-range frees are errors.
    pub fn free(&mut self, blocks: &[u32]) -> Result<(), AllocError> {
        // Validate before mutating (all-or-nothing on bad input).
        for &b in blocks {
            match self.allocated.get(b as usize) {
                None => return Err(AllocError::OutOfRange(b)),
                Some(false) => return Err(AllocError::DoubleFree(b)),
                Some(true) => {}
            }
        }
        // A duplicate *within* this call is also a double free.
        let mut seen = crate::util::rng::DetSet::with_capacity_and_hasher(
            blocks.len(),
            Default::default(),
        );
        for &b in blocks {
            if !seen.insert(b) {
                return Err(AllocError::DoubleFree(b));
            }
        }
        for &b in blocks {
            self.allocated[b as usize] = false;
            self.free.push(b);
        }
        Ok(())
    }

    pub fn is_allocated(&self, block: u32) -> bool {
        self.allocated.get(block as usize).copied().unwrap_or(false)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::proptest;

    #[test]
    fn alloc_free_roundtrip() {
        let mut a = BlockAllocator::new(8);
        let blocks = a.alloc(5).unwrap();
        assert_eq!(blocks.len(), 5);
        assert_eq!(a.used(), 5);
        a.free(&blocks).unwrap();
        assert_eq!(a.used(), 0);
        assert_eq!(a.free_count(), 8);
    }

    #[test]
    fn all_or_nothing_alloc() {
        let mut a = BlockAllocator::new(4);
        a.alloc(3).unwrap();
        let err = a.alloc(2).unwrap_err();
        assert_eq!(
            err,
            AllocError::OutOfBlocks {
                requested: 2,
                free: 1
            }
        );
        assert_eq!(a.used(), 3, "failed alloc must not leak");
    }

    #[test]
    fn double_free_detected() {
        let mut a = BlockAllocator::new(4);
        let b = a.alloc(1).unwrap();
        a.free(&b).unwrap();
        assert_eq!(a.free(&b).unwrap_err(), AllocError::DoubleFree(b[0]));
    }

    #[test]
    fn duplicate_in_one_call_detected() {
        let mut a = BlockAllocator::new(4);
        let b = a.alloc(1).unwrap();
        let dup = vec![b[0], b[0]];
        assert!(matches!(
            a.free(&dup).unwrap_err(),
            AllocError::DoubleFree(_)
        ));
        // Validation happened before mutation: block still allocated.
        assert!(a.is_allocated(b[0]));
    }

    #[test]
    fn out_of_range_free() {
        let mut a = BlockAllocator::new(4);
        assert_eq!(a.free(&[99]).unwrap_err(), AllocError::OutOfRange(99));
    }

    #[test]
    fn high_water_tracks_peak() {
        let mut a = BlockAllocator::new(10);
        let b1 = a.alloc(7).unwrap();
        a.free(&b1).unwrap();
        a.alloc(2).unwrap();
        assert_eq!(a.high_water(), 7);
    }

    #[test]
    fn prop_no_leaks_no_duplicates() {
        proptest(100, |g| {
            let cap = g.usize(1, 128);
            let mut a = BlockAllocator::new(cap);
            let mut live: Vec<Vec<u32>> = vec![];
            for _ in 0..g.usize(1, 60) {
                if g.bool() || live.is_empty() {
                    let n = g.usize(0, cap / 2 + 1);
                    if let Ok(bs) = a.alloc(n) {
                        live.push(bs);
                    }
                } else {
                    let i = g.usize(0, live.len() - 1);
                    let bs = live.swap_remove(i);
                    a.free(&bs).unwrap();
                }
                // Invariant: live handles are exactly the allocated set.
                let live_count: usize = live.iter().map(Vec::len).sum();
                assert_eq!(a.used(), live_count);
                let mut all: Vec<u32> =
                    live.iter().flatten().copied().collect();
                all.sort_unstable();
                let before = all.len();
                all.dedup();
                assert_eq!(before, all.len(), "duplicate block handed out");
            }
        });
    }
}
