//! MemPool — the paper's elastic memory pool (§4).
//!
//! One `MemPool` runs inside every inference instance and manages that
//! instance's memory across two tiers (HBM-sim and DRAM-sim), both backed
//! by real host arenas. It owns:
//!
//! * a fixed-size block allocator per tier ([`allocator`], [`tier`]);
//! * the token-indexed radix tree mapping prompt prefixes to historical
//!   KV cache blocks ([`index`]), with TTL + LRU leaf eviction;
//! * the Table-1 API facade ([`api`]): `alloc_mem`, `free_mem`, `insert`,
//!   `match_prefix`, `delete`, `swap_out`, `swap_in`;
//! * the distributed-transfer protocol datatypes ([`transfer`]) used by
//!   `transfer` / `transfer_with_insert` over the [`crate::net`] fabric.

pub mod allocator;
pub mod api;
pub mod block;
pub mod index;
pub mod index_ref;
pub mod tier;
pub mod transfer;

pub use api::{MatchResult, MemPool, PoolError, PoolStats};
pub use block::{BlockAddr, BlockGeometry, InstanceId, Tier};
pub use index::{GroupList, RadixIndex, TouchStats, DEFERRED_TOUCH_CAP};
pub use index_ref::RefRadixIndex;
pub use transfer::{TransferFlags, TransferMode, TransferRequest};
