//! The MemPool API facade — Table 1 of the paper.
//!
//! One `MemPool` per inference instance, owned by that instance's thread
//! (the paper's MemPool also runs *within* each instance, §4). The
//! distributed APIs (`transfer`, `transfer_with_insert`) are driven by
//! the instance event loop over the [`crate::net`] fabric using the
//! local halves implemented here (`export_blocks` on the sender,
//! `import_blocks` + `insert` on the receiver).

use std::sync::atomic::{AtomicU64, Ordering};

use crate::util::rng::DetMap;

use super::allocator::AllocError;
use super::block::{BlockAddr, BlockGeometry, InstanceId, Tier};
use super::index::{BlockGroup, GroupList, IndexMatch, RadixIndex};
use super::tier::Arena;

/// Pool-level counters (exported into [`crate::metrics::Metrics`]).
/// Obtained as a point-in-time snapshot from [`MemPool::stats`]: the
/// match-path counters live in atomics (the match path takes `&self`)
/// and the deferred-touch counters come from the index's touch queue.
#[derive(Clone, Debug, Default)]
pub struct PoolStats {
    pub inserts: u64,
    pub insert_dup_blocks: u64,
    pub matches: u64,
    pub match_hit_token_blocks: u64,
    pub evicted_blocks: u64,
    pub expired_blocks: u64,
    pub swapped_out: u64,
    pub swapped_in: u64,
    pub alloc_failures: u64,
    /// Leaf LRU refreshes queued by `&self` matches
    /// ([`super::index::TouchStats::deferred`]).
    pub touches_deferred: u64,
    /// Deferred refreshes applied by a later `&mut` operation.
    pub touches_drained: u64,
    /// Touches dropped at queue capacity (those leaves keep an older —
    /// eviction-safe — access time, so LRU may under-credit recency but
    /// never over-credits it).
    pub touches_dropped: u64,
}

#[derive(Debug, thiserror::Error)]
pub enum PoolError {
    #[error("allocation failed: {0}")]
    Alloc(#[from] AllocError),
    #[error("address {0} not owned by this instance")]
    NotLocal(BlockAddr),
    #[error("capacity: cannot make room for {0} blocks")]
    Capacity(usize),
}

/// Result of `match_prefix` at pool level. Groups come back as a flat
/// [`GroupList`] — borrowed-slice handles into one allocation, not one
/// heap-cloned `Vec` per matched token-block.
#[derive(Clone, Debug, Default)]
pub struct MatchResult {
    /// Matched tokens (multiple of block_tokens).
    pub tokens: usize,
    /// One group per matched token-block.
    pub groups: GroupList,
}

impl MatchResult {
    /// Does any matched block live in DRAM (needs swap_in before use)?
    pub fn needs_swap_in(&self) -> bool {
        self.groups.flat().iter().any(|a| a.tier == Tier::Dram)
    }

    pub fn flat_addrs(&self) -> Vec<BlockAddr> {
        self.groups.flat().to_vec()
    }
}

pub struct MemPool {
    instance: InstanceId,
    geom: BlockGeometry,
    hbm: Arena,
    dram: Arena,
    index: RadixIndex,
    stats: PoolStats,
    /// Match-path counters, atomic because [`Self::match_prefix`] takes
    /// `&self` (concurrent readers share the pool).
    matches: AtomicU64,
    match_hit_token_blocks: AtomicU64,
    /// Token prefixes the LRU evicted since the last
    /// [`Self::take_evicted_prefixes`] — the honest-eviction signal the
    /// instance loop reports upstream as `DeltaEvent::Expire` so the
    /// global scheduler stops believing in KV this pool dropped.
    evict_reports: Vec<Vec<u32>>,
}

impl MemPool {
    pub fn new(
        instance: InstanceId,
        geom: BlockGeometry,
        hbm_blocks: usize,
        dram_blocks: usize,
        index_ttl_s: f64,
        materialize: bool,
    ) -> Self {
        MemPool {
            instance,
            geom,
            hbm: Arena::new(hbm_blocks, geom.floats_per_block(), materialize),
            dram: Arena::new(dram_blocks, geom.floats_per_block(), materialize),
            index: RadixIndex::new(geom.block_tokens, index_ttl_s),
            stats: PoolStats::default(),
            matches: AtomicU64::new(0),
            match_hit_token_blocks: AtomicU64::new(0),
            evict_reports: vec![],
        }
    }

    pub fn instance(&self) -> InstanceId {
        self.instance
    }

    pub fn geometry(&self) -> &BlockGeometry {
        &self.geom
    }

    /// Counter snapshot: the `&mut`-path counters plus the atomic
    /// match-path counters and the index's deferred-touch counters.
    pub fn stats(&self) -> PoolStats {
        let mut s = self.stats.clone();
        // ordering: Relaxed — monotonic stat counters; reads are
        // point-in-time snapshots with no cross-field consistency.
        s.matches = self.matches.load(Ordering::Relaxed);
        // ordering: Relaxed — same counter family as above.
        s.match_hit_token_blocks =
            self.match_hit_token_blocks.load(Ordering::Relaxed);
        let ts = self.index.touch_stats();
        s.touches_deferred = ts.deferred;
        s.touches_drained = ts.drained;
        s.touches_dropped = ts.dropped;
        s
    }

    pub fn free_blocks(&self, tier: Tier) -> usize {
        self.arena(tier).allocator().free_count()
    }

    pub fn used_blocks(&self, tier: Tier) -> usize {
        self.arena(tier).allocator().used()
    }

    pub fn capacity(&self, tier: Tier) -> usize {
        self.arena(tier).allocator().capacity()
    }

    /// Token-blocks of historical KV currently indexed.
    pub fn indexed_token_blocks(&self) -> usize {
        self.index.total_token_blocks()
    }

    fn arena(&self, tier: Tier) -> &Arena {
        match tier {
            Tier::Hbm => &self.hbm,
            Tier::Dram => &self.dram,
        }
    }

    fn arena_mut(&mut self, tier: Tier) -> &mut Arena {
        match tier {
            Tier::Hbm => &mut self.hbm,
            Tier::Dram => &mut self.dram,
        }
    }

    // ------------------------------------------------------------------
    // Memory block APIs (Table 1: alloc_mem / free_mem)
    // ------------------------------------------------------------------

    /// Allocate `n` blocks in `tier`; addresses encode this instance.
    pub fn alloc_mem(&mut self, n: usize, tier: Tier)
                     -> Result<Vec<BlockAddr>, PoolError> {
        let inst = self.instance;
        match self.arena_mut(tier).alloc(n) {
            Ok(idxs) => Ok(idxs
                .into_iter()
                .map(|i| BlockAddr::new(inst, tier, i))
                .collect()),
            Err(e) => {
                self.stats.alloc_failures += 1;
                Err(e.into())
            }
        }
    }

    pub fn free_mem(&mut self, addrs: &[BlockAddr]) -> Result<(), PoolError> {
        for a in addrs {
            if a.instance != self.instance {
                return Err(PoolError::NotLocal(*a));
            }
        }
        let mut hbm = vec![];
        let mut dram = vec![];
        for a in addrs {
            match a.tier {
                Tier::Hbm => hbm.push(a.index),
                Tier::Dram => dram.push(a.index),
            }
        }
        self.hbm.free(&hbm)?;
        self.dram.free(&dram)?;
        Ok(())
    }

    /// Make at least `n` HBM blocks free: first swap historical KV out to
    /// DRAM, then (if DRAM is full too) evict LRU entries outright.
    /// Blocks not owned by the index (active KV) are never touched.
    pub fn ensure_free_hbm(&mut self, n: usize, now: f64)
                           -> Result<(), PoolError> {
        if self.free_blocks(Tier::Hbm) >= n {
            return Ok(());
        }
        // TTL housekeeping first — free expiry is better than eviction.
        self.expire(now);
        while self.free_blocks(Tier::Hbm) < n {
            let need_groups = self
                .geom
                .blocks_per_token_block()
                .max(1);
            let deficit = n - self.free_blocks(Tier::Hbm);
            let want_tb = deficit.div_ceil(need_groups);
            if self.free_blocks(Tier::Dram) >= deficit {
                let moved = self.swap_out(want_tb)?;
                if moved > 0 {
                    continue;
                }
            }
            let evicted = self.evict(want_tb);
            if evicted == 0 {
                self.stats.alloc_failures += 1;
                return Err(PoolError::Capacity(n));
            }
        }
        Ok(())
    }

    // ------------------------------------------------------------------
    // Index APIs (Table 1: insert / match / delete; + evict, expire)
    // ------------------------------------------------------------------

    /// Retire active KV into the historical index. Duplicate block groups
    /// (prefix already cached) are freed immediately. Returns the number
    /// of token-blocks newly indexed.
    pub fn insert(&mut self, tokens: &[u32], groups: Vec<BlockGroup>,
                  now: f64) -> Result<usize, PoolError> {
        let offered = groups.len();
        let dups = self.index.insert(tokens, &groups, now);
        let n_dup = dups.len();
        for g in dups {
            self.free_mem(&g)?;
        }
        self.stats.inserts += 1;
        self.stats.insert_dup_blocks += n_dup as u64;
        Ok(offered.saturating_sub(n_dup))
    }

    /// [`Self::insert`] over a [`GroupList`] — the engine retire path,
    /// which assembles prefix + fresh groups without materializing
    /// per-group `Vec`s.
    pub fn insert_list(&mut self, tokens: &[u32], groups: &GroupList,
                       now: f64) -> Result<usize, PoolError> {
        let offered = groups.len();
        let dup = self.index.insert_list(tokens, groups, now);
        let n_dup = dup.len();
        self.free_mem(dup.flat())?;
        self.stats.inserts += 1;
        self.stats.insert_dup_blocks += n_dup as u64;
        Ok(offered.saturating_sub(n_dup))
    }

    /// Match and pin in one step — the engine's admission path. The
    /// pinned prefix cannot be evicted/swapped/expired until
    /// [`Self::unpin`] (call it with the same token slice at retire).
    pub fn match_and_pin(&mut self, tokens: &[u32], now: f64) -> MatchResult {
        let m = self.match_prefix(tokens, now);
        let pinned = self.index.pin(&tokens[..m.tokens]);
        debug_assert_eq!(pinned, m.tokens);
        m
    }

    /// Release a [`Self::match_and_pin`] pin. Pass the same pinned slice
    /// (`&tokens[..match.tokens]`).
    pub fn unpin(&mut self, pinned_tokens: &[u32]) {
        self.index.unpin(pinned_tokens);
    }

    /// Longest cached prefix of `tokens`. Takes `&self` — the index
    /// match path defers its LRU maintenance (see
    /// [`super::index::RadixIndex::match_prefix`]), so any number of
    /// lookups may run concurrently against a shared pool.
    pub fn match_prefix(&self, tokens: &[u32], now: f64) -> MatchResult {
        let IndexMatch { tokens: t, groups } =
            self.index.match_prefix(tokens, now);
        // ordering: Relaxed — independent stat counters; no other
        // memory is published through them.
        self.matches.fetch_add(1, Ordering::Relaxed);
        // ordering: Relaxed — same counter family as above.
        self.match_hit_token_blocks
            .fetch_add(groups.len() as u64, Ordering::Relaxed);
        MatchResult { tokens: t, groups }
    }

    /// Delete a cached prompt (and everything extending it); frees blocks.
    pub fn delete(&mut self, tokens: &[u32]) -> Result<usize, PoolError> {
        let freed = self.index.delete(tokens);
        let n = freed.len();
        self.free_mem(&freed)?;
        Ok(n)
    }

    /// Undrained eviction reports beyond this collapse to one
    /// conservative whole-view expiry (empty prefix): honest — the GS
    /// may only *under*-believe — and bounded for pool users (benches,
    /// embedders) that never call [`Self::take_evicted_prefixes`].
    const MAX_EVICT_REPORTS: usize = 1024;

    /// Evict `n` token-blocks LRU-first; returns token-blocks evicted.
    /// Each victim's token prefix is queued for
    /// [`Self::take_evicted_prefixes`].
    pub fn evict(&mut self, n_token_blocks: usize) -> usize {
        let (freed, mut prefixes) =
            self.index.evict_lru_report(n_token_blocks);
        if self.evict_reports.len() + prefixes.len()
            > Self::MAX_EVICT_REPORTS
        {
            // Nobody is draining reports (or eviction outpaces the
            // drain): collapse to "this instance's whole view is
            // stale". An empty Expire prefix clears the instance's
            // entire global-tree claim — a superset of every queued
            // report, so correctness (no over-belief) is preserved
            // while memory stays bounded.
            self.evict_reports.clear();
            self.evict_reports.push(vec![]);
        } else {
            self.evict_reports.append(&mut prefixes);
        }
        let n = freed.len();
        self.stats.evicted_blocks += n as u64;
        let _ = self.free_mem(&freed);
        n / self.geom.blocks_per_token_block().max(1)
    }

    /// Drain the token prefixes evicted since the last call (each the
    /// `DeltaEvent::Expire` shape: that prefix and every extension is
    /// gone from this pool). The instance loop reports them to the
    /// leader so global-tree routing stops counting on dropped KV —
    /// replacing TTL guesswork with the honest signal (§6 Discussion).
    pub fn take_evicted_prefixes(&mut self) -> Vec<Vec<u32>> {
        std::mem::take(&mut self.evict_reports)
    }

    /// TTL expiry pass.
    pub fn expire(&mut self, now: f64) -> usize {
        let freed = self.index.expire(now);
        let n = freed.len();
        self.stats.expired_blocks += n as u64;
        let _ = self.free_mem(&freed);
        n
    }

    // ------------------------------------------------------------------
    // Swap APIs (Table 1: swap_out / swap_in)
    // ------------------------------------------------------------------

    /// Swap up to `n` LRU *indexed* token-blocks from HBM to DRAM.
    /// Returns blocks moved (allocatable-block granularity).
    pub fn swap_out(&mut self, n_token_blocks: usize)
                    -> Result<usize, PoolError> {
        let victims = self.index.lru_addrs(n_token_blocks, |a| {
            a.tier == Tier::Hbm
        });
        if victims.is_empty() {
            return Ok(0);
        }
        let mut remap = DetMap::default();
        let mut tmp = vec![0.0f32; self.geom.floats_per_block()];
        for old in victims {
            if self.dram.allocator().free_count() == 0 {
                break;
            }
            let new_idx = self.dram.alloc(1)?[0];
            if self.hbm.is_materialized() {
                self.hbm.read_block(old.index, &mut tmp);
                self.dram.write_block(new_idx, &tmp);
            }
            self.hbm.free(&[old.index])?;
            remap.insert(
                old,
                BlockAddr::new(self.instance, Tier::Dram, new_idx),
            );
        }
        self.index.remap(&remap);
        self.stats.swapped_out += remap.len() as u64;
        Ok(remap.len())
    }

    /// Swap the given DRAM blocks back into HBM; returns the new
    /// addresses (in input order). The index is remapped.
    pub fn swap_in(&mut self, addrs: &[BlockAddr])
                   -> Result<Vec<BlockAddr>, PoolError> {
        let mut remap = DetMap::default();
        let mut out = Vec::with_capacity(addrs.len());
        let mut tmp = vec![0.0f32; self.geom.floats_per_block()];
        for &old in addrs {
            if old.instance != self.instance {
                return Err(PoolError::NotLocal(old));
            }
            if old.tier == Tier::Hbm {
                out.push(old); // already resident
                continue;
            }
            let new_idx = self.hbm.alloc(1)?[0];
            if self.dram.is_materialized() {
                self.dram.read_block(old.index, &mut tmp);
                self.hbm.write_block(new_idx, &tmp);
            }
            self.dram.free(&[old.index])?;
            let new = BlockAddr::new(self.instance, Tier::Hbm, new_idx);
            remap.insert(old, new);
            out.push(new);
        }
        self.index.remap(&remap);
        self.stats.swapped_in += remap.len() as u64;
        Ok(out)
    }

    // ------------------------------------------------------------------
    // Data plane (engine + transfer use these local halves)
    // ------------------------------------------------------------------

    pub fn write_block(&mut self, addr: BlockAddr, data: &[f32])
                       -> Result<(), PoolError> {
        if addr.instance != self.instance {
            return Err(PoolError::NotLocal(addr));
        }
        self.arena_mut(addr.tier).write_block(addr.index, data);
        Ok(())
    }

    pub fn read_block(&self, addr: BlockAddr, out: &mut [f32])
                      -> Result<(), PoolError> {
        if addr.instance != self.instance {
            return Err(PoolError::NotLocal(addr));
        }
        self.arena(addr.tier).read_block(addr.index, out);
        Ok(())
    }

    /// Sender half of `transfer`: serialize blocks into one payload.
    pub fn export_blocks(&self, addrs: &[BlockAddr])
                         -> Result<Vec<f32>, PoolError> {
        let fpb = self.geom.floats_per_block();
        let mut out = vec![0.0f32; fpb * addrs.len()];
        for (i, &a) in addrs.iter().enumerate() {
            self.read_block(a, &mut out[i * fpb..(i + 1) * fpb])?;
        }
        Ok(out)
    }

    /// Receiver half of `transfer`: allocate (if needed) and land the
    /// payload. Returns the destination addresses.
    pub fn import_blocks(
        &mut self,
        payload: &[f32],
        n_blocks: usize,
        dst: Option<Vec<BlockAddr>>,
        tier: Tier,
        now: f64,
    ) -> Result<Vec<BlockAddr>, PoolError> {
        let fpb = self.geom.floats_per_block();
        assert_eq!(payload.len(), fpb * n_blocks, "payload size mismatch");
        let addrs = match dst {
            Some(a) => {
                assert_eq!(a.len(), n_blocks);
                a
            }
            None => {
                if tier == Tier::Hbm {
                    self.ensure_free_hbm(n_blocks, now)?;
                }
                self.alloc_mem(n_blocks, tier)?
            }
        };
        for (i, &a) in addrs.iter().enumerate() {
            self.write_block(a, &payload[i * fpb..(i + 1) * fpb])?;
        }
        Ok(addrs)
    }

    /// Leak check: every indexed address must be allocated, and the two
    /// tiers' allocation counts must cover exactly the indexed blocks
    /// plus `active` blocks the engine holds.
    pub fn check_consistency(&self, active_blocks: usize) -> Result<(), String> {
        let indexed = self.index.all_addrs();
        for a in &indexed {
            let arena = self.arena(a.tier);
            if !arena.allocator().is_allocated(a.index) {
                return Err(format!("indexed addr {a} is not allocated"));
            }
        }
        let used = self.used_blocks(Tier::Hbm) + self.used_blocks(Tier::Dram);
        if used != indexed.len() + active_blocks {
            return Err(format!(
                "used={used} != indexed={} + active={active_blocks}",
                indexed.len()
            ));
        }
        Ok(())
    }

    /// Release every block owned by a failed remote instance (paper §4.4
    /// — called on cluster-membership change). This pool only stores its
    /// *own* blocks, so the argument filters index references to remote
    /// data in the *global* tree case; locally it is a no-op guard.
    pub fn release_remote(&mut self, _failed: InstanceId) {
        // Local pools never hold remote blocks (addresses encode owner);
        // the method exists for API parity and future multi-tenant pools.
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::proptest;

    fn geom() -> BlockGeometry {
        BlockGeometry {
            block_tokens: 4,
            layers: 2,
            n_heads: 2,
            head_dim: 4,
            aggregated: true,
        }
    }

    fn pool(hbm: usize, dram: usize) -> MemPool {
        MemPool::new(InstanceId(3), geom(), hbm, dram, 0.0, true)
    }

    fn toks(n: usize, seed: u32) -> Vec<u32> {
        (0..n as u32).map(|i| i * 7 + seed).collect()
    }

    #[test]
    fn alloc_encodes_instance() {
        let mut p = pool(4, 4);
        let a = p.alloc_mem(2, Tier::Hbm).unwrap();
        assert_eq!(a[0].instance, InstanceId(3));
        assert_eq!(a[0].tier, Tier::Hbm);
        p.free_mem(&a).unwrap();
    }

    #[test]
    fn insert_match_roundtrip_with_data() {
        let mut p = pool(8, 8);
        let t = toks(8, 0);
        let addrs = p.alloc_mem(2, Tier::Hbm).unwrap();
        let fpb = p.geometry().floats_per_block();
        p.write_block(addrs[0], &vec![1.5; fpb]).unwrap();
        p.write_block(addrs[1], &vec![2.5; fpb]).unwrap();
        let new = p
            .insert(&t, vec![vec![addrs[0]], vec![addrs[1]]], 1.0)
            .unwrap();
        assert_eq!(new, 2);
        let m = p.match_prefix(&t, 2.0);
        assert_eq!(m.tokens, 8);
        let mut buf = vec![0.0; fpb];
        p.read_block(m.groups[1][0], &mut buf).unwrap();
        assert_eq!(buf[0], 2.5);
        p.check_consistency(0).unwrap();
    }

    #[test]
    fn duplicate_insert_frees_blocks() {
        let mut p = pool(8, 8);
        let t = toks(4, 0);
        let a1 = p.alloc_mem(1, Tier::Hbm).unwrap();
        p.insert(&t, vec![a1], 1.0).unwrap();
        let a2 = p.alloc_mem(1, Tier::Hbm).unwrap();
        let newly = p.insert(&t, vec![a2], 2.0).unwrap();
        assert_eq!(newly, 0);
        // The duplicate block was freed: 1 used (the original).
        assert_eq!(p.used_blocks(Tier::Hbm), 1);
        p.check_consistency(0).unwrap();
    }

    #[test]
    fn swap_out_moves_data_and_remaps() {
        let mut p = pool(4, 4);
        let t = toks(8, 0);
        let addrs = p.alloc_mem(2, Tier::Hbm).unwrap();
        let fpb = p.geometry().floats_per_block();
        p.write_block(addrs[0], &vec![7.0; fpb]).unwrap();
        p.write_block(addrs[1], &vec![8.0; fpb]).unwrap();
        p.insert(&t, vec![vec![addrs[0]], vec![addrs[1]]], 1.0)
            .unwrap();
        let moved = p.swap_out(2).unwrap();
        assert_eq!(moved, 2);
        assert_eq!(p.used_blocks(Tier::Hbm), 0);
        assert_eq!(p.used_blocks(Tier::Dram), 2);
        let m = p.match_prefix(&t, 2.0);
        assert!(m.needs_swap_in());
        // Data survived the move.
        let mut buf = vec![0.0; fpb];
        p.read_block(m.groups[0][0], &mut buf).unwrap();
        assert_eq!(buf[0], 7.0);
        p.check_consistency(0).unwrap();
    }

    #[test]
    fn swap_in_restores_hbm() {
        let mut p = pool(4, 4);
        let t = toks(4, 0);
        let addrs = p.alloc_mem(1, Tier::Hbm).unwrap();
        let fpb = p.geometry().floats_per_block();
        p.write_block(addrs[0], &vec![3.25; fpb]).unwrap();
        p.insert(&t, vec![addrs], 1.0).unwrap();
        p.swap_out(1).unwrap();
        let m = p.match_prefix(&t, 2.0);
        let back = p.swap_in(&m.flat_addrs()).unwrap();
        assert!(back.iter().all(|a| a.tier == Tier::Hbm));
        let mut buf = vec![0.0; fpb];
        p.read_block(back[0], &mut buf).unwrap();
        assert_eq!(buf[0], 3.25);
        // Index now points at HBM again.
        assert!(!p.match_prefix(&t, 3.0).needs_swap_in());
        p.check_consistency(0).unwrap();
    }

    #[test]
    fn ensure_free_hbm_swaps_then_evicts() {
        let mut p = pool(4, 2);
        // Fill HBM with 4 indexed blocks (2 prompts).
        for (i, seed) in [(0u32, 1u32), (1, 2)] {
            let t = toks(8, seed * 100);
            let a = p.alloc_mem(2, Tier::Hbm).unwrap();
            p.insert(&t, a.into_iter().map(|x| vec![x]).collect(), i as f64)
                .unwrap();
        }
        assert_eq!(p.free_blocks(Tier::Hbm), 0);
        // Need 3 free: 2 can swap to DRAM, 1 must be evicted.
        p.ensure_free_hbm(3, 10.0).unwrap();
        assert!(p.free_blocks(Tier::Hbm) >= 3);
        assert!(p.stats().swapped_out >= 2 || p.stats().evicted_blocks >= 1);
        p.check_consistency(0).unwrap();
    }

    #[test]
    fn ensure_free_fails_when_nothing_evictable() {
        let mut p = pool(2, 0);
        // Active (un-indexed) blocks cannot be reclaimed.
        let _active = p.alloc_mem(2, Tier::Hbm).unwrap();
        assert!(p.ensure_free_hbm(1, 0.0).is_err());
    }

    #[test]
    fn export_import_roundtrip() {
        let mut src = pool(4, 4);
        let mut dst = MemPool::new(InstanceId(9), geom(), 4, 4, 0.0, true);
        let fpb = src.geometry().floats_per_block();
        let a = src.alloc_mem(2, Tier::Hbm).unwrap();
        src.write_block(a[0], &vec![1.0; fpb]).unwrap();
        src.write_block(a[1], &vec![2.0; fpb]).unwrap();
        let payload = src.export_blocks(&a).unwrap();
        let landed = dst
            .import_blocks(&payload, 2, None, Tier::Hbm, 0.0)
            .unwrap();
        assert_eq!(landed[0].instance, InstanceId(9));
        let mut buf = vec![0.0; fpb];
        dst.read_block(landed[1], &mut buf).unwrap();
        assert_eq!(buf[0], 2.0);
    }

    #[test]
    fn remote_addr_rejected() {
        let mut p = pool(2, 2);
        let foreign = BlockAddr::new(InstanceId(42), Tier::Hbm, 0);
        assert!(matches!(
            p.free_mem(&[foreign]),
            Err(PoolError::NotLocal(_))
        ));
        assert!(p.read_block(foreign, &mut [0.0; 64]).is_err());
    }

    #[test]
    fn ttl_expiry_frees_memory() {
        let mut p = MemPool::new(InstanceId(0), geom(), 8, 8, 5.0, true);
        let a = p.alloc_mem(1, Tier::Hbm).unwrap();
        p.insert(&toks(4, 0), vec![a], 0.0).unwrap();
        assert_eq!(p.expire(10.0), 1);
        assert_eq!(p.used_blocks(Tier::Hbm), 0);
        assert_eq!(p.match_prefix(&toks(4, 0), 11.0).tokens, 0);
    }

    /// Lifecycle property: random alloc/insert/match/evict/swap sequences
    /// keep the pool consistent (no leaks, no double-ownership).
    #[test]
    fn prop_pool_lifecycle_consistent() {
        proptest(40, |g| {
            let hbm = g.usize(4, 16);
            let dram = g.usize(4, 16);
            let mut p = MemPool::new(
                InstanceId(1),
                geom(),
                hbm,
                dram,
                0.0,
                false, // bookkeeping-only for speed (sim path)
            );
            let mut active: Vec<BlockAddr> = vec![];
            let mut now = 0.0;
            for _ in 0..g.usize(1, 50) {
                now += 1.0;
                match g.usize(0, 5) {
                    0 => {
                        let n = g.usize(1, 3);
                        if let Ok(a) = p.alloc_mem(n, Tier::Hbm) {
                            active.extend(a);
                        }
                    }
                    1 => {
                        // Retire some active blocks under a random prompt.
                        if !active.is_empty() {
                            let n = g.usize(1, active.len().min(3));
                            let blocks: Vec<BlockAddr> =
                                active.drain(..n).collect();
                            let t = g.vec_u32(n * 4, 0, 5);
                            p.insert(
                                &t,
                                blocks.into_iter().map(|b| vec![b]).collect(),
                                now,
                            )
                            .unwrap();
                        }
                    }
                    2 => {
                        let n = g.usize(0, 12);
                        let t = g.vec_u32(n, 0, 5);
                        let _ = p.match_prefix(&t, now);
                    }
                    3 => {
                        p.evict(g.usize(1, 3));
                    }
                    4 => {
                        let _ = p.swap_out(g.usize(1, 2));
                    }
                    _ => {
                        if !active.is_empty() {
                            let b = active.pop().unwrap();
                            p.free_mem(&[b]).unwrap();
                        }
                    }
                }
                p.check_consistency(active.len())
                    .unwrap_or_else(|e| panic!("{e}"));
            }
        });
    }
}
