//! Reference radix index — the seed implementation, preserved.
//!
//! This is the pre-optimization [`super::index::RadixIndex`]: children
//! keyed by owned `Vec<u32>` token-blocks (SipHash over the full block
//! per hop), one heap-cloned `Vec<BlockAddr>` per matched token-block,
//! and an O(nodes) scan *per eviction victim*. It exists for two
//! purposes and must not be used on any serving path:
//!
//! * **Differential testing.** The property tests in [`super::index`]
//!   drive random insert/match/pin/unpin/delete/evict sequences through
//!   both implementations and require identical observable results,
//!   including under forced fingerprint collisions.
//! * **Benchmark baseline.** `benches/fig10_index.rs` uses it to show
//!   the O(n²)→O(log n) eviction-churn fix and the per-hop key-hashing
//!   win with real numbers.
//!
//! Behavioral contract (shared with the optimized index): block-aligned
//! edges, whole-leaf LRU eviction, pin duplication across splits, TTL
//! expiry of wholly-stale subtrees, and duplicate-group reporting on
//! insert.

use crate::util::rng::DetMap;

use super::block::BlockAddr;
use super::index::BlockGroup;

#[derive(Debug)]
struct Node {
    edge: Vec<u32>,
    groups: Vec<BlockGroup>,
    children: DetMap<Vec<u32>, usize>,
    parent: usize,
    last_access: f64,
    pins: u32,
    valid: bool,
}

/// The seed token-keyed index (see module docs). API mirrors
/// [`super::index::RadixIndex`], with matches returned as owned groups.
#[derive(Debug)]
pub struct RefRadixIndex {
    nodes: Vec<Node>,
    free_list: Vec<usize>,
    block_tokens: usize,
    ttl: f64,
    token_blocks: usize,
}

/// Result of a prefix match (owned-group form).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct RefIndexMatch {
    /// Matched length in tokens (multiple of block_tokens).
    pub tokens: usize,
    /// One group per matched token-block, in prompt order.
    pub groups: Vec<BlockGroup>,
}

const ROOT: usize = 0;

impl RefRadixIndex {
    pub fn new(block_tokens: usize, ttl: f64) -> Self {
        assert!(block_tokens > 0);
        RefRadixIndex {
            nodes: vec![Node {
                edge: vec![],
                groups: vec![],
                children: DetMap::default(),
                parent: ROOT,
                last_access: 0.0,
                pins: 0,
                valid: true,
            }],
            free_list: vec![],
            block_tokens,
            ttl,
            token_blocks: 0,
        }
    }

    pub fn block_tokens(&self) -> usize {
        self.block_tokens
    }

    pub fn total_token_blocks(&self) -> usize {
        self.token_blocks
    }

    pub fn is_empty(&self) -> bool {
        self.token_blocks == 0
    }

    fn alloc_node(&mut self, node: Node) -> usize {
        if let Some(i) = self.free_list.pop() {
            self.nodes[i] = node;
            i
        } else {
            self.nodes.push(node);
            self.nodes.len() - 1
        }
    }

    fn release_node(&mut self, idx: usize) {
        debug_assert_ne!(idx, ROOT);
        self.nodes[idx].valid = false;
        self.nodes[idx].children.clear();
        self.nodes[idx].edge.clear();
        self.nodes[idx].groups.clear();
        self.free_list.push(idx);
    }

    /// Truncate a token sequence to whole token-blocks.
    pub fn usable_len(&self, tokens: usize) -> usize {
        tokens - tokens % self.block_tokens
    }

    /// Insert `tokens` (truncated to whole blocks) mapping to `groups`;
    /// returns the duplicate groups (prefix already indexed).
    pub fn insert(&mut self, tokens: &[u32], groups: &[BlockGroup], now: f64)
                  -> Vec<BlockGroup> {
        let usable = self.usable_len(tokens.len());
        let tokens = &tokens[..usable];
        let n_blocks = usable / self.block_tokens;
        assert!(
            groups.len() >= n_blocks,
            "need {n_blocks} groups, got {}",
            groups.len()
        );
        let mut dup: Vec<BlockGroup> = vec![];
        let mut cur = ROOT;
        let mut pos = 0; // tokens consumed
        self.nodes[ROOT].last_access = now;

        while pos < usable {
            let key = &tokens[pos..pos + self.block_tokens];
            match self.nodes[cur].children.get(key).copied() {
                None => {
                    // Attach the whole remainder as one new leaf.
                    let edge: Vec<u32> = tokens[pos..].to_vec();
                    let g: Vec<BlockGroup> = groups
                        [pos / self.block_tokens..n_blocks]
                        .to_vec();
                    self.token_blocks += g.len();
                    let leaf = self.alloc_node(Node {
                        edge,
                        groups: g,
                        children: DetMap::default(),
                        parent: cur,
                        last_access: now,
                        pins: 0,
                        valid: true,
                    });
                    self.nodes[cur]
                        .children
                        .insert(key.to_vec(), leaf);
                    return dup;
                }
                Some(child) => {
                    let common = self.common_block_prefix(
                        &self.nodes[child].edge,
                        &tokens[pos..],
                    );
                    debug_assert!(
                        common >= self.block_tokens,
                        "block-keyed child must share its first block"
                    );
                    if common < self.nodes[child].edge.len() {
                        self.split(child, common);
                    }
                    // Matched blocks already exist: incoming copies are
                    // duplicates unless they alias the indexed ones.
                    let n_common_blocks = common / self.block_tokens;
                    let start = pos / self.block_tokens;
                    let child_now = self.nodes[cur].children[key];
                    for (i, g) in groups[start..start + n_common_blocks]
                        .iter()
                        .enumerate()
                    {
                        if self.nodes[child_now].groups.get(i) != Some(g) {
                            dup.push(g.clone());
                        }
                    }
                    let child = self.nodes[cur].children[key];
                    self.nodes[child].last_access = now;
                    cur = child;
                    pos += common;
                }
            }
        }
        dup
    }

    /// Longest common prefix of `edge` and `rest`, rounded down to a
    /// block boundary.
    fn common_block_prefix(&self, edge: &[u32], rest: &[u32]) -> usize {
        let mut i = 0;
        let max = edge.len().min(rest.len());
        while i < max && edge[i] == rest[i] {
            i += 1;
        }
        i - i % self.block_tokens
    }

    /// Split `node`'s edge at `at` tokens (block-aligned).
    fn split(&mut self, node: usize, at: usize) {
        debug_assert!(at % self.block_tokens == 0 && at > 0);
        let tail_edge = self.nodes[node].edge.split_off(at);
        let tail_groups = self.nodes[node]
            .groups
            .split_off(at / self.block_tokens);
        let tail_children = std::mem::take(&mut self.nodes[node].children);
        let last_access = self.nodes[node].last_access;
        let pins = self.nodes[node].pins;
        let tail = self.alloc_node(Node {
            edge: tail_edge,
            groups: tail_groups,
            children: tail_children,
            parent: node,
            last_access,
            // A pin covers the whole edge, so both halves inherit it.
            pins,
            valid: true,
        });
        let grandchildren: Vec<usize> =
            self.nodes[tail].children.values().copied().collect();
        for gc in grandchildren {
            self.nodes[gc].parent = tail;
        }
        let tail_key =
            self.nodes[tail].edge[..self.block_tokens].to_vec();
        self.nodes[node].children.insert(tail_key, tail);
    }

    /// Longest indexed prefix of `tokens`; bumps last_access on the path.
    pub fn match_prefix(&mut self, tokens: &[u32], now: f64)
                        -> RefIndexMatch {
        let mut cur = ROOT;
        let mut pos = 0;
        let mut out = RefIndexMatch::default();
        self.nodes[ROOT].last_access = now;
        loop {
            if pos + self.block_tokens > tokens.len() {
                break;
            }
            let key = &tokens[pos..pos + self.block_tokens];
            let Some(&child) = self.nodes[cur].children.get(key) else {
                break;
            };
            let common = self.common_block_prefix(
                &self.nodes[child].edge,
                &tokens[pos..],
            );
            debug_assert!(common >= self.block_tokens);
            self.nodes[child].last_access = now;
            for g in &self.nodes[child].groups[..common / self.block_tokens] {
                out.groups.push(g.clone());
            }
            pos += common;
            out.tokens += common;
            if common < self.nodes[child].edge.len() {
                break; // partial edge match ends the walk
            }
            cur = child;
        }
        out
    }

    /// Pin the matched prefix of `tokens`; returns the pinned length.
    pub fn pin(&mut self, tokens: &[u32]) -> usize {
        self.walk_path(tokens, |n| n.pins += 1)
    }

    /// Release a pin taken by [`Self::pin`] on the same token sequence.
    pub fn unpin(&mut self, tokens: &[u32]) -> usize {
        self.walk_path(tokens, |n| {
            debug_assert!(n.pins > 0, "unpin without pin");
            n.pins = n.pins.saturating_sub(1);
        })
    }

    /// Walk the matched path applying `f` to each fully-matched node,
    /// splitting a final partially-matched edge.
    fn walk_path<F: FnMut(&mut Node)>(&mut self, tokens: &[u32], mut f: F)
                                      -> usize {
        let mut cur = ROOT;
        let mut pos = 0;
        loop {
            if pos + self.block_tokens > tokens.len() {
                break;
            }
            let key = &tokens[pos..pos + self.block_tokens];
            let Some(&child) = self.nodes[cur].children.get(key) else {
                break;
            };
            let common = self.common_block_prefix(
                &self.nodes[child].edge,
                &tokens[pos..],
            );
            debug_assert!(common >= self.block_tokens);
            if common < self.nodes[child].edge.len() {
                self.split(child, common);
            }
            f(&mut self.nodes[child]);
            pos += common;
            cur = child;
        }
        pos
    }

    fn subtree_pinned(&self, node: usize) -> bool {
        if self.nodes[node].pins > 0 {
            return true;
        }
        self.nodes[node]
            .children
            .values()
            .any(|&c| self.subtree_pinned(c))
    }

    /// Delete the exact prefix `tokens` and everything below it.
    pub fn delete(&mut self, tokens: &[u32]) -> Vec<BlockAddr> {
        let usable = self.usable_len(tokens.len());
        let tokens = &tokens[..usable];
        let mut cur = ROOT;
        let mut pos = 0;
        while pos < usable {
            let key = &tokens[pos..pos + self.block_tokens];
            let Some(&child) = self.nodes[cur].children.get(key) else {
                return vec![];
            };
            let common = self.common_block_prefix(
                &self.nodes[child].edge,
                &tokens[pos..],
            );
            debug_assert!(common >= self.block_tokens);
            pos += common;
            if common < self.nodes[child].edge.len() {
                if pos < usable {
                    return vec![]; // diverged: prefix not present
                }
                // Ends mid-edge: drop the edge tail + subtree.
                let mut freed = vec![];
                let tail_groups = self.nodes[child]
                    .groups
                    .split_off(common / self.block_tokens);
                self.nodes[child].edge.truncate(common);
                self.token_blocks -= tail_groups.len();
                for g in tail_groups {
                    freed.extend(g);
                }
                let grandchildren: Vec<usize> =
                    self.nodes[child].children.values().copied().collect();
                self.nodes[child].children.clear();
                for gc in grandchildren {
                    self.drop_subtree(gc, &mut freed);
                }
                return freed;
            }
            cur = child;
        }
        if cur == ROOT {
            return vec![];
        }
        let mut freed = vec![];
        let parent = self.nodes[cur].parent;
        let key = self.nodes[cur].edge[..self.block_tokens].to_vec();
        self.nodes[parent].children.remove(&key);
        self.drop_subtree(cur, &mut freed);
        freed
    }

    fn drop_subtree(&mut self, node: usize, freed: &mut Vec<BlockAddr>) {
        let children: Vec<usize> =
            self.nodes[node].children.values().copied().collect();
        for c in children {
            self.drop_subtree(c, freed);
        }
        self.token_blocks -= self.nodes[node].groups.len();
        for g in std::mem::take(&mut self.nodes[node].groups) {
            freed.extend(g);
        }
        self.release_node(node);
    }

    /// Evict at least `want_token_blocks` token-blocks, oldest leaves
    /// first — via a full O(nodes) scan per victim (the behavior under
    /// study in the eviction-churn benchmark).
    pub fn evict_lru(&mut self, want_token_blocks: usize) -> Vec<BlockAddr> {
        let mut freed = vec![];
        let mut freed_blocks = 0;
        while freed_blocks < want_token_blocks {
            // Oldest leaf (no children, valid, not root).
            let mut best: Option<(usize, f64)> = None;
            for (i, n) in self.nodes.iter().enumerate() {
                if i == ROOT || !n.valid || !n.children.is_empty()
                    || n.pins > 0
                {
                    continue;
                }
                if best.map(|(_, t)| n.last_access < t).unwrap_or(true) {
                    best = Some((i, n.last_access));
                }
            }
            let Some((leaf, _)) = best else { break };
            freed_blocks += self.nodes[leaf].groups.len();
            let parent = self.nodes[leaf].parent;
            let key = self.nodes[leaf].edge[..self.block_tokens].to_vec();
            self.nodes[parent].children.remove(&key);
            self.token_blocks -= self.nodes[leaf].groups.len();
            for g in std::mem::take(&mut self.nodes[leaf].groups) {
                freed.extend(g);
            }
            self.release_node(leaf);
        }
        freed
    }

    /// LRU leaf groups satisfying `filter`, without removal (swap picks).
    pub fn lru_addrs<F: Fn(&BlockAddr) -> bool>(
        &self,
        want_token_blocks: usize,
        filter: F,
    ) -> Vec<BlockAddr> {
        let mut leaves: Vec<(f64, usize)> = self
            .nodes
            .iter()
            .enumerate()
            .skip(1)
            .filter(|(_, n)| n.valid && n.children.is_empty() && n.pins == 0)
            .map(|(i, n)| (n.last_access, i))
            .collect();
        leaves.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        let mut out = vec![];
        let mut groups_taken = 0;
        'outer: for (_, leaf) in leaves {
            // Walk trailing groups first (deepest data is coldest).
            for g in self.nodes[leaf].groups.iter().rev() {
                if groups_taken >= want_token_blocks {
                    break 'outer;
                }
                let addrs: Vec<BlockAddr> =
                    g.iter().copied().filter(|a| filter(a)).collect();
                if addrs.len() == g.len() {
                    out.extend(addrs);
                    groups_taken += 1;
                }
            }
        }
        out
    }

    /// Drop every node idle longer than the TTL. Returns freed addresses.
    pub fn expire(&mut self, now: f64) -> Vec<BlockAddr> {
        if self.ttl <= 0.0 {
            return vec![];
        }
        let mut freed = vec![];
        loop {
            let mut victim = None;
            for (i, n) in self.nodes.iter().enumerate() {
                if i == ROOT || !n.valid {
                    continue;
                }
                if now - n.last_access > self.ttl && !self.subtree_pinned(i) {
                    victim = Some(i);
                    break;
                }
            }
            let Some(v) = victim else { break };
            let parent = self.nodes[v].parent;
            let key = self.nodes[v].edge[..self.block_tokens].to_vec();
            self.nodes[parent].children.remove(&key);
            self.drop_subtree(v, &mut freed);
        }
        freed
    }

    /// Rewrite addresses after a swap (old -> new).
    pub fn remap(&mut self, map: &DetMap<BlockAddr, BlockAddr>) {
        for n in &mut self.nodes {
            if !n.valid {
                continue;
            }
            for g in &mut n.groups {
                for a in g.iter_mut() {
                    if let Some(new) = map.get(a) {
                        *a = *new;
                    }
                }
            }
        }
    }

    /// All addresses currently referenced (diagnostics / leak checks).
    pub fn all_addrs(&self) -> Vec<BlockAddr> {
        let mut out = vec![];
        for n in self.nodes.iter().filter(|n| n.valid) {
            for g in &n.groups {
                out.extend(g.iter().copied());
            }
        }
        out
    }

    /// Live node count (excluding root).
    pub fn node_count(&self) -> usize {
        self.nodes.iter().skip(1).filter(|n| n.valid).count()
    }
}
