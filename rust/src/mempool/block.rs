//! Block addressing and KV-block geometry.
//!
//! Per Table 1 of the paper, every address encodes the owning instance ID
//! so any instance can name any other instance's memory (the cluster
//! manager uses this to release leaked blocks after a failure, §4.4).
//!
//! Geometry covers the paper's §5.2 layouts:
//! * **discrete** (vLLM-style): one block = one layer's K *or* V half for
//!   `block_tokens` tokens → `2 * layers` blocks per token-block;
//! * **aggregated** (the paper's huge-page optimization): one block spans
//!   all layers and both halves → 1 block per token-block, cutting the
//!   number of network calls by `2 * layers`.

use std::fmt;

/// Identifies an inference instance in the cluster.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct InstanceId(pub u32);

impl fmt::Display for InstanceId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "inst{}", self.0)
    }
}

/// Memory tier: simulated GPU HBM (fast, scarce) or CPU DRAM (slow, big).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Tier {
    Hbm,
    Dram,
}

impl Tier {
    pub fn name(self) -> &'static str {
        match self {
            Tier::Hbm => "hbm",
            Tier::Dram => "dram",
        }
    }
}

/// A block address: owner instance ⊕ tier ⊕ slot index. `Copy`, ordered,
/// hashable — used as the universal KV-cache handle across the cluster.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct BlockAddr {
    pub instance: InstanceId,
    pub tier: Tier,
    pub index: u32,
}

impl BlockAddr {
    pub fn new(instance: InstanceId, tier: Tier, index: u32) -> Self {
        BlockAddr {
            instance,
            tier,
            index,
        }
    }

    /// Pack into a u64 (instance:24 | tier:8 | index:32) — the wire form.
    pub fn pack(self) -> u64 {
        ((self.instance.0 as u64) << 40)
            | (((self.tier == Tier::Dram) as u64) << 32)
            | self.index as u64
    }

    pub fn unpack(x: u64) -> Self {
        BlockAddr {
            instance: InstanceId((x >> 40) as u32),
            tier: if (x >> 32) & 1 == 1 {
                Tier::Dram
            } else {
                Tier::Hbm
            },
            index: x as u32,
        }
    }
}

impl fmt::Display for BlockAddr {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}:{}", self.instance, self.tier.name(), self.index)
    }
}

/// KV block geometry — derived from the model geometry + layout choice.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct BlockGeometry {
    /// Tokens per block (vLLM block size; paper tests use 16).
    pub block_tokens: usize,
    pub layers: usize,
    pub n_heads: usize,
    pub head_dim: usize,
    /// Aggregated huge-page layout (paper §5.2)?
    pub aggregated: bool,
}

impl BlockGeometry {
    /// Floats of KV data one *token* carries in one layer's K or V half.
    pub fn floats_per_token_half(&self) -> usize {
        self.n_heads * self.head_dim
    }

    /// Total floats of KV data per token across all layers, both halves.
    pub fn floats_per_token(&self) -> usize {
        2 * self.layers * self.floats_per_token_half()
    }

    /// Floats stored in one allocatable block.
    pub fn floats_per_block(&self) -> usize {
        if self.aggregated {
            self.block_tokens * self.floats_per_token()
        } else {
            self.block_tokens * self.floats_per_token_half()
        }
    }

    pub fn bytes_per_block(&self) -> usize {
        self.floats_per_block() * 4
    }

    /// Allocatable blocks per token-block (the unit the index tracks).
    pub fn blocks_per_token_block(&self) -> usize {
        if self.aggregated {
            1
        } else {
            2 * self.layers
        }
    }

    /// Bytes of KV cache for `tokens` tokens (layout-independent).
    pub fn bytes_for_tokens(&self, tokens: usize) -> usize {
        tokens * self.floats_per_token() * 4
    }

    /// Token-blocks needed to hold `tokens` tokens (ceil).
    pub fn token_blocks(&self, tokens: usize) -> usize {
        tokens.div_ceil(self.block_tokens)
    }

    /// Allocatable blocks needed for `tokens` tokens.
    pub fn alloc_blocks(&self, tokens: usize) -> usize {
        self.token_blocks(tokens) * self.blocks_per_token_block()
    }

    /// Network API calls to ship `tokens` tokens of KV (paper §5.2: one
    /// NCCL send per discrete block; aggregation cuts this 2*L times).
    pub fn transfer_calls(&self, tokens: usize) -> usize {
        self.alloc_blocks(tokens)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn geom(aggregated: bool) -> BlockGeometry {
        BlockGeometry {
            block_tokens: 16,
            layers: 4,
            n_heads: 8,
            head_dim: 32,
            aggregated,
        }
    }

    #[test]
    fn pack_unpack_roundtrip() {
        for inst in [0u32, 1, 77, 0xFFFF] {
            for tier in [Tier::Hbm, Tier::Dram] {
                for idx in [0u32, 5, u32::MAX] {
                    let a = BlockAddr::new(InstanceId(inst), tier, idx);
                    assert_eq!(BlockAddr::unpack(a.pack()), a);
                }
            }
        }
    }

    #[test]
    fn discrete_vs_aggregated_same_total_bytes() {
        let d = geom(false);
        let a = geom(true);
        // 256 tokens: total KV bytes identical across layouts.
        assert_eq!(
            d.alloc_blocks(256) * d.bytes_per_block(),
            a.alloc_blocks(256) * a.bytes_per_block()
        );
        assert_eq!(d.bytes_for_tokens(256), a.alloc_blocks(256) * a.bytes_per_block());
    }

    #[test]
    fn aggregation_cuts_transfer_calls_2l_times() {
        let d = geom(false);
        let a = geom(true);
        let calls_d = d.transfer_calls(256);
        let calls_a = a.transfer_calls(256);
        assert_eq!(calls_d, calls_a * 2 * 4);
    }

    #[test]
    fn token_block_rounding() {
        let g = geom(true);
        assert_eq!(g.token_blocks(1), 1);
        assert_eq!(g.token_blocks(16), 1);
        assert_eq!(g.token_blocks(17), 2);
        assert_eq!(g.token_blocks(0), 0);
    }

    #[test]
    fn per_token_floats() {
        let g = geom(true);
        assert_eq!(g.floats_per_token(), 2 * 4 * 8 * 32);
        assert_eq!(g.floats_per_block(), 16 * 2048);
    }
}
