//! Distributed-transfer datatypes (paper §4.3, Fig 2 & Fig 5).
//!
//! The actual 3-step protocol (allocation → transmission → insertion)
//! executes over [`crate::net`]'s fabric between instance threads; this
//! module defines the request/flag types plus the call-count/byte math
//! that drives the by-layer / by-request / by-request-agg comparison
//! (paper Fig 12) and the block-aggregation study (Fig 11).

use super::block::{BlockAddr, BlockGeometry, InstanceId, Tier};

/// KV transfer granularity from prefill to decode (paper Fig 5).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TransferMode {
    /// Send each layer's KV as soon as that layer finishes prefill —
    /// overlaps compute and communication (best at low load).
    ByLayer,
    /// Send everything after the prefill completes, discrete blocks.
    ByRequest,
    /// By-request over the aggregated huge-page layout — cuts network
    /// calls by 2·layers (the paper's optimization; best at high load).
    ByRequestAgg,
}

impl TransferMode {
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "by_layer" => Some(TransferMode::ByLayer),
            "by_request" | "by_req" => Some(TransferMode::ByRequest),
            "by_request_agg" | "by_req_agg" => Some(TransferMode::ByRequestAgg),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            TransferMode::ByLayer => "by_layer",
            TransferMode::ByRequest => "by_request",
            TransferMode::ByRequestAgg => "by_request_agg",
        }
    }

    /// Network API calls needed to ship `tokens` tokens of KV.
    ///
    /// Paper §5.2: with the discrete layout the number of calls equals the
    /// number of discrete blocks (2·L per token-block) for *both* by-layer
    /// and by-request; aggregation reduces it to one call per token-block
    /// but only composes with by-request (by-layer inherently needs ≥ L
    /// calls since layers finish at different times).
    pub fn network_calls(self, geom: &BlockGeometry, tokens: usize) -> usize {
        let tb = geom.token_blocks(tokens);
        match self {
            TransferMode::ByLayer | TransferMode::ByRequest => {
                tb * 2 * geom.layers
            }
            TransferMode::ByRequestAgg => tb,
        }
    }

    /// Bytes on the wire (same for all modes — payload is the KV cache).
    pub fn network_bytes(self, geom: &BlockGeometry, tokens: usize) -> usize {
        geom.token_blocks(tokens) * geom.block_tokens
            * geom.floats_per_token() * 4
    }

    /// Can communication overlap the prefill compute? (By-layer sends
    /// layer i while layer i+1 computes.)
    pub fn overlaps_compute(self) -> bool {
        matches!(self, TransferMode::ByLayer)
    }

    /// Does this mode require the aggregated block layout?
    pub fn requires_aggregated(self) -> bool {
        matches!(self, TransferMode::ByRequestAgg)
    }
}

/// Flags controlling receiver-side behaviour (Table 1 "flags").
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct TransferFlags {
    /// Receiver inserts (tokens → KV) into its local index after landing
    /// the data — this is `transfer_with_insert`.
    pub insert: bool,
    /// Receiver allocates destination blocks on demand (no dstAddrList).
    pub on_demand_alloc: bool,
    /// Tier the receiver should allocate in.
    pub dst_tier: Tier,
}

impl Default for Tier {
    fn default() -> Self {
        Tier::Hbm
    }
}

/// A transfer job: the sender side of `transfer` /
/// `transfer_with_insert`. `private` carries opaque engine metadata
/// (request id, sampling params, prompt tokens — paper §5.1a).
#[derive(Clone, Debug)]
pub struct TransferRequest {
    pub dst: InstanceId,
    /// Prompt tokens covered by the payload (needed for insert).
    pub tokens: Vec<u32>,
    pub src_addrs: Vec<BlockAddr>,
    /// Pre-negotiated destination (skips the allocation round-trip —
    /// used by layer-by-layer streaming, paper §4.3).
    pub dst_addrs: Option<Vec<BlockAddr>>,
    pub flags: TransferFlags,
    pub private: Vec<u8>,
}

#[cfg(test)]
mod tests {
    use super::*;

    fn geom(aggregated: bool) -> BlockGeometry {
        BlockGeometry {
            block_tokens: 16,
            layers: 4,
            n_heads: 8,
            head_dim: 32,
            aggregated,
        }
    }

    #[test]
    fn parse_roundtrip() {
        for m in [
            TransferMode::ByLayer,
            TransferMode::ByRequest,
            TransferMode::ByRequestAgg,
        ] {
            assert_eq!(TransferMode::parse(m.name()), Some(m));
        }
        assert_eq!(TransferMode::parse("nope"), None);
    }

    #[test]
    fn agg_cuts_calls_2l_times() {
        let g = geom(true);
        let calls_disc = TransferMode::ByRequest.network_calls(&g, 1024);
        let calls_agg = TransferMode::ByRequestAgg.network_calls(&g, 1024);
        assert_eq!(calls_disc, calls_agg * 2 * g.layers);
        assert_eq!(calls_agg, 64); // 1024/16 token-blocks
    }

    #[test]
    fn by_layer_same_calls_as_by_request() {
        let g = geom(false);
        assert_eq!(
            TransferMode::ByLayer.network_calls(&g, 512),
            TransferMode::ByRequest.network_calls(&g, 512)
        );
    }

    #[test]
    fn bytes_are_mode_independent() {
        let g = geom(true);
        let b1 = TransferMode::ByLayer.network_bytes(&g, 100);
        let b2 = TransferMode::ByRequestAgg.network_bytes(&g, 100);
        assert_eq!(b1, b2);
        // 7 token-blocks * 16 tokens * 2*4*8*32 floats * 4 bytes
        assert_eq!(b1, 7 * 16 * 2048 * 4);
    }

    #[test]
    fn overlap_only_by_layer() {
        assert!(TransferMode::ByLayer.overlaps_compute());
        assert!(!TransferMode::ByRequestAgg.overlaps_compute());
    }
}
