//! Token-indexed radix tree mapping prompt prefixes to historical KV
//! cache blocks (paper §4.2).
//!
//! Following SGLang's design with the paper's two extensions: (a) block
//! addresses can point at *any tier* (HBM or DRAM — see [`super::tier`]),
//! and (b) the same structure doubles as the global scheduler's prompt
//! tree. Indexing granularity is one *token-block* (`block_tokens`
//! tokens, 16 in the paper's tests): only full blocks are cached, and
//! every edge length is a multiple of `block_tokens`, so node splits land
//! on block boundaries and the KV layout never needs reshaping.
//!
//! Eviction is LRU over leaves (evicting an interior node would orphan
//! its descendants' prefixes); TTL expiry handles the global tree's
//! staleness problem (paper §6 Discussion).

use std::collections::HashMap;

use super::block::BlockAddr;

/// Addresses backing one token-block (1 entry when aggregated, 2·L when
/// discrete).
pub type BlockGroup = Vec<BlockAddr>;

#[derive(Debug)]
struct Node {
    /// Edge label from the parent; length is a multiple of `block_tokens`
    /// (except the root, which has an empty edge).
    edge: Vec<u32>,
    /// One group per token-block of the edge.
    groups: Vec<BlockGroup>,
    /// Children keyed by the *entire first block* of the child's edge
    /// (not the first token): distinct blocks that happen to share a
    /// first token — e.g. sessions diverging inside the block where a
    /// common non-aligned prefix ends — must coexist (vLLM's hash-based
    /// prefix cache gets this for free by hashing whole blocks).
    children: HashMap<Vec<u32>, usize>,
    parent: usize,
    last_access: f64,
    /// In-use count: requests currently reading this node's blocks.
    /// Pinned nodes are skipped by eviction, swap victim selection, and
    /// TTL expiry (SGLang's lock_ref, needed so an admission's matched
    /// prefix cannot be reclaimed before the request retires).
    pins: u32,
    valid: bool,
}

#[derive(Debug)]
pub struct RadixIndex {
    nodes: Vec<Node>,
    free_list: Vec<usize>,
    block_tokens: usize,
    /// TTL in seconds; 0 disables expiry.
    ttl: f64,
    token_blocks: usize,
}

/// Result of a prefix match.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct IndexMatch {
    /// Matched length in tokens (multiple of block_tokens).
    pub tokens: usize,
    /// One group per matched token-block, in prompt order.
    pub groups: Vec<BlockGroup>,
}

const ROOT: usize = 0;

impl RadixIndex {
    pub fn new(block_tokens: usize, ttl: f64) -> Self {
        assert!(block_tokens > 0);
        RadixIndex {
            nodes: vec![Node {
                edge: vec![],
                groups: vec![],
                children: HashMap::new(),
                parent: ROOT,
                last_access: 0.0,
                pins: 0,
                valid: true,
            }],
            free_list: vec![],
            block_tokens,
            ttl,
            token_blocks: 0,
        }
    }

    pub fn block_tokens(&self) -> usize {
        self.block_tokens
    }

    /// Total token-blocks currently indexed.
    pub fn total_token_blocks(&self) -> usize {
        self.token_blocks
    }

    pub fn is_empty(&self) -> bool {
        self.token_blocks == 0
    }

    fn alloc_node(&mut self, node: Node) -> usize {
        if let Some(i) = self.free_list.pop() {
            self.nodes[i] = node;
            i
        } else {
            self.nodes.push(node);
            self.nodes.len() - 1
        }
    }

    fn release_node(&mut self, idx: usize) {
        debug_assert_ne!(idx, ROOT);
        self.nodes[idx].valid = false;
        self.nodes[idx].children.clear();
        self.nodes[idx].edge.clear();
        self.nodes[idx].groups.clear();
        self.free_list.push(idx);
    }

    /// Truncate a token sequence to whole token-blocks.
    pub fn usable_len(&self, tokens: usize) -> usize {
        tokens - tokens % self.block_tokens
    }

    /// Insert `tokens` (truncated to whole blocks) mapping to `groups`
    /// (one per token-block). Returns the *duplicate* groups — block
    /// groups the caller passed for prefixes that were already indexed —
    /// so the caller can free that memory (paper: `insert` retires the
    /// active KV; if the prefix is already cached the new copy is
    /// redundant).
    pub fn insert(&mut self, tokens: &[u32], groups: &[BlockGroup], now: f64)
                  -> Vec<BlockGroup> {
        let usable = self.usable_len(tokens.len());
        let tokens = &tokens[..usable];
        let n_blocks = usable / self.block_tokens;
        assert!(
            groups.len() >= n_blocks,
            "need {n_blocks} groups, got {}",
            groups.len()
        );
        let mut dup: Vec<BlockGroup> = vec![];
        let mut cur = ROOT;
        let mut pos = 0; // tokens consumed
        self.nodes[ROOT].last_access = now;

        while pos < usable {
            let key = &tokens[pos..pos + self.block_tokens];
            match self.nodes[cur].children.get(key).copied() {
                None => {
                    // Attach the whole remainder as one new leaf.
                    let edge: Vec<u32> = tokens[pos..].to_vec();
                    let g: Vec<BlockGroup> = groups
                        [pos / self.block_tokens..n_blocks]
                        .to_vec();
                    self.token_blocks += g.len();
                    let leaf = self.alloc_node(Node {
                        edge,
                        groups: g,
                        children: HashMap::new(),
                        parent: cur,
                        last_access: now,
                        pins: 0,
                        valid: true,
                    });
                    self.nodes[cur]
                        .children
                        .insert(key.to_vec(), leaf);
                    return dup;
                }
                Some(child) => {
                    let common = self.common_block_prefix(
                        &self.nodes[child].edge,
                        &tokens[pos..],
                    );
                    debug_assert!(
                        common >= self.block_tokens,
                        "block-keyed child must share its first block"
                    );
                    if common < self.nodes[child].edge.len() {
                        self.split(child, common);
                    }
                    // The matched blocks already exist: incoming copies
                    // are duplicates — unless they are the *same* blocks
                    // (the engine re-inserts a prompt whose prefix groups
                    // alias what `match` returned; identity means there
                    // is nothing to free).
                    let n_common_blocks = common / self.block_tokens;
                    let start = pos / self.block_tokens;
                    let child_now = self.nodes[cur].children[key];
                    for (i, g) in groups[start..start + n_common_blocks]
                        .iter()
                        .enumerate()
                    {
                        if self.nodes[child_now].groups.get(i) != Some(g) {
                            dup.push(g.clone());
                        }
                    }
                    let child = self.nodes[cur].children[key];
                    self.nodes[child].last_access = now;
                    cur = child;
                    pos += common;
                }
            }
        }
        dup
    }

    /// Longest common prefix of `edge` and `rest`, rounded down to a
    /// block boundary.
    fn common_block_prefix(&self, edge: &[u32], rest: &[u32]) -> usize {
        let mut i = 0;
        let max = edge.len().min(rest.len());
        while i < max && edge[i] == rest[i] {
            i += 1;
        }
        i - i % self.block_tokens
    }

    /// Split `node`'s edge at `at` tokens (block-aligned): the node keeps
    /// the head; a new child gets the tail + original children.
    fn split(&mut self, node: usize, at: usize) {
        debug_assert!(at % self.block_tokens == 0 && at > 0);
        let tail_edge = self.nodes[node].edge.split_off(at);
        let tail_groups = self.nodes[node]
            .groups
            .split_off(at / self.block_tokens);
        let tail_children = std::mem::take(&mut self.nodes[node].children);
        let last_access = self.nodes[node].last_access;
        let pins = self.nodes[node].pins;
        let tail = self.alloc_node(Node {
            edge: tail_edge,
            groups: tail_groups,
            children: tail_children,
            parent: node,
            last_access,
            // A pin covers the whole edge (pins are taken on block-split
            // boundaries), so both halves inherit it; unpin walks both.
            pins,
            valid: true,
        });
        // Fix the grandchildren's parent pointers.
        let grandchildren: Vec<usize> =
            self.nodes[tail].children.values().copied().collect();
        for gc in grandchildren {
            self.nodes[gc].parent = tail;
        }
        let tail_key =
            self.nodes[tail].edge[..self.block_tokens].to_vec();
        self.nodes[node].children.insert(tail_key, tail);
    }

    /// Longest indexed prefix of `tokens`; bumps last_access on the path.
    pub fn match_prefix(&mut self, tokens: &[u32], now: f64) -> IndexMatch {
        let mut cur = ROOT;
        let mut pos = 0;
        let mut out = IndexMatch::default();
        self.nodes[ROOT].last_access = now;
        loop {
            if pos + self.block_tokens > tokens.len() {
                break;
            }
            let key = &tokens[pos..pos + self.block_tokens];
            let Some(&child) = self.nodes[cur].children.get(key) else {
                break;
            };
            let common = self.common_block_prefix(
                &self.nodes[child].edge,
                &tokens[pos..],
            );
            debug_assert!(common >= self.block_tokens);
            self.nodes[child].last_access = now;
            for g in &self.nodes[child].groups[..common / self.block_tokens] {
                out.groups.push(g.clone());
            }
            pos += common;
            out.tokens += common;
            if common < self.nodes[child].edge.len() {
                break; // partial edge match ends the walk
            }
            cur = child;
        }
        out
    }

    /// Pin the matched prefix of `tokens` against eviction/swap/expiry.
    /// Returns the pinned length in tokens; pass the same slice to
    /// [`Self::unpin`] when the request retires.
    pub fn pin(&mut self, tokens: &[u32]) -> usize {
        self.walk_path(tokens, |n| n.pins += 1)
    }

    /// Release a pin taken by [`Self::pin`] on the same token sequence.
    pub fn unpin(&mut self, tokens: &[u32]) -> usize {
        self.walk_path(tokens, |n| {
            debug_assert!(n.pins > 0, "unpin without pin");
            n.pins = n.pins.saturating_sub(1);
        })
    }

    /// Walk the matched path applying `f` to each fully-matched node,
    /// splitting a final partially-matched edge so pin boundaries always
    /// land on node boundaries. Returns matched tokens.
    fn walk_path<F: FnMut(&mut Node)>(&mut self, tokens: &[u32], mut f: F)
                                      -> usize {
        let mut cur = ROOT;
        let mut pos = 0;
        loop {
            if pos + self.block_tokens > tokens.len() {
                break;
            }
            let key = &tokens[pos..pos + self.block_tokens];
            let Some(&child) = self.nodes[cur].children.get(key) else {
                break;
            };
            let common = self.common_block_prefix(
                &self.nodes[child].edge,
                &tokens[pos..],
            );
            debug_assert!(common >= self.block_tokens);
            if common < self.nodes[child].edge.len() {
                // Align the node boundary to the matched span so `f`
                // applies to exactly the in-use blocks.
                self.split(child, common);
            }
            f(&mut self.nodes[child]);
            pos += common;
            cur = child;
        }
        pos
    }

    fn subtree_pinned(&self, node: usize) -> bool {
        if self.nodes[node].pins > 0 {
            return true;
        }
        self.nodes[node]
            .children
            .values()
            .any(|&c| self.subtree_pinned(c))
    }

    /// Delete the exact prefix `tokens` and everything below it. Returns
    /// the freed block addresses.
    pub fn delete(&mut self, tokens: &[u32]) -> Vec<BlockAddr> {
        let usable = self.usable_len(tokens.len());
        let tokens = &tokens[..usable];
        // Walk to the node whose path equals `tokens` (may end mid-edge).
        let mut cur = ROOT;
        let mut pos = 0;
        while pos < usable {
            let key = &tokens[pos..pos + self.block_tokens];
            let Some(&child) = self.nodes[cur].children.get(key) else {
                return vec![];
            };
            let common = self.common_block_prefix(
                &self.nodes[child].edge,
                &tokens[pos..],
            );
            debug_assert!(common >= self.block_tokens);
            pos += common;
            if common < self.nodes[child].edge.len() {
                if pos < usable {
                    return vec![]; // diverged: prefix not present
                }
                // Ends mid-edge: drop the tail blocks of this edge + subtree.
                let mut freed = vec![];
                let tail_groups = self.nodes[child]
                    .groups
                    .split_off(common / self.block_tokens);
                self.nodes[child].edge.truncate(common);
                self.token_blocks -= tail_groups.len();
                for g in tail_groups {
                    freed.extend(g);
                }
                let grandchildren: Vec<usize> =
                    self.nodes[child].children.values().copied().collect();
                self.nodes[child].children.clear();
                for gc in grandchildren {
                    self.drop_subtree(gc, &mut freed);
                }
                return freed;
            }
            cur = child;
        }
        if cur == ROOT {
            return vec![];
        }
        let mut freed = vec![];
        let parent = self.nodes[cur].parent;
        let key = self.nodes[cur].edge[..self.block_tokens].to_vec();
        self.nodes[parent].children.remove(&key);
        self.drop_subtree(cur, &mut freed);
        freed
    }

    fn drop_subtree(&mut self, node: usize, freed: &mut Vec<BlockAddr>) {
        let children: Vec<usize> =
            self.nodes[node].children.values().copied().collect();
        for c in children {
            self.drop_subtree(c, freed);
        }
        self.token_blocks -= self.nodes[node].groups.len();
        for g in std::mem::take(&mut self.nodes[node].groups) {
            freed.extend(g);
        }
        self.release_node(node);
    }

    /// Evict at least `want_token_blocks` token-blocks, oldest leaves
    /// first (whole-leaf granularity). Returns freed addresses; may free
    /// fewer than requested if the tree runs dry.
    pub fn evict_lru(&mut self, want_token_blocks: usize) -> Vec<BlockAddr> {
        let mut freed = vec![];
        let mut freed_blocks = 0;
        while freed_blocks < want_token_blocks {
            // Oldest leaf (no children, valid, not root).
            let mut best: Option<(usize, f64)> = None;
            for (i, n) in self.nodes.iter().enumerate() {
                if i == ROOT || !n.valid || !n.children.is_empty()
                    || n.pins > 0
                {
                    continue;
                }
                if best.map(|(_, t)| n.last_access < t).unwrap_or(true) {
                    best = Some((i, n.last_access));
                }
            }
            let Some((leaf, _)) = best else { break };
            freed_blocks += self.nodes[leaf].groups.len();
            let parent = self.nodes[leaf].parent;
            let key = self.nodes[leaf].edge[..self.block_tokens].to_vec();
            self.nodes[parent].children.remove(&key);
            self.token_blocks -= self.nodes[leaf].groups.len();
            for g in std::mem::take(&mut self.nodes[leaf].groups) {
                freed.extend(g);
            }
            self.release_node(leaf);
        }
        freed
    }

    /// Addresses of the least-recently-used leaf groups satisfying
    /// `filter`, up to `want_token_blocks` groups — *without* removing
    /// them from the index. Used by `swap_out` to pick HBM victims whose
    /// data moves to DRAM (the index is then remapped, not pruned).
    pub fn lru_addrs<F: Fn(&BlockAddr) -> bool>(
        &self,
        want_token_blocks: usize,
        filter: F,
    ) -> Vec<BlockAddr> {
        let mut leaves: Vec<(f64, usize)> = self
            .nodes
            .iter()
            .enumerate()
            .skip(1)
            .filter(|(_, n)| n.valid && n.children.is_empty() && n.pins == 0)
            .map(|(i, n)| (n.last_access, i))
            .collect();
        leaves.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        let mut out = vec![];
        let mut groups_taken = 0;
        'outer: for (_, leaf) in leaves {
            // Walk trailing groups first (deepest data is coldest).
            for g in self.nodes[leaf].groups.iter().rev() {
                if groups_taken >= want_token_blocks {
                    break 'outer;
                }
                let addrs: Vec<BlockAddr> =
                    g.iter().copied().filter(|a| filter(a)).collect();
                if addrs.len() == g.len() {
                    out.extend(addrs);
                    groups_taken += 1;
                }
            }
        }
        out
    }

    /// Drop every node idle longer than the TTL. Returns freed addresses.
    pub fn expire(&mut self, now: f64) -> Vec<BlockAddr> {
        if self.ttl <= 0.0 {
            return vec![];
        }
        let mut freed = vec![];
        // Repeat until fixpoint: expiring a parent requires dropping its
        // subtree; we conservatively expire stale *subtrees* whose root's
        // entire lineage is stale (children may be fresher than parents
        // since match bumps the whole path).
        loop {
            let mut victim = None;
            for (i, n) in self.nodes.iter().enumerate() {
                if i == ROOT || !n.valid {
                    continue;
                }
                if now - n.last_access > self.ttl && !self.subtree_pinned(i) {
                    victim = Some(i);
                    break;
                }
            }
            let Some(v) = victim else { break };
            let parent = self.nodes[v].parent;
            let key = self.nodes[v].edge[..self.block_tokens].to_vec();
            self.nodes[parent].children.remove(&key);
            self.drop_subtree(v, &mut freed);
        }
        freed
    }

    /// Rewrite addresses after a swap (old -> new), e.g. HBM -> DRAM.
    pub fn remap(&mut self, map: &HashMap<BlockAddr, BlockAddr>) {
        for n in &mut self.nodes {
            if !n.valid {
                continue;
            }
            for g in &mut n.groups {
                for a in g.iter_mut() {
                    if let Some(new) = map.get(a) {
                        *a = *new;
                    }
                }
            }
        }
    }

    /// All addresses currently referenced (diagnostics / leak checks).
    pub fn all_addrs(&self) -> Vec<BlockAddr> {
        let mut out = vec![];
        for n in self.nodes.iter().filter(|n| n.valid) {
            for g in &n.groups {
                out.extend(g.iter().copied());
            }
        }
        out
    }

    /// Live node count (excluding root).
    pub fn node_count(&self) -> usize {
        self.nodes.iter().skip(1).filter(|n| n.valid).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mempool::block::{InstanceId, Tier};
    use crate::util::proptest::proptest;

    const BT: usize = 4; // block_tokens for tests

    fn addr(i: u32) -> BlockAddr {
        BlockAddr::new(InstanceId(0), Tier::Hbm, i)
    }

    /// groups for n token-blocks starting at base, 1 addr per group
    fn groups(base: u32, n: usize) -> Vec<BlockGroup> {
        (0..n as u32).map(|i| vec![addr(base + i)]).collect()
    }

    fn seq(xs: &[u32]) -> Vec<u32> {
        xs.to_vec()
    }

    #[test]
    fn insert_then_match_exact() {
        let mut idx = RadixIndex::new(BT, 0.0);
        let toks: Vec<u32> = (0..12).collect();
        let dup = idx.insert(&toks, &groups(0, 3), 1.0);
        assert!(dup.is_empty());
        let m = idx.match_prefix(&toks, 2.0);
        assert_eq!(m.tokens, 12);
        assert_eq!(m.groups, groups(0, 3));
        assert_eq!(idx.total_token_blocks(), 3);
    }

    #[test]
    fn match_respects_block_granularity() {
        let mut idx = RadixIndex::new(BT, 0.0);
        let toks: Vec<u32> = (0..8).collect();
        idx.insert(&toks, &groups(0, 2), 1.0);
        // Query shares only 6 tokens -> matched must round down to 4.
        let mut q = toks.clone();
        q[6] = 999;
        let m = idx.match_prefix(&q, 2.0);
        assert_eq!(m.tokens, 4);
        assert_eq!(m.groups, groups(0, 1));
    }

    #[test]
    fn partial_tail_tokens_ignored_on_insert() {
        let mut idx = RadixIndex::new(BT, 0.0);
        let toks: Vec<u32> = (0..10).collect(); // 2 blocks + 2 stray tokens
        idx.insert(&toks, &groups(0, 2), 1.0);
        assert_eq!(idx.total_token_blocks(), 2);
        let m = idx.match_prefix(&toks, 2.0);
        assert_eq!(m.tokens, 8);
    }

    #[test]
    fn shared_prefix_splits_node() {
        let mut idx = RadixIndex::new(BT, 0.0);
        let a: Vec<u32> = vec![1, 2, 3, 4, 5, 6, 7, 8];
        let b: Vec<u32> = vec![1, 2, 3, 4, 9, 9, 9, 9];
        idx.insert(&a, &groups(0, 2), 1.0);
        let dup = idx.insert(&b, &groups(10, 2), 2.0);
        // First block of b duplicates a's first block.
        assert_eq!(dup, vec![vec![addr(10)]]);
        assert_eq!(idx.total_token_blocks(), 3);
        let ma = idx.match_prefix(&a, 3.0);
        assert_eq!(ma.groups, groups(0, 2));
        let mb = idx.match_prefix(&b, 3.0);
        assert_eq!(mb.groups, vec![vec![addr(0)], vec![addr(11)]]);
    }

    #[test]
    fn duplicate_insert_reports_all_groups() {
        let mut idx = RadixIndex::new(BT, 0.0);
        let toks: Vec<u32> = (0..8).collect();
        idx.insert(&toks, &groups(0, 2), 1.0);
        let dup = idx.insert(&toks, &groups(50, 2), 2.0);
        assert_eq!(dup, groups(50, 2));
        assert_eq!(idx.total_token_blocks(), 2);
    }

    #[test]
    fn extension_insert_reuses_prefix() {
        let mut idx = RadixIndex::new(BT, 0.0);
        idx.insert(&seq(&[1, 2, 3, 4]), &groups(0, 1), 1.0);
        // Extend with 2 blocks; first duplicates.
        let dup = idx.insert(&seq(&[1, 2, 3, 4, 5, 6, 7, 8]), &groups(10, 2), 2.0);
        assert_eq!(dup, vec![vec![addr(10)]]);
        let m = idx.match_prefix(&seq(&[1, 2, 3, 4, 5, 6, 7, 8]), 3.0);
        assert_eq!(m.groups, vec![vec![addr(0)], vec![addr(11)]]);
    }

    #[test]
    fn delete_exact_and_subtree() {
        let mut idx = RadixIndex::new(BT, 0.0);
        let a: Vec<u32> = vec![1, 2, 3, 4, 5, 6, 7, 8];
        let b: Vec<u32> = vec![1, 2, 3, 4, 9, 9, 9, 9];
        idx.insert(&a, &groups(0, 2), 1.0);
        idx.insert(&b, &groups(10, 2), 1.0);
        // Delete prefix [1,2,3,4]: everything below goes too.
        let freed = idx.delete(&seq(&[1, 2, 3, 4]));
        let mut f = freed.clone();
        f.sort();
        assert_eq!(f, vec![addr(0), addr(1), addr(11)]);
        assert!(idx.is_empty());
        assert_eq!(idx.match_prefix(&a, 2.0).tokens, 0);
    }

    #[test]
    fn delete_missing_is_noop() {
        let mut idx = RadixIndex::new(BT, 0.0);
        idx.insert(&seq(&[1, 2, 3, 4]), &groups(0, 1), 1.0);
        assert!(idx.delete(&seq(&[9, 9, 9, 9])).is_empty());
        assert_eq!(idx.total_token_blocks(), 1);
    }

    #[test]
    fn evict_lru_takes_oldest_leaf() {
        let mut idx = RadixIndex::new(BT, 0.0);
        idx.insert(&seq(&[1, 1, 1, 1]), &groups(0, 1), 1.0);
        idx.insert(&seq(&[2, 2, 2, 2]), &groups(1, 1), 2.0);
        idx.insert(&seq(&[3, 3, 3, 3]), &groups(2, 1), 3.0);
        // Touch the oldest so the second-oldest becomes the victim.
        idx.match_prefix(&seq(&[1, 1, 1, 1]), 4.0);
        let freed = idx.evict_lru(1);
        assert_eq!(freed, vec![addr(1)]);
        assert_eq!(idx.total_token_blocks(), 2);
    }

    #[test]
    fn evict_leaf_before_parent() {
        let mut idx = RadixIndex::new(BT, 0.0);
        let long: Vec<u32> = (0..8).collect();
        idx.insert(&long, &groups(0, 2), 1.0);
        let short: Vec<u32> = (0..4).collect();
        // Split so parent=block0, leaf=block1.
        idx.insert(&seq(&[0, 1, 2, 3, 9, 9, 9, 9]), &groups(10, 2), 2.0);
        let freed = idx.evict_lru(1);
        // Oldest leaf is the tail of `long` (last_access 1.0), not the
        // shared parent block.
        assert_eq!(freed, vec![addr(1)]);
        assert_eq!(idx.match_prefix(&short, 3.0).tokens, 4);
    }

    #[test]
    fn ttl_expiry() {
        let mut idx = RadixIndex::new(BT, 10.0);
        idx.insert(&seq(&[1, 1, 1, 1]), &groups(0, 1), 0.0);
        idx.insert(&seq(&[2, 2, 2, 2]), &groups(1, 1), 5.0);
        let freed = idx.expire(12.0);
        assert_eq!(freed, vec![addr(0)]);
        assert_eq!(idx.total_token_blocks(), 1);
        assert_eq!(idx.match_prefix(&seq(&[2, 2, 2, 2]), 12.0).tokens, 4);
    }

    #[test]
    fn remap_rewrites_addrs() {
        let mut idx = RadixIndex::new(BT, 0.0);
        idx.insert(&seq(&[1, 2, 3, 4]), &groups(0, 1), 1.0);
        let mut map = HashMap::new();
        map.insert(addr(0), BlockAddr::new(InstanceId(0), Tier::Dram, 7));
        idx.remap(&map);
        let m = idx.match_prefix(&seq(&[1, 2, 3, 4]), 2.0);
        assert_eq!(m.groups[0][0].tier, Tier::Dram);
        assert_eq!(m.groups[0][0].index, 7);
    }

    #[test]
    fn pinned_leaf_not_evicted() {
        let mut idx = RadixIndex::new(BT, 0.0);
        idx.insert(&seq(&[1, 1, 1, 1]), &groups(0, 1), 1.0);
        idx.insert(&seq(&[2, 2, 2, 2]), &groups(1, 1), 2.0);
        assert_eq!(idx.pin(&seq(&[1, 1, 1, 1])), 4);
        // Oldest leaf is pinned -> second-oldest goes first.
        assert_eq!(idx.evict_lru(1), vec![addr(1)]);
        // Nothing else evictable while pinned.
        assert!(idx.evict_lru(1).is_empty());
        idx.unpin(&seq(&[1, 1, 1, 1]));
        assert_eq!(idx.evict_lru(1), vec![addr(0)]);
    }

    #[test]
    fn pin_survives_split_and_unpins_cleanly() {
        let mut idx = RadixIndex::new(BT, 0.0);
        let long: Vec<u32> = (0..8).collect();
        idx.insert(&long, &groups(0, 2), 1.0);
        idx.pin(&long);
        // A diverging insert splits the pinned node.
        idx.insert(&seq(&[0, 1, 2, 3, 9, 9, 9, 9]), &groups(10, 2), 2.0);
        // Both halves of `long` remain protected.
        let freed = idx.evict_lru(10);
        assert_eq!(freed, vec![addr(11)]); // only the diverging leaf
        idx.unpin(&long);
        let freed2 = idx.evict_lru(10);
        assert_eq!(freed2.len(), 2, "{freed2:?}");
    }

    #[test]
    fn pin_partial_edge_splits_for_exact_coverage() {
        let mut idx = RadixIndex::new(BT, 0.0);
        let long: Vec<u32> = (0..12).collect();
        idx.insert(&long, &groups(0, 3), 1.0);
        // Pin only the first 2 blocks.
        assert_eq!(idx.pin(&long[..8]), 8);
        // The unpinned tail block is evictable; the pinned head is not.
        let freed = idx.evict_lru(5);
        assert_eq!(freed, vec![addr(2)]);
        idx.unpin(&long[..8]);
        assert_eq!(idx.evict_lru(5).len(), 2);
    }

    #[test]
    fn pinned_nodes_skip_ttl_and_swap_selection() {
        let mut idx = RadixIndex::new(BT, 10.0);
        idx.insert(&seq(&[1, 1, 1, 1]), &groups(0, 1), 0.0);
        idx.pin(&seq(&[1, 1, 1, 1]));
        assert!(idx.expire(100.0).is_empty());
        assert!(idx.lru_addrs(5, |_| true).is_empty());
        idx.unpin(&seq(&[1, 1, 1, 1]));
        assert_eq!(idx.expire(100.0), vec![addr(0)]);
    }

    #[test]
    fn identity_insert_reports_no_dup() {
        let mut idx = RadixIndex::new(BT, 0.0);
        let toks: Vec<u32> = (0..8).collect();
        idx.insert(&toks, &groups(0, 2), 1.0);
        // Re-insert the exact same groups (the engine retire path after a
        // full cache hit): nothing is duplicate, nothing to free.
        let dup = idx.insert(&toks, &groups(0, 2), 2.0);
        assert!(dup.is_empty());
        // Mixed: first group aliases, second is a fresh copy.
        let mixed = vec![vec![addr(0)], vec![addr(50)]];
        let dup2 = idx.insert(&toks, &mixed, 3.0);
        assert_eq!(dup2, vec![vec![addr(50)]]);
        assert_eq!(idx.total_token_blocks(), 2);
    }

    #[test]
    fn node_reuse_after_delete() {
        let mut idx = RadixIndex::new(BT, 0.0);
        for round in 0..10 {
            let t: Vec<u32> = (0..4).map(|i| i + round).collect();
            idx.insert(&t, &groups(round, 1), round as f64);
            idx.delete(&t);
        }
        assert!(idx.nodes.len() < 6, "nodes leaked: {}", idx.nodes.len());
    }

    /// Executable-spec model: a map from every block-aligned prefix to
    /// its first-insertion group. With children keyed by whole blocks,
    /// the tree accepts every new block whose parent prefix exists —
    /// exactly a prefix map.
    #[derive(Default)]
    struct Model {
        /// accepted prefix (ending on a block boundary) -> its group
        addrs: HashMap<Vec<u32>, BlockGroup>,
    }

    impl Model {
        fn insert(&mut self, toks: &[u32], gs: &[BlockGroup]) {
            let mut p: Vec<u32> = vec![];
            for (i, grp) in gs.iter().enumerate() {
                p.extend(&toks[i * BT..(i + 1) * BT]);
                self.addrs.entry(p.clone()).or_insert_with(|| grp.clone());
            }
        }

        fn match_prefix(&self, toks: &[u32]) -> (usize, Vec<BlockGroup>) {
            let mut p: Vec<u32> = vec![];
            let mut out = vec![];
            for i in 0..toks.len() / BT {
                let b = &toks[i * BT..(i + 1) * BT];
                let mut q = p.clone();
                q.extend(b);
                match self.addrs.get(&q) {
                    Some(grp) => {
                        out.push(grp.clone());
                        p = q;
                    }
                    None => break,
                }
            }
            (p.len(), out)
        }
    }

    #[test]
    fn prop_matches_naive_model() {
        proptest(60, |g| {
            let mut idx = RadixIndex::new(BT, 0.0);
            let mut model = Model::default();
            let mut next_addr = 0u32;
            let mut now = 0.0;
            for _ in 0..g.usize(1, 25) {
                now += 1.0;
                // Small alphabet to force shared prefixes and splits.
                let len = g.usize(0, 6) * BT + g.usize(0, BT - 1);
                let toks = g.vec_u32(len, 0, 3);
                if g.bool() {
                    let nb = idx.usable_len(toks.len()) / BT;
                    let gs: Vec<BlockGroup> = (0..nb)
                        .map(|i| vec![addr(next_addr + i as u32)])
                        .collect();
                    next_addr += nb as u32;
                    idx.insert(&toks, &gs, now);
                    model.insert(&toks, &gs);
                } else {
                    let m = idx.match_prefix(&toks, now);
                    let (expect, expect_groups) = model.match_prefix(&toks);
                    assert_eq!(m.tokens, expect, "toks={toks:?}");
                    assert_eq!(m.groups, expect_groups);
                }
                assert_eq!(idx.total_token_blocks(), model.addrs.len());
            }
        });
    }

    /// Eviction + insert interleaving never corrupts counters or leaks.
    #[test]
    fn prop_evict_consistency() {
        proptest(40, |g| {
            let mut idx = RadixIndex::new(BT, 0.0);
            let mut next_addr = 0u32;
            let mut live: std::collections::HashSet<BlockAddr> =
                Default::default();
            let mut now = 0.0;
            for _ in 0..g.usize(1, 40) {
                now += 1.0;
                if g.bool() {
                    let len = g.usize(1, 5) * BT;
                    let toks = g.vec_u32(len, 0, 4);
                    let nb = len / BT;
                    let gs: Vec<BlockGroup> = (0..nb)
                        .map(|i| vec![addr(next_addr + i as u32)])
                        .collect();
                    next_addr += nb as u32;
                    for grp in &gs {
                        live.insert(grp[0]);
                    }
                    for grp in idx.insert(&toks, &gs, now) {
                        for a in grp {
                            live.remove(&a);
                        }
                    }
                } else {
                    for a in idx.evict_lru(g.usize(1, 3)) {
                        assert!(live.remove(&a), "double-evict {a}");
                    }
                }
                let mut in_tree = idx.all_addrs();
                in_tree.sort();
                let mut expect: Vec<BlockAddr> =
                    live.iter().copied().collect();
                expect.sort();
                assert_eq!(in_tree, expect, "tree/model addr divergence");
                assert_eq!(idx.total_token_blocks(), in_tree.len());
            }
        });
    }
}
