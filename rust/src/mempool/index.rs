//! Token-indexed radix tree mapping prompt prefixes to historical KV
//! cache blocks (paper §4.2) — the hot-path edition.
//!
//! Following SGLang's design with the paper's two extensions: (a) block
//! addresses can point at *any tier* (HBM or DRAM — see [`super::tier`]),
//! and (b) the same structure doubles as the global scheduler's prompt
//! tree. Indexing granularity is one *token-block* (`block_tokens`
//! tokens, 16 in the paper's tests): only full blocks are cached, and
//! every edge length is a multiple of `block_tokens`, so node splits land
//! on block boundaries and the KV layout never needs reshaping.
//!
//! # Internals (performance notes)
//!
//! The paper's requirement is that index checks stay µs-scale on the
//! request path, far below ms-scale model compute. Three design choices
//! keep it there (the seed implementation — preserved verbatim in
//! [`super::index_ref`] as a differential-testing baseline — paid a
//! `Vec<u32>` key allocation + 64-byte SipHash per tree hop, one heap
//! clone per matched token-block, and an O(nodes) scan *per eviction
//! victim*):
//!
//! * **Fingerprint-keyed children.** Children are keyed by a 64-bit
//!   FxHash-style fingerprint of the child's first edge block
//!   ([`block_fingerprint`]) in a `HashMap<u64, usize>` with a
//!   pass-through hasher. Lookup hashes `block_tokens` words once and
//!   compares actual tokens only on fingerprint hit; colliding siblings
//!   chain intrusively through `Node::next_sibling`, so collisions cost
//!   one extra token compare, never a wrong answer.
//! * **Flat per-node address storage.** Each node stores its block
//!   groups as one flat `Vec<BlockAddr>` (`group_size` addresses per
//!   token-block). [`RadixIndex::match_prefix`] appends node slices into
//!   a [`GroupList`] — one `memcpy` per *node* on the path and zero
//!   per-block allocations (the seed cloned one `Vec` per matched
//!   token-block: 256 clones for a 4K-token match at bt=16).
//! * **O(log n) LRU + pinned-descendant counters.** Eviction victims
//!   come from a lazy min-heap over candidate leaves; stale entries are
//!   invalidated by a per-node `stamp` and discarded at pop, so victim
//!   selection is O(log n) amortized instead of an O(nodes) scan per
//!   victim. Each node also maintains `sub_pins` (total pins in its
//!   subtree), making the old recursive `subtree_pinned` walk an O(1)
//!   field read (used by TTL expiry).
//!
//! Eviction is LRU over leaves (evicting an interior node would orphan
//! its descendants' prefixes); TTL expiry handles the global tree's
//! staleness problem (paper §6 Discussion).
//!
//! # Lock-free read path
//!
//! [`RadixIndex::match_prefix`] takes `&self`: the only state a match
//! mutates is recency. `last_access` is a relaxed `AtomicU64` (f64
//! bits), and LRU heap maintenance for touched *leaves* is deferred
//! through a bounded slot queue ([`DeferredTouches`]) drained at the
//! top of every `&mut` operation — exclusive access makes the drain
//! race-free by construction. Concurrent readers therefore share the
//! index with zero contention; LRU ordering is exact up to the drain
//! point, which every structural operation (insert / evict / expire /
//! pin / …) establishes before it reads the heap.
//!
//! The one subtle invariant: a live heap entry is keyed by the exact
//! `(stamp, last_access)` pair, so an evictable leaf's `last_access`
//! may only advance when its deferred refresh is *guaranteed* to land.
//! On a full queue the touch is dropped whole (counted in
//! [`TouchStats::dropped`]) and the leaf keeps its older — therefore
//! eviction-safe — access time; advancing the clock without queueing
//! the refresh would orphan the heap entry and leak the leaf as
//! permanently unevictable.

use std::collections::{BinaryHeap, HashMap};
use std::hash::{BuildHasherDefault, Hasher};

use crate::util::sync::{
    with_mut_u64, with_mut_usize, AtomicU64, AtomicUsize, Ordering,
};

use crate::util::heap::lazy_heap_needs_compact;

use super::block::BlockAddr;

/// Addresses backing one token-block (1 entry when aggregated, 2·L when
/// discrete). Used on the *insert* side; matches come back as a
/// [`GroupList`].
pub type BlockGroup = Vec<BlockAddr>;

/// Sentinel for "no node" in intrusive links.
const NONE: usize = usize::MAX;

const ROOT: usize = 0;

/// FxHash-style 64-bit fingerprint of one token-block. One
/// multiply-rotate step per token — no allocation, no byte-wise SipHash.
#[inline]
pub fn block_fingerprint(block: &[u32]) -> u64 {
    const K: u64 = 0x517c_c1b7_2722_0a95;
    let mut h = 0x2d35_8dcc_aa6c_78a5u64 ^ block.len() as u64;
    for &t in block {
        h = (h.rotate_left(5) ^ t as u64).wrapping_mul(K);
    }
    h
}

/// Pass-through hasher for already-mixed u64 fingerprint keys.
#[derive(Default)]
pub struct FpHasher(u64);

impl Hasher for FpHasher {
    #[inline]
    fn finish(&self) -> u64 {
        self.0
    }

    #[inline]
    fn write(&mut self, bytes: &[u8]) {
        const K: u64 = 0x517c_c1b7_2722_0a95;
        for &b in bytes {
            self.0 = (self.0.rotate_left(8) ^ b as u64).wrapping_mul(K);
        }
    }

    #[inline]
    fn write_u64(&mut self, k: u64) {
        self.0 = k;
    }
}

type FpMap = HashMap<u64, usize, BuildHasherDefault<FpHasher>>;

/// Flat, zero-clone view of matched block groups: `n_groups` groups of
/// `group_size` addresses each, stored contiguously in match order.
/// Group 2·i of a discrete-layout pool is `&list[i]` — an indexed slice,
/// not an owned `Vec`.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct GroupList {
    addrs: Vec<BlockAddr>,
    group_size: usize,
    n_groups: usize,
}

impl GroupList {
    /// Number of groups (matched token-blocks).
    pub fn len(&self) -> usize {
        self.n_groups
    }

    pub fn is_empty(&self) -> bool {
        self.n_groups == 0
    }

    /// Addresses per group (0 for address-free trees).
    pub fn group_size(&self) -> usize {
        self.group_size
    }

    /// All addresses, flat, in match order.
    pub fn flat(&self) -> &[BlockAddr] {
        &self.addrs
    }

    /// Borrowed view of group `i`.
    pub fn group(&self, i: usize) -> &[BlockAddr] {
        assert!(i < self.n_groups, "group {i} out of {}", self.n_groups);
        let gs = self.group_size;
        &self.addrs[i * gs..(i + 1) * gs]
    }

    /// Iterate groups as borrowed slices.
    pub fn iter(&self) -> impl Iterator<Item = &[BlockAddr]> + '_ {
        let gs = self.group_size;
        (0..self.n_groups).map(move |i| &self.addrs[i * gs..(i + 1) * gs])
    }

    /// Append one group; the first push fixes the group arity.
    pub fn push_group(&mut self, g: &[BlockAddr]) {
        if self.n_groups == 0 {
            self.group_size = g.len();
        }
        assert_eq!(g.len(), self.group_size, "mixed group arity");
        self.addrs.extend_from_slice(g);
        self.n_groups += 1;
    }

    /// Append `n_blocks` groups copied from a node's flat storage.
    fn extend_flat(&mut self, addrs: &[BlockAddr], gs: usize, n_blocks: usize) {
        if n_blocks == 0 {
            return;
        }
        if self.n_groups == 0 {
            self.group_size = gs;
        }
        // Hard assert (one compare per path node): a silently mixed
        // arity would corrupt every group offset after it.
        assert_eq!(gs, self.group_size, "mixed group arity");
        self.addrs.extend_from_slice(addrs);
        self.n_groups += n_blocks;
    }

    /// Append groups `[from, to)` of `other` (same arity — one memcpy).
    pub fn extend_range(&mut self, other: &GroupList, from: usize, to: usize) {
        assert!(from <= to && to <= other.n_groups, "range out of bounds");
        if from == to {
            return;
        }
        let gs = other.group_size;
        self.extend_flat(&other.addrs[from * gs..to * gs], gs, to - from);
    }

    /// Append every group of `other` (same arity).
    pub fn extend_list(&mut self, other: &GroupList) {
        self.extend_range(other, 0, other.n_groups);
    }

    /// Keep only the first `n` groups.
    pub fn truncate(&mut self, n: usize) {
        if n < self.n_groups {
            self.addrs.truncate(n * self.group_size);
            self.n_groups = n;
        }
    }

    /// Materialize owned per-group `Vec`s (slow path: retire/mutation).
    pub fn to_groups(&self) -> Vec<BlockGroup> {
        self.iter().map(|g| g.to_vec()).collect()
    }
}

impl std::ops::Index<usize> for GroupList {
    type Output = [BlockAddr];

    fn index(&self, i: usize) -> &[BlockAddr] {
        self.group(i)
    }
}

/// Equality against the owned-group form, for tests and callers that
/// still speak `Vec<BlockGroup>`.
impl PartialEq<Vec<BlockGroup>> for GroupList {
    fn eq(&self, other: &Vec<BlockGroup>) -> bool {
        self.n_groups == other.len()
            && self.iter().zip(other).all(|(a, b)| a == b.as_slice())
    }
}

#[derive(Debug)]
struct Node {
    /// Edge label from the parent; length is a multiple of `block_tokens`
    /// (except the root, which has an empty edge).
    edge: Vec<u32>,
    /// Flat block addresses: `edge_blocks * group_size` entries,
    /// block-major.
    addrs: Vec<BlockAddr>,
    /// Addresses per token-block (0 for address-free trees, e.g. the
    /// global prompt trees).
    group_size: u32,
    /// Children keyed by the fingerprint of the *entire first block* of
    /// the child's edge (not the first token): distinct blocks that
    /// happen to share a first token — e.g. sessions diverging inside
    /// the block where a common non-aligned prefix ends — must coexist.
    children: FpMap,
    /// Next child of the same parent whose first block collides on
    /// fingerprint (NONE-terminated chain).
    next_sibling: usize,
    parent: usize,
    /// f64 bits of the last-access time, relaxed-atomic so the `&self`
    /// match path can bump recency concurrently (see module docs).
    last_access: AtomicU64,
    /// In-use count: requests currently reading this node's blocks.
    /// Pinned nodes are skipped by eviction, swap victim selection, and
    /// TTL expiry (SGLang's lock_ref, needed so an admission's matched
    /// prefix cannot be reclaimed before the request retires).
    pins: u32,
    /// Total pins in this node's subtree (self included) — the O(1)
    /// replacement for the recursive `subtree_pinned` walk.
    sub_pins: u32,
    /// Bumped whenever this node's LRU candidacy or access time changes;
    /// heap entries carrying an older stamp are discarded at pop.
    stamp: u64,
    valid: bool,
}

impl Node {
    fn blocks(&self, block_tokens: usize) -> usize {
        self.edge.len() / block_tokens
    }

    #[inline]
    fn access(&self) -> f64 {
        // ordering: Relaxed — a recency stamp read/written by racing
        // `&self` matchers; any interleaving yields SOME matcher's
        // timestamp, and eviction only needs approximate recency.
        f64::from_bits(self.last_access.load(Ordering::Relaxed))
    }

    #[inline]
    fn set_access(&self, now: f64) {
        // ordering: Relaxed — see `access`; no other memory is
        // published through this stamp.
        self.last_access.store(now.to_bits(), Ordering::Relaxed);
    }
}

/// NetStats-style counters for the deferred-touch queue (see module
/// docs): how many leaf touches were queued by `&self` matches, how
/// many a `&mut` drain has refreshed into the LRU heap, and how many
/// were dropped because the queue was at capacity (those leaves kept
/// their old access time — older, never newer, than the truth).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct TouchStats {
    pub deferred: u64,
    pub drained: u64,
    pub dropped: u64,
}

/// Bounded multi-producer slot queue of leaf touches. Producers (the
/// `&self` match path) claim a slot by `fetch_add` and store the node
/// index; the consumer runs only under `&mut RadixIndex`, when Rust's
/// aliasing rules guarantee no producer is mid-store, so the drain
/// needs no synchronization beyond reading the atomics.
#[derive(Debug)]
struct DeferredTouches {
    /// `node + 1` per claimed slot (0 = never written).
    slots: Box<[AtomicU64]>,
    /// Slots claimed since the last drain (may exceed `slots.len()`:
    /// the excess claims were dropped).
    claimed: AtomicUsize,
    deferred: AtomicU64,
    drained: AtomicU64,
    dropped: AtomicU64,
}

impl DeferredTouches {
    fn new(capacity: usize) -> Self {
        assert!(capacity > 0, "touch queue needs at least one slot");
        DeferredTouches {
            slots: (0..capacity).map(|_| AtomicU64::new(0)).collect(),
            claimed: AtomicUsize::new(0),
            deferred: AtomicU64::new(0),
            drained: AtomicU64::new(0),
            dropped: AtomicU64::new(0),
        }
    }

    /// Queue a touch of `node`; false when the queue is full (the
    /// caller must then leave the node's access time alone).
    #[inline]
    fn defer(&self, node: usize) -> bool {
        // ordering: Relaxed — fetch_add hands each producer a distinct
        // slot; no release needed anywhere in this protocol because
        // the drain runs under `&mut RadixIndex`, whose exclusive
        // borrow (a sync point in every path that reaches it) is the
        // publication edge. The loom model below pins exactly this
        // claim.
        // ordering: Relaxed — slot claim; see block above.
        let i = self.claimed.fetch_add(1, Ordering::Relaxed);
        if i >= self.slots.len() {
            // ordering: Relaxed — monotonic drop counter.
            self.dropped.fetch_add(1, Ordering::Relaxed);
            return false;
        }
        // ordering: Relaxed — slot store; the drain's `&mut` borrow
        // publishes it (block comment above, loom-pinned).
        self.slots[i].store(node as u64 + 1, Ordering::Relaxed);
        self.deferred.fetch_add(1, Ordering::Relaxed);
        true
    }

    /// Take every queued touch under `&mut` — the aliasing guarantee IS
    /// the synchronization (no producer can be mid-store while an
    /// exclusive borrow exists). Returns the touched node indices.
    fn drain(&mut self) -> Vec<usize> {
        // ordering: (get_mut/with_mut) — exclusive access, no atomics
        // ordering involved at all; see `defer` for the protocol.
        let claimed = with_mut_usize(&mut self.claimed, std::mem::take);
        if claimed == 0 {
            return vec![];
        }
        let n = claimed.min(self.slots.len());
        with_mut_u64(&mut self.drained, |d| *d += n as u64);
        let mut out = Vec::with_capacity(n);
        for slot in self.slots.iter_mut().take(n) {
            let v = with_mut_u64(slot, std::mem::take);
            if v == 0 {
                continue; // claimed but never stored: impossible under &mut
            }
            out.push((v - 1) as usize);
        }
        out
    }

    fn stats(&self) -> TouchStats {
        // ordering: Relaxed — diagnostic counters; each is
        // independently monotonic.
        TouchStats {
            deferred: self.deferred.load(Ordering::Relaxed),
            drained: self.drained.load(Ordering::Relaxed),
            dropped: self.dropped.load(Ordering::Relaxed),
        }
    }
}

/// Lazy-deletion min-heap entry for LRU victim selection.
#[derive(Debug, PartialEq)]
struct LruEntry {
    access: f64,
    stamp: u64,
    node: usize,
}

impl Eq for LruEntry {}

impl Ord for LruEntry {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // Reversed: BinaryHeap is a max-heap, we want the oldest access
        // first; ties break toward the lowest node index (deterministic,
        // and it matches the seed's first-minimum scan).
        other
            .access
            .partial_cmp(&self.access)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then_with(|| other.node.cmp(&self.node))
    }
}

impl PartialOrd for LruEntry {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

#[derive(Debug)]
pub struct RadixIndex {
    nodes: Vec<Node>,
    free_list: Vec<usize>,
    block_tokens: usize,
    /// TTL in seconds; 0 disables expiry.
    ttl: f64,
    token_blocks: usize,
    /// Live (valid, non-root) node count.
    live_nodes: usize,
    /// Candidate-leaf min-heap (lazy deletion via `Node::stamp`).
    lru: BinaryHeap<LruEntry>,
    /// Mask applied to child fingerprints. All-ones normally; tests
    /// shrink it to force collision chains.
    fp_mask: u64,
    /// Leaf touches queued by `&self` matches, drained (into
    /// [`Self::refresh_lru`]) at the top of every `&mut` operation.
    touches: DeferredTouches,
}

/// Default capacity of the deferred-touch queue: the number of leaf
/// touches `&self` matches can queue between two `&mut` operations
/// before further touches are dropped (dropped leaves keep their old,
/// eviction-safe access time — see the module docs). 1024 covers far
/// more concurrent matches than any realistic gap between structural
/// operations at 8 bytes per slot.
pub const DEFERRED_TOUCH_CAP: usize = 1024;

/// Result of a prefix match: matched length plus a zero-clone
/// [`GroupList`] of the matched block groups in prompt order.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct IndexMatch {
    /// Matched length in tokens (multiple of block_tokens).
    pub tokens: usize,
    /// One group per matched token-block, in prompt order.
    pub groups: GroupList,
}

impl RadixIndex {
    pub fn new(block_tokens: usize, ttl: f64) -> Self {
        Self::with_touch_capacity(block_tokens, ttl, DEFERRED_TOUCH_CAP)
    }

    /// [`Self::new`] with an explicit deferred-touch queue capacity —
    /// tests shrink it to exercise the dropped-at-capacity path.
    pub fn with_touch_capacity(
        block_tokens: usize,
        ttl: f64,
        touch_capacity: usize,
    ) -> Self {
        assert!(block_tokens > 0);
        RadixIndex {
            nodes: vec![Node {
                edge: vec![],
                addrs: vec![],
                group_size: 0,
                children: FpMap::default(),
                next_sibling: NONE,
                parent: ROOT,
                last_access: AtomicU64::new(0.0f64.to_bits()),
                pins: 0,
                sub_pins: 0,
                stamp: 0,
                valid: true,
            }],
            free_list: vec![],
            block_tokens,
            ttl,
            token_blocks: 0,
            live_nodes: 0,
            lru: BinaryHeap::new(),
            fp_mask: u64::MAX,
            touches: DeferredTouches::new(touch_capacity),
        }
    }

    pub fn block_tokens(&self) -> usize {
        self.block_tokens
    }

    /// Total token-blocks currently indexed.
    pub fn total_token_blocks(&self) -> usize {
        self.token_blocks
    }

    pub fn is_empty(&self) -> bool {
        self.token_blocks == 0
    }

    /// Test hook: mask child fingerprints down to `mask` bits so
    /// collisions become common and the sibling chains get exercised.
    /// Must be called on a fresh, empty index (existing map keys would
    /// otherwise go stale).
    #[doc(hidden)]
    pub fn set_fingerprint_mask(&mut self, mask: u64) {
        assert!(
            self.nodes[ROOT].children.is_empty() && self.live_nodes == 0,
            "fingerprint mask must be set before any insert"
        );
        self.fp_mask = mask;
    }

    #[inline]
    fn fp(&self, block: &[u32]) -> u64 {
        block_fingerprint(block) & self.fp_mask
    }

    // ------------------------------------------------------------------
    // Node + child-link plumbing
    // ------------------------------------------------------------------

    fn alloc_node(&mut self, mut node: Node) -> usize {
        self.live_nodes += 1;
        if let Some(i) = self.free_list.pop() {
            // Continue the slot's stamp sequence so heap entries from a
            // previous incarnation of this slot can never alias the new
            // node.
            node.stamp = self.nodes[i].stamp + 1;
            self.nodes[i] = node;
            i
        } else {
            self.nodes.push(node);
            self.nodes.len() - 1
        }
    }

    fn release_node(&mut self, idx: usize) {
        debug_assert_ne!(idx, ROOT);
        let n = &mut self.nodes[idx];
        n.valid = false;
        n.stamp += 1;
        n.children.clear();
        n.edge.clear();
        n.addrs.clear();
        n.next_sibling = NONE;
        n.pins = 0;
        n.sub_pins = 0;
        self.live_nodes -= 1;
        self.free_list.push(idx);
    }

    /// Find `parent`'s child whose edge starts with the block `key`.
    /// Fingerprint first; token verification only on fingerprint hit.
    fn find_child(&self, parent: usize, key: &[u32]) -> Option<usize> {
        let fp = self.fp(key);
        let mut cand = self.nodes[parent].children.get(&fp).copied();
        while let Some(i) = cand {
            if &self.nodes[i].edge[..self.block_tokens] == key {
                return Some(i);
            }
            let next = self.nodes[i].next_sibling;
            cand = if next == NONE { None } else { Some(next) };
        }
        None
    }

    /// Link `child` under `parent`, chaining on fingerprint collision.
    fn attach_child(&mut self, parent: usize, child: usize) {
        let fp = self.fp(&self.nodes[child].edge[..self.block_tokens]);
        let prev = self.nodes[parent].children.insert(fp, child);
        self.nodes[child].next_sibling = prev.unwrap_or(NONE);
    }

    /// Unlink `child` from `parent` (must be linked). Call before the
    /// child's edge is modified — the fingerprint is recomputed from it.
    fn detach_child(&mut self, parent: usize, child: usize) {
        let fp = self.fp(&self.nodes[child].edge[..self.block_tokens]);
        let head = self.nodes[parent].children[&fp];
        if head == child {
            let next = self.nodes[child].next_sibling;
            if next == NONE {
                self.nodes[parent].children.remove(&fp);
            } else {
                *self.nodes[parent].children.get_mut(&fp).unwrap() = next;
            }
        } else {
            let mut prev = head;
            loop {
                let next = self.nodes[prev].next_sibling;
                if next == NONE {
                    debug_assert!(false, "child not linked under parent");
                    break;
                }
                if next == child {
                    self.nodes[prev].next_sibling =
                        self.nodes[child].next_sibling;
                    break;
                }
                prev = next;
            }
        }
        self.nodes[child].next_sibling = NONE;
    }

    /// All children of `node` (map heads plus collision chains).
    fn child_indices(&self, node: usize) -> Vec<usize> {
        let mut out = vec![];
        for &head in self.nodes[node].children.values() {
            let mut c = head;
            while c != NONE {
                out.push(c);
                c = self.nodes[c].next_sibling;
            }
        }
        out
    }

    // ------------------------------------------------------------------
    // LRU heap + pin-counter plumbing
    // ------------------------------------------------------------------

    fn lru_entry_live(&self, e: &LruEntry) -> bool {
        if e.node == ROOT {
            return false;
        }
        let n = &self.nodes[e.node];
        n.valid
            && e.stamp == n.stamp
            && e.access == n.access()
            && n.children.is_empty()
            && n.pins == 0
    }

    /// Invalidate any stale heap entry for `idx` and, if it is an
    /// evictable leaf right now, push a fresh one. Call whenever a
    /// node's candidacy inputs change (access, pins, leaf-ness, death).
    fn refresh_lru(&mut self, idx: usize) {
        let n = &mut self.nodes[idx];
        n.stamp += 1;
        if idx != ROOT && n.valid && n.pins == 0 && n.children.is_empty() {
            self.lru.push(LruEntry {
                access: n.access(),
                stamp: n.stamp,
                node: idx,
            });
        }
        // Bound stale-entry growth: rebuild when the heap is dominated
        // by dead entries (shared policy, see `util::heap`).
        if lazy_heap_needs_compact(self.lru.len(), self.live_nodes) {
            let old = std::mem::take(&mut self.lru);
            for e in old {
                if self.lru_entry_live(&e) {
                    self.lru.push(e);
                }
            }
        }
    }

    /// Bump `idx`'s access time, re-queueing it for LRU if it is a leaf.
    fn touch(&mut self, idx: usize, now: f64) {
        self.nodes[idx].set_access(now);
        if self.nodes[idx].children.is_empty() {
            self.refresh_lru(idx);
        }
    }

    /// `&self` counterpart of [`Self::touch`] for the shared match
    /// path. Interior nodes (and the root) carry no heap entry, so a
    /// plain atomic store suffices; a leaf's heap refresh is deferred
    /// through the touch queue, and — the module-docs invariant — its
    /// access time only advances when the deferral actually landed.
    fn touch_shared(&self, idx: usize, now: f64) {
        let n = &self.nodes[idx];
        if n.children.is_empty() {
            if self.touches.defer(idx) {
                n.set_access(now);
            }
        } else {
            n.set_access(now);
        }
    }

    /// Apply every queued leaf touch to the LRU heap. Runs at the top
    /// of each `&mut` operation, so by the time structural state is
    /// read or modified the heap reflects all completed matches. Under
    /// `&mut self` no reader is live, hence plain `get_mut` access.
    fn drain_touches(&mut self) {
        for idx in self.touches.drain() {
            // Node identity is stable from defer to drain: any
            // structural mutation since would itself have drained first.
            if self.nodes[idx].valid && self.nodes[idx].children.is_empty() {
                self.refresh_lru(idx);
            }
        }
    }

    /// Deferred-touch queue counters (see [`TouchStats`]).
    pub fn touch_stats(&self) -> TouchStats {
        self.touches.stats()
    }

    /// Capacity of the deferred-touch queue.
    pub fn touch_queue_capacity(&self) -> usize {
        self.touches.slots.len()
    }

    /// Add `delta` to `sub_pins` on `idx` and every ancestor up to root.
    fn adjust_sub_pins(&mut self, mut idx: usize, delta: i32) {
        loop {
            let n = &mut self.nodes[idx];
            n.sub_pins = (n.sub_pins as i64 + delta as i64) as u32;
            if idx == ROOT {
                break;
            }
            idx = n.parent;
        }
    }

    // ------------------------------------------------------------------
    // Core operations
    // ------------------------------------------------------------------

    /// Truncate a token sequence to whole token-blocks.
    pub fn usable_len(&self, tokens: usize) -> usize {
        tokens - tokens % self.block_tokens
    }

    /// Insert `tokens` (truncated to whole blocks) mapping to `groups`
    /// (one per token-block). Returns the *duplicate* groups — block
    /// groups the caller passed for prefixes that were already indexed —
    /// so the caller can free that memory (paper: `insert` retires the
    /// active KV; if the prefix is already cached the new copy is
    /// redundant).
    pub fn insert(&mut self, tokens: &[u32], groups: &[BlockGroup], now: f64)
                  -> Vec<BlockGroup> {
        self.insert_with(tokens, groups.len(), |i| groups[i].as_slice(), now)
            .to_groups()
    }

    /// [`Self::insert`] over a [`GroupList`] — the engine's retire path,
    /// which no longer materializes `Vec<BlockGroup>`. Duplicates come
    /// back as a `GroupList` too (free them via its flat slice).
    pub fn insert_list(&mut self, tokens: &[u32], groups: &GroupList,
                       now: f64) -> GroupList {
        self.insert_with(tokens, groups.len(), |i| groups.group(i), now)
    }

    fn insert_with<'g, F>(&mut self, tokens: &[u32], n_groups: usize,
                          group: F, now: f64) -> GroupList
    where
        F: Fn(usize) -> &'g [BlockAddr],
    {
        self.drain_touches();
        let bt = self.block_tokens;
        let usable = self.usable_len(tokens.len());
        let tokens = &tokens[..usable];
        let n_blocks = usable / bt;
        assert!(n_groups >= n_blocks, "need {n_blocks} groups, got {n_groups}");
        let mut dup = GroupList::default();
        let mut cur = ROOT;
        let mut pos = 0; // tokens consumed
        self.nodes[ROOT].set_access(now);

        while pos < usable {
            let key = &tokens[pos..pos + bt];
            match self.find_child(cur, key) {
                None => {
                    // Attach the whole remainder as one new leaf.
                    let start = pos / bt;
                    let gs = group(start).len();
                    let mut addrs =
                        Vec::with_capacity(gs * (n_blocks - start));
                    for i in start..n_blocks {
                        let g = group(i);
                        assert_eq!(g.len(), gs, "mixed group arity");
                        addrs.extend_from_slice(g);
                    }
                    self.token_blocks += n_blocks - start;
                    let leaf = self.alloc_node(Node {
                        edge: tokens[pos..].to_vec(),
                        addrs,
                        group_size: gs as u32,
                        children: FpMap::default(),
                        next_sibling: NONE,
                        parent: cur,
                        last_access: AtomicU64::new(now.to_bits()),
                        pins: 0,
                        sub_pins: 0,
                        stamp: 0,
                        valid: true,
                    });
                    self.attach_child(cur, leaf);
                    self.refresh_lru(leaf);
                    return dup;
                }
                Some(child) => {
                    let common = self.common_block_prefix(
                        &self.nodes[child].edge,
                        &tokens[pos..],
                    );
                    debug_assert!(
                        common >= bt,
                        "block-keyed child must share its first block"
                    );
                    if common < self.nodes[child].edge.len() {
                        self.split(child, common);
                    }
                    // The matched blocks already exist: incoming copies
                    // are duplicates — unless they are the *same* blocks
                    // (the engine re-inserts a prompt whose prefix groups
                    // alias what `match` returned; identity means there
                    // is nothing to free).
                    let n_common = common / bt;
                    let start = pos / bt;
                    let gs = self.nodes[child].group_size as usize;
                    for i in 0..n_common {
                        let g = group(start + i);
                        let existing =
                            &self.nodes[child].addrs[i * gs..(i + 1) * gs];
                        if existing != g {
                            dup.push_group(g);
                        }
                    }
                    self.touch(child, now);
                    cur = child;
                    pos += common;
                }
            }
        }
        dup
    }

    /// Address-free insert (global prompt trees / simulator): the same
    /// prefix bookkeeping with implicit empty groups.
    pub fn insert_unaddressed(&mut self, tokens: &[u32], now: f64) {
        let n = self.usable_len(tokens.len()) / self.block_tokens;
        let groups = vec![BlockGroup::new(); n];
        self.insert(tokens, &groups, now);
    }

    /// Longest common prefix of `edge` and `rest`, rounded down to a
    /// block boundary.
    fn common_block_prefix(&self, edge: &[u32], rest: &[u32]) -> usize {
        let mut i = 0;
        let max = edge.len().min(rest.len());
        while i < max && edge[i] == rest[i] {
            i += 1;
        }
        i - i % self.block_tokens
    }

    /// Split `node`'s edge at `at` tokens (block-aligned): the node keeps
    /// the head; a new child gets the tail + original children. Returns
    /// the tail node's index.
    fn split(&mut self, node: usize, at: usize) -> usize {
        let bt = self.block_tokens;
        debug_assert!(at % bt == 0 && at > 0);
        let tail_edge = self.nodes[node].edge.split_off(at);
        let gs = self.nodes[node].group_size;
        let tail_addrs =
            self.nodes[node].addrs.split_off((at / bt) * gs as usize);
        let tail_children = std::mem::take(&mut self.nodes[node].children);
        let last_access = self.nodes[node].access();
        // A pin covers the whole edge (pins are taken on block-split
        // boundaries), so both halves inherit it; unpin walks both.
        let pins = self.nodes[node].pins;
        let sub = self.nodes[node].sub_pins;
        let tail = self.alloc_node(Node {
            edge: tail_edge,
            addrs: tail_addrs,
            group_size: gs,
            children: tail_children,
            next_sibling: NONE,
            parent: node,
            last_access: AtomicU64::new(last_access.to_bits()),
            pins,
            // tail subtree = the old children plus the duplicated pin:
            // exactly the old node's subtree total.
            sub_pins: sub,
            stamp: 0,
            valid: true,
        });
        // Fix the grandchildren's parent pointers.
        for gc in self.child_indices(tail) {
            self.nodes[gc].parent = tail;
        }
        self.nodes[node].sub_pins = sub + pins;
        if pins > 0 {
            // The duplicated pin raises every ancestor's subtree total.
            let parent = self.nodes[node].parent;
            self.adjust_sub_pins(parent, pins as i32);
        }
        self.attach_child(node, tail);
        self.refresh_lru(node); // now interior
        self.refresh_lru(tail); // may be a leaf
        tail
    }

    /// Longest indexed prefix of `tokens`; bumps last_access on the path.
    /// Returns borrowed-copy handles ([`GroupList`]) — no per-block
    /// allocation.
    ///
    /// Takes `&self`: recency is bumped through relaxed atomics and the
    /// deferred-touch queue (module docs), so any number of matches may
    /// run concurrently with each other without contention.
    pub fn match_prefix(&self, tokens: &[u32], now: f64) -> IndexMatch {
        let bt = self.block_tokens;
        let mut cur = ROOT;
        let mut pos = 0;
        let mut out = IndexMatch::default();
        self.nodes[ROOT].set_access(now);
        loop {
            if pos + bt > tokens.len() {
                break;
            }
            let Some(child) = self.find_child(cur, &tokens[pos..pos + bt])
            else {
                break;
            };
            let common = self.common_block_prefix(
                &self.nodes[child].edge,
                &tokens[pos..],
            );
            debug_assert!(common >= bt);
            self.touch_shared(child, now);
            let n_blocks = common / bt;
            let gs = self.nodes[child].group_size as usize;
            out.groups.extend_flat(
                &self.nodes[child].addrs[..n_blocks * gs],
                gs,
                n_blocks,
            );
            pos += common;
            out.tokens += common;
            if common < self.nodes[child].edge.len() {
                break; // partial edge match ends the walk
            }
            cur = child;
        }
        out
    }

    /// Longest indexed prefix of `tokens` in tokens — **read-only**: no
    /// last-access bump, no LRU traffic, no group copying. Used by the
    /// reference global prompt trees, whose staleness is governed by
    /// insert recency alone (routing a prompt must not extend its TTL).
    pub fn match_len(&self, tokens: &[u32]) -> usize {
        let bt = self.block_tokens;
        let mut cur = ROOT;
        let mut pos = 0;
        loop {
            if pos + bt > tokens.len() {
                break;
            }
            let Some(child) = self.find_child(cur, &tokens[pos..pos + bt])
            else {
                break;
            };
            let common = self.common_block_prefix(
                &self.nodes[child].edge,
                &tokens[pos..],
            );
            debug_assert!(common >= bt);
            pos += common;
            if common < self.nodes[child].edge.len() {
                break;
            }
            cur = child;
        }
        pos
    }

    /// Pin the matched prefix of `tokens` against eviction/swap/expiry.
    /// Returns the pinned length in tokens; pass the same slice to
    /// [`Self::unpin`] when the request retires.
    pub fn pin(&mut self, tokens: &[u32]) -> usize {
        self.drain_touches();
        let (pos, path) = self.matched_path(tokens);
        // The path is a root→leaf chain (path[0] is a child of the
        // root), so one reverse pass gives each node its exact subtree
        // delta — O(path) total, not O(path²) of per-node root walks.
        let mut covered = 0u32; // pinned path nodes at this depth or below
        for &idx in path.iter().rev() {
            self.nodes[idx].pins += 1;
            covered += 1;
            self.nodes[idx].sub_pins += covered;
            self.refresh_lru(idx);
        }
        self.nodes[ROOT].sub_pins += covered;
        pos
    }

    /// Release a pin taken by [`Self::pin`] on the same token sequence.
    pub fn unpin(&mut self, tokens: &[u32]) -> usize {
        self.drain_touches();
        let (pos, path) = self.matched_path(tokens);
        // Mirror of `pin`: reverse pass with a running count of the
        // decrements actually applied at this depth or below.
        let mut covered = 0u32;
        for &idx in path.iter().rev() {
            debug_assert!(self.nodes[idx].pins > 0, "unpin without pin");
            if self.nodes[idx].pins > 0 {
                self.nodes[idx].pins -= 1;
                covered += 1;
            }
            self.nodes[idx].sub_pins -= covered;
            self.refresh_lru(idx);
        }
        self.nodes[ROOT].sub_pins -= covered;
        pos
    }

    /// Walk the matched path, splitting a final partially-matched edge so
    /// pin boundaries always land on node boundaries. Returns matched
    /// tokens plus the fully-matched node indices in root→leaf order.
    fn matched_path(&mut self, tokens: &[u32]) -> (usize, Vec<usize>) {
        let bt = self.block_tokens;
        let mut cur = ROOT;
        let mut pos = 0;
        let mut path = vec![];
        loop {
            if pos + bt > tokens.len() {
                break;
            }
            let Some(child) = self.find_child(cur, &tokens[pos..pos + bt])
            else {
                break;
            };
            let common = self.common_block_prefix(
                &self.nodes[child].edge,
                &tokens[pos..],
            );
            debug_assert!(common >= bt);
            if common < self.nodes[child].edge.len() {
                // Align the node boundary to the matched span so the pin
                // covers exactly the in-use blocks.
                self.split(child, common);
            }
            path.push(child);
            pos += common;
            cur = child;
        }
        (pos, path)
    }

    /// Delete the exact prefix `tokens` and everything below it. Returns
    /// the freed block addresses.
    pub fn delete(&mut self, tokens: &[u32]) -> Vec<BlockAddr> {
        self.drain_touches();
        let bt = self.block_tokens;
        let usable = self.usable_len(tokens.len());
        let tokens = &tokens[..usable];
        // Walk to the node whose path equals `tokens` (may end mid-edge).
        let mut cur = ROOT;
        let mut pos = 0;
        while pos < usable {
            let key = &tokens[pos..pos + bt];
            let Some(child) = self.find_child(cur, key) else {
                return vec![];
            };
            let common = self.common_block_prefix(
                &self.nodes[child].edge,
                &tokens[pos..],
            );
            debug_assert!(common >= bt);
            pos += common;
            if common < self.nodes[child].edge.len() {
                if pos < usable {
                    return vec![]; // diverged: prefix not present
                }
                // Ends mid-edge: drop the tail blocks of this edge +
                // subtree. The edge head (and thus the parent link's
                // fingerprint) is unchanged.
                let mut freed = vec![];
                let keep = common / bt;
                let total = self.nodes[child].blocks(bt);
                let gs = self.nodes[child].group_size as usize;
                let tail_addrs =
                    self.nodes[child].addrs.split_off(keep * gs);
                self.nodes[child].edge.truncate(common);
                self.token_blocks -= total - keep;
                freed.extend(tail_addrs);
                for gc in self.child_indices(child) {
                    let lost = self.nodes[gc].sub_pins;
                    if lost > 0 {
                        self.adjust_sub_pins(child, -(lost as i32));
                    }
                    self.drop_subtree(gc, &mut freed);
                }
                self.nodes[child].children.clear();
                self.refresh_lru(child); // may be a leaf now
                return freed;
            }
            cur = child;
        }
        if cur == ROOT {
            return vec![];
        }
        let mut freed = vec![];
        let parent = self.nodes[cur].parent;
        self.detach_child(parent, cur);
        let lost = self.nodes[cur].sub_pins;
        if lost > 0 {
            self.adjust_sub_pins(parent, -(lost as i32));
        }
        self.drop_subtree(cur, &mut freed);
        self.refresh_lru(parent);
        freed
    }

    /// `prefix` (block-truncated) is no longer cached: drop its *last*
    /// block and every extension, keeping proper prefixes and sibling
    /// branches — the token-level shape local LRU eviction reports
    /// upstream (a `DeltaEvent::Expire`), structure-independent unlike
    /// [`Self::delete`] (whose granularity is the final node's whole
    /// edge). An empty prefix drops the entire tree; a prefix that is
    /// not fully indexed is a no-op. Returns the freed addresses.
    pub fn prune_at(&mut self, prefix: &[u32]) -> Vec<BlockAddr> {
        self.drain_touches();
        let bt = self.block_tokens;
        let usable = self.usable_len(prefix.len());
        let mut freed = vec![];
        if usable == 0 {
            for c in self.child_indices(ROOT) {
                let lost = self.nodes[c].sub_pins;
                if lost > 0 {
                    self.adjust_sub_pins(ROOT, -(lost as i32));
                }
                self.detach_child(ROOT, c);
                self.drop_subtree(c, &mut freed);
            }
            return freed;
        }
        let prefix = &prefix[..usable];
        let mut cur = ROOT;
        let mut pos = 0;
        loop {
            let Some(child) = self.find_child(cur, &prefix[pos..pos + bt])
            else {
                return freed;
            };
            let common = self.common_block_prefix(
                &self.nodes[child].edge,
                &prefix[pos..],
            );
            debug_assert!(common >= bt);
            pos += common;
            if pos == usable {
                // `child` holds the prefix's last block at edge offset
                // `common - bt`: split there so earlier blocks survive,
                // then drop the tail node and its subtree.
                let target = if common > bt {
                    self.split(child, common - bt)
                } else {
                    child
                };
                let parent = self.nodes[target].parent;
                let lost = self.nodes[target].sub_pins;
                if lost > 0 {
                    self.adjust_sub_pins(parent, -(lost as i32));
                }
                self.detach_child(parent, target);
                self.drop_subtree(target, &mut freed);
                self.refresh_lru(parent);
                return freed;
            }
            if common < self.nodes[child].edge.len() {
                return freed; // diverged: prefix not indexed
            }
            cur = child;
        }
    }

    fn drop_subtree(&mut self, node: usize, freed: &mut Vec<BlockAddr>) {
        for c in self.child_indices(node) {
            self.drop_subtree(c, freed);
        }
        self.token_blocks -= self.nodes[node].blocks(self.block_tokens);
        freed.append(&mut self.nodes[node].addrs);
        self.release_node(node);
    }

    /// Evict at least `want_token_blocks` token-blocks, oldest leaves
    /// first (whole-leaf granularity). Victim selection pops the lazy
    /// LRU heap — O(log n) amortized, not an O(nodes) scan per victim.
    /// Returns freed addresses; may free fewer than requested if the
    /// tree runs dry.
    pub fn evict_lru(&mut self, want_token_blocks: usize) -> Vec<BlockAddr> {
        self.evict_lru_inner(want_token_blocks, None)
    }

    /// [`Self::evict_lru`] that also surfaces *what* was evicted: for
    /// each victim leaf, the token prefix whose last block is the leaf
    /// edge's first block. That is exactly the shape of a
    /// `DeltaEvent::Expire` — "this prefix and every extension of it is
    /// gone, proper prefixes and siblings survive" — so the instance
    /// can report honest evictions to the global scheduler instead of
    /// leaving it to TTL guessing (paper §6 Discussion).
    pub fn evict_lru_report(
        &mut self,
        want_token_blocks: usize,
    ) -> (Vec<BlockAddr>, Vec<Vec<u32>>) {
        let mut prefixes = vec![];
        let freed = self.evict_lru_inner(want_token_blocks, Some(&mut prefixes));
        (freed, prefixes)
    }

    fn evict_lru_inner(
        &mut self,
        want_token_blocks: usize,
        mut report: Option<&mut Vec<Vec<u32>>>,
    ) -> Vec<BlockAddr> {
        self.drain_touches();
        let mut freed = vec![];
        let mut freed_blocks = 0;
        while freed_blocks < want_token_blocks {
            let Some(e) = self.lru.pop() else { break };
            if !self.lru_entry_live(&e) {
                continue; // stale lazy-deleted entry
            }
            let leaf = e.node;
            if let Some(out) = report.as_deref_mut() {
                // Path up to and including the leaf edge's FIRST block:
                // releasing that block (+ extensions) upstream mirrors
                // dropping the whole leaf here.
                let mut path = self.path_of(leaf);
                let edge_len = self.nodes[leaf].edge.len();
                path.truncate(path.len() - edge_len + self.block_tokens);
                out.push(path);
            }
            let blocks = self.nodes[leaf].blocks(self.block_tokens);
            freed_blocks += blocks;
            self.token_blocks -= blocks;
            let parent = self.nodes[leaf].parent;
            self.detach_child(parent, leaf);
            freed.append(&mut self.nodes[leaf].addrs);
            self.release_node(leaf);
            self.refresh_lru(parent); // parent may be a leaf now
        }
        freed
    }

    /// Full token path from the root to (and including) `node`'s edge.
    fn path_of(&self, node: usize) -> Vec<u32> {
        let mut chain = vec![];
        let mut cur = node;
        while cur != ROOT {
            chain.push(cur);
            cur = self.nodes[cur].parent;
        }
        let mut out = vec![];
        for &n in chain.iter().rev() {
            out.extend_from_slice(&self.nodes[n].edge);
        }
        out
    }

    /// Addresses of the least-recently-used leaf groups satisfying
    /// `filter`, up to `want_token_blocks` groups — *without* removing
    /// them from the index. Used by `swap_out` to pick HBM victims whose
    /// data moves to DRAM (the index is then remapped, not pruned), and
    /// by drain-time donor scans. Victim selection pops the same lazy
    /// LRU heap eviction uses — O(k log n) for k victims instead of the
    /// former sort-every-leaf scan (stale entries encountered on the way
    /// are discarded for good, a free heap cleanup); live entries are
    /// pushed back afterwards, so the scan stays semantically read-only.
    pub fn lru_addrs<F: Fn(&BlockAddr) -> bool>(
        &mut self,
        want_token_blocks: usize,
        filter: F,
    ) -> Vec<BlockAddr> {
        self.drain_touches();
        let mut out = vec![];
        let mut groups_taken = 0;
        let mut popped = vec![];
        while groups_taken < want_token_blocks {
            let Some(e) = self.lru.pop() else { break };
            if !self.lru_entry_live(&e) {
                continue; // stale lazy-deleted entry
            }
            let n = &self.nodes[e.node];
            let gs = n.group_size as usize;
            if gs > 0 {
                // Walk trailing groups first (deepest data is coldest).
                for b in (0..n.blocks(self.block_tokens)).rev() {
                    if groups_taken >= want_token_blocks {
                        break;
                    }
                    let g = &n.addrs[b * gs..(b + 1) * gs];
                    if g.iter().all(|a| filter(a)) {
                        out.extend_from_slice(g);
                        groups_taken += 1;
                    }
                }
            }
            popped.push(e);
        }
        for e in popped {
            self.lru.push(e);
        }
        out
    }

    /// Drop every node idle longer than the TTL. Returns freed addresses.
    pub fn expire(&mut self, now: f64) -> Vec<BlockAddr> {
        if self.ttl <= 0.0 {
            return vec![];
        }
        self.drain_touches();
        let mut freed = vec![];
        // Repeat until fixpoint: expiring a parent requires dropping its
        // subtree; we conservatively expire stale *subtrees* whose root's
        // entire lineage is stale (children may be fresher than parents
        // since match bumps the whole path). The pinned-subtree check is
        // the O(1) `sub_pins` counter, not a recursive walk.
        loop {
            let mut victim = None;
            for (i, n) in self.nodes.iter().enumerate() {
                if i == ROOT || !n.valid {
                    continue;
                }
                if now - n.access() > self.ttl && n.sub_pins == 0 {
                    victim = Some(i);
                    break;
                }
            }
            let Some(v) = victim else { break };
            let parent = self.nodes[v].parent;
            self.detach_child(parent, v);
            self.drop_subtree(v, &mut freed);
            self.refresh_lru(parent);
        }
        freed
    }

    /// Rewrite addresses after a swap (old -> new), e.g. HBM -> DRAM.
    pub fn remap(&mut self, map: &crate::util::rng::DetMap<BlockAddr, BlockAddr>) {
        self.drain_touches();
        for n in &mut self.nodes {
            if !n.valid {
                continue;
            }
            for a in n.addrs.iter_mut() {
                if let Some(new) = map.get(a) {
                    *a = *new;
                }
            }
        }
    }

    /// All addresses currently referenced (diagnostics / leak checks).
    pub fn all_addrs(&self) -> Vec<BlockAddr> {
        let mut out = vec![];
        for n in self.nodes.iter().filter(|n| n.valid) {
            out.extend_from_slice(&n.addrs);
        }
        out
    }

    /// Live node count (excluding root).
    pub fn node_count(&self) -> usize {
        self.live_nodes
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mempool::block::{InstanceId, Tier};
    use crate::mempool::index_ref::RefRadixIndex;
    use crate::util::proptest::proptest;

    const BT: usize = 4; // block_tokens for tests

    fn addr(i: u32) -> BlockAddr {
        BlockAddr::new(InstanceId(0), Tier::Hbm, i)
    }

    /// groups for n token-blocks starting at base, 1 addr per group
    fn groups(base: u32, n: usize) -> Vec<BlockGroup> {
        (0..n as u32).map(|i| vec![addr(base + i)]).collect()
    }

    fn seq(xs: &[u32]) -> Vec<u32> {
        xs.to_vec()
    }

    #[test]
    fn insert_then_match_exact() {
        let mut idx = RadixIndex::new(BT, 0.0);
        let toks: Vec<u32> = (0..12).collect();
        let dup = idx.insert(&toks, &groups(0, 3), 1.0);
        assert!(dup.is_empty());
        let m = idx.match_prefix(&toks, 2.0);
        assert_eq!(m.tokens, 12);
        assert_eq!(m.groups, groups(0, 3));
        assert_eq!(idx.total_token_blocks(), 3);
    }

    #[test]
    fn match_respects_block_granularity() {
        let mut idx = RadixIndex::new(BT, 0.0);
        let toks: Vec<u32> = (0..8).collect();
        idx.insert(&toks, &groups(0, 2), 1.0);
        // Query shares only 6 tokens -> matched must round down to 4.
        let mut q = toks.clone();
        q[6] = 999;
        let m = idx.match_prefix(&q, 2.0);
        assert_eq!(m.tokens, 4);
        assert_eq!(m.groups, groups(0, 1));
    }

    #[test]
    fn partial_tail_tokens_ignored_on_insert() {
        let mut idx = RadixIndex::new(BT, 0.0);
        let toks: Vec<u32> = (0..10).collect(); // 2 blocks + 2 stray tokens
        idx.insert(&toks, &groups(0, 2), 1.0);
        assert_eq!(idx.total_token_blocks(), 2);
        let m = idx.match_prefix(&toks, 2.0);
        assert_eq!(m.tokens, 8);
    }

    #[test]
    fn shared_prefix_splits_node() {
        let mut idx = RadixIndex::new(BT, 0.0);
        let a: Vec<u32> = vec![1, 2, 3, 4, 5, 6, 7, 8];
        let b: Vec<u32> = vec![1, 2, 3, 4, 9, 9, 9, 9];
        idx.insert(&a, &groups(0, 2), 1.0);
        let dup = idx.insert(&b, &groups(10, 2), 2.0);
        // First block of b duplicates a's first block.
        assert_eq!(dup, vec![vec![addr(10)]]);
        assert_eq!(idx.total_token_blocks(), 3);
        let ma = idx.match_prefix(&a, 3.0);
        assert_eq!(ma.groups, groups(0, 2));
        let mb = idx.match_prefix(&b, 3.0);
        assert_eq!(mb.groups, vec![vec![addr(0)], vec![addr(11)]]);
    }

    #[test]
    fn duplicate_insert_reports_all_groups() {
        let mut idx = RadixIndex::new(BT, 0.0);
        let toks: Vec<u32> = (0..8).collect();
        idx.insert(&toks, &groups(0, 2), 1.0);
        let dup = idx.insert(&toks, &groups(50, 2), 2.0);
        assert_eq!(dup, groups(50, 2));
        assert_eq!(idx.total_token_blocks(), 2);
    }

    #[test]
    fn extension_insert_reuses_prefix() {
        let mut idx = RadixIndex::new(BT, 0.0);
        idx.insert(&seq(&[1, 2, 3, 4]), &groups(0, 1), 1.0);
        // Extend with 2 blocks; first duplicates.
        let dup = idx.insert(&seq(&[1, 2, 3, 4, 5, 6, 7, 8]), &groups(10, 2), 2.0);
        assert_eq!(dup, vec![vec![addr(10)]]);
        let m = idx.match_prefix(&seq(&[1, 2, 3, 4, 5, 6, 7, 8]), 3.0);
        assert_eq!(m.groups, vec![vec![addr(0)], vec![addr(11)]]);
    }

    #[test]
    fn delete_exact_and_subtree() {
        let mut idx = RadixIndex::new(BT, 0.0);
        let a: Vec<u32> = vec![1, 2, 3, 4, 5, 6, 7, 8];
        let b: Vec<u32> = vec![1, 2, 3, 4, 9, 9, 9, 9];
        idx.insert(&a, &groups(0, 2), 1.0);
        idx.insert(&b, &groups(10, 2), 1.0);
        // Delete prefix [1,2,3,4]: everything below goes too.
        let freed = idx.delete(&seq(&[1, 2, 3, 4]));
        let mut f = freed.clone();
        f.sort();
        assert_eq!(f, vec![addr(0), addr(1), addr(11)]);
        assert!(idx.is_empty());
        assert_eq!(idx.match_prefix(&a, 2.0).tokens, 0);
    }

    #[test]
    fn delete_missing_is_noop() {
        let mut idx = RadixIndex::new(BT, 0.0);
        idx.insert(&seq(&[1, 2, 3, 4]), &groups(0, 1), 1.0);
        assert!(idx.delete(&seq(&[9, 9, 9, 9])).is_empty());
        assert_eq!(idx.total_token_blocks(), 1);
    }

    #[test]
    fn evict_lru_takes_oldest_leaf() {
        let mut idx = RadixIndex::new(BT, 0.0);
        idx.insert(&seq(&[1, 1, 1, 1]), &groups(0, 1), 1.0);
        idx.insert(&seq(&[2, 2, 2, 2]), &groups(1, 1), 2.0);
        idx.insert(&seq(&[3, 3, 3, 3]), &groups(2, 1), 3.0);
        // Touch the oldest so the second-oldest becomes the victim.
        idx.match_prefix(&seq(&[1, 1, 1, 1]), 4.0);
        let freed = idx.evict_lru(1);
        assert_eq!(freed, vec![addr(1)]);
        assert_eq!(idx.total_token_blocks(), 2);
    }

    #[test]
    fn evict_leaf_before_parent() {
        let mut idx = RadixIndex::new(BT, 0.0);
        let long: Vec<u32> = (0..8).collect();
        idx.insert(&long, &groups(0, 2), 1.0);
        let short: Vec<u32> = (0..4).collect();
        // Split so parent=block0, leaf=block1.
        idx.insert(&seq(&[0, 1, 2, 3, 9, 9, 9, 9]), &groups(10, 2), 2.0);
        let freed = idx.evict_lru(1);
        // Oldest leaf is the tail of `long` (last_access 1.0), not the
        // shared parent block.
        assert_eq!(freed, vec![addr(1)]);
        assert_eq!(idx.match_prefix(&short, 3.0).tokens, 4);
    }

    #[test]
    fn evict_lru_report_surfaces_expire_shaped_prefixes() {
        let mut idx = RadixIndex::new(BT, 0.0);
        let abc: Vec<u32> = vec![1, 1, 1, 1, 2, 2, 2, 2, 3, 3, 3, 3];
        let ad: Vec<u32> = vec![1, 1, 1, 1, 9, 9, 9, 9];
        idx.insert(&abc, &groups(0, 3), 1.0);
        idx.insert(&ad, &groups(10, 2), 2.0);
        // Victim: the B-C tail leaf (oldest). Its report is the path up
        // to B's block — exactly what `prune_at`/`release_prefix` would
        // take to mirror the eviction upstream.
        let (freed, prefixes) = idx.evict_lru_report(1);
        assert_eq!(freed.len(), 2, "B and C blocks freed");
        assert_eq!(prefixes, vec![abc[..8].to_vec()]);
        assert_eq!(idx.match_prefix(&abc, 3.0).tokens, 4);
        assert_eq!(idx.match_prefix(&ad, 3.0).tokens, 8);
        // Evicting the rest reports each leaf once; replaying the
        // reports through prune_at on a twin empties it identically.
        let mut twin = RadixIndex::new(BT, 0.0);
        twin.insert(&abc, &groups(0, 3), 1.0);
        twin.insert(&ad, &groups(10, 2), 2.0);
        twin.prune_at(&abc[..8]);
        let (_, rest) = idx.evict_lru_report(8);
        for p in &rest {
            twin.prune_at(p);
        }
        assert_eq!(idx.total_token_blocks(), 0);
        assert_eq!(twin.total_token_blocks(), 0);
    }

    #[test]
    fn ttl_expiry() {
        let mut idx = RadixIndex::new(BT, 10.0);
        idx.insert(&seq(&[1, 1, 1, 1]), &groups(0, 1), 0.0);
        idx.insert(&seq(&[2, 2, 2, 2]), &groups(1, 1), 5.0);
        let freed = idx.expire(12.0);
        assert_eq!(freed, vec![addr(0)]);
        assert_eq!(idx.total_token_blocks(), 1);
        assert_eq!(idx.match_prefix(&seq(&[2, 2, 2, 2]), 12.0).tokens, 4);
    }

    #[test]
    fn prune_at_drops_last_block_extensions_keeps_siblings() {
        let mut idx = RadixIndex::new(BT, 0.0);
        let abc: Vec<u32> = vec![1, 1, 1, 1, 2, 2, 2, 2, 3, 3, 3, 3];
        let ad: Vec<u32> = vec![1, 1, 1, 1, 9, 9, 9, 9];
        idx.insert(&abc, &groups(0, 3), 1.0);
        idx.insert(&ad, &groups(10, 2), 1.0);
        // Prune at A-B: loses B's block and the C extension; keeps A
        // (shared) and the A-D sibling branch.
        let mut freed = idx.prune_at(&abc[..8]);
        freed.sort();
        assert_eq!(freed, vec![addr(1), addr(2)]);
        assert_eq!(idx.match_prefix(&abc, 2.0).tokens, 4);
        assert_eq!(idx.match_prefix(&ad, 2.0).tokens, 8);
        assert_eq!(idx.total_token_blocks(), 3);
    }

    #[test]
    fn prune_at_splits_inside_long_edge() {
        let mut idx = RadixIndex::new(BT, 0.0);
        let long: Vec<u32> = (0..16).collect(); // one 4-block leaf
        idx.insert(&long, &groups(0, 4), 1.0);
        let freed = idx.prune_at(&long[..8]);
        // Blocks 1..4 go; block 0 survives inside the split head.
        assert_eq!(freed.len(), 3);
        assert_eq!(idx.match_prefix(&long, 2.0).tokens, 4);
        assert_eq!(idx.total_token_blocks(), 1);
        // Not-fully-indexed prefix: no-op.
        assert!(idx.prune_at(&long[..8]).is_empty());
        assert_eq!(idx.total_token_blocks(), 1);
    }

    #[test]
    fn prune_at_empty_prefix_clears_tree() {
        let mut idx = RadixIndex::new(BT, 0.0);
        idx.insert(&seq(&[1, 1, 1, 1]), &groups(0, 1), 1.0);
        idx.insert(&seq(&[2, 2, 2, 2, 3, 3, 3, 3]), &groups(1, 2), 2.0);
        let freed = idx.prune_at(&[]);
        assert_eq!(freed.len(), 3);
        assert!(idx.is_empty());
        assert_eq!(idx.node_count(), 0);
    }

    #[test]
    fn lru_addrs_follows_eviction_order_and_is_readonly() {
        let mut idx = RadixIndex::new(BT, 0.0);
        idx.insert(&seq(&[1, 1, 1, 1]), &groups(0, 1), 1.0);
        idx.insert(&seq(&[2, 2, 2, 2]), &groups(1, 1), 2.0);
        idx.insert(&seq(&[3, 3, 3, 3]), &groups(2, 1), 3.0);
        assert_eq!(idx.lru_addrs(2, |_| true), vec![addr(0), addr(1)]);
        // Read-only: repeated calls (and later eviction) see the same
        // heap state.
        assert_eq!(idx.lru_addrs(2, |_| true), vec![addr(0), addr(1)]);
        assert_eq!(idx.evict_lru(1), vec![addr(0)]);
        assert_eq!(idx.lru_addrs(2, |_| true), vec![addr(1), addr(2)]);
    }

    #[test]
    fn remap_rewrites_addrs() {
        let mut idx = RadixIndex::new(BT, 0.0);
        idx.insert(&seq(&[1, 2, 3, 4]), &groups(0, 1), 1.0);
        let mut map = crate::util::rng::DetMap::default();
        map.insert(addr(0), BlockAddr::new(InstanceId(0), Tier::Dram, 7));
        idx.remap(&map);
        let m = idx.match_prefix(&seq(&[1, 2, 3, 4]), 2.0);
        assert_eq!(m.groups[0][0].tier, Tier::Dram);
        assert_eq!(m.groups[0][0].index, 7);
    }

    #[test]
    fn pinned_leaf_not_evicted() {
        let mut idx = RadixIndex::new(BT, 0.0);
        idx.insert(&seq(&[1, 1, 1, 1]), &groups(0, 1), 1.0);
        idx.insert(&seq(&[2, 2, 2, 2]), &groups(1, 1), 2.0);
        assert_eq!(idx.pin(&seq(&[1, 1, 1, 1])), 4);
        // Oldest leaf is pinned -> second-oldest goes first.
        assert_eq!(idx.evict_lru(1), vec![addr(1)]);
        // Nothing else evictable while pinned.
        assert!(idx.evict_lru(1).is_empty());
        idx.unpin(&seq(&[1, 1, 1, 1]));
        assert_eq!(idx.evict_lru(1), vec![addr(0)]);
    }

    #[test]
    fn pin_survives_split_and_unpins_cleanly() {
        let mut idx = RadixIndex::new(BT, 0.0);
        let long: Vec<u32> = (0..8).collect();
        idx.insert(&long, &groups(0, 2), 1.0);
        idx.pin(&long);
        // A diverging insert splits the pinned node.
        idx.insert(&seq(&[0, 1, 2, 3, 9, 9, 9, 9]), &groups(10, 2), 2.0);
        // Both halves of `long` remain protected.
        let freed = idx.evict_lru(10);
        assert_eq!(freed, vec![addr(11)]); // only the diverging leaf
        idx.unpin(&long);
        let freed2 = idx.evict_lru(10);
        assert_eq!(freed2.len(), 2, "{freed2:?}");
    }

    #[test]
    fn pin_partial_edge_splits_for_exact_coverage() {
        let mut idx = RadixIndex::new(BT, 0.0);
        let long: Vec<u32> = (0..12).collect();
        idx.insert(&long, &groups(0, 3), 1.0);
        // Pin only the first 2 blocks.
        assert_eq!(idx.pin(&long[..8]), 8);
        // The unpinned tail block is evictable; the pinned head is not.
        let freed = idx.evict_lru(5);
        assert_eq!(freed, vec![addr(2)]);
        idx.unpin(&long[..8]);
        assert_eq!(idx.evict_lru(5).len(), 2);
    }

    #[test]
    fn pinned_nodes_skip_ttl_and_swap_selection() {
        let mut idx = RadixIndex::new(BT, 10.0);
        idx.insert(&seq(&[1, 1, 1, 1]), &groups(0, 1), 0.0);
        idx.pin(&seq(&[1, 1, 1, 1]));
        assert!(idx.expire(100.0).is_empty());
        assert!(idx.lru_addrs(5, |_| true).is_empty());
        idx.unpin(&seq(&[1, 1, 1, 1]));
        assert_eq!(idx.expire(100.0), vec![addr(0)]);
    }

    #[test]
    fn identity_insert_reports_no_dup() {
        let mut idx = RadixIndex::new(BT, 0.0);
        let toks: Vec<u32> = (0..8).collect();
        idx.insert(&toks, &groups(0, 2), 1.0);
        // Re-insert the exact same groups (the engine retire path after a
        // full cache hit): nothing is duplicate, nothing to free.
        let dup = idx.insert(&toks, &groups(0, 2), 2.0);
        assert!(dup.is_empty());
        // Mixed: first group aliases, second is a fresh copy.
        let mixed = vec![vec![addr(0)], vec![addr(50)]];
        let dup2 = idx.insert(&toks, &mixed, 3.0);
        assert_eq!(dup2, vec![vec![addr(50)]]);
        assert_eq!(idx.total_token_blocks(), 2);
    }

    #[test]
    fn node_reuse_after_delete() {
        let mut idx = RadixIndex::new(BT, 0.0);
        for round in 0..10 {
            let t: Vec<u32> = (0..4).map(|i| i + round).collect();
            idx.insert(&t, &groups(round, 1), round as f64);
            idx.delete(&t);
        }
        assert!(idx.nodes.len() < 6, "nodes leaked: {}", idx.nodes.len());
    }

    #[test]
    fn grouplist_indexing_and_iteration() {
        let mut gl = GroupList::default();
        assert!(gl.is_empty());
        gl.push_group(&[addr(1), addr(2)]);
        gl.push_group(&[addr(3), addr(4)]);
        assert_eq!(gl.len(), 2);
        assert_eq!(gl.group_size(), 2);
        assert_eq!(&gl[1], &[addr(3), addr(4)][..]);
        assert_eq!(gl.flat(), &[addr(1), addr(2), addr(3), addr(4)][..]);
        let collected: Vec<&[BlockAddr]> = gl.iter().collect();
        assert_eq!(collected.len(), 2);
        gl.truncate(1);
        assert_eq!(gl.len(), 1);
        assert_eq!(gl.flat(), &[addr(1), addr(2)][..]);
        assert_eq!(gl.to_groups(), vec![vec![addr(1), addr(2)]]);
    }

    #[test]
    fn grouplist_extend_range_and_list() {
        let mut a = GroupList::default();
        for i in 0..4 {
            a.push_group(&[addr(i), addr(10 + i)]);
        }
        let mut b = GroupList::default();
        b.extend_range(&a, 1, 3);
        assert_eq!(b.len(), 2);
        assert_eq!(&b[0], a.group(1));
        assert_eq!(&b[1], a.group(2));
        let mut c = GroupList::default();
        c.extend_list(&b);
        c.extend_range(&a, 0, 0); // empty range is a no-op
        assert_eq!(c.len(), 2);
        assert_eq!(c.flat(), b.flat());
    }

    #[test]
    fn match_len_is_read_only_and_agrees_with_match_prefix() {
        let mut idx = RadixIndex::new(BT, 10.0);
        let toks: Vec<u32> = (0..12).collect();
        idx.insert(&toks, &groups(0, 3), 0.0);
        assert_eq!(idx.match_len(&toks), 12);
        assert_eq!(idx.match_len(&toks[..7]), 4);
        assert_eq!(idx.match_len(&[9, 9, 9, 9]), 0);
        // Read-only: repeated match_len never refreshes the TTL clock.
        for _ in 0..3 {
            assert_eq!(idx.match_len(&toks), 12);
        }
        idx.expire(11.0);
        assert_eq!(idx.match_len(&toks), 0);
    }

    #[test]
    fn insert_list_matches_vec_insert() {
        let mut a = RadixIndex::new(BT, 0.0);
        let mut b = RadixIndex::new(BT, 0.0);
        let toks: Vec<u32> = (0..8).collect();
        let gs = groups(0, 2);
        let mut gl = GroupList::default();
        for g in &gs {
            gl.push_group(g);
        }
        assert!(a.insert(&toks, &gs, 1.0).is_empty());
        assert!(b.insert_list(&toks, &gl, 1.0).is_empty());
        // A duplicate re-insert reports the same dups through both APIs.
        let dup_vec = a.insert(&toks, &groups(50, 2), 2.0);
        let mut gl2 = GroupList::default();
        for g in &groups(50, 2) {
            gl2.push_group(g);
        }
        let dup_list = b.insert_list(&toks, &gl2, 2.0);
        assert_eq!(dup_list, dup_vec);
        assert_eq!(
            a.match_prefix(&toks, 3.0).groups,
            b.match_prefix(&toks, 3.0).groups
        );
        assert_eq!(a.total_token_blocks(), b.total_token_blocks());
    }

    #[test]
    fn grouplist_empty_groups_have_zero_size() {
        let mut idx = RadixIndex::new(BT, 0.0);
        idx.insert_unaddressed(&seq(&[1, 2, 3, 4, 5, 6, 7, 8]), 1.0);
        let m = idx.match_prefix(&seq(&[1, 2, 3, 4, 5, 6, 7, 8]), 2.0);
        assert_eq!(m.tokens, 8);
        assert_eq!(m.groups.len(), 2);
        assert_eq!(m.groups.group_size(), 0);
        assert!(m.groups[0].is_empty());
        assert_eq!(idx.total_token_blocks(), 2);
    }

    /// Forced fingerprint collisions: with a 0-bit mask every child of a
    /// node lives on one collision chain; all operations must still give
    /// token-exact answers.
    #[test]
    fn colliding_fingerprints_still_resolve_by_tokens() {
        let mut idx = RadixIndex::new(BT, 0.0);
        idx.set_fingerprint_mask(0);
        let a = seq(&[1, 1, 1, 1]);
        let b = seq(&[2, 2, 2, 2]);
        let c = seq(&[3, 3, 3, 3]);
        idx.insert(&a, &groups(0, 1), 1.0);
        idx.insert(&b, &groups(1, 1), 2.0);
        idx.insert(&c, &groups(2, 1), 3.0);
        assert_eq!(idx.node_count(), 3);
        assert_eq!(idx.match_prefix(&a, 4.0).groups, groups(0, 1));
        assert_eq!(idx.match_prefix(&b, 4.0).groups, groups(1, 1));
        assert_eq!(idx.match_prefix(&c, 4.0).groups, groups(2, 1));
        assert_eq!(idx.match_prefix(&seq(&[4, 4, 4, 4]), 4.0).tokens, 0);
        // Delete the chain head, the middle, then the tail.
        assert_eq!(idx.delete(&c), vec![addr(2)]);
        assert_eq!(idx.delete(&a), vec![addr(0)]);
        assert_eq!(idx.match_prefix(&b, 5.0).groups, groups(1, 1));
        assert_eq!(idx.delete(&b), vec![addr(1)]);
        assert!(idx.is_empty());
    }

    /// Executable-spec model: a map from every block-aligned prefix to
    /// its first-insertion group. With children keyed by whole blocks,
    /// the tree accepts every new block whose parent prefix exists —
    /// exactly a prefix map.
    #[derive(Default)]
    struct Model {
        /// accepted prefix (ending on a block boundary) -> its group
        addrs: HashMap<Vec<u32>, BlockGroup>,
    }

    impl Model {
        fn insert(&mut self, toks: &[u32], gs: &[BlockGroup]) {
            let mut p: Vec<u32> = vec![];
            for (i, grp) in gs.iter().enumerate() {
                p.extend(&toks[i * BT..(i + 1) * BT]);
                self.addrs.entry(p.clone()).or_insert_with(|| grp.clone());
            }
        }

        fn match_prefix(&self, toks: &[u32]) -> (usize, Vec<BlockGroup>) {
            let mut p: Vec<u32> = vec![];
            let mut out = vec![];
            for i in 0..toks.len() / BT {
                let b = &toks[i * BT..(i + 1) * BT];
                let mut q = p.clone();
                q.extend(b);
                match self.addrs.get(&q) {
                    Some(grp) => {
                        out.push(grp.clone());
                        p = q;
                    }
                    None => break,
                }
            }
            (p.len(), out)
        }
    }

    #[test]
    fn prop_matches_naive_model() {
        proptest(60, |g| {
            let mut idx = RadixIndex::new(BT, 0.0);
            let mut model = Model::default();
            let mut next_addr = 0u32;
            let mut now = 0.0;
            for _ in 0..g.usize(1, 25) {
                now += 1.0;
                // Small alphabet to force shared prefixes and splits.
                let len = g.usize(0, 6) * BT + g.usize(0, BT - 1);
                let toks = g.vec_u32(len, 0, 3);
                if g.bool() {
                    let nb = idx.usable_len(toks.len()) / BT;
                    let gs: Vec<BlockGroup> = (0..nb)
                        .map(|i| vec![addr(next_addr + i as u32)])
                        .collect();
                    next_addr += nb as u32;
                    idx.insert(&toks, &gs, now);
                    model.insert(&toks, &gs);
                } else {
                    let m = idx.match_prefix(&toks, now);
                    let (expect, expect_groups) = model.match_prefix(&toks);
                    assert_eq!(m.tokens, expect, "toks={toks:?}");
                    assert_eq!(m.groups, expect_groups);
                }
                assert_eq!(idx.total_token_blocks(), model.addrs.len());
            }
        });
    }

    /// Eviction + insert interleaving never corrupts counters or leaks.
    #[test]
    fn prop_evict_consistency() {
        proptest(40, |g| {
            let mut idx = RadixIndex::new(BT, 0.0);
            let mut next_addr = 0u32;
            let mut live: std::collections::HashSet<BlockAddr> =
                Default::default();
            let mut now = 0.0;
            for _ in 0..g.usize(1, 40) {
                now += 1.0;
                if g.bool() {
                    let len = g.usize(1, 5) * BT;
                    let toks = g.vec_u32(len, 0, 4);
                    let nb = len / BT;
                    let gs: Vec<BlockGroup> = (0..nb)
                        .map(|i| vec![addr(next_addr + i as u32)])
                        .collect();
                    next_addr += nb as u32;
                    for grp in &gs {
                        live.insert(grp[0]);
                    }
                    for grp in idx.insert(&toks, &gs, now) {
                        for a in grp {
                            live.remove(&a);
                        }
                    }
                } else {
                    for a in idx.evict_lru(g.usize(1, 3)) {
                        assert!(live.remove(&a), "double-evict {a}");
                    }
                }
                let mut in_tree = idx.all_addrs();
                in_tree.sort();
                let mut expect: Vec<BlockAddr> =
                    live.iter().copied().collect();
                expect.sort();
                assert_eq!(in_tree, expect, "tree/model addr divergence");
                assert_eq!(idx.total_token_blocks(), in_tree.len());
            }
        });
    }

    /// Differential property: random insert/match/pin/unpin/delete/evict
    /// sequences produce identical observable results on the
    /// fingerprint-keyed index and the seed token-keyed reference
    /// implementation — under the normal fingerprint and under a
    /// 4-bit mask that forces heavy collision chaining.
    #[test]
    fn prop_differential_vs_reference_index() {
        for mask in [u64::MAX, 0xF] {
            proptest(30, move |g| {
                let mut new = RadixIndex::new(BT, 0.0);
                new.set_fingerprint_mask(mask);
                let mut old = RefRadixIndex::new(BT, 0.0);
                let mut next_addr = 0u32;
                let mut now = 0.0;
                let mut pinned: Vec<Vec<u32>> = vec![];
                for _ in 0..g.usize(1, 30) {
                    now += 1.0;
                    // Small alphabet: shared prefixes, splits, collisions.
                    let len = g.usize(0, 5) * BT + g.usize(0, BT - 1);
                    let toks = g.vec_u32(len, 0, 3);
                    match g.usize(0, 6) {
                        0 | 1 => {
                            let nb = new.usable_len(toks.len()) / BT;
                            let gs: Vec<BlockGroup> = (0..nb)
                                .map(|i| vec![addr(next_addr + i as u32)])
                                .collect();
                            next_addr += nb as u32;
                            let d1 = new.insert(&toks, &gs, now);
                            let d2 = old.insert(&toks, &gs, now);
                            assert_eq!(d1, d2, "insert dups diverged");
                        }
                        2 => {
                            let m1 = new.match_prefix(&toks, now);
                            let m2 = old.match_prefix(&toks, now);
                            assert_eq!(m1.tokens, m2.tokens);
                            assert_eq!(m1.groups, m2.groups);
                        }
                        3 => {
                            let pos = new.pin(&toks);
                            assert_eq!(pos, old.pin(&toks));
                            // Keep the pinned slice only: unpin must be
                            // called with exactly what pin covered (the
                            // API contract), or it would touch nodes
                            // inserted after the pin.
                            pinned.push(toks[..pos].to_vec());
                        }
                        4 => {
                            if let Some(t) = pinned.pop() {
                                assert_eq!(new.unpin(&t), old.unpin(&t));
                            } else {
                                // Subtree drop order follows child-map
                                // iteration order, which legitimately
                                // differs between the two maps — the
                                // freed *set* must match.
                                let mut f1 = new.delete(&toks);
                                let mut f2 = old.delete(&toks);
                                f1.sort();
                                f2.sort();
                                assert_eq!(f1, f2, "delete freed diverged");
                            }
                        }
                        5 => {
                            let want = g.usize(1, 3);
                            let f1 = new.evict_lru(want);
                            let f2 = old.evict_lru(want);
                            assert_eq!(f1, f2, "evict freed diverged");
                        }
                        _ => {
                            // Heap-driven victim picking must reproduce
                            // the seed's sort-once scan exactly, and
                            // leave the heap usable afterwards.
                            let want = g.usize(1, 4);
                            let v1 = new.lru_addrs(want, |_| true);
                            let v2 = old.lru_addrs(want, |_| true);
                            assert_eq!(v1, v2, "lru_addrs diverged");
                        }
                    }
                    assert_eq!(
                        new.total_token_blocks(),
                        old.total_token_blocks()
                    );
                    let mut a1 = new.all_addrs();
                    a1.sort();
                    let mut a2 = old.all_addrs();
                    a2.sort();
                    assert_eq!(a1, a2, "indexed addr sets diverged");
                }
            });
        }
    }

    /// Differential property for the deferred-touch queue: stacking
    /// many `&self` matches between structural operations (so the
    /// queue actually accumulates depth before each drain) must leave
    /// LRU victim selection identical to the seed reference, which
    /// applies every touch eagerly. With the default queue capacity no
    /// touch is ever dropped, so serializing the queue at the next
    /// `&mut` call reconstructs the eager ordering exactly.
    #[test]
    fn prop_deferred_touch_lru_equivalence() {
        proptest(40, |g| {
            let mut new = RadixIndex::new(BT, 0.0);
            let mut old = RefRadixIndex::new(BT, 0.0);
            let mut next_addr = 0u32;
            let mut now = 0.0;
            for _ in 0..g.usize(1, 25) {
                now += 1.0;
                match g.usize(0, 3) {
                    0 => {
                        let len = g.usize(1, 5) * BT;
                        let toks = g.vec_u32(len, 0, 3);
                        let nb = len / BT;
                        let gs: Vec<BlockGroup> = (0..nb)
                            .map(|i| vec![addr(next_addr + i as u32)])
                            .collect();
                        next_addr += nb as u32;
                        assert_eq!(
                            new.insert(&toks, &gs, now),
                            old.insert(&toks, &gs, now)
                        );
                    }
                    1 | 2 => {
                        // A burst of matches with NO intervening &mut
                        // call: all land in the queue, drained only by
                        // the next structural op.
                        for _ in 0..g.usize(1, 6) {
                            now += 1.0;
                            let len = g.usize(0, 5) * BT;
                            let toks = g.vec_u32(len, 0, 3);
                            let m1 = new.match_prefix(&toks, now);
                            let m2 = old.match_prefix(&toks, now);
                            assert_eq!(m1.tokens, m2.tokens);
                            assert_eq!(m1.groups, m2.groups);
                        }
                    }
                    _ => {
                        let want = g.usize(1, 3);
                        assert_eq!(
                            new.evict_lru(want),
                            old.evict_lru(want),
                            "LRU victims diverged after deferred touches"
                        );
                    }
                }
            }
            new.evict_lru(0); // final drain (pops nothing)
            let ts = new.touch_stats();
            assert_eq!(ts.dropped, 0, "default capacity must not drop");
            assert_eq!(ts.deferred, ts.drained, "drain must be complete");
        });
    }

    /// At capacity the queue drops touches whole: the counters say so,
    /// and the dropped leaf keeps its OLD access time — so it stays
    /// evictable under its original heap entry instead of leaking as a
    /// node whose heap entry no longer matches its access time.
    #[test]
    fn deferred_touch_drop_at_capacity() {
        let mut idx = RadixIndex::with_touch_capacity(BT, 0.0, 2);
        let a = seq(&[1, 1, 1, 1]);
        let b = seq(&[2, 2, 2, 2]);
        let c = seq(&[3, 3, 3, 3]);
        idx.insert(&a, &groups(0, 1), 1.0);
        idx.insert(&b, &groups(1, 1), 2.0);
        idx.insert(&c, &groups(2, 1), 3.0);
        // Three leaf touches into a 2-slot queue: the third drops.
        assert_eq!(idx.match_prefix(&c, 10.0).tokens, 4);
        assert_eq!(idx.match_prefix(&b, 11.0).tokens, 4);
        assert_eq!(idx.match_prefix(&a, 12.0).tokens, 4);
        let ts = idx.touch_stats();
        assert_eq!(
            ts,
            TouchStats { deferred: 2, drained: 0, dropped: 1 }
        );
        // `a`'s touch was dropped, so its access time is still 1.0 and
        // its original heap entry is live: it must be the LRU victim,
        // not un-evictable.
        assert_eq!(idx.evict_lru(1), groups(0, 1)[0]);
        let ts = idx.touch_stats();
        assert_eq!(ts.drained, 2);
        // The refreshed leaves survive with their new recency: next
        // victim is `c` (10.0), then `b` (11.0).
        assert_eq!(idx.evict_lru(1), groups(2, 1)[0]);
        assert_eq!(idx.evict_lru(1), groups(1, 1)[0]);
    }

    /// Concurrent `&self` matches: shared-reference readers on multiple
    /// threads return correct matches, and the touch counters stay
    /// consistent (every leaf touch either deferred or dropped).
    #[test]
    fn concurrent_shared_matches() {
        let mut idx = RadixIndex::new(BT, 0.0);
        let seqs: Vec<Vec<u32>> = (0..8u32)
            .map(|i| vec![i; 2 * BT])
            .collect();
        for (i, s) in seqs.iter().enumerate() {
            idx.insert(s, &groups(2 * i as u32, 2), 1.0);
        }
        let idx = &idx;
        std::thread::scope(|scope| {
            for t in 0..4usize {
                let seqs = &seqs;
                scope.spawn(move || {
                    for round in 0..50 {
                        let s = &seqs[(t * 13 + round) % seqs.len()];
                        let m = idx.match_prefix(s, 2.0 + round as f64);
                        assert_eq!(m.tokens, 2 * BT);
                    }
                });
            }
        });
        let ts = idx.touch_stats();
        // 4 threads * 50 matches, one leaf touch each.
        assert_eq!(ts.deferred + ts.dropped, 200);
        assert_eq!(ts.drained, 0, "no &mut op ran during the scope");
    }
}

/// Loom models for the deferred-touch protocol (run via
/// `RUSTFLAGS="--cfg loom" cargo test --release --lib loom_`; the
/// shim in `util::sync` swaps the queue's atomics for loom's). Small
/// on purpose: two producers already cover every claim/claim and
/// claim/store race the protocol has.
#[cfg(all(test, loom))]
mod loom_tests {
    use super::DeferredTouches;
    use loom::sync::Arc;
    use loom::thread;

    /// The R4 justification in `defer` claims Relaxed is enough
    /// because the drain's `&mut` borrow is the publication edge.
    /// Model exactly that: two producers defer concurrently, then the
    /// drain (exclusive access recovered after join) must observe
    /// both stamps exactly once under every interleaving.
    #[test]
    fn loom_deferred_touches_lose_no_stamp() {
        loom::model(|| {
            let mut q = Arc::new(DeferredTouches::new(2));
            let mut joins = Vec::with_capacity(2);
            for node in 0..2usize {
                let q = Arc::clone(&q);
                joins.push(thread::spawn(move || q.defer(10 + node)));
            }
            for j in joins {
                assert!(j.join().expect("producer"), "queue had room");
            }
            let qm = Arc::get_mut(&mut q).expect("producers joined");
            let mut got = qm.drain();
            got.sort_unstable();
            assert_eq!(got, vec![10, 11], "a claimed stamp was lost");
            let st = qm.stats();
            assert_eq!((st.deferred, st.drained, st.dropped), (2, 2, 0));
        });
    }

    /// At capacity exactly one claim wins the slot; the loser is
    /// dropped *and accounted*, and the winner's stamp still drains.
    #[test]
    fn loom_deferred_touches_account_drops_at_capacity() {
        loom::model(|| {
            let mut q = Arc::new(DeferredTouches::new(1));
            let t = {
                let q = Arc::clone(&q);
                thread::spawn(move || q.defer(7))
            };
            let mine = q.defer(8);
            let theirs = t.join().expect("producer");
            assert!(mine != theirs, "exactly one claim fits");
            let qm = Arc::get_mut(&mut q).expect("producer joined");
            let got = qm.drain();
            assert!(got == [7] || got == [8]);
            let st = qm.stats();
            assert_eq!((st.deferred, st.drained, st.dropped), (1, 1, 1));
        });
    }
}
