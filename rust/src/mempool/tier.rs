//! Memory tiers: HBM-sim and DRAM-sim arenas with real backing storage.
//!
//! Each tier couples a [`BlockAllocator`] with an optional data arena.
//! The live serving path materializes KV bytes (the engine reads/writes
//! real f32 data); the discrete-event simulator runs the same allocator
//! and index logic with `materialize = false` so sweeps stay fast while
//! exercising identical bookkeeping.

use super::allocator::{AllocError, BlockAllocator};

#[derive(Debug)]
pub struct Arena {
    alloc: BlockAllocator,
    floats_per_block: usize,
    /// Backing store; empty when not materialized.
    data: Vec<f32>,
    materialize: bool,
}

impl Arena {
    pub fn new(capacity_blocks: usize, floats_per_block: usize,
               materialize: bool) -> Self {
        let data = if materialize {
            vec![0.0; capacity_blocks * floats_per_block]
        } else {
            vec![]
        };
        Arena {
            alloc: BlockAllocator::new(capacity_blocks),
            floats_per_block,
            data,
            materialize,
        }
    }

    pub fn allocator(&self) -> &BlockAllocator {
        &self.alloc
    }

    pub fn alloc(&mut self, n: usize) -> Result<Vec<u32>, AllocError> {
        self.alloc.alloc(n)
    }

    pub fn free(&mut self, blocks: &[u32]) -> Result<(), AllocError> {
        self.alloc.free(blocks)
    }

    pub fn floats_per_block(&self) -> usize {
        self.floats_per_block
    }

    pub fn is_materialized(&self) -> bool {
        self.materialize
    }

    /// Immutable view of one block's floats (materialized arenas only).
    pub fn block(&self, index: u32) -> &[f32] {
        assert!(self.materialize, "arena not materialized");
        let s = index as usize * self.floats_per_block;
        &self.data[s..s + self.floats_per_block]
    }

    /// Mutable view of one block's floats.
    pub fn block_mut(&mut self, index: u32) -> &mut [f32] {
        assert!(self.materialize, "arena not materialized");
        let s = index as usize * self.floats_per_block;
        &mut self.data[s..s + self.floats_per_block]
    }

    /// Copy data into a block (no-op when not materialized — the sim path).
    pub fn write_block(&mut self, index: u32, data: &[f32]) {
        if !self.materialize {
            return;
        }
        assert_eq!(data.len(), self.floats_per_block);
        self.block_mut(index).copy_from_slice(data);
    }

    /// Copy a block out (zeros when not materialized).
    pub fn read_block(&self, index: u32, out: &mut [f32]) {
        assert_eq!(out.len(), self.floats_per_block);
        if !self.materialize {
            out.fill(0.0);
            return;
        }
        out.copy_from_slice(self.block(index));
    }
}

/// Move one block's contents between two arenas (swap in/out). Returns
/// the destination slot. Both arenas must share `floats_per_block`.
pub fn move_block(src: &mut Arena, src_idx: u32, dst: &mut Arena)
                  -> Result<u32, AllocError> {
    assert_eq!(src.floats_per_block, dst.floats_per_block);
    let dst_idx = dst.alloc(1)?[0];
    if src.materialize && dst.materialize {
        // Split-borrow safe: copy through a scratch buffer.
        let mut tmp = vec![0.0f32; src.floats_per_block];
        src.read_block(src_idx, &mut tmp);
        dst.write_block(dst_idx, &tmp);
    }
    src.free(&[src_idx])?;
    Ok(dst_idx)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn materialized_read_write() {
        let mut a = Arena::new(4, 8, true);
        let b = a.alloc(1).unwrap()[0];
        let data: Vec<f32> = (0..8).map(|i| i as f32).collect();
        a.write_block(b, &data);
        let mut out = vec![0.0; 8];
        a.read_block(b, &mut out);
        assert_eq!(out, data);
    }

    #[test]
    fn unmaterialized_is_bookkeeping_only() {
        let mut a = Arena::new(4, 8, false);
        let b = a.alloc(2).unwrap();
        a.write_block(b[0], &vec![1.0; 8]); // no-op, must not panic
        let mut out = vec![9.0; 8];
        a.read_block(b[0], &mut out);
        assert_eq!(out, vec![0.0; 8]);
        assert_eq!(a.allocator().used(), 2);
    }

    #[test]
    fn move_block_copies_and_frees() {
        let mut hbm = Arena::new(2, 4, true);
        let mut dram = Arena::new(2, 4, true);
        let b = hbm.alloc(1).unwrap()[0];
        hbm.write_block(b, &[1.0, 2.0, 3.0, 4.0]);
        let d = move_block(&mut hbm, b, &mut dram).unwrap();
        assert_eq!(hbm.allocator().used(), 0);
        assert_eq!(dram.block(d), &[1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn move_block_fails_when_dst_full() {
        let mut hbm = Arena::new(2, 4, true);
        let mut dram = Arena::new(1, 4, true);
        dram.alloc(1).unwrap();
        let b = hbm.alloc(1).unwrap()[0];
        assert!(move_block(&mut hbm, b, &mut dram).is_err());
        // Source must be untouched on failure.
        assert!(hbm.allocator().is_allocated(b));
    }

    #[test]
    #[should_panic(expected = "not materialized")]
    fn block_view_panics_unmaterialized() {
        let mut a = Arena::new(2, 4, false);
        let b = a.alloc(1).unwrap()[0];
        let _ = a.block(b);
    }
}
