//! Arrival process (paper §8.2 "Arrival Pattern"): request arrival times
//! sampled from a Poisson process at a configurable rate, with the causal
//! session dependency — turn k+1 of a session is released only after turn
//! k's response has been received (the driver enforces the max() with the
//! response time; this module supplies the nominal schedule).

use crate::util::rng::Rng;
use crate::workload::spec::WorkloadSpec;

/// A request's identity within the workload plan.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct PlannedRequest {
    pub session_idx: usize,
    pub turn_idx: usize,
    /// Nominal Poisson arrival time (seconds from epoch). The effective
    /// send time is `max(nominal, prev_turn_response_time)`.
    pub nominal_time_s: f64,
}

impl PlannedRequest {
    fn time(&self) -> f64 {
        self.nominal_time_s
    }
}

/// The full nominal schedule, sorted by time.
#[derive(Clone, Debug)]
pub struct ArrivalPlan {
    pub requests: Vec<PlannedRequest>,
    pub rate: f64,
}

impl ArrivalPlan {
    /// Build a Poisson schedule at `rate` requests/second across the
    /// whole workload. Turn order within a session is preserved (turn k's
    /// nominal time precedes turn k+1's).
    pub fn poisson(spec: &WorkloadSpec, rate: f64, seed: u64) -> ArrivalPlan {
        assert!(rate > 0.0);
        let mut rng = Rng::new(seed ^ 0xA221_7A);
        let total: usize = spec.total_requests();
        // Draw global inter-arrival gaps.
        let mut times = Vec::with_capacity(total);
        let mut t = 0.0;
        for _ in 0..total {
            t += rng.exponential(rate);
            times.push(t);
        }
        // Assign arrival slots to sessions round-robin-with-jitter so
        // sessions interleave (like real traffic), preserving turn order.
        let mut cursors: Vec<usize> =
            spec.sessions.iter().map(|_| 0).collect();
        let mut order: Vec<usize> = (0..spec.sessions.len())
            .flat_map(|i| std::iter::repeat(i).take(spec.sessions[i].turns.len()))
            .collect();
        rng.shuffle(&mut order);
        // Shuffling can violate turn order *within* a session only if we
        // didn't track per-session cursors — we do, so each occurrence of
        // session i consumes its next turn.
        let mut requests = Vec::with_capacity(total);
        for (slot, &sess) in order.iter().enumerate() {
            let turn = cursors[sess];
            cursors[sess] += 1;
            requests.push(PlannedRequest {
                session_idx: sess,
                turn_idx: turn,
                nominal_time_s: times[slot],
            });
        }
        ArrivalPlan {
            requests,
            rate,
        }
    }

    /// Mean offered rate over the schedule (sanity metric).
    pub fn empirical_rate(&self) -> f64 {
        if self.requests.len() < 2 {
            return 0.0;
        }
        let span = self
            .requests
            .iter()
            .map(PlannedRequest::time)
            .fold(f64::NEG_INFINITY, f64::max);
        self.requests.len() as f64 / span
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::spec::{WorkloadKind, WorkloadSpec};

    fn plan(rate: f64) -> (WorkloadSpec, ArrivalPlan) {
        let spec =
            WorkloadSpec::generate(WorkloadKind::ShareGpt, 30, 1, 2048, 512);
        let plan = ArrivalPlan::poisson(&spec, rate, 9);
        (spec, plan)
    }

    #[test]
    fn covers_every_turn_exactly_once() {
        let (spec, plan) = plan(5.0);
        assert_eq!(plan.requests.len(), spec.total_requests());
        let mut seen = std::collections::HashSet::new();
        for r in &plan.requests {
            assert!(seen.insert((r.session_idx, r.turn_idx)));
            assert!(r.turn_idx < spec.sessions[r.session_idx].turns.len());
        }
    }

    #[test]
    fn turn_order_monotone_within_session() {
        let (spec, plan) = plan(3.0);
        for s in 0..spec.sessions.len() {
            let times: Vec<f64> = plan
                .requests
                .iter()
                .filter(|r| r.session_idx == s)
                .map(|r| (r.turn_idx, r.nominal_time_s))
                .collect::<std::collections::BTreeMap<_, _>>()
                .into_values()
                .collect();
            for w in times.windows(2) {
                assert!(w[0] < w[1], "turn order violated in session {s}");
            }
        }
    }

    #[test]
    fn empirical_rate_close_to_nominal() {
        let (_, plan) = plan(10.0);
        let r = plan.empirical_rate();
        assert!((r - 10.0).abs() / 10.0 < 0.35, "rate={r}");
    }

    #[test]
    fn deterministic() {
        let (_, a) = plan(2.0);
        let (_, b) = plan(2.0);
        assert_eq!(a.requests, b.requests);
    }
}
