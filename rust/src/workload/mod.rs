//! Synthetic workloads matched to the paper's three traces (§8.2, Fig 7).
//!
//! The real datasets (ShareGPT, LooGLE, ReAct/HotpotQA traces) are not
//! redistributable here; these generators reproduce the *distributional
//! properties* the paper's results depend on — prompt/generation length
//! distributions, their ratio, session structure (multi-turn causality),
//! and shared-prefix percentage (Fig 7a–d) — scaled to the tiny model's
//! 512-token context (the paper truncates LooGLE docs to 1k tokens of a
//! 4k window; we keep the same ~25% ratio).
//!
//! * **ShareGPT-like**: multi-turn chat; moderate prompts, the longest
//!   generations, sharing mostly *within* a session (conversation
//!   history) plus a small cross-session system prompt.
//! * **LooGLE-like**: long-document QA; one long shared document per
//!   session, several short questions, short answers → huge shared
//!   prefix, prompt ≫ generation.
//! * **ReAct-like**: agent traces; a long few-shot exemplar shared
//!   *across all sessions*, growing thought/action/observation history,
//!   fairly long generations.

pub mod arrival;
pub mod spec;
pub mod stats;

pub use arrival::ArrivalPlan;
pub use spec::{SessionSpec, TurnSpec, WorkloadKind, WorkloadSpec};
pub use stats::WorkloadStats;
