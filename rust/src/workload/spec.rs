//! Workload specification + the three generators.
//!
//! Everything is in *token* space (the global scheduler's tokenizer is
//! exercised by the text-level quickstart example; generators produce
//! token ids directly so the sim and the live driver share one format).
//! Token ids stay within the model vocab and are deterministic per seed.

use crate::util::rng::Rng;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WorkloadKind {
    ShareGpt,
    Loogle,
    React,
}

impl WorkloadKind {
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "sharegpt" => Some(Self::ShareGpt),
            "loogle" => Some(Self::Loogle),
            "react" => Some(Self::React),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Self::ShareGpt => "sharegpt",
            Self::Loogle => "loogle",
            Self::React => "react",
        }
    }

    pub fn all() -> [WorkloadKind; 3] {
        [Self::ShareGpt, Self::Loogle, Self::React]
    }
}

/// One user turn: tokens appended to the running context, plus how many
/// tokens the "assistant" should generate in response.
#[derive(Clone, Debug, PartialEq)]
pub struct TurnSpec {
    pub user_tokens: Vec<u32>,
    pub target_gen: usize,
}

/// One session (chat conversation / document QA / agent episode).
/// The prompt of turn k is:
///   shared_prefix ++ Σ_{i<k} (user_i ++ response_i) ++ user_k
/// where response_i is whatever the serving system generated (causal
/// dependency — turn k+1 cannot be built before turn k's response).
#[derive(Clone, Debug, PartialEq)]
pub struct SessionSpec {
    pub id: u64,
    pub shared_prefix: Vec<u32>,
    pub turns: Vec<TurnSpec>,
}

impl SessionSpec {
    /// Worst-case context this session can reach (for capacity checks).
    pub fn max_context(&self) -> usize {
        self.shared_prefix.len()
            + self
                .turns
                .iter()
                .map(|t| t.user_tokens.len() + t.target_gen)
                .sum::<usize>()
    }

    pub fn total_requests(&self) -> usize {
        self.turns.len()
    }
}

#[derive(Clone, Debug)]
pub struct WorkloadSpec {
    pub kind: WorkloadKind,
    pub sessions: Vec<SessionSpec>,
    pub seed: u64,
}

/// Generation parameters, scaled to a `max_seq`-token context window.
struct Scale {
    max_seq: usize,
}

impl Scale {
    fn frac(&self, x: f64) -> usize {
        ((self.max_seq as f64) * x).round().max(1.0) as usize
    }
}

fn rand_tokens(rng: &mut Rng, n: usize, vocab: u32) -> Vec<u32> {
    use crate::tokenizer::RESERVED;
    (0..n)
        .map(|_| RESERVED + rng.below((vocab - RESERVED) as u64) as u32)
        .collect()
}

/// Clamp a lognormal sample into `[lo, hi]`.
fn ln_len(rng: &mut Rng, mu: f64, sigma: f64, lo: usize, hi: usize) -> usize {
    (rng.lognormal(mu, sigma).round() as usize).clamp(lo, hi)
}

impl WorkloadSpec {
    /// Generate `n_sessions` sessions of the given kind.
    ///
    /// `vocab` bounds token ids; `max_seq` scales lengths so every
    /// session fits the context window (paper model: 4k; tiny model:
    /// 512 — all distributions scale down by the same factor).
    pub fn generate(
        kind: WorkloadKind,
        n_sessions: usize,
        seed: u64,
        vocab: u32,
        max_seq: usize,
    ) -> WorkloadSpec {
        let mut rng = Rng::new(seed ^ 0xB07D01);
        let s = Scale { max_seq };
        let sessions = (0..n_sessions)
            .map(|i| {
                let mut srng = rng.fork(i as u64);
                match kind {
                    WorkloadKind::ShareGpt => {
                        Self::gen_sharegpt(&mut srng, i as u64, vocab, &s)
                    }
                    WorkloadKind::Loogle => {
                        Self::gen_loogle(&mut srng, i as u64, vocab, &s, seed)
                    }
                    WorkloadKind::React => {
                        Self::gen_react(&mut srng, i as u64, vocab, &s, seed)
                    }
                }
            })
            .collect();
        WorkloadSpec {
            kind,
            sessions,
            seed,
        }
    }

    /// ShareGPT-like: 1–8 turns, moderate user messages, long-ish
    /// generations (the longest of the three), small cross-session
    /// system prompt.
    fn gen_sharegpt(rng: &mut Rng, id: u64, vocab: u32, s: &Scale)
                    -> SessionSpec {
        // System prompt shared by ALL sessions (same token seed).
        let mut sys_rng = Rng::new(0x5151);
        let shared_prefix = rand_tokens(&mut sys_rng, s.frac(0.03), vocab);
        let n_turns = 1 + rng.below(8) as usize;
        let mut budget = s.max_seq
            - shared_prefix.len()
            - 8; // slack
        let mut turns = vec![];
        for _ in 0..n_turns {
            // user ~ lognormal around 4% of window; gen around 6%.
            let user = ln_len(rng, (s.frac(0.04) as f64).ln(), 0.8, 2,
                              s.frac(0.12));
            let gen = ln_len(rng, (s.frac(0.06) as f64).ln(), 0.7, 2,
                             s.frac(0.15));
            if user + gen + 2 > budget {
                break;
            }
            budget -= user + gen;
            turns.push(TurnSpec {
                user_tokens: rand_tokens(rng, user, vocab),
                target_gen: gen,
            });
        }
        if turns.is_empty() {
            turns.push(TurnSpec {
                user_tokens: rand_tokens(rng, 4, vocab),
                target_gen: 4,
            });
        }
        SessionSpec {
            id,
            shared_prefix,
            turns,
        }
    }

    /// LooGLE-like: a long document (25% of the window, mirroring the
    /// paper's 1k-of-4k truncation) + up to 5 short questions with short
    /// answers. A few distinct documents are shared across sessions
    /// (inter-session reuse — what Fig 15's share-ratio experiment
    /// scales).
    fn gen_loogle(rng: &mut Rng, id: u64, vocab: u32, s: &Scale,
                  seed: u64) -> SessionSpec {
        // Draw the document from a small pool so sessions share docs.
        let n_docs = 8u64;
        let doc_id = rng.zipf(n_docs, 1.0);
        let mut doc_rng = Rng::new(seed ^ 0xD0C_000 ^ doc_id);
        let doc_len = s.frac(0.25)
            + (doc_id as usize * 7) % s.frac(0.05); // mild variety
        let shared_prefix = rand_tokens(&mut doc_rng, doc_len, vocab);
        let n_q = 1 + rng.below(5) as usize;
        let mut turns = vec![];
        let mut budget = s.max_seq - shared_prefix.len() - 8;
        for _ in 0..n_q {
            let q = ln_len(rng, (s.frac(0.03) as f64).ln(), 0.5, 2,
                           s.frac(0.06));
            let a = ln_len(rng, (s.frac(0.015) as f64).ln(), 0.6, 2,
                           s.frac(0.04));
            if q + a + 2 > budget {
                break;
            }
            budget -= q + a;
            turns.push(TurnSpec {
                user_tokens: rand_tokens(rng, q, vocab),
                target_gen: a,
            });
        }
        if turns.is_empty() {
            turns.push(TurnSpec {
                user_tokens: rand_tokens(rng, 4, vocab),
                target_gen: 3,
            });
        }
        SessionSpec {
            id,
            shared_prefix,
            turns,
        }
    }

    /// ReAct-like: one two-shot exemplar shared across ALL sessions
    /// (30% of the window), then thought/action/observation rounds whose
    /// generations are long (reasoning text).
    fn gen_react(rng: &mut Rng, id: u64, vocab: u32, s: &Scale,
                 seed: u64) -> SessionSpec {
        let mut ex_rng = Rng::new(seed ^ 0x2EAC7);
        let shared_prefix = rand_tokens(&mut ex_rng, s.frac(0.30), vocab);
        let n_rounds = 2 + rng.below(4) as usize;
        let mut turns = vec![];
        let mut budget = s.max_seq - shared_prefix.len() - 8;
        for round in 0..n_rounds {
            // Round 0 is the task; later "user" turns are observations.
            let user = if round == 0 {
                ln_len(rng, (s.frac(0.035) as f64).ln(), 0.4, 2, s.frac(0.07))
            } else {
                ln_len(rng, (s.frac(0.02) as f64).ln(), 0.6, 2, s.frac(0.05))
            };
            let gen = ln_len(rng, (s.frac(0.05) as f64).ln(), 0.5, 2,
                             s.frac(0.10));
            if user + gen + 2 > budget {
                break;
            }
            budget -= user + gen;
            turns.push(TurnSpec {
                user_tokens: rand_tokens(rng, user, vocab),
                target_gen: gen,
            });
        }
        if turns.is_empty() {
            turns.push(TurnSpec {
                user_tokens: rand_tokens(rng, 4, vocab),
                target_gen: 6,
            });
        }
        SessionSpec {
            id,
            shared_prefix,
            turns,
        }
    }

    pub fn total_requests(&self) -> usize {
        self.sessions.iter().map(SessionSpec::total_requests).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const V: u32 = 2048;
    const MS: usize = 512;

    #[test]
    fn deterministic_per_seed() {
        for kind in WorkloadKind::all() {
            let a = WorkloadSpec::generate(kind, 10, 7, V, MS);
            let b = WorkloadSpec::generate(kind, 10, 7, V, MS);
            assert_eq!(a.sessions, b.sessions, "{kind:?}");
            let c = WorkloadSpec::generate(kind, 10, 8, V, MS);
            assert_ne!(a.sessions, c.sessions, "{kind:?}");
        }
    }

    #[test]
    fn sessions_fit_context_window() {
        for kind in WorkloadKind::all() {
            let w = WorkloadSpec::generate(kind, 50, 1, V, MS);
            for s in &w.sessions {
                assert!(
                    s.max_context() <= MS,
                    "{kind:?} session {} needs {} tokens",
                    s.id,
                    s.max_context()
                );
                assert!(!s.turns.is_empty());
            }
        }
    }

    #[test]
    fn token_ids_in_vocab_and_above_reserved() {
        for kind in WorkloadKind::all() {
            let w = WorkloadSpec::generate(kind, 10, 2, V, MS);
            for s in &w.sessions {
                for &t in s.shared_prefix.iter().chain(
                    s.turns.iter().flat_map(|t| t.user_tokens.iter()),
                ) {
                    assert!(t >= crate::tokenizer::RESERVED && t < V);
                }
            }
        }
    }

    #[test]
    fn loogle_has_longest_shared_prefix_react_shares_globally() {
        let sg = WorkloadSpec::generate(WorkloadKind::ShareGpt, 20, 3, V, MS);
        let lg = WorkloadSpec::generate(WorkloadKind::Loogle, 20, 3, V, MS);
        let ra = WorkloadSpec::generate(WorkloadKind::React, 20, 3, V, MS);
        let avg = |w: &WorkloadSpec| {
            w.sessions
                .iter()
                .map(|s| s.shared_prefix.len())
                .sum::<usize>() as f64
                / w.sessions.len() as f64
        };
        assert!(avg(&lg) > avg(&sg) * 3.0, "LooGLE prefix should dominate");
        assert!(avg(&ra) > avg(&sg) * 3.0);
        // ReAct exemplar identical across sessions:
        assert_eq!(ra.sessions[0].shared_prefix, ra.sessions[5].shared_prefix);
        // ShareGPT system prompt identical too (but short):
        assert_eq!(sg.sessions[0].shared_prefix, sg.sessions[5].shared_prefix);
    }

    #[test]
    fn loogle_documents_repeat_across_sessions() {
        let lg = WorkloadSpec::generate(WorkloadKind::Loogle, 40, 4, V, MS);
        let mut prefix_counts =
            std::collections::HashMap::<&[u32], usize>::new();
        for s in &lg.sessions {
            *prefix_counts.entry(&s.shared_prefix).or_default() += 1;
        }
        assert!(prefix_counts.len() < 40, "no document reuse at all");
        assert!(
            prefix_counts.values().any(|&c| c >= 5),
            "zipf should concentrate on few docs: {prefix_counts:?} sizes"
        );
    }

    #[test]
    fn sharegpt_generates_longest_outputs() {
        let sg = WorkloadSpec::generate(WorkloadKind::ShareGpt, 50, 5, V, MS);
        let lg = WorkloadSpec::generate(WorkloadKind::Loogle, 50, 5, V, MS);
        let avg_gen = |w: &WorkloadSpec| {
            let (sum, n) = w
                .sessions
                .iter()
                .flat_map(|s| s.turns.iter())
                .fold((0usize, 0usize), |(s, n), t| (s + t.target_gen, n + 1));
            sum as f64 / n as f64
        };
        assert!(avg_gen(&sg) > avg_gen(&lg) * 1.5);
    }
}
