//! Workload statistics — regenerates the paper's Figure 7 panels:
//! (a) prompt length, (b) generation length, (c) prompt:generation ratio,
//! (d) shared-prefix percentage per request.
//!
//! Shared-prefix % of a request = (longest token prefix shared with any
//! *earlier* request) / prompt length — computed with a radix index at
//! token granularity, which is exactly the reuse a perfect cache could
//! achieve.

use crate::mempool::RadixIndex;
use crate::util::stats::Samples;
use crate::workload::spec::WorkloadSpec;

#[derive(Debug, Default)]
pub struct WorkloadStats {
    pub prompt_len: Samples,
    pub gen_len: Samples,
    pub ratio: Samples,
    pub shared_prefix_pct: Samples,
    pub requests: usize,
}

impl WorkloadStats {
    /// Replay the workload in session-turn order (generation simulated as
    /// `target_gen` placeholder tokens — length statistics do not depend
    /// on token values).
    pub fn compute(spec: &WorkloadSpec) -> WorkloadStats {
        let mut idx = RadixIndex::new(1, 0.0); // token granularity
        let mut out = WorkloadStats::default();
        // Interleave sessions turn-by-turn (round-robin) so "earlier
        // request" reflects concurrent sessions, like a live trace.
        let max_turns = spec
            .sessions
            .iter()
            .map(|s| s.turns.len())
            .max()
            .unwrap_or(0);
        // Running context per session.
        let mut ctx: Vec<Vec<u32>> = spec
            .sessions
            .iter()
            .map(|s| s.shared_prefix.clone())
            .collect();
        let mut synth_tok = 3_000_000u32; // out-of-vocab placeholder ids
        for turn in 0..max_turns {
            for (si, sess) in spec.sessions.iter().enumerate() {
                let Some(t) = sess.turns.get(turn) else { continue };
                let mut prompt = ctx[si].clone();
                prompt.extend_from_slice(&t.user_tokens);
                let m = idx.match_prefix(&prompt, 1.0);
                out.prompt_len.push(prompt.len() as f64);
                out.gen_len.push(t.target_gen as f64);
                out.ratio
                    .push(prompt.len() as f64 / t.target_gen.max(1) as f64);
                out.shared_prefix_pct
                    .push(100.0 * m.tokens as f64 / prompt.len() as f64);
                out.requests += 1;
                idx.insert_unaddressed(&prompt, 1.0);
                // Append simulated response tokens to the context.
                ctx[si] = prompt;
                for _ in 0..t.target_gen {
                    synth_tok += 1;
                    ctx[si].push(synth_tok);
                }
            }
        }
        out
    }

    /// Paper-style summary row: means and P50s of all four panels.
    pub fn summary(&mut self) -> String {
        format!(
            "prompt(mean={:.0} p50={:.0}) gen(mean={:.0} p50={:.0}) \
             ratio(mean={:.1}) shared-prefix(mean={:.0}% p50={:.0}%)",
            self.prompt_len.mean(),
            self.prompt_len.p50(),
            self.gen_len.mean(),
            self.gen_len.p50(),
            self.ratio.mean(),
            self.shared_prefix_pct.mean(),
            self.shared_prefix_pct.p50(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::spec::WorkloadKind;

    fn stats(kind: WorkloadKind) -> WorkloadStats {
        let spec = WorkloadSpec::generate(kind, 40, 11, 2048, 512);
        WorkloadStats::compute(&spec)
    }

    #[test]
    fn fig7_shapes_hold() {
        let mut sg = stats(WorkloadKind::ShareGpt);
        let mut lg = stats(WorkloadKind::Loogle);
        let mut ra = stats(WorkloadKind::React);

        // (a,b) LooGLE: long prompts, short generations.
        assert!(lg.prompt_len.mean() > sg.prompt_len.mean());
        assert!(lg.gen_len.mean() < sg.gen_len.mean());
        // (c) ratio ordering: LooGLE >> ReAct > ShareGPT.
        assert!(lg.ratio.mean() > ra.ratio.mean());
        assert!(ra.ratio.mean() > sg.ratio.mean());
        // (d) shared prefix: LooGLE & ReAct large, ShareGPT lower.
        assert!(lg.shared_prefix_pct.mean() > 55.0,
                "loogle share {}", lg.shared_prefix_pct.mean());
        assert!(ra.shared_prefix_pct.mean() > 45.0,
                "react share {}", ra.shared_prefix_pct.mean());
        assert!(
            sg.shared_prefix_pct.mean() < lg.shared_prefix_pct.mean(),
            "sharegpt {} vs loogle {}",
            sg.shared_prefix_pct.mean(),
            lg.shared_prefix_pct.mean()
        );
    }

    #[test]
    fn multi_turn_requests_share_their_own_history() {
        // Any session's turn >= 1 must see a large shared prefix (its own
        // turn-0 context is in the index).
        let spec = WorkloadSpec::generate(WorkloadKind::ShareGpt, 5, 3,
                                          2048, 512);
        let s = WorkloadStats::compute(&spec);
        // Requests counted == spec turns.
        assert_eq!(s.requests, spec.total_requests());
    }

    #[test]
    fn summary_prints() {
        let mut s = stats(WorkloadKind::Loogle);
        let line = s.summary();
        assert!(line.contains("shared-prefix"));
    }
}
