//! Deterministic synthetic tokenizer.
//!
//! The global scheduler's first step is tokenization (paper §6); context
//! caching correctness depends on *stable* token IDs so equal text
//! prefixes produce equal token prefixes across sessions and instances.
//! Real BPE is out of scope (no model vocabulary ships with the synthetic
//! workloads); this tokenizer splits on whitespace/punctuation and maps
//! each word to a stable FNV-hashed ID in `[RESERVED, vocab)`.

/// IDs below this are reserved (padding=0, BOS=1, EOS=2, byte fallbacks).
pub const RESERVED: u32 = 16;

pub const PAD: u32 = 0;
pub const BOS: u32 = 1;
pub const EOS: u32 = 2;

#[derive(Clone, Debug)]
pub struct Tokenizer {
    vocab: u32,
}

impl Tokenizer {
    pub fn new(vocab: u32) -> Self {
        assert!(vocab > RESERVED * 2, "vocab too small: {vocab}");
        Tokenizer { vocab }
    }

    pub fn vocab(&self) -> u32 {
        self.vocab
    }

    /// FNV-1a 64-bit — stable across runs/platforms.
    fn word_id(&self, word: &str) -> u32 {
        let mut h: u64 = 0xcbf29ce484222325;
        for b in word.as_bytes() {
            h ^= *b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
        RESERVED + (h % (self.vocab as u64 - RESERVED as u64)) as u32
    }

    /// Tokenize text: words and single punctuation marks become tokens.
    pub fn encode(&self, text: &str) -> Vec<u32> {
        let mut out = Vec::with_capacity(text.len() / 4 + 1);
        let mut word_start: Option<usize> = None;
        let bytes = text.as_bytes();
        for (i, &b) in bytes.iter().enumerate() {
            let c = b as char;
            if c.is_ascii_alphanumeric() || c == '_' || c == '\'' || b >= 0x80 {
                if word_start.is_none() {
                    word_start = Some(i);
                }
            } else {
                if let Some(s) = word_start.take() {
                    out.push(self.word_id(&text[s..i]));
                }
                if !c.is_ascii_whitespace() {
                    // Single punctuation char gets its own stable token.
                    out.push(self.word_id(&text[i..i + 1]));
                }
            }
        }
        if let Some(s) = word_start {
            out.push(self.word_id(&text[s..]));
        }
        out
    }

    /// Encode with BOS prepended — the canonical prompt form, guaranteeing
    /// every prompt shares at least the BOS prefix (radix-tree root edge).
    pub fn encode_prompt(&self, text: &str) -> Vec<u32> {
        let mut v = vec![BOS];
        v.extend(self.encode(text));
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t() -> Tokenizer {
        Tokenizer::new(2048)
    }

    #[test]
    fn deterministic() {
        let a = t().encode("the quick brown fox");
        let b = t().encode("the quick brown fox");
        assert_eq!(a, b);
        assert_eq!(a.len(), 4);
    }

    #[test]
    fn shared_text_prefix_gives_shared_token_prefix() {
        let a = t().encode_prompt("system: you are helpful. user: hi");
        let b = t().encode_prompt("system: you are helpful. user: bye now");
        let common = a.iter().zip(&b).take_while(|(x, y)| x == y).count();
        // "system: you are helpful. user:" = 6 words + 3 punct + BOS
        assert!(common >= 9, "common={common}");
        assert_ne!(a[common..], b[common..]);
    }

    #[test]
    fn ids_in_range_and_reserved_respected() {
        let toks = t().encode("a b c d ! ? , . 123 x_y O'Neil");
        for &tok in &toks {
            assert!((RESERVED..2048).contains(&tok), "tok={tok}");
        }
    }

    #[test]
    fn punctuation_splits_words() {
        let a = t().encode("a,b");
        assert_eq!(a.len(), 3);
        let b = t().encode("a , b");
        assert_eq!(a, b);
    }

    #[test]
    fn empty_and_whitespace() {
        assert!(t().encode("").is_empty());
        assert!(t().encode("   \n\t ").is_empty());
        assert_eq!(t().encode_prompt(""), vec![BOS]);
    }

    #[test]
    fn different_words_usually_differ() {
        let tok = t();
        let ids: std::collections::HashSet<u32> = (0..200)
            .map(|i| tok.word_id(&format!("word{i}")))
            .collect();
        assert!(ids.len() > 180, "too many collisions: {}", ids.len());
    }
}
