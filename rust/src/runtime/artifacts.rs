//! Artifact manifest: `artifacts/meta.json` + `weights.bin` loading.
//!
//! `meta.json` is the cross-language ABI emitted by `python/compile/aot.py`
//! — model geometry, the static-shape bucket list, the parameter manifest
//! (flatten order = executable argument order), and the artifact file
//! index. This module parses and validates it without touching PJRT, so
//! it is testable without artifacts on disk.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

#[derive(Clone, Debug, PartialEq)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset_f32: usize,
    pub len_f32: usize,
}

#[derive(Clone, Debug)]
pub struct ModelMeta {
    pub vocab: usize,
    pub layers: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub head_dim: usize,
    pub max_seq: usize,
    pub param_count: usize,
    /// (N, C) prefill buckets, sorted by (N, C).
    pub prefill_buckets: Vec<(usize, usize)>,
    /// Decode context buckets, sorted.
    pub decode_ctx: Vec<usize>,
    pub params: Vec<ParamSpec>,
    pub weights_file: String,
    /// artifact name -> file name.
    pub artifacts: BTreeMap<String, String>,
    pub dir: PathBuf,
}

impl ModelMeta {
    pub fn load(dir: &str) -> Result<ModelMeta> {
        let dir = PathBuf::from(dir);
        let meta_path = dir.join("meta.json");
        let text = std::fs::read_to_string(&meta_path)
            .with_context(|| format!("reading {meta_path:?} — run `make artifacts`?"))?;
        let j = Json::parse(&text)
            .map_err(|e| anyhow::anyhow!("parsing {meta_path:?}: {e}"))?;
        Self::from_json(&j, dir)
    }

    pub fn from_json(j: &Json, dir: PathBuf) -> Result<ModelMeta> {
        let num = |path: &[&str]| -> Result<usize> {
            j.at(path)
                .and_then(Json::as_usize)
                .with_context(|| format!("meta.json missing {path:?}"))
        };
        let mut prefill_buckets = vec![];
        for row in j
            .at(&["buckets", "prefill"])
            .and_then(Json::as_arr)
            .context("buckets.prefill")?
        {
            let pair = row.as_arr().context("prefill bucket not a pair")?;
            prefill_buckets.push((
                pair[0].as_usize().context("bucket N")?,
                pair[1].as_usize().context("bucket C")?,
            ));
        }
        prefill_buckets.sort_unstable();
        let mut decode_ctx = vec![];
        for c in j
            .at(&["buckets", "decode_ctx"])
            .and_then(Json::as_arr)
            .context("buckets.decode_ctx")?
        {
            decode_ctx.push(c.as_usize().context("decode ctx")?);
        }
        decode_ctx.sort_unstable();

        let mut params = vec![];
        for p in j.at(&["params"]).and_then(Json::as_arr).context("params")? {
            params.push(ParamSpec {
                name: p
                    .get("name")
                    .and_then(Json::as_str)
                    .context("param name")?
                    .to_string(),
                shape: p
                    .get("shape")
                    .and_then(Json::as_arr)
                    .context("param shape")?
                    .iter()
                    .map(|x| x.as_usize().unwrap_or(0))
                    .collect(),
                offset_f32: p
                    .get("offset_f32")
                    .and_then(Json::as_usize)
                    .context("offset")?,
                len_f32: p
                    .get("len_f32")
                    .and_then(Json::as_usize)
                    .context("len")?,
            });
        }

        let mut artifacts = BTreeMap::new();
        for (k, v) in j
            .at(&["artifacts"])
            .and_then(Json::as_obj)
            .context("artifacts")?
        {
            artifacts.insert(
                k.clone(),
                v.as_str().context("artifact path")?.to_string(),
            );
        }

        let meta = ModelMeta {
            vocab: num(&["model", "vocab"])?,
            layers: num(&["model", "layers"])?,
            d_model: num(&["model", "d_model"])?,
            n_heads: num(&["model", "n_heads"])?,
            head_dim: num(&["model", "head_dim"])?,
            max_seq: num(&["model", "max_seq"])?,
            param_count: num(&["model", "param_count"])?,
            prefill_buckets,
            decode_ctx,
            params,
            weights_file: j
                .at(&["weights_file"])
                .and_then(Json::as_str)
                .context("weights_file")?
                .to_string(),
            artifacts,
            dir,
        };
        meta.validate()?;
        Ok(meta)
    }

    pub fn validate(&self) -> Result<()> {
        if self.prefill_buckets.is_empty() || self.decode_ctx.is_empty() {
            bail!("no buckets in meta.json");
        }
        let total: usize = self.params.iter().map(|p| p.len_f32).sum();
        if total != self.param_count {
            bail!("param manifest sums to {total}, expected {}", self.param_count);
        }
        let mut offset = 0;
        for p in &self.params {
            if p.offset_f32 != offset {
                bail!("param {} not contiguous", p.name);
            }
            let n: usize = p.shape.iter().product();
            if n != p.len_f32 {
                bail!("param {} shape/len mismatch", p.name);
            }
            offset += p.len_f32;
        }
        for (n, c) in &self.prefill_buckets {
            if !self.artifacts.contains_key(&format!("prefill_n{n}_c{c}")) {
                bail!("missing artifact for prefill bucket ({n},{c})");
            }
        }
        for ctx in &self.decode_ctx {
            if !self.artifacts.contains_key(&format!("decode_ctx{ctx}")) {
                bail!("missing artifact for decode ctx {ctx}");
            }
        }
        Ok(())
    }

    /// Floats of KV one token carries (all layers, K+V).
    pub fn kv_floats_per_token(&self) -> usize {
        2 * self.layers * self.n_heads * self.head_dim
    }

    /// Flat decode-state length for a context bucket.
    pub fn state_len(&self, ctx: usize) -> usize {
        self.vocab + self.layers * 2 * ctx * self.n_heads * self.head_dim
    }

    /// Smallest prefill bucket (N, C) with N >= new_len and C >= cache_len
    /// (C == 0 bucket only when cache_len == 0).
    pub fn pick_prefill_bucket(&self, new_len: usize, cache_len: usize)
                               -> Option<(usize, usize)> {
        self.prefill_buckets
            .iter()
            .filter(|(n, c)| {
                *n >= new_len
                    && if cache_len == 0 { *c == 0 } else { *c >= cache_len }
            })
            .min_by_key(|(n, c)| (*n, *c))
            .copied()
    }

    /// Smallest decode context bucket >= len.
    pub fn pick_decode_ctx(&self, len: usize) -> Option<usize> {
        self.decode_ctx.iter().find(|&&c| c >= len).copied()
    }

    pub fn artifact_path(&self, name: &str) -> Option<PathBuf> {
        self.artifacts.get(name).map(|f| self.dir.join(f))
    }

    pub fn weights_path(&self) -> PathBuf {
        self.dir.join(&self.weights_file)
    }

    /// Read weights.bin (little-endian f32) into one contiguous Vec.
    pub fn read_weights(&self) -> Result<Vec<f32>> {
        let path = self.weights_path();
        let bytes = std::fs::read(&path)
            .with_context(|| format!("reading {path:?}"))?;
        if bytes.len() != 4 * self.param_count {
            bail!(
                "weights.bin is {} bytes, expected {}",
                bytes.len(),
                4 * self.param_count
            );
        }
        let mut out = vec![0f32; self.param_count];
        for (i, chunk) in bytes.chunks_exact(4).enumerate() {
            out[i] = f32::from_le_bytes(chunk.try_into().unwrap());
        }
        Ok(out)
    }
}

/// Check the default artifacts directory exists relative to the repo root
/// (tests use this to self-skip when artifacts are not built).
pub fn artifacts_available(dir: &str) -> bool {
    Path::new(dir).join("meta.json").exists()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_json() -> Json {
        Json::parse(
            r#"{
  "model": {"vocab": 8, "layers": 2, "d_model": 4, "n_heads": 2,
             "head_dim": 2, "max_seq": 64, "param_count": 40},
  "buckets": {"prefill": [[16, 0], [16, 32]], "decode_ctx": [32, 64]},
  "params": [
    {"name": "embed", "shape": [8, 4], "offset_f32": 0, "len_f32": 32},
    {"name": "unembed", "shape": [4, 2], "offset_f32": 32, "len_f32": 8}
  ],
  "weights_file": "weights.bin",
  "artifacts": {
    "prefill_n16_c0": "a.hlo.txt", "prefill_n16_c32": "b.hlo.txt",
    "decode_ctx32": "c.hlo.txt", "decode_ctx64": "d.hlo.txt"
  }
}"#,
        )
        .unwrap()
    }

    #[test]
    fn parses_and_validates() {
        let m = ModelMeta::from_json(&sample_json(), PathBuf::from("/tmp"))
            .unwrap();
        assert_eq!(m.vocab, 8);
        assert_eq!(m.prefill_buckets, vec![(16, 0), (16, 32)]);
        assert_eq!(m.kv_floats_per_token(), 2 * 2 * 2 * 2);
        assert_eq!(m.state_len(32), 8 + 2 * 2 * 32 * 2 * 2);
    }

    #[test]
    fn bucket_picking() {
        let m = ModelMeta::from_json(&sample_json(), PathBuf::from("/tmp"))
            .unwrap();
        assert_eq!(m.pick_prefill_bucket(10, 0), Some((16, 0)));
        assert_eq!(m.pick_prefill_bucket(10, 5), Some((16, 32)));
        assert_eq!(m.pick_prefill_bucket(10, 33), None);
        assert_eq!(m.pick_prefill_bucket(17, 0), None);
        assert_eq!(m.pick_decode_ctx(31), Some(32));
        assert_eq!(m.pick_decode_ctx(33), Some(64));
        assert_eq!(m.pick_decode_ctx(65), None);
    }

    #[test]
    fn rejects_noncontiguous_params() {
        let mut j = sample_json();
        if let Json::Obj(m) = &mut j {
            if let Some(Json::Arr(ps)) = m.get_mut("params") {
                if let Json::Obj(p1) = &mut ps[1] {
                    p1.insert("offset_f32".into(), Json::Num(33.0));
                }
            }
        }
        assert!(ModelMeta::from_json(&j, PathBuf::from("/tmp")).is_err());
    }

    #[test]
    fn rejects_missing_artifact() {
        let mut j = sample_json();
        if let Json::Obj(m) = &mut j {
            if let Some(Json::Obj(a)) = m.get_mut("artifacts") {
                a.remove("decode_ctx64");
            }
        }
        assert!(ModelMeta::from_json(&j, PathBuf::from("/tmp")).is_err());
    }

    #[test]
    fn loads_real_artifacts_if_present() {
        if !artifacts_available("artifacts") {
            return; // skip when `make artifacts` hasn't run
        }
        let m = ModelMeta::load("artifacts").unwrap();
        assert_eq!(m.vocab, 2048);
        assert_eq!(m.layers, 4);
        let w = m.read_weights().unwrap();
        assert_eq!(w.len(), m.param_count);
        // Norm weights (all-ones) exist somewhere in the blob.
        let p = m.params.iter().find(|p| p.name == "final_norm").unwrap();
        assert!(w[p.offset_f32..p.offset_f32 + p.len_f32]
            .iter()
            .all(|&x| x == 1.0));
    }
}
