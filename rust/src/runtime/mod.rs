//! PJRT runtime: loads the AOT artifacts emitted by `python/compile/aot.py`
//! and executes them on the request path. This is the **only** place model
//! compute happens at serving time — Python never runs here.
//!
//! Pipeline per artifact: `HloModuleProto::from_text_file` (HLO *text* —
//! jax ≥0.5 serialized protos are rejected by xla_extension 0.5.1) →
//! `XlaComputation::from_proto` → `PjRtClient::compile`. Weights are
//! uploaded to device buffers once at load; per-call arguments ride
//! `execute_b` alongside them.
//!
//! Decode uses the flat-state design (see `model.decode_state`): the
//! output buffer is fed back as the next step's input, so active KV stays
//! device-resident for a whole request and only the logits region is read
//! back per step.

pub mod artifacts;
pub mod executor;

pub use artifacts::ModelMeta;
pub use executor::{DecodeSession, ModelRuntime, PrefillOutput};
