//! The PJRT executor: compile-once, execute-many model runtime.
//!
//! One `ModelRuntime` per inference instance (the paper's engines each
//! own their GPU; ours each own a PJRT CPU "device" context). Loading
//! compiles every bucket's HLO and uploads the weights once; the serving
//! hot path then only moves per-request data.

use std::collections::BTreeMap;
use std::sync::Mutex;

use anyhow::{anyhow, bail, Context, Result};

use super::artifacts::ModelMeta;

/// Prefill results, downloaded to host (the engine scatters `new_kv` into
/// MemPool blocks and feeds `logits` to sampling).
#[derive(Clone, Debug)]
pub struct PrefillOutput {
    /// f32[L, 2, N, H, hd] flattened (N = bucket size; only the first
    /// `new_len` token slots are meaningful).
    pub new_kv: Vec<f32>,
    /// Bucket N the KV is laid out for.
    pub bucket_n: usize,
    /// f32[vocab] — logits after the last real prompt token.
    pub logits: Vec<f32>,
}

/// A device-resident decode loop: the flat state buffer ([logits | kv])
/// is fed back step to step; KV never round-trips to the host.
pub struct DecodeSession {
    state: xla::PjRtBuffer,
    pub ctx: usize,
    pub pos: usize,
    steps: usize,
    /// Reused host-side staging buffer for the per-step state download
    /// (avoids a ~0.5–4 MB allocation + copy every token).
    scratch: Vec<f32>,
}

// SAFETY: the xla crate's handles are raw pointers (auto-!Send/!Sync),
// but the underlying PJRT *CPU* client (TfrtCpuClient) is documented
// thread-safe, and this runtime only wraps immutable-after-load state
// (compiled executables + weight buffers) plus a Mutex'd counter block.
// DecodeSession buffers are owned by one request at a time. We confine
// mutation to &mut self / Mutex and allow cross-thread sharing.
//
// These scoped allows are the crate's *only* sanctioned unsafe
// (`#![deny(unsafe_code)]` in lib.rs — see the note there).
#[allow(unsafe_code)]
unsafe impl Send for ModelRuntime {}
#[allow(unsafe_code)]
unsafe impl Sync for ModelRuntime {}
#[allow(unsafe_code)]
unsafe impl Send for DecodeSession {}

pub struct ModelRuntime {
    client: xla::PjRtClient,
    pub meta: ModelMeta,
    weights: Vec<xla::PjRtBuffer>,
    prefill_exe: BTreeMap<(usize, usize), xla::PjRtLoadedExecutable>,
    decode_exe: BTreeMap<usize, xla::PjRtLoadedExecutable>,
    /// Executor-level counters (perf pass instrumentation).
    pub counters: Mutex<RuntimeCounters>,
}

#[derive(Clone, Debug, Default)]
pub struct RuntimeCounters {
    pub prefill_calls: u64,
    pub decode_steps: u64,
    pub prefill_seconds: f64,
    pub decode_seconds: f64,
    pub bytes_uploaded: u64,
    pub bytes_downloaded: u64,
}

impl ModelRuntime {
    /// Load + compile every artifact in `dir`. Expensive (seconds); do it
    /// once per instance at startup.
    pub fn load(dir: &str) -> Result<ModelRuntime> {
        let meta = ModelMeta::load(dir)?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow!("PjRtClient::cpu: {e:?}"))?;

        // Upload weights once.
        let blob = meta.read_weights()?;
        let mut weights = Vec::with_capacity(meta.params.len());
        for p in &meta.params {
            let seg = &blob[p.offset_f32..p.offset_f32 + p.len_f32];
            let buf = client
                .buffer_from_host_buffer::<f32>(seg, &p.shape, None)
                .map_err(|e| anyhow!("upload {}: {e:?}", p.name))?;
            weights.push(buf);
        }

        let compile = |name: &str| -> Result<xla::PjRtLoadedExecutable> {
            let path = meta
                .artifact_path(name)
                .with_context(|| format!("artifact {name}"))?;
            let path_s = path.to_str().context("path utf8")?;
            let proto = xla::HloModuleProto::from_text_file(path_s)
                .map_err(|e| anyhow!("parse {name}: {e:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            client
                .compile(&comp)
                .map_err(|e| anyhow!("compile {name}: {e:?}"))
        };

        let mut prefill_exe = BTreeMap::new();
        for &(n, c) in &meta.prefill_buckets {
            prefill_exe.insert((n, c), compile(&format!("prefill_n{n}_c{c}"))?);
        }
        let mut decode_exe = BTreeMap::new();
        for &ctx in &meta.decode_ctx {
            decode_exe.insert(ctx, compile(&format!("decode_ctx{ctx}"))?);
        }
        log::info!(
            "runtime loaded: {} prefill + {} decode executables, {:.1}M params",
            prefill_exe.len(),
            decode_exe.len(),
            meta.param_count as f64 / 1e6
        );
        Ok(ModelRuntime {
            client,
            meta,
            weights,
            prefill_exe,
            decode_exe,
            counters: Mutex::new(RuntimeCounters::default()),
        })
    }

    fn upload_f32(&self, data: &[f32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        self.counters.lock().unwrap().bytes_uploaded += 4 * data.len() as u64;
        self.client
            .buffer_from_host_buffer::<f32>(data, dims, None)
            .map_err(|e| anyhow!("upload f32: {e:?}"))
    }

    fn upload_i32(&self, data: &[i32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_buffer::<i32>(data, dims, None)
            .map_err(|e| anyhow!("upload i32: {e:?}"))
    }

    /// Run prefill for `tokens` (new tokens only) against an optional
    /// cached prefix. `cache_kv` is f32[L,2,C,H,hd] flattened for the
    /// chosen bucket's capacity C; `cache_len` tokens of it are valid.
    pub fn prefill(
        &self,
        tokens: &[u32],
        cache_kv: Option<&[f32]>,
        cache_len: usize,
    ) -> Result<PrefillOutput> {
        let t0 = std::time::Instant::now();
        let new_len = tokens.len();
        let (n, c) = self
            .meta
            .pick_prefill_bucket(new_len, cache_len)
            .with_context(|| {
                format!("no prefill bucket for new={new_len} cached={cache_len}")
            })?;
        let exe = &self.prefill_exe[&(n, c)];

        // Build argument buffers: weights then per-call args.
        let mut toks = vec![0i32; n];
        for (i, &t) in tokens.iter().enumerate() {
            toks[i] = t as i32;
        }
        let tok_buf = self.upload_i32(&toks, &[n])?;
        let newlen_buf = self.upload_i32(&[new_len as i32], &[])?;
        let cachelen_buf = self.upload_i32(&[cache_len as i32], &[])?;

        let mut args: Vec<&xla::PjRtBuffer> = self.weights.iter().collect();
        args.push(&tok_buf);
        args.push(&newlen_buf);
        args.push(&cachelen_buf);
        let kv_buf;
        if c > 0 {
            let kv = cache_kv.context("bucket expects cache_kv")?;
            let dims = [
                self.meta.layers,
                2,
                c,
                self.meta.n_heads,
                self.meta.head_dim,
            ];
            let expect: usize = dims.iter().product();
            if kv.len() != expect {
                bail!("cache_kv len {} != {expect}", kv.len());
            }
            kv_buf = self.upload_f32(kv, &dims)?;
            args.push(&kv_buf);
        }

        let result = exe
            .execute_b(&args)
            .map_err(|e| anyhow!("prefill execute: {e:?}"))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("prefill download: {e:?}"))?;
        let (kv_lit, logits_lit) = lit
            .to_tuple2()
            .map_err(|e| anyhow!("prefill untuple: {e:?}"))?;
        let new_kv = kv_lit
            .to_vec::<f32>()
            .map_err(|e| anyhow!("kv to_vec: {e:?}"))?;
        let logits = logits_lit
            .to_vec::<f32>()
            .map_err(|e| anyhow!("logits to_vec: {e:?}"))?;
        let mut ctr = self.counters.lock().unwrap();
        ctr.prefill_calls += 1;
        ctr.prefill_seconds += t0.elapsed().as_secs_f64();
        ctr.bytes_downloaded += 4 * (new_kv.len() + logits.len()) as u64;
        Ok(PrefillOutput {
            new_kv,
            bucket_n: n,
            logits,
        })
    }

    /// Start a decode session: upload a KV snapshot (f32[L,2,ctx,H,hd]
    /// flattened, first `valid_len` token slots meaningful) into a flat
    /// state buffer.
    pub fn decode_start(&self, kv: &[f32], ctx: usize, valid_len: usize)
                        -> Result<DecodeSession> {
        if !self.decode_exe.contains_key(&ctx) {
            bail!("no decode executable for ctx {ctx}");
        }
        let state_len = self.meta.state_len(ctx);
        let kv_len = state_len - self.meta.vocab;
        if kv.len() != kv_len {
            bail!("kv len {} != {kv_len} for ctx {ctx}", kv.len());
        }
        let mut state = vec![0f32; state_len];
        state[self.meta.vocab..].copy_from_slice(kv);
        let buf = self.upload_f32(&state, &[state_len])?;
        Ok(DecodeSession {
            state: buf,
            ctx,
            pos: valid_len,
            steps: 0,
            scratch: vec![0f32; state_len],
        })
    }

    /// One decode step: feed `token` at the session's position; returns
    /// the logits for the next token. O(vocab) host traffic only.
    pub fn decode_step(&self, sess: &mut DecodeSession, token: u32)
                       -> Result<Vec<f32>> {
        let t0 = std::time::Instant::now();
        if sess.pos >= sess.ctx {
            bail!("decode session full: pos {} >= ctx {}", sess.pos, sess.ctx);
        }
        let exe = &self.decode_exe[&sess.ctx];
        let tok_buf = self.upload_i32(&[token as i32], &[1])?;
        let pos_buf = self.upload_i32(&[sess.pos as i32], &[])?;
        let mut args: Vec<&xla::PjRtBuffer> = self.weights.iter().collect();
        args.push(&tok_buf);
        args.push(&pos_buf);
        args.push(&sess.state);
        let mut result = exe
            .execute_b(&args)
            .map_err(|e| anyhow!("decode execute: {e:?}"))?;
        // Single (non-tuple) output: becomes the next state.
        sess.state = result
            .pop()
            .and_then(|mut r| if r.is_empty() { None } else { Some(r.remove(0)) })
            .context("decode returned no buffer")?;
        sess.pos += 1;
        sess.steps += 1;
        // xla_extension 0.5.1's CPU client does not implement
        // CopyRawToHost, so the whole state literal is downloaded and the
        // logits region sliced out (KV still never re-uploads: the state
        // buffer feeds back on device).
        self.download_state(sess)?;
        let logits = sess.scratch[..self.meta.vocab].to_vec();
        let mut ctr = self.counters.lock().unwrap();
        ctr.decode_steps += 1;
        ctr.decode_seconds += t0.elapsed().as_secs_f64();
        ctr.bytes_downloaded += 4 * self.meta.state_len(sess.ctx) as u64;
        Ok(logits)
    }

    /// Download the state into the session's scratch buffer (one copy,
    /// no allocation — the reused staging buffer is the §Perf fix for
    /// the missing CopyRawToHost in xla_extension 0.5.1).
    fn download_state(&self, sess: &mut DecodeSession) -> Result<()> {
        let lit = sess
            .state
            .to_literal_sync()
            .map_err(|e| anyhow!("state download: {e:?}"))?;
        lit.copy_raw_to::<f32>(&mut sess.scratch)
            .map_err(|e| anyhow!("state copy: {e:?}"))
    }

    /// Download the session's KV region (f32[L,2,ctx,H,hd] flattened) —
    /// used at retire time (active KV -> MemPool historical KV).
    pub fn decode_kv(&self, sess: &mut DecodeSession) -> Result<Vec<f32>> {
        self.download_state(sess)?;
        self.counters.lock().unwrap().bytes_downloaded +=
            4 * sess.scratch.len() as u64;
        Ok(sess.scratch[self.meta.vocab..].to_vec())
    }

    /// Grow a session to a larger context bucket (KV round-trips through
    /// the host; rare — happens at bucket boundaries only).
    pub fn decode_grow(&self, mut sess: DecodeSession, new_ctx: usize)
                       -> Result<DecodeSession> {
        if new_ctx <= sess.ctx {
            return Ok(sess);
        }
        let old_kv = self.decode_kv(&mut sess)?;
        let per_slot = self.meta.n_heads * self.meta.head_dim;
        let old_ctx = sess.ctx;
        let kv_len_new =
            self.meta.layers * 2 * new_ctx * per_slot;
        let mut kv = vec![0f32; kv_len_new];
        // Re-stride [L,2,old_ctx,H,hd] -> [L,2,new_ctx,H,hd].
        for l in 0..self.meta.layers {
            for h in 0..2 {
                let src = (l * 2 + h) * old_ctx * per_slot;
                let dst = (l * 2 + h) * new_ctx * per_slot;
                kv[dst..dst + old_ctx * per_slot]
                    .copy_from_slice(&old_kv[src..src + old_ctx * per_slot]);
            }
        }
        self.decode_start(&kv, new_ctx, sess.pos)
    }

    pub fn snapshot_counters(&self) -> RuntimeCounters {
        self.counters.lock().unwrap().clone()
    }
}

#[cfg(test)]
mod tests {
    //! Integration tests against the real artifacts; self-skip when
    //! `make artifacts` has not run.
    use super::*;
    use crate::runtime::artifacts::artifacts_available;
    use once_cell::sync::Lazy;

    static RT: Lazy<Option<ModelRuntime>> = Lazy::new(|| {
        if !artifacts_available("artifacts") {
            eprintln!("[skip] artifacts/ not built");
            return None;
        }
        Some(ModelRuntime::load("artifacts").expect("runtime load"))
    });

    fn rt() -> Option<&'static ModelRuntime> {
        RT.as_ref()
    }

    fn toks(n: usize, seed: u32) -> Vec<u32> {
        (0..n as u32)
            .map(|i| (i.wrapping_mul(2654435761).wrapping_add(seed)) % 2048)
            .collect()
    }

    fn argmax(xs: &[f32]) -> usize {
        xs.iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0
    }

    #[test]
    fn prefill_runs_and_is_deterministic() {
        let Some(rt) = rt() else { return };
        let t = toks(20, 1);
        let a = rt.prefill(&t, None, 0).unwrap();
        let b = rt.prefill(&t, None, 0).unwrap();
        assert_eq!(a.logits, b.logits);
        assert_eq!(a.new_kv, b.new_kv);
        assert_eq!(a.logits.len(), 2048);
        assert!(a.logits.iter().all(|x| x.is_finite()));
        assert_eq!(a.bucket_n, 32);
    }

    #[test]
    fn bucket_padding_invariance() {
        let Some(rt) = rt() else { return };
        // 20 tokens fit the N=32 bucket; forcing N=64 via longer padding
        // is not exposed, but 33 tokens -> N=64. Instead: same prompt via
        // different cache splits must agree (tests bucket C too).
        let t = toks(40, 2);
        let full = rt.prefill(&t, None, 0).unwrap();

        // Split: prefill 32, then 8 with cache_len=32 in the C=256 bucket.
        let part = rt.prefill(&t[..32], None, 0).unwrap();
        let meta = &rt.meta;
        let per_slot = meta.n_heads * meta.head_dim;
        let c = 256;
        let mut cache = vec![0f32; meta.layers * 2 * c * per_slot];
        // part.new_kv is [L,2,N,H,hd] with N = part.bucket_n.
        let n = part.bucket_n;
        for l in 0..meta.layers {
            for h in 0..2 {
                for tkn in 0..32 {
                    let src = ((l * 2 + h) * n + tkn) * per_slot;
                    let dst = ((l * 2 + h) * c + tkn) * per_slot;
                    cache[dst..dst + per_slot]
                        .copy_from_slice(&part.new_kv[src..src + per_slot]);
                }
            }
        }
        let cached = rt.prefill(&t[32..], Some(&cache), 32).unwrap();
        let max_err: f32 = full
            .logits
            .iter()
            .zip(&cached.logits)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max);
        assert!(max_err < 1e-3, "cached prefill diverged: {max_err}");
    }

    #[test]
    fn decode_continues_prefill() {
        let Some(rt) = rt() else { return };
        let t = toks(24, 3);
        // Full prefill of 24 tokens.
        let full = rt.prefill(&t, None, 0).unwrap();
        // Prefill 23, then decode token 24.
        let part = rt.prefill(&t[..23], None, 0).unwrap();
        let meta = &rt.meta;
        let per_slot = meta.n_heads * meta.head_dim;
        let ctx = 64;
        let n = part.bucket_n;
        let mut kv = vec![0f32; meta.layers * 2 * ctx * per_slot];
        for l in 0..meta.layers {
            for h in 0..2 {
                for tkn in 0..23 {
                    let src = ((l * 2 + h) * n + tkn) * per_slot;
                    let dst = ((l * 2 + h) * ctx + tkn) * per_slot;
                    kv[dst..dst + per_slot]
                        .copy_from_slice(&part.new_kv[src..src + per_slot]);
                }
            }
        }
        let mut sess = rt.decode_start(&kv, ctx, 23).unwrap();
        let logits = rt.decode_step(&mut sess, t[23]).unwrap();
        assert_eq!(argmax(&logits), argmax(&full.logits));
        let max_err: f32 = logits
            .iter()
            .zip(&full.logits)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max);
        assert!(max_err < 1e-3, "decode diverged: {max_err}");
        assert_eq!(sess.pos, 24);
    }

    #[test]
    fn decode_session_chains_steps() {
        let Some(rt) = rt() else { return };
        let t = toks(16, 4);
        let p = rt.prefill(&t, None, 0).unwrap();
        let meta = &rt.meta;
        let per_slot = meta.n_heads * meta.head_dim;
        let ctx = 64;
        let n = p.bucket_n;
        let mut kv = vec![0f32; meta.layers * 2 * ctx * per_slot];
        for l in 0..meta.layers {
            for h in 0..2 {
                for tkn in 0..16 {
                    let src = ((l * 2 + h) * n + tkn) * per_slot;
                    let dst = ((l * 2 + h) * ctx + tkn) * per_slot;
                    kv[dst..dst + per_slot]
                        .copy_from_slice(&p.new_kv[src..src + per_slot]);
                }
            }
        }
        let mut sess = rt.decode_start(&kv, ctx, 16).unwrap();
        let mut tok = argmax(&p.logits) as u32;
        let mut seq = vec![];
        for _ in 0..10 {
            let logits = rt.decode_step(&mut sess, tok).unwrap();
            tok = argmax(&logits) as u32;
            seq.push(tok);
        }
        assert_eq!(sess.pos, 26);
        // Greedy decode must be reproducible.
        let mut sess2 = rt.decode_start(&kv, ctx, 16).unwrap();
        let mut tok2 = argmax(&p.logits) as u32;
        let mut seq2 = vec![];
        for _ in 0..10 {
            let logits = rt.decode_step(&mut sess2, tok2).unwrap();
            tok2 = argmax(&logits) as u32;
            seq2.push(tok2);
        }
        assert_eq!(seq, seq2);
    }

    #[test]
    fn decode_grow_preserves_history() {
        let Some(rt) = rt() else { return };
        let t = toks(16, 5);
        let p = rt.prefill(&t, None, 0).unwrap();
        let meta = &rt.meta;
        let per_slot = meta.n_heads * meta.head_dim;
        let n = p.bucket_n;
        let build = |ctx: usize| {
            let mut kv = vec![0f32; meta.layers * 2 * ctx * per_slot];
            for l in 0..meta.layers {
                for h in 0..2 {
                    for tkn in 0..16 {
                        let src = ((l * 2 + h) * n + tkn) * per_slot;
                        let dst = ((l * 2 + h) * ctx + tkn) * per_slot;
                        kv[dst..dst + per_slot]
                            .copy_from_slice(&p.new_kv[src..src + per_slot]);
                    }
                }
            }
            kv
        };
        // Path A: ctx=64 directly.
        let mut sa = rt.decode_start(&build(64), 64, 16).unwrap();
        let la = rt.decode_step(&mut sa, t[0]).unwrap();
        // Path B: ctx=... grow 64->128 then same step.
        let sb0 = rt.decode_start(&build(64), 64, 16).unwrap();
        let mut sb = rt.decode_grow(sb0, 128).unwrap();
        assert_eq!(sb.ctx, 128);
        assert_eq!(sb.pos, 16);
        let lb = rt.decode_step(&mut sb, t[0]).unwrap();
        let max_err: f32 = la
            .iter()
            .zip(&lb)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max);
        assert!(max_err < 1e-3, "grow diverged: {max_err}");
    }

    #[test]
    fn counters_accumulate() {
        let Some(rt) = rt() else { return };
        let before = rt.snapshot_counters();
        let _ = rt.prefill(&toks(10, 6), None, 0).unwrap();
        let after = rt.snapshot_counters();
        assert!(after.prefill_calls > before.prefill_calls);
        assert!(after.bytes_downloaded > before.bytes_downloaded);
    }
}
