//! Substrate utilities built in-tree because the build environment is
//! fully offline (no tokio / serde / clap / rand / criterion / proptest).
//!
//! Everything here is deliberately small, dependency-free, and unit-tested:
//! * [`rng`]    — deterministic SplitMix64 / xoshiro256** PRNG + distributions
//! * [`stats`]  — streaming summaries, exact percentiles, histograms
//! * [`json`]   — minimal JSON parser + writer (for `artifacts/meta.json`
//!   and machine-readable bench output)
//! * [`args`]   — a tiny declarative CLI argument parser
//! * [`heap`]   — the lazy-deletion heap compaction policy shared by the
//!   MemPool LRU heap and the fused tree's TTL heap
//! * [`proptest`] — randomized property-testing harness with shrinking-lite
//! * [`bench`]  — the hand-rolled benchmark harness used by `cargo bench`
//! * [`logging`] — a `log`-crate backend writing to stderr with levels
//! * [`sync`]   — the loom-swappable synchronization shim + poison-
//!   recovering lock traits + the `EpochGate` fence (ISSUE 10)
//! * [`clock`]  — the single wall-clock primitive archlint R1 allows

pub mod args;
pub mod bench;
pub mod clock;
pub mod heap;
pub mod json;
pub mod logging;
pub mod proptest;
pub mod rng;
pub mod stats;
pub mod sync;
