//! Minimal `log`-crate backend: stderr, level filter from
//! `MEMSERVE_LOG` (error|warn|info|debug|trace), monotonic timestamps.

use std::io::Write;
use std::sync::atomic::{AtomicBool, Ordering};
use std::time::Instant;

use once_cell::sync::Lazy;

static START: Lazy<Instant> = Lazy::new(Instant::now);
static INSTALLED: AtomicBool = AtomicBool::new(false);

struct StderrLogger {
    level: log::LevelFilter,
}

impl log::Log for StderrLogger {
    fn enabled(&self, metadata: &log::Metadata) -> bool {
        metadata.level() <= self.level
    }

    fn log(&self, record: &log::Record) {
        if !self.enabled(record.metadata()) {
            return;
        }
        let t = START.elapsed().as_secs_f64();
        let mut err = std::io::stderr().lock();
        let _ = writeln!(
            err,
            "[{t:10.4}s {:5} {}] {}",
            record.level(),
            record.target().split("::").last().unwrap_or(""),
            record.args()
        );
    }

    fn flush(&self) {}
}

/// Install the logger once; later calls are no-ops. Level from
/// `MEMSERVE_LOG` env var, default `info`.
pub fn init() {
    // ordering: SeqCst — once-only install flag on a cold path; the
    // strongest order keeps the single-winner guarantee obvious.
    if INSTALLED.swap(true, Ordering::SeqCst) {
        return;
    }
    let level = match std::env::var("MEMSERVE_LOG")
        .unwrap_or_default()
        .to_lowercase()
        .as_str()
    {
        "error" => log::LevelFilter::Error,
        "warn" => log::LevelFilter::Warn,
        "debug" => log::LevelFilter::Debug,
        "trace" => log::LevelFilter::Trace,
        "off" => log::LevelFilter::Off,
        _ => log::LevelFilter::Info,
    };
    let _ = log::set_boxed_logger(Box::new(StderrLogger { level }));
    log::set_max_level(level);
}

#[cfg(test)]
mod tests {
    #[test]
    fn init_is_idempotent() {
        super::init();
        super::init();
        log::info!("logging smoke test");
    }
}
