//! Deterministic PRNG (xoshiro256** seeded via SplitMix64) plus the
//! distributions the workload generators need (uniform, exponential for
//! Poisson arrivals, zipf for prefix popularity, normal via Box–Muller).
//!
//! Determinism matters: every workload, every property test, and every
//! simulation sweep is reproducible from a single `u64` seed that benches
//! print alongside their results.

/// SplitMix64: used to expand a seed into xoshiro state and as a cheap
/// standalone generator for hashing-style use.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// xoshiro256** — fast, high-quality, 256-bit state.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut s = [0u64; 4];
        for slot in &mut s {
            *slot = splitmix64(&mut sm);
        }
        // Avoid the all-zero state (probability ~0 but cheap to guard).
        if s == [0, 0, 0, 0] {
            s[0] = 1;
        }
        Rng { s }
    }

    /// Derive an independent stream (e.g. per-session, per-instance).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)` with 53-bit precision.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in `[0, n)`. Uses Lemire's multiply-shift reduction
    /// (bias negligible for n << 2^64; fine for workload generation).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform integer in `[lo, hi]` inclusive.
    #[inline]
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        debug_assert!(lo <= hi);
        lo + self.below(hi - lo + 1)
    }

    /// Uniform f64 in `[lo, hi)`.
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Bernoulli trial.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Exponential with rate `lambda` (mean `1/lambda`) — Poisson
    /// inter-arrival gaps.
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        debug_assert!(lambda > 0.0);
        let u = 1.0 - self.f64(); // (0, 1]
        -u.ln() / lambda
    }

    /// Standard normal via Box–Muller (one value per call; simple > fast).
    pub fn normal(&mut self) -> f64 {
        let u1 = 1.0 - self.f64();
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Log-normal with given location/scale of the underlying normal.
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    /// Zipf-distributed rank in `[0, n)` with exponent `s` (rejection-free
    /// inverse-CDF over a precomputed table is overkill; harmonic-sum
    /// inversion by binary search on the fly is O(log n) via pow).
    /// Uses the standard rejection sampler (Devroye) — O(1) expected.
    pub fn zipf(&mut self, n: u64, s: f64) -> u64 {
        debug_assert!(n > 0);
        if n == 1 {
            return 0;
        }
        if s <= 0.0 {
            return self.below(n);
        }
        // Devroye's rejection method for Zipf(s) truncated to [1, n].
        let nf = n as f64;
        loop {
            let u = self.f64();
            let v = self.f64();
            let x = if (s - 1.0).abs() < 1e-9 {
                nf.powf(u)
            } else {
                let t = nf.powf(1.0 - s);
                (1.0 - u * (1.0 - t)).powf(1.0 / (1.0 - s))
            };
            let k = x.floor().max(1.0).min(nf);
            let ratio = (k / x).powf(s) * (x / k).min(1.0);
            if v * ratio <= 1.0 {
                return k as u64 - 1;
            }
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Pick a uniformly random element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }
}

/// A seed-free, process-stable hasher for decision-path maps (ISSUE 10,
/// archlint R2). `HashMap::new()` defaults to `RandomState`, whose
/// per-process random keys make *iteration order* differ run to run —
/// any decision that walks such a map (tie-breaks, fan-out order)
/// silently breaks `deterministic_replay`. `DetMap`/`DetSet` swap in a
/// SplitMix64-finalized hasher with a fixed key: same insertion
/// history, same iteration order, every run.
#[derive(Default, Clone)]
pub struct DetHasher {
    state: u64,
}

impl std::hash::Hasher for DetHasher {
    #[inline]
    fn write(&mut self, bytes: &[u8]) {
        for &b in bytes {
            // FNV-style absorb, SplitMix64 finish: cheap, well-mixed,
            // and keyed by a constant instead of RandomState.
            self.state = (self.state ^ b as u64)
                .wrapping_mul(0x100_0000_01B3);
        }
    }

    #[inline]
    fn write_u64(&mut self, v: u64) {
        self.state = self.state.rotate_left(29) ^ v;
        self.state = self.state.wrapping_mul(0x9E37_79B9_7F4A_7C15);
    }

    #[inline]
    fn write_u32(&mut self, v: u32) {
        self.write_u64(v as u64);
    }

    #[inline]
    fn write_usize(&mut self, v: usize) {
        self.write_u64(v as u64);
    }

    #[inline]
    fn finish(&self) -> u64 {
        let mut s = self.state;
        splitmix64(&mut s)
    }
}

/// `HashMap` with deterministic (seed-free) hashing — the R2-sanctioned
/// map for scheduler/elastic/replica/sim decision paths.
pub type DetMap<K, V> =
    std::collections::HashMap<K, V, std::hash::BuildHasherDefault<DetHasher>>;

/// `HashSet` twin of [`DetMap`].
pub type DetSet<K> =
    std::collections::HashSet<K, std::hash::BuildHasherDefault<DetHasher>>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds_and_covers() {
        let mut r = Rng::new(3);
        let mut seen = [false; 10];
        for _ in 0..10_000 {
            let v = r.below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn exponential_mean_close() {
        let mut r = Rng::new(11);
        let lambda = 4.0;
        let n = 100_000;
        let sum: f64 = (0..n).map(|_| r.exponential(lambda)).sum();
        let mean = sum / n as f64;
        assert!((mean - 0.25).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(13);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn zipf_is_skewed_and_bounded() {
        let mut r = Rng::new(17);
        let n = 1000u64;
        let mut counts = vec![0u64; n as usize];
        for _ in 0..50_000 {
            let k = r.zipf(n, 1.1);
            assert!(k < n);
            counts[k as usize] += 1;
        }
        // Rank 0 must dominate the tail by a wide margin.
        assert!(counts[0] > 20 * counts[500].max(1));
    }

    #[test]
    fn zipf_s_zero_is_uniformish() {
        let mut r = Rng::new(19);
        let mut lo = 0u64;
        for _ in 0..10_000 {
            if r.zipf(100, 0.0) < 50 {
                lo += 1;
            }
        }
        assert!((4000..6000).contains(&lo), "lo={lo}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(23);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut base = Rng::new(5);
        let mut a = base.fork(1);
        let mut b = base.fork(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn det_map_iteration_order_is_reproducible() {
        let build = || {
            let mut m: DetMap<u64, u32> = DetMap::default();
            for i in 0..512u64 {
                m.insert(i.wrapping_mul(0x9E37_79B9), i as u32);
            }
            m.into_iter().collect::<Vec<_>>()
        };
        // Same insertion history ⇒ same iteration order, unlike
        // RandomState maps whose order varies per process.
        assert_eq!(build(), build());
    }

    #[test]
    fn det_set_spreads_keys() {
        // Sanity: the hasher isn't degenerate — sequential keys don't
        // all collide into a handful of buckets (lookup stays O(1)).
        let mut s: DetSet<u64> = DetSet::default();
        for i in 0..10_000u64 {
            s.insert(i);
        }
        assert_eq!(s.len(), 10_000);
        assert!(s.contains(&9_999));
        assert!(!s.contains(&10_000));
    }
}
