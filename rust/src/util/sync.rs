//! Synchronization shim for the lock-free data plane (ISSUE 10).
//!
//! The hot paths that PR 7 made lock-free — the relaxed-atomic
//! `last_access` stamps and deferred-touch queue in `mempool/index.rs`,
//! the epoch fence in `scheduler/data_plane.rs`, the relaxed metric
//! registry in `obs/registry.rs` — import their primitives from here
//! instead of `std::sync`, so a `RUSTFLAGS="--cfg loom"` build swaps in
//! loom's model-checked equivalents without touching any call site.
//! Under the normal build these re-exports *are* the `std` types; the
//! shim costs nothing.
//!
//! Also lives here:
//! * [`LockExt`] / [`RwLockExt`] — poison-recovering lock acquisition
//!   (`plock`/`pread`/`pwrite`). A poisoned mutex means some thread
//!   panicked while holding the guard; for our state (metric counters,
//!   delta logs, fault tables) the data is still structurally sound, so
//!   every protocol path prefers recovering the guard over unwinding
//!   the whole process. archlint R5 bans `.lock().unwrap()` in
//!   server/replica/net code; these are the sanctioned replacement.
//! * [`EpochGate`] — the extracted AckBoard epoch fence, small enough
//!   to model-check directly (see `loom_tests` below).

#[cfg(loom)]
pub use loom::sync::atomic::{
    AtomicBool, AtomicU32, AtomicU64, AtomicUsize, Ordering,
};
#[cfg(loom)]
pub use loom::sync::{
    Condvar, Mutex, MutexGuard, RwLock, RwLockReadGuard, RwLockWriteGuard,
};

#[cfg(not(loom))]
pub use std::sync::atomic::{
    AtomicBool, AtomicU32, AtomicU64, AtomicUsize, Ordering,
};
#[cfg(not(loom))]
pub use std::sync::{
    Condvar, Mutex, MutexGuard, RwLock, RwLockReadGuard, RwLockWriteGuard,
};

use std::sync::PoisonError;

/// Unsynchronized access to an atomic through `&mut` — `get_mut` on
/// std, `with_mut` under loom (loom atomics have no `get_mut`). The
/// exclusive borrow *is* the synchronization; callers state why in an
/// `// ordering:` comment at the use site.
#[cfg(not(loom))]
pub fn with_mut_u64<R>(a: &mut AtomicU64, f: impl FnOnce(&mut u64) -> R) -> R {
    f(a.get_mut())
}

#[cfg(loom)]
pub fn with_mut_u64<R>(a: &mut AtomicU64, f: impl FnOnce(&mut u64) -> R) -> R {
    a.with_mut(f)
}

/// [`with_mut_u64`] for `AtomicUsize`.
#[cfg(not(loom))]
pub fn with_mut_usize<R>(
    a: &mut AtomicUsize,
    f: impl FnOnce(&mut usize) -> R,
) -> R {
    f(a.get_mut())
}

#[cfg(loom)]
pub fn with_mut_usize<R>(
    a: &mut AtomicUsize,
    f: impl FnOnce(&mut usize) -> R,
) -> R {
    a.with_mut(f)
}

/// Poison-recovering `Mutex` acquisition. See module docs for why
/// recovery (not unwinding) is the right default in protocol paths.
///
/// Implemented for `std::sync::Mutex` by name — NOT the shim alias —
/// so every call site that imports the std mutex directly (most of
/// server/ and net/) still compiles in a loom build. Loom-side code
/// (only [`EpochGate`] here) recovers inline instead.
pub trait LockExt<T: ?Sized> {
    /// `lock()`, recovering the guard from a poisoned mutex.
    fn plock(&self) -> std::sync::MutexGuard<'_, T>;
}

impl<T: ?Sized> LockExt<T> for std::sync::Mutex<T> {
    fn plock(&self) -> std::sync::MutexGuard<'_, T> {
        self.lock().unwrap_or_else(PoisonError::into_inner)
    }
}

/// Poison-recovering `RwLock` acquisition (read and write sides).
pub trait RwLockExt<T: ?Sized> {
    fn pread(&self) -> std::sync::RwLockReadGuard<'_, T>;
    fn pwrite(&self) -> std::sync::RwLockWriteGuard<'_, T>;
}

impl<T: ?Sized> RwLockExt<T> for std::sync::RwLock<T> {
    fn pread(&self) -> std::sync::RwLockReadGuard<'_, T> {
        self.read().unwrap_or_else(PoisonError::into_inner)
    }
    fn pwrite(&self) -> std::sync::RwLockWriteGuard<'_, T> {
        self.write().unwrap_or_else(PoisonError::into_inner)
    }
}

/// Epoch fence: one monotonically-advancing ack slot per participant,
/// plus a waiter that blocks until every slot has reached an epoch.
///
/// This is the `ShardWorkerPool` broadcast fence (PR 7) factored out so
/// loom can model it in isolation: the property that matters is that
/// any write a worker performs *before* `ack(slot, e)` happens-before a
/// waiter's reads *after* `wait(e)` returns — i.e. a routed read can
/// never observe a pre-broadcast membership view. The mutex/condvar
/// pair provides that edge; `loom_tests::loom_epoch_gate_fences_pre_ack_writes`
/// proves it under exhaustive interleavings.
pub struct EpochGate {
    acked: Mutex<Vec<u64>>,
    cv: Condvar,
}

impl EpochGate {
    pub fn new(slots: usize) -> Self {
        EpochGate {
            acked: Mutex::new(vec![0; slots]),
            cv: Condvar::new(),
        }
    }

    /// Record that participant `slot` has applied everything up to
    /// `epoch`. Out-of-range slots are ignored (the gate is sized once
    /// at pool construction; a stale ack from a dead worker is inert).
    pub fn ack(&self, slot: usize, epoch: u64) {
        let mut a = self.acked.lock().unwrap_or_else(PoisonError::into_inner);
        if let Some(e) = a.get_mut(slot) {
            *e = (*e).max(epoch);
        }
        self.cv.notify_all();
    }

    /// Block until every slot has acked `epoch` (or beyond).
    pub fn wait(&self, epoch: u64) {
        let mut a = self.acked.lock().unwrap_or_else(PoisonError::into_inner);
        while a.iter().any(|&e| e < epoch) {
            a = self
                .cv
                .wait(a)
                .unwrap_or_else(PoisonError::into_inner);
        }
    }

    /// The slowest participant's acked epoch (diagnostics).
    pub fn min_acked(&self) -> u64 {
        self.acked
            .lock()
            .unwrap_or_else(PoisonError::into_inner)
            .iter()
            .copied()
            .min()
            .unwrap_or(0)
    }
}

#[cfg(all(test, not(loom)))]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn plock_recovers_a_poisoned_mutex() {
        let m = Arc::new(Mutex::new(41u32));
        let m2 = Arc::clone(&m);
        let _ = std::thread::spawn(move || {
            let _g = m2.plock();
            panic!("poison the mutex");
        })
        .join();
        // std::sync::Mutex is now poisoned; plock still yields the
        // guard and the data is intact.
        let mut g = m.plock();
        assert_eq!(*g, 41);
        *g += 1;
        drop(g);
        assert_eq!(*m.plock(), 42);
    }

    #[test]
    fn pread_pwrite_recover_a_poisoned_rwlock() {
        let l = Arc::new(RwLock::new(7u32));
        let l2 = Arc::clone(&l);
        let _ = std::thread::spawn(move || {
            let _g = l2.pwrite();
            panic!("poison the rwlock");
        })
        .join();
        assert_eq!(*l.pread(), 7);
        *l.pwrite() = 8;
        assert_eq!(*l.pread(), 8);
    }

    #[test]
    fn epoch_gate_blocks_until_every_slot_acks() {
        let gate = Arc::new(EpochGate::new(3));
        let done = Arc::new(AtomicBool::new(false));
        let waiter = {
            let gate = Arc::clone(&gate);
            let done = Arc::clone(&done);
            std::thread::spawn(move || {
                gate.wait(2);
                // ordering: Relaxed — the gate's mutex already orders
                // this store after every pre-ack write; the flag is a
                // test-side completion marker only.
                done.store(true, Ordering::Relaxed);
            })
        };
        for slot in 0..3 {
            assert!(!done.load(Ordering::Relaxed), "woke before slot {slot}");
            gate.ack(slot, 2);
        }
        waiter.join().expect("waiter thread");
        assert!(done.load(Ordering::Relaxed));
        assert_eq!(gate.min_acked(), 2);
    }

    #[test]
    fn epoch_gate_acks_are_monotonic_and_bounds_checked() {
        let gate = EpochGate::new(2);
        gate.ack(0, 5);
        gate.ack(0, 3); // stale ack must not regress the slot
        gate.ack(7, 9); // out-of-range slot is inert
        gate.ack(1, 5);
        gate.wait(5); // returns immediately: both slots at 5
        assert_eq!(gate.min_acked(), 5);
    }
}

/// Loom models (run via `RUSTFLAGS="--cfg loom" cargo test --release
/// --lib loom_`). Kept small: loom explores every interleaving, so one
/// extra thread multiplies the state space.
#[cfg(all(test, loom))]
mod loom_tests {
    use super::*;
    use loom::sync::Arc;
    use loom::thread;

    /// The AckBoard/EpochGate fence property (ISSUE 10): a membership
    /// write a worker makes *before* acking the epoch must be visible
    /// to the waiter *after* `wait` returns — a routed read can never
    /// observe a pre-broadcast membership view. The per-shard view bit
    /// is deliberately Relaxed: the gate's mutex/condvar pair is the
    /// only thing publishing it, which is exactly what this model pins.
    #[test]
    fn loom_epoch_gate_fences_pre_ack_writes() {
        loom::model(|| {
            let gate = Arc::new(EpochGate::new(2));
            let view = Arc::new(AtomicU64::new(0));
            let mut joins = vec![];
            for k in 0..2u64 {
                let gate = Arc::clone(&gate);
                let view = Arc::clone(&view);
                joins.push(thread::spawn(move || {
                    // ordering: Relaxed — published by the gate's ack
                    // (mutex release); this model proves that edge.
                    view.fetch_or(1 << k, Ordering::Relaxed);
                    gate.ack(k as usize, 1);
                }));
            }
            gate.wait(1);
            // ordering: Relaxed — the acquire edge came from wait().
            assert_eq!(
                view.load(Ordering::Relaxed),
                0b11,
                "waiter observed a pre-broadcast membership view"
            );
            for j in joins {
                j.join().expect("loom worker");
            }
        });
    }

    /// Concurrent acks on the same slot keep it monotonic (the `max`
    /// in `ack`): a stale ack racing a fresh one can never regress
    /// what a waiter already observed.
    #[test]
    fn loom_epoch_gate_acks_never_regress() {
        loom::model(|| {
            let gate = Arc::new(EpochGate::new(1));
            let t = {
                let gate = Arc::clone(&gate);
                thread::spawn(move || gate.ack(0, 1))
            };
            gate.ack(0, 2);
            t.join().expect("loom acker");
            assert_eq!(gate.min_acked(), 2, "stale ack regressed the slot");
        });
    }
}
