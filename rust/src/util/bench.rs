//! Hand-rolled benchmark harness (criterion is unavailable offline).
//!
//! Two pieces:
//! * [`time_it`] / [`Bench`] — warmup + timed iterations with percentile
//!   reporting, for microbenchmarks (Fig 9/10/11-class).
//! * [`Table`] — paper-style row printer + JSON sink so every bench emits
//!   both a human table and a machine-readable record under
//!   `bench_results/` by default.
//!
//! The JSON sink is controlled by the `MEMSERVE_BENCH_JSON` env var so
//! perf trajectories can be collected across PRs without scraping
//! stdout: unset or `1` writes `bench_results/<name>.json`; `0`/`off`
//! disables the sink; any other value is used as the output directory
//! (e.g. `MEMSERVE_BENCH_JSON=perf_history/pr42`).

use std::time::Instant;

use crate::util::json::Json;
use crate::util::stats::Samples;

/// Time `f` for `iters` iterations after `warmup` unrecorded runs.
/// Returns per-iteration samples in **microseconds**.
pub fn time_it<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> Samples {
    for _ in 0..warmup {
        f();
    }
    let mut s = Samples::new();
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        s.push(t0.elapsed().as_secs_f64() * 1e6);
    }
    s
}

/// Adaptive variant: run for at least `min_total_ms` wall time, at least
/// `min_iters` iterations.
pub fn time_adaptive<F: FnMut()>(min_total_ms: f64, min_iters: usize, mut f: F) -> Samples {
    f(); // warmup
    let mut s = Samples::new();
    let start = Instant::now();
    while s.len() < min_iters || start.elapsed().as_secs_f64() * 1e3 < min_total_ms {
        let t0 = Instant::now();
        f();
        s.push(t0.elapsed().as_secs_f64() * 1e6);
        if s.len() > 2_000_000 {
            break;
        }
    }
    s
}

/// Black-box: prevent the optimizer from deleting a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Paper-style results table with aligned columns + JSON record sink.
pub struct Table {
    name: String,
    columns: Vec<String>,
    rows: Vec<Vec<String>>,
    json_rows: Vec<Json>,
}

impl Table {
    pub fn new(name: &str, columns: &[&str]) -> Self {
        Table {
            name: name.to_string(),
            columns: columns.iter().map(|s| s.to_string()).collect(),
            rows: vec![],
            json_rows: vec![],
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.columns.len(), "row arity mismatch");
        let rec = Json::Obj(
            self.columns
                .iter()
                .zip(&cells)
                .map(|(c, v)| {
                    let j = v
                        .parse::<f64>()
                        .map(Json::Num)
                        .unwrap_or_else(|_| Json::Str(v.clone()));
                    (c.clone(), j)
                })
                .collect(),
        );
        self.json_rows.push(rec);
        self.rows.push(cells);
    }

    /// Print the table to stdout with aligned columns.
    pub fn print(&self) {
        let mut widths: Vec<usize> =
            self.columns.iter().map(|c| c.len()).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate() {
                widths[i] = widths[i].max(cell.len());
            }
        }
        println!("\n== {} ==", self.name);
        let header: Vec<String> = self
            .columns
            .iter()
            .zip(&widths)
            .map(|(c, w)| format!("{c:>w$}"))
            .collect();
        println!("{}", header.join("  "));
        println!("{}", "-".repeat(header.join("  ").len()));
        for row in &self.rows {
            let line: Vec<String> = row
                .iter()
                .zip(&widths)
                .map(|(c, w)| format!("{c:>w$}"))
                .collect();
            println!("{}", line.join("  "));
        }
    }

    /// Write `bench_results/<name>.json` (creates the directory).
    pub fn save_json(&self, dir: &str) -> std::io::Result<String> {
        std::fs::create_dir_all(dir)?;
        let path = format!("{dir}/{}.json", self.name);
        let j = Json::obj(vec![
            ("bench", Json::str(self.name.clone())),
            ("columns", Json::arr(
                self.columns.iter().map(|c| Json::str(c.clone())).collect(),
            )),
            ("rows", Json::Arr(self.json_rows.clone())),
        ]);
        std::fs::write(&path, j.to_string())?;
        Ok(path)
    }

    /// Print + save; the standard bench epilogue. The JSON sink follows
    /// `MEMSERVE_BENCH_JSON` (see module docs).
    pub fn finish(&self) {
        self.print();
        let var = std::env::var("MEMSERVE_BENCH_JSON").ok();
        let Some(dir) = json_sink_dir(var.as_deref()) else {
            return;
        };
        match self.save_json(&dir) {
            Ok(p) => println!("[saved {p}]"),
            Err(e) => eprintln!("[warn] could not save bench json: {e}"),
        }
    }
}

/// Resolve the JSON sink directory from `MEMSERVE_BENCH_JSON`:
/// `None`/`""`/`"1"` → the default `bench_results`; `"0"`/`"off"` →
/// disabled; anything else is the directory itself.
fn json_sink_dir(var: Option<&str>) -> Option<String> {
    match var {
        None | Some("") | Some("1") => Some("bench_results".to_string()),
        Some("0") | Some("off") => None,
        Some(dir) => Some(dir.to_string()),
    }
}

/// The active JSON sink directory resolved from the environment, or
/// `None` when the sink is disabled. Benches use this to drop extra
/// artifacts (trace JSON, flight-recorder dumps) next to their tables.
pub fn bench_json_dir() -> Option<String> {
    let var = std::env::var("MEMSERVE_BENCH_JSON").ok();
    json_sink_dir(var.as_deref())
}

/// Like [`bench_json_dir`], but only when `MEMSERVE_BENCH_JSON` was
/// *explicitly* set. The leader's flight-recorder dump uses this so a
/// unit-test run that trips the failure detector never grows a
/// `bench_results/` directory as a side effect.
pub fn explicit_json_dir() -> Option<String> {
    explicit_sink_dir(std::env::var("MEMSERVE_BENCH_JSON").ok().as_deref())
}

/// The [`explicit_json_dir`] gating contract, pure for testability:
/// an *unset* var is `None` (unlike [`json_sink_dir`], which defaults
/// it on), everything else follows the sink rules.
fn explicit_sink_dir(var: Option<&str>) -> Option<String> {
    json_sink_dir(Some(var?))
}

/// Re-measure attempts for bench overhead gates (fig19/fig20) before
/// a below-floor ratio becomes a hard failure — contended CI runners
/// produce one-off stalls. `MEMSERVE_GATE_ATTEMPTS` overrides the
/// default of 3; values clamp to at least 1.
pub fn gate_attempts() -> usize {
    std::env::var("MEMSERVE_GATE_ATTEMPTS")
        .ok()
        .and_then(|s| s.trim().parse().ok())
        .unwrap_or(3)
        .max(1)
}

/// Format microseconds human-readably.
pub fn fmt_us(us: f64) -> String {
    if us < 1e3 {
        format!("{us:.1}us")
    } else if us < 1e6 {
        format!("{:.2}ms", us / 1e3)
    } else {
        format!("{:.3}s", us / 1e6)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_it_returns_iters_samples() {
        let s = time_it(2, 10, || {
            black_box(1 + 1);
        });
        assert_eq!(s.len(), 10);
        assert!(s.min() >= 0.0);
    }

    #[test]
    fn adaptive_meets_minimums() {
        let s = time_adaptive(1.0, 5, || {
            black_box((0..100).sum::<u64>());
        });
        assert!(s.len() >= 5);
    }

    #[test]
    fn table_roundtrip() {
        let dir = std::env::temp_dir().join("memserve_bench_test");
        let dir = dir.to_str().unwrap();
        let mut t = Table::new("unit_test_table", &["x", "label"]);
        t.row(vec!["1.5".into(), "a".into()]);
        t.row(vec!["2".into(), "b".into()]);
        t.print();
        let path = t.save_json(dir).unwrap();
        let text = std::fs::read_to_string(path).unwrap();
        let j = crate::util::json::Json::parse(&text).unwrap();
        assert_eq!(j.at(&["rows"]).unwrap().as_arr().unwrap().len(), 2);
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn table_rejects_bad_row() {
        let mut t = Table::new("t", &["a", "b"]);
        t.row(vec!["1".into()]);
    }

    #[test]
    fn json_sink_dir_env_contract() {
        assert_eq!(json_sink_dir(None).as_deref(), Some("bench_results"));
        assert_eq!(json_sink_dir(Some("")).as_deref(), Some("bench_results"));
        assert_eq!(json_sink_dir(Some("1")).as_deref(), Some("bench_results"));
        assert_eq!(json_sink_dir(Some("0")), None);
        assert_eq!(json_sink_dir(Some("off")), None);
        assert_eq!(
            json_sink_dir(Some("perf_history/pr42")).as_deref(),
            Some("perf_history/pr42")
        );
    }

    /// ISSUE 9 satellite: the explicit-dump gate — unset stays off
    /// (no `bench_results/` side effect from unit tests), everything
    /// else follows the sink contract.
    #[test]
    fn explicit_sink_dir_gates_on_unset() {
        assert_eq!(explicit_sink_dir(None), None);
        assert_eq!(explicit_sink_dir(Some("0")), None);
        assert_eq!(explicit_sink_dir(Some("off")), None);
        assert_eq!(
            explicit_sink_dir(Some("")).as_deref(),
            Some("bench_results")
        );
        assert_eq!(
            explicit_sink_dir(Some("1")).as_deref(),
            Some("bench_results")
        );
        assert_eq!(
            explicit_sink_dir(Some("artifacts/x")).as_deref(),
            Some("artifacts/x")
        );
    }

    #[test]
    fn fmt_us_scales() {
        assert_eq!(fmt_us(500.0), "500.0us");
        assert_eq!(fmt_us(1500.0), "1.50ms");
        assert_eq!(fmt_us(2_500_000.0), "2.500s");
    }
}
