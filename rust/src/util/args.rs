//! Tiny declarative CLI argument parser (clap is unavailable offline).
//!
//! Supports `--flag`, `--key value`, `--key=value`, positional arguments,
//! repeated `--set k=v` config overrides, and generated `--help` text.

use std::collections::BTreeMap;

#[derive(Debug, Default)]
pub struct Args {
    flags: BTreeMap<String, bool>,
    values: BTreeMap<String, String>,
    sets: Vec<(String, String)>,
    positional: Vec<String>,
}

#[derive(Debug, Clone)]
struct Spec {
    name: String,
    takes_value: bool,
    help: String,
    default: Option<String>,
}

/// Declarative parser: declare options, then `parse()`.
#[derive(Debug, Default)]
pub struct Parser {
    program: String,
    about: String,
    specs: Vec<Spec>,
}

impl Parser {
    pub fn new(program: &str, about: &str) -> Self {
        Parser {
            program: program.to_string(),
            about: about.to_string(),
            specs: vec![],
        }
    }

    pub fn flag(mut self, name: &str, help: &str) -> Self {
        self.specs.push(Spec {
            name: name.to_string(),
            takes_value: false,
            help: help.to_string(),
            default: None,
        });
        self
    }

    pub fn opt(mut self, name: &str, default: &str, help: &str) -> Self {
        self.specs.push(Spec {
            name: name.to_string(),
            takes_value: true,
            help: help.to_string(),
            default: Some(default.to_string()),
        });
        self
    }

    pub fn help_text(&self) -> String {
        let mut s = format!("{} — {}\n\nOptions:\n", self.program, self.about);
        for spec in &self.specs {
            let arg = if spec.takes_value {
                format!("--{} <v>", spec.name)
            } else {
                format!("--{}", spec.name)
            };
            let default = spec
                .default
                .as_ref()
                .map(|d| format!(" [default: {d}]"))
                .unwrap_or_default();
            s.push_str(&format!("  {arg:<24} {}{default}\n", spec.help));
        }
        s.push_str("  --set k=v                override a config key (repeatable)\n");
        s.push_str("  --help                   print this help\n");
        s
    }

    /// Parse a token list. Returns Err(message) on unknown/invalid args;
    /// Err with the help text if `--help` is present.
    pub fn parse(&self, argv: &[String]) -> Result<Args, String> {
        let mut out = Args::default();
        for spec in &self.specs {
            if let Some(d) = &spec.default {
                out.values.insert(spec.name.clone(), d.clone());
            }
        }
        let mut i = 0;
        while i < argv.len() {
            let tok = &argv[i];
            if tok == "--help" || tok == "-h" {
                return Err(self.help_text());
            }
            if let Some(body) = tok.strip_prefix("--") {
                let (name, inline) = match body.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (body.to_string(), None),
                };
                if name == "set" {
                    let v = match inline {
                        Some(v) => v,
                        None => {
                            i += 1;
                            argv.get(i)
                                .cloned()
                                .ok_or("--set requires k=v".to_string())?
                        }
                    };
                    let (k, val) = v
                        .split_once('=')
                        .ok_or(format!("--set wants k=v, got '{v}'"))?;
                    out.sets.push((k.to_string(), val.to_string()));
                    i += 1;
                    continue;
                }
                let spec = self
                    .specs
                    .iter()
                    .find(|s| s.name == name)
                    .ok_or(format!("unknown option --{name}"))?;
                if spec.takes_value {
                    let v = match inline {
                        Some(v) => v,
                        None => {
                            i += 1;
                            argv.get(i)
                                .cloned()
                                .ok_or(format!("--{name} requires a value"))?
                        }
                    };
                    out.values.insert(name, v);
                } else {
                    if inline.is_some() {
                        return Err(format!("--{name} takes no value"));
                    }
                    out.flags.insert(name, true);
                }
            } else {
                out.positional.push(tok.clone());
            }
            i += 1;
        }
        Ok(out)
    }
}

impl Args {
    pub fn flag(&self, name: &str) -> bool {
        self.flags.get(name).copied().unwrap_or(false)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str) -> Result<usize, String> {
        self.get(name)
            .ok_or(format!("missing --{name}"))?
            .parse()
            .map_err(|e| format!("--{name}: {e}"))
    }

    pub fn get_f64(&self, name: &str) -> Result<f64, String> {
        self.get(name)
            .ok_or(format!("missing --{name}"))?
            .parse()
            .map_err(|e| format!("--{name}: {e}"))
    }

    pub fn sets(&self) -> &[(String, String)] {
        &self.sets
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(str::to_string).collect()
    }

    fn parser() -> Parser {
        Parser::new("t", "test")
            .flag("verbose", "noise")
            .opt("rate", "1.0", "req rate")
            .opt("out", "", "output")
    }

    #[test]
    fn defaults_apply() {
        let a = parser().parse(&argv("")).unwrap();
        assert_eq!(a.get("rate"), Some("1.0"));
        assert!(!a.flag("verbose"));
    }

    #[test]
    fn flags_values_positional() {
        let a = parser()
            .parse(&argv("run --verbose --rate 2.5 file.txt"))
            .unwrap();
        assert!(a.flag("verbose"));
        assert_eq!(a.get_f64("rate").unwrap(), 2.5);
        assert_eq!(a.positional(), ["run", "file.txt"]);
    }

    #[test]
    fn equals_syntax() {
        let a = parser().parse(&argv("--rate=7")).unwrap();
        assert_eq!(a.get("rate"), Some("7"));
    }

    #[test]
    fn set_overrides_collect() {
        let a = parser()
            .parse(&argv("--set a.b=1 --set=c=x"))
            .unwrap();
        assert_eq!(
            a.sets(),
            &[("a.b".into(), "1".into()), ("c".into(), "x".into())]
        );
    }

    #[test]
    fn unknown_option_rejected() {
        assert!(parser().parse(&argv("--nope")).is_err());
    }

    #[test]
    fn missing_value_rejected() {
        assert!(parser().parse(&argv("--rate")).is_err());
    }

    #[test]
    fn help_is_err_with_text() {
        let err = parser().parse(&argv("--help")).unwrap_err();
        assert!(err.contains("--rate"));
    }
}
