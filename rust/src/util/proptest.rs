//! Property-testing harness (the real `proptest` crate is unavailable
//! offline). Runs a property over many random cases from a deterministic
//! seed; on failure it retries with "shrunk" size parameters and reports
//! the failing seed so the case is exactly reproducible.
//!
//! Usage:
//! ```ignore
//! proptest(200, |g| {
//!     let n = g.usize(1, 512);
//!     let xs = g.vec_u32(n, 0, 1000);
//!     /* ... assertions ... */
//! });
//! ```

use crate::util::rng::Rng;

/// Case generator handed to each property invocation.
pub struct Gen {
    rng: Rng,
    /// Size dampening factor in (0, 1]; shrink passes lower it.
    pub size: f64,
}

impl Gen {
    pub fn new(seed: u64, size: f64) -> Self {
        Gen {
            rng: Rng::new(seed),
            size,
        }
    }

    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }

    /// Integer in `[lo, hi]`, range dampened by the shrink size.
    pub fn usize(&mut self, lo: usize, hi: usize) -> usize {
        let span = ((hi - lo) as f64 * self.size).ceil() as u64;
        lo + self.rng.below(span.max(1)) as usize
    }

    pub fn u64(&mut self, lo: u64, hi: u64) -> u64 {
        let span = ((hi - lo) as f64 * self.size).ceil() as u64;
        lo + self.rng.below(span.max(1))
    }

    pub fn f64(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.range_f64(lo, hi)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.chance(0.5)
    }

    pub fn vec_u32(&mut self, len: usize, lo: u32, hi: u32) -> Vec<u32> {
        (0..len)
            .map(|_| self.rng.range(lo as u64, hi as u64) as u32)
            .collect()
    }

    /// Pick one of the provided choices.
    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        self.rng.choose(xs)
    }
}

/// Run `cases` random instances of `prop`. Panics (with the failing seed)
/// if any case panics. A failing case is re-run at smaller sizes first so
/// the reported counterexample tends to be small.
pub fn proptest<F: Fn(&mut Gen) + std::panic::RefUnwindSafe>(cases: u32, prop: F) {
    // Fixed base seed + env override for reproduction.
    let base = std::env::var("MEMSERVE_PROPTEST_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xC0FFEEu64);
    for case in 0..cases {
        let seed = base.wrapping_add(case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let result = std::panic::catch_unwind(|| {
            let mut g = Gen::new(seed, 1.0);
            prop(&mut g);
        });
        if result.is_ok() {
            continue;
        }
        // Shrink-lite: try smaller sizes with the same seed to find a
        // smaller counterexample before reporting.
        for &size in &[0.1, 0.25, 0.5] {
            let shrunk = std::panic::catch_unwind(|| {
                let mut g = Gen::new(seed, size);
                prop(&mut g);
            });
            if shrunk.is_err() {
                panic!(
                    "property failed (seed={seed:#x}, size={size}); rerun \
                     with MEMSERVE_PROPTEST_SEED={base} case {case}"
                );
            }
        }
        panic!(
            "property failed (seed={seed:#x}, size=1.0); rerun with \
             MEMSERVE_PROPTEST_SEED={base} case {case}"
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        proptest(50, |g| {
            let n = g.usize(0, 100);
            assert!(n <= 100);
        });
    }

    #[test]
    fn deterministic_generation() {
        let mut a = Gen::new(5, 1.0);
        let mut b = Gen::new(5, 1.0);
        for _ in 0..20 {
            assert_eq!(a.usize(0, 1000), b.usize(0, 1000));
        }
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn reports_failure() {
        proptest(50, |g| {
            let n = g.usize(0, 100);
            assert!(n < 95, "boom");
        });
    }

    #[test]
    fn shrink_reduces_sizes() {
        let mut big = Gen::new(1, 1.0);
        let mut small = Gen::new(1, 0.1);
        let b = big.usize(0, 1_000_000);
        let s = small.usize(0, 1_000_000);
        assert!(s <= b.max(100_000));
    }
}
