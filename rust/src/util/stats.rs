//! Statistics helpers for metrics and benches: exact percentiles over
//! collected samples, streaming mean/variance (Welford), and fixed-width
//! histograms for workload-statistics reporting (paper Fig 7).

/// Collects raw f64 samples; percentiles are exact (sorted on demand).
#[derive(Clone, Debug, Default)]
pub struct Samples {
    xs: Vec<f64>,
    sorted: bool,
}

impl Samples {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, x: f64) {
        self.xs.push(x);
        self.sorted = false;
    }

    pub fn extend(&mut self, other: &Samples) {
        self.xs.extend_from_slice(&other.xs);
        self.sorted = false;
    }

    pub fn len(&self) -> usize {
        self.xs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.xs.is_empty()
    }

    pub fn mean(&self) -> f64 {
        if self.xs.is_empty() {
            return f64::NAN;
        }
        self.xs.iter().sum::<f64>() / self.xs.len() as f64
    }

    pub fn min(&self) -> f64 {
        self.xs.iter().cloned().fold(f64::INFINITY, f64::min)
    }

    pub fn max(&self) -> f64 {
        self.xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
    }

    pub fn sum(&self) -> f64 {
        self.xs.iter().sum()
    }

    pub fn std(&self) -> f64 {
        let n = self.xs.len();
        if n < 2 {
            return 0.0;
        }
        let m = self.mean();
        (self.xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>()
            / (n - 1) as f64)
            .sqrt()
    }

    fn ensure_sorted(&mut self) {
        if !self.sorted {
            self.xs
                .sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
            self.sorted = true;
        }
    }

    /// Exact percentile with linear interpolation; `p` in `[0, 100]`.
    pub fn percentile(&mut self, p: f64) -> f64 {
        if self.xs.is_empty() {
            return f64::NAN;
        }
        self.ensure_sorted();
        let n = self.xs.len();
        if n == 1 {
            return self.xs[0];
        }
        let rank = (p / 100.0) * (n - 1) as f64;
        let lo = rank.floor() as usize;
        let hi = rank.ceil() as usize;
        let frac = rank - lo as f64;
        self.xs[lo] * (1.0 - frac) + self.xs[hi.min(n - 1)] * frac
    }

    pub fn p50(&mut self) -> f64 {
        self.percentile(50.0)
    }

    pub fn p99(&mut self) -> f64 {
        self.percentile(99.0)
    }

    /// `(mean, p50, p99, max)` — the row format most benches print.
    pub fn digest(&mut self) -> (f64, f64, f64, f64) {
        (self.mean(), self.p50(), self.p99(), self.max())
    }

    pub fn values(&self) -> &[f64] {
        &self.xs
    }
}

/// Streaming mean/variance (Welford) — O(1) memory, used by long sims.
#[derive(Clone, Debug, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            f64::NAN
        } else {
            self.mean
        }
    }

    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.variance().sqrt()
    }
}

/// Fixed-bin histogram over `[lo, hi)`; out-of-range clamps to edge bins.
#[derive(Clone, Debug)]
pub struct Histogram {
    lo: f64,
    hi: f64,
    bins: Vec<u64>,
    count: u64,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, nbins: usize) -> Self {
        assert!(hi > lo && nbins > 0);
        Histogram {
            lo,
            hi,
            bins: vec![0; nbins],
            count: 0,
        }
    }

    pub fn push(&mut self, x: f64) {
        let n = self.bins.len();
        let t = ((x - self.lo) / (self.hi - self.lo) * n as f64) as isize;
        let idx = t.clamp(0, n as isize - 1) as usize;
        self.bins[idx] += 1;
        self.count += 1;
    }

    pub fn bins(&self) -> &[u64] {
        &self.bins
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    /// Cumulative fraction at each bin edge — CDF rows for Fig 7.
    pub fn cdf(&self) -> Vec<(f64, f64)> {
        let width = (self.hi - self.lo) / self.bins.len() as f64;
        let mut acc = 0u64;
        self.bins
            .iter()
            .enumerate()
            .map(|(i, &c)| {
                acc += c;
                (
                    self.lo + width * (i + 1) as f64,
                    acc as f64 / self.count.max(1) as f64,
                )
            })
            .collect()
    }

    /// Render a sparkline-ish ASCII bar per bin (for terminal figures).
    pub fn ascii(&self, width: usize) -> Vec<String> {
        let max = self.bins.iter().copied().max().unwrap_or(1).max(1);
        let bw = (self.hi - self.lo) / self.bins.len() as f64;
        self.bins
            .iter()
            .enumerate()
            .map(|(i, &c)| {
                let bar = "#".repeat((c as usize * width / max as usize).max(
                    usize::from(c > 0),
                ));
                format!(
                    "[{:>8.1},{:>8.1}) {:>7} {}",
                    self.lo + bw * i as f64,
                    self.lo + bw * (i + 1) as f64,
                    c,
                    bar
                )
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_exact_small() {
        let mut s = Samples::new();
        for x in [1.0, 2.0, 3.0, 4.0, 5.0] {
            s.push(x);
        }
        assert_eq!(s.p50(), 3.0);
        assert_eq!(s.percentile(0.0), 1.0);
        assert_eq!(s.percentile(100.0), 5.0);
        assert!((s.percentile(25.0) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn percentile_interpolates() {
        let mut s = Samples::new();
        s.push(0.0);
        s.push(10.0);
        assert!((s.p50() - 5.0).abs() < 1e-12);
        assert!((s.percentile(90.0) - 9.0).abs() < 1e-12);
    }

    #[test]
    fn empty_is_nan() {
        let mut s = Samples::new();
        assert!(s.p50().is_nan());
        assert!(s.mean().is_nan());
    }

    #[test]
    fn mean_std() {
        let mut s = Samples::new();
        for x in [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0] {
            s.push(x);
        }
        assert!((s.mean() - 5.0).abs() < 1e-12);
        assert!((s.std() - 2.138089935).abs() < 1e-6);
    }

    #[test]
    fn welford_matches_samples() {
        let mut s = Samples::new();
        let mut w = Welford::default();
        let mut state = 99u64;
        for _ in 0..1000 {
            let x = (crate::util::rng::splitmix64(&mut state) % 1000) as f64;
            s.push(x);
            w.push(x);
        }
        assert!((s.mean() - w.mean()).abs() < 1e-9);
        assert!((s.std() - w.std()).abs() < 1e-9);
    }

    #[test]
    fn histogram_bins_and_cdf() {
        let mut h = Histogram::new(0.0, 10.0, 10);
        for i in 0..10 {
            h.push(i as f64 + 0.5);
        }
        assert!(h.bins().iter().all(|&c| c == 1));
        let cdf = h.cdf();
        assert!((cdf[4].1 - 0.5).abs() < 1e-12);
        assert!((cdf[9].1 - 1.0).abs() < 1e-12);
    }

    #[test]
    fn histogram_clamps_outliers() {
        let mut h = Histogram::new(0.0, 1.0, 4);
        h.push(-100.0);
        h.push(100.0);
        assert_eq!(h.bins()[0], 1);
        assert_eq!(h.bins()[3], 1);
    }

    #[test]
    fn sorted_flag_reset_on_push() {
        let mut s = Samples::new();
        s.push(5.0);
        let _ = s.p50();
        s.push(1.0);
        assert_eq!(s.percentile(0.0), 1.0);
    }
}
