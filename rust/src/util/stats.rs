//! Statistics helpers for metrics and benches: exact percentiles over
//! collected samples, streaming mean/variance (Welford), and fixed-width
//! histograms for workload-statistics reporting (paper Fig 7).

/// Retained-sample cap for [`Samples::new`]. At ~8 bytes a sample this
/// bounds a digest at 512 KiB no matter how long the run; percentile
/// error from uniform reservoir sampling at this size is far below the
/// log2-histogram error live paths accept (ISSUE 8 satellite).
pub const DEFAULT_SAMPLE_CAP: usize = 65_536;

/// Collects f64 samples for end-of-run digests.
///
/// Percentiles sort the retained vector in place, so memory and sort
/// cost must stay bounded on long runs: beyond [`DEFAULT_SAMPLE_CAP`]
/// retained values, `push` switches to uniform reservoir replacement
/// (deterministic splitmix64, so runs reproduce). `mean`/`sum`/`min`/
/// `max` stay **exact** over everything ever pushed (tracked as
/// running aggregates); `percentile`/`std` are computed over the
/// retained reservoir — exact until the cap is first exceeded,
/// statistically unbiased after. Callers that truly need exact
/// percentiles over unbounded history (short benches, tests) opt in
/// via [`Samples::unbounded`]. Live serving paths should prefer
/// `obs::registry` log2 histograms — O(1) memory and `&self`.
#[derive(Clone, Debug)]
pub struct Samples {
    xs: Vec<f64>,
    sorted: bool,
    /// Retained-sample cap; 0 = unbounded.
    cap: usize,
    /// Total samples ever pushed (≥ `xs.len()`).
    seen: u64,
    /// Exact running aggregates over everything pushed.
    total: f64,
    run_min: f64,
    run_max: f64,
    /// splitmix64 state for reservoir replacement.
    rng: u64,
}

impl Default for Samples {
    fn default() -> Self {
        Self::with_cap(DEFAULT_SAMPLE_CAP)
    }
}

impl Samples {
    pub fn new() -> Self {
        Self::default()
    }

    /// No retained-sample cap: exact percentiles, unbounded memory.
    /// For short benches and tests only — see the type docs.
    pub fn unbounded() -> Self {
        Self::with_cap(0)
    }

    /// Explicit retained-sample cap (`0` = unbounded).
    pub fn with_cap(cap: usize) -> Self {
        Samples {
            xs: Vec::new(),
            sorted: false,
            cap,
            seen: 0,
            total: 0.0,
            run_min: f64::INFINITY,
            run_max: f64::NEG_INFINITY,
            rng: 0x9E37_79B9_7F4A_7C15,
        }
    }

    pub fn push(&mut self, x: f64) {
        self.seen += 1;
        self.total += x;
        self.run_min = self.run_min.min(x);
        self.run_max = self.run_max.max(x);
        if self.cap == 0 || self.xs.len() < self.cap {
            self.xs.push(x);
            self.sorted = false;
        } else {
            // Algorithm R: keep each of the `seen` samples with equal
            // probability cap/seen by overwriting a uniform slot.
            let j = crate::util::rng::splitmix64(&mut self.rng) % self.seen;
            if (j as usize) < self.cap {
                self.xs[j as usize] = x;
                self.sorted = false;
            }
        }
    }

    pub fn extend(&mut self, other: &Samples) {
        for &x in &other.xs {
            self.push(x);
        }
        // Samples `other` rotated out of its reservoir are gone as
        // values, but their count and sum keep mean/sum/min/max exact.
        let dropped = other.seen - other.xs.len() as u64;
        if dropped > 0 {
            self.seen += dropped;
            self.total += other.total - other.xs.iter().sum::<f64>();
            self.run_min = self.run_min.min(other.run_min);
            self.run_max = self.run_max.max(other.run_max);
        }
    }

    /// Retained samples (≤ cap). See [`Samples::seen`] for the true
    /// observation count.
    pub fn len(&self) -> usize {
        self.xs.len()
    }

    /// Total observations ever pushed, including reservoir-rotated ones.
    pub fn seen(&self) -> u64 {
        self.seen
    }

    pub fn is_empty(&self) -> bool {
        self.seen == 0
    }

    /// Exact mean over all observations (not just the reservoir).
    pub fn mean(&self) -> f64 {
        if self.seen == 0 {
            return f64::NAN;
        }
        self.total / self.seen as f64
    }

    /// Exact minimum over all observations.
    pub fn min(&self) -> f64 {
        self.run_min
    }

    /// Exact maximum over all observations.
    pub fn max(&self) -> f64 {
        self.run_max
    }

    /// Exact sum over all observations.
    pub fn sum(&self) -> f64 {
        self.total
    }

    /// Standard deviation of the retained reservoir (exact until the
    /// cap is exceeded).
    pub fn std(&self) -> f64 {
        let n = self.xs.len();
        if n < 2 {
            return 0.0;
        }
        let m = self.xs.iter().sum::<f64>() / n as f64;
        (self.xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>()
            / (n - 1) as f64)
            .sqrt()
    }

    fn ensure_sorted(&mut self) {
        if !self.sorted {
            self.xs
                .sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
            self.sorted = true;
        }
    }

    /// Percentile with linear interpolation over the retained
    /// reservoir; `p` in `[0, 100]`. Exact while `seen() <= cap`.
    pub fn percentile(&mut self, p: f64) -> f64 {
        if self.xs.is_empty() {
            return f64::NAN;
        }
        self.ensure_sorted();
        let n = self.xs.len();
        if n == 1 {
            return self.xs[0];
        }
        let rank = (p / 100.0) * (n - 1) as f64;
        let lo = rank.floor() as usize;
        let hi = rank.ceil() as usize;
        let frac = rank - lo as f64;
        self.xs[lo] * (1.0 - frac) + self.xs[hi.min(n - 1)] * frac
    }

    pub fn p50(&mut self) -> f64 {
        self.percentile(50.0)
    }

    pub fn p99(&mut self) -> f64 {
        self.percentile(99.0)
    }

    /// `(mean, p50, p99, max)` — the row format most benches print.
    pub fn digest(&mut self) -> (f64, f64, f64, f64) {
        (self.mean(), self.p50(), self.p99(), self.max())
    }

    /// The retained samples (the full history only when under cap).
    pub fn values(&self) -> &[f64] {
        &self.xs
    }
}

/// Streaming mean/variance (Welford) — O(1) memory, used by long sims.
#[derive(Clone, Debug, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            f64::NAN
        } else {
            self.mean
        }
    }

    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.variance().sqrt()
    }
}

/// Fixed-bin histogram over `[lo, hi)`; out-of-range clamps to edge bins.
#[derive(Clone, Debug)]
pub struct Histogram {
    lo: f64,
    hi: f64,
    bins: Vec<u64>,
    count: u64,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, nbins: usize) -> Self {
        assert!(hi > lo && nbins > 0);
        Histogram {
            lo,
            hi,
            bins: vec![0; nbins],
            count: 0,
        }
    }

    pub fn push(&mut self, x: f64) {
        let n = self.bins.len();
        let t = ((x - self.lo) / (self.hi - self.lo) * n as f64) as isize;
        let idx = t.clamp(0, n as isize - 1) as usize;
        self.bins[idx] += 1;
        self.count += 1;
    }

    pub fn bins(&self) -> &[u64] {
        &self.bins
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    /// Cumulative fraction at each bin edge — CDF rows for Fig 7.
    pub fn cdf(&self) -> Vec<(f64, f64)> {
        let width = (self.hi - self.lo) / self.bins.len() as f64;
        let mut acc = 0u64;
        self.bins
            .iter()
            .enumerate()
            .map(|(i, &c)| {
                acc += c;
                (
                    self.lo + width * (i + 1) as f64,
                    acc as f64 / self.count.max(1) as f64,
                )
            })
            .collect()
    }

    /// Render a sparkline-ish ASCII bar per bin (for terminal figures).
    pub fn ascii(&self, width: usize) -> Vec<String> {
        let max = self.bins.iter().copied().max().unwrap_or(1).max(1);
        let bw = (self.hi - self.lo) / self.bins.len() as f64;
        self.bins
            .iter()
            .enumerate()
            .map(|(i, &c)| {
                let bar = "#".repeat((c as usize * width / max as usize).max(
                    usize::from(c > 0),
                ));
                format!(
                    "[{:>8.1},{:>8.1}) {:>7} {}",
                    self.lo + bw * i as f64,
                    self.lo + bw * (i + 1) as f64,
                    c,
                    bar
                )
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_exact_small() {
        let mut s = Samples::new();
        for x in [1.0, 2.0, 3.0, 4.0, 5.0] {
            s.push(x);
        }
        assert_eq!(s.p50(), 3.0);
        assert_eq!(s.percentile(0.0), 1.0);
        assert_eq!(s.percentile(100.0), 5.0);
        assert!((s.percentile(25.0) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn percentile_interpolates() {
        let mut s = Samples::new();
        s.push(0.0);
        s.push(10.0);
        assert!((s.p50() - 5.0).abs() < 1e-12);
        assert!((s.percentile(90.0) - 9.0).abs() < 1e-12);
    }

    #[test]
    fn empty_is_nan() {
        let mut s = Samples::new();
        assert!(s.p50().is_nan());
        assert!(s.mean().is_nan());
    }

    #[test]
    fn mean_std() {
        let mut s = Samples::new();
        for x in [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0] {
            s.push(x);
        }
        assert!((s.mean() - 5.0).abs() < 1e-12);
        assert!((s.std() - 2.138089935).abs() < 1e-6);
    }

    #[test]
    fn welford_matches_samples() {
        let mut s = Samples::new();
        let mut w = Welford::default();
        let mut state = 99u64;
        for _ in 0..1000 {
            let x = (crate::util::rng::splitmix64(&mut state) % 1000) as f64;
            s.push(x);
            w.push(x);
        }
        assert!((s.mean() - w.mean()).abs() < 1e-9);
        assert!((s.std() - w.std()).abs() < 1e-9);
    }

    #[test]
    fn histogram_bins_and_cdf() {
        let mut h = Histogram::new(0.0, 10.0, 10);
        for i in 0..10 {
            h.push(i as f64 + 0.5);
        }
        assert!(h.bins().iter().all(|&c| c == 1));
        let cdf = h.cdf();
        assert!((cdf[4].1 - 0.5).abs() < 1e-12);
        assert!((cdf[9].1 - 1.0).abs() < 1e-12);
    }

    #[test]
    fn histogram_clamps_outliers() {
        let mut h = Histogram::new(0.0, 1.0, 4);
        h.push(-100.0);
        h.push(100.0);
        assert_eq!(h.bins()[0], 1);
        assert_eq!(h.bins()[3], 1);
    }

    #[test]
    fn sorted_flag_reset_on_push() {
        let mut s = Samples::new();
        s.push(5.0);
        let _ = s.p50();
        s.push(1.0);
        assert_eq!(s.percentile(0.0), 1.0);
    }

    /// ISSUE 8 satellite: memory stays bounded past the cap while
    /// mean/sum/min/max stay exact and percentiles stay close.
    #[test]
    fn reservoir_bounds_memory_keeps_aggregates_exact() {
        let cap = 256;
        let mut s = Samples::with_cap(cap);
        let n = 20_000u64;
        for i in 0..n {
            s.push(i as f64);
        }
        assert_eq!(s.len(), cap);
        assert_eq!(s.seen(), n);
        assert_eq!(s.min(), 0.0);
        assert_eq!(s.max(), (n - 1) as f64);
        assert!((s.sum() - (n * (n - 1) / 2) as f64).abs() < 1e-6);
        assert!((s.mean() - (n - 1) as f64 / 2.0).abs() < 1e-9);
        // Uniform input: reservoir p50 lands near the true median.
        let p50 = s.p50();
        let true_med = (n - 1) as f64 / 2.0;
        assert!(
            (p50 - true_med).abs() < 0.15 * n as f64,
            "reservoir p50 {p50} too far from {true_med}"
        );
    }

    #[test]
    fn unbounded_keeps_everything() {
        let mut s = Samples::unbounded();
        for i in 0..(DEFAULT_SAMPLE_CAP + 10) {
            s.push(i as f64);
        }
        assert_eq!(s.len(), DEFAULT_SAMPLE_CAP + 10);
        assert_eq!(s.percentile(100.0), (DEFAULT_SAMPLE_CAP + 9) as f64);
    }

    #[test]
    fn extend_preserves_exact_aggregates_across_caps() {
        let mut a = Samples::with_cap(8);
        for i in 0..100 {
            a.push(i as f64);
        }
        let mut b = Samples::unbounded();
        b.push(1000.0);
        b.extend(&a);
        assert_eq!(b.seen(), 101);
        assert_eq!(b.max(), 1000.0);
        assert_eq!(b.min(), 0.0);
        assert!((b.sum() - (1000.0 + 4950.0)).abs() < 1e-9);
        // Only a's 8 retained values landed as concrete samples.
        assert_eq!(b.len(), 9);
    }

    #[test]
    fn reservoir_is_deterministic() {
        let fill = |n: u64| {
            let mut s = Samples::with_cap(16);
            for i in 0..n {
                s.push(i as f64);
            }
            s.values().to_vec()
        };
        assert_eq!(fill(5000), fill(5000));
    }
}
