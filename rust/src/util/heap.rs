//! Lazy-deletion heap maintenance policy — the ONE definition of the
//! compaction threshold shared by the MemPool index's LRU victim heap
//! (`mempool::index::RadixIndex`) and the fused prompt tree's TTL
//! expiry heap (`scheduler::fused_tree::FusedPromptTree`).
//!
//! Both heaps invalidate entries lazily (a per-node stamp marks heap
//! entries stale; stale entries are discarded at pop), so the heap can
//! grow dominated by dead entries under churn. Each used to hard-code
//! the same rebuild trigger — "more than 64 entries AND more than 4×
//! the live population" — in two places (flagged as a PR 1 follow-up
//! in ROADMAP.md); a drifting copy would silently change one heap's
//! amortized complexity. The policy lives here once, with the boundary
//! pinned by unit tests.
//!
//! Why these values: the 4× slack bounds wasted memory and pop-side
//! stale-entry skips to a constant factor of the live set (amortized
//! O(log n) per operation survives, since each compaction is O(heap)
//! but at least 3/4 of the entries it scans are dead and were paid for
//! by the pushes that created them). The floor of 64 keeps tiny heaps
//! from compacting on every push — below it the whole heap fits in a
//! couple of cache lines and rebuilds cost more than they save.

/// Minimum heap length before compaction is ever considered.
pub const LAZY_HEAP_COMPACT_MIN: usize = 64;

/// Compact when the heap exceeds this multiple of the live entry count
/// (dead entries then dominate at least (FACTOR-1)/FACTOR of the heap).
pub const LAZY_HEAP_STALE_FACTOR: usize = 4;

/// Should a lazy-deletion heap of `heap_len` entries, of which at most
/// `live_entries` are still valid, be rebuilt now? (`live_entries + 1`
/// keeps the empty-population case from compacting on every push.)
#[inline]
pub fn lazy_heap_needs_compact(heap_len: usize, live_entries: usize) -> bool {
    heap_len > LAZY_HEAP_COMPACT_MIN
        && heap_len > LAZY_HEAP_STALE_FACTOR * (live_entries + 1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn boundary_of_the_minimum_floor() {
        // With zero live entries the factor term is satisfied from
        // length 5 up — the floor alone gates until 65.
        assert!(!lazy_heap_needs_compact(LAZY_HEAP_COMPACT_MIN, 0));
        assert!(lazy_heap_needs_compact(LAZY_HEAP_COMPACT_MIN + 1, 0));
    }

    #[test]
    fn boundary_of_the_stale_factor() {
        // live = 31 → threshold is 4 * 32 = 128: exactly 128 entries
        // must NOT compact, 129 must.
        let live = 31;
        let threshold = LAZY_HEAP_STALE_FACTOR * (live + 1);
        assert!(threshold > LAZY_HEAP_COMPACT_MIN, "factor term governs");
        assert!(!lazy_heap_needs_compact(threshold, live));
        assert!(lazy_heap_needs_compact(threshold + 1, live));
    }

    #[test]
    fn large_live_population_never_compacts_below_factor() {
        // A heap tracking a big live set compacts only when dead
        // entries actually dominate.
        assert!(!lazy_heap_needs_compact(4_000, 1_000));
        assert!(lazy_heap_needs_compact(4_005, 1_000));
    }
}
