//! The repo's single wall-clock primitive (ISSUE 10, archlint R1).
//!
//! Nothing outside the allow-listed live-server modules may read the
//! wall clock directly: `deterministic_replay` and the identical-routing
//! gates (PR 6/7) only hold when every decision is a function of the
//! caller-provided virtual timestamp. Code that genuinely needs live
//! time (the serve loop, fabric recv deadlines, bench harnesses) calls
//! these helpers or takes one of them as an injected `fn() -> f64`
//! timer — passing `monotonic_secs` *by name* (no call) is always
//! allowed; *calling* it is what archlint restricts to the allow list.

use std::time::Instant;

use once_cell::sync::Lazy;

/// Process-start anchor so monotonic readings are small, comparable
/// f64s rather than opaque `Instant`s.
static START: Lazy<Instant> = Lazy::new(Instant::now);

/// Seconds elapsed since the first clock read in this process.
/// Monotonic; safe to subtract. This is the injectable route timer.
pub fn monotonic_secs() -> f64 {
    START.elapsed().as_secs_f64()
}

/// Seconds since the UNIX epoch, for human-facing stamps (artifact
/// metadata, log prefixes). Not monotonic; never feed it to decisions.
pub fn epoch_secs() -> f64 {
    std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs_f64())
        .unwrap_or(0.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn monotonic_never_decreases() {
        let a = monotonic_secs();
        let b = monotonic_secs();
        assert!(b >= a);
        assert!(a >= 0.0);
    }

    #[test]
    fn epoch_is_plausible() {
        // Any machine running this code post-dates 2020-01-01.
        assert!(epoch_secs() > 1.577e9);
    }
}
