//! Minimal JSON: a recursive-descent parser and a writer.
//!
//! Scope: everything `artifacts/meta.json` and our bench outputs need —
//! objects, arrays, strings (with escapes), numbers, bools, null. Not a
//! general-purpose library; rejects trailing garbage, depth > 128, and
//! invalid escapes.

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug, thiserror::Error)]
#[error("json error at byte {pos}: {msg}")]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl Json {
    // ---------------- accessors ----------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `obj["a"]["b"][2]`-style path access: `j.at(&["a", "b"])`.
    pub fn at(&self, path: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for k in path {
            cur = cur.get(k)?;
        }
        Some(cur)
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    // ---------------- parsing ----------------

    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            b: text.as_bytes(),
            pos: 0,
            depth: 0,
        };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing garbage"));
        }
        Ok(v)
    }

    // ---------------- writing ----------------

    pub fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    // ---------------- builders ----------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(
            pairs
                .into_iter()
                .map(|(k, v)| (k.to_string(), v))
                .collect(),
        )
    }

    pub fn num(x: f64) -> Json {
        Json::Num(x)
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn arr(v: Vec<Json>) -> Json {
        Json::Arr(v)
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
    depth: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            pos: self.pos,
            msg: msg.to_string(),
        }
    }

    fn ws(&mut self) {
        while self.pos < self.b.len()
            && matches!(self.b[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.depth += 1;
        if self.depth > 128 {
            return Err(self.err("nesting too deep"));
        }
        let v = match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        };
        self.depth -= 1;
        v
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err("bad literal"))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.pos += 1;
        }
        std::str::from_utf8(&self.b[start..self.pos])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.b.len() {
                                return Err(self.err("bad \\u"));
                            }
                            let hex = std::str::from_utf8(
                                &self.b[self.pos + 1..self.pos + 5],
                            )
                            .map_err(|_| self.err("bad \\u"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u"))?;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Copy a UTF-8 run verbatim.
                    let start = self.pos;
                    while self
                        .peek()
                        .map(|c| c != b'"' && c != b'\\')
                        .unwrap_or(false)
                    {
                        self.pos += 1;
                    }
                    s.push_str(
                        std::str::from_utf8(&self.b[start..self.pos])
                            .map_err(|_| self.err("invalid utf8"))?,
                    );
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(
            Json::parse("\"a\\nb\"").unwrap(),
            Json::Str("a\nb".into())
        );
    }

    #[test]
    fn parses_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(j.at(&["a"]).unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            j.at(&["a"]).unwrap().as_arr().unwrap()[2]
                .get("b")
                .unwrap()
                .as_str(),
            Some("x")
        );
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(
            Json::parse("\"\\u0041\"").unwrap(),
            Json::Str("A".into())
        );
    }

    #[test]
    fn roundtrip() {
        let j = Json::obj(vec![
            ("name", Json::str("memserve")),
            ("n", Json::num(42.0)),
            ("xs", Json::arr(vec![Json::num(1.5), Json::Bool(false)])),
            ("quote", Json::str("a\"b\\c\nd")),
        ]);
        let text = j.to_string();
        assert_eq!(Json::parse(&text).unwrap(), j);
    }

    #[test]
    fn integers_print_without_fraction() {
        assert_eq!(Json::num(42.0).to_string(), "42");
        assert_eq!(Json::num(0.5).to_string(), "0.5");
    }

    #[test]
    fn parses_real_meta_json_shape() {
        let text = r#"{
  "format_version": 1,
  "model": {"vocab": 2048, "layers": 4},
  "artifacts": {"decode_ctx64": "decode_ctx64.hlo.txt"},
  "params": [{"name": "embed", "shape": [2048, 256], "offset_f32": 0}]
}"#;
        let j = Json::parse(text).unwrap();
        assert_eq!(j.at(&["model", "vocab"]).unwrap().as_usize(), Some(2048));
        assert_eq!(
            j.at(&["artifacts", "decode_ctx64"]).unwrap().as_str(),
            Some("decode_ctx64.hlo.txt")
        );
    }
}
