//! Paged-KV layout conversions.
//!
//! The AOT graphs exchange KV as contiguous `[L, 2, T, H, hd]` buffers
//! (T = bucket capacity); MemPool stores it in fixed-size blocks. Two
//! block layouts exist (paper §5.2):
//!
//! * **aggregated** ("huge page"): one block per token-block holding
//!   `[L, 2, bt, H, hd]` — all layers and halves together;
//! * **discrete** (vLLM-style): `2·L` blocks per token-block, each
//!   holding one layer-half `[bt, H, hd]`, ordered
//!   `(layer0 K, layer0 V, layer1 K, ...)`.
//!
//! Total bytes are identical; what changes is the number of blocks (and
//! therefore network calls — the whole point of Fig 11/12).

use crate::mempool::{BlockGeometry, GroupList, MemPool, PoolError, Tier};

/// Per-(token, layer-half) float count: H · hd.
fn slot(geom: &BlockGeometry) -> usize {
    geom.n_heads * geom.head_dim
}

/// Scatter freshly produced KV (`[L, 2, N, H, hd]` flattened, bucket
/// capacity N, first `n_tokens` valid) into newly allocated pool blocks.
/// Returns a [`GroupList`] with one group per token-block (flat storage,
/// no per-group `Vec`s). Partial trailing tokens (beyond the last whole
/// block) are stored too — the group covers them — but only whole blocks
/// should be indexed (the caller truncates when calling `insert`).
pub fn scatter_new_kv(
    pool: &mut MemPool,
    new_kv: &[f32],
    bucket_n: usize,
    n_tokens: usize,
    now: f64,
) -> Result<GroupList, PoolError> {
    let geom = *pool.geometry();
    let s = slot(&geom);
    let bt = geom.block_tokens;
    assert_eq!(new_kv.len(), geom.layers * 2 * bucket_n * s, "kv len");
    assert!(n_tokens <= bucket_n);
    let n_blocks = geom.token_blocks(n_tokens);
    let per_tb = geom.blocks_per_token_block();
    pool.ensure_free_hbm(n_blocks * per_tb, now)?;

    // Tokens are contiguous within each (layer, half) plane in both the
    // bucket layout ([L, 2, N, H, hd]) and the block layouts, so every
    // block copies `valid·s`-float *runs* per (layer, half) — one memcpy
    // instead of `bt` token-sized ones.
    let mut groups = GroupList::default();
    let mut buf = vec![0f32; geom.floats_per_block()];
    let mut small = vec![0f32; bt * s];
    for b in 0..n_blocks {
        let addrs = pool.alloc_mem(per_tb, Tier::Hbm)?;
        let t0 = b * bt;
        let valid = n_tokens.saturating_sub(t0).min(bt);
        if geom.aggregated {
            // Block layout [L, 2, bt, H, hd].
            for l in 0..geom.layers {
                for h in 0..2 {
                    let dst = (l * 2 + h) * bt * s;
                    let src = ((l * 2 + h) * bucket_n + t0) * s;
                    buf[dst..dst + valid * s]
                        .copy_from_slice(&new_kv[src..src + valid * s]);
                    buf[dst + valid * s..dst + bt * s].fill(0.0);
                }
            }
            pool.write_block(addrs[0], &buf)?;
        } else {
            // One block per (layer, half): layout [bt, H, hd].
            for l in 0..geom.layers {
                for h in 0..2 {
                    let src = ((l * 2 + h) * bucket_n + t0) * s;
                    small[..valid * s]
                        .copy_from_slice(&new_kv[src..src + valid * s]);
                    small[valid * s..].fill(0.0);
                    pool.write_block(addrs[l * 2 + h], &small)?;
                }
            }
        }
        groups.push_group(&addrs);
    }
    Ok(groups)
}

/// Gather block groups into a contiguous `[L, 2, cap, H, hd]` buffer
/// (first `groups.len() * bt` token slots populated; rest zero).
pub fn gather_to_buffer(
    pool: &MemPool,
    groups: &GroupList,
    cap: usize,
) -> Result<Vec<f32>, PoolError> {
    let geom = *pool.geometry();
    let s = slot(&geom);
    let bt = geom.block_tokens;
    assert!(groups.len() * bt <= cap, "cap too small");
    // As in `scatter_new_kv`, copy whole `bt·s` runs per (layer, half).
    // Discrete blocks ([bt, H, hd]) are exactly one destination run, so
    // they land directly in `out` with no staging buffer at all.
    let mut out = vec![0f32; geom.layers * 2 * cap * s];
    let mut buf = vec![0f32; geom.floats_per_block()];
    for (b, group) in groups.iter().enumerate() {
        let t0 = b * bt;
        if geom.aggregated {
            pool.read_block(group[0], &mut buf)?;
            for l in 0..geom.layers {
                for h in 0..2 {
                    let src = (l * 2 + h) * bt * s;
                    let dst = ((l * 2 + h) * cap + t0) * s;
                    out[dst..dst + bt * s]
                        .copy_from_slice(&buf[src..src + bt * s]);
                }
            }
        } else {
            for l in 0..geom.layers {
                for h in 0..2 {
                    let dst = ((l * 2 + h) * cap + t0) * s;
                    pool.read_block(
                        group[l * 2 + h],
                        &mut out[dst..dst + bt * s],
                    )?;
                }
            }
        }
    }
    Ok(out)
}

/// Extract the KV of token range `[from, to)` from a contiguous
/// `[L, 2, cap, H, hd]` buffer into bucket-N layout `[L, 2, n, H, hd]`
/// (n = to - from) — used when re-slicing decode output for retirement.
pub fn slice_tokens(
    geom: &BlockGeometry,
    kv: &[f32],
    cap: usize,
    from: usize,
    to: usize,
) -> Vec<f32> {
    let s = slot(geom);
    assert!(from <= to && to <= cap);
    assert_eq!(kv.len(), geom.layers * 2 * cap * s);
    let n = to - from;
    let mut out = vec![0f32; geom.layers * 2 * n * s];
    for l in 0..geom.layers {
        for h in 0..2 {
            let src = ((l * 2 + h) * cap + from) * s;
            let dst = (l * 2 + h) * n * s;
            out[dst..dst + n * s].copy_from_slice(&kv[src..src + n * s]);
        }
    }
    out
}

/// Merge `extra` (`[L, 2, n, H, hd]`, n tokens) into `kv`
/// (`[L, 2, cap, H, hd]`) at token offset `at` — the decode-side landing
/// of transferred prefill KV.
pub fn splice_tokens(
    geom: &BlockGeometry,
    kv: &mut [f32],
    cap: usize,
    extra: &[f32],
    n: usize,
    at: usize,
) {
    let s = slot(geom);
    assert!(at + n <= cap);
    assert_eq!(extra.len(), geom.layers * 2 * n * s);
    for l in 0..geom.layers {
        for h in 0..2 {
            let dst = ((l * 2 + h) * cap + at) * s;
            let src = (l * 2 + h) * n * s;
            kv[dst..dst + n * s].copy_from_slice(&extra[src..src + n * s]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mempool::InstanceId;
    use crate::util::rng::Rng;

    fn mk_pool(aggregated: bool) -> MemPool {
        let geom = BlockGeometry {
            block_tokens: 4,
            layers: 3,
            n_heads: 2,
            head_dim: 5,
            aggregated,
        };
        MemPool::new(InstanceId(0), geom, 64, 64, 0.0, true)
    }

    fn rand_kv(rng: &mut Rng, geom: &BlockGeometry, n: usize) -> Vec<f32> {
        (0..geom.layers * 2 * n * slot(geom))
            .map(|_| rng.f64() as f32)
            .collect()
    }

    #[test]
    fn scatter_gather_roundtrip_both_layouts() {
        for aggregated in [true, false] {
            let mut pool = mk_pool(aggregated);
            let geom = *pool.geometry();
            let mut rng = Rng::new(1);
            let bucket_n = 16;
            let n_tokens = 11; // partial last block
            let kv = rand_kv(&mut rng, &geom, bucket_n);
            let groups =
                scatter_new_kv(&mut pool, &kv, bucket_n, n_tokens, 0.0)
                    .unwrap();
            assert_eq!(groups.len(), 3); // ceil(11/4)
            assert_eq!(
                groups[0].len(),
                if aggregated { 1 } else { 6 }
            );
            let cap = 16;
            let out = gather_to_buffer(&pool, &groups, cap).unwrap();
            // Token t of layer l half h must match.
            let s = slot(&geom);
            for l in 0..geom.layers {
                for h in 0..2 {
                    for t in 0..n_tokens {
                        let src = ((l * 2 + h) * bucket_n + t) * s;
                        let dst = ((l * 2 + h) * cap + t) * s;
                        assert_eq!(
                            &kv[src..src + s],
                            &out[dst..dst + s],
                            "mismatch l={l} h={h} t={t} agg={aggregated}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn gather_smaller_group_subset() {
        let mut pool = mk_pool(true);
        let geom = *pool.geometry();
        let mut rng = Rng::new(2);
        let kv = rand_kv(&mut rng, &geom, 16);
        let groups = scatter_new_kv(&mut pool, &kv, 16, 16, 0.0).unwrap();
        // Gather only the first 2 of 4 blocks.
        let mut head = GroupList::default();
        head.extend_range(&groups, 0, 2);
        let out = gather_to_buffer(&pool, &head, 8).unwrap();
        let s = slot(&geom);
        for l in 0..geom.layers {
            let src = (l * 2) * 16 * s;
            let dst = (l * 2) * 8 * s;
            assert_eq!(&kv[src..src + 8 * s], &out[dst..dst + 8 * s]);
        }
    }

    #[test]
    fn slice_and_splice_are_inverse() {
        let geom = BlockGeometry {
            block_tokens: 4,
            layers: 2,
            n_heads: 2,
            head_dim: 3,
            aggregated: true,
        };
        let mut rng = Rng::new(3);
        let cap = 12;
        let kv: Vec<f32> = (0..geom.layers * 2 * cap * slot(&geom))
            .map(|_| rng.f64() as f32)
            .collect();
        let piece = slice_tokens(&geom, &kv, cap, 4, 9);
        let mut kv2 = vec![0f32; kv.len()];
        splice_tokens(&geom, &mut kv2, cap, &piece, 5, 4);
        let s = slot(&geom);
        for l in 0..geom.layers {
            for h in 0..2 {
                for t in 4..9 {
                    let i = ((l * 2 + h) * cap + t) * s;
                    assert_eq!(&kv[i..i + s], &kv2[i..i + s]);
                }
            }
        }
    }

    #[test]
    fn scatter_fails_cleanly_when_pool_full() {
        let geom = BlockGeometry {
            block_tokens: 4,
            layers: 3,
            n_heads: 2,
            head_dim: 5,
            aggregated: true,
        };
        let mut pool = MemPool::new(InstanceId(0), geom, 2, 0, 0.0, true);
        let mut rng = Rng::new(4);
        let kv = rand_kv(&mut rng, &geom, 16);
        // 16 tokens need 4 blocks; only 2 exist and none evictable.
        assert!(scatter_new_kv(&mut pool, &kv, 16, 16, 0.0).is_err());
    }

    #[test]
    fn scatter_triggers_eviction_under_pressure() {
        let geom = BlockGeometry {
            block_tokens: 4,
            layers: 3,
            n_heads: 2,
            head_dim: 5,
            aggregated: true,
        };
        let mut pool = MemPool::new(InstanceId(0), geom, 4, 0, 0.0, true);
        let mut rng = Rng::new(5);
        // Fill with an indexed (evictable) entry.
        let kv1 = rand_kv(&mut rng, &geom, 16);
        let g1 = scatter_new_kv(&mut pool, &kv1, 16, 16, 0.0).unwrap();
        let toks: Vec<u32> = (0..16).collect();
        pool.insert_list(&toks, &g1, 0.0).unwrap();
        assert_eq!(pool.free_blocks(Tier::Hbm), 0);
        // New scatter must evict the old entry and succeed.
        let kv2 = rand_kv(&mut rng, &geom, 8);
        let g2 = scatter_new_kv(&mut pool, &kv2, 8, 8, 1.0).unwrap();
        assert_eq!(g2.len(), 2);
        assert!(pool.stats().evicted_blocks > 0);
    }
}
