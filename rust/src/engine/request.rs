//! Request types, lifecycle states, and sampling.

use crate::util::rng::Rng;

pub type RequestId = u64;

/// Sampling parameters carried in the request (and through `transfer`'s
/// `private` field in disaggregated mode — paper §5.1a).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SamplingParams {
    /// 0.0 = greedy argmax; otherwise softmax temperature sampling.
    pub temperature: f64,
    /// Stop after this many generated tokens.
    pub max_new_tokens: usize,
    /// Generation stops early on this token (tokenizer::EOS by default).
    pub eos_token: u32,
    /// Seed for temperature sampling (deterministic per request).
    pub seed: u64,
}

impl Default for SamplingParams {
    fn default() -> Self {
        SamplingParams {
            temperature: 0.0,
            max_new_tokens: 32,
            eos_token: crate::tokenizer::EOS,
            seed: 0,
        }
    }
}

/// An inference request as the engine sees it.
#[derive(Clone, Debug)]
pub struct Request {
    pub id: RequestId,
    pub session: u64,
    pub prompt: Vec<u32>,
    pub sampling: SamplingParams,
    /// Arrival time on the caller's clock (seconds).
    pub arrival: f64,
}

/// Pick the next token from logits.
pub fn sample(logits: &[f32], params: &SamplingParams, rng: &mut Rng) -> u32 {
    if params.temperature <= 0.0 {
        return argmax(logits) as u32;
    }
    // Softmax with temperature, sampled via inverse CDF.
    let t = params.temperature as f32;
    let max = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let mut probs: Vec<f32> =
        logits.iter().map(|&x| ((x - max) / t).exp()).collect();
    let sum: f32 = probs.iter().sum();
    for p in &mut probs {
        *p /= sum;
    }
    let u = rng.f64() as f32;
    let mut acc = 0.0;
    for (i, &p) in probs.iter().enumerate() {
        acc += p;
        if u <= acc {
            return i as u32;
        }
    }
    (probs.len() - 1) as u32
}

pub fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    let mut best_v = f32::NEG_INFINITY;
    for (i, &x) in xs.iter().enumerate() {
        if x > best_v {
            best_v = x;
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_is_argmax() {
        let logits = vec![0.1, 2.0, -1.0, 1.9];
        let mut rng = Rng::new(0);
        let p = SamplingParams::default();
        assert_eq!(sample(&logits, &p, &mut rng), 1);
    }

    #[test]
    fn temperature_sampling_covers_support() {
        let logits = vec![1.0, 1.0, 1.0, -100.0];
        let p = SamplingParams {
            temperature: 1.0,
            ..Default::default()
        };
        let mut rng = Rng::new(7);
        let mut seen = [false; 4];
        for _ in 0..200 {
            seen[sample(&logits, &p, &mut rng) as usize] = true;
        }
        assert!(seen[0] && seen[1] && seen[2]);
        assert!(!seen[3], "negligible-probability token sampled");
    }

    #[test]
    fn low_temperature_concentrates() {
        let logits = vec![0.0, 3.0, 0.0];
        let p = SamplingParams {
            temperature: 0.05,
            ..Default::default()
        };
        let mut rng = Rng::new(9);
        for _ in 0..50 {
            assert_eq!(sample(&logits, &p, &mut rng), 1);
        }
    }

    #[test]
    fn argmax_first_on_tie() {
        assert_eq!(argmax(&[1.0, 1.0, 0.0]), 0);
    }
}
