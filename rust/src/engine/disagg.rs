//! Disaggregation + caching design milestones (paper §5.1, Table 4).
//!
//! | Milestone    | Steps     | Behaviour                                  |
//! |--------------|-----------|--------------------------------------------|
//! | PD-Basic     | 1         | transfer A-KV P→D, no caching anywhere     |
//! | PD-Caching-1 | 1+2       | P inserts prefill KV into its index        |
//! | PD-Caching-2 | 1+2+3+4   | + P sends `transfer_with_insert` (D indexes|
//! |              |           | prompt KV) and D inserts decode KV locally |
//! | PD-Caching-3 | 1+2+3+4+5 | + D sends decode KV back to P              |
//!
//! The enum drives both the live server's instance logic and the
//! discrete-event simulator, so Fig 8 (1P1D vs 1P1D-CC) and the Table-4
//! ablation bench share one source of truth.

#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum DisaggMilestone {
    PdBasic,
    PdCaching1,
    PdCaching2,
    PdCaching3,
}

impl DisaggMilestone {
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "pd_basic" | "basic" => Some(Self::PdBasic),
            "pd_caching_1" | "caching1" => Some(Self::PdCaching1),
            "pd_caching_2" | "caching2" => Some(Self::PdCaching2),
            "pd_caching_3" | "caching3" | "full" => Some(Self::PdCaching3),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Self::PdBasic => "pd_basic",
            Self::PdCaching1 => "pd_caching_1",
            Self::PdCaching2 => "pd_caching_2",
            Self::PdCaching3 => "pd_caching_3",
        }
    }

    /// Step 2: does the prefill instance retire prefill KV to its index?
    pub fn prefill_caches(self) -> bool {
        self >= Self::PdCaching1
    }

    /// Steps 3+4: does the decode instance index transferred + decoded KV
    /// (P uses `transfer_with_insert`, D can then skip re-received data)?
    pub fn decode_caches(self) -> bool {
        self >= Self::PdCaching2
    }

    /// Step 5: does the decode instance ship decode KV back to P so P's
    /// cache grows with conversation turns?
    pub fn decode_to_prefill(self) -> bool {
        self >= Self::PdCaching3
    }

    pub fn all() -> [DisaggMilestone; 4] {
        [
            Self::PdBasic,
            Self::PdCaching1,
            Self::PdCaching2,
            Self::PdCaching3,
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn capability_ladder_is_monotone() {
        let caps: Vec<(bool, bool, bool)> = DisaggMilestone::all()
            .iter()
            .map(|m| {
                (m.prefill_caches(), m.decode_caches(), m.decode_to_prefill())
            })
            .collect();
        assert_eq!(
            caps,
            vec![
                (false, false, false),
                (true, false, false),
                (true, true, false),
                (true, true, true),
            ]
        );
    }

    #[test]
    fn parse_roundtrip() {
        for m in DisaggMilestone::all() {
            assert_eq!(DisaggMilestone::parse(m.name()), Some(m));
        }
        assert_eq!(DisaggMilestone::parse("x"), None);
    }
}
