//! The engine core: admission → cached prefill → iteration-level decode →
//! KV retirement, all against one instance's MemPool + PJRT runtime.
//!
//! The engine exposes *primitives*; the colocated/prefill-only/decode-only
//! instance loops in [`crate::server`] compose them per role, and
//! [`run_to_completion`]-style helpers serve the examples and tests.

use std::sync::Arc;

use anyhow::{Context, Result};

use super::kv;
use super::request::{sample, Request};
#[cfg(test)]
use super::request::SamplingParams;
use crate::mempool::{GroupList, MemPool, Tier};
use crate::runtime::{DecodeSession, ModelRuntime};
use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct EngineOptions {
    /// Enable context caching (MemPool insert/match).
    pub context_caching: bool,
    /// Upper bound on concurrently decoding requests.
    pub max_batch: usize,
}

impl Default for EngineOptions {
    fn default() -> Self {
        EngineOptions {
            context_caching: true,
            max_batch: 8,
        }
    }
}

/// Prefill outcome: KV now lives in pool blocks (prefix pinned + fresh
/// active blocks); logits are ready for the first sampled token.
pub struct PrefillDone {
    /// Tokens matched in the local cache (block-rounded, < prompt len).
    pub cached_tokens: usize,
    /// Pinned prefix length (== cached_tokens; unpin at retire).
    pub pinned_tokens: usize,
    /// Index-owned groups covering the cached prefix (flat storage —
    /// the pool's zero-clone match handles, kept as-is end-to-end).
    pub prefix_groups: GroupList,
    /// Engine-owned groups covering the new tokens (incl. a zero-padded
    /// partial tail block when the prompt is not block-aligned).
    pub new_groups: GroupList,
    /// Logits after the last prompt token.
    pub logits: Vec<f32>,
    /// Prompt length this prefill covered.
    pub prompt_len: usize,
}

/// A request actively decoding on this engine.
pub struct ActiveDecode {
    pub req: Request,
    /// Timestamps the instance loop stamps for metrics (caller's clock).
    pub scheduled: f64,
    pub first_token_time: f64,
    pub sess: DecodeSession,
    pub prompt_len: usize,
    pub cached_tokens: usize,
    pub pinned_tokens: usize,
    pub prefix_groups: GroupList,
    pub new_groups: GroupList,
    pub generated: Vec<u32>,
    /// Next token to feed (last sampled).
    pub pending_token: u32,
    rng: Rng,
    pub done: bool,
}

/// One decode iteration's result for a request.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum StepOutcome {
    /// Emitted one token; request continues.
    Token(u32),
    /// Emitted the final token (EOS or budget exhausted).
    Finished(u32),
}

/// A set of concurrently-decoding requests (the instance loop's batch).
#[derive(Default)]
pub struct ActiveDecodeSet {
    pub jobs: Vec<ActiveDecode>,
}

impl ActiveDecodeSet {
    pub fn is_empty(&self) -> bool {
        self.jobs.is_empty()
    }

    pub fn len(&self) -> usize {
        self.jobs.len()
    }
}

pub struct Engine {
    pub runtime: Arc<ModelRuntime>,
    pub pool: MemPool,
    pub opts: EngineOptions,
}

impl Engine {
    pub fn new(runtime: Arc<ModelRuntime>, pool: MemPool,
               opts: EngineOptions) -> Self {
        Engine {
            runtime,
            pool,
            opts,
        }
    }

    fn block_tokens(&self) -> usize {
        self.pool.geometry().block_tokens
    }

    /// Admission + prefill: match the local cache, swap in DRAM-resident
    /// hits, gather, run the bucketized prefill, scatter new KV into
    /// blocks.
    pub fn prefill(&mut self, prompt: &[u32], now: f64)
                   -> Result<PrefillDone> {
        anyhow::ensure!(!prompt.is_empty(), "empty prompt");
        anyhow::ensure!(
            prompt.len() < self.runtime.meta.max_seq,
            "prompt too long: {}",
            prompt.len()
        );
        let bt = self.block_tokens();
        // Cap the cache hit below the full prompt: at least one new token
        // must run to produce logits.
        let max_cached = (prompt.len() - 1) / bt * bt;
        let m = if self.opts.context_caching {
            self.pool.match_and_pin(&prompt[..max_cached], now)
        } else {
            Default::default()
        };
        let cached = m.tokens;
        // The match handles stay in their flat zero-clone form for the
        // whole request lifetime — no per-group `Vec` materialization.
        let mut prefix_groups = m.groups;
        // DRAM-resident prefix blocks must come back to HBM before use.
        if prefix_groups.flat().iter().any(|a| a.tier == Tier::Dram) {
            let need = prefix_groups
                .flat()
                .iter()
                .filter(|a| a.tier == Tier::Dram)
                .count();
            self.pool.ensure_free_hbm(need, now)?;
            let back = self.pool.swap_in(prefix_groups.flat())?;
            let per = self.pool.geometry().blocks_per_token_block();
            let mut rebuilt = GroupList::default();
            for c in back.chunks(per) {
                rebuilt.push_group(c);
            }
            prefix_groups = rebuilt;
        }

        let new_tokens = &prompt[cached..];
        let (_, c) = self
            .runtime
            .meta
            .pick_prefill_bucket(new_tokens.len(), cached)
            .with_context(|| {
                format!(
                    "no bucket: new={} cached={cached}",
                    new_tokens.len()
                )
            })?;
        let cache_buf = if c > 0 {
            Some(kv::gather_to_buffer(&self.pool, &prefix_groups, c)?)
        } else {
            None
        };
        let out = self
            .runtime
            .prefill(new_tokens, cache_buf.as_deref(), cached)?;
        let new_groups = kv::scatter_new_kv(
            &mut self.pool,
            &out.new_kv,
            out.bucket_n,
            new_tokens.len(),
            now,
        )?;
        Ok(PrefillDone {
            cached_tokens: cached,
            pinned_tokens: if self.opts.context_caching { cached } else { 0 },
            prefix_groups,
            new_groups,
            logits: out.logits,
            prompt_len: prompt.len(),
        })
    }

    /// Begin decoding from a completed prefill: build the device KV state
    /// from pool blocks and sample the first token.
    pub fn start_decode(&mut self, req: Request, pf: PrefillDone)
                        -> Result<ActiveDecode> {
        let total_len =
            (pf.prompt_len + req.sampling.max_new_tokens).min(
                self.runtime.meta.max_seq,
            );
        let ctx = self
            .runtime
            .meta
            .pick_decode_ctx(total_len)
            .with_context(|| format!("no decode ctx >= {total_len}"))?;
        let mut groups = pf.prefix_groups.clone();
        groups.extend_list(&pf.new_groups);
        let kv_buf = kv::gather_to_buffer(&self.pool, &groups, ctx)?;
        let sess = self.runtime.decode_start(&kv_buf, ctx, pf.prompt_len)?;
        let mut rng = Rng::new(req.sampling.seed ^ req.id);
        let first = sample(&pf.logits, &req.sampling, &mut rng);
        Ok(ActiveDecode {
            req,
            scheduled: 0.0,
            first_token_time: 0.0,
            sess,
            prompt_len: pf.prompt_len,
            cached_tokens: pf.cached_tokens,
            pinned_tokens: pf.pinned_tokens,
            prefix_groups: pf.prefix_groups,
            new_groups: pf.new_groups,
            generated: vec![first],
            pending_token: first,
            rng,
            done: false,
        })
    }

    /// Begin decoding on a *decode-only* instance from already-landed KV
    /// blocks (the disaggregated receive path).
    pub fn start_decode_from_blocks(
        &mut self,
        req: Request,
        groups: GroupList,
        prompt_len: usize,
        first_logits: Vec<f32>,
        pinned_tokens: usize,
    ) -> Result<ActiveDecode> {
        let total_len = (prompt_len + req.sampling.max_new_tokens)
            .min(self.runtime.meta.max_seq);
        let ctx = self
            .runtime
            .meta
            .pick_decode_ctx(total_len)
            .with_context(|| format!("no decode ctx >= {total_len}"))?;
        let kv_buf = kv::gather_to_buffer(&self.pool, &groups, ctx)?;
        let sess = self.runtime.decode_start(&kv_buf, ctx, prompt_len)?;
        let mut rng = Rng::new(req.sampling.seed ^ req.id);
        let first = sample(&first_logits, &req.sampling, &mut rng);
        Ok(ActiveDecode {
            req,
            scheduled: 0.0,
            first_token_time: 0.0,
            sess,
            prompt_len,
            cached_tokens: 0,
            pinned_tokens,
            prefix_groups: GroupList::default(),
            new_groups: groups,
            generated: vec![first],
            pending_token: first,
            rng,
            done: false,
        })
    }

    /// One decode iteration for one request (iteration-level scheduling:
    /// the instance loop round-robins this across its active set).
    pub fn step(&mut self, a: &mut ActiveDecode) -> Result<StepOutcome> {
        anyhow::ensure!(!a.done, "stepping a finished request");
        let budget = a.req.sampling.max_new_tokens;
        if a.generated.len() >= budget
            || *a.generated.last().unwrap() == a.req.sampling.eos_token
            || a.sess.pos + 1 >= a.sess.ctx
        {
            a.done = true;
            return Ok(StepOutcome::Finished(a.pending_token));
        }
        let logits = self.runtime.decode_step(&mut a.sess, a.pending_token)?;
        let tok = sample(&logits, &a.req.sampling, &mut a.rng);
        a.generated.push(tok);
        a.pending_token = tok;
        if a.generated.len() >= budget || tok == a.req.sampling.eos_token {
            a.done = true;
            return Ok(StepOutcome::Finished(tok));
        }
        Ok(StepOutcome::Token(tok))
    }

    /// Retire a finished request: unpin the prefix and either index the
    /// consumed KV (context caching on) or free the active blocks.
    ///
    /// Returns the token sequence whose KV is now cached (empty when
    /// caching is off).
    pub fn retire(&mut self, mut a: ActiveDecode, now: f64)
                  -> Result<Vec<u32>> {
        a.done = true;
        let bt = self.block_tokens();
        let pinned = a.pinned_tokens;
        if pinned > 0 {
            self.pool.unpin(&a.req.prompt[..pinned]);
        }
        if !self.opts.context_caching {
            for g in a.new_groups.iter() {
                self.pool.free_mem(g)?;
            }
            return Ok(vec![]);
        }
        // Tokens whose KV exists: prompt + generated tokens actually fed
        // (all but the final sampled one).
        let consumed = a.sess.pos;
        let mut seq = a.req.prompt.clone();
        seq.extend_from_slice(&a.generated[..consumed - a.prompt_len]);
        debug_assert_eq!(seq.len(), consumed);
        let full_prompt_blocks = a.prompt_len / bt;
        let total_full_blocks = consumed / bt;

        // Keep prompt full-block groups; re-scatter the mixed/generated
        // tail from the decode buffer; drop the prefill partial block.
        // Everything stays in flat GroupList form — no per-group Vecs.
        let mut groups = std::mem::take(&mut a.prefix_groups);
        let prefix_blocks = groups.len();
        debug_assert!(prefix_blocks <= full_prompt_blocks);
        let keep_new =
            (full_prompt_blocks - prefix_blocks).min(a.new_groups.len());
        groups.extend_range(&a.new_groups, 0, keep_new);
        // Free the prefill groups beyond full prompt blocks (partial
        // tail).
        for g in a.new_groups.iter().skip(keep_new) {
            self.pool.free_mem(g)?;
        }
        if total_full_blocks > full_prompt_blocks {
            let kv_host = self.runtime.decode_kv(&mut a.sess)?;
            let from = full_prompt_blocks * bt;
            let to = total_full_blocks * bt;
            let tail = kv::slice_tokens(
                self.pool.geometry(),
                &kv_host,
                a.sess.ctx,
                from,
                to,
            );
            let tail_groups = kv::scatter_new_kv(
                &mut self.pool,
                &tail,
                to - from,
                to - from,
                now,
            )?;
            groups.extend_list(&tail_groups);
        }
        let indexable = total_full_blocks * bt;
        self.pool.insert_list(&seq[..indexable], &groups, now)?;
        Ok(seq)
    }

    /// Retire a prefill on a *prefill-only* instance (no local decode):
    /// index the full prompt blocks (caching on) or free everything.
    /// Call after the KV has been exported/transferred.
    pub fn retire_prefill(&mut self, prompt: &[u32], pf: PrefillDone,
                          now: f64) -> Result<()> {
        let bt = self.block_tokens();
        if pf.pinned_tokens > 0 {
            self.pool.unpin(&prompt[..pf.pinned_tokens]);
        }
        if !self.opts.context_caching {
            for g in pf.new_groups.iter() {
                self.pool.free_mem(g)?;
            }
            return Ok(());
        }
        let full_blocks = pf.prompt_len / bt;
        let mut groups = pf.prefix_groups;
        let keep_new =
            (full_blocks - groups.len().min(full_blocks)).min(pf.new_groups.len());
        groups.extend_range(&pf.new_groups, 0, keep_new);
        for g in pf.new_groups.iter().skip(keep_new) {
            self.pool.free_mem(g)?;
        }
        self.pool.insert_list(&prompt[..full_blocks * bt], &groups, now)?;
        Ok(())
    }

    /// Land a transferred KV *suffix* into the local index (the
    /// `transfer_with_insert` receive path for decode→prefill backflow,
    /// paper §5.1d): `seq` is the full token sequence, `suffix_groups`
    /// cover blocks `[suffix_start_block ..)`, and the prefix must
    /// already be indexed locally (it is, when this instance prefilled
    /// the prompt). If the local prefix was evicted meanwhile the suffix
    /// is unusable and is freed (best-effort, like the paper's GS trees).
    pub fn insert_suffix(
        &mut self,
        seq: &[u32],
        suffix_groups: GroupList,
        suffix_start_block: usize,
        now: f64,
    ) -> Result<bool> {
        let bt = self.block_tokens();
        let m = self.pool.match_prefix(seq, now);
        if m.tokens / bt < suffix_start_block {
            for g in suffix_groups.iter() {
                self.pool.free_mem(g)?;
            }
            return Ok(false);
        }
        let mut groups = m.groups;
        groups.truncate(suffix_start_block);
        groups.extend_list(&suffix_groups);
        let tokens = groups.len() * bt;
        anyhow::ensure!(tokens <= seq.len(), "suffix exceeds sequence");
        self.pool.insert_list(&seq[..tokens], &groups, now)?;
        Ok(true)
    }

    /// Convenience: run one request start-to-finish on a colocated
    /// engine. Returns (generated tokens, cached_tokens_at_admission).
    pub fn run_to_completion(&mut self, req: Request, now: f64)
                             -> Result<(Vec<u32>, usize)> {
        let pf = self.prefill(&req.prompt, now)?;
        let cached = pf.cached_tokens;
        let mut active = self.start_decode(req, pf)?;
        while !active.done {
            self.step(&mut active)?;
        }
        let generated = active.generated.clone();
        self.retire(active, now)?;
        Ok((generated, cached))
    }

    /// Active blocks the engine currently holds (for leak accounting in
    /// tests): callers track their ActiveDecode sets; a quiescent engine
    /// should report pool consistency with 0 active blocks.
    pub fn check_quiescent(&self) -> Result<(), String> {
        self.pool.check_consistency(0)
    }
}

#[cfg(test)]
mod tests {
    //! Engine integration tests over the real runtime + artifacts.
    //! Self-skip when artifacts are absent.
    use super::*;
    use crate::mempool::{BlockGeometry, InstanceId};
    use crate::runtime::artifacts::artifacts_available;
    use once_cell::sync::Lazy;

    static RT: Lazy<Option<Arc<ModelRuntime>>> = Lazy::new(|| {
        if !artifacts_available("artifacts") {
            eprintln!("[skip] artifacts/ not built");
            return None;
        }
        Some(Arc::new(ModelRuntime::load("artifacts").unwrap()))
    });

    fn engine(caching: bool) -> Option<Engine> {
        let rt = RT.as_ref()?.clone();
        let geom = BlockGeometry {
            block_tokens: 16,
            layers: rt.meta.layers,
            n_heads: rt.meta.n_heads,
            head_dim: rt.meta.head_dim,
            aggregated: true,
        };
        let pool = MemPool::new(InstanceId(0), geom, 256, 512, 0.0, true);
        Some(Engine::new(
            rt,
            pool,
            EngineOptions {
                context_caching: caching,
                max_batch: 4,
            },
        ))
    }

    fn req(id: u64, prompt: Vec<u32>, max_new: usize) -> Request {
        Request {
            id,
            session: id,
            prompt,
            sampling: SamplingParams {
                max_new_tokens: max_new,
                eos_token: u32::MAX, // never stop early (deterministic len)
                ..Default::default()
            },
            arrival: 0.0,
        }
    }

    fn toks(n: usize, seed: u32) -> Vec<u32> {
        (0..n as u32)
            .map(|i| (i.wrapping_mul(2654435761).wrapping_add(seed)) % 2048)
            .collect()
    }

    #[test]
    fn greedy_generation_is_deterministic_and_cache_invariant() {
        let Some(mut e) = engine(true) else { return };
        let prompt = toks(40, 1);
        let (gen1, cached1) =
            e.run_to_completion(req(1, prompt.clone(), 8), 1.0).unwrap();
        assert_eq!(cached1, 0);
        assert_eq!(gen1.len(), 8);
        // Second identical request: hits the cache, same output.
        let (gen2, cached2) =
            e.run_to_completion(req(2, prompt.clone(), 8), 2.0).unwrap();
        assert!(cached2 >= 32, "expected cache hit, got {cached2}");
        assert_eq!(gen1, gen2, "caching changed generation");
        e.check_quiescent().unwrap();
    }

    #[test]
    fn multi_turn_grows_cache() {
        let Some(mut e) = engine(true) else { return };
        let mut history = toks(30, 2);
        let mut last_cached = 0;
        for turn in 0..3 {
            let (generated, cached) = e
                .run_to_completion(req(10 + turn, history.clone(), 6), turn as f64)
                .unwrap();
            if turn > 0 {
                assert!(cached >= last_cached, "cache shrank");
                assert!(cached > 0, "turn {turn} missed cache");
            }
            last_cached = cached;
            history.extend(generated);
            history.extend(toks(5, 100 + turn as u32)); // next user turn
        }
        // The cached prefix must include previous turns' *generated* KV
        // (decode retirement worked): at turn 2 history > 41 tokens.
        assert!(last_cached >= 32, "{last_cached}");
        e.check_quiescent().unwrap();
    }

    #[test]
    fn caching_off_frees_everything() {
        let Some(mut e) = engine(false) else { return };
        let used0 = e.pool.used_blocks(Tier::Hbm);
        let (_, cached) =
            e.run_to_completion(req(1, toks(50, 3), 5), 0.0).unwrap();
        assert_eq!(cached, 0);
        assert_eq!(e.pool.used_blocks(Tier::Hbm), used0, "leak");
        assert_eq!(e.pool.indexed_token_blocks(), 0);
    }

    #[test]
    fn interleaved_decode_requests_do_not_interfere() {
        let Some(mut e) = engine(true) else { return };
        let pa = toks(20, 4);
        let pb = toks(24, 5);
        // Sequential references.
        let mut e2 = engine(true).unwrap();
        let (ga, _) = e2.run_to_completion(req(1, pa.clone(), 6), 0.0).unwrap();
        let (gb, _) = e2.run_to_completion(req(2, pb.clone(), 6), 0.1).unwrap();
        // Interleaved on the main engine.
        let fa = e.prefill(&pa, 0.0).unwrap();
        let mut a = e.start_decode(req(1, pa, 6), fa).unwrap();
        let fb = e.prefill(&pb, 0.1).unwrap();
        let mut b = e.start_decode(req(2, pb, 6), fb).unwrap();
        while !a.done || !b.done {
            if !a.done {
                e.step(&mut a).unwrap();
            }
            if !b.done {
                e.step(&mut b).unwrap();
            }
        }
        assert_eq!(a.generated, ga, "interleaving corrupted request A");
        assert_eq!(b.generated, gb, "interleaving corrupted request B");
        e.retire(a, 1.0).unwrap();
        e.retire(b, 1.0).unwrap();
        e.check_quiescent().unwrap();
    }

    #[test]
    fn eviction_pressure_does_not_break_running_request() {
        let Some(rt) = RT.as_ref() else { return };
        // Tiny HBM: 12 blocks; prompts of 3 blocks + decode tails force
        // eviction of older cache entries while requests run.
        let geom = BlockGeometry {
            block_tokens: 16,
            layers: rt.meta.layers,
            n_heads: rt.meta.n_heads,
            head_dim: rt.meta.head_dim,
            aggregated: true,
        };
        let pool = MemPool::new(InstanceId(0), geom, 12, 4, 0.0, true);
        let mut e = Engine::new(
            rt.clone(),
            pool,
            EngineOptions {
                context_caching: true,
                max_batch: 2,
            },
        );
        for i in 0..10 {
            let prompt = toks(80, 100 + i as u32);
            let (generated, _) = e
                .run_to_completion(req(i, prompt, 4), i as f64)
                .unwrap();
            assert_eq!(generated.len(), 4);
        }
        // The pool stayed consistent under repeated eviction.
        e.check_quiescent().unwrap();
        let st = e.pool.stats();
        assert!(
            st.evicted_blocks > 0 && st.swapped_out > 0,
            "expected both swap and eviction under pressure: {st:?}"
        );
    }

    #[test]
    fn prompt_longer_than_max_seq_rejected() {
        let Some(mut e) = engine(true) else { return };
        assert!(e.prefill(&toks(600, 6), 0.0).is_err());
        assert!(e.prefill(&[], 0.0).is_err());
    }
}
