//! The inference engine: everything between the global scheduler and the
//! PJRT runtime on one instance.
//!
//! * [`kv`] — paged-KV layout conversions between MemPool blocks and the
//!   contiguous buffers the AOT graphs consume (discrete vs aggregated
//!   layouts — paper §5.2).
//! * [`request`] — request state machine + sampling.
//! * [`core`] — the engine proper: admission with context-cache match
//!   (insert/match against MemPool), prefill bucketing, the iteration-
//!   level decode loop (continuous batching), and KV retirement.
//! * [`disagg`] — the §5.1 design milestones (Table 4): PD-Basic through
//!   PD-Caching-3, i.e. which side caches and which transfers what.

pub mod core;
pub mod disagg;
pub mod kv;
pub mod request;

pub use core::{ActiveDecodeSet, Engine, EngineOptions, StepOutcome};
pub use disagg::DisaggMilestone;
pub use request::{Request, RequestId, SamplingParams};
