//! Event queue for the discrete-event simulator: a min-heap on (time,
//! sequence) so simultaneous events pop in insertion order (deterministic
//! replay).

use std::cmp::Ordering;
use std::collections::BinaryHeap;

struct Entry<E> {
    time: f64,
    seq: u64,
    ev: E,
}

impl<E> PartialEq for Entry<E> {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}

impl<E> Eq for Entry<E> {}

impl<E> Ord for Entry<E> {
    fn cmp(&self, other: &Self) -> Ordering {
        // Reverse for a min-heap; NaN times are a programming error.
        other
            .time
            .partial_cmp(&self.time)
            .unwrap()
            .then(other.seq.cmp(&self.seq))
    }
}

impl<E> PartialOrd for Entry<E> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

pub struct EventQueue<E> {
    heap: BinaryHeap<Entry<E>>,
    seq: u64,
    now: f64,
}

impl<E> Default for EventQueue<E> {
    fn default() -> Self {
        EventQueue {
            heap: BinaryHeap::new(),
            seq: 0,
            now: 0.0,
        }
    }
}

impl<E> EventQueue<E> {
    pub fn new() -> Self {
        Self::default()
    }

    /// Current simulation time (last popped event's time).
    pub fn now(&self) -> f64 {
        self.now
    }

    pub fn push(&mut self, time: f64, ev: E) {
        debug_assert!(time.is_finite(), "non-finite event time");
        debug_assert!(
            time >= self.now - 1e-9,
            "scheduling into the past: {time} < {}",
            self.now
        );
        self.heap.push(Entry {
            time: time.max(self.now),
            seq: self.seq,
            ev,
        });
        self.seq += 1;
    }

    pub fn pop(&mut self) -> Option<(f64, E)> {
        let e = self.heap.pop()?;
        self.now = e.time;
        Some((e.time, e.ev))
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.push(3.0, "c");
        q.push(1.0, "a");
        q.push(2.0, "b");
        let order: Vec<&str> = std::iter::from_fn(|| q.pop().map(|(_, e)| e))
            .collect();
        assert_eq!(order, vec!["a", "b", "c"]);
    }

    #[test]
    fn simultaneous_events_fifo() {
        let mut q = EventQueue::new();
        q.push(1.0, 1);
        q.push(1.0, 2);
        q.push(1.0, 3);
        assert_eq!(q.pop().unwrap().1, 1);
        assert_eq!(q.pop().unwrap().1, 2);
        assert_eq!(q.pop().unwrap().1, 3);
    }

    #[test]
    fn now_advances() {
        let mut q = EventQueue::new();
        q.push(5.0, ());
        assert_eq!(q.now(), 0.0);
        q.pop();
        assert_eq!(q.now(), 5.0);
        // Scheduling "now" from a handler is fine.
        q.push(5.0, ());
        assert!(q.pop().is_some());
    }

    #[test]
    #[should_panic]
    #[cfg(debug_assertions)]
    fn rejects_past_events() {
        let mut q = EventQueue::new();
        q.push(5.0, ());
        q.pop();
        q.push(1.0, ());
    }
}
