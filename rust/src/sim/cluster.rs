//! The cluster simulation: GS + instances + fabric on a virtual clock.

use std::collections::{BTreeMap, VecDeque};

use crate::elastic::delta::DeltaEvent;
use crate::elastic::lifecycle::InstanceState;
use crate::elastic::planner::{plan_migration, PlannerConfig, Recipient};
use crate::engine::DisaggMilestone;
use crate::mempool::{
    BlockGeometry, InstanceId, RadixIndex, TransferMode,
};
use crate::metrics::{Metrics, RequestRecord};
use crate::net::LinkModel;
use crate::obs::flight::kind as fkind;
use crate::obs::trace::phase;
use crate::obs::{
    trace, view, Alert, AttribBook, ClusterView, FlightRecorder, Labels,
    Registry, Timeline, TraceSink, Watchdog,
};
use crate::replica::ShardedReplicaGroup;
use crate::scheduler::cost_model::OperatorCostModel;
use crate::scheduler::prompt_tree::InstanceKind;
use crate::scheduler::router::{GlobalScheduler, InstanceLoad};
use crate::scheduler::PolicyKind;
use crate::sim::clock::EventQueue;
use crate::workload::{ArrivalPlan, WorkloadSpec};

#[derive(Clone, Debug)]
pub struct SimConfig {
    pub prefill_instances: usize,
    pub decode_instances: usize,
    pub colocated_instances: usize,
    /// Context caching (both the local indexes and GS tree routing).
    pub caching: bool,
    pub milestone: DisaggMilestone,
    pub policy: PolicyKind,
    pub transfer_mode: TransferMode,
    pub cost: OperatorCostModel,
    pub link: LinkModel,
    pub geom: BlockGeometry,
    /// HBM capacity per instance, in allocatable blocks.
    pub hbm_blocks: usize,
    pub max_batch: usize,
    /// Global-tree TTL seconds (0 = off).
    pub tree_ttl: f64,
    /// GS follower replicas mirroring every ownership delta (0 = off).
    /// With replicas, a scripted [`FleetOp::GsFailover`] can crash the
    /// routing tree mid-trace and promote a follower.
    pub gs_replicas: usize,
    /// Prefix-range shards of the global prompt tree (≥ 1): each shard
    /// is its own fused tree, delta stream, and replica group, so a
    /// scripted failover can crash ONE shard's slice while the others
    /// keep serving. 1 = the unsharded tree, bit-identical to before.
    pub gs_shards: usize,
    /// Per-delivery drop probability on the GS replication stream
    /// (ISSUE 6; 0 = lossless/synchronous, bit-identical to before).
    /// Lossy mirroring exercises the transport's gap-repair and
    /// retransmit paths mid-trace; a [`FleetOp::GsFailover`] first
    /// pumps the lossy transports to convergence (the real protocol's
    /// retry loop) so promotion still restores the full state.
    pub replication_drop: f64,
    /// Scripted elasticity events (drain / join) on the virtual clock.
    pub fleet: Vec<FleetEvent>,
    /// Observability (ISSUE 8): when set, the sim records request
    /// spans, folds instance stats into a metric registry, and keeps a
    /// flight-recorder ring — all exported via [`SimReport::obs`].
    /// Instrumentation is record-only: it never changes a routing
    /// decision or a virtual-clock timestamp, so trace-identity tests
    /// hold with it on or off. Default off (byte-stable reports).
    pub observe: bool,
    /// Timeline window (virtual seconds) for the windowed time-series
    /// + watchdog pass (ISSUE 9); only read when `observe` is set. The
    /// tick runs between popped events — it never enqueues anything,
    /// so event order (and thus routing) is untouched.
    pub obs_window_s: f64,
}

/// A scripted fleet change in the discrete-event simulation.
#[derive(Clone, Debug)]
pub struct FleetEvent {
    pub at: f64,
    pub op: FleetOp,
}

#[derive(Clone, Debug)]
pub enum FleetOp {
    /// Begin draining instance `inst` (index into the fleet): routing
    /// stops immediately, hot cached prefixes migrate to Active peers
    /// when `migrate` is set (the naive scale-down baseline drops them),
    /// in-flight work completes, then the instance decommissions.
    Drain { inst: usize, migrate: bool },
    /// A new instance joins the fleet and becomes routable.
    Join { kind: InstanceKind },
    /// The global scheduler's primary tree crashes — all shards, or
    /// just `shard` when set (per-shard failover: the other shards'
    /// slices keep serving untouched). The most-caught-up follower of
    /// each crashed shard is promoted (after catch-up) and serves every
    /// subsequent route of that prefix range. Requires `gs_replicas >
    /// 0`; zero request loss and — since followers replay the same
    /// sequenced delta streams — route decisions identical to an
    /// uninterrupted run.
    GsFailover { shard: Option<usize> },
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig {
            prefill_instances: 1,
            decode_instances: 1,
            colocated_instances: 0,
            caching: true,
            milestone: DisaggMilestone::PdCaching3,
            policy: PolicyKind::PromptTree,
            transfer_mode: TransferMode::ByRequestAgg,
            cost: OperatorCostModel::paper_13b(),
            link: LinkModel::default(),
            geom: BlockGeometry {
                block_tokens: 16,
                layers: 40,
                n_heads: 40,
                head_dim: 128,
                aggregated: true,
            },
            hbm_blocks: 4096,
            max_batch: 16,
            tree_ttl: 300.0,
            gs_replicas: 0,
            gs_shards: 1,
            replication_drop: 0.0,
            fleet: vec![],
            observe: false,
            obs_window_s: 1.0,
        }
    }
}

/// Simulation outcome: per-request metrics + network/caching counters.
#[derive(Debug, Default)]
pub struct SimReport {
    pub metrics: Metrics,
    pub wire_bytes: u64,
    pub wire_calls: u64,
    pub wire_seconds: f64,
    pub evicted_blocks: u64,
    pub sim_seconds: f64,
    /// Token-blocks shipped by drain-time migration.
    pub migrated_token_blocks: u64,
    /// Token-blocks a scale-down dropped (cold tails, or everything
    /// under a naive decommission).
    pub dropped_token_blocks: u64,
    /// Scripted GS-primary failovers executed.
    pub gs_failovers: u64,
    /// Token-blocks the GS believes the fleet caches at trace end.
    pub gs_believed_token_blocks: u64,
    /// Token-blocks the local indexes actually hold at trace end. With
    /// honest-eviction reporting, believed never exceeds actual
    /// (pre-ISSUE-4, only the TTL bounded the GS's over-belief).
    pub indexed_token_blocks: u64,
    /// Deferred-touch queue counters summed over every instance index
    /// (the `&self` match path queues LRU stamps; `&mut` ops drain
    /// them). Dropped touches leave a leaf's access time *older* than
    /// the truth, so the over-belief accounting stays one-sided: late
    /// stamps can only make the LRU evict a hot leaf early — reported
    /// honestly as an `Expire` — never keep a cold one alive.
    pub touches_deferred: u64,
    pub touches_drained: u64,
    pub touches_dropped: u64,
    /// Observability bundle ([`SimConfig::observe`]): folded cluster
    /// view, the trace sink (span chains + Chrome export), and the
    /// flight-recorder ring. `None` when observation was off.
    pub obs: Option<SimObs>,
}

/// The sim's observability outputs (handles share state with the run).
#[derive(Clone)]
pub struct SimObs {
    pub view: ClusterView,
    pub trace: TraceSink,
    pub flight: FlightRecorder,
    /// Windowed time-series driven by the virtual clock (ISSUE 9):
    /// one frame per `obs_window_s`, flushed at trace end.
    pub timeline: Timeline,
    /// Every watchdog alert the run fired (also in the flight ring as
    /// `kind::ALERT`). Empty on a healthy trace.
    pub alerts: Vec<Alert>,
}

impl std::fmt::Debug for SimObs {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let (recorded, dropped, dups, orphans) = self.trace.stats();
        f.debug_struct("SimObs")
            .field("view_at", &self.view.at)
            .field("trace_recorded", &recorded)
            .field("trace_dropped", &dropped)
            .field("trace_dup_closes", &dups)
            .field("trace_orphan_ends", &orphans)
            .field("flight_events", &self.flight.len())
            .field("timeline_frames", &self.timeline.len())
            .field("alerts", &self.alerts.len())
            .finish()
    }
}

// ---------------------------------------------------------------------
// Internal entities
// ---------------------------------------------------------------------

#[derive(Clone, Debug)]
struct Job {
    rid: u64,
    session: usize,
    turn: usize,
    prompt: Vec<u32>,
    cached: usize,
    gen_target: usize,
    generated: usize,
    rec: RequestRecord,
    /// Decode instance chosen at routing (disaggregated only).
    decode_inst: Option<usize>,
    /// When the transferred KV lands at the decode instance.
    wire_done: f64,
    /// Receive-side cost at the decode instance: posting one recv per
    /// block is synchronous engine work (paper §7's single NCCL thread;
    /// the root cause of "overhead with increasing load", §5.2).
    recv_tax: f64,
    /// Eq. 1 prefill cost the router predicted at route time; compared
    /// against the observed prefill at retire (ISSUE 9 attribution).
    predicted_prefill_s: f64,
}

struct Instance {
    id: InstanceId,
    kind: InstanceKind,
    index: RadixIndex,
    /// allocatable blocks used by the index.
    index_blocks: usize,
    capacity_blocks: usize,
    prefill_q: VecDeque<Job>,
    /// decoding jobs (<= max_batch in the running set).
    active: Vec<Job>,
    pending_decode: VecDeque<Job>,
    busy: bool,
    queued_tokens: usize,
    evicted_blocks: u64,
    /// The outbound NCCL "thread": transfers serialize on this resource
    /// (paper §7 — one thread per communicator for ordering). Modeled
    /// separately from compute so by-layer can overlap the two.
    wire_free: f64,
    /// Receive-side call overhead accrued since the last decode
    /// iteration; charged to the next iteration (engine contention).
    pending_recv_tax: f64,
    /// Lifecycle state (elasticity): Draining instances receive no new
    /// routes but finish their work; Decommissioned ones are gone.
    state: InstanceState,
    /// Outstanding drain-migration transfers still on the wire.
    pending_migrations: usize,
    /// Requests routed here for decode whose KV has not arrived yet
    /// (still prefilling elsewhere or on the wire) — a draining decode
    /// instance must wait these out before decommissioning.
    expected_arrivals: usize,
}

impl Instance {
    fn new(id: u32, kind: InstanceKind, cfg: &SimConfig) -> Self {
        Instance {
            id: InstanceId(id),
            kind,
            index: RadixIndex::new(cfg.geom.block_tokens, 0.0),
            index_blocks: 0,
            capacity_blocks: cfg.hbm_blocks,
            prefill_q: VecDeque::new(),
            active: vec![],
            pending_decode: VecDeque::new(),
            busy: false,
            queued_tokens: 0,
            evicted_blocks: 0,
            wire_free: 0.0,
            pending_recv_tax: 0.0,
            state: InstanceState::Active,
            pending_migrations: 0,
            expected_arrivals: 0,
        }
    }

    fn pressure(&self) -> f64 {
        (self.index_blocks as f64 / self.capacity_blocks.max(1) as f64)
            .min(1.0)
    }

    /// Insert tokens into the local index (capacity-enforced LRU).
    /// Returns the token prefixes the LRU evicted to make room — the
    /// honest-eviction signal the caller reports to the GS as `Expire`
    /// deltas instead of leaving stale global-tree entries to the TTL.
    fn index_insert(&mut self, tokens: &[u32], now: f64,
                    geom: &BlockGeometry) -> Vec<Vec<u32>> {
        let mut evicted = vec![];
        let usable = self.index.usable_len(tokens.len());
        let nb = usable / geom.block_tokens;
        if nb == 0 {
            return evicted;
        }
        let per = geom.blocks_per_token_block();
        // Evict to fit (active KV accounting is folded into capacity by
        // reserving ~25% headroom at config time).
        let need = nb * per;
        while self.index_blocks + need > self.capacity_blocks
            && self.index.total_token_blocks() > 0
        {
            // Sim groups carry no addresses; count freed *token-blocks*.
            let before_tb = self.index.total_token_blocks();
            let (_, mut prefixes) = self.index.evict_lru_report(1);
            evicted.append(&mut prefixes);
            let freed_tb = before_tb - self.index.total_token_blocks();
            if freed_tb == 0 {
                break;
            }
            self.index_blocks =
                self.index_blocks.saturating_sub(freed_tb * per);
            self.evicted_blocks += (freed_tb * per) as u64;
        }
        let before = self.index.total_token_blocks();
        self.index.insert_unaddressed(&tokens[..usable], now);
        let added = self.index.total_token_blocks() - before;
        self.index_blocks += added * per;
        evicted
    }

    fn index_match(&mut self, tokens: &[u32], now: f64) -> usize {
        self.index.match_prefix(tokens, now).tokens
    }
}

enum Ev {
    /// Release turn `turn` of session `session` (nominal or causal).
    Send { session: usize, turn: usize },
    /// Instance should try to start work.
    Start { inst: usize },
    /// A prefill finished on `inst`.
    PrefillDone { inst: usize, job: Job },
    /// A decode iteration finished; `rids` were in the batch.
    IterDone { inst: usize, rids: Vec<u64> },
    /// Transferred prompt KV landed on decode instance.
    KvArrive { inst: usize, job: Job },
    /// Scripted fleet change (drain / join).
    Fleet { op: FleetOp },
    /// A drain-migration transfer landed on `to`: index + handoff.
    MigrateArrive {
        from: usize,
        to: usize,
        tokens: Vec<u32>,
    },
}

pub struct Simulation {
    cfg: SimConfig,
    spec: WorkloadSpec,
    nominal: BTreeMap<(usize, usize), f64>,
    instances: Vec<Instance>,
    gs: GlobalScheduler,
    /// GS follower replicas, one group per prefix-range shard: every
    /// ownership delta the serving tree applies is mirrored through its
    /// shard's sequenced log, so a scripted [`FleetOp::GsFailover`] can
    /// promote per shard mid-trace. `None` when unreplicated; a
    /// consumed shard (post-failover) stops mirroring, the rest
    /// continue.
    replicas: Option<ShardedReplicaGroup>,
    /// Seeded drop schedule for `replication_drop` (deterministic).
    rep_rng: crate::util::rng::Rng,
    q: EventQueue<Ev>,
    ctx: Vec<Vec<u32>>, // per-session running context
    report: SimReport,
    next_rid: u64,
    /// Metric registry ([`SimConfig::observe`]); disabled = inert.
    obs: Registry,
    /// Trace sink on the *virtual* clock — span timestamps are sim
    /// seconds, so the export shape is identical to the live server's.
    trace: TraceSink,
    flight: FlightRecorder,
    /// Windowed time-series + invariant checker (ISSUE 9), ticked
    /// between popped events on `obs_window_s` boundaries.
    timeline: Timeline,
    watchdog: Watchdog,
    alerts: Vec<Alert>,
    /// Per-instance phase/TTFT/TBT digests + Eq. 1 cost error.
    attrib: AttribBook,
    /// Next virtual-clock frame boundary (first window starts at 0).
    next_frame: f64,
}

impl Simulation {
    pub fn new(cfg: SimConfig, spec: WorkloadSpec, plan: &ArrivalPlan)
               -> Simulation {
        let mut instances = vec![];
        for _ in 0..cfg.prefill_instances {
            instances.push(Instance::new(
                instances.len() as u32,
                InstanceKind::PrefillOnly,
                &cfg,
            ));
        }
        for _ in 0..cfg.decode_instances {
            instances.push(Instance::new(
                instances.len() as u32,
                InstanceKind::DecodeOnly,
                &cfg,
            ));
        }
        for _ in 0..cfg.colocated_instances {
            instances.push(Instance::new(
                instances.len() as u32,
                InstanceKind::Colocated,
                &cfg,
            ));
        }
        assert!(!instances.is_empty());
        let mut gs = GlobalScheduler::with_shards(
            cfg.policy,
            cfg.cost.clone(),
            cfg.geom.block_tokens,
            cfg.tree_ttl,
            cfg.gs_shards.max(1),
        );
        gs.bytes_per_token = cfg.geom.floats_per_token() * 4;
        gs.bandwidth_bytes_per_s = cfg.link.bandwidth;
        gs.per_call_s = cfg.link.call_overhead_s;
        gs.calls_per_token_block = if cfg.geom.aggregated {
            1
        } else {
            2 * cfg.geom.layers
        };
        for inst in &instances {
            gs.add_instance(inst.id, inst.kind);
        }
        let obs = Registry::new(cfg.observe);
        let trace_sink = TraceSink::new(cfg.observe);
        if cfg.observe {
            gs.attach_obs(&obs, None);
        }
        // GS replication: the followers consume the same membership
        // deltas the serving tree starts from.
        let replicas = if cfg.gs_replicas > 0 {
            let mut grp = ShardedReplicaGroup::new(
                cfg.gs_shards.max(1),
                1 + cfg.gs_replicas,
                cfg.geom.block_tokens,
                cfg.tree_ttl,
                256,
            );
            for inst in &instances {
                grp.apply_sync(DeltaEvent::Join {
                    instance: inst.id,
                    kind: inst.kind,
                });
            }
            Some(grp)
        } else {
            None
        };
        let mut nominal = BTreeMap::new();
        for r in &plan.requests {
            nominal.insert((r.session_idx, r.turn_idx), r.nominal_time_s);
        }
        let mut q = EventQueue::new();
        // Seed: turn 0 of every session at its nominal time.
        for (si, _) in spec.sessions.iter().enumerate() {
            if let Some(&t0) = nominal.get(&(si, 0)) {
                q.push(t0, Ev::Send {
                    session: si,
                    turn: 0,
                });
            }
        }
        // Seed the scripted elasticity events.
        for ev in &cfg.fleet {
            q.push(ev.at, Ev::Fleet { op: ev.op.clone() });
        }
        let ctx = spec
            .sessions
            .iter()
            .map(|s| s.shared_prefix.clone())
            .collect();
        let timeline = Timeline::with_window(cfg.obs_window_s.max(1e-9));
        let attrib = AttribBook::new(&obs);
        Simulation {
            cfg,
            spec,
            nominal,
            instances,
            gs,
            replicas,
            rep_rng: crate::util::rng::Rng::new(0xFA_0175),
            q,
            ctx,
            report: SimReport::default(),
            next_rid: 1,
            obs,
            trace: trace_sink,
            flight: FlightRecorder::default(),
            timeline,
            watchdog: Watchdog::default(),
            alerts: vec![],
            attrib,
            next_frame: 0.0,
        }
    }

    /// The single write path of the (replicated) global prompt tree:
    /// apply to the serving tree and mirror through the follower
    /// replicas' sequenced log. Synchronous when lossless (the virtual
    /// clock has no in-flight window to model); with
    /// `replication_drop > 0` each pump's deliveries can drop on the
    /// floor — followers fall behind and recover via gap re-requests
    /// and retransmits, exactly the live transport's discipline.
    fn gs_delta(&mut self, ev: DeltaEvent) {
        self.gs.trees.apply_delta(&ev);
        let p = self.cfg.replication_drop;
        if let Some(grp) = &mut self.replicas {
            if p > 0.0 {
                let rng = &mut self.rep_rng;
                grp.apply(ev);
                grp.pump_lossy(&mut |_, _, _| rng.chance(p));
            } else {
                grp.apply_sync(ev);
            }
        }
    }

    /// Response-path record (Fig 6 right), replicated.
    fn gs_record(&mut self, instance: InstanceId, tokens: &[u32], now: f64) {
        self.gs_delta(DeltaEvent::Record {
            instance,
            tokens: tokens.to_vec(),
            now,
        });
    }

    /// Honest-eviction reports from instance `i`'s local LRU.
    fn gs_evictions(&mut self, i: usize, prefixes: Vec<Vec<u32>>) {
        let id = self.instances[i].id;
        for prefix in prefixes {
            self.gs_delta(DeltaEvent::Expire {
                instance: id,
                prefix,
            });
        }
    }

    /// Deterministic placeholder id for generated token i of (s, t).
    fn synth_token(&self, session: usize, turn: usize, i: usize) -> u32 {
        // Out-of-vocab ids are fine for the index; uniqueness per
        // position keeps prefix matching exact across turns.
        0x4000_0000u32
            .wrapping_add((session as u32) << 18)
            .wrapping_add((turn as u32) << 10)
            .wrapping_add(i as u32)
    }

    /// Run to completion; returns the report.
    pub fn run(mut self) -> SimReport {
        let mut guard = 0u64;
        let limit = 200_000_000;
        while let Some((now, ev)) = self.q.pop() {
            guard += 1;
            assert!(guard < limit, "simulation runaway");
            // Timeline tick (ISSUE 9): runs *between* popped events,
            // never through the queue — pushing obs events would shift
            // push-order sequence tie-breaks and change routing.
            if self.cfg.observe && now >= self.next_frame {
                self.obs_tick(now);
            }
            match ev {
                Ev::Send { session, turn } => self.on_send(now, session, turn),
                Ev::Start { inst } => self.try_start(now, inst),
                Ev::PrefillDone { inst, job } => {
                    self.on_prefill_done(now, inst, job)
                }
                Ev::IterDone { inst, rids } => {
                    self.on_iter_done(now, inst, rids)
                }
                Ev::KvArrive { inst, job } => {
                    // Posting one recv per block is engine work on the
                    // receiver (paper §7's single NCCL thread). While the
                    // instance is idle it overlaps the wire for free;
                    // under load it steals time from the running batch —
                    // modeled by charging the accrued tax to the *next*
                    // decode iteration (only when a batch is running).
                    if !self.instances[inst].active.is_empty() {
                        self.instances[inst].pending_recv_tax +=
                            job.recv_tax;
                    }
                    self.on_kv_arrive(now, inst, job)
                }
                Ev::Fleet { op } => self.on_fleet(now, op),
                Ev::MigrateArrive { from, to, tokens } => {
                    self.on_migrate_arrive(now, from, to, tokens)
                }
            }
        }
        self.report.sim_seconds = self.q.now();
        for inst in &self.instances {
            self.report.gs_believed_token_blocks +=
                self.gs.trees.cached_blocks(inst.id) as u64;
            self.report.indexed_token_blocks +=
                inst.index.total_token_blocks() as u64;
            self.report.evicted_blocks += inst.evicted_blocks;
            let ts = inst.index.touch_stats();
            self.report.touches_deferred += ts.deferred;
            self.report.touches_drained += ts.drained;
            self.report.touches_dropped += ts.dropped;
            assert!(
                inst.prefill_q.is_empty()
                    && inst.active.is_empty()
                    && inst.pending_decode.is_empty(),
                "instance {} finished with stranded work",
                inst.id
            );
        }
        if self.cfg.observe {
            for i in 0..self.instances.len() {
                // A decommissioned instance's LAST fold (taken before
                // its index was torn down) is the one that must
                // survive — re-folding would overwrite it with zeros.
                if self.instances[i].state != InstanceState::Decommissioned {
                    self.fold_instance_stats(i);
                }
            }
            // Close the partial last window and give the watchdog a
            // final pass over it.
            self.fold_shared_obs();
            if self.timeline.flush(self.obs.snapshot(self.report.sim_seconds))
            {
                self.watchdog_pass();
            }
            self.report.obs = Some(SimObs {
                view: ClusterView::capture(&self.obs, self.report.sim_seconds),
                trace: self.trace.clone(),
                flight: self.flight.clone(),
                timeline: self.timeline.clone(),
                alerts: self.alerts.clone(),
            });
        }
        self.report
    }

    /// One timeline tick: close every frame boundary at or before
    /// `now`. Folds the scrape-equivalent stats, feeds the registry
    /// snapshot to the timeline, and runs the watchdog on each closed
    /// frame. Read-only against the sim state (no queue pushes, no
    /// timestamp changes).
    fn obs_tick(&mut self, now: f64) {
        let w = self.cfg.obs_window_s.max(1e-9);
        while now >= self.next_frame {
            let at = self.next_frame;
            for i in 0..self.instances.len() {
                if self.instances[i].state != InstanceState::Decommissioned {
                    self.fold_instance_stats(i);
                }
            }
            self.fold_shared_obs();
            if self.timeline.observe(self.obs.snapshot(at)) {
                self.watchdog_pass();
            }
            self.next_frame += w;
        }
    }

    /// Fold the leader-scrape-equivalent shared stats: per-shard
    /// replication lag (live followers vs the shard's log head) and
    /// trace/flight health.
    fn fold_shared_obs(&self) {
        if let Some(grp) = &self.replicas {
            for s in 0..grp.shards() {
                if grp.is_consumed(s) {
                    continue;
                }
                let Some(g) = grp.group(s) else { continue };
                let head = g.log_head();
                let lags: Vec<(u32, u64)> = g
                    .live_indices()
                    .into_iter()
                    .filter(|&i| i != g.primary_index())
                    .map(|i| {
                        (i as u32, head.saturating_sub(g.applied_seq(i)))
                    })
                    .collect();
                view::fold_replication(&self.obs, s as u32, head, &lags);
            }
        }
        view::fold_trace(&self.obs, &self.trace);
        view::fold_flight(&self.obs, &self.flight);
    }

    /// Run the watchdog over the current frame ring; fired alerts land
    /// in the flight ring (kind `alert`) and in [`SimObs::alerts`].
    fn watchdog_pass(&mut self) {
        let frames = self.timeline.frames();
        let alerts = self.watchdog.check(&frames);
        for a in &alerts {
            self.flight.record(
                a.at,
                u32::MAX,
                fkind::ALERT,
                format!("{} [{}] {}", a.rule, a.subject, a.detail),
            );
        }
        self.alerts.extend(alerts);
    }

    fn on_send(&mut self, now: f64, session: usize, turn: usize) {
        let user = &self.spec.sessions[session].turns[turn];
        let mut prompt = self.ctx[session].clone();
        prompt.extend_from_slice(&user.user_tokens);
        let rid = self.next_rid;
        self.next_rid += 1;

        // --- Global scheduling (paper §6). ---
        // Push loads into the scheduler's book (an unchanged load is an
        // O(1) no-op; the capped cold sample reads the book's policy
        // ordering instead of ranking the whole fleet). Decommissioned
        // instances are skipped — their Leave already purged them from
        // the registry and the book, and re-adding an idle entry would
        // make every cold scan skip over the dead id forever.
        for inst in &self.instances {
            if inst.state == InstanceState::Decommissioned {
                continue;
            }
            self.gs.set_load(inst.id, InstanceLoad {
                queued_tokens: inst.queued_tokens,
                queued_cached_ratio: 0.0,
                running: inst.active.len(),
                capacity_pressure: inst.pressure(),
            });
        }
        let out = self
            .gs
            .route(&prompt, session as u64, now)
            .expect("sim cluster has prefill-capable instances");
        let p_idx = out.decision.instance.0 as usize;
        // Acceptance invariant: the fused tree must never hand a route
        // to a non-Active (Draining/Decommissioned) instance.
        assert_eq!(
            self.instances[p_idx].state,
            InstanceState::Active,
            "routed to non-Active instance {p_idx}"
        );
        // Span chain (ISSUE 8): routing is instantaneous on the
        // virtual clock (zero-length route interval); the queue phase
        // runs until the prefill admits the job.
        let span = trace::request_span(rid);
        self.trace.complete(span, phase::ROUTE, u32::MAX, now, now);
        self.trace.begin(span, phase::QUEUE, p_idx as u32, now);
        // Decode instance: least-loaded Active decode-only
        // (disaggregated), or the same instance (colocated).
        let decode_inst = if self.cfg.decode_instances > 0
            && self.instances[p_idx].kind == InstanceKind::PrefillOnly
        {
            Some(
                self.instances
                    .iter()
                    .enumerate()
                    .filter(|(_, i)| {
                        i.kind == InstanceKind::DecodeOnly
                            && i.state == InstanceState::Active
                    })
                    .min_by_key(|(_, i)| {
                        i.active.len() + i.pending_decode.len()
                    })
                    .map(|(i, _)| i)
                    .expect("no decode instance"),
            )
        } else {
            None
        };

        let rec = RequestRecord {
            request_id: rid,
            session_id: session as u64,
            arrival: now,
            prompt_tokens: prompt.len(),
            prefill_instance: p_idx as u32,
            decode_instance: decode_inst.unwrap_or(p_idx) as u32,
            ..Default::default()
        };
        let job = Job {
            rid,
            session,
            turn,
            prompt,
            cached: 0,
            gen_target: user.target_gen.max(1),
            generated: 0,
            rec,
            decode_inst,
            wire_done: 0.0,
            recv_tax: 0.0,
            predicted_prefill_s: out.expected_prefill_s,
        };
        if let Some(d) = decode_inst {
            self.instances[d].expected_arrivals += 1;
        }
        let inst = &mut self.instances[p_idx];
        inst.queued_tokens += job.prompt.len();
        inst.prefill_q.push_back(job);
        self.q.push(now, Ev::Start { inst: p_idx });
    }

    /// Scripted elasticity: drain (graceful scale-down with optional
    /// migration) or join (scale-up).
    fn on_fleet(&mut self, now: f64, op: FleetOp) {
        match op {
            FleetOp::Join { kind } => {
                let id = self.instances.len() as u32;
                let inst = Instance::new(id, kind, &self.cfg);
                self.flight.record(
                    now,
                    id,
                    fkind::MEMBERSHIP,
                    format!("joined as {kind:?}"),
                );
                self.gs_delta(DeltaEvent::Join {
                    instance: InstanceId(id),
                    kind,
                });
                self.instances.push(inst);
            }
            FleetOp::GsFailover { shard } => {
                // The serving tree's crashed shard(s): promote each
                // one's most-caught-up follower (catch-up included) and
                // hand its tree to the scheduler's shard slot. Since
                // every delta was mirrored through the shard's
                // sequenced log, the promoted replica's route decisions
                // are identical to the lost primary's — the trace
                // continues as if nothing happened (zero request loss,
                // zero locality loss). Promoted shards are consumed: a
                // second failover of the same shard needs fresh
                // replicas; untouched shards keep mirroring.
                self.flight.record(
                    now,
                    shard.map(|s| s as u32).unwrap_or(u32::MAX),
                    fkind::SUSPICION,
                    "scripted GS primary crash",
                );
                let p = self.cfg.replication_drop;
                let rng = &mut self.rep_rng;
                let grp = self.replicas.as_mut().expect(
                    "GsFailover needs gs_replicas > 0 and fires at \
                     most once per shard per trace",
                );
                // Lossy mirroring: drive the transports to convergence
                // first (the live protocol's retransmit/ack loop runs
                // until quiesce before a promotion reply is captured) —
                // still dropping per delivery, so convergence is won by
                // gap repair, not by turning the faults off.
                if p > 0.0 {
                    let mut guard = 0u32;
                    while !grp.all_caught_up() {
                        grp.pump_lossy(&mut |_, _, _| rng.chance(p));
                        guard += 1;
                        assert!(
                            guard < 1_000_000,
                            "replication never converged pre-promotion"
                        );
                    }
                }
                let targets: Vec<usize> = match shard {
                    Some(s) => vec![s],
                    None => (0..grp.shards()).collect(),
                };
                for s in targets {
                    let promoted = grp
                        .fail_primary(s)
                        .expect("gs_replicas >= 1 leaves a follower");
                    let tree = grp
                        .extract_tree(s, promoted)
                        .expect("promoted shard still live");
                    self.gs.trees.set_shard_tree(s, tree);
                    self.report.gs_failovers += 1;
                    self.flight.record(
                        now,
                        s as u32,
                        fkind::PROMOTION,
                        format!("promoted replica {promoted}"),
                    );
                    self.trace.complete(
                        trace::promotion_span(s as u64),
                        phase::PROMOTE,
                        u32::MAX,
                        now,
                        now,
                    );
                }
            }
            FleetOp::Drain { inst, migrate } => {
                if self.instances[inst].state != InstanceState::Active {
                    return;
                }
                // Mirror the live leader's refusal, but fail fast: a
                // script draining the last routable prefill-capable
                // instance is author error — surface it here instead of
                // a confusing route panic at the next arrival.
                if self.instances[inst].kind.runs_prefill() {
                    assert!(
                        self.instances.iter().enumerate().any(|(j, x)| {
                            j != inst
                                && x.state == InstanceState::Active
                                && x.kind.runs_prefill()
                        }),
                        "fleet script drains the last Active \
                         prefill-capable instance"
                    );
                }
                self.instances[inst].state = InstanceState::Draining;
                let id = self.instances[inst].id;
                self.flight
                    .record(now, id.0, fkind::MEMBERSHIP, "draining");
                // Routing stops seeing it immediately; its view stays
                // matchable for the planner.
                self.gs_delta(DeltaEvent::SetDraining {
                    instance: id,
                    draining: true,
                });
                if migrate {
                    let recipients: Vec<Recipient> = self
                        .instances
                        .iter()
                        .enumerate()
                        .filter(|(j, x)| {
                            *j != inst
                                && x.state == InstanceState::Active
                                && x.kind.runs_prefill()
                        })
                        .map(|(_, x)| Recipient {
                            id: x.id,
                            pressure: x.pressure(),
                        })
                        .collect();
                    let plan = plan_migration(
                        &self.gs.trees,
                        id,
                        now,
                        &recipients,
                        &PlannerConfig::default(),
                    );
                    self.report.dropped_token_blocks +=
                        plan.dropped_blocks as u64;
                    // Each task serializes on the donor's outbound NCCL
                    // thread, like any other KV transfer (paper §7).
                    for task in plan.tasks {
                        let ship = task.tokens.len();
                        let bytes = self
                            .cfg
                            .transfer_mode
                            .network_bytes(&self.cfg.geom, ship);
                        let calls = self
                            .cfg
                            .transfer_mode
                            .network_calls(&self.cfg.geom, ship);
                        let wire = self
                            .cfg
                            .link
                            .transfer_seconds(bytes, calls, false, false);
                        self.report.wire_bytes += bytes as u64;
                        self.report.wire_calls += calls as u64;
                        self.report.wire_seconds += wire;
                        let begin = now.max(self.instances[inst].wire_free);
                        let done = begin + wire;
                        self.instances[inst].wire_free = done;
                        self.instances[inst].pending_migrations += 1;
                        self.q.push(done, Ev::MigrateArrive {
                            from: inst,
                            to: task.to.0 as usize,
                            tokens: task.tokens,
                        });
                    }
                } else {
                    // Naive decommission: the whole view dies with the
                    // instance.
                    self.report.dropped_token_blocks +=
                        self.gs.trees.cached_blocks(id) as u64;
                }
                self.maybe_decommission(inst);
            }
        }
    }

    /// A migrated prefix landed: index it on the receiver and re-point
    /// global-tree ownership atomically (routing never saw it as lost —
    /// the donor stayed matchable until this handoff).
    fn on_migrate_arrive(
        &mut self,
        now: f64,
        from: usize,
        to: usize,
        tokens: Vec<u32>,
    ) {
        let geom = self.cfg.geom;
        let blocks = tokens.len() / geom.block_tokens;
        if self.instances[to].state != InstanceState::Active {
            // Overlapping drains: the recipient left (or is leaving)
            // since planning. The transfer is wasted — the donor keeps
            // its claim until its own Leave; count the blocks dropped.
            self.report.dropped_token_blocks += blocks as u64;
            self.instances[from].pending_migrations -= 1;
            self.maybe_decommission(from);
            return;
        }
        let evicted = self.instances[to].index_insert(&tokens, now, &geom);
        self.gs_evictions(to, evicted);
        let (fid, tid) = (self.instances[from].id, self.instances[to].id);
        self.gs_delta(DeltaEvent::Handoff {
            from: fid,
            to: tid,
            tokens,
            now,
        });
        self.report.migrated_token_blocks += blocks as u64;
        self.instances[from].pending_migrations -= 1;
        self.maybe_decommission(from);
    }

    /// A Draining instance with no outstanding migrations and no work
    /// left (zero request loss) leaves the fleet for good.
    fn maybe_decommission(&mut self, i: usize) {
        let inst = &self.instances[i];
        if inst.state != InstanceState::Draining
            || inst.pending_migrations > 0
            || inst.expected_arrivals > 0
            || inst.busy
            || !inst.prefill_q.is_empty()
            || !inst.active.is_empty()
            || !inst.pending_decode.is_empty()
        {
            return;
        }
        let id = inst.id;
        // Counter-loss fix (ISSUE 8 satellite, sim half): fold the
        // final index stats into the registry BEFORE the index is
        // replaced — the decommissioned instance's counters survive
        // into the end-of-run cluster view.
        if self.cfg.observe {
            self.fold_instance_stats(i);
        }
        self.flight
            .record(self.q.now(), id.0, fkind::DEREGISTER, "decommissioned");
        self.instances[i].state = InstanceState::Decommissioned;
        self.instances[i].index =
            RadixIndex::new(self.cfg.geom.block_tokens, 0.0);
        self.instances[i].index_blocks = 0;
        self.gs_delta(DeltaEvent::Leave { instance: id });
    }

    /// Fold one sim instance's ad-hoc counters (touch stats, eviction
    /// and residency totals) into the registry under its instance
    /// label. Absolute stores — idempotent across repeated folds.
    fn fold_instance_stats(&self, i: usize) {
        let inst = &self.instances[i];
        let l = Labels::instance(inst.id.0);
        let ts = inst.index.touch_stats();
        self.obs.set_counter("pool.touches_deferred", l, ts.deferred);
        self.obs.set_counter("pool.touches_drained", l, ts.drained);
        self.obs.set_counter("pool.touches_dropped", l, ts.dropped);
        self.obs.set_counter("pool.evicted_blocks", l, inst.evicted_blocks);
        self.obs.set_counter(
            "pool.indexed_token_blocks",
            l,
            inst.index.total_token_blocks() as u64,
        );
        // The GS's side of the divergence pair (ISSUE 9 watchdog):
        // what the global tree *believes* this instance caches, vs the
        // `pool.indexed_token_blocks` truth above.
        self.obs.set_counter(
            "gs.believed_token_blocks",
            l,
            self.gs.trees.cached_blocks(inst.id) as u64,
        );
    }

    /// Serial-resource discipline: prefill-first, then decode iteration.
    fn try_start(&mut self, now: f64, i: usize) {
        if self.instances[i].busy {
            return;
        }
        // Admit pending decodes up to the batch cap at iteration
        // boundaries.
        while self.instances[i].active.len() < self.cfg.max_batch {
            match self.instances[i].pending_decode.pop_front() {
                Some(j) => self.instances[i].active.push(j),
                None => break,
            }
        }
        if let Some(mut job) = self.instances[i].prefill_q.pop_front() {
            // --- Prefill (with local cache match). ---
            let span = trace::request_span(job.rid);
            self.trace.end(span, phase::QUEUE, now);
            self.trace.begin(span, phase::PREFILL, i as u32, now);
            self.instances[i].queued_tokens =
                self.instances[i].queued_tokens.saturating_sub(job.prompt.len());
            let cached = if self.cfg.caching {
                let max_cached = (job.prompt.len() - 1)
                    / self.cfg.geom.block_tokens
                    * self.cfg.geom.block_tokens;
                self.instances[i]
                    .index_match(&job.prompt[..max_cached], now)
            } else {
                0
            };
            job.cached = cached;
            job.rec.scheduled = now;
            job.rec.cached_tokens = cached;
            let x = job.prompt.len();
            let y = cached as f64 / x.max(1) as f64;
            let exec = self.cfg.cost.exec(x, y);
            // Transfer cost to the decode instance (disagg only): the
            // *new* suffix always ships; with decode-side caching the
            // prefix the decoder already holds is skipped (incremental
            // transfer, paper §5.1c).
            if let Some(d) = job.decode_inst {
                let skip = if self.cfg.milestone.decode_caches()
                    && self.cfg.caching
                {
                    let max_cached = (job.prompt.len() - 1)
                        / self.cfg.geom.block_tokens
                        * self.cfg.geom.block_tokens;
                    self.instances[d]
                        .index_match(&job.prompt[..max_cached], now)
                } else {
                    0
                };
                let ship_tokens = x - skip;
                let bytes =
                    self.cfg.transfer_mode.network_bytes(&self.cfg.geom,
                                                         ship_tokens);
                let calls =
                    self.cfg.transfer_mode.network_calls(&self.cfg.geom,
                                                         ship_tokens);
                let wire =
                    self.cfg.link.transfer_seconds(bytes, calls, false, false);
                self.report.wire_bytes += bytes as u64;
                self.report.wire_calls += calls as u64;
                self.report.wire_seconds += wire;
                // The wire is a separate serialized resource (one NCCL
                // thread per communicator, paper §7). By-layer may start
                // streaming while the prefill computes (overlap), but a
                // request's KV cannot fully land before its own last
                // layer finishes (+ that layer's share of wire time);
                // by-req(/agg) only starts after the prefill completes.
                let start = if self.cfg.transfer_mode.overlaps_compute() {
                    now // streams alongside compute
                } else {
                    now + exec
                };
                let begin = start.max(self.instances[i].wire_free);
                let mut done = begin + wire;
                if self.cfg.transfer_mode.overlaps_compute() {
                    done = done.max(
                        now + exec + wire / self.cfg.geom.layers as f64,
                    );
                }
                self.instances[i].wire_free = done;
                job.wire_done = done;
                job.recv_tax = calls as f64 * self.cfg.link.call_overhead_s
                    / self.cfg.link.communicators as f64;
            }
            self.instances[i].busy = true;
            self.q.push(now + exec, Ev::PrefillDone {
                inst: i,
                job,
            });
        } else if !self.instances[i].active.is_empty() {
            // --- One continuous-batching decode iteration. ---
            let inst = &mut self.instances[i];
            let sum_ctx: usize = inst
                .active
                .iter()
                .map(|j| j.prompt.len() + j.generated)
                .sum();
            let dur = self.cfg.cost.decode_base
                / self.cfg.cost.tp as f64
                + self.cfg.cost.decode_per_ctx_token * sum_ctx as f64
                    / self.cfg.cost.tp as f64
                + std::mem::take(&mut inst.pending_recv_tax);
            let rids: Vec<u64> = inst.active.iter().map(|j| j.rid).collect();
            self.instances[i].busy = true;
            self.q.push(now + dur, Ev::IterDone {
                inst: i,
                rids,
            });
        } else {
            // Idle: a draining instance with nothing left to do (and no
            // transfers in flight) can decommission now.
            self.maybe_decommission(i);
        }
    }

    fn on_prefill_done(&mut self, now: f64, i: usize, mut job: Job) {
        self.instances[i].busy = false;
        let span = trace::request_span(job.rid);
        self.trace.end(span, phase::PREFILL, now);
        self.attrib.observe_phase_secs(
            i as u32,
            phase::PREFILL,
            now - job.rec.scheduled,
        );
        job.rec.first_token = now; // prefill emits the first token
        job.generated = 1;
        // Caching at the prefill side (milestone step 2 / colocated).
        let prefill_caches = match self.instances[i].kind {
            InstanceKind::Colocated => self.cfg.caching,
            InstanceKind::PrefillOnly => {
                self.cfg.caching && self.cfg.milestone.prefill_caches()
            }
            InstanceKind::DecodeOnly => false,
        };
        if prefill_caches {
            let prompt = job.prompt.clone();
            let geom = self.cfg.geom;
            let evicted = self.instances[i].index_insert(&prompt, now, &geom);
            self.gs_evictions(i, evicted);
            let id = self.instances[i].id;
            self.gs_record(id, &prompt, now);
        }
        match job.decode_inst {
            Some(d) => {
                // The KV lands when its (serialized) transfer completes.
                self.trace.begin(span, phase::KV_TRANSFER, i as u32, now);
                let at = job.wire_done.max(now);
                self.q.push(at, Ev::KvArrive {
                    inst: d,
                    job,
                });
            }
            None => {
                // Colocated: join the local decode set.
                self.trace.begin(span, phase::DECODE, i as u32, now);
                if job.generated >= job.gen_target {
                    self.finish(now, i, job);
                } else if self.instances[i].active.len() < self.cfg.max_batch {
                    self.instances[i].active.push(job);
                } else {
                    self.instances[i].pending_decode.push_back(job);
                }
            }
        }
        self.q.push(now, Ev::Start { inst: i });
    }

    fn on_kv_arrive(&mut self, now: f64, d: usize, mut job: Job) {
        self.instances[d].expected_arrivals -= 1;
        let span = trace::request_span(job.rid);
        self.trace.end(span, phase::KV_TRANSFER, now);
        self.attrib.observe_phase_secs(
            d as u32,
            phase::KV_TRANSFER,
            now - job.rec.first_token,
        );
        self.trace.begin(span, phase::DECODE, d as u32, now);
        // Decode-side caching of the transferred prompt KV
        // (transfer_with_insert — milestone step 3).
        if self.cfg.caching && self.cfg.milestone.decode_caches() {
            let prompt = job.prompt.clone();
            let geom = self.cfg.geom;
            let evicted = self.instances[d].index_insert(&prompt, now, &geom);
            self.gs_evictions(d, evicted);
        }
        if job.generated >= job.gen_target {
            self.finish(now, d, job);
        } else {
            job.rec.decode_instance = d as u32;
            if self.instances[d].active.len() < self.cfg.max_batch {
                self.instances[d].active.push(job);
            } else {
                self.instances[d].pending_decode.push_back(job);
            }
            self.q.push(now, Ev::Start { inst: d });
        }
    }

    fn on_iter_done(&mut self, now: f64, i: usize, rids: Vec<u64>) {
        self.instances[i].busy = false;
        let mut finished = vec![];
        for rid in rids {
            let Some(pos) = self.instances[i]
                .active
                .iter()
                .position(|j| j.rid == rid)
            else {
                continue;
            };
            let j = &mut self.instances[i].active[pos];
            j.generated += 1;
            if j.generated >= j.gen_target {
                finished.push(self.instances[i].active.swap_remove(pos));
            }
        }
        for job in finished {
            self.finish(now, i, job);
        }
        self.q.push(now, Ev::Start { inst: i });
    }

    /// Request completion: metrics, session continuation, decode-side
    /// retirement + D→P transfer (milestone step 5).
    fn finish(&mut self, now: f64, inst_idx: usize, mut job: Job) {
        job.rec.completion = now;
        job.rec.output_tokens = job.gen_target;
        let span = trace::request_span(job.rid);
        self.trace.end(span, phase::DECODE, now);
        self.trace
            .complete(span, phase::RETIRE, inst_idx as u32, now, now);
        // Retire-side attribution (ISSUE 9): decode duration on the
        // finishing instance; queue/TTFT/TBT + the Eq. 1 cost error on
        // the prefill instance the router charged the prediction to.
        self.attrib.observe_phase_secs(
            inst_idx as u32,
            phase::DECODE,
            now - job.rec.first_token,
        );
        self.attrib.observe_retire(
            job.rec.prefill_instance,
            &crate::obs::RetireSample {
                arrival: job.rec.arrival,
                scheduled: job.rec.scheduled,
                first_token: job.rec.first_token,
                completion: now,
                output_tokens: job.gen_target,
                predicted_prefill_s: job.predicted_prefill_s,
            },
        );
        // Build the full consumed sequence (prompt + generated KV).
        let mut seq = job.prompt.clone();
        for k in 0..job.gen_target {
            seq.push(self.synth_token(job.session, job.turn, k));
        }
        let on_decode_only =
            self.instances[inst_idx].kind == InstanceKind::DecodeOnly;
        if self.cfg.caching
            && (!on_decode_only || self.cfg.milestone.decode_caches())
        {
            let geom = self.cfg.geom;
            let evicted =
                self.instances[inst_idx].index_insert(&seq, now, &geom);
            self.gs_evictions(inst_idx, evicted);
            if !on_decode_only {
                let id = self.instances[inst_idx].id;
                self.gs_record(id, &seq, now);
            }
        }
        // Step 5: decode KV flows back to the prefill instance so its
        // cache grows turn over turn (unless that instance has left or
        // is leaving the fleet).
        if on_decode_only
            && self.cfg.caching
            && self.cfg.milestone.decode_to_prefill()
            && self.instances[job.rec.prefill_instance as usize].state
                == InstanceState::Active
        {
            let p = job.rec.prefill_instance as usize;
            // Incremental: only the decode-produced suffix ships back.
            let ship_tokens = job.gen_target;
            let bytes = self
                .cfg
                .transfer_mode
                .network_bytes(&self.cfg.geom, ship_tokens);
            let calls = self
                .cfg
                .transfer_mode
                .network_calls(&self.cfg.geom, ship_tokens);
            let wire =
                self.cfg.link.transfer_seconds(bytes, calls, false, false);
            self.report.wire_bytes += bytes as u64;
            self.report.wire_calls += calls as u64;
            self.report.wire_seconds += wire;
            let geom = self.cfg.geom;
            let evicted =
                self.instances[p].index_insert(&seq, now + wire, &geom);
            self.gs_evictions(p, evicted);
            let id = self.instances[p].id;
            self.gs_record(id, &seq, now + wire);
        }
        // Session continuation (causal dependency).
        self.ctx[job.session] = seq;
        let next = job.turn + 1;
        if let Some(&nom) = self.nominal.get(&(job.session, next)) {
            self.q.push(nom.max(now), Ev::Send {
                session: job.session,
                turn: next,
            });
        }
        self.report.metrics.push(job.rec);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mempool::DEFERRED_TOUCH_CAP;
    use crate::workload::WorkloadKind;

    fn workload_kind(kind: WorkloadKind, n: usize, seed: u64)
                     -> (WorkloadSpec, ArrivalPlan) {
        let spec = WorkloadSpec::generate(
            kind,
            n,
            seed,
            2048,
            4096, // paper-scale context for the 13B cost model
        );
        let plan = ArrivalPlan::poisson(&spec, 4.0, seed);
        (spec, plan)
    }

    fn workload(n: usize, seed: u64) -> (WorkloadSpec, ArrivalPlan) {
        workload_kind(WorkloadKind::Loogle, n, seed)
    }

    fn run(cfg: SimConfig, n: usize, seed: u64) -> SimReport {
        let (spec, plan) = workload(n, seed);
        Simulation::new(cfg, spec, &plan).run()
    }

    fn run_kind(cfg: SimConfig, kind: WorkloadKind, n: usize, seed: u64)
                -> SimReport {
        let (spec, plan) = workload_kind(kind, n, seed);
        Simulation::new(cfg, spec, &plan).run()
    }

    fn pd_colocated(caching: bool) -> SimConfig {
        SimConfig {
            prefill_instances: 0,
            decode_instances: 0,
            colocated_instances: 2,
            caching,
            ..Default::default()
        }
    }

    fn disagg(caching: bool) -> SimConfig {
        SimConfig {
            prefill_instances: 1,
            decode_instances: 1,
            colocated_instances: 0,
            caching,
            milestone: if caching {
                DisaggMilestone::PdCaching3
            } else {
                DisaggMilestone::PdBasic
            },
            ..Default::default()
        }
    }

    #[test]
    fn all_requests_complete() {
        let (spec, plan) = workload(20, 1);
        let total = spec.total_requests();
        let rep = Simulation::new(pd_colocated(true), spec, &plan).run();
        assert_eq!(rep.metrics.records.len(), total);
        for r in &rep.metrics.records {
            assert!(r.completion >= r.first_token);
            assert!(r.first_token >= r.scheduled);
            assert!(r.scheduled >= r.arrival);
        }
    }

    #[test]
    fn caching_improves_ttft() {
        let base = run(pd_colocated(false), 30, 2);
        let cached = run(pd_colocated(true), 30, 2);
        let t0 = base.metrics.ttft().mean;
        let t1 = cached.metrics.ttft().mean;
        assert!(
            t1 < t0 * 0.8,
            "caching should cut TTFT markedly: {t1} vs {t0}"
        );
        assert!(cached.metrics.mean_cached_ratio() > 0.3);
        assert_eq!(base.metrics.mean_cached_ratio(), 0.0);
    }

    #[test]
    fn disagg_with_caching_beats_plain_disagg() {
        let plain = run(disagg(false), 30, 3);
        let cached = run(disagg(true), 30, 3);
        assert!(
            cached.metrics.jct().mean < plain.metrics.jct().mean,
            "caching must improve disaggregated JCT"
        );
        assert!(
            cached.metrics.ttft().mean < plain.metrics.ttft().mean * 0.8
        );
    }

    #[test]
    fn milestone3_grows_prefill_cache_over_turns() {
        // PD-Caching-1 vs PD-Caching-3: with decode→prefill backflow the
        // prefill cache covers previous turns' generations, so multi-turn
        // cached ratio is higher.
        let mk = |m: DisaggMilestone| SimConfig {
            milestone: m,
            ..disagg(true)
        };
        // ShareGPT: long generations -> the decode→prefill backflow
        // (step 5) matters most there (paper §5.1d).
        let m1 = run_kind(mk(DisaggMilestone::PdCaching1),
                          WorkloadKind::ShareGpt, 30, 4);
        let m3 = run_kind(mk(DisaggMilestone::PdCaching3),
                          WorkloadKind::ShareGpt, 30, 4);
        assert!(
            m3.metrics.mean_cached_ratio()
                > m1.metrics.mean_cached_ratio() + 0.05,
            "m3={} m1={}",
            m3.metrics.mean_cached_ratio(),
            m1.metrics.mean_cached_ratio()
        );
        assert!(m3.metrics.ttft().mean < m1.metrics.ttft().mean);
    }

    #[test]
    fn decode_side_caching_cuts_wire_traffic() {
        let m1 = run(SimConfig {
            milestone: DisaggMilestone::PdCaching1,
            ..disagg(true)
        }, 30, 5);
        let m2 = run(SimConfig {
            milestone: DisaggMilestone::PdCaching2,
            ..disagg(true)
        }, 30, 5);
        assert!(
            m2.wire_bytes < m1.wire_bytes,
            "incremental transfer must ship fewer bytes: {} vs {}",
            m2.wire_bytes,
            m1.wire_bytes
        );
    }

    #[test]
    fn by_req_agg_reduces_calls_2l_times() {
        let mut disc = disagg(false);
        disc.transfer_mode = TransferMode::ByRequest;
        let mut agg = disagg(false);
        agg.transfer_mode = TransferMode::ByRequestAgg;
        let rep_d = run(disc, 15, 6);
        let rep_a = run(agg, 15, 6);
        assert_eq!(rep_d.wire_bytes, rep_a.wire_bytes);
        assert_eq!(rep_d.wire_calls, rep_a.wire_calls * 2 * 40);
        assert!(rep_a.wire_seconds < rep_d.wire_seconds);
    }

    #[test]
    fn deterministic_replay() {
        let a = run(disagg(true), 15, 7);
        let b = run(disagg(true), 15, 7);
        assert_eq!(a.metrics.records.len(), b.metrics.records.len());
        assert_eq!(a.wire_bytes, b.wire_bytes);
        let ja = a.metrics.jct();
        let jb = b.metrics.jct();
        assert_eq!(ja.mean, jb.mean);
    }

    #[test]
    fn fleet_scale_routing_64_prefill_instances() {
        // The fused global tree makes routing O(prompt_blocks) in the
        // instance count; this exercises the full sim loop at a fleet
        // size the seed's per-instance walk made painful, including
        // TTL housekeeping on the routing path.
        let cfg = SimConfig {
            prefill_instances: 64,
            decode_instances: 4,
            colocated_instances: 0,
            tree_ttl: 60.0,
            ..disagg(true)
        };
        let (spec, plan) = workload(25, 11);
        let total = spec.total_requests();
        let rep = Simulation::new(cfg, spec, &plan).run();
        assert_eq!(rep.metrics.records.len(), total);
        assert!(rep.metrics.mean_cached_ratio() > 0.0);
    }

    #[test]
    fn drain_with_migration_preserves_cache_and_completes_all() {
        let drain_at = 6.0;
        let mk = |migrate: bool| SimConfig {
            prefill_instances: 4,
            decode_instances: 2,
            colocated_instances: 0,
            fleet: vec![FleetEvent {
                at: drain_at,
                op: FleetOp::Drain { inst: 0, migrate },
            }],
            ..disagg(true)
        };
        let post_ratio = |rep: &SimReport| {
            let post: Vec<_> = rep
                .metrics
                .records
                .iter()
                .filter(|r| r.scheduled > drain_at)
                .collect();
            assert!(!post.is_empty());
            post.iter()
                .map(|r| {
                    r.cached_tokens as f64 / r.prompt_tokens.max(1) as f64
                })
                .sum::<f64>()
                / post.len() as f64
        };
        let (spec, plan) = workload(60, 21);
        let total = spec.total_requests();
        let naive = Simulation::new(mk(false), spec.clone(), &plan).run();
        let migr = Simulation::new(mk(true), spec, &plan).run();
        // Zero request loss under both scale-downs (the in-sim assert
        // also guarantees no post-drain route touched instance 0).
        assert_eq!(naive.metrics.records.len(), total);
        assert_eq!(migr.metrics.records.len(), total);
        for rep in [&naive, &migr] {
            for r in &rep.metrics.records {
                if r.scheduled > drain_at {
                    assert_ne!(r.prefill_instance, 0, "routed to drained");
                }
            }
        }
        assert!(migr.migrated_token_blocks > 0, "nothing migrated");
        assert_eq!(naive.migrated_token_blocks, 0);
        assert!(naive.dropped_token_blocks > 0);
        // Migration must preserve fleet-wide hit rate after the drain.
        let (rm, rn) = (post_ratio(&migr), post_ratio(&naive));
        assert!(
            rm > rn,
            "migrate-on-drain should beat naive decommission: {rm} vs {rn}"
        );
    }

    #[test]
    fn gs_failover_zero_loss_identical_routing() {
        // The ISSUE 4 acceptance bar: crash the GS primary mid-trace
        // with 2 follower replicas. Zero request loss, and — because
        // the promoted follower replayed the same sequenced delta
        // stream — every subsequent route decision must be identical to
        // an uninterrupted single-GS reference run.
        let mk = |failover: bool| SimConfig {
            prefill_instances: 3,
            decode_instances: 2,
            colocated_instances: 0,
            gs_replicas: if failover { 2 } else { 0 },
            fleet: if failover {
                vec![FleetEvent {
                    at: 5.0,
                    op: FleetOp::GsFailover { shard: None },
                }]
            } else {
                vec![]
            },
            ..disagg(true)
        };
        let (spec, plan) = workload(50, 31);
        let total = spec.total_requests();
        let reference = Simulation::new(mk(false), spec.clone(), &plan).run();
        let crashed = Simulation::new(mk(true), spec, &plan).run();
        assert_eq!(crashed.gs_failovers, 1, "failover did not fire");
        assert_eq!(reference.gs_failovers, 0);
        // Zero request loss.
        assert_eq!(reference.metrics.records.len(), total);
        assert_eq!(crashed.metrics.records.len(), total);
        // Route-decision convergence: per-request prefill AND decode
        // placement identical, timings included (the promoted tree is
        // state-identical, so the whole trace replays bit-equal).
        let key = |m: &Metrics| {
            let mut v: Vec<_> = m
                .records
                .iter()
                .map(|r| {
                    (
                        r.request_id,
                        r.prefill_instance,
                        r.decode_instance,
                        r.cached_tokens,
                    )
                })
                .collect();
            v.sort_unstable();
            v
        };
        assert_eq!(
            key(&reference.metrics),
            key(&crashed.metrics),
            "promoted GS diverged from the uninterrupted reference"
        );
    }

    #[test]
    fn sharded_gs_identical_routing_and_per_shard_failover() {
        // ISSUE 5 acceptance, end to end: (a) sharding the GS tree
        // (S=2) must not change a single routing decision vs the
        // unsharded reference run; (b) crashing ONE shard's primary
        // mid-trace and promoting its follower must leave the whole
        // trace identical too (the other shard never even notices).
        let mk = |shards: usize, failover: Option<usize>| SimConfig {
            prefill_instances: 3,
            decode_instances: 2,
            colocated_instances: 0,
            gs_shards: shards,
            gs_replicas: if failover.is_some() { 2 } else { 0 },
            fleet: match failover {
                Some(s) => vec![FleetEvent {
                    at: 5.0,
                    op: FleetOp::GsFailover { shard: Some(s) },
                }],
                None => vec![],
            },
            ..disagg(true)
        };
        let (spec, plan) = workload(40, 33);
        let total = spec.total_requests();
        let flat = Simulation::new(mk(1, None), spec.clone(), &plan).run();
        let sharded = Simulation::new(mk(2, None), spec.clone(), &plan)
            .run();
        let crashed = Simulation::new(mk(2, Some(1)), spec, &plan).run();
        assert_eq!(crashed.gs_failovers, 1, "per-shard failover missed");
        // Zero request loss everywhere.
        for rep in [&flat, &sharded, &crashed] {
            assert_eq!(rep.metrics.records.len(), total);
        }
        let key = |m: &Metrics| {
            let mut v: Vec<_> = m
                .records
                .iter()
                .map(|r| {
                    (
                        r.request_id,
                        r.prefill_instance,
                        r.decode_instance,
                        r.cached_tokens,
                    )
                })
                .collect();
            v.sort_unstable();
            v
        };
        assert_eq!(
            key(&flat.metrics),
            key(&sharded.metrics),
            "sharding changed routing decisions"
        );
        assert_eq!(
            key(&sharded.metrics),
            key(&crashed.metrics),
            "per-shard failover diverged from the uninterrupted run"
        );
    }

    #[test]
    fn lossy_replication_converges_to_lossless_routing() {
        // ISSUE 6: mirror every GS delta through a 20%-drop replication
        // stream, then crash a shard's primary mid-trace. The transport
        // recovers losses via gap repair/retransmits and the failover
        // pumps to convergence before promoting, so the whole trace —
        // every placement and cached-token count — must be identical
        // to the lossless-replication run.
        let mk = |drop: f64| SimConfig {
            prefill_instances: 3,
            decode_instances: 2,
            colocated_instances: 0,
            gs_shards: 2,
            gs_replicas: 2,
            replication_drop: drop,
            fleet: vec![FleetEvent {
                at: 5.0,
                op: FleetOp::GsFailover { shard: Some(0) },
            }],
            ..disagg(true)
        };
        let (spec, plan) = workload(40, 35);
        let total = spec.total_requests();
        let lossless = Simulation::new(mk(0.0), spec.clone(), &plan).run();
        let lossy = Simulation::new(mk(0.2), spec, &plan).run();
        assert_eq!(lossless.gs_failovers, 1);
        assert_eq!(lossy.gs_failovers, 1);
        assert_eq!(lossless.metrics.records.len(), total);
        assert_eq!(lossy.metrics.records.len(), total);
        let key = |m: &Metrics| {
            let mut v: Vec<_> = m
                .records
                .iter()
                .map(|r| {
                    (
                        r.request_id,
                        r.prefill_instance,
                        r.decode_instance,
                        r.cached_tokens,
                    )
                })
                .collect();
            v.sort_unstable();
            v
        };
        assert_eq!(
            key(&lossless.metrics),
            key(&lossy.metrics),
            "lossy replication changed the trace"
        );
    }

    #[test]
    fn honest_evictions_reach_the_global_tree() {
        // Tiny caches force LRU churn; the honest-eviction Expire
        // deltas must keep the GS's believed blocks within the actual
        // local index totals (no stale over-belief), while the trace
        // still completes. (Pre-ISSUE-4 the GS only ever learned about
        // inserts, so its view could only over-count between TTLs.)
        let mut cfg = pd_colocated(true);
        cfg.hbm_blocks = 64;
        cfg.tree_ttl = 0.0; // no TTL: evictions are the ONLY cleanup
        let (spec, plan) = workload(40, 8);
        let total = spec.total_requests();
        let sim = Simulation::new(cfg, spec, &plan);
        let rep = sim.run();
        assert_eq!(rep.metrics.records.len(), total);
        assert!(rep.evicted_blocks > 0, "workload must churn the cache");
        assert!(
            rep.gs_believed_token_blocks <= rep.indexed_token_blocks,
            "GS over-believes despite honest evictions: believed {} > \
             indexed {}",
            rep.gs_believed_token_blocks,
            rep.indexed_token_blocks
        );
        // Deferred-touch accounting (ISSUE 7): the match path defers
        // LRU stamps, `&mut` ops drain them. A drain can never refresh
        // more than was queued, and the undrained backlog is bounded
        // by each instance's queue capacity — late stamps are the only
        // slack in the over-belief story above, and it is bounded.
        assert!(
            rep.touches_drained <= rep.touches_deferred,
            "drained {} > deferred {}",
            rep.touches_drained,
            rep.touches_deferred
        );
        // pd_colocated runs 2 instances, each with one bounded queue.
        let cap = DEFERRED_TOUCH_CAP as u64 * 2;
        assert!(
            rep.touches_deferred - rep.touches_drained <= cap,
            "undrained touch backlog {} exceeds the per-instance queue \
             bound {}",
            rep.touches_deferred - rep.touches_drained,
            cap
        );
    }

    #[test]
    fn join_mid_run_takes_load() {
        let cfg = SimConfig {
            prefill_instances: 2,
            decode_instances: 1,
            colocated_instances: 0,
            fleet: vec![FleetEvent {
                at: 3.0,
                op: FleetOp::Join {
                    kind: InstanceKind::PrefillOnly,
                },
            }],
            ..disagg(true)
        };
        let (spec, plan) = workload(30, 22);
        let total = spec.total_requests();
        let rep = Simulation::new(cfg, spec, &plan).run();
        assert_eq!(rep.metrics.records.len(), total);
        // The joined instance (id 3: after 2 prefill + 1 decode) must
        // end up serving some of the post-join traffic.
        assert!(
            rep.metrics.records.iter().any(|r| r.prefill_instance == 3),
            "joined instance never routed to"
        );
    }

    #[test]
    fn capacity_pressure_triggers_eviction() {
        let mut cfg = pd_colocated(true);
        cfg.hbm_blocks = 64; // tiny cache
        let rep = run(cfg, 40, 8);
        assert!(rep.evicted_blocks > 0, "no eviction under tiny capacity");
        // Still correct: all requests completed.
        assert!(rep.metrics.records.len() > 0);
    }

    #[test]
    fn prompt_tree_policy_beats_least_load_on_shared_workload() {
        let mk = |p: PolicyKind| SimConfig {
            prefill_instances: 3,
            decode_instances: 1,
            colocated_instances: 0,
            policy: p,
            ..disagg(true)
        };
        // ShareGPT: sharing is mostly intra-session (Table 6's hard
        // case) — least-load scatters a session's turns across prefill
        // instances, prompt-tree routes them home. High offered rate so
        // queues actually build (idle least-load degenerates to a single
        // instance and would trivially keep locality).
        let run_at = |cfg: SimConfig, rate: f64| {
            let spec = WorkloadSpec::generate(
                WorkloadKind::ShareGpt, 60, 9, 2048, 4096);
            let plan = ArrivalPlan::poisson(&spec, rate, 9);
            Simulation::new(cfg, spec, &plan).run()
        };
        let ll = run_at(mk(PolicyKind::LeastLoad), 40.0);
        let pt = run_at(mk(PolicyKind::PromptTree), 40.0);
        // Least-load still accrues *stale partial* prefixes on every
        // instance over a session's many turns, so the hit-ratio gap at
        // moderate share is modest (the paper amplifies it in Fig 15 by
        // sweeping the share ratio); direction must hold on both hit
        // ratio and tail TTFT.
        assert!(
            pt.metrics.mean_cached_ratio()
                > ll.metrics.mean_cached_ratio() + 0.01,
            "prompt-tree should concentrate shared prefixes: {} vs {}",
            pt.metrics.mean_cached_ratio(),
            ll.metrics.mean_cached_ratio()
        );
        // Mean TTFT (the tail is dominated by policy-independent cold
        // first turns at this scale; the Fig 15 bench sweeps share ratio
        // to expose the tail effect).
        assert!(
            pt.metrics.ttft().mean < ll.metrics.ttft().mean,
            "prompt-tree should cut mean TTFT: {} vs {}",
            pt.metrics.ttft().mean,
            ll.metrics.ttft().mean
        );
    }
}
