//! Discrete-event simulator for request-rate sweeps (Fig 8/12/15).
//!
//! The live server executes real PJRT compute, so its throughput ceiling
//! is this CPU — useless for sweeping request rates at paper scale. The
//! simulator swaps the *compute* for the operator-level cost model
//! (§5.3 — itself a paper artifact, validated in Fig 14) and the *wire*
//! for [`crate::net::LinkModel`], while running the **same coordination
//! code** as the live path: [`crate::scheduler::GlobalScheduler`] with
//! its global prompt trees and policies, [`crate::mempool::RadixIndex`]
//! for per-instance caches, [`crate::engine::DisaggMilestone`] for the
//! §5.1 designs, and [`crate::mempool::TransferMode`] for Fig 5.
//!
//! Model per instance: a single serial resource (one GPU). Prefill jobs
//! run whole; decode runs as continuous-batching iterations
//! (iteration time = base + Σ per-token·ctx over the batch). Colocated
//! instances interleave both — prefill-first between iterations, exactly
//! the vLLM discipline whose interference disaggregation removes.

pub mod clock;
pub mod cluster;

pub use clock::EventQueue;
pub use cluster::{FleetEvent, FleetOp, SimConfig, SimObs, SimReport, Simulation};
