//! Configuration system: typed config structs, a TOML-subset file parser,
//! `--set section.key=value` overrides, and validation.
//!
//! The subset understood: `[section]` headers, `key = value` lines where
//! value is an int, float, bool, or quoted string, `#` comments. That is
//! all the launcher needs; presets live in `configs/*.toml`.

mod toml;

pub use toml::{parse_toml, TomlValue};

use std::collections::BTreeMap;

use crate::mempool::TransferMode;
use crate::scheduler::PolicyKind;

/// Everything the launcher needs to assemble a cluster.
#[derive(Clone, Debug, PartialEq)]
pub struct Config {
    pub cluster: ClusterConfig,
    pub mempool: MemPoolConfig,
    pub fabric: FabricConfig,
    pub scheduler: SchedulerConfig,
    pub engine: EngineConfig,
    pub workload: WorkloadConfig,
    /// Directory holding AOT artifacts (meta.json, *.hlo.txt, weights.bin).
    pub artifacts_dir: String,
}

#[derive(Clone, Debug, PartialEq)]
pub struct ClusterConfig {
    /// Number of prefill-only instances.
    pub prefill_instances: usize,
    /// Number of decode-only instances.
    pub decode_instances: usize,
    /// Number of PD-colocated instances.
    pub colocated_instances: usize,
    /// Heartbeat period (virtual or real ms depending on mode).
    pub heartbeat_ms: f64,
    /// Heartbeats missed before an instance is declared dead.
    pub heartbeat_misses: u32,
}

#[derive(Clone, Debug, PartialEq)]
pub struct MemPoolConfig {
    /// Tokens per (small) KV block — vLLM-style block size.
    pub block_tokens: usize,
    /// HBM-sim tier capacity in blocks (per instance).
    pub hbm_blocks: usize,
    /// DRAM-sim tier capacity in blocks (per instance).
    pub dram_blocks: usize,
    /// Aggregated "huge page" layout (paper §5.2): one block spans all
    /// 2*L per-layer halves instead of 2*L discrete blocks.
    pub aggregated_layout: bool,
    /// Index entry TTL in seconds (paper §6 Discussion); 0 = no TTL.
    pub index_ttl_s: f64,
    /// Enable context caching (insert/match on the historical index).
    pub context_caching: bool,
}

#[derive(Clone, Debug, PartialEq)]
pub struct FabricConfig {
    /// Per network-API-call overhead in microseconds (NCCL launch cost).
    pub call_overhead_us: f64,
    /// Link bandwidth in GB/s (NVLink-class default).
    pub bandwidth_gbps: f64,
    /// Number of communicators (parallel serialization domains).
    pub communicators: usize,
    /// NCCL-style buffer size per communicator in MB (HBM cost knob).
    pub buffer_mb: f64,
    /// Extra latency for any DRAM-side endpoint (socket path), us.
    pub dram_penalty_us: f64,
}

#[derive(Clone, Debug, PartialEq)]
pub struct SchedulerConfig {
    pub policy: PolicyKind,
    /// Global prompt-tree TTL in seconds.
    pub tree_ttl_s: f64,
    /// Use the transfer-vs-recompute rule (paper Eq. 2).
    pub transfer_decision: bool,
    /// GS follower replicas (0 = unreplicated). Each runs a full copy
    /// of the fused prompt tree fed by the sequenced delta log; a
    /// primary crash promotes the most-caught-up follower with its
    /// locality state intact (`ServeCluster::fail_gs_primary`).
    pub gs_replicas: usize,
    /// Prefix-range shards of the global prompt tree (≥ 1). Each shard
    /// owns a contiguous range of first token-block fingerprints with
    /// its own delta log, so write replication scales ~1/S per shard;
    /// 1 = the unsharded tree (bit-identical behavior).
    pub gs_shards: usize,
}

#[derive(Clone, Debug, PartialEq)]
pub struct EngineConfig {
    /// Max sequence length (must match artifacts meta).
    pub max_seq: usize,
    /// Max new tokens per request (generation cap).
    pub max_new_tokens: usize,
    /// Max running requests per instance (batch slots).
    pub max_batch: usize,
    /// KV transfer granularity P->D (paper Fig 5).
    pub transfer_mode: TransferMode,
    /// Sampling temperature (0 = greedy).
    pub temperature: f64,
}

#[derive(Clone, Debug, PartialEq)]
pub struct WorkloadConfig {
    /// "sharegpt" | "loogle" | "react".
    pub kind: String,
    /// Request rate per instance (req/s).
    pub rate: f64,
    /// Number of sessions to generate.
    pub sessions: usize,
    /// RNG seed.
    pub seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            cluster: ClusterConfig {
                prefill_instances: 1,
                decode_instances: 1,
                colocated_instances: 0,
                heartbeat_ms: 100.0,
                heartbeat_misses: 3,
            },
            mempool: MemPoolConfig {
                block_tokens: 16,
                hbm_blocks: 512,
                dram_blocks: 4096,
                aggregated_layout: true,
                index_ttl_s: 300.0,
                context_caching: true,
            },
            fabric: FabricConfig {
                call_overhead_us: 15.0,
                bandwidth_gbps: 40.0,
                communicators: 1,
                buffer_mb: 4.0,
                dram_penalty_us: 50.0,
            },
            scheduler: SchedulerConfig {
                policy: PolicyKind::PromptTree,
                tree_ttl_s: 300.0,
                transfer_decision: true,
                gs_replicas: 0,
                gs_shards: 1,
            },
            engine: EngineConfig {
                max_seq: 512,
                max_new_tokens: 128,
                max_batch: 8,
                transfer_mode: TransferMode::ByRequestAgg,
                temperature: 0.0,
            },
            workload: WorkloadConfig {
                kind: "sharegpt".into(),
                rate: 2.0,
                sessions: 32,
                seed: 42,
            },
            artifacts_dir: "artifacts".into(),
        }
    }
}

impl Config {
    /// Load a TOML-subset file over the defaults, then validate.
    pub fn from_file(path: &str) -> Result<Config, String> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("{path}: {e}"))?;
        let mut cfg = Config::default();
        for (key, value) in parse_toml(&text)? {
            cfg.apply(&key, &value)?;
        }
        cfg.validate()?;
        Ok(cfg)
    }

    /// Apply `--set section.key=value` overrides, then validate.
    pub fn apply_sets(&mut self, sets: &[(String, String)]) -> Result<(), String> {
        for (k, v) in sets {
            self.apply(k, &TomlValue::parse_scalar(v))?;
        }
        self.validate()
    }

    fn apply(&mut self, key: &str, v: &TomlValue) -> Result<(), String> {
        let bad = || format!("bad value for {key}: {v:?}");
        match key {
            "cluster.prefill_instances" => {
                self.cluster.prefill_instances = v.as_usize().ok_or_else(bad)?
            }
            "cluster.decode_instances" => {
                self.cluster.decode_instances = v.as_usize().ok_or_else(bad)?
            }
            "cluster.colocated_instances" => {
                self.cluster.colocated_instances = v.as_usize().ok_or_else(bad)?
            }
            "cluster.heartbeat_ms" => {
                self.cluster.heartbeat_ms = v.as_f64().ok_or_else(bad)?
            }
            "cluster.heartbeat_misses" => {
                self.cluster.heartbeat_misses =
                    v.as_usize().ok_or_else(bad)? as u32
            }
            "mempool.block_tokens" => {
                self.mempool.block_tokens = v.as_usize().ok_or_else(bad)?
            }
            "mempool.hbm_blocks" => {
                self.mempool.hbm_blocks = v.as_usize().ok_or_else(bad)?
            }
            "mempool.dram_blocks" => {
                self.mempool.dram_blocks = v.as_usize().ok_or_else(bad)?
            }
            "mempool.aggregated_layout" => {
                self.mempool.aggregated_layout = v.as_bool().ok_or_else(bad)?
            }
            "mempool.index_ttl_s" => {
                self.mempool.index_ttl_s = v.as_f64().ok_or_else(bad)?
            }
            "mempool.context_caching" => {
                self.mempool.context_caching = v.as_bool().ok_or_else(bad)?
            }
            "fabric.call_overhead_us" => {
                self.fabric.call_overhead_us = v.as_f64().ok_or_else(bad)?
            }
            "fabric.bandwidth_gbps" => {
                self.fabric.bandwidth_gbps = v.as_f64().ok_or_else(bad)?
            }
            "fabric.communicators" => {
                self.fabric.communicators = v.as_usize().ok_or_else(bad)?
            }
            "fabric.buffer_mb" => {
                self.fabric.buffer_mb = v.as_f64().ok_or_else(bad)?
            }
            "fabric.dram_penalty_us" => {
                self.fabric.dram_penalty_us = v.as_f64().ok_or_else(bad)?
            }
            "scheduler.policy" => {
                self.scheduler.policy = v
                    .as_str()
                    .and_then(PolicyKind::parse)
                    .ok_or_else(bad)?
            }
            "scheduler.tree_ttl_s" => {
                self.scheduler.tree_ttl_s = v.as_f64().ok_or_else(bad)?
            }
            "scheduler.transfer_decision" => {
                self.scheduler.transfer_decision = v.as_bool().ok_or_else(bad)?
            }
            "scheduler.gs_replicas" => {
                self.scheduler.gs_replicas = v.as_usize().ok_or_else(bad)?
            }
            "scheduler.gs_shards" => {
                self.scheduler.gs_shards = v.as_usize().ok_or_else(bad)?
            }
            "engine.max_seq" => self.engine.max_seq = v.as_usize().ok_or_else(bad)?,
            "engine.max_new_tokens" => {
                self.engine.max_new_tokens = v.as_usize().ok_or_else(bad)?
            }
            "engine.max_batch" => {
                self.engine.max_batch = v.as_usize().ok_or_else(bad)?
            }
            "engine.transfer_mode" => {
                self.engine.transfer_mode = v
                    .as_str()
                    .and_then(TransferMode::parse)
                    .ok_or_else(bad)?
            }
            "engine.temperature" => {
                self.engine.temperature = v.as_f64().ok_or_else(bad)?
            }
            "workload.kind" => {
                self.workload.kind = v.as_str().ok_or_else(bad)?.to_string()
            }
            "workload.rate" => self.workload.rate = v.as_f64().ok_or_else(bad)?,
            "workload.sessions" => {
                self.workload.sessions = v.as_usize().ok_or_else(bad)?
            }
            "workload.seed" => {
                self.workload.seed = v.as_f64().ok_or_else(bad)? as u64
            }
            "artifacts_dir" => {
                self.artifacts_dir = v.as_str().ok_or_else(bad)?.to_string()
            }
            _ => return Err(format!("unknown config key: {key}")),
        }
        Ok(())
    }

    pub fn validate(&self) -> Result<(), String> {
        let c = &self.cluster;
        if c.prefill_instances + c.decode_instances + c.colocated_instances == 0 {
            return Err("cluster has zero instances".into());
        }
        if (c.prefill_instances == 0) != (c.decode_instances == 0) {
            return Err(
                "prefill-only and decode-only instances must come in \
                 nonzero pairs (disaggregated mode needs both)"
                    .into(),
            );
        }
        if self.mempool.block_tokens == 0
            || !self.mempool.block_tokens.is_power_of_two()
        {
            return Err("mempool.block_tokens must be a power of two".into());
        }
        if self.mempool.hbm_blocks == 0 {
            return Err("mempool.hbm_blocks must be > 0".into());
        }
        if self.fabric.bandwidth_gbps <= 0.0 {
            return Err("fabric.bandwidth_gbps must be > 0".into());
        }
        if self.fabric.communicators == 0 {
            return Err("fabric.communicators must be > 0".into());
        }
        if self.scheduler.gs_shards == 0 {
            return Err("scheduler.gs_shards must be >= 1".into());
        }
        if self.engine.max_seq % self.mempool.block_tokens != 0 {
            return Err("engine.max_seq must be a multiple of block_tokens".into());
        }
        match self.workload.kind.as_str() {
            "sharegpt" | "loogle" | "react" => {}
            k => return Err(format!("unknown workload.kind '{k}'")),
        }
        Ok(())
    }

    /// Flatten to key=value map (used by tests and `--dump-config`).
    pub fn dump(&self) -> BTreeMap<String, String> {
        let mut m = BTreeMap::new();
        let c = self;
        m.insert("cluster.prefill_instances".into(), c.cluster.prefill_instances.to_string());
        m.insert("cluster.decode_instances".into(), c.cluster.decode_instances.to_string());
        m.insert("cluster.colocated_instances".into(), c.cluster.colocated_instances.to_string());
        m.insert("mempool.block_tokens".into(), c.mempool.block_tokens.to_string());
        m.insert("mempool.hbm_blocks".into(), c.mempool.hbm_blocks.to_string());
        m.insert("mempool.dram_blocks".into(), c.mempool.dram_blocks.to_string());
        m.insert("mempool.aggregated_layout".into(), c.mempool.aggregated_layout.to_string());
        m.insert("mempool.context_caching".into(), c.mempool.context_caching.to_string());
        m.insert("fabric.call_overhead_us".into(), c.fabric.call_overhead_us.to_string());
        m.insert("fabric.bandwidth_gbps".into(), c.fabric.bandwidth_gbps.to_string());
        m.insert("fabric.communicators".into(), c.fabric.communicators.to_string());
        m.insert("scheduler.policy".into(), c.scheduler.policy.name().into());
        m.insert("scheduler.gs_replicas".into(), c.scheduler.gs_replicas.to_string());
        m.insert("scheduler.gs_shards".into(), c.scheduler.gs_shards.to_string());
        m.insert("engine.transfer_mode".into(), c.engine.transfer_mode.name().into());
        m.insert("workload.kind".into(), c.workload.kind.clone());
        m.insert("workload.rate".into(), c.workload.rate.to_string());
        m
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_valid() {
        Config::default().validate().unwrap();
    }

    #[test]
    fn apply_sets_overrides() {
        let mut cfg = Config::default();
        cfg.apply_sets(&[
            ("mempool.block_tokens".into(), "32".into()),
            ("scheduler.policy".into(), "least_load".into()),
            ("engine.transfer_mode".into(), "by_layer".into()),
            ("fabric.bandwidth_gbps".into(), "400".into()),
        ])
        .unwrap();
        assert_eq!(cfg.mempool.block_tokens, 32);
        assert_eq!(cfg.scheduler.policy, PolicyKind::LeastLoad);
        assert_eq!(cfg.engine.transfer_mode, TransferMode::ByLayer);
        assert_eq!(cfg.fabric.bandwidth_gbps, 400.0);
    }

    #[test]
    fn rejects_unknown_key() {
        let mut cfg = Config::default();
        assert!(cfg
            .apply_sets(&[("nope.nope".into(), "1".into())])
            .is_err());
    }

    #[test]
    fn rejects_invalid_values() {
        let mut cfg = Config::default();
        assert!(cfg
            .apply_sets(&[("mempool.block_tokens".into(), "17".into())])
            .is_err());
        let mut cfg = Config::default();
        assert!(cfg
            .apply_sets(&[("workload.kind".into(), "martian".into())])
            .is_err());
    }

    #[test]
    fn rejects_unpaired_disagg() {
        let mut cfg = Config::default();
        let r = cfg.apply_sets(&[("cluster.decode_instances".into(), "0".into())]);
        assert!(r.is_err());
    }

    #[test]
    fn parses_full_file() {
        let text = r#"
# serving preset
[cluster]
prefill_instances = 1
decode_instances = 2

[mempool]
block_tokens = 16
aggregated_layout = true

[scheduler]
policy = "prompt_tree"

[workload]
kind = "loogle"
rate = 3.5
"#;
        let mut cfg = Config::default();
        for (k, v) in parse_toml(text).unwrap() {
            cfg.apply(&k, &v).unwrap();
        }
        cfg.validate().unwrap();
        assert_eq!(cfg.cluster.decode_instances, 2);
        assert_eq!(cfg.workload.kind, "loogle");
        assert_eq!(cfg.workload.rate, 3.5);
    }
}
