//! TOML-subset parser: `[section]` headers, `key = value` lines, `#`
//! comments. Values: integers, floats, booleans, quoted strings. Returns
//! flat `section.key` pairs in file order.

#[derive(Clone, Debug, PartialEq)]
pub enum TomlValue {
    Int(i64),
    Float(f64),
    Bool(bool),
    Str(String),
}

impl TomlValue {
    pub fn as_usize(&self) -> Option<usize> {
        match self {
            TomlValue::Int(i) if *i >= 0 => Some(*i as usize),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            TomlValue::Int(i) => Some(*i as f64),
            TomlValue::Float(f) => Some(*f),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            TomlValue::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            TomlValue::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Best-effort scalar parse for `--set key=value` strings (which come
    /// without quotes).
    pub fn parse_scalar(s: &str) -> TomlValue {
        let t = s.trim();
        if t == "true" {
            return TomlValue::Bool(true);
        }
        if t == "false" {
            return TomlValue::Bool(false);
        }
        if let Ok(i) = t.parse::<i64>() {
            return TomlValue::Int(i);
        }
        if let Ok(f) = t.parse::<f64>() {
            return TomlValue::Float(f);
        }
        TomlValue::Str(t.trim_matches('"').to_string())
    }
}

/// Parse the subset; returns `(section.key, value)` pairs.
pub fn parse_toml(text: &str) -> Result<Vec<(String, TomlValue)>, String> {
    let mut section = String::new();
    let mut out = vec![];
    for (lineno, raw) in text.lines().enumerate() {
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(name) = line.strip_prefix('[') {
            let name = name
                .strip_suffix(']')
                .ok_or(format!("line {}: unterminated section", lineno + 1))?
                .trim();
            if name.is_empty() {
                return Err(format!("line {}: empty section name", lineno + 1));
            }
            section = name.to_string();
            continue;
        }
        let (key, value) = line
            .split_once('=')
            .ok_or(format!("line {}: expected key = value", lineno + 1))?;
        let key = key.trim();
        if key.is_empty() {
            return Err(format!("line {}: empty key", lineno + 1));
        }
        let full = if section.is_empty() {
            key.to_string()
        } else {
            format!("{section}.{key}")
        };
        out.push((full, parse_value(value.trim(), lineno + 1)?));
    }
    Ok(out)
}

fn strip_comment(line: &str) -> &str {
    // A '#' inside a quoted string does not start a comment.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(v: &str, lineno: usize) -> Result<TomlValue, String> {
    if v.is_empty() {
        return Err(format!("line {lineno}: empty value"));
    }
    if v == "true" {
        return Ok(TomlValue::Bool(true));
    }
    if v == "false" {
        return Ok(TomlValue::Bool(false));
    }
    if let Some(s) = v.strip_prefix('"') {
        let s = s
            .strip_suffix('"')
            .ok_or(format!("line {lineno}: unterminated string"))?;
        return Ok(TomlValue::Str(s.to_string()));
    }
    if let Ok(i) = v.parse::<i64>() {
        return Ok(TomlValue::Int(i));
    }
    if let Ok(f) = v.parse::<f64>() {
        return Ok(TomlValue::Float(f));
    }
    Err(format!("line {lineno}: cannot parse value '{v}'"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_types() {
        let text = "top = 1\n[a]\nx = 1.5\ny = \"s\"\nz = true\n[b]\nx = -2\n";
        let kv = parse_toml(text).unwrap();
        assert_eq!(kv[0], ("top".into(), TomlValue::Int(1)));
        assert_eq!(kv[1], ("a.x".into(), TomlValue::Float(1.5)));
        assert_eq!(kv[2], ("a.y".into(), TomlValue::Str("s".into())));
        assert_eq!(kv[3], ("a.z".into(), TomlValue::Bool(true)));
        assert_eq!(kv[4], ("b.x".into(), TomlValue::Int(-2)));
    }

    #[test]
    fn comments_and_blanks() {
        let text = "# header\n[a]\nx = 2 # inline\n\ns = \"a # not comment\"\n";
        let kv = parse_toml(text).unwrap();
        assert_eq!(kv[0].1, TomlValue::Int(2));
        assert_eq!(kv[1].1, TomlValue::Str("a # not comment".into()));
    }

    #[test]
    fn errors_are_line_numbered() {
        assert!(parse_toml("[oops\n").unwrap_err().contains("line 1"));
        assert!(parse_toml("\nnokey\n").unwrap_err().contains("line 2"));
        assert!(parse_toml("x = \n").unwrap_err().contains("line 1"));
    }

    #[test]
    fn scalar_parse_for_sets() {
        assert_eq!(TomlValue::parse_scalar("3"), TomlValue::Int(3));
        assert_eq!(TomlValue::parse_scalar("3.5"), TomlValue::Float(3.5));
        assert_eq!(TomlValue::parse_scalar("true"), TomlValue::Bool(true));
        assert_eq!(
            TomlValue::parse_scalar("abc"),
            TomlValue::Str("abc".into())
        );
    }
}
