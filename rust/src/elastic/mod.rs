//! Elasticity — the subsystem that makes the memory pool live up to the
//! paper's title (*Elastic* Memory Pool): instances join, drain, and
//! leave while their cached KV outlives them.
//!
//! Four pieces, threaded through every existing layer:
//!
//! * [`lifecycle`] — the `Joining → Active → Draining → Decommissioned`
//!   state machine gating routing, donation, and migration targets.
//! * [`delta`] — ownership delta events (`Record` / `Expire` /
//!   `Handoff` / membership) over token sequences: the atomic-visibility
//!   protocol migration rides and the replication log a future
//!   multi-replica global scheduler would consume.
//! * [`planner`] — which cached prefixes move where when an instance
//!   drains or runs capacity-hot: hot, deep prefixes migrate to
//!   least-pressured Active peers; cold tails are dropped.
//! * [`executor`] — the 3-step allocate → transmit → insert transfer
//!   (paper §4.3) between MemPools, with donor-side pin-during-transfer
//!   and receiver-side `transfer_with_insert`.
//!
//! The live server drives drains over the fabric
//! (`ServeCluster::drain` / `ServeCluster::join`), the discrete-event
//! simulator replays drain/join plans at fleet scale, and
//! `benches/fig16_elastic.rs` measures what survives a scale-down.

pub mod delta;
pub mod executor;
pub mod lifecycle;
pub mod planner;

pub use delta::{DeltaEvent, DeltaLog};
pub use executor::{
    execute_plan, export_prefix, land_prefix, migrate_prefix,
    ExportedPrefix, MigrationOutcome,
};
pub use lifecycle::{InstanceState, Lifecycle, LifecycleError};
pub use planner::{
    plan_migration, MigrationPlan, MigrationTask, PlannerConfig, Recipient,
};
