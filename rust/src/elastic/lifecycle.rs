//! Instance lifecycle state machine (paper §4: the pool is *elastic*).
//!
//! Every instance moves through `Joining → Active → Draining →
//! Decommissioned`. The states gate what the rest of the system may do
//! with the instance:
//!
//! * **Joining** — registered, thread/process starting; receives no work
//!   and owns no global-tree entries yet.
//! * **Active** — full member: routable, records cached prefixes, can
//!   donate or receive migrated KV.
//! * **Draining** — scale-down in progress: excluded from routing (the
//!   fused tree's `match_into` never emits it), finishes its in-flight
//!   requests, and *donates* its hot cached prefixes to Active peers via
//!   the migration planner/executor. Its data remains matchable through
//!   [`crate::scheduler::fused_tree::FusedPromptTree::match_one`] until
//!   decommission, so nothing is lost mid-migration.
//! * **Decommissioned** — gone: ownership cleared everywhere, blocks
//!   released, id retired (a rejoin is a fresh `Joining` registration).
//!
//! Transitions are validated — the leader, the simulator, and tests all
//! share this one table, so an illegal order (e.g. draining an instance
//! that never activated) is a programming error surfaced immediately.

use std::collections::BTreeMap;

use crate::mempool::InstanceId;
use crate::scheduler::prompt_tree::InstanceKind;

/// Where an instance is in its life (see module docs).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum InstanceState {
    Joining,
    Active,
    Draining,
    Decommissioned,
}

impl InstanceState {
    pub fn name(self) -> &'static str {
        match self {
            InstanceState::Joining => "joining",
            InstanceState::Active => "active",
            InstanceState::Draining => "draining",
            InstanceState::Decommissioned => "decommissioned",
        }
    }

    /// May the global scheduler route *new* work here?
    pub fn routable(self) -> bool {
        matches!(self, InstanceState::Active)
    }

    /// May this instance receive migrated KV (be a migration target)?
    pub fn accepts_migration(self) -> bool {
        matches!(self, InstanceState::Active)
    }

    /// May this instance donate KV (drain-donor or pressure-donor)?
    pub fn donates(self) -> bool {
        matches!(self, InstanceState::Active | InstanceState::Draining)
    }
}

#[derive(Debug, thiserror::Error, PartialEq)]
pub enum LifecycleError {
    #[error("unknown instance {0}")]
    Unknown(InstanceId),
    #[error("instance {0} already registered")]
    AlreadyRegistered(InstanceId),
    #[error("illegal transition for {id}: {from:?} -> {to:?}")]
    IllegalTransition {
        id: InstanceId,
        from: InstanceState,
        to: InstanceState,
    },
}

#[derive(Clone, Copy, Debug)]
struct Entry {
    state: InstanceState,
    kind: InstanceKind,
}

/// Fleet-wide lifecycle tracker: one entry per known instance, with
/// transition validation. Pure bookkeeping — the leader/sim apply the
/// side effects (tree draining bits, membership, migrations).
#[derive(Default)]
pub struct Lifecycle {
    entries: BTreeMap<InstanceId, Entry>,
}

impl Lifecycle {
    pub fn new() -> Self {
        Lifecycle::default()
    }

    /// Register a new instance in `Joining`.
    pub fn join(&mut self, id: InstanceId, kind: InstanceKind)
                -> Result<(), LifecycleError> {
        // A decommissioned id may rejoin (fresh state, nothing carries
        // over); a live one may not.
        if let Some(e) = self.entries.get(&id) {
            if e.state != InstanceState::Decommissioned {
                return Err(LifecycleError::AlreadyRegistered(id));
            }
        }
        self.entries.insert(id, Entry {
            state: InstanceState::Joining,
            kind,
        });
        Ok(())
    }

    /// `Joining → Active`: the instance thread is up and registered.
    pub fn activate(&mut self, id: InstanceId) -> Result<(), LifecycleError> {
        self.transition(id, InstanceState::Active)
    }

    /// `Active → Draining`: scale-down begins.
    pub fn begin_drain(&mut self, id: InstanceId)
                       -> Result<(), LifecycleError> {
        self.transition(id, InstanceState::Draining)
    }

    /// `Draining → Active`: an aborted scale-down (e.g. drain timeout).
    /// The instance returns to full service with whatever it still
    /// holds; any handoffs already applied stay applied (they were
    /// honest — the receivers really cache those prefixes now).
    pub fn abort_drain(&mut self, id: InstanceId)
                       -> Result<(), LifecycleError> {
        self.transition(id, InstanceState::Active)
    }

    /// `Draining → Decommissioned` (or `Joining → Decommissioned` for an
    /// aborted join). An Active instance must drain first — that is the
    /// whole point of the subsystem.
    pub fn decommission(&mut self, id: InstanceId)
                        -> Result<(), LifecycleError> {
        self.transition(id, InstanceState::Decommissioned)
    }

    /// Abrupt removal (heartbeat failure, §4.4): skips the graceful
    /// Draining stage — the instance is simply gone, from any state.
    /// No-op for unknown ids.
    pub fn force_decommission(&mut self, id: InstanceId) {
        if let Some(e) = self.entries.get_mut(&id) {
            e.state = InstanceState::Decommissioned;
        }
    }

    fn transition(&mut self, id: InstanceId, to: InstanceState)
                  -> Result<(), LifecycleError> {
        let e = self
            .entries
            .get_mut(&id)
            .ok_or(LifecycleError::Unknown(id))?;
        let legal = matches!(
            (e.state, to),
            (InstanceState::Joining, InstanceState::Active)
                | (InstanceState::Active, InstanceState::Draining)
                | (InstanceState::Draining, InstanceState::Active)
                | (InstanceState::Draining, InstanceState::Decommissioned)
                | (InstanceState::Joining, InstanceState::Decommissioned)
        );
        if !legal {
            return Err(LifecycleError::IllegalTransition {
                id,
                from: e.state,
                to,
            });
        }
        e.state = to;
        Ok(())
    }

    pub fn state(&self, id: InstanceId) -> Option<InstanceState> {
        self.entries.get(&id).map(|e| e.state)
    }

    pub fn kind(&self, id: InstanceId) -> Option<InstanceKind> {
        self.entries.get(&id).map(|e| e.kind)
    }

    pub fn is_routable(&self, id: InstanceId) -> bool {
        self.state(id).is_some_and(|s| s.routable())
    }

    pub fn is_draining(&self, id: InstanceId) -> bool {
        self.state(id) == Some(InstanceState::Draining)
    }

    /// Active instances (ascending id) satisfying `pred` on their kind —
    /// the migration-recipient candidate set is
    /// `active_where(|k| k.runs_prefill())`.
    pub fn active_where<F: Fn(InstanceKind) -> bool>(
        &self,
        pred: F,
    ) -> Vec<InstanceId> {
        self.entries
            .iter()
            .filter(|(_, e)| e.state == InstanceState::Active && pred(e.kind))
            .map(|(&id, _)| id)
            .collect()
    }

    /// All ids currently in `Draining`.
    pub fn draining(&self) -> Vec<InstanceId> {
        self.entries
            .iter()
            .filter(|(_, e)| e.state == InstanceState::Draining)
            .map(|(&id, _)| id)
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const A: InstanceId = InstanceId(0);
    const B: InstanceId = InstanceId(1);

    #[test]
    fn full_lifecycle_path() {
        let mut lc = Lifecycle::new();
        lc.join(A, InstanceKind::PrefillOnly).unwrap();
        assert_eq!(lc.state(A), Some(InstanceState::Joining));
        assert!(!lc.is_routable(A));
        lc.activate(A).unwrap();
        assert!(lc.is_routable(A));
        assert!(lc.state(A).unwrap().donates());
        lc.begin_drain(A).unwrap();
        assert!(!lc.is_routable(A));
        assert!(lc.is_draining(A));
        assert!(lc.state(A).unwrap().donates());
        assert!(!lc.state(A).unwrap().accepts_migration());
        lc.decommission(A).unwrap();
        assert_eq!(lc.state(A), Some(InstanceState::Decommissioned));
        assert!(!lc.state(A).unwrap().donates());
    }

    #[test]
    fn illegal_transitions_rejected() {
        let mut lc = Lifecycle::new();
        lc.join(A, InstanceKind::Colocated).unwrap();
        // Joining cannot drain (nor abort a drain it never began).
        assert!(matches!(
            lc.begin_drain(A),
            Err(LifecycleError::IllegalTransition { .. })
        ));
        assert!(lc.abort_drain(A).is_err());
        lc.activate(A).unwrap();
        // Active cannot skip draining.
        assert!(matches!(
            lc.decommission(A),
            Err(LifecycleError::IllegalTransition { .. })
        ));
        // Unknown id.
        assert_eq!(lc.activate(B), Err(LifecycleError::Unknown(B)));
    }

    #[test]
    fn aborted_drain_returns_to_active() {
        let mut lc = Lifecycle::new();
        lc.join(A, InstanceKind::PrefillOnly).unwrap();
        lc.activate(A).unwrap();
        lc.begin_drain(A).unwrap();
        lc.abort_drain(A).unwrap();
        assert_eq!(lc.state(A), Some(InstanceState::Active));
        assert!(lc.is_routable(A));
        // And it may drain again later.
        lc.begin_drain(A).unwrap();
        lc.decommission(A).unwrap();
    }

    #[test]
    fn rejoin_after_decommission() {
        let mut lc = Lifecycle::new();
        lc.join(A, InstanceKind::PrefillOnly).unwrap();
        assert!(matches!(
            lc.join(A, InstanceKind::PrefillOnly),
            Err(LifecycleError::AlreadyRegistered(_))
        ));
        lc.activate(A).unwrap();
        lc.begin_drain(A).unwrap();
        lc.decommission(A).unwrap();
        // The id may come back as a fresh member.
        lc.join(A, InstanceKind::DecodeOnly).unwrap();
        assert_eq!(lc.state(A), Some(InstanceState::Joining));
        assert_eq!(lc.kind(A), Some(InstanceKind::DecodeOnly));
    }

    #[test]
    fn failure_force_decommissions_from_any_state() {
        let mut lc = Lifecycle::new();
        lc.join(A, InstanceKind::Colocated).unwrap();
        lc.activate(A).unwrap();
        lc.force_decommission(A);
        assert_eq!(lc.state(A), Some(InstanceState::Decommissioned));
        lc.force_decommission(B); // unknown id: no-op
        assert_eq!(lc.state(B), None);
    }

    #[test]
    fn aborted_join_decommissions_directly() {
        let mut lc = Lifecycle::new();
        lc.join(A, InstanceKind::PrefillOnly).unwrap();
        lc.decommission(A).unwrap();
        assert_eq!(lc.state(A), Some(InstanceState::Decommissioned));
    }

    #[test]
    fn active_where_filters_state_and_kind() {
        let mut lc = Lifecycle::new();
        for (id, kind) in [
            (InstanceId(0), InstanceKind::PrefillOnly),
            (InstanceId(1), InstanceKind::DecodeOnly),
            (InstanceId(2), InstanceKind::Colocated),
            (InstanceId(3), InstanceKind::PrefillOnly),
        ] {
            lc.join(id, kind).unwrap();
            lc.activate(id).unwrap();
        }
        lc.begin_drain(InstanceId(3)).unwrap();
        assert_eq!(
            lc.active_where(|k| k.runs_prefill()),
            vec![InstanceId(0), InstanceId(2)]
        );
        assert_eq!(lc.draining(), vec![InstanceId(3)]);
    }
}
