//! Ownership delta events for the global prompt tree.
//!
//! The fused tree is leader-local today; every mutation of its
//! per-(node, instance) ownership can be expressed as one of a small set
//! of *delta events*, which gives three things at once:
//!
//! 1. **Atomic migration visibility.** A [`DeltaEvent::Handoff`] grants
//!    the receiver ownership of a migrated prefix *and* retires the
//!    donor's claim in a single event, so routing never observes a
//!    window in which the prefix is owned by nobody (the failure mode of
//!    naive "expire then re-record" sequencing).
//! 2. **An honest eviction signal.** [`DeltaEvent::Expire`] is shaped
//!    exactly like what a local LRU produces — a leaf (one branch's
//!    deepest extension) disappears, proper prefixes and sibling
//!    branches survive — so an instance can report precisely what it
//!    evicted instead of the TTL guessing.
//! 3. **A replication log.** Events are self-contained values over
//!    token sequences (never node indices, which are an implementation
//!    detail of one tree). Applying the same event stream to any replica
//!    of the tree yields the same ownership state — the basis for a
//!    future replicated/sharded global scheduler (see ROADMAP).
//!
//! Both tree implementations consume the same events —
//! [`crate::scheduler::fused_tree::FusedPromptTree::apply_delta`] and
//! [`crate::scheduler::prompt_tree_ref::RefGlobalPromptTrees::apply_delta`]
//! — and the differential proptest in `prompt_tree_ref` interleaves
//! deltas (handoffs, expiries, drain toggles, leave/rejoin) to pin them
//! together, forced fingerprint collisions included.

use crate::mempool::InstanceId;
use crate::scheduler::prompt_tree::InstanceKind;

/// One ownership mutation of the global prompt tree. Token sequences are
/// block-truncated by the consumer; `now` fields are the cluster clock
/// used for TTL stamps.
#[derive(Clone, Debug, PartialEq)]
pub enum DeltaEvent {
    /// A new instance registers (membership, paper §4.4).
    Join {
        instance: InstanceId,
        kind: InstanceKind,
    },
    /// An instance leaves for good (failure or decommission): all of its
    /// ownership is cleared and ownerless subtrees reclaimed.
    Leave { instance: InstanceId },
    /// Response path (paper Fig 6 right): `instance` now caches
    /// `tokens`.
    Record {
        instance: InstanceId,
        tokens: Vec<u32>,
        now: f64,
    },
    /// `instance` no longer caches `prefix` nor any extension of it;
    /// proper prefixes and sibling branches survive. An empty prefix
    /// clears the instance's entire view; a prefix the instance never
    /// fully cached is a no-op.
    Expire {
        instance: InstanceId,
        prefix: Vec<u32>,
    },
    /// Live migration landed: `to` now caches `tokens`, and `from`'s
    /// claim on the handed prefix is retired in the same event (`from`
    /// keeps the proper prefixes of `tokens` — honest, since it
    /// physically holds them until decommission). Sub-block `tokens`
    /// are a no-op.
    Handoff {
        from: InstanceId,
        to: InstanceId,
        tokens: Vec<u32>,
        now: f64,
    },
    /// Routing visibility toggle: a draining instance stops receiving
    /// new work but its entries stay matchable (donor role) until
    /// `Leave`.
    SetDraining {
        instance: InstanceId,
        draining: bool,
    },
}

/// An append-only event log — the unit of replication for the
/// multi-replica global scheduler (replicas consuming the same stream
/// converge to the same ownership state). The sequenced transport over
/// it — monotonic seqs, per-replica ack cursors, bounded windows, gap
/// re-request, snapshot-gated truncation — lives in
/// [`crate::replica::log`]; this type stays the minimal unsequenced
/// form for tests and local accounting.
#[derive(Clone, Debug, Default)]
pub struct DeltaLog {
    events: Vec<DeltaEvent>,
}

impl DeltaLog {
    pub fn new() -> Self {
        DeltaLog::default()
    }

    pub fn push(&mut self, ev: DeltaEvent) {
        self.events.push(ev);
    }

    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    pub fn iter(&self) -> impl Iterator<Item = &DeltaEvent> + '_ {
        self.events.iter()
    }

    /// Number of handoff events (drain-progress reporting).
    pub fn handoffs(&self) -> usize {
        self.events
            .iter()
            .filter(|e| matches!(e, DeltaEvent::Handoff { .. }))
            .count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn log_counts_handoffs() {
        let mut log = DeltaLog::new();
        assert!(log.is_empty());
        log.push(DeltaEvent::Record {
            instance: InstanceId(0),
            tokens: vec![1, 2, 3, 4],
            now: 1.0,
        });
        log.push(DeltaEvent::Handoff {
            from: InstanceId(0),
            to: InstanceId(1),
            tokens: vec![1, 2, 3, 4],
            now: 2.0,
        });
        assert_eq!(log.len(), 2);
        assert_eq!(log.handoffs(), 1);
        assert_eq!(log.iter().count(), 2);
    }
}
