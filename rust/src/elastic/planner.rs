//! Migration planner: which cached prefixes move where when an instance
//! drains (or runs capacity-hot).
//!
//! The planner works entirely from global-scheduler state — per-instance
//! [`crate::scheduler::shard::ShardedPromptTrees::owned_paths`]
//! inventories (depth + last-insert recency, merged token-sorted across
//! the prefix-range shards) and per-recipient capacity pressure — so
//! the leader can plan without touching any instance's pool. Selection policy, per the paper's economics (§5.3: transfer
//! beats recompute in proportion to prefix length; Fig 13: caching gains
//! grow with depth):
//!
//! * **Hot, deep prefixes migrate.** Depth is the value of a cache entry
//!   (a d-block prefix saves O(d) recompute *and* its transfer amortizes
//!   the per-call overhead); recency predicts reuse. Shallow or stale
//!   entries are **cold tails — dropped**, not shipped: moving them
//!   costs more wire than the recompute they might save.
//! * **Prefixes already replicated on an Active instance are skipped**
//!   (they survive the drain for free).
//! * **Recipients are chosen by capacity pressure**, spread so one peer
//!   does not absorb the whole donor: an instance near eviction churn
//!   would just evict what it receives (the same signal Eq. 1 now uses
//!   to discount matched length — see
//!   [`crate::scheduler::cost_model::pressure_discount`]).

use crate::mempool::InstanceId;
use crate::scheduler::fused_tree::OwnedPrefix;
use crate::scheduler::shard::ShardedPromptTrees;

/// Planner knobs. Defaults suit a drain (move every hot, deep prefix);
/// set `max_blocks` for a pressure-offload rebalance that moves only the
/// most valuable entries.
#[derive(Clone, Debug)]
pub struct PlannerConfig {
    /// Minimum depth (token-blocks) worth migrating; shallower prefixes
    /// are cheaper to recompute than to ship.
    pub min_depth_blocks: usize,
    /// Entries whose last insert is older than this are cold tails —
    /// dropped (`0` disables the age cut).
    pub max_age_s: f64,
    /// Cap on total migrated token-blocks (`None` = everything hot).
    pub max_blocks: Option<usize>,
}

impl Default for PlannerConfig {
    fn default() -> Self {
        PlannerConfig {
            min_depth_blocks: 2,
            max_age_s: 0.0,
            max_blocks: None,
        }
    }
}

/// A migration target: an Active instance and its capacity pressure in
/// `[0, 1]` (fraction of its pool the index already occupies).
#[derive(Clone, Copy, Debug)]
pub struct Recipient {
    pub id: InstanceId,
    pub pressure: f64,
}

/// One unit of migration work: ship the donor's cached `tokens` to `to`
/// via the 3-step transfer protocol, then hand off tree ownership.
#[derive(Clone, Debug, PartialEq)]
pub struct MigrationTask {
    pub from: InstanceId,
    pub to: InstanceId,
    pub tokens: Vec<u32>,
    pub blocks: usize,
}

/// Planner output plus the accounting the drain report surfaces.
#[derive(Clone, Debug, Default)]
pub struct MigrationPlan {
    pub tasks: Vec<MigrationTask>,
    /// Token-blocks scheduled to move.
    pub planned_blocks: usize,
    /// Cold/shallow/over-cap token-blocks left to die with the donor.
    pub dropped_blocks: usize,
    /// Token-blocks already fully cached on an Active instance.
    pub replicated_blocks: usize,
}

/// Plan the migrations for a draining (or pressure-hot) `donor`.
/// `recipients` must be Active, non-donor instances; an empty set yields
/// an all-dropped plan (the caller decides whether that is acceptable —
/// the leader refuses to drain the last prefill instance). Deterministic
/// for a given tree state: inventory order is token-sorted and every
/// tie breaks by instance id.
pub fn plan_migration(
    tree: &ShardedPromptTrees,
    donor: InstanceId,
    now: f64,
    recipients: &[Recipient],
    cfg: &PlannerConfig,
) -> MigrationPlan {
    plan_migration_from(
        tree.owned_paths(donor),
        |id, tokens| tree.match_one(id, tokens),
        donor,
        now,
        recipients,
        cfg,
    )
}

/// Source-agnostic form of [`plan_migration`]: the donor inventory and
/// the replication probe are supplied by the caller. The sharded-lock
/// data plane plans across per-shard trees it cannot expose as one
/// `ShardedPromptTrees` — it concatenates per-unit `owned_paths` and
/// routes each probe to the unit owning the prefix (a prefix chain
/// never crosses shards, so both are exact). Determinism is preserved:
/// the sort key (depth, recency, tokens) is total, so inventory
/// concatenation order cannot change the plan.
pub fn plan_migration_from(
    inventory: Vec<OwnedPrefix>,
    match_one: impl Fn(InstanceId, &[u32]) -> usize,
    donor: InstanceId,
    now: f64,
    recipients: &[Recipient],
    cfg: &PlannerConfig,
) -> MigrationPlan {
    let mut plan = MigrationPlan::default();
    let mut inventory = inventory;
    // Deepest (then hottest) first, so a `max_blocks` cap keeps the most
    // valuable entries; owned_paths is token-sorted, making ties stable.
    inventory.sort_by(|a, b| {
        b.blocks
            .cmp(&a.blocks)
            .then(
                b.last_insert
                    .partial_cmp(&a.last_insert)
                    .unwrap_or(std::cmp::Ordering::Equal),
            )
            .then_with(|| a.tokens.cmp(&b.tokens))
    });
    let recipients: Vec<Recipient> = recipients
        .iter()
        .copied()
        .filter(|r| r.id != donor)
        .collect();
    // Per-recipient blocks assigned so far, for spreading.
    let mut assigned = vec![0usize; recipients.len()];
    let donor_total: usize = inventory.iter().map(|p| p.blocks).sum();
    for path in inventory {
        let hot = path.blocks >= cfg.min_depth_blocks
            && (cfg.max_age_s <= 0.0 || now - path.last_insert <= cfg.max_age_s);
        let capped = cfg
            .max_blocks
            .is_some_and(|cap| plan.planned_blocks + path.blocks > cap);
        if !hot || capped || recipients.is_empty() {
            plan.dropped_blocks += path.blocks;
            continue;
        }
        // Already fully cached on some Active peer: survives for free.
        if recipients
            .iter()
            .any(|r| match_one(r.id, &path.tokens) >= path.tokens.len())
        {
            plan.replicated_blocks += path.blocks;
            continue;
        }
        // Least-pressured recipient, spread-corrected: pressure plus the
        // share of this drain already assigned to it.
        let score = |k: usize| {
            recipients[k].pressure
                + assigned[k] as f64 / donor_total.max(1) as f64
        };
        let best = (0..recipients.len())
            .min_by(|&i, &j| {
                score(i)
                    .partial_cmp(&score(j))
                    .unwrap_or(std::cmp::Ordering::Equal)
                    .then(recipients[i].id.cmp(&recipients[j].id))
            })
            .expect("recipients non-empty");
        assigned[best] += path.blocks;
        plan.planned_blocks += path.blocks;
        plan.tasks.push(MigrationTask {
            from: donor,
            to: recipients[best].id,
            tokens: path.tokens,
            blocks: path.blocks,
        });
    }
    plan
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scheduler::prompt_tree::InstanceKind;

    const BT: usize = 4;

    fn toks(n: usize, seed: u32) -> Vec<u32> {
        (0..n as u32).map(|i| i * 5 + seed * 1000).collect()
    }

    fn tree_with(donor_prompts: &[(usize, u32, f64)])
                 -> ShardedPromptTrees {
        // Two shards: planning must see the same inventory regardless
        // of how the prefix ranges split it.
        let mut t = ShardedPromptTrees::with_shards(BT, 0.0, 2);
        for i in 0..4 {
            t.add_instance(InstanceId(i), InstanceKind::PrefillOnly);
        }
        for &(len, seed, at) in donor_prompts {
            t.record(InstanceId(0), &toks(len, seed), at);
        }
        t
    }

    fn rec(ids: &[(u32, f64)]) -> Vec<Recipient> {
        ids.iter()
            .map(|&(id, pressure)| Recipient {
                id: InstanceId(id),
                pressure,
            })
            .collect()
    }

    #[test]
    fn deep_hot_prefixes_move_cold_tails_drop() {
        // 4-block deep+hot, 1-block shallow, 3-block stale.
        let t = tree_with(&[(16, 1, 100.0), (4, 2, 100.0), (12, 3, 1.0)]);
        let cfg = PlannerConfig {
            min_depth_blocks: 2,
            max_age_s: 50.0,
            max_blocks: None,
        };
        let plan = plan_migration(
            &t,
            InstanceId(0),
            110.0,
            &rec(&[(1, 0.0)]),
            &cfg,
        );
        assert_eq!(plan.tasks.len(), 1);
        assert_eq!(plan.tasks[0].tokens, toks(16, 1));
        assert_eq!(plan.planned_blocks, 4);
        assert_eq!(plan.dropped_blocks, 1 + 3);
    }

    #[test]
    fn replicated_prefixes_skipped() {
        let mut t = tree_with(&[(16, 1, 1.0), (16, 2, 1.0)]);
        // Instance 2 already caches prompt 1 fully.
        t.record(InstanceId(2), &toks(16, 1), 2.0);
        let plan = plan_migration(
            &t,
            InstanceId(0),
            3.0,
            &rec(&[(1, 0.0), (2, 0.0)]),
            &PlannerConfig::default(),
        );
        assert_eq!(plan.tasks.len(), 1);
        assert_eq!(plan.tasks[0].tokens, toks(16, 2));
        assert_eq!(plan.replicated_blocks, 4);
    }

    #[test]
    fn recipients_chosen_by_pressure_then_spread() {
        let t = tree_with(&[(16, 1, 1.0), (16, 2, 1.0), (16, 3, 1.0)]);
        // Instance 2 is heavily pressured: everything should prefer 1
        // and 3, spreading between them.
        let plan = plan_migration(
            &t,
            InstanceId(0),
            2.0,
            &rec(&[(1, 0.0), (2, 0.9), (3, 0.0)]),
            &PlannerConfig::default(),
        );
        assert_eq!(plan.tasks.len(), 3);
        let to2 = plan.tasks.iter().filter(|t| t.to == InstanceId(2)).count();
        assert_eq!(to2, 0, "pressured recipient must be avoided: {plan:?}");
        let to1 = plan.tasks.iter().filter(|t| t.to == InstanceId(1)).count();
        let to3 = plan.tasks.iter().filter(|t| t.to == InstanceId(3)).count();
        assert!(to1 >= 1 && to3 >= 1, "load must spread: {plan:?}");
    }

    #[test]
    fn max_blocks_caps_and_prefers_deepest() {
        let t = tree_with(&[(8, 1, 1.0), (16, 2, 1.0), (12, 3, 1.0)]);
        let cfg = PlannerConfig {
            max_blocks: Some(7),
            ..Default::default()
        };
        let plan = plan_migration(
            &t,
            InstanceId(0),
            2.0,
            &rec(&[(1, 0.0)]),
            &cfg,
        );
        // Deepest-first: the 4-block and 3-block prompts fit (7), the
        // 2-block one is over cap.
        assert_eq!(plan.planned_blocks, 7);
        assert_eq!(plan.dropped_blocks, 2);
        assert_eq!(plan.tasks[0].tokens, toks(16, 2));
    }

    #[test]
    fn no_recipients_drops_everything() {
        let t = tree_with(&[(16, 1, 1.0)]);
        let plan = plan_migration(
            &t,
            InstanceId(0),
            2.0,
            &[],
            &PlannerConfig::default(),
        );
        assert!(plan.tasks.is_empty());
        assert_eq!(plan.dropped_blocks, 4);
        // The donor itself is never a recipient.
        let plan = plan_migration(
            &t,
            InstanceId(0),
            2.0,
            &rec(&[(0, 0.0)]),
            &PlannerConfig::default(),
        );
        assert!(plan.tasks.is_empty());
    }
}
